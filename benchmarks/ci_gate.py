"""Scheduled-lane perf gate: fail when a smoke metric regresses vs history.

Reads ``BENCH_history.jsonl`` (one JSON record per smoke run, appended by
``benchmarks.run --smoke --history``) and compares the newest record's
``--field`` against the best of the last ``--window`` records that carry it
*and* were measured on the same platform — QPS numbers are not comparable
across machines, so a cache-miss run whose only prior records came from a
different box is skipped, not failed. Records from before the field existed are skipped too, and a
history with fewer than two comparable records passes trivially.

``--direction`` picks the metric's polarity: ``max`` (default) for
higher-is-better fields like ``graph_qps`` (baseline = window max, fail when
the new value drops more than ``tolerance`` below it); ``min`` for
lower-is-better fields like ``build_seconds`` (baseline = window min, fail
when the new value rises more than ``tolerance`` above it).
"""
from __future__ import annotations

import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--history", required=True, metavar="PATH",
                    help="BENCH_history.jsonl path")
    ap.add_argument("--field", default="graph_qps",
                    help="history field to gate on (default: graph_qps)")
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed relative regression, e.g. 0.2 = 20%% "
                         "(default)")
    ap.add_argument("--direction", choices=("max", "min"), default="max",
                    help="max: higher is better (QPS); min: lower is better "
                         "(build seconds)")
    ap.add_argument("--window", type=int, default=5,
                    help="gate against the best of the last N same-platform "
                         "records (default 5) so slow regressions can't "
                         "ratchet the baseline down run by run")
    args = ap.parse_args()

    try:
        with open(args.history) as f:
            lines = [json.loads(line) for line in f if line.strip()]
    except FileNotFoundError:
        print(f"ci_gate: no history at {args.history}; skipping")
        return
    vals = [(rec.get("commit", "?"), rec[args.field], rec.get("platform"))
            for rec in lines if rec.get(args.field) is not None]
    if len(vals) < 2:
        print(f"ci_gate: {len(vals)} record(s) with {args.field}; skipping")
        return
    cur_commit, cur, cur_platform = vals[-1]
    same_box = [v for v in vals[:-1] if v[2] == cur_platform]
    if not same_box:
        print(f"ci_gate: no prior {args.field} record from this platform "
              f"({cur_platform}); skipping")
        return
    # baseline = best of the last window, not just the previous record —
    # anchoring on the previous run alone would let sub-tolerance
    # regressions compound silently across runs (a 15%-per-run slide never
    # trips a 20% gate measured run-over-run)
    window = same_box[-args.window:]
    pick = max if args.direction == "max" else min
    prev_commit, prev = pick(((c, v) for c, v, _ in window),
                             key=lambda t: t[1])
    if args.direction == "max":
        bound = (1.0 - args.tolerance) * prev
        failed = cur < bound
        bound_name = "floor"
    else:
        bound = (1.0 + args.tolerance) * prev
        failed = cur > bound
        bound_name = "ceiling"
    verdict = "REGRESSION" if failed else "OK"
    print(f"ci_gate: {args.field} best-of-{len(window)} {prev:.1f} "
          f"({prev_commit}) -> {cur:.1f} ({cur_commit}); {bound_name} "
          f"{bound:.1f} [{verdict}]")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
