"""Shared benchmark utilities: datasets, timers, CSV emission."""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import obs
from repro.core import (ANY_OVERLAP, EngineConfig, MSTGIndex, QueryEngine,
                        SearchRequest)
from repro.data import make_range_dataset

QUICK = os.environ.get("BENCH_QUICK", "0") == "1"
N = 1200 if QUICK else 3000
D = 32
Q = 16 if QUICK else 32
K = 10

_cache = {}


def bench_dataset(dist: str = "uniform", n: int = None, seed: int = 0):
    key = (dist, n or N, seed)
    if key not in _cache:
        _cache[key] = make_range_dataset(n=n or N, d=D, n_queries=Q,
                                         quantize=128, dist=dist, seed=seed)
    return _cache[key]


def bench_index(ds=None, variants=("T", "Tp", "Tpp"), m=12, ef_con=64):
    ds = ds or bench_dataset()
    key = ("idx", id(ds), variants, m, ef_con)
    if key not in _cache:
        _cache[key] = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=variants,
                                m=m, ef_con=ef_con)
    return _cache[key]


def bench_engine(idx=None, route: str = "auto", **kw):
    idx = idx or bench_index()
    key = ("engine", id(idx), route, tuple(sorted(kw.items())))
    if key not in _cache:
        _cache[key] = QueryEngine(idx, config=EngineConfig(route=route, **kw))
    return _cache[key]


def request(queries, qlo, qhi, predicate=ANY_OVERLAP, k=K, ef=64, route=None):
    """Declarative-API request used by every experiment (route=None -> the
    engine's default; experiments pin "graph"/"pruned"/"flat" explicitly)."""
    return SearchRequest(queries, (qlo, qhi), predicate, k=k, ef=ef,
                         route=route)


_last_timing: dict = {}


def time_call(fn, *args, repeats: int = 3, best: bool = False,
              name: str = None, **kw):
    """Time ``fn``: mean over ``repeats`` by default; ``best=True`` takes the
    fastest repeat instead — the standard filter for scheduler noise on
    shared CI machines, used by the smoke lane's QPS rows.

    Every repeat is also recorded into the process obs registry
    (``bench_repeat_ms{call=<name or fn name>}``) and into the module-level
    :func:`last_timing` summary, so benches can report p50/p95 spread
    alongside the best-of-N headline without changing the return shape."""
    fn(*args, **kw)  # warmup / compile
    label = name or getattr(fn, "__name__", "call") or "call"
    hist = obs.get_registry().histogram(
        "bench_repeat_ms", "per-repeat wall time of time_call benchmarks",
        labels=("call",), lo_ms=1e-3, hi_ms=6e4)
    times = []
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        dt = time.perf_counter() - t0
        times.append(dt)
        hist.observe(dt * 1e3, call=label)
    srt = sorted(times)
    _last_timing.clear()
    _last_timing.update({
        "call": label,
        "repeats": repeats,
        "best_s": srt[0],
        "mean_s": sum(times) / len(times),
        "p50_s": srt[len(srt) // 2],
        "p95_s": srt[min(len(srt) - 1, int(0.95 * len(srt)))],
    })
    return (min(times) if best else sum(times) / len(times)), out


def last_timing() -> dict:
    """Per-repeat spread of the most recent :func:`time_call`:
    ``{call, repeats, best_s, mean_s, p50_s, p95_s}`` (empty before any
    call). Lets callers report percentile spread next to the headline
    number without widening time_call's ``(time, out)`` return."""
    return dict(_last_timing)


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
