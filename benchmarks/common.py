"""Shared benchmark utilities: datasets, timers, CSV emission."""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (ANY_OVERLAP, EngineConfig, MSTGIndex, QueryEngine,
                        SearchRequest)
from repro.data import make_range_dataset

QUICK = os.environ.get("BENCH_QUICK", "0") == "1"
N = 1200 if QUICK else 3000
D = 32
Q = 16 if QUICK else 32
K = 10

_cache = {}


def bench_dataset(dist: str = "uniform", n: int = None, seed: int = 0):
    key = (dist, n or N, seed)
    if key not in _cache:
        _cache[key] = make_range_dataset(n=n or N, d=D, n_queries=Q,
                                         quantize=128, dist=dist, seed=seed)
    return _cache[key]


def bench_index(ds=None, variants=("T", "Tp", "Tpp"), m=12, ef_con=64):
    ds = ds or bench_dataset()
    key = ("idx", id(ds), variants, m, ef_con)
    if key not in _cache:
        _cache[key] = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=variants,
                                m=m, ef_con=ef_con)
    return _cache[key]


def bench_engine(idx=None, route: str = "auto", **kw):
    idx = idx or bench_index()
    key = ("engine", id(idx), route, tuple(sorted(kw.items())))
    if key not in _cache:
        _cache[key] = QueryEngine(idx, config=EngineConfig(route=route, **kw))
    return _cache[key]


def request(queries, qlo, qhi, predicate=ANY_OVERLAP, k=K, ef=64, route=None):
    """Declarative-API request used by every experiment (route=None -> the
    engine's default; experiments pin "graph"/"pruned"/"flat" explicitly)."""
    return SearchRequest(queries, (qlo, qhi), predicate, k=k, ef=ef,
                         route=route)


def time_call(fn, *args, repeats: int = 3, best: bool = False, **kw):
    """Time ``fn``: mean over ``repeats`` by default; ``best=True`` takes the
    fastest repeat instead — the standard filter for scheduler noise on
    shared CI machines, used by the smoke lane's QPS rows."""
    fn(*args, **kw)  # warmup / compile
    times = []
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        times.append(time.perf_counter() - t0)
    return (min(times) if best else sum(times) / len(times)), out


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
