"""Appendix experiments: Exp.10 (|A| cardinality), Exp.11 (k), Exp.12/13
(ef_con / M build params) — parameter-robustness of MSTG."""
import numpy as np

from repro.core import ANY_OVERLAP, MSTGIndex, MSTGSearcher
from repro.data import (make_range_dataset, make_queries, brute_force_topk,
                        recall_at_k, relative_distance_error)

from .common import Q, QUICK, emit, time_call


def run():
    # Exp.10: attribute cardinality |A|
    for K in ((32, 128) if QUICK else (32, 128, 512)):
        ds = make_range_dataset(n=1500, d=32, n_queries=Q, quantize=K, seed=31)
        idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp"),
                        m=12, ef_con=64)
        gs = MSTGSearcher(idx)
        qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.1, seed=32)
        tids, tds = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                     qlo, qhi, ANY_OVERLAP, 10)
        dt, (ids, dd) = time_call(lambda: gs.search(ds.queries, qlo, qhi,
                                                    ANY_OVERLAP, k=10, ef=64))
        emit(f"exp10/cardA{idx.domain.K}", dt / Q * 1e6,
             f"recall@10={recall_at_k(np.asarray(ids), tids):.3f};"
             f"rde={relative_distance_error(np.asarray(dd), tds):.4f};"
             f"levels={idx.variants['T'].Lv}")

    # Exp.11: k sweep (fixed index)
    ds = make_range_dataset(n=1500, d=32, n_queries=Q, quantize=128, seed=33)
    idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp"),
                    m=12, ef_con=64)
    gs = MSTGSearcher(idx)
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.15, seed=34)
    for k in (1, 10, 50):
        tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                   qlo, qhi, ANY_OVERLAP, k)
        dt, (ids, _) = time_call(lambda: gs.search(ds.queries, qlo, qhi,
                                                   ANY_OVERLAP, k=k,
                                                   ef=max(64, 2 * k)))
        emit(f"exp11/k{k}", dt / Q * 1e6,
             f"recall@{k}={recall_at_k(np.asarray(ids), tids):.3f}")

    # Exp.12/13: build params M (out-degree) and ef_con
    if not QUICK:
        tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                   qlo, qhi, ANY_OVERLAP, 10)
        for m, efc in ((8, 32), (12, 64), (16, 96)):
            idx2 = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp"),
                             m=m, ef_con=efc)
            gs2 = MSTGSearcher(idx2)
            dt, (ids, _) = time_call(lambda: gs2.search(
                ds.queries, qlo, qhi, ANY_OVERLAP, k=10, ef=64))
            emit(f"exp12/m{m}_efcon{efc}", dt / Q * 1e6,
                 f"recall@10={recall_at_k(np.asarray(ids), tids):.3f};"
                 f"build_s={sum(idx2.build_seconds.values()):.1f};"
                 f"bytes={idx2.index_bytes()}")
