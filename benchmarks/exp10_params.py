"""Appendix experiments: Exp.10 (|A| cardinality), Exp.11 (k), Exp.12/13
(ef_con / M build params) — parameter-robustness of MSTG."""
import numpy as np

from repro.core import MSTGIndex, Overlaps, QueryEngine
from repro.data import (make_range_dataset, make_queries, brute_force_topk,
                        relative_distance_error)

from .common import Q, QUICK, emit, request, time_call


def run():
    pred = Overlaps()
    # Exp.10: attribute cardinality |A|
    for K in ((32, 128) if QUICK else (32, 128, 512)):
        ds = make_range_dataset(n=1500, d=32, n_queries=Q, quantize=K, seed=31)
        idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp"),
                        m=12, ef_con=64)
        eng = QueryEngine(idx)
        qlo, qhi = make_queries(ds, pred.mask, 0.1, seed=32)
        tids, tds = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                     qlo, qhi, pred.mask, 10)
        req = request(ds.queries, qlo, qhi, pred, k=10, route="graph")
        dt, res = time_call(eng.search, req)
        emit(f"exp10/cardA{idx.domain.K}", dt / Q * 1e6,
             f"recall@10={res.recall_vs(tids):.3f};"
             f"rde={relative_distance_error(np.asarray(res.dists), tds):.4f};"
             f"levels={idx.variants['T'].Lv}")

    # Exp.11: k sweep (fixed index)
    ds = make_range_dataset(n=1500, d=32, n_queries=Q, quantize=128, seed=33)
    idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp"),
                    m=12, ef_con=64)
    eng = QueryEngine(idx)
    qlo, qhi = make_queries(ds, pred.mask, 0.15, seed=34)
    for k in (1, 10, 50):
        tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                   qlo, qhi, pred.mask, k)
        req = request(ds.queries, qlo, qhi, pred, k=k, ef=max(64, 2 * k),
                      route="graph")
        dt, res = time_call(eng.search, req)
        emit(f"exp11/k{k}", dt / Q * 1e6,
             f"recall@{k}={res.recall_vs(tids):.3f}")

    # Exp.12/13: build params M (out-degree) and ef_con
    if not QUICK:
        tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                   qlo, qhi, pred.mask, 10)
        for m, efc in ((8, 32), (12, 64), (16, 96)):
            idx2 = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp"),
                             m=m, ef_con=efc)
            eng2 = QueryEngine(idx2)
            req = request(ds.queries, qlo, qhi, pred, k=10, route="graph")
            dt, res = time_call(eng2.search, req)
            emit(f"exp12/m{m}_efcon{efc}", dt / Q * 1e6,
                 f"recall@10={res.recall_vs(tids):.3f};"
                 f"build_s={sum(idx2.build_seconds.values()):.1f};"
                 f"bytes={idx2.index_bytes()}")
