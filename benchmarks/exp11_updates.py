"""Exp. 11: streaming updates — insert/delete/query interleave on the
segmented MSTG.

Measures what the static experiments cannot: update throughput (upserts +
deletes into the delta buffer, ops/sec), flush/compact cost, query service
during churn, and **update_recall** — recall of the streamed index
(segments + tombstones + unflushed delta) after a 10% insert / 5% delete
churn, with a from-scratch static ``MSTGIndex.build`` over the post-churn
corpus as the reference (the EMA-style deployability question: does serving
a live corpus cost recall?).

``--smoke`` runs a small fixed configuration, prints a JSON report, and
exits non-zero if ``update_recall`` drops below 0.95 — the CI gate.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (ANY_OVERLAP, EngineConfig, IndexSpec, MSTGIndex,
                        QueryEngine, SearchRequest)
from repro.data import (RangeDataset, brute_force_topk, make_queries,
                        make_range_dataset, recall_at_k)
from repro.streaming import SegmentedIndex

from .common import K, QUICK, emit

RECALL_GATE = 0.95


def run_churn(n: int = 800, d: int = 32, n_queries: int = 16, k: int = K,
              insert_frac: float = 0.10, delete_frac: float = 0.05,
              selectivity: float = 0.05, batch: int = 32, seed: int = 0,
              spec: IndexSpec = None,
              engine_config: EngineConfig = None) -> dict:
    """Bulk-load -> flush -> churn (interleaved upserts/deletes) -> measure.

    Returns a flat dict of metrics; ``update_recall`` is the streamed
    index's recall@k against the static rebuild's results on the identical
    post-churn corpus (1.0 = updates cost nothing vs a full rebuild)."""
    spec = spec or IndexSpec(variants=("T", "Tp"), m=12, ef_con=64)
    engine_config = engine_config or EngineConfig()
    ds = make_range_dataset(n=n, d=d, n_queries=n_queries, quantize=64,
                            dist="uniform", seed=seed)
    fresh = make_range_dataset(n=max(int(n * insert_frac), 1), d=d,
                               n_queries=1, quantize=64, dist="uniform",
                               seed=seed + 1)
    corpus = {int(i): (ds.vectors[i], float(ds.lo[i]), float(ds.hi[i]))
              for i in range(n)}

    sidx = SegmentedIndex(spec, engine_config=engine_config)
    t0 = time.perf_counter()
    half = n // 2
    sidx.add(np.arange(half), ds.vectors[:half], ds.lo[:half], ds.hi[:half])
    sidx.flush()
    sidx.add(np.arange(half, n), ds.vectors[half:], ds.lo[half:], ds.hi[half:])
    sidx.flush()
    bulk_seconds = time.perf_counter() - t0

    # interleaved churn: batches of upserts with deletes mixed in
    rng = np.random.default_rng(seed + 2)
    ins_ids = np.arange(n, n + fresh.n)
    del_ids = rng.choice(n, size=max(int(n * delete_frac), 1), replace=False)
    n_ops = 0
    t0 = time.perf_counter()
    di = 0
    for s in range(0, fresh.n, batch):
        e = min(s + batch, fresh.n)
        sidx.add(ins_ids[s:e], fresh.vectors[s:e], fresh.lo[s:e], fresh.hi[s:e])
        n_ops += e - s
        de = min(di + max(batch // 2, 1), len(del_ids))
        if de > di:
            sidx.delete(del_ids[di:de])
            n_ops += de - di
            di = de
    if di < len(del_ids):
        sidx.delete(del_ids[di:])
        n_ops += len(del_ids) - di
    churn_seconds = time.perf_counter() - t0
    for i, e in enumerate(ins_ids):
        corpus[int(e)] = (fresh.vectors[i], float(fresh.lo[i]),
                          float(fresh.hi[i]))
    for e in del_ids:
        corpus.pop(int(e))

    # post-churn live corpus, canonical (ext-id) order
    live = np.array(sorted(corpus), np.int64)
    vecs = np.stack([corpus[int(e)][0] for e in live])
    lo = np.array([corpus[int(e)][1] for e in live])
    hi = np.array([corpus[int(e)][2] for e in live])
    post = RangeDataset(vectors=vecs, lo=lo, hi=hi, queries=ds.queries,
                        span=ds.span)
    qlo, qhi = make_queries(post, ANY_OVERLAP, selectivity, seed=seed + 3)
    tids, _ = brute_force_topk(vecs, lo, hi, post.queries, qlo, qhi,
                               ANY_OVERLAP, k)
    truth_ext = np.where(tids >= 0, live[np.clip(tids, 0, None)], -1)

    req = SearchRequest(post.queries, (qlo, qhi), ANY_OVERLAP, k=k, ef=96)
    res = sidx.search(req)          # streamed: 2 segments + tombs + delta
    t0 = time.perf_counter()
    sidx.search(req)
    q_seconds = time.perf_counter() - t0

    t0 = time.perf_counter()
    static = MSTGIndex.build(spec, vecs, lo, hi)
    rebuild_seconds = time.perf_counter() - t0
    seng = QueryEngine(static, config=engine_config)
    sres = seng.search(req)
    static_ext = np.where(sres.ids >= 0, live[np.clip(sres.ids, 0, None)], -1)

    streamed_recall = recall_at_k(res.ids, truth_ext)
    static_recall = recall_at_k(static_ext, truth_ext)
    update_recall = recall_at_k(res.ids, static_ext)

    t0 = time.perf_counter()
    sidx.flush()
    comp = sidx.compact(full=True)
    compact_seconds = time.perf_counter() - t0
    return {
        "n": n, "d": d, "k": k, "n_queries": n_queries,
        "inserted": int(fresh.n), "deleted": int(len(del_ids)),
        "bulk_load_seconds": round(bulk_seconds, 4),
        "update_ops_per_sec": round(n_ops / churn_seconds, 1),
        "query_qps_streamed": round(n_queries / q_seconds, 1),
        "update_recall": round(update_recall, 4),
        "streamed_recall_at_k": round(streamed_recall, 4),
        "static_recall_at_k": round(static_recall, 4),
        "static_rebuild_seconds": round(rebuild_seconds, 4),
        "compact_seconds": round(compact_seconds, 4),
        "compacted_rows": comp["rows"], "dropped_tombstones": comp["dropped"],
    }


def run():
    """CSV lane (benchmarks.run): one churn pass at bench scale."""
    r = run_churn(n=600 if QUICK else 1500, d=32, n_queries=16)
    emit("exp11/updates", 1e6 / max(r["update_ops_per_sec"], 1e-9),
         f"ops/sec={r['update_ops_per_sec']};"
         f"update_recall={r['update_recall']};"
         f"streamed_recall={r['streamed_recall_at_k']};"
         f"rebuild_s={r['static_rebuild_seconds']}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small fixed config; JSON report; exit 1 if "
                         f"update_recall < {RECALL_GATE}")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="also write the JSON report to PATH")
    args = ap.parse_args()
    if args.smoke:
        report = run_churn(n=400, d=24, n_queries=12,
                           spec=IndexSpec(variants=("T", "Tp"), m=8,
                                          ef_con=48))
    else:
        report = run_churn()
    print(json.dumps(report, indent=2, sort_keys=True))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
    if args.smoke and report["update_recall"] < RECALL_GATE:
        print(f"FAIL: update_recall {report['update_recall']} < {RECALL_GATE}",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
