"""Exp. 12 — wavefront graph-search diagnostics (beyond-paper §Perf).

Quantifies the two pathologies the wavefront rework removes from the
Algorithm-4 loop and the speedup it buys:

* **steps-to-convergence histogram** — per-query convergence steps of the
  dominant plan slot (the skew is why a single global ``lax.while_loop``
  makes every query pay for the slowest one);
* **wasted-eval fraction** — fraction of candidate distance evaluations spent
  on already-converged rows: the single-loop value (computed analytically
  from the per-query convergence steps) vs the chunked-compaction driver's
  actual value;
* **graph-route QPS** — single-loop vs chunked-compaction engine throughput
  at a serving-style batch size.
"""
from __future__ import annotations

import numpy as np

from repro.core import ANY_OVERLAP, SearchRequest
from repro.core.search import mstg_graph_search_chunked
from repro.data import make_queries

from .common import K, bench_dataset, bench_engine, bench_index, emit, time_call

SINGLE_LOOP = 0                  # chunk=0 pins the single-while_loop driver


def _mixed_queries(ds, mask: int, sel, seed: int = 11):
    """Query ranges at one selectivity, or a contiguous mix when ``sel`` is a
    tuple — heterogeneous batches are where convergence skew (and therefore
    compaction) actually matters."""
    sels = tuple(sel) if isinstance(sel, (tuple, list)) else (sel,)
    Q = ds.queries.shape[0]
    qlo = np.empty(Q)
    qhi = np.empty(Q)
    per = max(Q // len(sels), 1)
    for i, s_ in enumerate(sels):
        a, b = make_queries(ds, mask, s_, seed=seed + i)
        part = slice(i * per, Q if i == len(sels) - 1 else (i + 1) * per)
        qlo[part], qhi[part] = a[part], b[part]
    return qlo, qhi


def wavefront_metrics(eng, ds, mask: int = ANY_OVERLAP, sel=0.05,
                      ef: int = 64, k: int = K, chunk: int = 16,
                      fanout: int = 1) -> dict:
    """Steps/waste diagnostics for the dominant plan slot of one query batch
    (``sel`` may be a tuple for a mixed-selectivity batch).

    Reused by the smoke lane (``BENCH_smoke.json``'s ``wasted_eval_frac``),
    so it must stay cheap at smoke sizes.
    """
    qlo, qhi = _mixed_queries(ds, mask, sel)
    slots = eng.plan(mask, qlo, qhi)
    slot = max(slots, key=lambda s: int(np.sum((s.version >= 0)
                                               & (s.key_lo <= s.key_hi))))
    dv = eng.graph_dev(slot.variant)
    common = dict(k=k, ef=ef, max_steps=(4 * ef + 64) // fanout + 8,
                  Kpad=dv.meta.Kpad, fanout=fanout)
    _, _, st_chunked = mstg_graph_search_chunked(
        dv.tree(), ds.queries, slot.version, slot.key_lo, slot.key_hi,
        chunk=chunk, with_stats=True, **common)
    conv = st_chunked["conv_steps"]
    Q = conv.shape[0]
    g = max(int(st_chunked["steps"]), 1)
    # single-loop waste: every row pays all g global steps, only conv of
    # them advance it
    wasted_single = 1.0 - float(conv.sum()) / (Q * g)
    edges = [0, 8, 16, 32, 64, 128, 1 << 30]
    hist, _ = np.histogram(conv, bins=edges)
    return {
        "Q": Q,
        "slot_variant": slot.variant,
        "steps_global": int(st_chunked["steps"]),
        "conv_steps_p50": float(np.percentile(conv, 50)),
        "conv_steps_p90": float(np.percentile(conv, 90)),
        "conv_steps_max": int(conv.max(initial=0)),
        "steps_hist_edges": edges[:-1],
        "steps_hist": hist.tolist(),
        "wasted_eval_frac_single": wasted_single,
        "wasted_eval_frac_chunked": float(st_chunked["wasted_eval_frac"]),
    }


def run():
    ds = bench_dataset()
    idx = bench_index(ds)
    eng = bench_engine(idx, route="graph")
    mask = ANY_OVERLAP
    m = wavefront_metrics(eng, ds, mask, sel=(0.02, 0.30))
    emit("exp12/steps_to_convergence", m["steps_global"],
         f"p50={m['conv_steps_p50']:.0f};p90={m['conv_steps_p90']:.0f};"
         f"max={m['conv_steps_max']};hist={m['steps_hist']}")
    emit("exp12/wasted_eval_frac", m["wasted_eval_frac_single"] * 100,
         f"single={m['wasted_eval_frac_single']:.3f};"
         f"chunked={m['wasted_eval_frac_chunked']:.3f}")

    qlo, qhi = make_queries(ds, mask, 0.05, seed=11)
    Qn = ds.queries.shape[0]
    for label, chunk in (("single_loop", SINGLE_LOOP), ("chunked16", 16)):
        req = SearchRequest(ds.queries, (qlo, qhi), mask, k=K, ef=64,
                            route="graph", chunk=chunk)
        dt, _ = time_call(eng.search, req)
        emit(f"exp12/graph_qps_{label}", dt / Qn * 1e6, f"qps={Qn/dt:.1f}")
