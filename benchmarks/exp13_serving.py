"""Exp. 13 — serving latency/throughput: async continuous batching vs sync
tick (beyond-paper §Serving).

Both servers execute the same workload on the same engine with the graph
route pinned, so every served answer is bit-identical to solo execution and
recall is equal **by construction** — the comparison isolates the serving
discipline:

* **sync** — back-to-back :meth:`RetrievalServer.tick` calls: each tick runs
  its whole accumulated queue to global convergence (stragglers hold the
  batch, arrivals wait out the tick, small batches pad up);
* **async** — :class:`AsyncRetrievalServer`: bounded admission, micro-batch
  dispatch, and wavefront slot refill keep the device batch occupied while
  requests enter/leave mid-flight.

Load generation: a **closed loop** (fixed backlog, one giant batch — the
regime that favors sync's whole-queue tick) and an **open loop** (Poisson
arrivals replayed on the wall clock at a sweep of offered rates, long
enough that queueing reaches steady state: p50/p95/p99 end-to-end latency,
shed + deadline-missed counts per rate). The stream length matters: short
bursts degenerate into closed-loop runs that hide the serving-discipline
difference. At steady state the sync tick pays its structural costs —
arrivals wait out the whole in-progress tick, and moderate queues keep the
batch under the engine's chunked-driver threshold where the single-loop
search re-traces per call — while the async front end keeps capped-slot
wavefront streams warm and refills them mid-flight.

**Sustained QPS is goodput under an SLO** (the MLPerf server-scenario
convention): answers delivered within a latency budget per second — a mode
has not "sustained" a rate if latency diverges while a backlog absorbs the
excess, which is exactly what the unbounded sync tick does at overload.
The budget is platform-relative: ``slo_ms = max(50, 25 x solo_p50)`` with
``solo_p50`` the measured single-query graph-route latency, both recorded
in the report. Async requests carry ``deadline_ms = slo_ms`` so admission
control can do its job (EDF + shed-expired); sync has no deadline concept
— late answers are counted against it post hoc, the client-side
abandonment view. Each mode's headline number is its peak goodput over the
**under-load** rates (offered ≥ 0.5x sync's closed-loop capacity): that is
the load a serving SLA is provisioned for. The lightest swept rate is kept
in the report to document the keep-up regime, where any discipline serves
everything and the comparison is ~1.0 by construction. Per-rate rows keep
both raw and goodput curves plus p50/p99 and shed / deadline-missed
counts.

Writes ``BENCH_serving.json``; ``--history`` appends ``serving_qps`` +
``serving_p99_ms`` (gated by ``ci_gate --direction min``) to the shared
bench trajectory file.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time

import numpy as np

from repro.core import (ANY_OVERLAP, QUERY_CONTAINED, EngineConfig,
                        MSTGIndex, QueryEngine, Rejected, SearchRequest,
                        intervals as iv)
from repro.data import (brute_force_topk, make_queries, make_range_dataset,
                        recall_at_k)
from repro.serving import AsyncRetrievalServer, RetrievalServer, SLOPolicy

from .common import emit, time_call


def make_workload(ds, masks, sel: float = 0.10, seed: int = 5):
    """Per-request (mask, qlo, qhi, query_row) tuples, masks round-robin —
    a mixed-predicate stream is what splits the sync server into per-mask
    groups."""
    per_mask = {}
    for m in masks:
        per_mask[m] = make_queries(ds, m, sel, seed=seed)
    Q = ds.queries.shape[0]
    work = []
    for i in range(Q):
        m = masks[i % len(masks)]
        qlo, qhi = per_mask[m]
        work.append((m, float(qlo[i]), float(qhi[i]), i))
    return work


def poisson_arrivals(n: int, rate_qps: float, seed: int = 9) -> np.ndarray:
    """Cumulative arrival offsets (seconds) of an open-loop Poisson stream."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_qps, n))


# both servers expose the same ServerMetrics snapshot schema (repro.obs
# StreamingHistogram summaries underneath); the per-rate rows read this one
# shared view from each so the artifact never needs per-server parsing
SNAPSHOT_KEYS = ("submitted", "admitted", "served", "shed",
                 "deadline_missed", "degraded", "queue_wait_ms", "e2e_ms")


def _server_view(snap: dict) -> dict:
    return {k: snap[k] for k in SNAPSHOT_KEYS}


def _latency_stats(lat_ms) -> dict:
    if not len(lat_ms):
        return {"p50": None, "p95": None, "p99": None, "mean": None}
    a = np.asarray(lat_ms)
    return {"p50": round(float(np.percentile(a, 50)), 3),
            "p95": round(float(np.percentile(a, 95)), 3),
            "p99": round(float(np.percentile(a, 99)), 3),
            "mean": round(float(a.mean()), 3)}


def run_sync_open(engine, embed_fn, work, arrivals, k, ef,
                  slo_ms=None) -> dict:
    """Replay the arrival trace against back-to-back ``tick()`` calls: every
    request joins the next tick after its arrival; a tick serves its whole
    queue at once. ``slo_ms`` only scores goodput — the sync server has no
    deadline concept, so every answer is produced and late ones are counted
    against it (client-side abandonment)."""
    srv = RetrievalServer(engine, embed_fn, k=k, ef=ef)
    lat = {}
    t0 = time.perf_counter()
    nxt = 0
    submitted = {}
    order = 0
    while len(lat) < len(work):
        now = time.perf_counter() - t0
        while nxt < len(work) and arrivals[nxt] <= now:
            m, qlo, qhi, row = work[nxt]
            srv.submit(row, qlo, qhi, m)
            submitted[order] = nxt
            order += 1
            nxt += 1
        if not srv.queue:
            if nxt < len(work):
                time.sleep(max(0.0, arrivals[nxt] - (time.perf_counter() - t0)))
            continue
        base = order - len(srv.queue)
        res = srv.tick()
        done = time.perf_counter() - t0
        for qi in res:
            ridx = submitted[base + qi]
            lat[ridx] = ((done - arrivals[ridx]) * 1e3, res[qi])
    wall = time.perf_counter() - t0
    lat_ms = [v[0] for v in lat.values()]
    good = (sum(1 for v in lat_ms if v <= slo_ms) if slo_ms is not None
            else len(lat_ms))
    return {"lat": lat, "wall_s": wall,
            "qps": round(len(lat) / wall, 2),
            "goodput_qps": round(good / wall, 2),
            "stats": _latency_stats(lat_ms),
            "shed": 0, "deadline_missed": len(lat_ms) - good,
            "server": _server_view(srv.snapshot())}


def run_async_open(engine, embed_fn, work, arrivals, k, ef,
                   policy=None, deadline_ms=None) -> dict:
    """Replay the same trace against the continuous-batching front end.
    ``deadline_ms`` rides on every request, so the scheduler's admission
    control (EDF ordering + shed-expired) is live during the replay."""
    # latency-provisioned depth: 64 in-flight rows keeps the in-service time
    # (Little's law: inflight / throughput) inside an interactive SLO.
    # bucket=32 caps both variant streams at 32-row slots: a handful of jit
    # shapes that warmup covers, so arrival timing can't surface fresh
    # compiles mid-replay (uncapped adaptive buckets retrace per pow2 shape
    # combo), while sparse fan-out streams still shrink below the cap
    srv = AsyncRetrievalServer(
        engine, embed_fn, k=k, ef=ef, max_inflight=64, bucket=32,
        policy=policy or SLOPolicy(max_wait_ms=1.0, max_batch=64))
    lat = {}
    shed = 0
    tickets = {}
    t0 = time.perf_counter()
    nxt = 0
    while len(lat) + shed < len(work):
        now = time.perf_counter() - t0
        while nxt < len(work) and arrivals[nxt] <= now:
            m, qlo, qhi, row = work[nxt]
            out = srv.submit(row, qlo, qhi, m, deadline_ms=deadline_ms)
            if isinstance(out, Rejected):
                shed += 1
            else:
                tickets[out] = nxt
            nxt += 1
        if srv.idle and nxt < len(work):
            time.sleep(max(0.0, arrivals[nxt] - (time.perf_counter() - t0)))
            continue
        for t, res in srv.step().items():
            ridx = tickets.get(t)
            if ridx is None:
                continue
            if isinstance(res, Rejected):
                shed += 1
            else:
                done = time.perf_counter() - t0
                lat[ridx] = ((done - arrivals[ridx]) * 1e3, res)
    wall = time.perf_counter() - t0
    snap = srv.snapshot()
    lat_ms = [v[0] for v in lat.values()]
    good = (sum(1 for v in lat_ms if v <= deadline_ms)
            if deadline_ms is not None else len(lat_ms))
    return {"lat": lat, "wall_s": wall,
            "qps": round(len(lat) / wall, 2),
            "goodput_qps": round(good / wall, 2),
            "stats": _latency_stats(lat_ms),
            "shed": shed, "deadline_missed": snap["deadline_missed"],
            "occupancy": round(snap.get("batch_occupancy", 1.0), 4),
            "refill_efficiency": round(snap.get("refill_efficiency", 1.0), 4),
            "refills": snap.get("refills", 0),
            "server": _server_view(snap)}


def run_closed(engine, embed_fn, work, k, ef, mode: str,
               repeats: int = 3) -> float:
    """Peak sustained QPS with the full workload as backlog (one shot per
    repeat, best-of)."""
    def sync_once():
        srv = RetrievalServer(engine, embed_fn, k=k, ef=ef)
        for m, qlo, qhi, row in work:
            srv.submit(row, qlo, qhi, m)
        return srv.tick()

    def async_once():
        srv = AsyncRetrievalServer(
            engine, embed_fn, k=k, ef=ef, max_inflight=128, bucket=64,
            policy=SLOPolicy(max_wait_ms=0.0, max_batch=128))
        for m, qlo, qhi, row in work:
            srv.submit(row, qlo, qhi, m)
        return srv.run_until_idle()

    fn = sync_once if mode == "sync" else async_once
    dt, out = time_call(fn, repeats=repeats, best=True)
    n_served = len([r for r in out.values()
                    if not isinstance(r, Rejected)])
    return round(n_served / dt, 2)


def _recall(ds, work, hits, k) -> float:
    """Recall@k of served answers vs brute force over the same predicate."""
    got, want = [], []
    for (m, qlo, qhi, row), hit in hits:
        tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi,
                                   ds.queries[row:row + 1],
                                   np.array([qlo]), np.array([qhi]), m, k)
        want.append(tids[0])
        got.append(hit.ids[:k])
    if not got:
        return 0.0
    return round(float(recall_at_k(np.stack(got), np.stack(want))), 4)


def run_serving_bench(out_path: str = "BENCH_serving.json", n: int = 2000,
                      d: int = 32, n_requests: int = 384, k: int = 10,
                      ef: int = 64, history_path: str = None,
                      rates=None) -> dict:
    report = {"schema": 1, "unix_time": time.time(),
              "platform": platform.platform(),
              "sizes": {"n": n, "d": d, "requests": n_requests, "k": k,
                        "ef": ef}}
    ds = make_range_dataset(n=n, d=d, n_queries=n_requests, quantize=128,
                            seed=0)
    idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp"), m=12,
                    ef_con=64)
    masks = (ANY_OVERLAP, QUERY_CONTAINED)
    report["masks"] = [iv.mask_name(m) for m in masks]
    work = make_workload(ds, masks, sel=0.10)
    embed_fn = lambda items: ds.queries[np.asarray(items)]
    # the graph route is pinned on both servers: answers are bit-identical
    # to solo execution on either path, so recall is equal by construction
    # (recorded once to document it)
    engine = QueryEngine(idx, config=EngineConfig(route="graph"))

    # platform-relative SLO anchor: solo single-query latency on this box
    def solo_once(i):
        m, qlo, qhi, row = work[i]
        return engine.execute(SearchRequest(
            ds.queries[row:row + 1], (np.array([qlo]), np.array([qhi])), m,
            k=k, ef=ef, route="graph"))
    solo_once(0)
    solo_ms = []
    for i in range(16):
        t0 = time.perf_counter()
        solo_once(i)
        solo_ms.append((time.perf_counter() - t0) * 1e3)
    solo_p50 = float(np.percentile(solo_ms, 50))
    slo_ms = round(max(50.0, 25.0 * solo_p50), 1)
    report["slo"] = {"solo_p50_ms": round(solo_p50, 2), "slo_ms": slo_ms,
                     "rule": "max(50, 25 * solo_p50)"}
    print(f"  solo p50={solo_p50:.2f} ms -> slo={slo_ms} ms")

    # warm both serving paths (jit traces for the pow2 buckets they touch)
    _ = run_closed(engine, embed_fn, work[:16], k, ef, "sync", repeats=1)
    _ = run_closed(engine, embed_fn, work[:16], k, ef, "async", repeats=1)

    sync_qps = run_closed(engine, embed_fn, work, k, ef, "sync")
    async_qps = run_closed(engine, embed_fn, work, k, ef, "async")
    report["closed_loop"] = {"sync_qps": sync_qps, "async_qps": async_qps,
                            "speedup": round(async_qps / sync_qps, 3)}

    if rates is None:
        # anchored to sync's closed-loop (giant-batch) capacity; low enough
        # that both modes' steady-state capacity is bracketed from below
        rates = [round(sync_qps * f, 1) for f in (0.3, 0.6, 1.0)]
    # unmeasured open-loop passes per mode at the sweep's extreme rates: the
    # open-loop batch compositions (small per-mask pow2 buckets, stream
    # concat/gather shape combos) differ from the closed-loop ones AND vary
    # with arrival timing, so a first-touch jit compile inside a measured run
    # would be charged to whichever rate ran first
    for warm_rate in (rates[0], rates[-1]):
        warm_arr = poisson_arrivals(len(work), warm_rate)
        run_sync_open(engine, embed_fn, work, warm_arr, k, ef, slo_ms=slo_ms)
        run_async_open(engine, embed_fn, work, warm_arr, k, ef,
                       deadline_ms=slo_ms)
    open_rows = []
    for rate in rates:
        arr = poisson_arrivals(len(work), rate)
        # best-of-2 per mode: one replay is a single sample of a timing-
        # dependent process; a stray compile or scheduler hiccup in either
        # mode would otherwise masquerade as a serving-discipline difference
        s = max((run_sync_open(engine, embed_fn, work, arr, k, ef,
                               slo_ms=slo_ms)
                 for _ in range(2)), key=lambda r: r["goodput_qps"])
        a = max((run_async_open(engine, embed_fn, work, arr, k, ef,
                                deadline_ms=slo_ms)
                 for _ in range(2)), key=lambda r: r["goodput_qps"])
        row = {"offered_qps": rate,
               "sync": {kk: s[kk] for kk in ("qps", "goodput_qps", "stats",
                                             "shed", "deadline_missed",
                                             "server")},
               "async": {kk: a[kk] for kk in ("qps", "goodput_qps", "stats",
                                              "shed", "deadline_missed",
                                              "occupancy",
                                              "refill_efficiency",
                                              "refills", "server")}}
        open_rows.append(row)
        print(f"  rate={rate}: sync good={s['goodput_qps']} qps={s['qps']} "
              f"p50={s['stats']['p50']} p99={s['stats']['p99']} | "
              f"async good={a['goodput_qps']} qps={a['qps']} "
              f"p50={a['stats']['p50']} p99={a['stats']['p99']} "
              f"shed={a['shed']} occ={a.get('occupancy')}")
        if rate == rates[-1]:
            # recall parity documented at the last (most stressed) rate
            report["recall"] = {
                "sync": _recall(ds, work,
                                [(work[i], v[1]) for i, v in
                                 s["lat"].items()], k),
                "async": _recall(ds, work,
                                 [(work[i], v[1].hit) for i, v in
                                  a["lat"].items()], k)}
    report["open_loop"] = open_rows
    # sustained QPS per mode = peak goodput over the under-load rates
    # (offered >= 0.5x sync's closed-loop capacity — the lighter rates
    # document the keep-up regime where every discipline serves everything);
    # each mode carries its own latency at its own peak
    loaded = [r for r in open_rows if r["offered_qps"] >= 0.5 * sync_qps]
    if not loaded:          # custom --rates sweep entirely below capacity
        loaded = open_rows
    best_a = max(loaded, key=lambda r: r["async"]["goodput_qps"])
    best_s = max(loaded, key=lambda r: r["sync"]["goodput_qps"])
    report["headline"] = {
        "serving_qps": best_a["async"]["goodput_qps"],
        "serving_p50_ms": best_a["async"]["stats"]["p50"],
        "serving_p99_ms": best_a["async"]["stats"]["p99"],
        "sync_qps": best_s["sync"]["goodput_qps"],
        "sync_p50_ms": best_s["sync"]["stats"]["p50"],
        "sync_p99_ms": best_s["sync"]["stats"]["p99"],
        "slo_ms": slo_ms,
        "speedup_open_loop": round(best_a["async"]["goodput_qps"]
                                   / max(best_s["sync"]["goodput_qps"],
                                         1e-9), 3),
        "speedup_closed_loop": report["closed_loop"]["speedup"],
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path}")
    print(json.dumps(report["headline"], indent=2))
    if history_path:
        record = {
            "commit": os.environ.get("GITHUB_SHA", "local")[:12],
            "unix_time": round(report["unix_time"], 1),
            "platform": report["platform"],
            "serving_qps": report["headline"]["serving_qps"],
            "serving_p99_ms": report["headline"]["serving_p99_ms"],
            "serving_speedup": report["headline"]["speedup_open_loop"],
        }
        with open(history_path, "a") as f:
            f.write(json.dumps(record, sort_keys=True) + "\n")
        print(f"appended {history_path}: {json.dumps(record, sort_keys=True)}")
    return report


def run():
    """CSV mode (benchmarks.run default lane): closed-loop sync vs async."""
    from .common import bench_dataset, bench_index, K
    ds = bench_dataset()
    idx = bench_index(ds)
    engine = QueryEngine(idx, config=EngineConfig(route="graph"))
    masks = (ANY_OVERLAP, QUERY_CONTAINED)
    work = make_workload(ds, masks, sel=0.10)
    embed_fn = lambda items: ds.queries[np.asarray(items)]
    _ = run_closed(engine, embed_fn, work[:8], K, 64, "sync", repeats=1)
    _ = run_closed(engine, embed_fn, work[:8], K, 64, "async", repeats=1)
    sync_qps = run_closed(engine, embed_fn, work, K, 64, "sync")
    async_qps = run_closed(engine, embed_fn, work, K, 64, "async")
    emit("exp13/sync_tick_qps", 1e6 / sync_qps, f"qps={sync_qps}")
    emit("exp13/async_qps", 1e6 / async_qps,
         f"qps={async_qps};speedup={async_qps / sync_qps:.2f}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI sizes; writes BENCH_serving.json")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--history", default=None, metavar="PATH",
                    help="append serving_qps/serving_p99_ms JSON line")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--requests", type=int, default=None)
    args = ap.parse_args()
    if args.smoke:
        run_serving_bench(out_path=args.out, n=args.n or 1200, d=32,
                          n_requests=args.requests or 384,
                          history_path=args.history)
    else:
        run_serving_bench(out_path=args.out, n=args.n or 4000, d=32,
                          n_requests=args.requests or 768,
                          history_path=args.history)


if __name__ == "__main__":
    main()
