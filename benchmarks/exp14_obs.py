"""Exp. 14 — observability overhead + trace-export sanity (PR 7 gate).

Two questions, answered in one artifact (``BENCH_obs.json``):

1. **What does instrumentation cost?** The same cold graph-route search
   the smoke lane times (identical sizes/seeds, best-of-7, selectivity
   cache cleared per call) is run twice — tracing off (the no-op fast path
   every production query takes) and ``SearchRequest(trace=True)``. The
   gated headline ``obs_overhead_pct`` is the **no-op instrumentation
   share** of an untraced request: spans-per-request (counted from the
   traced run) x the microbenchmarked no-op ``obs.span()`` cost, as a
   percentage of the untraced request time — a ratio of two same-box
   measurements, so it stays stable where raw cross-run wall clock does
   not, and it grows if either the span count on the hot path or the
   no-op path cost creeps up (``ci_gate --field obs_overhead_pct
   --direction min``). The traced-ON slowdown is recorded as
   ``trace_on_overhead_pct`` (informational: the traced path deliberately
   blocks on device results per kernel/chunk so spans measure work).

2. **Does the export pipeline still work?** One ``trace=True`` request
   through ``engine_auto`` on a 2-shard :class:`ShardedDeployment` must
   yield Chrome-trace JSON whose spans cover plan, route decision,
   per-shard search, and merge, with ``explain()`` rendering the same —
   the PR's acceptance scenario, re-checked on every scheduled run.

Because the traced-off measurement replicates the smoke lane's
``graph_qps`` row exactly, it is directly comparable against prior
same-platform ``graph_qps`` history records: when one exists,
``traced_off_vs_history`` records the < 5% no-op-overhead budget verdict
against the pre-PR baseline (hard-fail at the 20% band the graph_qps
gate uses — single cross-process samples swing past 5% on shared boxes). ``--history`` appends ``obs_overhead_pct`` (plus
``obs_graph_qps`` — namespaced so smoke's ``graph_qps`` gate never
compares across workloads) to the shared trajectory file.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import platform
import time

import numpy as np

from repro import obs
from repro.core import (ANY_OVERLAP, IndexSpec, MSTGIndex, QueryEngine,
                        SearchRequest, intervals as iv)
from repro.data import make_queries, make_range_dataset

from .common import last_timing, time_call

# mirror of the smoke lane's graph_qps row (run_smoke defaults) — the
# traced-off number here must stay comparable with smoke history records
SMOKE_N, SMOKE_D, SMOKE_Q, SMOKE_K, SMOKE_SEL = 800, 32, 16, 10, 0.05

REQUIRED_SPANS = ("sharded_search", "plan", "shard-0", "shard-1", "merge",
                  "search", "route")


def noop_span_ns(iters: int = 200_000) -> float:
    """ns per ``obs.span()`` enter/exit with no tracer active — the cost
    every untraced query pays at each instrumentation point."""
    t0 = time.perf_counter()
    for _ in range(iters):
        with obs.span("noop") as sp:
            sp.set("k", 1)
    return (time.perf_counter() - t0) / iters * 1e9


def trace_export_sanity(ds, k: int = SMOKE_K) -> dict:
    """The acceptance scenario: engine_auto + trace=True on a 2-shard
    host-merge deployment -> valid Chrome JSON covering plan / route /
    per-shard / merge, and explain() rendering the same spans."""
    from repro.distributed import DeploymentSpec, ShardedDeployment
    dep = ShardedDeployment.build(
        ds.vectors, ds.lo, ds.hi, mesh=None,
        spec=DeploymentSpec(n_shards=2,
                            index=IndexSpec(variants=("T", "Tp"), m=8,
                                            ef_con=48)))
    qlo, qhi = make_queries(ds, ANY_OVERLAP, SMOKE_SEL, seed=11)
    res = dep.execute(SearchRequest(ds.queries[:4], (qlo[:4], qhi[:4]),
                                    ANY_OVERLAP, k=k, trace=True))
    out = {"ok": False, "spans": [], "chrome_events": 0}
    if res.trace is None:
        out["error"] = "no trace attached"
        return out
    names = res.trace.span_names()
    out["spans"] = names
    chrome = json.loads(res.trace.to_json())
    events = chrome.get("traceEvents", [])
    out["chrome_events"] = len(events)
    missing = [s for s in REQUIRED_SPANS if s not in names]
    if missing:
        out["error"] = f"missing spans: {missing}"
        return out
    if not events or any(e.get("ph") != "X" for e in events):
        out["error"] = "traceEvents not complete ('X') events"
        return out
    rendered = res.explain()
    if not all(s in rendered for s in ("route:", "trace:", "shard[0]",
                                       "merge")):
        out["error"] = "explain() missing trace breakdown"
        return out
    out["ok"] = True
    return out


def compare_vs_history(history_path: str, platform_str: str,
                       qps_off: float, window: int = 5) -> dict:
    """Traced-off QPS vs the best same-platform smoke ``graph_qps`` of the
    last ``window`` history records — the < 5% budget vs the pre-PR
    baseline. Skipped (not failed) when no comparable record exists."""
    try:
        with open(history_path) as f:
            recs = [json.loads(line) for line in f if line.strip()]
    except FileNotFoundError:
        return {"available": False, "reason": f"no history at {history_path}"}
    prior = [r for r in recs if r.get("graph_qps") is not None
             and r.get("platform") == platform_str]
    if not prior:
        return {"available": False,
                "reason": "no same-platform graph_qps record"}
    base = max(r["graph_qps"] for r in prior[-window:])
    reg = (base - qps_off) / base * 100.0
    return {"available": True, "baseline_qps": base,
            "traced_off_qps": round(qps_off, 1),
            "regression_pct": round(reg, 2),
            "within_5pct": bool(reg < 5.0)}


def run_obs_bench(out_path: str = "BENCH_obs.json",
                  history_path: str = None,
                  baseline_history: str = "BENCH_history.jsonl") -> dict:
    report: dict = {"schema": 1, "unix_time": time.time(),
                    "platform": platform.platform(),
                    "sizes": {"n": SMOKE_N, "d": SMOKE_D,
                              "queries": SMOKE_Q, "k": SMOKE_K,
                              "sel": SMOKE_SEL}}

    ds = make_range_dataset(n=SMOKE_N, d=SMOKE_D, n_queries=SMOKE_Q,
                            quantize=128, dist="uniform", seed=0)
    idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp", "Tpp"),
                    m=12, ef_con=64)
    eng = QueryEngine(idx)
    qlo, qhi = make_queries(ds, ANY_OVERLAP, SMOKE_SEL, seed=11)
    req_off = SearchRequest(ds.queries, (qlo, qhi), ANY_OVERLAP, k=SMOKE_K,
                            ef=64, route="graph")
    req_on = dataclasses.replace(req_off, trace=True)

    def cold_search(req):
        # identical discipline to the smoke lane's graph_qps row
        eng._sel_cache.clear()
        return eng.search(req)

    dt_off, _ = time_call(cold_search, req_off, repeats=7, best=True,
                          name="obs_traced_off")
    spread_off = last_timing()
    dt_on, res_on = time_call(cold_search, req_on, repeats=7, best=True,
                              name="obs_traced_on")
    # interleave a second off-pass and keep the best: the vs-history budget
    # below compares across processes on a shared box whose wall clock
    # swings well past the 5% budget (see ci.yml's gate-tolerance notes),
    # so a single unlucky pass must not decide it
    dt_off2, _ = time_call(cold_search, req_off, repeats=7, best=True,
                           name="obs_traced_off")
    dt_off = min(dt_off, dt_off2)
    assert res_on.trace is not None, "trace=True returned no trace"
    qps_off = SMOKE_Q / dt_off
    qps_on = SMOKE_Q / dt_on
    report["graph_qps_traced_off"] = round(qps_off, 1)
    report["graph_qps_traced_on"] = round(qps_on, 1)
    report["graph_repeat_ms"] = {"p50": round(spread_off["p50_s"] * 1e3, 2),
                                 "p95": round(spread_off["p95_s"] * 1e3, 2)}
    # informational only: the traced path deliberately blocks on device
    # results per kernel/chunk so spans measure real work, and cross-run
    # wall clock on this class of box swings past any tight budget anyway
    report["trace_on_overhead_pct"] = round((dt_on - dt_off) / dt_off * 100.0,
                                            2)
    noop_ns = noop_span_ns()
    n_spans = len(res_on.trace.span_names())
    report["noop_span_ns"] = round(noop_ns, 1)
    report["trace_spans_recorded"] = n_spans
    # gated headline: the no-op instrumentation share of an untraced
    # request — spans-per-request (counted from the traced run) x the
    # microbenchmarked no-op span cost, as a % of the untraced request
    # time. A ratio of two same-process measurements, so it is stable
    # where raw wall clock is not, and it rises if either the span count
    # on the hot path or the no-op path cost creeps up.
    report["obs_overhead_pct"] = round(
        n_spans * noop_ns / (dt_off * 1e9) * 100.0, 4)

    report["trace_export"] = trace_export_sanity(ds)
    report["traced_off_vs_history"] = compare_vs_history(
        baseline_history, report["platform"], qps_off)

    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path}")
    print(json.dumps({k: report[k] for k in
                      ("graph_qps_traced_off", "graph_qps_traced_on",
                       "obs_overhead_pct", "noop_span_ns")}, indent=2))
    print(f"trace_export ok={report['trace_export']['ok']} "
          f"spans={report['trace_export']['spans']}")
    print(f"vs_history: {json.dumps(report['traced_off_vs_history'])}")

    if history_path:
        record = {
            "commit": os.environ.get("GITHUB_SHA", "local")[:12],
            "unix_time": round(report["unix_time"], 1),
            "platform": report["platform"],
            "mask": iv.mask_name(ANY_OVERLAP),
            "obs_overhead_pct": report["obs_overhead_pct"],
            "obs_graph_qps": report["graph_qps_traced_off"],
            "obs_trace_export_ok": report["trace_export"]["ok"],
        }
        with open(history_path, "a") as f:
            f.write(json.dumps(record, sort_keys=True) + "\n")
        print(f"appended {history_path}: {json.dumps(record, sort_keys=True)}")

    if not report["trace_export"]["ok"]:
        raise RuntimeError(
            f"trace export sanity failed: {report['trace_export']}")
    # the < 5% no-op budget verdict is asserted in the artifact
    # (within_5pct); hard-fail only past the same 20% band the graph_qps
    # ci_gate uses — single cross-process samples on a shared box swing
    # past 5% routinely, and the trend is what the gates watch
    vs = report["traced_off_vs_history"]
    if vs.get("available") and vs["regression_pct"] > 20.0:
        raise RuntimeError(
            f"traced-off graph QPS regressed {vs['regression_pct']}% vs "
            f"same-platform baseline {vs['baseline_qps']} "
            f"(no-op budget < 5%, hard-fail band 20%)")
    return report


def run():
    """CSV mode (benchmarks.run default lane): tracing on/off cost."""
    report = run_obs_bench(out_path=os.devnull)
    from .common import emit
    emit("exp14/graph_traced_off",
         1e6 / max(report["graph_qps_traced_off"], 1e-9),
         f"qps={report['graph_qps_traced_off']}")
    emit("exp14/graph_traced_on",
         1e6 / max(report["graph_qps_traced_on"], 1e-9),
         f"qps={report['graph_qps_traced_on']};"
         f"overhead_pct={report['trace_on_overhead_pct']}")
    emit("exp14/noop_span", report["noop_span_ns"] / 1e3,
         f"ns={report['noop_span_ns']}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="alias for the default sizes (the lane is already "
                         "smoke-scale); writes BENCH_obs.json")
    ap.add_argument("--out", default="BENCH_obs.json")
    ap.add_argument("--history", default=None, metavar="PATH",
                    help="append obs_overhead_pct/obs_graph_qps JSON line")
    ap.add_argument("--baseline-history", default="BENCH_history.jsonl",
                    metavar="PATH",
                    help="smoke history file for the traced-off <5%% "
                         "vs-baseline assertion (skipped when absent)")
    args = ap.parse_args()
    run_obs_bench(out_path=args.out, history_path=args.history,
                  baseline_history=args.baseline_history)


if __name__ == "__main__":
    main()
