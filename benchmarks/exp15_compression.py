"""Exp. 15 — quantized vector tier: compressed-scan QPS vs float32 at
matched recall (tentpole of the int8 storage PR).

Three lanes, one per engine route:

* **flat** — the headline. A scan-dominated corpus (graph-free index,
  ``variants=()``) served at every storage tier. The float32 route runs the
  fused one-shot ``flat_search``; compressed tiers run the blocked
  compressed scan (``compressed_flat_topr``: per-block dequant in cache,
  running top-R) + exact float32 re-rank. The compressed scan streams
  1 byte/component instead of 4 — on bandwidth-bound backends that is the
  whole win; on this CPU box part of the measured speedup also comes from
  the blocked scan never materializing the (Q, N) distance matrix the
  fused path writes. Both effects only exist because the code tier fits
  blocks in cache, so the ratio is reported as one honest number
  (``flat_speedup``) with per-tier QPS alongside.
* **pruned** — selectivity-pruned exact scan over a ``builder="scan"``
  index (member structure without graphs, so the lane can afford a corpus
  where scanning dominates): gathers code rows (1 B/component) instead of
  float32 rows, then re-ranks.
* **graph** — recall parity check at small n (real graph build): the beam
  gathers + dequantizes code tiles; end recall must match float32 after
  the re-rank.

Every lane measures recall@k against the numpy brute-force oracle, so the
speedups are *at matched recall*: the gate is ``recall(float32) -
recall(tier) <= 0.01``. A ``rerank_k`` sweep documents how the exact
re-rank closes the quantization gap (recall-delta curve).

Writes ``BENCH_compression.json``; ``--history`` appends
``compressed_scan_qps`` (gated by ``ci_gate``) + ``compressed_speedup`` +
``compressed_recall_drop`` to the shared bench trajectory file. Exits
non-zero if a recall gate fails (deterministic); speedup regressions are
left to ``ci_gate`` vs history, which tolerates runner noise.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

import numpy as np

from repro.core import (ANY_OVERLAP, QUERY_CONTAINED, EngineConfig,
                        MSTGIndex, QueryEngine, SearchRequest,
                        maybe_quantize, intervals as iv)
from repro.data import (brute_force_topk, make_queries, make_range_dataset,
                        recall_at_k)

from .common import emit, time_call

TIERS = ("float32", "int8", "float16")
RECALL_DROP_GATE = 0.01
FLAT_SPEEDUP_GATE = 2.0


def _engine(idx, tier, route, rerank_k=None, use_kernel=False):
    return QueryEngine(idx, config=EngineConfig(
        route=route, rerank_k=rerank_k, use_kernel=use_kernel,
        storage_dtype=None if tier == "float32" else tier))


def _qps(engine, req, repeats):
    dt, _ = time_call(engine.execute, req, repeats=repeats, best=True)
    return round(len(req) / dt, 2)


def _bytes_per_vector(vectors, tier) -> float:
    st = maybe_quantize(vectors, tier)
    if st is None:
        return float(4 * vectors.shape[1])
    return round(st.bytes_breakdown()["total"] / vectors.shape[0], 2)


def flat_lane(n, d, Q, k, repeats, seed=3) -> dict:
    """Scan-dominated corpus: graph-free index, every tier, ANY_OVERLAP at
    moderate selectivity (the flat route's home regime)."""
    ds = make_range_dataset(n=n, d=d, n_queries=Q, quantize=64, seed=seed)
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.5, seed=seed + 1)
    idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=())
    true_ids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                   qlo, qhi, ANY_OVERLAP, k)
    req = SearchRequest(ds.queries, (qlo, qhi), ANY_OVERLAP, k=k,
                        route="flat")
    rows = {}
    for tier in TIERS:
        eng = _engine(idx, tier, "flat")
        res = eng.execute(req)
        rows[tier] = {
            "qps": _qps(eng, req, repeats),
            "recall": round(float(recall_at_k(res.ids, true_ids)), 4),
            "bytes_per_vector": _bytes_per_vector(ds.vectors, tier),
        }
        print(f"  flat {tier:8s}: qps={rows[tier]['qps']:>9} "
              f"recall={rows[tier]['recall']} "
              f"B/vec={rows[tier]['bytes_per_vector']}")
    # rerank_k sweep on the int8 tier: the recall-delta curve the README
    # tuning section points at
    curve = []
    for R in (k, 2 * k, 4 * k, 8 * k):
        eng = _engine(idx, "int8", "flat", rerank_k=R)
        res = eng.execute(req)
        curve.append({"rerank_k": R,
                      "recall": round(float(recall_at_k(res.ids, true_ids)),
                                      4)})
    return {"sizes": {"n": n, "d": d, "Q": Q, "k": k},
            "tiers": rows,
            "rerank_curve": curve,
            "flat_speedup": round(rows["int8"]["qps"]
                                  / max(rows["float32"]["qps"], 1e-9), 3),
            "recall_drop": round(rows["float32"]["recall"]
                                 - rows["int8"]["recall"], 4)}


def pruned_lane(n, d, Q, k, repeats, seed=11) -> dict:
    """Selectivity-pruned scan over a scan-only build (members, no graphs):
    the compressed gather reads 1 B/component code rows."""
    ds = make_range_dataset(n=n, d=d, n_queries=Q, quantize=32, seed=seed)
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.15, seed=seed + 1)
    idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp"),
                    builder="scan")
    true_ids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                   qlo, qhi, ANY_OVERLAP, k)
    req = SearchRequest(ds.queries, (qlo, qhi), ANY_OVERLAP, k=k,
                        route="pruned")
    rows = {}
    for tier in ("float32", "int8"):
        eng = _engine(idx, tier, "pruned")
        res = eng.execute(req)
        rows[tier] = {
            "qps": _qps(eng, req, repeats),
            "recall": round(float(recall_at_k(res.ids, true_ids)), 4),
        }
        print(f"  pruned {tier:8s}: qps={rows[tier]['qps']:>9} "
              f"recall={rows[tier]['recall']}")
    return {"sizes": {"n": n, "d": d, "Q": Q, "k": k},
            "tiers": rows,
            "pruned_speedup": round(rows["int8"]["qps"]
                                    / max(rows["float32"]["qps"], 1e-9), 3),
            "recall_drop": round(rows["float32"]["recall"]
                                 - rows["int8"]["recall"], 4)}


def graph_lane(n, d, Q, k, repeats, seed=7) -> dict:
    """Recall-parity check on the beam route (real graph build, small n):
    the wavefront gathers + dequantizes int8 tiles mid-search and the
    engine re-ranks the pool exactly."""
    ds = make_range_dataset(n=n, d=d, n_queries=Q, quantize=64, seed=seed)
    qlo, qhi = make_queries(ds, QUERY_CONTAINED, 0.3, seed=seed + 1)
    idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp"), m=12,
                    ef_con=64)
    true_ids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                   qlo, qhi, QUERY_CONTAINED, k)
    req = SearchRequest(ds.queries, (qlo, qhi), QUERY_CONTAINED, k=k,
                        ef=96, route="graph")
    rows = {}
    for tier in TIERS:
        eng = _engine(idx, tier, "graph")
        res = eng.execute(req)
        rows[tier] = {
            "qps": _qps(eng, req, repeats),
            "recall": round(float(recall_at_k(res.ids, true_ids)), 4),
        }
        print(f"  graph {tier:8s}: qps={rows[tier]['qps']:>9} "
              f"recall={rows[tier]['recall']}")
    return {"sizes": {"n": n, "d": d, "Q": Q, "k": k, "ef": 96},
            "tiers": rows,
            "recall_drop": round(rows["float32"]["recall"]
                                 - rows["int8"]["recall"], 4)}


def run_compression_bench(out_path="BENCH_compression.json", *,
                          flat_n=200_000, pruned_n=60_000, graph_n=2500,
                          d=64, Q=16, k=10, repeats=3,
                          history_path=None) -> dict:
    report = {"schema": 1, "unix_time": time.time(),
              "platform": platform.platform(),
              "gates": {"recall_drop_max": RECALL_DROP_GATE,
                        "flat_speedup_min": FLAT_SPEEDUP_GATE}}
    print(f"flat lane (n={flat_n}, d={d}) ...")
    report["flat"] = flat_lane(flat_n, d, Q, k, repeats)
    print(f"pruned lane (n={pruned_n}, d={d}) ...")
    report["pruned"] = pruned_lane(pruned_n, d, Q, k, repeats)
    print(f"graph lane (n={graph_n}, d={d}) ...")
    report["graph"] = graph_lane(graph_n, d, Q, k, repeats)

    ft = report["flat"]["tiers"]
    report["headline"] = {
        "compressed_scan_qps": ft["int8"]["qps"],
        "float32_scan_qps": ft["float32"]["qps"],
        "flat_speedup": report["flat"]["flat_speedup"],
        "pruned_speedup": report["pruned"]["pruned_speedup"],
        "bytes_per_vector": {t: ft[t]["bytes_per_vector"] for t in TIERS},
        "compression_ratio": round(ft["float32"]["bytes_per_vector"]
                                   / ft["int8"]["bytes_per_vector"], 2),
        "recall_drop": {"flat": report["flat"]["recall_drop"],
                        "pruned": report["pruned"]["recall_drop"],
                        "graph": report["graph"]["recall_drop"]},
    }
    drops = report["headline"]["recall_drop"]
    report["gates"]["recall_ok"] = bool(all(v <= RECALL_DROP_GATE
                                            for v in drops.values()))
    report["gates"]["flat_speedup_ok"] = bool(
        report["flat"]["flat_speedup"] >= FLAT_SPEEDUP_GATE)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path}")
    print(json.dumps(report["headline"], indent=2))
    if history_path:
        record = {
            "commit": os.environ.get("GITHUB_SHA", "local")[:12],
            "unix_time": round(report["unix_time"], 1),
            "platform": report["platform"],
            "compressed_scan_qps": report["headline"]["compressed_scan_qps"],
            "compressed_speedup": report["headline"]["flat_speedup"],
            "compressed_recall_drop": max(drops.values()),
        }
        with open(history_path, "a") as f:
            f.write(json.dumps(record, sort_keys=True) + "\n")
        print(f"appended {history_path}: {json.dumps(record, sort_keys=True)}")
    if not report["gates"]["recall_ok"]:
        print(f"RECALL GATE FAILED: drops {drops} > {RECALL_DROP_GATE}",
              file=sys.stderr)
        sys.exit(1)
    return report


def run():
    """CSV mode (benchmarks.run full lane): int8 vs float32 flat scan on the
    shared bench corpus."""
    from .common import bench_dataset, K
    ds = bench_dataset()
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.5, seed=4)
    idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=())
    req = SearchRequest(ds.queries, (qlo, qhi), ANY_OVERLAP, k=K,
                        route="flat")
    for tier in TIERS:
        eng = _engine(idx, tier, "flat")
        dt, _ = time_call(eng.execute, req, repeats=3, best=True,
                          name=f"exp15/flat_{tier}")
        emit(f"exp15/flat_{tier}_us", dt * 1e6 / len(req),
             f"n={ds.n};d={ds.d}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizes; writes BENCH_compression.json")
    ap.add_argument("--out", default="BENCH_compression.json")
    ap.add_argument("--history", default=None, metavar="PATH",
                    help="append compressed_scan_qps JSON line")
    ap.add_argument("--flat-n", type=int, default=None)
    ap.add_argument("--pruned-n", type=int, default=None)
    args = ap.parse_args()
    if args.smoke:
        run_compression_bench(out_path=args.out,
                              # n=200k keeps the float32 fused scan in its
                              # bandwidth-bound regime (the corpus no longer
                              # fits in LLC); smaller n understates the
                              # compressed win and is not the paper's setting
                              flat_n=args.flat_n or 200_000,
                              pruned_n=args.pruned_n or 60_000,
                              graph_n=2000, history_path=args.history)
    else:
        run_compression_bench(out_path=args.out,
                              flat_n=args.flat_n or 200_000,
                              pruned_n=args.pruned_n or 100_000,
                              graph_n=4000, history_path=args.history)


if __name__ == "__main__":
    main()
