"""Exp. 1 (Fig. 3/4): RRANN QPS vs recall — MSTG engines vs baselines."""
import numpy as np

from repro.core import ANY_OVERLAP
from repro.core.baselines import Prefiltering, Postfiltering, AcornLike
from repro.data import (make_queries, brute_force_topk, recall_at_k,
                        relative_distance_error)

from .common import Q, K, bench_dataset, bench_engine, bench_index, emit, time_call


def run():
    ds = bench_dataset()
    idx = bench_index(ds)
    for sel in (0.05, 0.10):
        qlo, qhi = make_queries(ds, ANY_OVERLAP, sel, seed=11)
        tids, tds = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                     qlo, qhi, ANY_OVERLAP, K)
        eng = bench_engine(idx)
        rows = [
            ("engine_auto", lambda: eng.search(ds.queries, qlo, qhi,
                                               ANY_OVERLAP, k=K, ef=64)),
            ("mstg_graph", lambda: eng.search_graph(ds.queries, qlo, qhi,
                                                    ANY_OVERLAP, k=K, ef=64)),
            ("mstg_flat", lambda: eng.search_flat(ds.queries, qlo, qhi,
                                                  ANY_OVERLAP, k=K)),
            ("mstg_pruned", lambda: eng.search_pruned(ds.queries, qlo, qhi,
                                                      ANY_OVERLAP, k=K)),
        ]
        base = [
            ("prefilter", Prefiltering(ds.vectors, ds.lo, ds.hi), {}),
            ("postfilter", Postfiltering(ds.vectors, ds.lo, ds.hi, m=12,
                                         ef_con=64), dict(ef=64)),
            ("acorn", AcornLike(ds.vectors, ds.lo, ds.hi, m=12, ef_con=64),
             dict(ef=64)),
        ]
        for name, fn in rows:
            dt, (ids, dd) = time_call(fn)
            r = recall_at_k(np.asarray(ids), tids)
            rde = relative_distance_error(np.asarray(dd), tds)
            emit(f"exp1/{name}/sel{int(sel*100)}", dt / Q * 1e6,
                 f"recall@10={r:.3f};qps={Q/dt:.1f};rde={rde:.4f}")
        for name, b, kw in base:
            dt, (ids, _) = time_call(
                lambda: b.search(ds.queries, qlo, qhi, ANY_OVERLAP, k=K, **kw))
            r = recall_at_k(ids, tids)
            emit(f"exp1/{name}/sel{int(sel*100)}", dt / Q * 1e6,
                 f"recall@10={r:.3f};qps={Q/dt:.1f}")
