"""Exp. 1 (Fig. 3/4): RRANN QPS vs recall — MSTG engines vs baselines."""
import numpy as np

from repro.core import Overlaps
from repro.core.baselines import Prefiltering, Postfiltering, AcornLike
from repro.data import (make_queries, brute_force_topk, recall_at_k,
                        relative_distance_error)

from .common import (Q, K, bench_dataset, bench_engine, bench_index, emit,
                     request, time_call)


def run():
    ds = bench_dataset()
    idx = bench_index(ds)
    pred = Overlaps()
    for sel in (0.05, 0.10):
        qlo, qhi = make_queries(ds, pred.mask, sel, seed=11)
        tids, tds = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                     qlo, qhi, pred.mask, K)
        eng = bench_engine(idx)
        rows = [
            ("engine_auto", None),
            ("mstg_graph", "graph"),
            ("mstg_flat", "flat"),
            ("mstg_pruned", "pruned"),
        ]
        for name, route in rows:
            req = request(ds.queries, qlo, qhi, pred, route=route)
            dt, res = time_call(eng.search, req)
            rde = relative_distance_error(np.asarray(res.dists), tds)
            emit(f"exp1/{name}/sel{int(sel*100)}", dt / Q * 1e6,
                 f"recall@10={res.recall_vs(tids):.3f};qps={Q/dt:.1f};"
                 f"rde={rde:.4f}")
        base = [
            ("prefilter", Prefiltering(ds.vectors, ds.lo, ds.hi), {}),
            ("postfilter", Postfiltering(ds.vectors, ds.lo, ds.hi, m=12,
                                         ef_con=64), dict(ef=64)),
            ("acorn", AcornLike(ds.vectors, ds.lo, ds.hi, m=12, ef_con=64),
             dict(ef=64)),
        ]
        for name, b, kw in base:
            dt, (ids, _) = time_call(
                lambda: b.search(ds.queries, qlo, qhi, pred.mask, k=K, **kw))
            r = recall_at_k(ids, tids)
            emit(f"exp1/{name}/sel{int(sel*100)}", dt / Q * 1e6,
                 f"recall@10={r:.3f};qps={Q/dt:.1f}")
