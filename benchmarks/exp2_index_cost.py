"""Exp. 2 (Fig. 5): index construction time and size."""
import time

import numpy as np

from repro.core import MSTGIndex
from repro.core.baselines import Postfiltering, AcornLike

from .common import bench_dataset, bench_index, emit


def run():
    ds = bench_dataset()
    idx = bench_index(ds)  # cached build
    total_s = sum(idx.build_seconds.values())
    emit("exp2/mstg_build", total_s * 1e6,
         f"bytes={idx.index_bytes()};variants={len(idx.variants)}")
    t0 = time.time()
    post = Postfiltering(ds.vectors, ds.lo, ds.hi, m=12, ef_con=64)
    emit("exp2/postfilter_build", (time.time() - t0) * 1e6,
         f"bytes={post.index_bytes()}")
    t0 = time.time()
    ac = AcornLike(ds.vectors, ds.lo, ds.hi, m=12, ef_con=64)
    emit("exp2/acorn_build", (time.time() - t0) * 1e6,
         f"bytes={ac.index_bytes()}")
    # labeled-compression effectiveness: edges vs naive multi-tree bound
    fv = idx.variants["T"]
    naive_edges = 0
    for lvl in range(fv.Lv):
        live = (fv.nbr[lvl] >= 0).sum()
        naive_edges += live
    emit("exp2/labels", 0.0,
         f"stored_edges={int(naive_edges)};"
         f"naive_pervers_bound={int(naive_edges) * fv.K}")
