"""Exp. 2 (Fig. 5): index construction time and size.

Includes the bulk-vs-incremental builder sweep: for each corpus size, one
variant is built with both construction paths and the build seconds +
``index_bytes`` are emitted side by side, so the bulk path's speedup and
size parity are tracked as first-class rows (the smoke lane gates the
headline ``build_seconds.total`` via ``benchmarks.ci_gate --direction min``).
"""
import time

from repro.core import MSTGIndex
from repro.core.baselines import Postfiltering, AcornLike

from .common import QUICK, bench_dataset, bench_index, emit


def run():
    ds = bench_dataset()
    idx = bench_index(ds)  # cached build
    total_s = sum(idx.build_seconds.values())
    emit("exp2/mstg_build", total_s * 1e6,
         f"bytes={idx.index_bytes()};variants={len(idx.variants)};"
         f"builder={idx.spec.builder}")
    t0 = time.perf_counter()
    post = Postfiltering(ds.vectors, ds.lo, ds.hi, m=12, ef_con=64)
    emit("exp2/postfilter_build", (time.perf_counter() - t0) * 1e6,
         f"bytes={post.index_bytes()}")
    t0 = time.perf_counter()
    ac = AcornLike(ds.vectors, ds.lo, ds.hi, m=12, ef_con=64)
    emit("exp2/acorn_build", (time.perf_counter() - t0) * 1e6,
         f"bytes={ac.index_bytes()}")
    # per-tier storage rows: same corpus quantized at build, reporting the
    # scan-side bytes and compression ratio next to the f32 baseline (the
    # f32 re-rank corpus is charged to every tier — it stays host-side)
    for tier in ("float32", "int8", "float16"):
        t0 = time.perf_counter()
        tidx = (idx if tier == "float32" else
                MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T",),
                          m=12, ef_con=64, storage_dtype=tier))
        dt = 0.0 if tier == "float32" else time.perf_counter() - t0
        sb = tidx.storage_bytes()
        emit(f"exp2/storage_{tier}", dt * 1e6,
             f"scan_bytes={sb['scan_bytes']};codes={sb['codes']};"
             f"scales={sb['scales']};sq_norm={sb['sq_norm']};"
             f"compression_ratio={sb['compression_ratio']:.3f}")

    # labeled-compression effectiveness: edges vs naive multi-tree bound
    fv = idx.variants["T"]
    naive_edges = 0
    for lvl in range(fv.Lv):
        live = (fv.nbr[lvl] >= 0).sum()
        naive_edges += live
    emit("exp2/labels", 0.0,
         f"stored_edges={int(naive_edges)};"
         f"naive_pervers_bound={int(naive_edges) * fv.K}")

    # bulk-vs-incremental n-sweep (single variant keeps the incremental
    # side affordable; both sides share dataset + hyper-parameters)
    for n in (200, 400) if QUICK else (256, 512, 1024):
        sweep_ds = bench_dataset(n=n, seed=1)
        row = {}
        for builder in ("bulk", "incremental"):
            t0 = time.perf_counter()
            swept = MSTGIndex(sweep_ds.vectors, sweep_ds.lo, sweep_ds.hi,
                              variants=("T",), m=12, ef_con=64,
                              builder=builder)
            row[builder] = (time.perf_counter() - t0, swept.index_bytes())
        (bulk_s, bulk_b), (inc_s, inc_b) = row["bulk"], row["incremental"]
        emit(f"exp2/builder_sweep_n{n}", bulk_s * 1e6,
             f"bulk_s={bulk_s:.3f};incremental_s={inc_s:.3f};"
             f"speedup={inc_s / max(bulk_s, 1e-9):.1f};"
             f"bulk_bytes={bulk_b};incremental_bytes={inc_b};"
             f"bytes_ratio={bulk_b / max(inc_b, 1):.3f}")

    # candidate-stage sweep: exact all-pairs vs coarse quantizer on the
    # bulk path (threshold lowered so the quantizer engages at bench
    # scale; at the default threshold these sizes are bit-identical)
    for n in (400, 800) if QUICK else (512, 1024, 2048):
        sweep_ds = bench_dataset(n=n, seed=2)
        row = {}
        for stage in ("exact", "coarse"):
            t0 = time.perf_counter()
            swept = MSTGIndex(sweep_ds.vectors, sweep_ds.lo, sweep_ds.hi,
                              variants=("T",), m=12, ef_con=64,
                              candidate_stage=stage,
                              coarse_threshold=n // 4)
            row[stage] = (time.perf_counter() - t0, swept.index_bytes())
        (ex_s, ex_b), (co_s, co_b) = row["exact"], row["coarse"]
        emit(f"exp2/candidate_sweep_n{n}", co_s * 1e6,
             f"exact_s={ex_s:.3f};coarse_s={co_s:.3f};"
             f"speedup={ex_s / max(co_s, 1e-9):.2f};"
             f"exact_bytes={ex_b};coarse_bytes={co_b}")
