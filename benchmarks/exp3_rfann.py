"""Exp. 3 (Fig. 6): RFANN — MSTG vs an iRangeGraph-style index."""
import numpy as np

from repro.core import QueryEngine, intervals as iv
from repro.core.baselines import IRangeGraphLike
from repro.core.mstg import MSTGIndex
from repro.data import brute_force_topk, recall_at_k

from .common import Q, K, bench_dataset, emit, request, time_call


def run():
    ds = bench_dataset()
    attr = (ds.lo + ds.hi) / 2
    lo = np.quantile(attr, 0.3)
    hi = np.quantile(attr, 0.4)   # ~10% selectivity
    qlo = np.full(Q, lo)
    qhi = np.full(Q, hi)
    tids, _ = brute_force_topk(ds.vectors, attr, attr, ds.queries, qlo, qhi,
                               iv.RFANN_MASK, K)
    mstg = MSTGIndex(ds.vectors, attr, attr, variants=("Tpp",), m=12, ef_con=64)
    eng = QueryEngine(mstg)
    req = request(ds.queries, qlo, qhi, iv.RFANN_MASK, route="graph")
    dt, res = time_call(eng.search, req)
    emit("exp3/mstg", dt / Q * 1e6,
         f"recall@10={res.recall_vs(tids):.3f};qps={Q/dt:.1f}")
    irg = IRangeGraphLike(ds.vectors, attr, m=12, ef_con=64)
    dt, (ids, _) = time_call(lambda: irg.search(ds.queries, qlo, qhi, k=K, ef=64))
    emit("exp3/irangegraph", dt / Q * 1e6,
         f"recall@10={recall_at_k(np.asarray(ids), tids):.3f};qps={Q/dt:.1f}")
