"""Exp. 4 (Fig. 8): IFANN — MSTG vs a Hi-PNG-style quadtree."""
import numpy as np

from repro.core import intervals as iv
from repro.core.baselines import HiPNGLike
from repro.data import make_queries, brute_force_topk, recall_at_k

from .common import (Q, K, bench_dataset, bench_engine, bench_index, emit,
                     request, time_call)


def run():
    ds = bench_dataset()
    idx = bench_index(ds)
    qlo, qhi = make_queries(ds, iv.IFANN_MASK, 0.15, seed=13)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries, qlo, qhi,
                               iv.IFANN_MASK, K)
    eng = bench_engine(idx)
    req = request(ds.queries, qlo, qhi, iv.IFANN_MASK, route="graph")
    dt, res = time_call(eng.search, req)
    emit("exp4/mstg", dt / Q * 1e6,
         f"recall@10={res.recall_vs(tids):.3f};qps={Q/dt:.1f}")
    hp = HiPNGLike(ds.vectors, ds.lo, ds.hi, leaf_size=64, m=12, ef_con=48)
    dt, (ids, _) = time_call(lambda: hp.search(ds.queries, qlo, qhi, k=K, ef=64))
    emit("exp4/hipng", dt / Q * 1e6,
         f"recall@10={recall_at_k(ids, tids):.3f};qps={Q/dt:.1f}")
