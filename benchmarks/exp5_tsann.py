"""Exp. 5 (Fig. 9): TSANN — MSTG vs a TS-Graph-style per-bucket index."""
import numpy as np

from repro.core import intervals as iv
from repro.core.baselines import TSGraphLike
from repro.data import brute_force_topk, recall_at_k

from .common import (Q, K, bench_dataset, bench_engine, bench_index, emit,
                     request, time_call)


def run():
    ds = bench_dataset()
    idx = bench_index(ds)
    t = float(np.median((ds.lo + ds.hi) / 2))
    qlo = np.full(Q, t)
    qhi = np.full(Q, t)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries, qlo, qhi,
                               iv.TSANN_MASK, K)
    eng = bench_engine(idx)
    req = request(ds.queries, qlo, qhi, iv.TSANN_MASK, route="graph")
    dt, res = time_call(eng.search, req)
    emit("exp5/mstg", dt / Q * 1e6,
         f"recall@10={res.recall_vs(tids):.3f};qps={Q/dt:.1f}")
    tsg = TSGraphLike(ds.vectors, ds.lo, ds.hi, n_buckets=16, m=12, ef_con=48)
    dt, (ids, _) = time_call(lambda: tsg.search(ds.queries, qlo, qhi, k=K, ef=64))
    emit("exp5/tsgraph", dt / Q * 1e6,
         f"recall@10={recall_at_k(ids, tids):.3f};qps={Q/dt:.1f}")
