"""Exp. 6 (Fig. 10): scalability in n (build cost + search latency)."""
import numpy as np

from repro.core import ANY_OVERLAP, MSTGIndex, MSTGSearcher
from repro.data import make_queries, brute_force_topk, recall_at_k

from .common import Q, K, QUICK, bench_dataset, emit, time_call


def run():
    for n in ((800, 1600) if QUICK else (1000, 2000, 4000)):
        ds = bench_dataset(n=n, seed=5)
        idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp"),
                        m=12, ef_con=64)
        qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.1, seed=6)
        gs = MSTGSearcher(idx)
        dt, (ids, _) = time_call(lambda: gs.search(ds.queries, qlo, qhi,
                                                   ANY_OVERLAP, k=K, ef=64))
        tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                   qlo, qhi, ANY_OVERLAP, K)
        emit(f"exp6/n{n}", dt / Q * 1e6,
             f"recall@10={recall_at_k(np.asarray(ids), tids):.3f};"
             f"build_s={sum(idx.build_seconds.values()):.1f};"
             f"bytes={idx.index_bytes()}")
