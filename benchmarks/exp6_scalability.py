"""Exp. 6 (Fig. 10): scalability in n (build cost + search latency)."""
import numpy as np

from repro.core import MSTGIndex, Overlaps, QueryEngine
from repro.data import make_queries, brute_force_topk

from .common import Q, K, QUICK, bench_dataset, emit, request, time_call


def run():
    pred = Overlaps()
    for n in ((800, 1600) if QUICK else (1000, 2000, 4000)):
        ds = bench_dataset(n=n, seed=5)
        idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp"),
                        m=12, ef_con=64)
        qlo, qhi = make_queries(ds, pred.mask, 0.1, seed=6)
        eng = QueryEngine(idx)
        req = request(ds.queries, qlo, qhi, pred, route="graph")
        dt, res = time_call(eng.search, req)
        tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                   qlo, qhi, pred.mask, K)
        emit(f"exp6/n{n}", dt / Q * 1e6,
             f"recall@10={res.recall_vs(tids):.3f};"
             f"build_s={sum(idx.build_seconds.values()):.1f};"
             f"bytes={idx.index_bytes()}")
