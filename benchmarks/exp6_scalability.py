"""Exp. 6 (Fig. 10): scalability in n (build cost + search latency).

Each size row carries the builder's wall-clock stage breakdown
(``cand``/``prune``/``insert``/``freeze`` seconds, from
``MSTGIndex.build_stats``) so the n-scaling of the candidate stage —
quadratic under ``candidate_stage="exact"``, sub-quadratic under
``"coarse"`` — is visible per row, and a candidate-vs-exact pair is
emitted at the largest size."""
import numpy as np

from repro.core import MSTGIndex, Overlaps, QueryEngine
from repro.data import make_queries, brute_force_topk

from .common import Q, K, QUICK, bench_dataset, emit, request, time_call


def _stage_breakdown(idx: MSTGIndex) -> str:
    """candidate/prune/insert/freeze seconds summed over built variants."""
    fields = (("cand", "candidate_s"), ("prune", "prune_s"),
              ("insert", "insert_s"), ("freeze", "freeze_s"))
    tot = {short: sum(s.get(key, 0.0) for s in idx.build_stats.values())
           for short, key in fields}
    return ";".join(f"{short}_s={v:.2f}" for short, v in tot.items())


def run():
    pred = Overlaps()
    sizes = (800, 1600) if QUICK else (1000, 2000, 4000)
    for n in sizes:
        ds = bench_dataset(n=n, seed=5)
        idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp"),
                        m=12, ef_con=64)
        qlo, qhi = make_queries(ds, pred.mask, 0.1, seed=6)
        eng = QueryEngine(idx)
        req = request(ds.queries, qlo, qhi, pred, route="graph")
        dt, res = time_call(eng.search, req)
        tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                   qlo, qhi, pred.mask, K)
        emit(f"exp6/n{n}", dt / Q * 1e6,
             f"recall@10={res.recall_vs(tids):.3f};"
             f"build_s={sum(idx.build_seconds.values()):.1f};"
             f"bytes={idx.index_bytes()};{_stage_breakdown(idx)}")
    # candidate-stage pair at the largest size: same corpus/params, exact
    # vs coarse candidate generation (threshold lowered so the quantizer
    # actually engages at bench scale)
    n = sizes[-1]
    ds = bench_dataset(n=n, seed=5)
    row = {}
    for stage in ("exact", "coarse"):
        idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T",),
                        m=12, ef_con=64, candidate_stage=stage,
                        coarse_threshold=n // 4)
        row[stage] = (sum(idx.build_seconds.values()), idx)
    ex_s, co_s = row["exact"][0], row["coarse"][0]
    emit(f"exp6/candidate_stage_n{n}", co_s * 1e6,
         f"exact_s={ex_s:.2f};coarse_s={co_s:.2f};"
         f"speedup={ex_s / max(co_s, 1e-9):.2f};"
         f"{_stage_breakdown(row['coarse'][1])}")
