"""Exp. 7 (Fig. 12): query selectivity sweep."""
import numpy as np

from repro.core import ANY_OVERLAP, MSTGSearcher
from repro.data import make_queries, brute_force_topk, recall_at_k

from .common import Q, K, bench_dataset, bench_index, emit, time_call


def run():
    ds = bench_dataset()
    idx = bench_index(ds)
    gs = MSTGSearcher(idx)
    for sel in (0.05, 0.1, 0.2, 0.4):
        qlo, qhi = make_queries(ds, ANY_OVERLAP, sel, seed=17)
        tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                   qlo, qhi, ANY_OVERLAP, K)
        dt, (ids, _) = time_call(lambda: gs.search(ds.queries, qlo, qhi,
                                                   ANY_OVERLAP, k=K, ef=64))
        emit(f"exp7/sel{int(sel*100)}", dt / Q * 1e6,
             f"recall@10={recall_at_k(np.asarray(ids), tids):.3f};qps={Q/dt:.1f}")
