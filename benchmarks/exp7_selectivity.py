"""Exp. 7 (Fig. 12): query selectivity sweep."""
import numpy as np

from repro.core import Overlaps
from repro.data import make_queries, brute_force_topk

from .common import (Q, K, bench_dataset, bench_engine, bench_index, emit,
                     request, time_call)


def run():
    ds = bench_dataset()
    idx = bench_index(ds)
    eng = bench_engine(idx)
    pred = Overlaps()
    for sel in (0.05, 0.1, 0.2, 0.4):
        qlo, qhi = make_queries(ds, pred.mask, sel, seed=17)
        tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                   qlo, qhi, pred.mask, K)
        req = request(ds.queries, qlo, qhi, pred, route="graph")
        dt, res = time_call(eng.search, req)
        emit(f"exp7/sel{int(sel*100)}", dt / Q * 1e6,
             f"recall@10={res.recall_vs(tids):.3f};qps={Q/dt:.1f}")
