"""Exp. 8 (Fig. 13): attribute distribution robustness."""
import numpy as np

from repro.core import MSTGIndex, Overlaps, QueryEngine
from repro.data import make_queries, brute_force_topk

from .common import Q, K, bench_dataset, emit, request, time_call


def run():
    pred = Overlaps()
    for dist in ("uniform", "normal", "longtail", "zipf"):
        ds = bench_dataset(dist=dist, n=1500, seed=8)
        idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp"),
                        m=12, ef_con=64)
        eng = QueryEngine(idx)
        qlo, qhi = make_queries(ds, pred.mask, 0.1, seed=9)
        tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                   qlo, qhi, pred.mask, K)
        req = request(ds.queries, qlo, qhi, pred, route="graph")
        dt, res = time_call(eng.search, req)
        emit(f"exp8/{dist}", dt / Q * 1e6,
             f"recall@10={res.recall_vs(tids):.3f}")
