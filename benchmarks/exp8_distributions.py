"""Exp. 8 (Fig. 13): attribute distribution robustness."""
import numpy as np

from repro.core import ANY_OVERLAP, MSTGIndex, MSTGSearcher
from repro.data import make_queries, brute_force_topk, recall_at_k

from .common import Q, K, bench_dataset, emit, time_call


def run():
    for dist in ("uniform", "normal", "longtail", "zipf"):
        ds = bench_dataset(dist=dist, n=1500, seed=8)
        idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp"),
                        m=12, ef_con=64)
        gs = MSTGSearcher(idx)
        qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.1, seed=9)
        tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                   qlo, qhi, ANY_OVERLAP, K)
        dt, (ids, _) = time_call(lambda: gs.search(ds.queries, qlo, qhi,
                                                   ANY_OVERLAP, k=K, ef=64))
        emit(f"exp8/{dist}", dt / Q * 1e6,
             f"recall@10={recall_at_k(np.asarray(ids), tids):.3f}")
