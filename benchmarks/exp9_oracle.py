"""Exp. 9 (Fig. 14): MSTG vs Oracle-HNSW (per-query index on O[R_q])."""
import numpy as np

from repro.core import Overlaps, intervals as iv
from repro.core.hnsw import PlainHNSW
from repro.data import make_queries, brute_force_topk

from .common import (K, bench_dataset, bench_engine, bench_index, emit,
                     request, time_call)


def run():
    ds = bench_dataset()
    idx = bench_index(ds)
    eng = bench_engine(idx)
    pred = Overlaps()
    nq = 6
    qlo, qhi = make_queries(ds, pred.mask, 0.1, n_queries=nq, seed=21)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries[:nq],
                               qlo, qhi, pred.mask, K)
    req = request(ds.queries[:nq], qlo, qhi, pred, route="graph")
    dt, res = time_call(eng.search, req)
    emit("exp9/mstg", dt / nq * 1e6, f"recall@10={res.recall_vs(tids):.3f}")
    # oracle: per-query HNSW over exactly the qualifying subset (not practical,
    # upper bound only)
    hits = 0
    total = 0
    for qi in range(nq):
        sel = np.nonzero(np.asarray(iv.eval_predicate(
            pred.mask, ds.lo, ds.hi, qlo[qi], qhi[qi])))[0]
        h = PlainHNSW(ds.vectors, m=12, ef_con=48)
        for u in sel:
            h.add(int(u))
        oids, _ = h.search(ds.queries[qi], k=K, ef=64)
        t = set(int(x) for x in tids[qi] if x >= 0)
        hits += len(t & set(int(x) for x in oids))
        total += len(t)
    emit("exp9/oracle_hnsw", 0.0, f"recall@10={hits/max(total,1):.3f}")
