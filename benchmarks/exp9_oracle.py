"""Exp. 9 (Fig. 14): MSTG vs Oracle-HNSW (per-query index on O[R_q])."""
import numpy as np

from repro.core import ANY_OVERLAP, MSTGSearcher, intervals as iv
from repro.core.hnsw import PlainHNSW
from repro.data import make_queries, brute_force_topk, recall_at_k

from .common import K, bench_dataset, bench_index, emit, time_call


def run():
    ds = bench_dataset()
    idx = bench_index(ds)
    gs = MSTGSearcher(idx)
    nq = 6
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.1, n_queries=nq, seed=21)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries[:nq],
                               qlo, qhi, ANY_OVERLAP, K)
    dt, (ids, _) = time_call(lambda: gs.search(ds.queries[:nq], qlo, qhi,
                                               ANY_OVERLAP, k=K, ef=64))
    emit("exp9/mstg", dt / nq * 1e6,
         f"recall@10={recall_at_k(np.asarray(ids), tids):.3f}")
    # oracle: per-query HNSW over exactly the qualifying subset (not practical,
    # upper bound only)
    hits = 0
    total = 0
    for qi in range(nq):
        sel = np.nonzero(np.asarray(iv.eval_predicate(
            ANY_OVERLAP, ds.lo, ds.hi, qlo[qi], qhi[qi])))[0]
        h = PlainHNSW(ds.vectors, m=12, ef_con=48)
        for u in sel:
            h.add(int(u))
        oids, _ = h.search(ds.queries[qi], k=K, ef=64)
        t = set(int(x) for x in tids[qi] if x >= 0)
        hits += len(t & set(int(x) for x in oids))
        total += len(t)
    emit("exp9/oracle_hnsw", 0.0, f"recall@10={hits/max(total,1):.3f}")
