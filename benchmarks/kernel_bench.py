"""Kernel microbenchmarks: fused-predicate pairwise L2 (interpret mode on CPU
— structural validation; wall-time roofline numbers come from the TPU
dry-run artifacts, see EXPERIMENTS.md §Roofline)."""
import numpy as np

import jax.numpy as jnp

from repro.core import ANY_OVERLAP
from repro.kernels import ops
from repro.kernels.ref import pairwise_l2_masked_ref

from .common import emit, time_call


def run():
    rng = np.random.default_rng(0)
    Qn, Nn, d = 16, 2048, 64
    q = rng.normal(0, 1, (Qn, d)).astype(np.float32)
    c = rng.normal(0, 1, (Nn, d)).astype(np.float32)
    lo = rng.uniform(0, 100, Nn).astype(np.float32)
    hi = lo + 10
    ql = np.full(Qn, 20, np.float32)
    qh = np.full(Qn, 60, np.float32)
    dt, _ = time_call(lambda: np.asarray(pairwise_l2_masked_ref(
        jnp.asarray(q), jnp.asarray(c), jnp.asarray(lo), jnp.asarray(hi),
        jnp.asarray(ql), jnp.asarray(qh), ANY_OVERLAP)))
    flops = 2 * Qn * Nn * d
    emit("kernel/pairwise_ref_jnp", dt * 1e6, f"gflops={flops/dt/1e9:.2f}")
    dt, _ = time_call(lambda: np.asarray(ops.pairwise_l2_masked(
        q, c, lo, hi, ql, qh, ANY_OVERLAP)))
    emit("kernel/pairwise_pallas_interpret", dt * 1e6,
         "correctness-path; TPU perf in dry-run")
