"""Kernel microbenchmarks: fused-predicate pairwise L2 and the int8
compressed-scan variants (interpret mode on CPU — structural validation;
wall-time roofline numbers come from the TPU dry-run artifacts, see
EXPERIMENTS.md §Roofline). Each row reports the kernel's *modeled* byte
stream (``ops.pairwise_stream_bytes`` / ``ops.gathered_stream_bytes`` at the
table's storage itemsize) so the f32-vs-int8 comparison is apples-to-apples:
the compressed rows move ~4x fewer table bytes for the same logical work."""
import numpy as np

import jax.numpy as jnp

from repro.core import ANY_OVERLAP
from repro.core.quant import QuantizedStore
from repro.kernels import ops
from repro.kernels.ref import pairwise_l2_masked_ref

from .common import emit, time_call


def run():
    rng = np.random.default_rng(0)
    Qn, Nn, d = 16, 2048, 64
    q = rng.normal(0, 1, (Qn, d)).astype(np.float32)
    c = rng.normal(0, 1, (Nn, d)).astype(np.float32)
    lo = rng.uniform(0, 100, Nn).astype(np.float32)
    hi = lo + 10
    ql = np.full(Qn, 20, np.float32)
    qh = np.full(Qn, 60, np.float32)
    dt, _ = time_call(lambda: np.asarray(pairwise_l2_masked_ref(
        jnp.asarray(q), jnp.asarray(c), jnp.asarray(lo), jnp.asarray(hi),
        jnp.asarray(ql), jnp.asarray(qh), ANY_OVERLAP)))
    flops = 2 * Qn * Nn * d
    emit("kernel/pairwise_ref_jnp", dt * 1e6, f"gflops={flops/dt/1e9:.2f}")
    dt, _ = time_call(lambda: np.asarray(ops.pairwise_l2_masked(
        q, c, lo, hi, ql, qh, ANY_OVERLAP)))
    sb32 = ops.pairwise_stream_bytes(Qn, Nn, d, 4)
    emit("kernel/pairwise_pallas_interpret", dt * 1e6,
         f"stream={sb32/1e6:.2f}MB; correctness-path; TPU perf in dry-run")

    # int8 compressed scan: same logical work, ~4x fewer table bytes. The
    # modeled stream uses itemsize=1 for the code table; achieved GB/s in
    # interpret mode is meaningless, but the byte model IS the artifact the
    # roofline dry-run multiplies through.
    st = QuantizedStore.from_vectors(c, "int8")
    dt8, _ = time_call(lambda: np.asarray(ops.pairwise_l2_int8(
        q, st.codes, st.scale, st.offset, st.sq_norm,
        lo, hi, ql, qh, ANY_OVERLAP)))
    sb8 = ops.pairwise_stream_bytes(Qn, Nn, d, 1)
    emit("kernel/pairwise_int8_interpret", dt8 * 1e6,
         f"stream={sb8/1e6:.2f}MB ({sb32/sb8:.2f}x fewer bytes than f32)")

    # beam-candidate distances (graph-search inner step, gather left to XLA)
    S = 24
    cv = rng.normal(0, 1, (Qn, S, d)).astype(np.float32)
    dt, _ = time_call(lambda: np.asarray(ops.gathered_l2(q, cv)))
    emit("kernel/gathered_l2_interpret", dt * 1e6, f"S={S}")

    # fused wavefront step: gather-by-id + L2 + label mask + beam merge
    wf = _wavefront_step_inputs(rng, Qn, Nn, d, M=S, L=32)
    dt, _ = time_call(lambda: np.asarray(ops.gathered_topk(*wf)[1]))
    emit("kernel/gathered_topk_interpret", dt * 1e6, "M=24;L=32")
    dt, _ = time_call(lambda: np.asarray(ops.gathered_topk_ref(
        *(jnp.asarray(a) for a in wf))[1]))
    emit("kernel/gathered_topk_ref_jnp", dt * 1e6, "M=24;L=32")

    # quantized wavefront step: gathers int8 rows + dequantizes in VMEM
    q_, table, *rest = wf
    tst = QuantizedStore.from_vectors(table, "int8")
    gb32 = ops.gathered_stream_bytes(Qn, S, 32, d, 4)
    gb8 = ops.gathered_stream_bytes(Qn, S, 32, d, 1)
    dt, _ = time_call(lambda: np.asarray(ops.gathered_topk_quant(
        q_, tst.codes, tst.scale, tst.offset, *rest)[1]))
    emit("kernel/gathered_topk_int8_interpret", dt * 1e6,
         f"M={S};L=32;stream={gb8/1e3:.1f}KB ({gb32/gb8:.2f}x fewer than f32)")


def _wavefront_step_inputs(rng, Q, n, d, M, L):
    """One plausible wavefront beam step (see repro.kernels.gathered_topk)."""
    q = rng.normal(0, 1, (Q, d)).astype(np.float32)
    table = rng.normal(0, 1, (n, d)).astype(np.float32)
    ids = rng.integers(0, n, (Q, M)).astype(np.int32)
    avail = np.ones((Q, M), np.int32)
    b = np.zeros((Q, M), np.int32)
    e = np.full((Q, M), 10**6, np.int32)
    ver = np.zeros(Q, np.int32)
    pool_d = np.sort(rng.random((Q, L)).astype(np.float32), axis=1)
    pool_ids = rng.integers(0, n, (Q, L)).astype(np.int32)
    pool_exp = np.zeros((Q, L), bool)
    return q, table, ids, avail, b, e, ver, pool_ids, pool_d, pool_exp
