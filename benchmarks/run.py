"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV. BENCH_QUICK=1 shrinks sizes."""
import sys
import traceback


def main() -> None:
    from . import (exp1_rrann, exp2_index_cost, exp3_rfann, exp4_ifann,
                   exp5_tsann, exp6_scalability, exp7_selectivity,
                   exp8_distributions, exp9_oracle, exp10_params, kernel_bench)
    mods = [exp1_rrann, exp2_index_cost, exp3_rfann, exp4_ifann, exp5_tsann,
            exp6_scalability, exp7_selectivity, exp8_distributions,
            exp9_oracle, exp10_params, kernel_bench]
    print("name,us_per_call,derived")
    failed = 0
    for mod in mods:
        try:
            mod.run()
        except Exception:  # noqa: BLE001
            failed += 1
            print(f"{mod.__name__},ERROR,", file=sys.stderr)
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
