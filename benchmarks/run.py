"""Benchmark harness — one module per paper table/figure.

Default mode prints ``name,us_per_call,derived`` CSV for every experiment
(BENCH_QUICK=1 shrinks sizes). ``--smoke`` instead runs the tiny CI lane
(exp1 + kernel bench + planner microbenchmark) and writes BENCH_smoke.json.
``--scale`` runs the sharded recall-QPS pareto lane at n >= 200k (multi-
device via XLA_FLAGS=--xla_force_host_platform_device_count) and writes
BENCH_scale.json.
"""
import argparse
import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI lane; writes a JSON perf artifact")
    ap.add_argument("--scale", action="store_true",
                    help="sharded pareto lane at n >= 200k; writes "
                         "BENCH_scale.json (multi-device when XLA_FLAGS "
                         "forces a host device count)")
    ap.add_argument("--out", default=None,
                    help="output path for --smoke / --scale (defaults: "
                         "BENCH_smoke.json / BENCH_scale.json)")
    ap.add_argument("--scale-n", type=int, default=200_000,
                    help="--scale corpus size (default 200000)")
    ap.add_argument("--graph-n", type=int, default=0,
                    help="--scale graph-lane corpus size (0 = lane off; "
                         "the scheduled CI lane runs 1000000)")
    ap.add_argument("--graph-shards", default="8",
                    help="--scale graph-lane comma-separated shard counts "
                         "(default 8)")
    ap.add_argument("--graph-efs", default="48,96",
                    help="--scale graph-lane comma-separated ef values "
                         "(default 48,96)")
    ap.add_argument("--build-workers", type=int, default=0,
                    help="process-pool width for --scale graph-lane shard "
                         "builds (0 = serial)")
    ap.add_argument("--shards", default="1,2,4,8",
                    help="--scale comma-separated shard counts "
                         "(default 1,2,4,8)")
    ap.add_argument("--mask", default="any_overlap",
                    help="RR predicate for the smoke lane, in any parse_mask "
                         "spelling: 'any_overlap', '1|2|<', '2,4' (single "
                         "digits are the paper's case numbers), or a "
                         "multi-digit raw int mask like '15' "
                         "(default: any_overlap)")
    ap.add_argument("--history", default=None, metavar="PATH",
                    help="append a one-line JSON record (keyed by commit) to "
                         "PATH after --smoke, accumulating the bench "
                         "trajectory across runs")
    args = ap.parse_args()

    if args.smoke:
        from repro.core import parse_mask

        from .smoke import run_smoke
        report = run_smoke(out_path=args.out or "BENCH_smoke.json",
                           mask=parse_mask(args.mask),
                           history_path=args.history)
        # per-section failures are isolated inside run_smoke (each records
        # into report["errors"] and the remaining sections still run +
        # land in history); surface them as a non-zero exit at the end so
        # a serving/kernel regression can't silently pass the lane
        errors = report.get("errors", {})
        if errors:
            for sec, msg in errors.items():
                print(f"SMOKE SECTION FAILED: {sec}: {msg}",
                      file=sys.stderr)
            sys.exit(1)
        return

    if args.scale:
        from repro.core import parse_mask

        from .scale import run_scale
        run_scale(out_path=args.out or "BENCH_scale.json", n=args.scale_n,
                  mask=parse_mask(args.mask),
                  shard_counts=tuple(int(s) for s in args.shards.split(",")),
                  history_path=args.history, graph_n=args.graph_n,
                  graph_shards=tuple(int(s)
                                     for s in args.graph_shards.split(",")),
                  graph_efs=tuple(int(e)
                                  for e in args.graph_efs.split(",")),
                  build_workers=args.build_workers)
        return

    from . import (exp1_rrann, exp2_index_cost, exp3_rfann, exp4_ifann,
                   exp5_tsann, exp6_scalability, exp7_selectivity,
                   exp8_distributions, exp9_oracle, exp10_params,
                   exp11_updates, exp12_wavefront, exp13_serving,
                   exp14_obs, exp15_compression, kernel_bench)
    mods = [exp1_rrann, exp2_index_cost, exp3_rfann, exp4_ifann, exp5_tsann,
            exp6_scalability, exp7_selectivity, exp8_distributions,
            exp9_oracle, exp10_params, exp11_updates, exp12_wavefront,
            exp13_serving, exp14_obs, exp15_compression, kernel_bench]
    print("name,us_per_call,derived")
    failed = 0
    for mod in mods:
        try:
            mod.run()
        except Exception:  # noqa: BLE001
            failed += 1
            print(f"{mod.__name__},ERROR,", file=sys.stderr)
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
