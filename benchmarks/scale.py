"""``--scale`` lane: sharded recall-QPS pareto curves at n >= 200k.

The smoke lane (n=800) can't say anything about distributed serving — at toy
scale recall is trivially 1.0 and the merge traffic rounds to zero. This
lane builds a corpus two-plus orders larger, shards it over a device mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=8`` in CI), and sweeps
the two axes that matter for a sharded deployment:

* **shard count D** — each count runs as one fused
  :func:`repro.distributed.topk.sharded_flat_topk` program (exact per-shard
  scans + collective merge; ``all_gather``/``tournament`` per
  :func:`resolve_merge`). The exact route is the right scale vehicle: MSTG
  graph construction is superlinear (~11 s at 5k rows, ~108 s at 20k on CI
  CPUs), so graph-backend sharding is exercised at smoke scale by
  ``tests/test_distributed.py`` while this lane measures the fan-out/merge
  machinery itself at n where it costs something.
* **per-shard fan-in k'** (``per_shard_k``) — each shard contributes only
  its local top-k' to the merge. ``k' == k`` is provably exact (recall
  matches single-device); ``k' < k`` cuts merge bytes ∝ D·Q·k' and can drop
  true neighbors when one shard holds more than k' of them. Sweeping k'
  traces the recall-QPS pareto frontier per shard count.

Ground truth is sampled: exact single-device flat top-k over the (Q-sized)
query sample, not the full query distribution. The headline ``sharded_qps``
(largest shard count at full fan-in, i.e. recall-exact) feeds
``benchmarks.ci_gate`` through the shared BENCH_history.jsonl.
"""
from __future__ import annotations

import json
import os
import platform
import time

import numpy as np

import jax

from repro.core import ANY_OVERLAP, SearchRequest, intervals as iv
from repro.data import make_range_dataset, make_queries, recall_at_k
from repro.distributed import DeploymentSpec, ShardedDeployment
from repro.launch.mesh import make_mesh


def _pareto_point(dep: ShardedDeployment, req: SearchRequest, tids,
                  repeats: int = 3) -> dict:
    res = dep.execute(req)                      # warmup / compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = dep.execute(req)
        best = min(best, time.perf_counter() - t0)
    return {"recall_at_10": round(recall_at_k(res.ids, tids), 4),
            "qps": round(len(req) / best, 1),
            "merge": res.report.merge}


def run_scale(out_path: str = "BENCH_scale.json", n: int = 200_000,
              d: int = 32, n_queries: int = 32, k: int = 10,
              mask: int = ANY_OVERLAP, shard_counts=(1, 2, 4, 8),
              fan_ins=(1, 2, 4, 0), history_path: str = None) -> dict:
    """Sweep shard count x per-shard fan-in; write BENCH_scale.json.

    ``fan_ins`` entries are ``per_shard_k`` values (0 = full k). Shard
    counts beyond the device count fall back to the host merge path (still
    measured, flagged ``merge: "host"``)."""
    n_dev = len(jax.devices())
    report: dict = {
        "schema": 1,
        "unix_time": time.time(),
        "platform": platform.platform(),
        "mask": iv.mask_name(mask),
        "devices": n_dev,
        "sizes": {"n": n, "d": d, "queries": n_queries, "k": k},
    }
    t0 = time.perf_counter()
    ds = make_range_dataset(n=n, d=d, n_queries=n_queries, quantize=1024,
                            dist="uniform", seed=0)
    qlo, qhi = make_queries(ds, mask, 0.05, seed=11)
    report["dataset_seconds"] = round(time.perf_counter() - t0, 2)

    # sampled ground truth: exact single-shard scan over the query sample
    gt = ShardedDeployment.flat(ds.vectors, ds.lo, ds.hi,
                                spec=DeploymentSpec(n_shards=1, merge="host"))
    req = SearchRequest(ds.queries, (qlo, qhi), mask, k=k)
    tids = gt.execute(req).ids

    pareto = []
    for D in shard_counts:
        if n % D:
            continue
        mesh = make_mesh((D,), ("data",)) if D <= n_dev else None
        for fk in fan_ins:
            spec = DeploymentSpec(n_shards=D, per_shard_k=fk,
                                  merge="auto" if mesh is not None else "host")
            dep = ShardedDeployment.flat(ds.vectors, ds.lo, ds.hi,
                                         spec=spec, mesh=mesh)
            point = _pareto_point(dep, req, tids)
            point.update({"shards": D, "per_shard_k": fk or k})
            pareto.append(point)
            print(f"  shards={D} k'={fk or k} merge={point['merge']:10s} "
                  f"recall@10={point['recall_at_10']:.3f} "
                  f"qps={point['qps']:.0f}")
    report["pareto"] = pareto

    # headline: largest shard count at full fan-in (recall-exact config)
    exact = [p for p in pareto if p["per_shard_k"] >= k]
    headline = max(exact, key=lambda p: p["shards"]) if exact else None
    report["sharded_qps"] = headline["qps"] if headline else None
    report["sharded_recall_at_10"] = (headline["recall_at_10"]
                                      if headline else None)
    report["sharded_shards"] = headline["shards"] if headline else None

    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path}")
    if history_path:
        record = {
            "commit": os.environ.get("GITHUB_SHA", "local")[:12],
            "unix_time": round(report["unix_time"], 1),
            "platform": report["platform"],
            "mask": report["mask"],
            "scale_n": n,
            "devices": n_dev,
            "sharded_qps": report["sharded_qps"],
            "sharded_recall_at_10": report["sharded_recall_at_10"],
            "sharded_shards": report["sharded_shards"],
            "pareto": pareto,
        }
        with open(history_path, "a") as f:
            f.write(json.dumps(record, sort_keys=True) + "\n")
        print(f"appended {history_path}: sharded_qps="
              f"{record['sharded_qps']}")
    return report
