"""``--scale`` lane: sharded recall-QPS pareto curves at n >= 200k.

The smoke lane (n=800) can't say anything about distributed serving — at toy
scale recall is trivially 1.0 and the merge traffic rounds to zero. This
lane builds a corpus two-plus orders larger, shards it over a device mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=8`` in CI), and sweeps
the two axes that matter for a sharded deployment:

* **shard count D** — each count runs as one fused
  :func:`repro.distributed.topk.sharded_flat_topk` program (exact per-shard
  scans + collective merge; ``all_gather``/``tournament`` per
  :func:`resolve_merge`). The exact route is the right scale vehicle: MSTG
  graph construction is superlinear (~11 s at 5k rows, ~108 s at 20k on CI
  CPUs), so graph-backend sharding is exercised at smoke scale by
  ``tests/test_distributed.py`` while this lane measures the fan-out/merge
  machinery itself at n where it costs something.
* **per-shard fan-in k'** (``per_shard_k``) — each shard contributes only
  its local top-k' to the merge. ``k' == k`` is provably exact (recall
  matches single-device); ``k' < k`` cuts merge bytes ∝ D·Q·k' and can drop
  true neighbors when one shard holds more than k' of them. Sweeping k'
  traces the recall-QPS pareto frontier per shard count.

Ground truth is sampled: exact single-device flat top-k over the (Q-sized)
query sample, not the full query distribution. The headline ``sharded_qps``
(largest shard count at full fan-in, i.e. recall-exact) feeds
``benchmarks.ci_gate`` through the shared BENCH_history.jsonl.

**Graph lane** (``graph_n > 0``): the coarse-quantizer candidate stage
(``IndexSpec(candidate_stage="coarse")``) plus shard-parallel builds make
MSTG construction sub-quadratic, so the *graph* route is now buildable at
n=1M — each shard builds an independent coarse-stage MSTG over its slice
and requests fan out exactly as above. The lane builds one deployment per
shard count, records the build cost (wall seconds, per-shard worker
seconds, pool size, ``rows/sec``), and sweeps ``ef`` for the recall-QPS
trade. Headlines ``graph_build_rows_per_sec`` (gate direction: max) and
``scale_graph_qps`` feed ``benchmarks.ci_gate`` through the shared
history. QUERY_CONTAINED single-variant (``("T",)``) keeps the per-shard
index one graph per tree level — the 1M scale config from the paper's
containment experiments.
"""
from __future__ import annotations

import json
import os
import platform
import time

import numpy as np

import jax

from repro.core import ANY_OVERLAP, IndexSpec, SearchRequest, intervals as iv
from repro.data import make_range_dataset, make_queries, recall_at_k
from repro.distributed import DeploymentSpec, ShardedDeployment
from repro.launch.mesh import make_mesh


def _pareto_point(dep: ShardedDeployment, req: SearchRequest, tids,
                  repeats: int = 3) -> dict:
    res = dep.execute(req)                      # warmup / compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = dep.execute(req)
        best = min(best, time.perf_counter() - t0)
    return {"recall_at_10": round(recall_at_k(res.ids, tids), 4),
            "qps": round(len(req) / best, 1),
            "merge": res.report.merge}


def _graph_spec(workers: int) -> "DeploymentSpec":
    """Per-shard build spec for the graph lane: QUERY_CONTAINED
    single-variant MSTG with the sub-quadratic coarse candidate stage —
    the configuration that makes the n=1M graph build tractable."""
    ispec = IndexSpec(predicate=iv.QUERY_CONTAINED, variants=("T",),
                      m=8, ef_con=48, batch_size=512,
                      candidate_stage="coarse")
    return DeploymentSpec(index=ispec, merge="host",
                          build_workers=workers)


def run_graph_lane(report: dict, *, graph_n: int, d: int, n_queries: int,
                   k: int, shard_counts=(8,), efs=(48, 96),
                   build_workers: int = 0) -> None:
    """Graph-route section of the scale lane (see module docstring): build
    one coarse-stage sharded MSTG deployment per shard count, record build
    cost, sweep ``ef``. Mutates ``report`` in place — adds ``graph`` (full
    sweep) plus the ``graph_build_rows_per_sec`` / ``scale_graph_qps`` /
    ``graph_recall_at_10`` headlines (largest shard count; best-recall ef
    for the recall headline, best qps for the qps one)."""
    mask = iv.QUERY_CONTAINED
    t0 = time.perf_counter()
    ds = make_range_dataset(n=graph_n, d=d, n_queries=n_queries,
                            quantize=256, dist="uniform", seed=0)
    qlo, qhi = make_queries(ds, mask, 0.05, seed=11)
    gt = ShardedDeployment.flat(ds.vectors, ds.lo, ds.hi,
                                spec=DeploymentSpec(n_shards=1, merge="host"))
    req0 = SearchRequest(ds.queries, (qlo, qhi), mask, k=k)
    tids = gt.execute(req0).ids
    graph: dict = {"n": graph_n, "mask": iv.mask_name(mask),
                   "dataset_seconds": round(time.perf_counter() - t0, 2),
                   "builds": [], "sweep": []}
    for D in shard_counts:
        t0 = time.perf_counter()
        dep = ShardedDeployment.build(ds.vectors, ds.lo, ds.hi,
                                      spec=_graph_spec(build_workers)
                                      .replace(n_shards=D))
        br = dep.build_report
        graph["builds"].append({
            "shards": D,
            "pool_size": br["pool_size"],
            "build_seconds": round(br["wall_s"], 2),
            "shard_seconds": [round(s, 2) for s in br["shard_seconds"]],
            "rows_per_sec": round(br["rows_per_sec"], 1),
        })
        print(f"  graph build shards={D} pool={br['pool_size']} "
              f"{br['wall_s']:.1f}s ({br['rows_per_sec']:.0f} rows/s)")
        for ef in efs:
            req = SearchRequest(ds.queries, (qlo, qhi), mask, k=k, ef=ef,
                                route="graph")
            point = _pareto_point(dep, req, tids)
            point.update({"shards": D, "ef": ef})
            graph["sweep"].append(point)
            print(f"  graph shards={D} ef={ef} "
                  f"recall@10={point['recall_at_10']:.3f} "
                  f"qps={point['qps']:.0f}")
    report["graph"] = graph
    big = max(s for s in shard_counts)
    build = next(b for b in graph["builds"] if b["shards"] == big)
    pts = [p for p in graph["sweep"] if p["shards"] == big]
    report["graph_build_rows_per_sec"] = build["rows_per_sec"]
    report["scale_graph_qps"] = max(p["qps"] for p in pts)
    report["graph_recall_at_10"] = max(p["recall_at_10"] for p in pts)


def run_scale(out_path: str = "BENCH_scale.json", n: int = 200_000,
              d: int = 32, n_queries: int = 32, k: int = 10,
              mask: int = ANY_OVERLAP, shard_counts=(1, 2, 4, 8),
              fan_ins=(1, 2, 4, 0), history_path: str = None,
              graph_n: int = 0, graph_shards=(8,), graph_efs=(48, 96),
              build_workers: int = 0) -> dict:
    """Sweep shard count x per-shard fan-in; write BENCH_scale.json.

    ``fan_ins`` entries are ``per_shard_k`` values (0 = full k). Shard
    counts beyond the device count fall back to the host merge path (still
    measured, flagged ``merge: "host"``). ``graph_n > 0`` additionally runs
    the graph lane (:func:`run_graph_lane`): sharded coarse-stage MSTG
    builds + an ef sweep at that corpus size, with ``build_workers`` wide
    process pools for the per-shard builds."""
    n_dev = len(jax.devices())
    report: dict = {
        "schema": 2,
        "unix_time": time.time(),
        "platform": platform.platform(),
        "mask": iv.mask_name(mask),
        "devices": n_dev,
        "sizes": {"n": n, "d": d, "queries": n_queries, "k": k},
    }
    t0 = time.perf_counter()
    ds = make_range_dataset(n=n, d=d, n_queries=n_queries, quantize=1024,
                            dist="uniform", seed=0)
    qlo, qhi = make_queries(ds, mask, 0.05, seed=11)
    report["dataset_seconds"] = round(time.perf_counter() - t0, 2)

    # sampled ground truth: exact single-shard scan over the query sample
    gt = ShardedDeployment.flat(ds.vectors, ds.lo, ds.hi,
                                spec=DeploymentSpec(n_shards=1, merge="host"))
    req = SearchRequest(ds.queries, (qlo, qhi), mask, k=k)
    tids = gt.execute(req).ids

    pareto = []
    for D in shard_counts:
        if n % D:
            continue
        mesh = make_mesh((D,), ("data",)) if D <= n_dev else None
        for fk in fan_ins:
            spec = DeploymentSpec(n_shards=D, per_shard_k=fk,
                                  merge="auto" if mesh is not None else "host")
            dep = ShardedDeployment.flat(ds.vectors, ds.lo, ds.hi,
                                         spec=spec, mesh=mesh)
            point = _pareto_point(dep, req, tids)
            point.update({"shards": D, "per_shard_k": fk or k})
            pareto.append(point)
            print(f"  shards={D} k'={fk or k} merge={point['merge']:10s} "
                  f"recall@10={point['recall_at_10']:.3f} "
                  f"qps={point['qps']:.0f}")
    report["pareto"] = pareto

    # headline: largest shard count at full fan-in (recall-exact config)
    exact = [p for p in pareto if p["per_shard_k"] >= k]
    headline = max(exact, key=lambda p: p["shards"]) if exact else None
    report["sharded_qps"] = headline["qps"] if headline else None
    report["sharded_recall_at_10"] = (headline["recall_at_10"]
                                      if headline else None)
    report["sharded_shards"] = headline["shards"] if headline else None

    if graph_n:
        run_graph_lane(report, graph_n=graph_n, d=d, n_queries=n_queries,
                       k=k, shard_counts=graph_shards, efs=graph_efs,
                       build_workers=build_workers)

    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path}")
    if history_path:
        record = {
            "commit": os.environ.get("GITHUB_SHA", "local")[:12],
            "unix_time": round(report["unix_time"], 1),
            "platform": report["platform"],
            "mask": report["mask"],
            "scale_n": n,
            "devices": n_dev,
            "sharded_qps": report["sharded_qps"],
            "sharded_recall_at_10": report["sharded_recall_at_10"],
            "sharded_shards": report["sharded_shards"],
            "pareto": pareto,
        }
        if graph_n:
            record.update({
                "graph_n": graph_n,
                "graph_build_rows_per_sec":
                    report["graph_build_rows_per_sec"],
                "scale_graph_qps": report["scale_graph_qps"],
                "graph_recall_at_10": report["graph_recall_at_10"],
                "graph_builds": report["graph"]["builds"],
            })
        with open(history_path, "a") as f:
            f.write(json.dumps(record, sort_keys=True) + "\n")
        print(f"appended {history_path}: sharded_qps="
              f"{record['sharded_qps']}")
    return report
