"""``--smoke`` lane: tiny end-to-end benchmark that writes BENCH_smoke.json.

Runs on CPU JAX in CI so the perf trajectory (build time, QPS, recall@10,
planner µs/query, wavefront graph_qps / wasted_eval_frac) accumulates as an
artifact over time. QPS rows are best-of-7 (scheduler-noise filter on shared
CI machines); ``graph_qps`` feeds the scheduled lane's regression gate
(``benchmarks.ci_gate``). Includes a planner
microbenchmark at Q=1024 against a faithful reimplementation of the seed's
per-query scalar loop — the acceptance gate for the vectorized planner is a
>= 10x speedup, recorded in the JSON.

``--history <path>`` additionally appends one compact JSON line per run,
keyed by the commit (``GITHUB_SHA`` in CI), so the bench trajectory is a
single greppable file rather than a pile of artifacts.
"""
from __future__ import annotations

import json
import os
import platform
import time
import traceback

import numpy as np

from repro.core import (ANY_OVERLAP, MSTGIndex, QueryEngine, SearchRequest,
                        intervals as iv)
from repro.data import make_range_dataset, make_queries, brute_force_topk, recall_at_k

from .common import last_timing, time_call


def _plan_batch_scalar(index: MSTGIndex, mask: int, ql, qh):
    """The seed repo's planner: one ``plan_searches_ranked`` call per query
    per task slot. Kept verbatim as the microbenchmark baseline."""
    domain = index.domain
    ql = np.asarray(ql, dtype=np.float64)
    qh = np.asarray(qh, dtype=np.float64)
    Q = ql.shape[0]
    tmpl = iv.plan_searches_ranked(mask, 0, 0, domain.K - 1, domain.K - 1,
                                   domain.K)
    fl = domain.floor_rank(ql)
    cl = domain.ceil_rank(ql)
    fr = domain.floor_rank(qh)
    cr = domain.ceil_rank(qh)
    out = []
    for slot, t0 in enumerate(tmpl):
        versions = np.empty(Q, np.int64)
        klo = np.empty(Q, np.int64)
        khi = np.empty(Q, np.int64)
        for qi in range(Q):
            t = iv.plan_searches_ranked(mask, int(fl[qi]), int(cl[qi]),
                                        int(fr[qi]), int(cr[qi]), domain.K)[slot]
            versions[qi], klo[qi], khi[qi] = t.version, t.key_lo, t.key_hi
        out.append((t0.variant, versions, klo, khi))
    return out


def planner_microbench(index: MSTGIndex, Q: int = 1024, mask: int = ANY_OVERLAP,
                       repeats: int = 5) -> dict:
    rng = np.random.default_rng(3)
    span = index.domain.values[-1] - index.domain.values[0]
    qlo = index.domain.values[0] + rng.uniform(0, 0.6, Q) * span
    qhi = qlo + rng.uniform(0, 0.4, Q) * span

    dt_vec, plans_vec = time_call(index.plan_batch, mask, qlo, qhi,
                                  repeats=repeats)
    dt_scalar, plans_ref = time_call(_plan_batch_scalar, index, mask, qlo, qhi,
                                     repeats=repeats)
    # sanity: the two planners must agree slot for slot
    assert len(plans_vec) == len(plans_ref)
    for s, (variant, ver, klo, khi) in zip(plans_vec, plans_ref):
        assert s.variant == variant
        assert (np.array_equal(s.version, ver) and np.array_equal(s.key_lo, klo)
                and np.array_equal(s.key_hi, khi))
    return {
        "Q": Q,
        "mask": iv.mask_name(mask),
        "vectorized_us_per_query": dt_vec / Q * 1e6,
        "scalar_us_per_query": dt_scalar / Q * 1e6,
        "speedup": dt_scalar / dt_vec,
    }


def streaming_churn_metrics(n: int = 400, d: int = 24) -> dict:
    """The ``update_recall`` lane: recall after a 10% insert + 5% delete
    churn on a :class:`repro.streaming.SegmentedIndex`, measured against a
    static from-scratch rebuild over the identical post-churn corpus
    (delegates to :func:`benchmarks.exp11_updates.run_churn`)."""
    from repro.core import IndexSpec

    from .exp11_updates import run_churn
    r = run_churn(n=n, d=d, n_queries=12,
                  spec=IndexSpec(variants=("T", "Tp"), m=8, ef_con=48))
    return {"update_recall": r["update_recall"],
            "streamed_recall_at_10": r["streamed_recall_at_k"],
            "static_recall_at_10": r["static_recall_at_k"],
            "update_ops_per_sec": r["update_ops_per_sec"],
            "static_rebuild_seconds": r["static_rebuild_seconds"]}


def append_history(report: dict, history_path: str) -> dict:
    """One compact JSON line per run, keyed by commit, appended so the bench
    trajectory accumulates across scheduled CI runs. Tolerant of missing
    sections (a failed section records None for its fields — ci_gate skips
    records without the gated field instead of crashing the lane)."""
    sel05 = report.get("exp1_rrann", {}).get("sel_05", {})
    auto = sel05.get("engine_auto", {})
    streaming = report.get("streaming", {})
    build = report.get("build_seconds", {})
    planner = report.get("planner", {})
    record = {
        "commit": os.environ.get("GITHUB_SHA", "local")[:12],
        "unix_time": round(report["unix_time"], 1),
        "platform": report.get("platform"),
        "mask": report.get("mask", iv.mask_name(ANY_OVERLAP)),
        "builder": report.get("builder"),
        "build_seconds": build.get("total"),
        "build_seconds_variants": {k: v for k, v in build.items()
                                   if k != "total"},
        "planner_speedup": planner.get("speedup"),
        "auto_qps": auto.get("qps"),
        "auto_recall_at_10": auto.get("recall_at_10"),
        "graph_qps": report.get("graph_qps"),
        "wasted_eval_frac": report.get("wasted_eval_frac"),
        "update_recall": streaming.get("update_recall"),
        "update_ops_per_sec": streaming.get("update_ops_per_sec"),
        "int8_recall_at_10": report.get("compression", {})
                                   .get("int8", {}).get("recall_at_10_flat"),
        "int8_compression_ratio": report.get("compression", {})
                                        .get("int8", {})
                                        .get("compression_ratio"),
    }
    if report.get("errors"):
        record["errors"] = sorted(report["errors"])
    with open(history_path, "a") as f:
        f.write(json.dumps(record, sort_keys=True) + "\n")
    return record


def _section(report: dict, name: str, fn) -> bool:
    """Run one smoke section; a failure records into ``report["errors"]``
    and lets the remaining sections run (per-exp isolation: a serving or
    kernel regression can't mask the graph/build metrics in history)."""
    try:
        fn()
        return True
    except Exception as e:  # noqa: BLE001
        report.setdefault("errors", {})[name] = f"{type(e).__name__}: {e}"
        print(f"smoke section {name!r} FAILED: {type(e).__name__}: {e}")
        traceback.print_exc()
        return False


def run_smoke(out_path: str = "BENCH_smoke.json", n: int = 800, d: int = 32,
              n_queries: int = 16, k: int = 10, mask: int = ANY_OVERLAP,
              history_path: str = None) -> dict:
    report: dict = {
        "schema": 6,
        "unix_time": time.time(),
        "platform": platform.platform(),
        "mask": iv.mask_name(mask),
        "sizes": {"n": n, "d": d, "queries": n_queries, "k": k},
    }

    ds = make_range_dataset(n=n, d=d, n_queries=n_queries, quantize=128,
                            dist="uniform", seed=0)
    t0 = time.perf_counter()
    idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp", "Tpp"),
                    m=12, ef_con=64)
    # per-variant build timings + builder name (schema 5): the bulk-vs-
    # incremental construction trajectory is gated by ci_gate --direction min
    report["build_seconds"] = {**{k_: round(v, 4) for k_, v in
                                  idx.build_seconds.items()},
                               "total": round(time.perf_counter() - t0, 4)}
    report["builder"] = idx.spec.builder
    report["index_bytes"] = idx.index_bytes()
    # schema 6: per-tier storage accounting (codes/scales/sq_norm vs the
    # float32 re-rank corpus) — compression_ratio is 1.0 on this f32 build;
    # the quantized-tier ratio + recall parity land in sec_compression
    report["storage_bytes"] = idx.storage_bytes()

    eng = QueryEngine(idx)

    def sec_exp1():
        # exp1 (RRANN): engine QPS + recall at two selectivities, on the
        # declarative SearchRequest surface
        rrann = {}
        for sel in (0.05, 0.10):
            qlo, qhi = make_queries(ds, mask, sel, seed=11)
            tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                       qlo, qhi, mask, k)
            row = {}
            for name, route in (("engine_auto", None), ("graph", "graph"),
                                ("pruned", "pruned")):
                req = SearchRequest(ds.queries, (qlo, qhi), mask, k=k, ef=64,
                                    route=route)

                def cold_search(req=req):
                    # auto-route pays selectivity estimation on every timed
                    # call (comparable with pre-cache history entries)
                    eng._sel_cache.clear()
                    return eng.search(req)

                # best-of-N: this box's CPU is noisily shared, and the
                # engine_auto >= min(graph, pruned) invariant drowns in
                # mean-of-N scheduler noise
                dt, res = time_call(cold_search, repeats=7, best=True,
                                    name=f"smoke_{name}")
                lt = last_timing()
                # percentile spread across the 7 repeats, next to the
                # best-of-N headline (flags noisy boxes in the artifact)
                row[name] = {"qps": round(n_queries / dt, 1),
                             "recall_at_10": round(res.recall_vs(tids), 4),
                             "repeat_ms_p50": round(lt["p50_s"] * 1e3, 2),
                             "repeat_ms_p95": round(lt["p95_s"] * 1e3, 2)}
            rrann[f"sel_{int(sel * 100):02d}"] = row
        report["exp1_rrann"] = rrann
        # headline wavefront fields (tracked by history + the CI perf gate)
        report["graph_qps"] = rrann["sel_05"]["graph"]["qps"]
        report["graph_qps_repeat_ms"] = {
            "p50": rrann["sel_05"]["graph"]["repeat_ms_p50"],
            "p95": rrann["sel_05"]["graph"]["repeat_ms_p95"]}

    def sec_wavefront():
        from .exp12_wavefront import wavefront_metrics
        # mixed-selectivity batch: convergence skew (the thing compaction
        # wins on) only exists when narrow and wide queries share a batch
        wf = wavefront_metrics(eng, ds, mask=mask, sel=(0.02, 0.30), ef=64,
                               k=k)
        report["wasted_eval_frac"] = round(wf["wasted_eval_frac_chunked"], 4)
        report["wavefront"] = {
            "steps_global": wf["steps_global"],
            "conv_steps_p50": round(wf["conv_steps_p50"], 1),
            "conv_steps_p90": round(wf["conv_steps_p90"], 1),
            "wasted_eval_frac_single": round(wf["wasted_eval_frac_single"], 4),
            "wasted_eval_frac_chunked": round(wf["wasted_eval_frac_chunked"], 4),
        }

    def sec_planner():
        # planner microbenchmark (acceptance: >= 10x over the seed loop)
        report["planner"] = {
            k_: (round(v, 4) if isinstance(v, float) else v)
            for k_, v in planner_microbench(idx, mask=mask).items()}

    def sec_streaming():
        # streaming churn lane: recall after 10% inserts + 5% deletes vs a
        # static rebuild of the post-churn corpus
        report["streaming"] = streaming_churn_metrics()

    def sec_kernel():
        # kernel bench (interpret mode on CPU: correctness-path timing only)
        import jax.numpy as jnp
        from repro.kernels import ops
        from repro.kernels.ref import pairwise_l2_masked_ref
        rng = np.random.default_rng(0)
        Qn, Nn, dk = 8, 512, 32
        q = rng.normal(0, 1, (Qn, dk)).astype(np.float32)
        c = rng.normal(0, 1, (Nn, dk)).astype(np.float32)
        lo = rng.uniform(0, 100, Nn).astype(np.float32)
        hi = lo + 10
        ql = np.full(Qn, 20, np.float32)
        qh = np.full(Qn, 60, np.float32)
        dt_ref, _ = time_call(lambda: np.asarray(pairwise_l2_masked_ref(
            jnp.asarray(q), jnp.asarray(c), jnp.asarray(lo), jnp.asarray(hi),
            jnp.asarray(ql), jnp.asarray(qh), mask)))
        dt_pal, _ = time_call(lambda: np.asarray(ops.pairwise_l2_masked(
            q, c, lo, hi, ql, qh, mask)))
        from .kernel_bench import _wavefront_step_inputs
        wf_in = _wavefront_step_inputs(rng, Qn, Nn, dk, M=24, L=32)
        dt_gtk, _ = time_call(lambda: np.asarray(
            ops.gathered_topk(*wf_in)[1]))
        dt_gtk_ref, _ = time_call(lambda: np.asarray(ops.gathered_topk_ref(
            *(jnp.asarray(a) for a in wf_in))[1]))
        report["kernel"] = {
            "pairwise_ref_us": round(dt_ref * 1e6, 1),
            "pairwise_pallas_interpret_us": round(dt_pal * 1e6, 1),
            "gathered_topk_interpret_us": round(dt_gtk * 1e6, 1),
            "gathered_topk_ref_us": round(dt_gtk_ref * 1e6, 1)}

    def sec_compression():
        # quantized tier at smoke scale: bytes-per-vector + recall parity vs
        # the f32 engine on the same queries (QPS at n=800 is meaningless —
        # the speedup headline lives in exp15 / BENCH_compression.json)
        from repro.core import EngineConfig
        qlo, qhi = make_queries(ds, mask, 0.10, seed=11)
        tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                   qlo, qhi, mask, k)
        comp = {}
        for tier in ("int8", "float16"):
            qidx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp"),
                             m=12, ef_con=64, storage_dtype=tier)
            qeng = QueryEngine(qidx, config=EngineConfig())
            sb = qidx.storage_bytes()
            row = {"compression_ratio": round(sb["compression_ratio"], 3),
                   "scan_bytes": sb["scan_bytes"]}
            for route in ("flat", "graph"):
                res = qeng.search(SearchRequest(ds.queries, (qlo, qhi), mask,
                                                k=k, ef=64, route=route))
                row[f"recall_at_10_{route}"] = round(res.recall_vs(tids), 4)
            comp[tier] = row
        report["compression"] = comp

    # each section is isolated: one failing experiment records an error and
    # the rest still produce their metrics (and the history line)
    for name, fn in (("exp1_rrann", sec_exp1), ("wavefront", sec_wavefront),
                     ("planner", sec_planner), ("streaming", sec_streaming),
                     ("kernel", sec_kernel),
                     ("compression", sec_compression)):
        _section(report, name, fn)

    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path}")
    if history_path:
        record = append_history(report, history_path)
        print(f"appended {history_path}: {json.dumps(record, sort_keys=True)}")
    if "planner" in report:
        print(json.dumps(report["planner"], indent=2))
    return report
