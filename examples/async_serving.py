"""Async serving walkthrough: SLO admission, slot refill, typed shedding.

Stands up the continuous-batching :class:`repro.serving.AsyncRetrievalServer`
over a built MSTG engine and walks the operator surface end to end:

1. staggered submission — later waves are admitted into wavefront slots
   freed by converged queries (observable as ``refills`` in the metrics),
   while every answer stays bit-identical to solo execution;
2. deadlines and priorities — an expired queued request is shed as a typed
   ``Rejected("deadline_expired")``, never an exception; a late *finisher*
   is served with ``deadline_missed=True``;
3. overload — a tiny bounded queue sheds ``Rejected("queue_full")``;
4. the metrics snapshot — queue-wait / e2e percentiles, shed counts,
   batch occupancy and refill efficiency.

    PYTHONPATH=src python examples/async_serving.py
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import (Overlaps, QueryContained, QueryEngine, MSTGIndex,
                        Rejected, SearchRequest, Served)
from repro.data import make_range_dataset, make_queries
from repro.serving import AsyncRetrievalServer, SLOPolicy


def main():
    n, d, n_req = 1500, 32, 48
    ds = make_range_dataset(n=n, d=d, n_queries=n_req, quantize=128, seed=0)
    idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp", "Tpp"),
                    m=12, ef_con=64)
    engine = QueryEngine(idx)
    embed_fn = lambda items: ds.queries[np.asarray(items)]  # stub embedding

    # 1. continuous batching: submit in waves, step between them — later
    # waves refill slots freed by earlier queries mid-flight
    srv = AsyncRetrievalServer(
        engine, embed_fn, k=10, ef=64, route="graph", chunk=8,
        policy=SLOPolicy(max_queue=256, max_wait_ms=1.0, max_batch=16))
    ov, qc = Overlaps(), QueryContained()
    qlo_o, qhi_o = make_queries(ds, ov.mask, 0.15, seed=2)
    qlo_c, qhi_c = make_queries(ds, qc.mask, 0.15, seed=2)
    tickets = {}
    t0 = time.time()
    for wave in range(4):
        for i in range(wave * 12, (wave + 1) * 12):
            pred = ov if i % 2 == 0 else qc
            qlo, qhi = (qlo_o, qhi_o) if i % 2 == 0 else (qlo_c, qhi_c)
            tickets[srv.submit(i, qlo[i], qhi[i], pred)] = i
        srv.step()                       # waves interleave with in-flight work
    results = srv.run_until_idle()
    dt = time.time() - t0
    served = {t: r for t, r in results.items() if isinstance(r, Served)}
    print(f"served {len(served)}/{n_req} in {dt*1e3:.0f} ms "
          f"({len(served)/dt:.0f} qps)")

    # every answer == solo execution, bit for bit
    t, r = next(iter(served.items()))
    i = tickets[t]
    pred = ov if i % 2 == 0 else qc
    qlo, qhi = (qlo_o, qhi_o) if i % 2 == 0 else (qlo_c, qhi_c)
    solo = engine.execute(SearchRequest(
        ds.queries[i:i + 1], (qlo[i:i + 1], qhi[i:i + 1]), pred, k=10, ef=64,
        route="graph"))
    assert (r.hit.ids == solo.ids[0]).all()
    assert (r.hit.dists == solo.dists[0]).all()
    print(f"ticket {t}: top ids {r.hit.ids[:5].tolist()} "
          f"(bit-identical to solo execute)")

    # 2. deadlines: an expired queued request sheds, typed — never raises
    lazy = AsyncRetrievalServer(engine, embed_fn, k=10, ef=64,
                                policy=SLOPolicy(max_wait_ms=50.0))
    t_dead = lazy.submit(0, qlo_o[0], qhi_o[0], ov, deadline_ms=1.0)
    time.sleep(0.02)                     # deadline passes while queued
    out = lazy.run_until_idle()[t_dead]
    assert isinstance(out, Rejected) and not out
    print(f"expired request shed: Rejected(reason={out.reason!r})")

    # 3. overload: bounded queue sheds queue_full at submit
    tiny = AsyncRetrievalServer(engine, embed_fn, k=10, ef=64,
                                policy=SLOPolicy(max_queue=4,
                                                 max_wait_ms=1e3))
    outcomes = [tiny.submit(i, qlo_o[i], qhi_o[i], ov) for i in range(8)]
    n_shed = sum(isinstance(o, Rejected) for o in outcomes)
    print(f"overload: {8 - n_shed} admitted, {n_shed} shed queue_full")
    tiny.run_until_idle()

    # 4. the operator view
    snap = srv.snapshot()
    print("metrics snapshot:")
    print(f"  served={snap['served']} shed={snap['shed']} "
          f"deadline_missed={snap['deadline_missed']}")
    print(f"  queue-wait ms p50/p95/p99: {snap['queue_wait_ms']['p50']:.2f}/"
          f"{snap['queue_wait_ms']['p95']:.2f}/"
          f"{snap['queue_wait_ms']['p99']:.2f}")
    print(f"  e2e ms p50/p95/p99: {snap['e2e_ms']['p50']:.2f}/"
          f"{snap['e2e_ms']['p95']:.2f}/{snap['e2e_ms']['p99']:.2f}")
    print(f"  occupancy={snap['batch_occupancy']:.2f} "
          f"refill_efficiency={snap['refill_efficiency']:.2f} "
          f"refills={snap['refills']} refilled_rows={snap['refilled_rows']}")
    assert snap["refills"] > 0           # the waves really did refill slots
    print("OK")


if __name__ == "__main__":
    main()
