"""Vector compression walkthrough: build a quantized index (int8 codes +
exact float32 re-rank), compare recall and bytes against the float32 tier,
and show the rerank_k knob and quantized save/load.

    PYTHONPATH=src python examples/compression.py
"""
import sys
import tempfile
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import (EngineConfig, IndexSpec, MSTGIndex, Overlaps,
                        QueryEngine, SearchRequest)
from repro.data import brute_force_topk, make_queries, make_range_dataset, \
    recall_at_k


def main():
    ds = make_range_dataset(n=60_000, d=64, n_queries=16, quantize=64, seed=0)
    qlo, qhi = make_queries(ds, Overlaps().mask, 0.5, seed=1)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                               qlo, qhi, Overlaps().mask, 10)
    req = SearchRequest(ds.queries, (qlo, qhi), Overlaps().mask, k=10,
                        route="flat")

    # 1. one build per storage tier — the tier lives on the IndexSpec, so it
    # persists and streams with the index
    print(f"{'tier':>8} {'scan MB':>8} {'ratio':>6} {'QPS':>8} {'recall':>7}")
    engines = {}
    for tier in ("float32", "float16", "int8"):
        idx = MSTGIndex.build(IndexSpec(predicate=Overlaps(), variants=(),
                                        storage_dtype=tier),
                              ds.vectors, ds.lo, ds.hi)
        eng = QueryEngine(idx)
        engines[tier] = eng
        res = eng.search(req)                       # warm the jit cache
        t0 = time.perf_counter()
        for _ in range(3):
            res = eng.search(req)
        dt = (time.perf_counter() - t0) / 3
        sb = idx.storage_bytes()
        print(f"{tier:>8} {sb['scan_bytes']/1e6:8.1f} "
              f"{sb['compression_ratio']:6.2f} {len(req)/dt:8.1f} "
              f"{recall_at_k(np.asarray(res.ids), tids):7.4f}")

    # 2. the rerank_k knob: how wide the exact re-rank looks. k trusts the
    # approximate (quantized) order; the default max(4k, 32) recovers recall
    idx8 = engines["int8"].index
    print("\nrerank_k sweep (int8):")
    for r in (10, 20, 40, 80):
        eng = QueryEngine(idx8, config=EngineConfig(rerank_k=r))
        rec = recall_at_k(np.asarray(eng.search(req).ids), tids)
        print(f"  rerank_k={r:<3d} recall@10={rec:.4f}")

    # 3. quantizing an existing float32 index on the fly (no rebuild): the
    # engine fits codes at construction from the retained float32 corpus
    eng = QueryEngine(engines["float32"].index,
                      config=EngineConfig(storage_dtype="int8"))
    rec = recall_at_k(np.asarray(eng.search(req).ids), tids)
    print(f"\non-the-fly int8 over a float32 index: recall@10={rec:.4f}")

    # 4. persistence: codes/scales travel inside the one .npz artifact
    with tempfile.TemporaryDirectory() as tmp:
        path = idx8.save(f"{tmp}/quant.npz")
        loaded = MSTGIndex.load(path)
        same = np.array_equal(loaded.storage.codes, idx8.storage.codes)
        print(f"saved+loaded int8 artifact: codes bit-identical={same}")


if __name__ == "__main__":
    main()
