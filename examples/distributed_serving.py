"""Distributed RRANN serving (deliverable b): corpus sharded over 8 fake
devices, exact filtered top-k with both merge schedules, plus the batched
RetrievalServer front end driven by the declarative Predicate API.

    PYTHONPATH=src python examples/distributed_serving.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys
import time

sys.path.insert(0, "src")

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import (IndexSpec, MSTGIndex, Overlaps, QueryEngine,
                        SearchRequest)
from repro.distributed import sharded_flat_topk
from repro.data import make_range_dataset, make_queries, brute_force_topk, recall_at_k
from repro.serving import RetrievalServer


def main():
    ds = make_range_dataset(n=4096, d=32, n_queries=32, quantize=128, seed=0)
    pred = Overlaps()
    qlo, qhi = make_queries(ds, pred.mask, 0.1, seed=1)
    tids, tds = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                 qlo, qhi, pred.mask, 10)
    mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
    print(f"mesh: {mesh.shape}; corpus {ds.n} sharded 8-way")
    for merge in ("all_gather", "tournament"):
        args = (mesh, jnp.asarray(ds.vectors), jnp.asarray(ds.lo, jnp.float32),
                jnp.asarray(ds.hi, jnp.float32), jnp.asarray(ds.queries),
                jnp.asarray(qlo, jnp.float32), jnp.asarray(qhi, jnp.float32))
        ids, d = sharded_flat_topk(*args, mask=pred.mask, k=10, merge=merge)
        t0 = time.time()
        ids, d = sharded_flat_topk(*args, mask=pred.mask, k=10, merge=merge)
        dt = time.time() - t0
        r = recall_at_k(np.asarray(ids), tids)
        print(f"  merge={merge:11s} recall@10={r:.3f} "
              f"({len(qlo)/dt:.0f} qps on 8 shards)")

    # batched serving front end on a single-host MSTG engine: requests carry
    # Predicate objects, the whole tick is embedded in one stacked call
    idx = MSTGIndex.build(IndexSpec(predicate=pred, m=12, ef_con=64),
                          ds.vectors[:1500], ds.lo[:1500], ds.hi[:1500])
    server = RetrievalServer(QueryEngine(idx),
                             embed_fn=lambda items: ds.queries[np.asarray(items)],
                             k=10)
    for i in range(16):
        server.submit(i, qlo[i], qhi[i], pred)
    t0 = time.time()
    res = server.tick()
    print(f"  retrieval server: {len(res)} requests in "
          f"{(time.time()-t0)*1e3:.0f} ms "
          f"(hit0 valid={int(res[0].valid.sum())}/{len(res[0].ids)})")


if __name__ == "__main__":
    main()
