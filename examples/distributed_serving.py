"""Distributed RRANN serving (deliverable b): corpus sharded over 8 fake
devices behind a ShardedDeployment — both merge schedules, per-shard fan-in
narrowing, simulated shard loss (degraded answers, never errors), and the
batched RetrievalServer front end serving straight from the deployment.

    PYTHONPATH=src python examples/distributed_serving.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys
import time

sys.path.insert(0, "src")

import numpy as np
import jax

from repro.core import (EngineConfig, IndexSpec, Overlaps, SearchRequest)
from repro.data import make_range_dataset, make_queries, brute_force_topk, recall_at_k
from repro.distributed import DeploymentSpec, ShardedDeployment
from repro.launch.mesh import make_mesh
from repro.serving import RetrievalServer


def main():
    ds = make_range_dataset(n=4096, d=32, n_queries=32, quantize=128, seed=0)
    pred = Overlaps()
    qlo, qhi = make_queries(ds, pred.mask, 0.1, seed=1)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                               qlo, qhi, pred.mask, 10)
    mesh = make_mesh((8,), ("data",))
    req = SearchRequest(ds.queries, (qlo, qhi), pred, k=10)
    print(f"mesh: 8 x {jax.devices()[0].platform}; corpus {ds.n} sharded 8-way")

    # exact flat shards, fused device path, both merge schedules
    for merge in ("all_gather", "tournament"):
        dep = ShardedDeployment.flat(
            ds.vectors, ds.lo, ds.hi, mesh=mesh,
            spec=DeploymentSpec(n_shards=8, merge=merge))
        dep.execute(req)  # compile
        t0 = time.time()
        res = dep.execute(req)
        dt = time.time() - t0
        print(f"  merge={merge:11s} recall@10="
              f"{recall_at_k(res.ids, tids):.3f} "
              f"({len(qlo)/dt:.0f} qps on 8 shards)")

    # narrow the per-shard fan-in: merge bytes drop ~2.5x, recall degrades
    dep4 = ShardedDeployment.flat(
        ds.vectors, ds.lo, ds.hi, mesh=mesh,
        spec=DeploymentSpec(n_shards=8, per_shard_k=4))
    r4 = dep4.execute(req)
    print(f"  per_shard_k=4: recall@10={recall_at_k(r4.ids, tids):.3f} "
          f"(fan-in 4/10 per shard)")

    # per-shard MSTG graph engines + shard loss: answers degrade, never raise.
    # Shards build through the coarse-quantizer candidate stage in a process
    # pool (the same configuration the scheduled scale lane runs at n=1M —
    # here the corpus is demo-sized, so the threshold is lowered to engage
    # the quantizer); build_report attributes wall clock per worker
    dep = ShardedDeployment.build(
        ds.vectors, ds.lo, ds.hi, mesh=mesh,
        spec=DeploymentSpec(n_shards=8,
                            engine=EngineConfig(route="graph"),
                            index=IndexSpec(predicate=pred, m=12, ef_con=64,
                                            candidate_stage="coarse",
                                            coarse_threshold=256),
                            build_workers=2))
    rep = dep.build_report
    print(f"  graph shard build: pool_size={rep['pool_size']} "
          f"wall={rep['wall_s']:.2f}s "
          f"rows/s={rep['rows_per_sec']:.0f} "
          f"slowest shard={max(rep['shard_seconds']):.2f}s")
    dep.fail(3)
    res = dep.execute(req)
    print(f"  graph shards, shard 3 down: degraded={res.degraded} "
          f"missing={res.report.missing_shards} "
          f"recall@10={recall_at_k(res.ids, tids):.3f} (vs full corpus)")

    # batched serving front end straight on the deployment: requests carry
    # Predicate objects, the whole tick is embedded in one stacked call
    server = RetrievalServer(dep,
                             embed_fn=lambda items: ds.queries[np.asarray(items)],
                             k=10)
    for i in range(16):
        server.submit(i, qlo[i], qhi[i], pred)
    t0 = time.time()
    res = server.tick()
    print(f"  retrieval server on the deployment: {len(res)} requests in "
          f"{(time.time()-t0)*1e3:.0f} ms "
          f"(degraded_queries={server.tick_stats['degraded_queries']})")


if __name__ == "__main__":
    main()
