"""Quickstart: the declarative RRANN API end to end — build an index from an
IndexSpec, search with Predicate + SearchRequest on all three engines, then
save/load the index and verify the serving artifact is bit-identical.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import (IndexSpec, LeftOverlap, MSTGIndex, Overlaps,
                        QueryContained, QueryEngine, RightOverlap,
                        SearchRequest)
from repro.data import make_range_dataset, make_queries, brute_force_topk


def main():
    # 1. a corpus of (vector, [lo, hi]) objects — e.g. products with price ranges
    ds = make_range_dataset(n=2000, d=32, n_queries=16, quantize=128, seed=0)

    # 2. declare what the index must serve; build derives the MSTG variants
    spec = IndexSpec(predicate=Overlaps(), m=12, ef_con=64)
    t0 = time.time()
    idx = MSTGIndex.build(spec, ds.vectors, ds.lo, ds.hi)
    print(f"built MSTG over n={ds.n} in {time.time()-t0:.1f}s "
          f"({idx.index_bytes()/1e6:.1f} MB, |A|={idx.domain.K}, "
          f"variants={sorted(idx.variants)})")
    eng = QueryEngine(idx)

    # 3. query: vectors + ranges + any predicate disjunction
    for pred, nm in ((Overlaps(), "overlap (1|2|3|4)"),
                     (QueryContained(), "query-contained (2)"),
                     (LeftOverlap() | RightOverlap(), "ends-overlap (1|3)")):
        qlo, qhi = make_queries(ds, pred.mask, 0.10, seed=3)
        tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                   qlo, qhi, pred.mask, 10)
        graph = eng.search(SearchRequest(ds.queries, (qlo, qhi), pred,
                                         k=10, ef=64, route="graph"))
        pruned = eng.search(SearchRequest(ds.queries, (qlo, qhi), pred,
                                          k=10, route="pruned"))
        print(f"  {nm:24s} graph recall@10 = {graph.recall_vs(tids):.3f}   "
              f"pruned-exact recall@10 = {pruned.recall_vs(tids):.3f}   "
              f"slots={graph.report.slot_count}")

    # 4. persist once, serve from the artifact (no rebuild)
    with tempfile.TemporaryDirectory() as td:
        path = idx.save(os.path.join(td, "mstg_index"))
        print(f"saved -> {os.path.basename(path)} "
              f"({os.path.getsize(path)/1e6:.1f} MB)")
        served = QueryEngine(MSTGIndex.load(path))
        qlo, qhi = make_queries(ds, Overlaps().mask, 0.10, seed=3)
        req = SearchRequest(ds.queries, (qlo, qhi), Overlaps(), k=10)
        a, b = eng.search(req), served.search(req)
        same = (np.array_equal(a.ids, b.ids)
                and np.array_equal(a.dists, b.dists))
        print(f"loaded index bit-identical results: {same} "
              f"(route={b.report.route}, "
              f"mean est selectivity={b.report.mean_selectivity:.3f})")


if __name__ == "__main__":
    main()
