"""Quickstart: build an MSTG index, run all three search engines, check recall.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import (ANY_OVERLAP, QUERY_CONTAINED, MSTGIndex, MSTGSearcher,
                        FlatSearcher, intervals as iv)
from repro.data import make_range_dataset, make_queries, brute_force_topk, recall_at_k


def main():
    # 1. a corpus of (vector, [lo, hi]) objects — e.g. products with price ranges
    ds = make_range_dataset(n=2000, d=32, n_queries=16, quantize=128, seed=0)

    # 2. build the paper's index (variants cover any RR predicate disjunction)
    t0 = time.time()
    idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp", "Tpp"),
                    m=12, ef_con=64)
    print(f"built MSTG over n={ds.n} in {time.time()-t0:.1f}s "
          f"({idx.index_bytes()/1e6:.1f} MB, |A|={idx.domain.K})")

    # 3. query: vectors + range + any RR predicate
    for mask, nm in ((ANY_OVERLAP, "overlap (1|2|3|4)"),
                     (QUERY_CONTAINED, "query-contained (2)"),
                     (iv.LEFT_OVERLAP | iv.RIGHT_OVERLAP, "ends-overlap (1|3)")):
        qlo, qhi = make_queries(ds, mask, 0.10, seed=3)
        tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                   qlo, qhi, mask, 10)
        gs = MSTGSearcher(idx)
        ids, dists = gs.search(ds.queries, qlo, qhi, mask, k=10, ef=64)
        fs = FlatSearcher(idx)
        fids, _ = fs.search_pruned(ds.queries, qlo, qhi, mask, k=10)
        print(f"  {nm:24s} graph recall@10 = {recall_at_k(ids, tids):.3f}   "
              f"pruned-exact recall@10 = {recall_at_k(fids, tids):.3f}")


if __name__ == "__main__":
    main()
