"""RRANN end to end on the declarative API: every predicate family the paper
supports — the atomic cases, disjunctions, the RFANN / IFANN / TSANN
specializations (paper Table 1), and the Allen disjoint relations
(Appendix A) — against brute-force ground truth.

    PYTHONPATH=src python examples/rrann_search.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import (After, Before, ContainedBy, Contains, IndexSpec,
                        LeftOverlap, MSTGIndex, Overlaps, QueryContained,
                        QueryContaining, QueryEngine, RightOverlap,
                        SearchRequest, intervals as iv)
from repro.data import make_range_dataset, make_queries, brute_force_topk


def main():
    ds = make_range_dataset(n=1500, d=32, n_queries=12, quantize=64, seed=1)
    idx = MSTGIndex.build(IndexSpec(variants=("T", "Tp", "Tpp"), m=12,
                                    ef_con=64), ds.vectors, ds.lo, ds.hi)
    eng = QueryEngine(idx)  # auto-routes graph vs exact-pruned by selectivity

    cases = [
        ("1 query-left-overlap", LeftOverlap()),
        ("2 query-contained   ", QueryContained()),
        ("3 query-right-overlap", RightOverlap()),
        ("4 query-containing  ", QueryContaining()),
        ("1|2|3|4 any-overlap ", Overlaps()),
        ("2|4 containment-both", Contains() | ContainedBy()),
        ("< strictly-before   ", Before()),
        ("> strictly-after    ", After()),
    ]
    for nm, pred in cases:
        qlo, qhi = make_queries(ds, pred.mask, 0.12, seed=5)
        tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                   qlo, qhi, pred.mask, 10)
        res = eng.search(SearchRequest(ds.queries, (qlo, qhi), pred,
                                       k=10, ef=64))
        rep = res.report
        print(f"{nm}  searches={rep.slot_count}  route={rep.route:<6}  "
              f"recall@10={res.recall_vs(tids):.3f}")

    # table-1 specializations
    print("\nspecializations:")
    attr = (ds.lo + ds.hi) / 2
    rf_idx = MSTGIndex.build(IndexSpec(predicate=iv.RFANN_MASK, m=12,
                                       ef_con=64), ds.vectors, attr, attr)
    qlo = np.quantile(attr, 0.2) * np.ones(12)
    qhi = np.quantile(attr, 0.5) * np.ones(12)
    tids, _ = brute_force_topk(ds.vectors, attr, attr, ds.queries, qlo, qhi,
                               iv.RFANN_MASK, 10)
    res = QueryEngine(rf_idx).search(SearchRequest(
        ds.queries, (qlo, qhi), iv.RFANN_MASK, k=10, ef=64))
    print(f"  RFANN recall@10 = {res.recall_vs(tids):.3f}")
    t = float(np.median(attr))
    qlo = np.full(12, t); qhi = np.full(12, t)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries, qlo, qhi,
                               iv.TSANN_MASK, 10)
    res = eng.search(SearchRequest(ds.queries, (qlo, qhi), iv.TSANN_MASK,
                                   k=10, ef=64))
    print(f"  TSANN recall@10 = {res.recall_vs(tids):.3f}")
    qlo, qhi = make_queries(ds, iv.IFANN_MASK, 0.15, seed=7)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries, qlo, qhi,
                               iv.IFANN_MASK, 10)
    res = eng.search(SearchRequest(ds.queries, (qlo, qhi), iv.IFANN_MASK,
                                   k=10, ef=64))
    print(f"  IFANN recall@10 = {res.recall_vs(tids):.3f}")


if __name__ == "__main__":
    main()
