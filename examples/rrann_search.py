"""RRANN end to end: every predicate family the paper supports, including the
RFANN / IFANN / TSANN specializations (paper Table 1) and the Allen disjoint
relations (Appendix A), against brute-force ground truth.

    PYTHONPATH=src python examples/rrann_search.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import MSTGIndex, QueryEngine, intervals as iv
from repro.data import make_range_dataset, make_queries, brute_force_topk, recall_at_k


def main():
    ds = make_range_dataset(n=1500, d=32, n_queries=12, quantize=64, seed=1)
    idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp", "Tpp"),
                    m=12, ef_con=64)
    gs = QueryEngine(idx)  # auto-routes graph vs exact-pruned by selectivity

    cases = [
        ("1 query-left-overlap", iv.LEFT_OVERLAP),
        ("2 query-contained   ", iv.QUERY_CONTAINED),
        ("3 query-right-overlap", iv.RIGHT_OVERLAP),
        ("4 query-containing  ", iv.QUERY_CONTAINING),
        ("1|2|3|4 any-overlap ", iv.ANY_OVERLAP),
        ("2|4 containment-both", iv.QUERY_CONTAINED | iv.QUERY_CONTAINING),
        ("< strictly-before   ", iv.BEFORE),
        ("> strictly-after    ", iv.AFTER),
    ]
    for nm, mask in cases:
        qlo, qhi = make_queries(ds, mask, 0.12, seed=5)
        tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                   qlo, qhi, mask, 10)
        plan = idx.plan(mask, float(qlo[0]), float(qhi[0]))
        route = gs.route_for(mask, qlo, qhi)
        ids, _ = gs.search(ds.queries, qlo, qhi, mask, k=10, ef=64)
        print(f"{nm}  searches={len(plan)}  route={route:<6}  "
              f"recall@10={recall_at_k(ids, tids):.3f}")

    # table-1 specializations
    print("\nspecializations:")
    attr = (ds.lo + ds.hi) / 2
    rf = MSTGIndex(ds.vectors, attr, attr, variants=("Tpp",), m=12, ef_con=64)
    qlo = np.quantile(attr, 0.2) * np.ones(12)
    qhi = np.quantile(attr, 0.5) * np.ones(12)
    tids, _ = brute_force_topk(ds.vectors, attr, attr, ds.queries, qlo, qhi,
                               iv.RFANN_MASK, 10)
    ids, _ = QueryEngine(rf).search(ds.queries, qlo, qhi, iv.RFANN_MASK,
                                    k=10, ef=64)
    print(f"  RFANN recall@10 = {recall_at_k(ids, tids):.3f}")
    t = float(np.median(attr))
    qlo = np.full(12, t); qhi = np.full(12, t)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries, qlo, qhi,
                               iv.TSANN_MASK, 10)
    ids, _ = gs.search(ds.queries, qlo, qhi, iv.TSANN_MASK, k=10, ef=64)
    print(f"  TSANN recall@10 = {recall_at_k(ids, tids):.3f}")
    qlo, qhi = make_queries(ds, iv.IFANN_MASK, 0.15, seed=7)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries, qlo, qhi,
                               iv.IFANN_MASK, 10)
    ids, _ = gs.search(ds.queries, qlo, qhi, iv.IFANN_MASK, k=10, ef=64)
    print(f"  IFANN recall@10 = {recall_at_k(ids, tids):.3f}")


if __name__ == "__main__":
    main()
