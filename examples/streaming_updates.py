"""Streaming ingestion walkthrough: the delta -> flush -> compact lifecycle.

Builds a live corpus with the segmented MSTG — upserts and deletes land in a
mutable delta buffer, ``flush()`` freezes the delta into an immutable MSTG
segment, ``compact()`` merges small segments and drops tombstoned rows — then
shows that search quality survives churn (recall vs a from-scratch rebuild)
and that save/load restores segments, tombstones, AND the unflushed delta.

    PYTHONPATH=src python examples/streaming_updates.py
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import (IndexSpec, MSTGIndex, Overlaps, QueryEngine,
                        SearchRequest)
from repro.data import make_range_dataset, make_queries, brute_force_topk
from repro.streaming import SegmentedIndex


def main():
    n, d = 1200, 32
    ds = make_range_dataset(n=n, d=d, n_queries=16, quantize=128, seed=0)
    spec = IndexSpec(predicate=Overlaps(), m=12, ef_con=64)

    # 1. bulk-load in two waves; each flush freezes an immutable MSTG segment
    sidx = SegmentedIndex(spec)
    t0 = time.time()
    sidx.add(np.arange(600), ds.vectors[:600], ds.lo[:600], ds.hi[:600])
    sidx.flush()
    sidx.add(np.arange(600, n), ds.vectors[600:], ds.lo[600:], ds.hi[600:])
    sidx.flush()
    print(f"bulk-loaded n={n} into {len(sidx.segments)} segments "
          f"in {time.time()-t0:.1f}s")

    # 2. live churn: upserts go to the delta, deletes tombstone frozen rows
    rng = np.random.default_rng(1)
    fresh = make_range_dataset(n=120, d=d, n_queries=1, quantize=128, seed=2)
    sidx.add(np.arange(n, n + 120), fresh.vectors, fresh.lo, fresh.hi)
    sidx.delete(rng.choice(n, 60, replace=False))
    moved = rng.choice(600, 10, replace=False)      # upsert frozen rows
    sidx.add(moved, ds.vectors[moved] * 0.9, ds.lo[moved], ds.hi[moved])
    print(f"after churn: {sidx.stats()}")

    # 3. query the streamed state: fan-out over segments + delta, tombstones
    #    filtered with per-segment over-fetch (exact routes stay recall-1.0)
    qlo, qhi = make_queries(ds, Overlaps().mask, 0.10, seed=3)
    req = SearchRequest(ds.queries, (qlo, qhi), Overlaps(), k=10)
    res = sidx.search(req)
    print("per-segment routing:",
          [(r.segment, r.route, f"k={r.k_fetched}", f"tombs={r.tombstones}")
           for r in res.report.segments])

    # 4. durability: manifest dir restores segments + tombstones + delta
    with tempfile.TemporaryDirectory() as td:
        root = os.path.join(td, "live_index")
        sidx.save(root)
        loaded = SegmentedIndex.load(root)
        lres = loaded.search(req)
        same = (np.array_equal(res.ids, lres.ids)
                and np.array_equal(res.dists, lres.dists))
        print(f"save/load round-trip bit-identical: {same} "
              f"(files: {sorted(os.listdir(root))})")

    # 5. compact: merge segments, drop tombstones; a fully compacted index
    #    equals a from-scratch build over the live corpus (canonical order)
    t0 = time.time()
    sidx.flush()
    rep = sidx.compact(full=True)
    print(f"compacted {rep['merged']} -> {rep['new_segment']} "
          f"({rep['rows']} rows, dropped {rep['dropped']}) "
          f"in {time.time()-t0:.1f}s")

    seg = sidx.segments[0]
    static = QueryEngine(MSTGIndex.build(
        spec, seg.index.vectors, seg.index.lo, seg.index.hi))
    sres = static.search(req)
    ext = np.where(sres.ids >= 0, seg.ext_ids[np.clip(sres.ids, 0, None)],
                   -1)
    tids, _ = brute_force_topk(seg.index.vectors, seg.index.lo, seg.index.hi,
                               ds.queries, qlo, qhi, Overlaps().mask, 10)
    truth = np.where(tids >= 0, seg.ext_ids[np.clip(tids, 0, None)], -1)
    got = sidx.search(req)
    print(f"compacted == static rebuild: "
          f"{np.array_equal(got.ids, ext)}; "
          f"recall vs brute force: {got.recall_vs(truth):.3f}")


if __name__ == "__main__":
    main()
