"""Observability walkthrough: trace spans, explain(), metrics, profiling.

Walks the ``repro.obs`` surface end to end on a small MSTG index:

1. one traced request — ``SearchRequest(trace=True)`` returns a
   ``SearchResult`` carrying a span tree (plan -> route decision -> per-slot
   execution -> merge); ``explain()`` renders it, ``trace.save()`` writes
   Chrome-trace JSON for chrome://tracing or https://ui.perfetto.dev;
2. the same through a 2-shard ``ShardedDeployment`` — the inner engines
   join the outer trace, so one file shows fan-out, per-shard search, and
   the merge schedule;
3. engine-level sampling — ``EngineConfig(trace_sample=0.25)`` traces every
   4th request with no caller opt-in;
4. scoped capture + kernel bandwidth — ``with obs.capture()`` traces any
   block; kernel spans annotate achieved GB/s vs the TPU v5e HBM peak;
5. the metrics registry — counters/histograms every subsystem records into,
   snapshot + Prometheus text (``repro.launch.serve --metrics-port`` serves
   the same over HTTP).

    PYTHONPATH=src python examples/tracing.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro import obs
from repro.core import (EngineConfig, IndexSpec, MSTGIndex, Overlaps,
                        QueryEngine, SearchRequest)
from repro.data import make_range_dataset, make_queries


def main():
    n, d = 1200, 32
    ds = make_range_dataset(n=n, d=d, n_queries=8, quantize=128, seed=0)
    spec = IndexSpec(variants=("T", "Tp"), m=12, ef_con=64)
    idx = MSTGIndex.build(spec, ds.vectors, ds.lo, ds.hi)
    engine = QueryEngine(idx)
    qlo, qhi = make_queries(ds, Overlaps().mask, 0.15, seed=2)

    # 1. one traced request: where did this query's time go?
    req = SearchRequest(ds.queries[:4], (qlo[:4], qhi[:4]), Overlaps(), k=10,
                        trace=True)
    res = engine.execute(req)
    print("=== explain(): route report + span tree ===")
    print(res.explain())
    path = res.trace.save("/tmp/repro_trace.json")
    print(f"\nChrome-trace JSON written to {path} "
          "(open in chrome://tracing or ui.perfetto.dev)\n")

    # 2. the same request through a sharded deployment: the shard engines
    # join the request's trace, so one tree covers fan-out + merge
    from repro.distributed import DeploymentSpec, ShardedDeployment
    dep = ShardedDeployment.build(ds.vectors, ds.lo, ds.hi, mesh=None,
                                  spec=DeploymentSpec(n_shards=2, index=spec))
    sres = dep.execute(req)
    print("=== sharded span tree ===")
    print(sres.trace.render())

    # 3. engine-level sampling: no caller opt-in, every 4th request traced
    sampled = QueryEngine(idx, config=EngineConfig(trace_sample=0.25))
    req_off = SearchRequest(ds.queries[:4], (qlo[:4], qhi[:4]), Overlaps())
    traced = [sampled.execute(req_off).trace is not None for _ in range(8)]
    print(f"\ntrace_sample=0.25 over 8 requests -> traced={traced}")

    # 4. scoped capture around arbitrary code; kernel spans carry achieved
    # bandwidth vs the HBM peak (repro.obs.profile)
    from repro.kernels import ops
    import jax.numpy as jnp
    q = jnp.asarray(ds.queries[:4])
    cand = jnp.asarray(np.stack([ds.vectors[:16]] * 4))
    with obs.capture() as tr:
        ops.gathered_l2(q, cand)
    ksp = tr.trace().roots[0]
    print(f"kernel span: {ksp.name} {ksp.args}")

    # 5. the process metrics registry (the engine recorded into it above)
    snap = obs.get_registry().snapshot()
    print(f"\nmetrics families: {sorted(snap['metrics'])}")
    print("Prometheus exposition (first lines):")
    print("\n".join(obs.get_registry().render_prometheus()
                    .splitlines()[:8]))
    print("\n(serve these over HTTP: python -m repro.launch.serve "
          "--metrics-port 9100)")


if __name__ == "__main__":
    main()
