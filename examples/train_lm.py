"""End-to-end LM training driver (deliverable b): any of the 10 assigned
architectures, with checkpoint/resume and straggler watchdog.

    PYTHONPATH=src python examples/train_lm.py --arch olmo-1b --steps 60
    PYTHONPATH=src python examples/train_lm.py --arch qwen3-moe-30b-a3b \
        --preset smoke --steps 30
    # on real hardware: --preset 100m --steps 300
"""
import sys

sys.path.insert(0, "src")

from repro.launch.train import main

if __name__ == "__main__":
    if not any(a.startswith("--steps") for a in sys.argv):
        sys.argv += ["--steps", "60"]
    main()
