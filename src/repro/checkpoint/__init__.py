from .checkpointer import Checkpointer
