from .checkpointer import Checkpointer
from .index_io import IndexIOError
