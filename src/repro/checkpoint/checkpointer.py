"""Sharded, atomic, async checkpointing with elastic restore (DESIGN.md §5).

Layout: ``<dir>/step_<N>/`` holding one ``.npy`` per pytree leaf (flattened
key path) + ``manifest.json`` (treedef, shapes, dtypes, step, extra metadata
like the data cursor). Writes go to ``step_<N>.tmp`` and are renamed only
after fsync — a torn write never shadows the previous checkpoint. ``save`` can
run on a background thread (async=True); ``wait()`` joins before the next
save so at most one write is in flight.

Elastic restore: leaves are host numpy arrays; ``restore(..., shardings=...)``
``device_put``s onto the *current* mesh, so a job restarted on a different
topology (lost pod) resharding-loads transparently. On a real multi-host fleet
each host writes its shard slice; this container is single-process, so leaves
are written whole — the format (per-leaf files + manifest) is the multi-host
one.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import numpy as np

import jax


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name.replace(" ", "_"), leaf))
    return out, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ---- save ----
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> str:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree, extra or {}))
            self._thread.start()
        else:
            self._write(step, host_tree, extra or {})
        return self.step_dir(step)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def _write(self, step: int, host_tree, extra: Dict):
        final = self.step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, _ = _flatten_with_paths(host_tree)
        manifest = {"step": step, "extra": extra, "leaves": []}
        for name, leaf in leaves:
            fn = name.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fn), leaf)
            manifest["leaves"].append(
                {"name": name, "file": fn, "shape": list(leaf.shape),
                 "dtype": str(leaf.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self):
        steps = self.list_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.step_dir(s), ignore_errors=True)

    # ---- restore ----
    def list_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, example_tree: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Returns (tree, step, extra). ``example_tree`` provides the treedef;
        ``shardings`` (same structure or a single sharding) triggers elastic
        device_put onto the current mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_name = {l["name"]: l for l in manifest["leaves"]}
        leaves, treedef = _flatten_with_paths(example_tree)
        out = []
        for name, _ in leaves:
            info = by_name[name]
            out.append(np.load(os.path.join(d, info["file"])))
        tree = jax.tree.unflatten(treedef, out)
        if shardings is not None:
            if jax.tree.structure(shardings, is_leaf=lambda x: hasattr(x, "mesh")) \
                    == jax.tree.structure(tree):
                tree = jax.tree.map(jax.device_put, tree, shardings)
            else:
                tree = jax.tree.map(lambda x: jax.device_put(x, shardings), tree)
        return tree, step, manifest["extra"]
