"""Single-file atomic ``.npz`` persistence for frozen index artifacts.

Same durability conventions as :mod:`repro.checkpoint.checkpointer` (write to
``<path>.tmp``, fsync, rename — a torn write never shadows a previous file),
but for the MSTG serving artifact: one ``.npz`` holding every array plus a
JSON metadata blob under the reserved key ``__meta__``. Kept free of any
``repro.core`` import so the core index can depend on it without a cycle.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Tuple

import numpy as np

META_KEY = "__meta__"


def save_npz_atomic(path: str, arrays: Dict[str, np.ndarray], meta: dict) -> str:
    """Atomically write ``arrays`` + ``meta`` to one uncompressed ``.npz``."""
    if META_KEY in arrays:
        raise ValueError(f"array key {META_KEY!r} is reserved for metadata")
    path = os.fspath(path)
    if not path.endswith(".npz"):
        path += ".npz"
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    tmp = path + ".tmp"
    payload = dict(arrays)
    payload[META_KEY] = np.frombuffer(
        json.dumps(meta, sort_keys=True).encode("utf-8"), dtype=np.uint8)
    with open(tmp, "wb") as f:
        np.savez(f, **payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)  # atomic publish
    return path


def load_npz(path: str) -> Tuple[Dict[str, np.ndarray], dict]:
    """Load a :func:`save_npz_atomic` file -> (arrays, meta)."""
    path = os.fspath(path)
    if not os.path.exists(path) and os.path.exists(path + ".npz"):
        path += ".npz"
    with np.load(path, allow_pickle=False) as z:
        if META_KEY not in z.files:
            raise ValueError(f"{path} is not an index artifact (no {META_KEY})")
        meta = json.loads(bytes(z[META_KEY]).decode("utf-8"))
        arrays = {k: z[k] for k in z.files if k != META_KEY}
    return arrays, meta
