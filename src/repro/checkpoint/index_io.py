"""Atomic persistence for frozen index artifacts.

Same durability conventions as :mod:`repro.checkpoint.checkpointer` (write to
``<path>.tmp``, fsync, rename — a torn write never shadows a previous file),
for two artifact shapes:

* single-file ``.npz`` — every array plus a JSON metadata blob under the
  reserved key ``__meta__`` (:func:`save_npz_atomic` / :func:`load_npz`);
* a *segment manifest* directory — per-segment ``.npz`` files that are
  immutable once written, committed by an atomically-renamed ``manifest.json``
  (:func:`save_manifest_atomic` / :func:`load_manifest`). A crash between
  segment writes and the manifest rename leaves the previous manifest (and the
  files it references) fully intact.

Every failure path raises :class:`IndexIOError` (a ``ValueError`` subclass)
naming the file and the problem — a truncated/corrupt ``.npz`` or a missing
array key never surfaces as a bare ``KeyError``/``zipfile`` error. Kept free
of any ``repro.core`` import so the core index can depend on it without a
cycle.
"""
from __future__ import annotations

import json
import os
import zipfile
from typing import Dict, Tuple

import numpy as np

META_KEY = "__meta__"
MANIFEST_NAME = "manifest.json"


class IndexIOError(ValueError):
    """A persisted index artifact is missing, truncated, or malformed."""


def save_npz_atomic(path: str, arrays: Dict[str, np.ndarray], meta: dict) -> str:
    """Atomically write ``arrays`` + ``meta`` to one uncompressed ``.npz``.

    On any failure the ``.tmp`` staging file is removed and an existing good
    file at ``path`` is left untouched (the rename only happens after a
    successful fsync)."""
    if META_KEY in arrays:
        raise ValueError(f"array key {META_KEY!r} is reserved for metadata")
    path = os.fspath(path)
    if not path.endswith(".npz"):
        path += ".npz"
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    tmp = path + ".tmp"
    payload = dict(arrays)
    payload[META_KEY] = np.frombuffer(
        json.dumps(meta, sort_keys=True).encode("utf-8"), dtype=np.uint8)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)  # atomic publish
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path


def load_npz(path: str) -> Tuple[Dict[str, np.ndarray], dict]:
    """Load a :func:`save_npz_atomic` file -> (arrays, meta).

    Raises :class:`IndexIOError` for a missing file, a truncated or corrupt
    archive, undecodable metadata, or an absent ``__meta__`` key."""
    path = os.fspath(path)
    if not os.path.exists(path) and os.path.exists(path + ".npz"):
        path += ".npz"
    if not os.path.exists(path):
        raise IndexIOError(f"{path}: no such index artifact")
    try:
        with np.load(path, allow_pickle=False) as z:
            if META_KEY not in z.files:
                raise IndexIOError(
                    f"{path} is not an index artifact (no {META_KEY})")
            meta = json.loads(bytes(z[META_KEY]).decode("utf-8"))
            # materialize every member inside the context so a truncated
            # archive fails here, wrapped, not lazily at first use
            arrays = {k: z[k] for k in z.files if k != META_KEY}
    except IndexIOError:
        raise
    except (zipfile.BadZipFile, OSError, EOFError, KeyError, ValueError,
            json.JSONDecodeError) as e:
        raise IndexIOError(f"{path}: corrupt or truncated index artifact "
                           f"({type(e).__name__}: {e})") from e
    return arrays, meta


def take(arrays: Dict[str, np.ndarray], key: str, path: str = "<artifact>"
         ) -> np.ndarray:
    """Fetch a required array, raising :class:`IndexIOError` (not KeyError)
    naming the missing key and the file it should have been in."""
    try:
        return arrays[key]
    except KeyError:
        raise IndexIOError(f"{path}: index artifact is missing required "
                           f"array {key!r}") from None


# ---- segment-manifest directories ----

def save_json_atomic(path: str, obj: dict) -> str:
    """Atomically write ``obj`` as JSON (tmp + fsync + rename)."""
    path = os.fspath(path)
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    tmp = path + ".tmp"
    try:
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(obj, f, indent=2, sort_keys=True)
            f.write("\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path


def save_manifest_atomic(root: str, manifest: dict) -> str:
    """Commit a segment-manifest directory: the ``manifest.json`` rename is
    the commit point, so callers must write every referenced ``.npz`` first
    (immutable, content-named files). Returns the manifest path."""
    root = os.fspath(root)
    os.makedirs(root, exist_ok=True)
    return save_json_atomic(os.path.join(root, MANIFEST_NAME), manifest)


def load_manifest(root: str) -> dict:
    """Read a directory's ``manifest.json`` -> dict (IndexIOError on any
    missing/undecodable manifest)."""
    path = os.path.join(os.fspath(root), MANIFEST_NAME)
    if not os.path.exists(path):
        raise IndexIOError(f"{path}: no such manifest")
    try:
        with open(path, "r", encoding="utf-8") as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise IndexIOError(f"{path}: corrupt manifest "
                           f"({type(e).__name__}: {e})") from e


def gc_unreferenced(root: str, referenced: set, subdir: str = "segments"
                    ) -> int:
    """Delete ``root/subdir`` files not named in ``referenced`` (basenames).
    Called after a manifest commit; never touches referenced files."""
    seg_dir = os.path.join(os.fspath(root), subdir)
    if not os.path.isdir(seg_dir):
        return 0
    removed = 0
    for name in os.listdir(seg_dir):
        if name not in referenced and not name.endswith(".tmp"):
            os.unlink(os.path.join(seg_dir, name))
            removed += 1
    return removed
