"""--arch registry: the 10 assigned architectures (+ paper's own serving cfg)."""
import importlib

from .base import (ModelConfig, ShapeConfig, ALL_SHAPES, SHAPES_BY_NAME,
                   TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K, supports_shape)

_ARCH_MODULES = {
    "recurrentgemma-2b": "recurrentgemma_2b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "gemma3-1b": "gemma3_1b",
    "qwen3-32b": "qwen3_32b",
    "qwen1.5-110b": "qwen15_110b",
    "olmo-1b": "olmo_1b",
    "rwkv6-7b": "rwkv6_7b",
}

ARCH_NAMES = tuple(_ARCH_MODULES)


def _mod(name: str):
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_NAMES}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _mod(name).config()


def get_smoke_config(name: str) -> ModelConfig:
    return _mod(name).smoke_config()
