"""Architecture + run-shape configuration (the ``--arch`` registry backbone)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    # attention
    causal: bool = True
    qk_norm: bool = False
    attn_bias: bool = False
    rope_theta: float = 1e4
    rope_theta_global: Optional[float] = None   # gemma3 global layers
    window: Optional[int] = None                # sliding window for local layers
    local_per_global: int = 0                   # N local : 1 global (0 = all global)
    attn_logit_softcap: Optional[float] = None
    q_chunk: int = 512
    kv_chunk: int = 512
    flash_unroll: bool = False   # unrolled flash blocks (roofline measurement)

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    router_fn: str = "softmax"                  # softmax | sigmoid (deepseek)
    router_norm_topk: bool = False
    capacity_factor: float = 1.25

    # MLA (DeepSeek)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mtp: bool = False                           # multi-token-prediction extra block

    # recurrent families
    block_pattern: Tuple[str, ...] = ()         # e.g. ("rg", "rg", "attn")
    lru_width: int = 0
    conv_width: int = 4
    rwkv_lora: int = 64
    rwkv_chunk: int = 16

    # enc-dec / frontends
    n_enc_layers: int = 0
    frontend: Optional[str] = None              # audio_stub | vision_stub
    n_frontend_tokens: int = 0
    frontend_dim: int = 0

    # norm / act / embeddings
    norm_type: str = "rmsnorm"                  # rmsnorm | layernorm_np
    act: str = "silu"
    tie_embeddings: bool = False
    embed_scale: bool = False                   # gemma multiplies by sqrt(d)

    # numerics
    param_dtype: str = "float32"
    activ_dtype: str = "float32"

    # distribution
    remat: bool = True
    scan_layers: bool = True

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def adtype(self):
        return jnp.dtype(self.activ_dtype)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer mixer kind: attn | attn_local | rg | rwkv."""
        if self.family == "ssm":
            return ("rwkv",) * self.n_layers
        if self.block_pattern:
            pat = self.block_pattern
            return tuple(pat[i % len(pat)] for i in range(self.n_layers))
        if self.local_per_global:
            # gemma3: N local then 1 global, repeating
            kinds = []
            for i in range(self.n_layers):
                kinds.append("attn" if (i % (self.local_per_global + 1) ==
                                        self.local_per_global) else "attn_local")
            return tuple(kinds)
        return ("attn",) * self.n_layers

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced config of the same family (smoke tests)."""
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode
    needs_subquadratic: bool = False


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode", needs_subquadratic=True)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Cell applicability (DESIGN.md §4)."""
    if shape.needs_subquadratic:
        kinds = cfg.layer_kinds()
        bounded = all(k in ("rg", "rwkv", "attn_local") for k in kinds)
        mostly_local = cfg.local_per_global > 0 or bounded
        if not (bounded or mostly_local):
            return False, ("pure full-attention arch: 500k-token decode cache "
                           "is unbounded; skipped per brief")
    return True, ""
