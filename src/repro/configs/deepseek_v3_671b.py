"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437; hf]. 61L d_model=7168 128H expert d_ff=2048 vocab=129280."""
from .base import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, head_dim=192,
        d_ff=18432, vocab=129280,
        n_experts=256, n_shared_experts=1, top_k=8, moe_d_ff=2048,
        first_dense_layers=3, router_fn="sigmoid", router_norm_topk=True,
        use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        mtp=True, rope_theta=1e4,
        param_dtype="bfloat16", activ_dtype="bfloat16")

def smoke_config() -> ModelConfig:
    return config().scaled(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=48,
        d_ff=128, vocab=256, n_experts=8, top_k=2, moe_d_ff=32,
        first_dense_layers=2, q_lora_rank=32, kv_lora_rank=16,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        capacity_factor=8.0,
        q_chunk=16, kv_chunk=16,
        param_dtype="float32", activ_dtype="float32")
