"""gemma3-1b [dense] — 5:1 local:global, 128k context
[hf:google/gemma-3-1b-pt; unverified]. 26L d_model=1152 4H (GQA kv=1)
d_ff=6912 vocab=262144, qk-norm, sliding window 512 on local layers."""
from .base import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b", family="dense",
        n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
        d_ff=6912, vocab=262144,
        local_per_global=5, window=512, qk_norm=True,
        rope_theta=1e4, rope_theta_global=1e6, act="gelu",
        embed_scale=True, tie_embeddings=True,
        param_dtype="bfloat16", activ_dtype="bfloat16")

def smoke_config() -> ModelConfig:
    return config().scaled(
        n_layers=6, d_model=64, n_heads=2, n_kv_heads=1, head_dim=32,
        d_ff=128, vocab=256, window=16, local_per_global=2,
        q_chunk=16, kv_chunk=16,
        param_dtype="float32", activ_dtype="float32")
