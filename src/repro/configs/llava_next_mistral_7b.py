"""llava-next-mistral-7b [vlm] — anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]. Mistral-7B backbone:
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000. The vision frontend is
a stub: input_specs() supplies precomputed patch embeddings (576 base tokens)."""
from .base import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b", family="vlm",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=32000,
        frontend="vision_stub", frontend_dim=1024, n_frontend_tokens=576,
        rope_theta=1e6,
        param_dtype="bfloat16", activ_dtype="bfloat16")

def smoke_config() -> ModelConfig:
    return config().scaled(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, frontend_dim=32, n_frontend_tokens=8,
        q_chunk=16, kv_chunk=16,
        param_dtype="float32", activ_dtype="float32")
