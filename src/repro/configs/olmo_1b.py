"""olmo-1b [dense] — non-parametric LN [arXiv:2402.00838; hf].
16L d_model=2048 16H (MHA kv=16) d_ff=8192 vocab=50304."""
from .base import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b", family="dense",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=8192, vocab=50304,
        norm_type="layernorm_np", tie_embeddings=True, rope_theta=1e4,
        param_dtype="bfloat16", activ_dtype="bfloat16")

def smoke_config() -> ModelConfig:
    return config().scaled(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256,
        q_chunk=16, kv_chunk=16,
        param_dtype="float32", activ_dtype="float32")
