"""qwen1.5-110b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B; hf].
80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064."""
from .base import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b", family="dense",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=49152, vocab=152064,
        attn_bias=True, rope_theta=1e6,
        param_dtype="bfloat16", activ_dtype="bfloat16")

def smoke_config() -> ModelConfig:
    return config().scaled(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256,
        q_chunk=16, kv_chunk=16,
        param_dtype="float32", activ_dtype="float32")
