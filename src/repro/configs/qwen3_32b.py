"""qwen3-32b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf].
64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936."""
from .base import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=25600, vocab=151936,
        qk_norm=True, rope_theta=1e6,
        param_dtype="bfloat16", activ_dtype="bfloat16")

def smoke_config() -> ModelConfig:
    return config().scaled(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256,
        q_chunk=16, kv_chunk=16,
        param_dtype="float32", activ_dtype="float32")
