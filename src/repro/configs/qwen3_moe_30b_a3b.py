"""qwen3-moe-30b-a3b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].
48L d_model=2048 32H (GQA kv=4) expert d_ff=768 vocab=151936."""
from .base import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
        d_ff=768, vocab=151936,
        n_experts=128, top_k=8, moe_d_ff=768, router_norm_topk=True,
        qk_norm=True, rope_theta=1e6,
        param_dtype="bfloat16", activ_dtype="bfloat16")

def smoke_config() -> ModelConfig:
    return config().scaled(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab=256, n_experts=8, top_k=2, moe_d_ff=64,
        capacity_factor=8.0,
        q_chunk=16, kv_chunk=16,
        param_dtype="float32", activ_dtype="float32")
