"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1 attn : 2 recurrent
[arXiv:2402.19427; hf]. 26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000."""
from .base import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
        d_ff=7680, vocab=256000,
        block_pattern=("rg", "rg", "attn_local"), lru_width=2560, conv_width=4,
        window=2048, rope_theta=1e4, act="gelu",
        embed_scale=True, tie_embeddings=True,
        param_dtype="bfloat16", activ_dtype="bfloat16")

def smoke_config() -> ModelConfig:
    return config().scaled(
        n_layers=5, d_model=64, n_heads=2, n_kv_heads=1, head_dim=32,
        d_ff=128, vocab=256, lru_width=64, window=16,
        q_chunk=16, kv_chunk=16,
        param_dtype="float32", activ_dtype="float32")
