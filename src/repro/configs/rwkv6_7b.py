"""rwkv6-7b [ssm] — Finch, data-dependent decay, attention-free
[arXiv:2404.05892; hf]. 32L d_model=4096 d_ff=14336 vocab=65536; head size 64."""
from .base import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b", family="ssm",
        n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, head_dim=64,
        d_ff=14336, vocab=65536,
        rwkv_lora=64, rwkv_chunk=16,
        param_dtype="bfloat16", activ_dtype="bfloat16")

def smoke_config() -> ModelConfig:
    return config().scaled(
        n_layers=3, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab=256, rwkv_lora=16,
        q_chunk=16, kv_chunk=16,
        param_dtype="float32", activ_dtype="float32")
