"""seamless-m4t-large-v2 [audio] — enc-dec, multimodal [arXiv:2308.11596; hf].
24L(+24 enc) d_model=1024 16H (kv=16) d_ff=8192 vocab=256206. The audio
frontend is a stub: input_specs() supplies precomputed frame embeddings."""
from .base import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="audio",
        n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        head_dim=64, d_ff=8192, vocab=256206,
        frontend="audio_stub", frontend_dim=1024, act="gelu",
        rope_theta=1e4,
        param_dtype="bfloat16", activ_dtype="bfloat16")

def smoke_config() -> ModelConfig:
    return config().scaled(
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=256, frontend_dim=32,
        q_chunk=16, kv_chunk=16,
        param_dtype="float32", activ_dtype="float32")
