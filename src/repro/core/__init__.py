"""MSTG core — the paper's contribution (RRANN index + search engines).

Public surface (the declarative API is the supported entry point):

* predicate algebra  — :mod:`repro.core.predicates` (``Overlaps() | Before()``)
* typed requests     — :class:`SearchRequest` -> :class:`SearchResult` with
  :class:`RouteReport` diagnostics (:mod:`repro.core.api`)
* index lifecycle    — :class:`IndexSpec`, ``MSTGIndex.build/save/load``
* execution          — :class:`QueryEngine` configured by one typed
  :class:`EngineConfig` (auto-routed graph / pruned / flat); sharded
  multi-device execution lives in :mod:`repro.distributed`
  (``ShardedDeployment``), reported per shard via :class:`ShardReport`

The tuple-era ``MSTGSearcher``/``FlatSearcher`` shims and the positional
``QueryEngine.search(queries, qlo, qhi, mask)`` form were removed in PR 6;
raw int masks remain accepted anywhere a Predicate is.
"""
from . import build, intervals, segment_tree
from .intervals import (LEFT_OVERLAP, QUERY_CONTAINED, RIGHT_OVERLAP,
                        QUERY_CONTAINING, BEFORE, AFTER, ANY_OVERLAP,
                        RFANN_MASK, IFANN_MASK, TSANN_MASK,
                        AttributeDomain, SearchTask, PlanSlot, plan_searches,
                        plan_batch_ranked, eval_predicate, mask_name,
                        parse_mask, SelectivityIndex)
from .predicates import (Predicate, LeftOverlap, RightOverlap, QueryContained,
                         QueryContaining, Contains, ContainedBy, Overlaps,
                         Before, After, as_predicate, as_mask)
from .api import (IndexSpec, QueryHit, Rejected, RouteReport, SearchRequest,
                  SearchResult, SegmentReport, Served, ShardReport)
from .mstg import MSTGIndex, FrozenVariant, build_variant
from .quant import STORAGE_DTYPES, QuantizedStore, maybe_quantize
from .compressed import compressed_flat_topr, exact_rerank
from .search import (WavefrontStream, mstg_graph_search,
                     mstg_graph_search_chunked, merge_topk)
from .flat import flat_search
from .engine import EngineConfig, QueryEngine

__all__ = [
    # predicate algebra
    "Predicate", "LeftOverlap", "RightOverlap", "QueryContained",
    "QueryContaining", "Contains", "ContainedBy", "Overlaps", "Before",
    "After", "as_predicate", "as_mask",
    # typed request/result surface
    "SearchRequest", "SearchResult", "QueryHit", "RouteReport",
    "SegmentReport", "ShardReport", "IndexSpec", "Rejected", "Served",
    # index + engines
    "MSTGIndex", "QueryEngine", "EngineConfig", "FrozenVariant",
    "build_variant", "AttributeDomain", "mstg_graph_search",
    "mstg_graph_search_chunked", "WavefrontStream", "merge_topk",
    "flat_search",
    # quantized storage tier
    "STORAGE_DTYPES", "QuantizedStore", "maybe_quantize",
    "compressed_flat_topr", "exact_rerank",
    # planner internals
    "SearchTask", "PlanSlot", "plan_searches", "plan_batch_ranked",
    "eval_predicate", "mask_name", "parse_mask", "SelectivityIndex",
    # legacy bitmask constants
    "LEFT_OVERLAP", "QUERY_CONTAINED", "RIGHT_OVERLAP", "QUERY_CONTAINING",
    "BEFORE", "AFTER", "ANY_OVERLAP", "RFANN_MASK", "IFANN_MASK", "TSANN_MASK",
    # submodules
    "build", "intervals", "segment_tree",
]
