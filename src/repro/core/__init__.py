"""MSTG core — the paper's contribution (RRANN index + search engines)."""
from . import intervals, segment_tree
from .intervals import (LEFT_OVERLAP, QUERY_CONTAINED, RIGHT_OVERLAP,
                        QUERY_CONTAINING, BEFORE, AFTER, ANY_OVERLAP,
                        RFANN_MASK, IFANN_MASK, TSANN_MASK,
                        AttributeDomain, SearchTask, PlanSlot, plan_searches,
                        plan_batch_ranked, eval_predicate)
from .mstg import MSTGIndex, FrozenVariant, build_variant
from .search import mstg_graph_search, merge_topk
from .flat import flat_search
from .engine import QueryEngine, MSTGSearcher, FlatSearcher
