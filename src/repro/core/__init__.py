"""MSTG core — the paper's contribution (RRANN index + search engines)."""
from . import intervals, segment_tree
from .intervals import (LEFT_OVERLAP, QUERY_CONTAINED, RIGHT_OVERLAP,
                        QUERY_CONTAINING, BEFORE, AFTER, ANY_OVERLAP,
                        RFANN_MASK, IFANN_MASK, TSANN_MASK,
                        AttributeDomain, SearchTask, plan_searches,
                        eval_predicate)
from .mstg import MSTGIndex, FrozenVariant, build_variant
from .search import MSTGSearcher, mstg_graph_search, merge_topk
from .flat import FlatSearcher, flat_search
