"""Typed request/result surface for RRANN search (the declarative API layer).

``SearchRequest`` bundles everything one filtered top-k batch needs — query
vectors, query ranges, a :class:`repro.core.predicates.Predicate` — and
normalizes shapes/dtypes once at the boundary so engines never re-validate.
``SearchResult`` replaces the bare ``(ids, dists)`` tuple: it knows which
slots are real hits (``valid_mask``), iterates per query as
:class:`QueryHit` records, computes recall against a reference, and carries a
:class:`RouteReport` describing what the engine actually did (chosen route,
estimated selectivity, plan slots, selectivity-cache traffic).

``IndexSpec`` is the build-time counterpart: a frozen config a process can
hand to :meth:`repro.core.mstg.MSTGIndex.build` and that travels inside the
saved ``.npz`` so a loaded index knows how it was made.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, NamedTuple, Optional, Tuple, Union

import numpy as np

from .predicates import Predicate, as_predicate


class QueryHit(NamedTuple):
    """One query's top-k: ids padded with ``NO_EDGE`` (< 0), dists with +inf.

    A NamedTuple, so it unpacks as the legacy ``(ids, dists)`` pair; use
    ``n_valid`` for the real-hit count (``len()`` keeps tuple semantics)."""

    ids: np.ndarray
    dists: np.ndarray

    @property
    def valid(self) -> np.ndarray:
        return self.ids >= 0

    @property
    def n_valid(self) -> int:
        return int((self.ids >= 0).sum())


@dataclasses.dataclass(frozen=True)
class Rejected:
    """A typed shed outcome: the serving layer declined an operation instead
    of raising (admission control is flow control, not an error).

    reason : why the op was shed — ``"queue_full"`` (bounded admission queue
             at capacity), ``"deadline_expired"`` (the request's
             ``deadline_ms`` passed before dispatch), ``"shutdown"`` (the
             server is draining), or ``"not_mutable"`` (a mutation submitted
             against a frozen index).
    op     : operation kind (``"query"`` | ``"upsert"`` | ``"delete"``).
    queue_depth : admission-queue depth observed at the shed decision.
    """

    reason: str
    op: str = "query"
    queue_depth: int = 0

    def __bool__(self) -> bool:          # `if outcome:` reads as "served?"
        return False


@dataclasses.dataclass(frozen=True)
class Served:
    """A completed serving outcome: the answer plus its latency breakdown.

    hit       : the :class:`QueryHit` (None for completed mutations).
    queue_ms  : submission -> dispatch wait (admission-queue time).
    e2e_ms    : submission -> completion, end to end.
    degraded  : sharded execution lost one or more shards for this answer
                (see :attr:`SearchResult.degraded`).
    deadline_missed : the request carried a ``deadline_ms`` and completed
                past it (served anyway — the scheduler only *sheds* requests
                whose deadline expires before dispatch).
    """

    hit: Optional[QueryHit]
    queue_ms: float = 0.0
    e2e_ms: float = 0.0
    degraded: bool = False
    deadline_missed: bool = False

    def __bool__(self) -> bool:
        return True


@dataclasses.dataclass(frozen=True, eq=False)
class SearchRequest:
    """A filtered top-k batch: vectors + query ranges + a predicate.

    ``ranges`` accepts either a ``(Q, 2)`` array (or nested list) of
    ``[qlo, qhi]`` rows, or a 2-**tuple** ``(qlo, qhi)`` of ``(Q,)`` arrays —
    the pair form must be a tuple so a two-row list of ranges is never
    misread as a pair. ``predicate`` accepts a :class:`Predicate`, a raw int
    mask, or a parseable string. Everything is normalized (float32 vectors,
    float64 ranges) at construction.

    ``fanout`` (frontier vertices expanded per wavefront step) and ``chunk``
    (steps per compaction slice of the chunked graph driver) default to
    ``None`` — *the engine picks*; pass an explicit int to pin either.
    ``chunk=0`` pins the single-``lax.while_loop`` driver (``fanout=1,
    chunk=0`` reproduces the seed's one-expansion single-loop behavior bit
    for bit).

    ``deadline_ms`` and ``priority`` are serving-level SLO metadata: the
    engine itself never reads them (an expired request still executes if
    handed to :meth:`repro.core.QueryEngine.execute` directly), but the
    async serving scheduler (:mod:`repro.serving.scheduler`) uses them for
    earliest-deadline-first micro-batch ordering and shed-on-overload
    decisions. ``deadline_ms`` is relative to submission; ``priority``
    breaks ties (higher first).

    ``trace=True`` records a :class:`repro.obs.Trace` of this one request —
    plan, route decision, per-slot/per-shard execution, merge — returned on
    :attr:`SearchResult.trace` (``result.explain()`` renders it;
    ``result.trace.save(path)`` writes Chrome-trace JSON). The default is
    the no-op fast path; see also ``EngineConfig.trace_sample`` for
    engine-level sampling.
    """

    vectors: np.ndarray
    ranges: np.ndarray
    predicate: Predicate
    k: int = 10
    ef: int = 64
    route: Optional[str] = None
    max_steps: Optional[int] = None
    fanout: Optional[int] = None
    chunk: Optional[int] = None
    deadline_ms: Optional[float] = None
    priority: int = 0
    trace: bool = False

    def __post_init__(self):
        vecs = np.ascontiguousarray(self.vectors, dtype=np.float32)
        if vecs.ndim != 2:
            raise ValueError(f"vectors must be (Q, d), got shape {vecs.shape}")
        rng = self.ranges
        if isinstance(rng, tuple) and len(rng) == 2:
            rng = np.stack([np.asarray(rng[0], np.float64).ravel(),
                            np.asarray(rng[1], np.float64).ravel()], axis=1)
        else:
            rng = np.asarray(rng, dtype=np.float64)
        if rng.ndim != 2 or rng.shape[1] != 2:
            raise ValueError(f"ranges must be (Q, 2), got shape {rng.shape}")
        if rng.shape[0] != vecs.shape[0]:
            raise ValueError(f"{vecs.shape[0]} vectors but {rng.shape[0]} ranges")
        if np.any(rng[:, 0] > rng[:, 1]):
            raise ValueError("query ranges must satisfy qlo <= qhi")
        if self.k < 1:
            raise ValueError("k must be >= 1")
        if self.ef < 1:
            raise ValueError("ef must be >= 1")
        if self.fanout is not None and self.fanout < 1:
            raise ValueError("fanout must be >= 1 (or None: engine decides)")
        if self.chunk is not None and self.chunk < 0:
            raise ValueError("chunk must be >= 1, 0 (pin the single-loop "
                             "driver), or None (engine decides)")
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise ValueError("deadline_ms must be > 0 (or None: no deadline)")
        object.__setattr__(self, "vectors", vecs)
        object.__setattr__(self, "ranges", rng)
        object.__setattr__(self, "predicate", as_predicate(self.predicate))

    def __len__(self) -> int:
        return self.vectors.shape[0]

    @property
    def qlo(self) -> np.ndarray:
        return self.ranges[:, 0]

    @property
    def qhi(self) -> np.ndarray:
        return self.ranges[:, 1]

    @property
    def mask(self) -> int:
        return self.predicate.mask


@dataclasses.dataclass(frozen=True)
class SegmentReport:
    """How one live segment (or the mutable delta) served its share of a
    fanned-out :class:`repro.streaming.SegmentedIndex` request.

    segment    : segment id (``"seg-000003"``) or ``"delta"``
    n          : rows the segment holds (including tombstoned rows)
    route      : route that segment executed ("graph"|"pruned"|"flat"|"delta")
    k_fetched  : per-segment top-k width (k + live tombstones, clamped to n,
                 so tombstone filtering can never push a true neighbor out)
    tombstones : tombstoned rows in this segment at execution time
    slot_count : Theorem 4.1 plan slots that segment executed
    """

    segment: str
    n: int
    route: str
    k_fetched: int
    tombstones: int = 0
    slot_count: int = 0


@dataclasses.dataclass(frozen=True)
class ShardReport:
    """How one shard of a :class:`repro.distributed.ShardedDeployment` served
    its share of a fanned-out request — the sharded-execution counterpart of
    :class:`SegmentReport`, so :class:`RouteReport` stays uniform across
    local, streaming, and sharded execution.

    shard      : shard index on the deployment's corpus axis
    n          : corpus rows assigned to this shard
    route      : route the shard's local engine executed ("graph" | "pruned"
                 | "flat" | "segmented"), or why it contributed nothing
                 ("lost" = marked down before the request, "error" = its
                 local search raised and was converted to a miss)
    alive      : False when the shard contributed no results (lost/error);
                 such shards also appear in ``RouteReport.missing_shards``
    k_fetched  : per-shard top-k width fanned in to the merge (the
                 deployment's ``per_shard_k``, clamped to the request's k)
    latency_s  : wall-clock seconds of the shard's local search (0.0 when the
                 whole fan-out ran as one fused ``shard_map`` call — the
                 device path has no per-shard host timing)
    slot_count : Theorem 4.1 plan slots the shard's local engine executed
    """

    shard: int
    n: int
    route: str
    alive: bool = True
    k_fetched: int = 0
    latency_s: float = 0.0
    slot_count: int = 0


@dataclasses.dataclass(frozen=True, eq=False)
class RouteReport:
    """What the engine did with one request (diagnostics, not results).

    route            : executed route ("graph" | "pruned" | "flat"); an
                       empty (Q=0) request executes nothing and mirrors the
                       requested value here (possibly "auto"); a streaming
                       :class:`repro.streaming.SegmentedIndex` fan-out reports
                       "segmented" here and per-segment routes in ``segments``;
                       a :class:`repro.distributed.ShardedDeployment` fan-out
                       reports "sharded" here and per-shard routes in
                       ``shards``
    requested        : what the caller asked for (may be "auto")
    est_selectivity  : (Q,) estimated predicate selectivity, when the auto
                       router evaluated it (None for pinned routes)
    slot_count       : number of Theorem 4.1 plan slots executed
    variants         : MSTG variant of each slot, in execution order
    cache_hits/misses: selectivity-cache traffic caused by this request
    segments         : per-segment :class:`SegmentReport` records when the
                       request fanned out over a segmented index (else empty)
    shards           : per-shard :class:`ShardReport` records when the request
                       fanned out over a sharded deployment (else empty)
    missing_shards   : shard indices that contributed nothing (lost or
                       errored); non-empty means the answer is ``degraded``
                       (complete over the surviving shards, possibly missing
                       true neighbors that lived on the lost ones)
    merge            : distributed top-k merge schedule that combined shard
                       results ("all_gather" | "tournament" | "host"; None
                       for non-sharded execution)
    """

    route: str
    requested: str
    est_selectivity: Optional[np.ndarray]
    slot_count: int
    variants: Tuple[str, ...]
    cache_hits: int = 0
    cache_misses: int = 0
    segments: Tuple[SegmentReport, ...] = ()
    shards: Tuple[ShardReport, ...] = ()
    missing_shards: Tuple[int, ...] = ()
    merge: Optional[str] = None

    @property
    def degraded(self) -> bool:
        """True when one or more shards contributed nothing — the results are
        complete over the surviving shards only (degraded recall, not an
        error)."""
        return len(self.missing_shards) > 0

    @property
    def mean_selectivity(self) -> Optional[float]:
        if self.est_selectivity is None or self.est_selectivity.size == 0:
            return None
        return float(np.mean(self.est_selectivity))


@dataclasses.dataclass(frozen=True, eq=False)
class SearchResult:
    """Filtered top-k results: ``(Q, k)`` ids (< 0 = empty slot) and squared
    distances (+inf = empty slot), plus the engine's :class:`RouteReport`.
    ``trace`` carries the request's :class:`repro.obs.Trace` when it ran
    with ``SearchRequest(trace=True)`` (or was sampled by the engine) —
    render with :meth:`explain`, export with ``result.trace.save(path)``."""

    ids: np.ndarray
    dists: np.ndarray
    report: Optional[RouteReport] = None
    trace: Optional[object] = None

    def __post_init__(self):
        ids = np.asarray(self.ids)
        dists = np.asarray(self.dists)
        if ids.shape != dists.shape or ids.ndim != 2:
            raise ValueError(f"ids {ids.shape} and dists {dists.shape} must be "
                             "equal (Q, k) shapes")
        object.__setattr__(self, "ids", ids)
        object.__setattr__(self, "dists", dists)

    # ---- shape / iteration ----
    def __len__(self) -> int:
        return self.ids.shape[0]

    @property
    def k(self) -> int:
        return self.ids.shape[1]

    def __iter__(self) -> Iterator[QueryHit]:
        for qi in range(self.ids.shape[0]):
            yield QueryHit(self.ids[qi], self.dists[qi])

    def __getitem__(self, qi) -> Union[QueryHit, "SearchResult"]:
        if isinstance(qi, (int, np.integer)):
            return QueryHit(self.ids[qi], self.dists[qi])
        return SearchResult(self.ids[qi], self.dists[qi], self.report)

    # ---- invariants / interop ----
    @property
    def valid_mask(self) -> np.ndarray:
        """(Q, k) bool: which result slots hold a real neighbor."""
        return self.ids >= 0

    @property
    def degraded(self) -> bool:
        """True when sharded execution lost one or more shards — the answer
        is complete over the surviving shards only (see
        ``report.missing_shards``). Always False for non-sharded execution."""
        return self.report is not None and self.report.degraded

    def astuple(self) -> Tuple[np.ndarray, np.ndarray]:
        """The legacy ``(ids, dists)`` pair (for tuple-era call sites)."""
        return self.ids, self.dists

    def explain(self) -> str:
        """One-query execution report: the :class:`RouteReport` breakdown
        (route decision, selectivity estimate, plan slots, per-shard /
        per-segment rows, merge schedule, degraded status) followed by the
        span tree when the request ran with ``trace=True``. Returns the
        rendered text (also handy under ``print``)."""
        lines = [f"SearchResult: {self.ids.shape[0]} queries x k={self.k}"]
        r = self.report
        if r is None:
            lines.append("  (no route report attached)")
        else:
            routed = r.route if r.route == r.requested \
                else f"{r.route} (requested {r.requested})"
            lines.append(f"  route: {routed}")
            sel = r.mean_selectivity
            if sel is not None:
                lines.append(f"  est_selectivity: mean={sel:.4f}")
            if r.slot_count or r.variants:
                lines.append(f"  plan: {r.slot_count} slots over "
                             f"variants={list(r.variants)}")
            if r.cache_hits or r.cache_misses:
                lines.append(f"  selectivity cache: {r.cache_hits} hits / "
                             f"{r.cache_misses} misses")
            for s in r.shards:
                status = "" if s.alive else "  [DEGRADED]"
                lines.append(
                    f"  shard[{s.shard}]: route={s.route} n={s.n} "
                    f"k_fetched={s.k_fetched} "
                    f"latency={s.latency_s * 1e3:.2f}ms{status}")
            if r.missing_shards:
                lines.append("  missing shards: "
                             f"{list(r.missing_shards)} (degraded)")
            for g in r.segments:
                lines.append(f"  segment[{g.segment}]: route={g.route} "
                             f"n={g.n} k_fetched={g.k_fetched} "
                             f"tombstones={g.tombstones}")
            if r.merge:
                lines.append(f"  merge: {r.merge}")
        if self.trace is not None:
            lines.append("  trace:")
            lines.extend("    " + ln
                         for ln in self.trace.render().splitlines())
        else:
            lines.append("  trace: (none — pass SearchRequest(trace=True))")
        return "\n".join(lines)

    def recall_vs(self, reference) -> float:
        """Recall@k against ``reference`` — a :class:`SearchResult` or a
        ``(Q, k')`` id array (e.g. brute-force ground truth): |found ∩ true|
        / |true| over queries with non-empty truth (the
        :func:`repro.data.recall_at_k` metric, to which this delegates)."""
        # deferred: repro.data imports repro.core at module import time
        from repro.data.datasets import recall_at_k
        true_ids = reference.ids if isinstance(reference, SearchResult) \
            else np.asarray(reference)
        if true_ids.shape[0] != self.ids.shape[0]:
            raise ValueError("reference has a different number of queries")
        return recall_at_k(self.ids, true_ids)


@dataclasses.dataclass(frozen=True)
class IndexSpec:
    """Build configuration for :class:`repro.core.mstg.MSTGIndex`.

    ``predicate`` decides which MSTG variants get built when ``variants`` is
    None (via ``Predicate.variants_required``); the graph hyper-parameters
    mirror the paper's (M, efConstruction, entry count). ``builder`` picks
    the construction path — ``"bulk"`` (batched, the default) or
    ``"incremental"`` (the paper-exact reference oracle) — and
    ``batch_size`` tunes the bulk path's batch width (None = its default).
    ``storage_dtype`` selects the vector storage tier ("float32" exact,
    "float16"/"int8" scalar-quantized codes + exact re-rank at query time
    — :mod:`repro.core.quant`); because it lives on the spec it travels
    through persistence *and* through streaming flush/compact, so
    segments quantize in the background automatically.

    ``candidate_stage`` picks the bulk builder's candidate generator:
    ``"exact"`` (all-pairs matmul per batch, O(n^2) total) or ``"coarse"``
    (IVF-style k-means quantizer — candidates from the ``n_probe`` nearest
    of ``n_clusters`` centroids' buckets, sub-quadratic; see
    :mod:`repro.core.build`). ``n_clusters=None`` sizes the quantizer
    automatically (~``16*sqrt(n)``); ``coarse_threshold`` is the inserted-
    prefix size below which batches keep the exact path bit-identically
    (None = the builder default, 4096). Like ``storage_dtype``, these ride
    the spec through persistence and streaming flush/compact.
    The spec is stored on the index and persisted by ``save()``; artifacts
    written before the ``builder`` / ``storage_dtype`` /
    ``candidate_stage`` fields existed load as ``"bulk"`` / ``"float32"``
    / ``"exact"``.
    """

    predicate: Predicate = None
    variants: Optional[Tuple[str, ...]] = None
    m: int = 16
    ef_con: int = 100
    m_max: Optional[int] = None
    n_entries: int = 4
    builder: str = "bulk"
    batch_size: Optional[int] = None
    storage_dtype: str = "float32"
    candidate_stage: str = "exact"
    n_clusters: Optional[int] = None
    n_probe: int = 8
    coarse_threshold: Optional[int] = None

    def __post_init__(self):
        from . import intervals as iv
        pred = self.predicate if self.predicate is not None else iv.ANY_OVERLAP
        object.__setattr__(self, "predicate", as_predicate(pred))
        if self.variants is not None:
            object.__setattr__(self, "variants", tuple(self.variants))
        from .build import BUILDERS, CANDIDATE_STAGES  # deferred: import-light
        if self.builder not in BUILDERS:
            raise ValueError(f"unknown builder {self.builder!r}; expected "
                             f"one of {BUILDERS}")
        if self.batch_size is not None and self.batch_size < 1:
            raise ValueError("batch_size must be >= 1 (or None for the "
                             "builder default)")
        if self.candidate_stage not in CANDIDATE_STAGES:
            raise ValueError(f"unknown candidate_stage "
                             f"{self.candidate_stage!r}; expected one of "
                             f"{CANDIDATE_STAGES}")
        if self.n_clusters is not None and self.n_clusters < 1:
            raise ValueError("n_clusters must be >= 1 (or None for the "
                             "automatic size)")
        if self.n_probe < 1:
            raise ValueError("n_probe must be >= 1")
        if self.coarse_threshold is not None and self.coarse_threshold < 1:
            raise ValueError("coarse_threshold must be >= 1 (or None for "
                             "the builder default)")
        from .quant import check_storage_dtype  # deferred, like BUILDERS
        object.__setattr__(self, "storage_dtype",
                           check_storage_dtype(self.storage_dtype))

    def to_dict(self) -> dict:
        return {"predicate": self.predicate.mask,
                "variants": list(self.variants) if self.variants else None,
                "m": self.m, "ef_con": self.ef_con, "m_max": self.m_max,
                "n_entries": self.n_entries, "builder": self.builder,
                "batch_size": self.batch_size,
                "storage_dtype": self.storage_dtype,
                "candidate_stage": self.candidate_stage,
                "n_clusters": self.n_clusters, "n_probe": self.n_probe,
                "coarse_threshold": self.coarse_threshold}

    @classmethod
    def from_dict(cls, d: dict) -> "IndexSpec":
        variants = d.get("variants")
        return cls(predicate=Predicate(d["predicate"]),
                   variants=tuple(variants) if variants else None,
                   m=d["m"], ef_con=d["ef_con"], m_max=d["m_max"],
                   n_entries=d["n_entries"],
                   builder=d.get("builder", "bulk"),
                   batch_size=d.get("batch_size"),
                   storage_dtype=d.get("storage_dtype", "float32"),
                   candidate_stage=d.get("candidate_stage", "exact"),
                   n_clusters=d.get("n_clusters"),
                   n_probe=d.get("n_probe", 8),
                   coarse_threshold=d.get("coarse_threshold"))
