"""The paper's comparison methods (§3, §5), at laptop scale.

Every baseline exposes ``search(queries, qlo, qhi, mask, k, **knobs) ->
(ids, dists)`` over the same (vectors, lo, hi) corpus so the benchmark harness
treats them uniformly. Distance counts (``last_dist_evals``) approximate the
paper's cost model: "each vector verification requires an expensive distance
computation".

* Prefiltering   — predicate scan then exact distances on qualifiers.
* Postfiltering  — plain HNSW k'-ANN then predicate filter; Milvus-style
                   progressive doubling of k' until k qualifiers survive.
* ACORN-like     — predicate-agnostic graph with enlarged degree (gamma),
                   filtered traversal at query time (ACORN-1 flavor).
* iRangeGraph    — segment tree on a point attribute with a PG per node; our
                   MSTG machinery with a degenerate (single-version) variant.
                   RFANN only.
* TSGraphLike    — per-timestamp-bucket HNSWs + exact recheck (TSANN).
* HiPNGLike      — quadtree over (l, r) 2D points with a PG per quad node;
                   in-rect nodes searched directly, boundary nodes post-
                   filtered (IFANN).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import intervals as iv
from .hnsw import PlainHNSW, l2sq


def _pad(ids: List[int], ds: List[float], k: int):
    out_i = np.full(k, -1, np.int64)
    out_d = np.full(k, np.inf)
    m = min(len(ids), k)
    out_i[:m] = ids[:m]
    out_d[:m] = ds[:m]
    return out_i, out_d


class BaseIndex:
    name = "base"

    def __init__(self, vectors: np.ndarray, lo: np.ndarray, hi: np.ndarray):
        self.vectors = np.ascontiguousarray(vectors, np.float32)
        self.lo = np.asarray(lo, np.float64)
        self.hi = np.asarray(hi, np.float64)
        self.last_dist_evals = 0

    def search(self, queries, qlo, qhi, mask: int, k: int = 10, **kw):
        raise NotImplementedError

    def index_bytes(self) -> int:
        return 0

    def storage_bytes(self) -> dict:
        """Per-tier byte accounting, same schema as
        :meth:`repro.core.mstg.MSTGIndex.storage_bytes`. Baselines store only
        the exact float32 corpus (no compressed tier), so the scan stream is
        the full corpus and the ratio is 1."""
        full = int(self.vectors.nbytes)
        return {"storage_dtype": "float32", "float32_rerank": full,
                "graph": self.index_bytes(), "codes": 0, "scales": 0,
                "sq_norm": 0, "scan_bytes": full, "compression_ratio": 1.0}


class Prefiltering(BaseIndex):
    name = "prefilter"

    def search(self, queries, qlo, qhi, mask: int, k: int = 10, **kw):
        Q = queries.shape[0]
        ids = np.full((Q, k), -1, np.int64)
        ds = np.full((Q, k), np.inf)
        self.last_dist_evals = 0
        for qi in range(Q):
            sel = np.nonzero(np.asarray(iv.eval_predicate(
                mask, self.lo, self.hi, qlo[qi], qhi[qi])))[0]
            if sel.size == 0:
                continue
            self.last_dist_evals += sel.size
            d = l2sq(self.vectors[sel], queries[qi])
            o = np.argsort(d, kind="stable")[:k]
            ids[qi, :o.size] = sel[o]
            ds[qi, :o.size] = d[o]
        return ids, ds


class Postfiltering(BaseIndex):
    """HNSW + progressive k' doubling (Milvus strategy, paper Appendix C)."""
    name = "postfilter"

    def __init__(self, vectors, lo, hi, m: int = 16, ef_con: int = 100):
        super().__init__(vectors, lo, hi)
        self.h = PlainHNSW(self.vectors, m=m, ef_con=ef_con).build(
            range(len(vectors)))

    def index_bytes(self) -> int:
        return sum(len(v) for v in self.h.g.open_adj.values()) * 8

    def search(self, queries, qlo, qhi, mask: int, k: int = 10,
               ef: int = 64, max_kprime: int = 1024, **kw):
        Q = queries.shape[0]
        out_i = np.full((Q, k), -1, np.int64)
        out_d = np.full((Q, k), np.inf)
        self.last_dist_evals = 0
        for qi in range(Q):
            kp = k
            while True:
                coll: List[int] = []
                cand, cd = self.h.search(queries[qi], k=kp,
                                         ef=max(ef, kp), collect=coll)
                self.last_dist_evals += int(np.sum(coll))
                sel = np.asarray(iv.eval_predicate(
                    mask, self.lo[cand], self.hi[cand], qlo[qi], qhi[qi]))
                good = np.nonzero(sel)[0]
                if good.size >= k or kp >= max_kprime:
                    out_i[qi], out_d[qi] = _pad(
                        [int(cand[g]) for g in good],
                        [float(cd[g]) for g in good], k)
                    break
                kp *= 2
        return out_i, out_d


class AcornLike(BaseIndex):
    """Predicate-agnostic index, filtered traversal (ACORN-1 / VBASE style).
    ``gamma`` widens construction degree like ACORN-gamma's neighbor
    expansion."""
    name = "acorn"

    def __init__(self, vectors, lo, hi, m: int = 16, ef_con: int = 100,
                 gamma: int = 2):
        super().__init__(vectors, lo, hi)
        self.h = PlainHNSW(self.vectors, m=m * gamma, ef_con=ef_con,
                           m_max=2 * m * gamma).build(range(len(vectors)))

    def index_bytes(self) -> int:
        return sum(len(v) for v in self.h.g.open_adj.values()) * 8

    def search(self, queries, qlo, qhi, mask: int, k: int = 10, ef: int = 64, **kw):
        Q = queries.shape[0]
        out_i = np.full((Q, k), -1, np.int64)
        out_d = np.full((Q, k), np.inf)
        self.last_dist_evals = 0
        for qi in range(Q):
            coll: List[int] = []
            pred = lambda u: bool(iv.eval_predicate(
                mask, self.lo[u], self.hi[u], qlo[qi], qhi[qi]))
            ids, ds = self.h.search(queries[qi], k=k, ef=ef,
                                    predicate=pred, collect=coll)
            self.last_dist_evals += int(np.sum(coll))
            out_i[qi], out_d[qi] = _pad(list(ids), list(ds), k)
        return out_i, out_d


class IRangeGraphLike(BaseIndex):
    """RFANN baseline: segment tree over a *point* attribute with a PG per
    node (iRangeGraph). Reuses the MSTG builder with a degenerate single
    version (labels trivially [0, OPEN)) — exactly the ancestor structure."""
    name = "irangegraph"

    def __init__(self, vectors, attr, m: int = 16, ef_con: int = 100):
        attr = np.asarray(attr, np.float64)
        super().__init__(vectors, attr, attr)
        from .mstg import MSTGIndex
        # Point objects, single tree keyed on the attribute. Querying at
        # version = top ignores labels entirely: the induced graph is the
        # final live HNSW per node — exactly iRangeGraph's elemental graphs.
        self.idx = MSTGIndex(self.vectors, attr, attr, variants=("T",),
                             m=m, ef_con=ef_con)

    def index_bytes(self) -> int:
        return self.idx.index_bytes()

    def storage_bytes(self) -> dict:
        return self.idx.storage_bytes()

    def search(self, queries, qlo, qhi, mask: int = iv.RFANN_MASK, k: int = 10,
               ef: int = 64, **kw):
        import jax.numpy as jnp
        from .search import DeviceVariant, mstg_graph_search
        if not hasattr(self, "_dev"):
            self._dev = DeviceVariant(self.idx.variants["T"], self.idx.vectors)
        Q = queries.shape[0]
        dom = self.idx.domain
        top = dom.K - 1
        version = np.full(Q, top, np.int64)
        klo = dom.ceil_rank(np.asarray(qlo))
        khi = dom.floor_rank(np.asarray(qhi))
        ids, d = mstg_graph_search(
            self._dev.tree(), jnp.asarray(queries, jnp.float32),
            jnp.asarray(version, jnp.int32), jnp.asarray(klo, jnp.int32),
            jnp.asarray(khi, jnp.int32), k=k, ef=ef, max_steps=4 * ef + 64,
            Kpad=self.idx.variants["T"].Kpad)
        return np.asarray(ids), np.asarray(d)


class TSGraphLike(BaseIndex):
    """TSANN baseline: bucketed timestamps, one HNSW per bucket over the
    objects whose range covers the bucket (TS-Graph's per-timestamp graphs,
    without its compression — honest at laptop scale)."""
    name = "tsgraph"

    def __init__(self, vectors, lo, hi, n_buckets: int = 16, m: int = 12,
                 ef_con: int = 60):
        super().__init__(vectors, lo, hi)
        self.edges = np.linspace(self.lo.min(), self.hi.max(), n_buckets + 1)
        self.buckets: List[Tuple[np.ndarray, PlainHNSW]] = []
        for b in range(n_buckets):
            a, c = self.edges[b], self.edges[b + 1]
            member = np.nonzero((self.lo <= c) & (self.hi >= a))[0]
            h = PlainHNSW(self.vectors, m=m, ef_con=ef_con)
            for u in member:
                h.add(int(u))
            self.buckets.append((member, h))

    def index_bytes(self) -> int:
        return sum(sum(len(v) for v in h.g.open_adj.values()) * 8
                   for _, h in self.buckets)

    def search(self, queries, qlo, qhi, mask: int = iv.TSANN_MASK, k: int = 10,
               ef: int = 64, **kw):
        Q = queries.shape[0]
        out_i = np.full((Q, k), -1, np.int64)
        out_d = np.full((Q, k), np.inf)
        self.last_dist_evals = 0
        nb = len(self.buckets)
        for qi in range(Q):
            t = qlo[qi]
            b = int(np.clip(np.searchsorted(self.edges, t, "right") - 1, 0, nb - 1))
            _, h = self.buckets[b]
            coll: List[int] = []
            pred = lambda u: bool(self.lo[u] <= t <= self.hi[u])
            ids, ds = h.search(queries[qi], k=k, ef=ef, predicate=pred,
                               collect=coll)
            self.last_dist_evals += int(np.sum(coll))
            out_i[qi], out_d[qi] = _pad(list(ids), list(ds), k)
        return out_i, out_d


@dataclasses.dataclass
class _QuadNode:
    x0: float
    x1: float
    y0: float
    y1: float
    members: np.ndarray
    children: Optional[List["_QuadNode"]]
    graph: Optional[PlainHNSW]


class HiPNGLike(BaseIndex):
    """IFANN baseline: quadtree over (l, r) points with a PG per node
    (Hi-PNG). Search: minimal node cover of the query rectangle
    [ql,qh]x[ql,qh]; fully-inside nodes searched directly, boundary nodes
    searched + post-filtered; merged."""
    name = "hipng"

    def __init__(self, vectors, lo, hi, leaf_size: int = 64, m: int = 12,
                 ef_con: int = 60, max_depth: int = 6):
        super().__init__(vectors, lo, hi)
        self.leaf_size = leaf_size
        self.max_depth = max_depth
        self.m, self.ef_con = m, ef_con
        ids = np.arange(len(vectors))
        self.root = self._build(ids, float(self.lo.min()), float(self.hi.max()),
                                float(self.lo.min()), float(self.hi.max()), 0)

    def _build(self, ids, x0, x1, y0, y1, depth) -> _QuadNode:
        g = PlainHNSW(self.vectors, m=self.m, ef_con=self.ef_con)
        for u in ids:
            g.add(int(u))
        node = _QuadNode(x0, x1, y0, y1, ids, None, g)
        if len(ids) > self.leaf_size and depth < self.max_depth:
            xm, ym = (x0 + x1) / 2, (y0 + y1) / 2
            quads = []
            for (a, b, c, d) in ((x0, xm, y0, ym), (xm, x1, y0, ym),
                                 (x0, xm, ym, y1), (xm, x1, ym, y1)):
                sub = ids[(self.lo[ids] >= a) & (self.lo[ids] <= b) &
                          (self.hi[ids] >= c) & (self.hi[ids] <= d)]
                quads.append(self._build(sub, a, b, c, d, depth + 1))
            node.children = quads
        return node

    def index_bytes(self) -> int:
        total = 0
        stack = [self.root]
        while stack:
            n = stack.pop()
            if n.graph:
                total += sum(len(v) for v in n.graph.g.open_adj.values()) * 8
            if n.children:
                stack.extend(n.children)
        return total

    def _cover(self, node, ql, qh, out):
        if node.x1 < ql or node.x0 > qh or node.y1 < ql or node.y0 > qh:
            return
        inside = (node.x0 >= ql and node.x1 <= qh and
                  node.y0 >= ql and node.y1 <= qh)
        if inside or node.children is None:
            out.append((node, inside))
            return
        for c in node.children:
            self._cover(c, ql, qh, out)

    def search(self, queries, qlo, qhi, mask: int = iv.IFANN_MASK, k: int = 10,
               ef: int = 64, **kw):
        Q = queries.shape[0]
        out_i = np.full((Q, k), -1, np.int64)
        out_d = np.full((Q, k), np.inf)
        self.last_dist_evals = 0
        for qi in range(Q):
            nodes: List[Tuple[_QuadNode, bool]] = []
            self._cover(self.root, qlo[qi], qhi[qi], nodes)
            pool: Dict[int, float] = {}
            for node, inside in nodes:
                if node.members.size == 0:
                    continue
                coll: List[int] = []
                ids, ds = node.graph.search(queries[qi], k=k, ef=ef,
                                            collect=coll)
                self.last_dist_evals += int(np.sum(coll))
                for u, d in zip(ids, ds):
                    u = int(u)
                    if not inside and not (qlo[qi] <= self.lo[u] and
                                           self.hi[u] <= qhi[qi]):
                        continue
                    pool[u] = float(d)
            top = sorted(pool.items(), key=lambda t: t[1])[:k]
            out_i[qi], out_d[qi] = _pad([u for u, _ in top], [d for _, d in top], k)
        return out_i, out_d
