"""Bulk MSTG construction — batched Algorithms 1–3 (the default build path).

The incremental builder (:mod:`repro.core.hnsw`) inserts one object at a
time: every insertion runs a Python ``heapq`` beam search over the live
graph per touched tree node, which costs ~ms per object and makes
construction ~3 orders of magnitude slower than the query side. The bulk
builder exploits the one structural fact the incremental path ignores: the
graph is never *searched* during construction if candidates can be produced
another way. So it

1. processes objects in sorted (version) order in fixed-size batches,
2. generates candidates with ONE batched distance matmul per batch — each
   batch object's distances to every earlier-inserted object are computed
   once and *shared across all* ``Lv`` *levels* of its root→leaf tree path
   (per level, candidates are just the nearest earlier members of the same
   tree node: a boolean mask over the shared distance rows),
3. applies the RNG "select neighbors" rule to all (object, level) rows at
   once (:func:`rng_prune_batch` — m rounds of (R, C) vector ops instead of
   R sequential Python scans), and
4. defers reverse-edge re-pruning: vertices far over quota are re-pruned
   at their own batch boundary (bounding hub degrees and the frozen slot
   axis), everything else in one shared sweep every ``REPRUNE_EVERY``
   batches — collapsing the per-batch prune/regrow churn.

Fidelity: candidate sets are *exact* nearest earlier same-node members
(the incremental beam search only approximates this), the pruning rule is
identical, and member / entry-point / version bookkeeping is bit-identical
to the incremental builder. Edge validity labels are a **superset** of the
incremental ones: an edge pruned at a boundary or sweep closes at that
batch's last version instead of the exact insertion version, so every
query version sees at least the edges the incremental graph would expose
(never fewer — recall is preserved; Theorem D.1 *exactness* is what the
``builder="incremental"`` oracle is kept for). The frozen array schema is
unchanged: both builders fill the same :class:`LabeledLevelGraph` adjacency
structures and go through the same freeze.

On accelerator backends the batched distance matmuls map onto the
:mod:`repro.kernels.ops` pairwise kernels; on CPU (this container) NumPy's
BLAS matmul is the fast path, so that is what runs here.
"""
from __future__ import annotations

import math
import time
from typing import Dict, List, Optional

import numpy as np

from repro.obs.log import get_logger

from .hnsw import NO_EDGE, OPEN

logger = get_logger(__name__)

# "scan" builds only the segment-tree member structure (flat/pruned routes,
# no graphs — see repro.core.mstg.build_scan_variant); the other two build
# the full labeled level graphs.
BUILDERS = ("bulk", "incremental", "scan")
DEFAULT_BATCH = 128

# Candidate generation for the bulk builder. "exact" is the PR-5 all-pairs
# matmul (per batch object, the true nearest earlier same-node members);
# "coarse" swaps in an IVF-style coarse quantizer once the inserted prefix
# passes ``coarse_threshold``: one k-means assignment matmul per batch, with
# candidates drawn from the object's ``n_probe`` nearest centroids' members
# plus the recent (not yet consolidated) insertion block. Batches whose
# prefix is still below the threshold run the exact path unchanged, so small
# builds stay bit-identical to the exact builder.
CANDIDATE_STAGES = ("exact", "coarse")
DEFAULT_N_PROBE = 8
DEFAULT_COARSE_THRESHOLD = 4096
# Deferred re-pruning cadence: vertices a little over quota wait up to this
# many batches for the shared sweep (labels close later — still a superset
# of the incremental builder's, so recall is preserved); vertices more than
# 2*m past quota are swept at their own batch boundary so hub degrees (and
# the frozen slot axis S) stay bounded.
REPRUNE_EVERY = 24
_KMEANS_ITERS = 4
_KMEANS_SAMPLE = 16384
_ASSIGN_CHUNK = 8192


def pairwise_sq(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Squared L2 between row sets via one BLAS matmul, clamped at 0."""
    a = np.ascontiguousarray(a, np.float32)
    b = np.ascontiguousarray(b, np.float32)
    d = np.einsum("id,id->i", a, a)[:, None] \
        + np.einsum("jd,jd->j", b, b)[None, :] - 2.0 * (a @ b.T)
    return np.maximum(d, 0.0, out=d)


def gathered_sq(base: np.ndarray, gathered: np.ndarray) -> np.ndarray:
    """Squared L2 between ``base[r]`` and every gathered row
    ``gathered[r, c]`` — the per-row dot-identity counterpart of
    :func:`pairwise_sq`, clamped at 0."""
    d = np.einsum("rcd,rcd->rc", gathered, gathered) \
        + np.einsum("rd,rd->r", base, base)[:, None] \
        - 2.0 * np.einsum("rd,rcd->rc", base, gathered)
    return np.maximum(d, 0.0, out=d)


def gathered_sq_ids(V: np.ndarray, sq_norm: np.ndarray,
                    base_ids: np.ndarray, gathered_ids: np.ndarray
                    ) -> np.ndarray:
    """:func:`gathered_sq` from ids plus precomputed global squared norms
    (``sq_norm[i] == ||V[i]||^2``): gathers norms instead of recomputing
    them, so each call is one batched matvec (BLAS) instead of three
    einsums. Negative ids are padding (clipped; caller masks them)."""
    gi = np.clip(gathered_ids, 0, None)
    d = sq_norm[gi] + sq_norm[base_ids][:, None] \
        - 2.0 * np.matmul(V[gi], V[base_ids][:, :, None])[:, :, 0]
    return np.maximum(d, 0.0, out=d)


def rng_prune_batch(vectors: np.ndarray, cand_ids: np.ndarray,
                    cand_d: np.ndarray, m: int,
                    sq_norm: Optional[np.ndarray] = None) -> np.ndarray:
    """Batched RNG rule ("select neighbors heuristic") over R rows at once.

    Per row, equivalent to :func:`repro.core.hnsw.rng_prune`: scanning
    candidates in ascending base distance, keep c iff no already-kept k has
    ``d(k, c) < d(base, c)``. Reformulated as suppression so it vectorizes:
    keeping a candidate suppresses every candidate j with
    ``d(kept, j) < d(base, j)``; the next kept is the first unsuppressed
    survivor. That is ``m`` rounds of (R, C) vector ops — the kept-vs-rest
    distances come from one batched matvec per round instead of per-row
    Python.

    cand_ids : (R, C) int, sorted ascending by ``cand_d``; ``-1`` = padding
    cand_d   : (R, C) float, base→candidate squared distance (inf padding)
    Returns (R, m) int64 kept ids, ``-1``-padded.
    """
    cand_ids = np.asarray(cand_ids)
    R, C = cand_ids.shape
    kept = np.full((R, m), -1, np.int64)
    if R == 0 or C == 0:
        return kept
    # rows are sorted with padding last, so trailing all-padding columns
    # carry no information — trim them (deep tree levels pad heavily, and
    # every round below pays per retained column)
    w = int((cand_ids >= 0).sum(axis=1).max())
    if w < C:
        C = max(w, 1)
        cand_ids = cand_ids[:, :C]
        cand_d = cand_d[:, :C]
    alive = cand_ids >= 0
    rows = np.arange(R)
    ci = np.clip(cand_ids, 0, None)
    Vc = vectors[ci]                                    # (R, C, d)
    # candidate norms are round-invariant: hoist them (or gather the global
    # precompute) so each round is one batched matvec instead of a full
    # gathered_sq (3 einsums) per round
    cnorm = sq_norm[ci] if sq_norm is not None \
        else np.einsum("rcd,rcd->rc", Vc, Vc)
    for t in range(m):
        first = np.argmax(alive, axis=1)                # first survivor
        act = alive[rows, first]                        # False when row done
        if not act.any():
            break
        kept[act, t] = cand_ids[act, first[act]]
        kv = Vc[rows, first]
        # d(kept, j) for every candidate j: the kept norm is a cnorm column
        dkj = cnorm + cnorm[rows, first][:, None] \
            - 2.0 * np.matmul(Vc, kv[:, :, None])[:, :, 0]
        np.maximum(dkj, 0.0, out=dkj)   # same clamp as gathered_sq
        alive &= ~(act[:, None] & (dkj < cand_d))
        alive[rows, first] &= ~act
    return kept


class _BulkLevel:
    """Array-backed level-graph accumulator for the bulk builder.

    Same construction semantics and frozen schema as
    :class:`repro.core.hnsw.LabeledLevelGraph` (which the incremental
    builder keeps using), but open adjacency lives in preallocated
    ``(n, W)`` arrays and the closed-edge log in flat chunks, so inserts
    and re-prunes are numpy scatters instead of per-edge Python appends —
    the linear stages shared by every candidate mode were the build-time
    ceiling once the candidate stage went sub-quadratic.
    """

    def __init__(self, vectors: np.ndarray, n: int, *, m: int, ef_con: int,
                 m_max: Optional[int] = None, n_entries: int = 4):
        self.vectors = vectors
        self.m = int(m)
        self.m_max = int(m_max if m_max is not None else m)
        self.ef_con = int(ef_con)
        self.n_entries = int(n_entries)
        W = max(4 * self.m_max + 2 * self.m, 32)
        self.adj = np.full((n, W), -1, np.int32)
        self.born = np.zeros((n, W), np.int32)
        self.cnt = np.zeros(n, np.int64)
        # (u, v, b, e) arrays per re-prune; chunk order is chronological,
        # so a stable per-u sort at freeze reproduces edge_log order
        self.closed_chunks: List[tuple] = []
        self._flat_cache: Optional[tuple] = None
        self.node_members: Dict[int, List[int]] = {}
        self.node_member_vers: Dict[int, List[int]] = {}

    def ensure_width(self, need: int) -> None:
        W = self.adj.shape[1]
        if need <= W:
            return
        new_w = W
        while new_w < need:
            new_w *= 2
        grow = np.full((self.adj.shape[0], new_w - W), -1, np.int32)
        self.adj = np.concatenate([self.adj, grow], axis=1)
        self.born = np.concatenate([self.born, np.zeros_like(grow)], axis=1)

    def _closed_flat(self, n: int):
        # cached on chunk count: max_slots + freeze both flatten, back to
        # back, and the log is append-only between them
        if not self.closed_chunks:
            return (np.zeros(0, np.int64),) * 4
        if (self._flat_cache is not None
                and self._flat_cache[0] == len(self.closed_chunks)):
            return self._flat_cache[1]
        cu = np.concatenate([c[0] for c in self.closed_chunks])
        cv = np.concatenate([c[1] for c in self.closed_chunks])
        cb = np.concatenate([c[2] for c in self.closed_chunks])
        ce = np.concatenate([np.full(c[0].shape[0], c[3], np.int64)
                             for c in self.closed_chunks])
        self._flat_cache = (len(self.closed_chunks), (cu, cv, cb, ce))
        return cu, cv, cb, ce

    def max_slots(self, n: int) -> int:
        cu = self._closed_flat(n)[0]
        tot = np.bincount(cu, minlength=n) + self.cnt[:n]
        return int(tot.max()) if n else 0

    def freeze(self, n: int, slots: Optional[int] = None, out=None):
        """Dense (n, S) arrays in :meth:`LabeledLevelGraph.edge_log` order:
        closed triples (chronological per vertex) then open edges. ``out``
        (a ``(tgt, lab_b, lab_e)`` triple of (n, S) int32 views) scatters
        in place instead of allocating — the caller's stacked slab slices
        skip one full (n, S)-sized copy per array."""
        cu, cv, cb, ce = self._closed_flat(n)
        ccnt = np.bincount(cu, minlength=n)
        tot = ccnt + self.cnt[:n]
        s_req = int(tot.max()) if n else 0
        S = int(slots if slots is not None else max(s_req, 1))
        if s_req > S:
            u = int(np.argmax(tot))
            raise ValueError(f"vertex {u} has {int(tot[u])} edges > {S} slots")
        if out is not None:
            tgt, lab_b, lab_e = out
            tgt[:] = NO_EDGE
            lab_b[:] = 0
            lab_e[:] = 0
        else:
            tgt = np.full((n, S), NO_EDGE, dtype=np.int32)
            lab_b = np.zeros((n, S), dtype=np.int32)
            lab_e = np.zeros((n, S), dtype=np.int32)
        if cu.size:
            o = np.argsort(cu, kind="stable")
            off = np.cumsum(ccnt) - ccnt
            within = np.arange(cu.size) - off[cu[o]]
            tgt[cu[o], within] = cv[o]
            lab_b[cu[o], within] = cb[o]
            lab_e[cu[o], within] = ce[o]
        cnt = self.cnt[:n]
        eo = int(cnt.sum())
        if eo:
            rows = np.repeat(np.arange(n), cnt)
            within = np.arange(eo) - np.repeat(np.cumsum(cnt) - cnt, cnt)
            cols = ccnt[rows] + within
            tgt[rows, cols] = self.adj[rows, within]
            lab_b[rows, cols] = self.born[rows, within]
            lab_e[rows, cols] = OPEN
        return tgt, lab_b, lab_e


def _reprune_vertices(g: _BulkLevel, vertices: np.ndarray,
                      close_version: int,
                      sq_norm: Optional[np.ndarray] = None) -> None:
    """Deferred, batched re-prune: RNG-prune every over-quota vertex of one
    level down to ``m_max`` in a single vectorized pass (the bulk analogue
    of ``LabeledLevelGraph._reprune``). Pruned edges close at
    ``close_version`` — the last version of the batch that caused the
    overflow — which keeps them valid for (at least) every version the
    incremental builder would have exposed them at."""
    vertices = np.asarray(vertices, np.int64)
    todo = vertices[g.cnt[vertices] > g.m_max]
    if todo.size == 0:
        return
    V = g.vectors
    deg = g.cnt[todo]
    R, Cmax = todo.size, int(deg.max())
    mask = np.arange(Cmax)[None, :] < deg[:, None]
    tgt = g.adj[todo, :Cmax].astype(np.int64)
    tgt[~mask] = -1
    if sq_norm is not None:
        d = gathered_sq_ids(V, sq_norm, todo, tgt)
    else:
        d = gathered_sq(V[todo], V[np.clip(tgt, 0, None)])
    d[~mask] = np.inf
    order = np.argsort(d, axis=1, kind="stable")
    kept = rng_prune_batch(V, np.take_along_axis(tgt, order, 1),
                           np.take_along_axis(d, order, 1), g.m_max,
                           sq_norm=sq_norm)
    # survivors-first compaction: adjacency rows are duplicate-free, so
    # flat (row, neighbor) keys identify edges; a stable argsort on the
    # keep mask rebuilds each row in original adjacency order
    stride = V.shape[0] + 1
    keys = np.arange(R, dtype=np.int64)[:, None] * stride \
        + np.where(mask, tgt, stride - 1)
    kkeys = (np.arange(R, dtype=np.int64)[:, None] * stride + kept)[kept >= 0]
    keep = np.isin(keys, kkeys).reshape(R, Cmax) & mask
    adj_rows = g.adj[todo, :Cmax].copy()
    born_rows = g.born[todo, :Cmax].copy()
    ordc = np.argsort(~keep, axis=1, kind="stable")
    g.adj[todo, :Cmax] = np.take_along_axis(adj_rows, ordc, 1)
    g.born[todo, :Cmax] = np.take_along_axis(born_rows, ordc, 1)
    g.cnt[todo] = keep.sum(axis=1)
    dropm = mask & ~keep
    if dropm.any():
        ri, _ = np.nonzero(dropm)
        g.closed_chunks.append((todo[ri], adj_rows[dropm].astype(np.int64),
                                born_rows[dropm].astype(np.int64),
                                int(close_version)))


def auto_n_clusters(n: int) -> int:
    """Default coarse-quantizer size for an ``n``-row training prefix:
    ``~16*sqrt(n)`` keeps probed-pool width ~``n_probe * sqrt(n)/16`` (the
    candidate matmul term, which dominates build time, shrinks linearly in
    the cluster count while the assignment matmul only grows ~n*K*d — cheap
    until K ~ 8192), clamped so tiny prefixes still get a few
    non-degenerate clusters and million-row builds stay under an
    8192-centroid assignment matmul."""
    return max(8, min(8192, int(round(16.0 * math.sqrt(n))), n // 8))


def _kmeans(X: np.ndarray, k: int, iters: int = _KMEANS_ITERS) -> np.ndarray:
    """Deterministic Lloyd k-means: evenly spaced init over the (already
    insertion-ordered) training rows, fixed iteration count, centroid
    updates as one scatter-add segment-sum per iteration (no per-cluster
    Python loop — the builder hot path stays array-native)."""
    n = int(X.shape[0])
    k = min(k, n)
    cent = np.ascontiguousarray(
        X[np.linspace(0, n - 1, k).astype(np.int64)], np.float32)
    for _ in range(iters):
        assign = np.empty(n, np.int64)
        for a in range(0, n, _ASSIGN_CHUNK):
            b = min(a + _ASSIGN_CHUNK, n)
            assign[a:b] = pairwise_sq(X[a:b], cent).argmin(axis=1)
        sums = np.zeros((k, X.shape[1]), np.float64)
        np.add.at(sums, assign, X)                  # segment-sum over rows
        counts = np.bincount(assign, minlength=k)
        nz = counts > 0
        cent[nz] = (sums[nz] / counts[nz, None]).astype(np.float32)
    return cent


class _CoarsePool:
    """IVF-style candidate pools over *insertion positions* of one variant.

    Trained lazily at the first batch whose inserted prefix reaches the
    coarse threshold: k-means centroids over (a sample of) the prefix, then
    every consolidated position lives in a CSR bucket per centroid. A batch
    row's pool is the members of its ``n_probe`` nearest centroids plus the
    *recent block* — positions inserted since the last consolidation, which
    are insertion-order (= attribute-order) neighbors and therefore carry
    most same-node candidates for the deep, narrow tree levels. Positions
    are merged into the CSR in O(new + total) per consolidation (stable
    within-cluster order), never re-sorted from scratch.
    """

    def __init__(self, V: np.ndarray, order: np.ndarray, *,
                 n_clusters: Optional[int], n_probe: int, ef_con: int,
                 batch: int, stats: Optional[Dict[str, float]] = None):
        self.V = V
        self.order = np.asarray(order, np.int64)
        self.n_clusters = n_clusters
        self.n_probe = max(1, int(n_probe))
        self.ef_con = ef_con
        self.consolidate_cap = max(batch, 512)
        self.stats = stats if stats is not None else {}
        self.trained = False
        self.centroids: Optional[np.ndarray] = None
        self.assign = np.full(self.order.shape[0], -1, np.int32)
        self.csr_until = 0
        self.K = 0
        self.csr_counts = np.zeros(0, np.int64)
        self.csr_indptr = np.zeros(1, np.int64)
        self.csr_idx = np.zeros(0, np.int64)

    def _assign_range(self, a: int, b: int) -> None:
        t0 = time.perf_counter()
        rows = self.V[self.order[a:b]]
        out = np.empty(b - a, np.int32)
        for c in range(0, b - a, _ASSIGN_CHUNK):
            e = min(c + _ASSIGN_CHUNK, b - a)
            out[c:e] = pairwise_sq(rows[c:e], self.centroids).argmin(axis=1)
        self.assign[a:b] = out
        self.stats["assign_s"] = (self.stats.get("assign_s", 0.0)
                                  + time.perf_counter() - t0)

    def _merge(self, upto: int) -> None:
        """Fold positions ``[csr_until, upto)`` into the CSR buckets."""
        a_new = self.assign[self.csr_until:upto].astype(np.int64)
        counts_new = np.bincount(a_new, minlength=self.K)
        counts = self.csr_counts + counts_new
        indptr = np.zeros(self.K + 1, np.int64)
        np.cumsum(counts, out=indptr[1:])
        idx = np.empty(int(indptr[-1]), np.int64)
        if self.csr_idx.size:
            cl_old = np.repeat(np.arange(self.K), self.csr_counts)
            within = np.arange(self.csr_idx.size) - self.csr_indptr[cl_old]
            idx[indptr[cl_old] + within] = self.csr_idx
        if a_new.size:
            o = np.argsort(a_new, kind="stable")
            cl_new = a_new[o]
            grp = np.cumsum(counts_new) - counts_new
            within = np.arange(a_new.size) - grp[cl_new]
            idx[indptr[cl_new] + self.csr_counts[cl_new] + within] = \
                np.arange(self.csr_until, upto, dtype=np.int64)[o]
        self.csr_counts, self.csr_indptr, self.csr_idx = counts, indptr, idx
        self.csr_until = upto

    def train(self, start: int) -> None:
        """Fit centroids on the ``start``-row inserted prefix and bucket it."""
        t0 = time.perf_counter()
        sample = np.linspace(0, start - 1,
                             min(start, _KMEANS_SAMPLE)).astype(np.int64)
        # size the quantizer for the FULL build, not the training prefix:
        # buckets fill toward n/K as insertion proceeds, so a prefix-sized K
        # would let pool width grow linearly with n
        k = self.n_clusters or auto_n_clusters(self.order.shape[0])
        k = min(k, start)
        self.centroids = _kmeans(self.V[self.order[sample]], k)
        self.K = int(self.centroids.shape[0])
        self.csr_counts = np.zeros(self.K, np.int64)
        self.csr_indptr = np.zeros(self.K + 1, np.int64)
        self.stats["kmeans_s"] = (self.stats.get("kmeans_s", 0.0)
                                  + time.perf_counter() - t0)
        self._assign_range(0, start)
        self._merge(start)
        self.trained = True

    def maybe_consolidate(self, start: int) -> None:
        if start - self.csr_until >= self.consolidate_cap:
            self._assign_range(self.csr_until, start)
            self._merge(start)

    def pool(self, start: int, end: int):
        """Candidate *positions* for batch rows [start, end): ``(P, wb)``
        where ``P`` is (R, Cpool) — per-row probed-cluster members in
        columns ``[0, wb)`` (``-1``-padded) and the recent block, identical
        for every row, in the fixed tail ``[wb, Cpool)``. The caller masks
        positions at or after each row's own."""
        R = end - start
        q = self.V[self.order[start:end]]
        dq = pairwise_sq(q, self.centroids)
        p = min(self.n_probe, self.K)
        if p < self.K:
            top = np.argpartition(dq, p - 1, axis=1)[:, :p]
        else:
            top = np.tile(np.arange(self.K), (R, 1))
        # per-cluster contribution cap: generous vs the mean bucket size so
        # it only trims pathological skew, keeping pool width bounded
        cap = max(2 * self.ef_con, (4 * max(self.csr_until, 1)) // self.K)
        cnt_used = np.minimum(self.csr_counts[top], cap)
        rec = np.arange(self.csr_until, end, dtype=np.int64)
        wb = int(cnt_used.sum(axis=1).max()) if R else 0
        pool = np.full((R, max(wb + rec.size, 1)), -1, np.int64)
        cnt = cnt_used.ravel()
        tot = int(cnt.sum())
        if tot:
            seg = np.repeat(np.arange(R * p), cnt)
            within = np.arange(tot) - np.repeat(np.cumsum(cnt) - cnt, cnt)
            src = self.csr_indptr[top.ravel()][seg] + within
            colbase = np.cumsum(cnt_used, axis=1) - cnt_used
            pool[seg // p, colbase.ravel()[seg] + within] = self.csr_idx[src]
        if rec.size:
            pool[:, wb:] = rec[None, :]
        return pool, wb


def _top_sorted(Dm: np.ndarray, C: int):
    """Per row: column indices + distances of the up-to-``C`` smallest
    entries of ``Dm``, sorted ascending (inf = masked-out)."""
    if Dm.shape[1] >= C:
        part = np.argpartition(Dm, C - 1, axis=1)[:, :C]
        pd = np.take_along_axis(Dm, part, axis=1)
    else:
        part = np.tile(np.arange(Dm.shape[1]), (Dm.shape[0], 1))
        pd = Dm
    o2 = np.argsort(pd, axis=1, kind="stable")
    return np.take_along_axis(part, o2, axis=1), \
        np.take_along_axis(pd, o2, axis=1)


def _apply_kept(g: _BulkLevel, batch: np.ndarray, kept: np.ndarray,
                rnode: np.ndarray, sort_rank: np.ndarray) -> np.ndarray:
    """Scatter pruned neighbor lists + member bookkeeping for one level's
    batch rows (in insertion order). Shared verbatim by the exact and
    coarse candidate stages — only the candidate sets feeding ``kept``
    differ. Returns every vertex whose degree changed; the caller checks
    quotas and schedules (deferred) re-pruning.

    Kept targets are always earlier than their row, so the forward scatter
    (batch rows start empty) followed by the grouped reverse scatter
    reproduces the per-edge append order of the incremental builder."""
    valid = kept >= 0                       # -1 padding is a suffix
    kcnt = valid.sum(axis=1)
    ver = sort_rank[batch]
    ri, ci = np.nonzero(valid)
    c_flat = kept[ri, ci]
    g.adj[batch[ri], ci] = c_flat
    g.born[batch[ri], ci] = ver[ri]
    g.cnt[batch] = kcnt
    uniq = np.zeros(0, np.int64)
    if ri.size:
        o = np.argsort(c_flat, kind="stable")
        cs = c_flat[o]
        uniq, counts = np.unique(cs, return_counts=True)
        g.ensure_width(int((g.cnt[uniq] + counts).max()))
        grp_off = np.cumsum(counts) - counts
        slot = np.repeat(g.cnt[uniq], counts) \
            + (np.arange(cs.size) - np.repeat(grp_off, counts))
        g.adj[cs, slot] = batch[ri[o]]
        g.born[cs, slot] = ver[ri[o]]
        g.cnt[uniq] += counts
    # membership bookkeeping stays per-row (one append per object-level)
    batch_l = batch.tolist()
    ver_l = ver.tolist()
    node_l = rnode.tolist()
    members, vers = g.node_members, g.node_member_vers
    for i, u in enumerate(batch_l):
        node = node_l[i]
        members.setdefault(node, []).append(u)
        vers.setdefault(node, []).append(ver_l[i])
    return np.concatenate([batch, uniq])


def bulk_insert_levels(vectors: np.ndarray, order: np.ndarray,
                       sort_rank: np.ndarray, tkey: np.ndarray, Lv: int, *,
                       m: int, ef_con: int, m_max: Optional[int] = None,
                       n_entries: int = 4, batch_size: Optional[int] = None,
                       progress: Optional[int] = None, variant: str = "?",
                       candidate_stage: str = "exact",
                       n_clusters: Optional[int] = None,
                       n_probe: int = DEFAULT_N_PROBE,
                       coarse_threshold: Optional[int] = None,
                       stats: Optional[Dict[str, float]] = None
                       ) -> "List[_BulkLevel]":
    """Build all ``Lv`` level graphs of one variant in sorted-order batches.

    Fills array-backed :class:`_BulkLevel` accumulators that freeze to the
    exact same dense schema as the incremental path's
    :class:`~repro.core.hnsw.LabeledLevelGraph`, but produces candidates
    from batched distance matmuls instead of per-object beam searches and
    applies edges as numpy scatters. Returns the populated level graphs.

    ``candidate_stage="exact"`` computes each batch row's distances to
    *every* earlier object (one BLAS matmul per batch) — O(n^2) total.
    ``"coarse"`` switches, once the inserted prefix reaches
    ``coarse_threshold`` (default ``DEFAULT_COARSE_THRESHOLD``), to the
    :class:`_CoarsePool` quantizer: candidates come from the row's
    ``n_probe`` nearest of ``n_clusters`` k-means centroids' member buckets
    plus the recent insertion block, bounding per-batch work by the pool
    width instead of the prefix length. Per level, rows whose whole
    earlier same-node population fits in ``ef_con`` bypass the pool and
    gather that population exactly (the deep-level backstop), so small
    tree nodes see identical candidate sets in both stages; batches below
    the threshold run the exact code path, bit-identically.

    ``stats``, when given a dict, accumulates the wall-clock stage
    breakdown: ``candidate_s`` / ``prune_s`` / ``insert_s`` (+
    ``kmeans_s`` / ``assign_s`` and batch counters on the coarse path).
    """
    n = int(order.shape[0])
    B = DEFAULT_BATCH if batch_size is None else int(batch_size)
    if B < 1:
        raise ValueError("batch_size must be >= 1")
    if candidate_stage not in CANDIDATE_STAGES:
        raise ValueError(f"candidate_stage must be one of {CANDIDATE_STAGES}")
    threshold = (DEFAULT_COARSE_THRESHOLD if coarse_threshold is None
                 else max(1, int(coarse_threshold)))
    st = stats if stats is not None else {}
    V = np.ascontiguousarray(vectors, np.float32)
    # global squared norms, shared by every distance identity below — the
    # per-call norm einsums were a top-3 profile entry at n=50k
    Vn = np.einsum("nd,nd->n", V, V)
    levels = [_BulkLevel(V, n, m=m, ef_con=ef_con, m_max=m_max,
                         n_entries=n_entries) for _ in range(Lv)]
    if n == 0:
        return levels
    # tree node of every object at every level (Algorithm 1's root→leaf path)
    tkey_arr = np.asarray(tkey, np.int64)
    node_of = np.stack([tkey_arr >> (Lv - 1 - lvl) for lvl in range(Lv)])
    coarse: Optional[_CoarsePool] = None
    if candidate_stage == "coarse":
        coarse = _CoarsePool(V, order, n_clusters=n_clusters,
                             n_probe=n_probe, ef_con=ef_con, batch=B,
                             stats=st)
    pending = np.zeros((Lv, n), bool)       # per-level deferred-reprune sets
    hard_cap = levels[0].m_max + 2 * m
    batch_no = 0
    done = 0
    for start in range(0, n, B):
        batch = order[start:start + B]
        R = batch.shape[0]
        end = start + R
        use_coarse = coarse is not None and start >= threshold
        t0 = time.perf_counter()
        if use_coarse:
            if not coarse.trained:
                coarse.train(start)
            else:
                coarse.maybe_consolidate(start)
            t0 = time.perf_counter()   # train/consolidate timed separately
            P, wb = coarse.pool(start, end)          # (R, Cpool) positions
            row_pos = start + np.arange(R)
            p_earlier = (P >= 0) & (P < row_pos[:, None])
            pool_ids = order[np.clip(P, 0, None)]    # object ids
            # split distance computation: per-row bucket columns need the
            # gathered matvec form, but the recent-block tail is the same
            # positions for every row — one real GEMM covers it
            Dp = np.empty(P.shape, np.float32)
            Dp[:, :wb] = gathered_sq_ids(V, Vn, batch, pool_ids[:, :wb])
            if wb < P.shape[1]:
                Dp[:, wb:] = pairwise_sq(V[batch], V[pool_ids[0, wb:]])
            # gather pool tree keys once; per-level node ids are shifts
            pool_tkey = tkey_arr[pool_ids]
            Db = earlier = prev = None
            st["coarse_batches"] = st.get("coarse_batches", 0) + 1
        else:
            prev = order[:end]                # insertion order, incl. batch
            # one matmul: batch rows vs every earlier-or-in-batch object;
            # per-level candidate sets are masks over these shared rows
            Db = pairwise_sq(V[batch], V[prev])
            earlier = np.arange(end)[None, :] \
                < (start + np.arange(R))[:, None]
            st["exact_batches"] = st.get("exact_batches", 0) + 1
        shared_s = time.perf_counter() - t0
        C = min(ef_con, end)
        # candidate matrices for ALL levels of this batch, stacked so one
        # rng_prune_batch call prunes every (object, level) row at once —
        # rows are independent, so this is result-identical to per-level
        # calls but amortizes the per-call numpy overhead Lv-fold
        cand_ids_all = np.empty((Lv, R, C), np.int64)
        cand_d_all = np.empty((Lv, R, C), np.float32)
        t0 = time.perf_counter()
        for lvl in range(Lv):
            rnode = node_of[lvl][batch]
            if not use_coarse:
                Dm = np.where(earlier & (node_of[lvl][prev][None, :]
                                         == rnode[:, None]), Db, np.inf)
                # exact top-ef_con earlier same-node members per batch object
                # (the incremental beam search only approximates this set)
                cols, cand_d = _top_sorted(Dm, C)
                cand_ids = np.where(np.isfinite(cand_d), prev[cols], -1)
            else:
                cand_ids, cand_d = _coarse_level_candidates(
                    levels[lvl], V, Vn, batch, rnode, C, pool_ids, Dp,
                    p_earlier, pool_tkey >> (Lv - 1 - lvl))
            cand_ids_all[lvl] = cand_ids
            cand_d_all[lvl] = cand_d
        st["candidate_s"] = st.get("candidate_s", 0.0) \
            + time.perf_counter() - t0 + shared_s
        t0 = time.perf_counter()
        kept_all = rng_prune_batch(
            V, cand_ids_all.reshape(Lv * R, C),
            cand_d_all.reshape(Lv * R, C), m,
            sq_norm=Vn).reshape(Lv, R, m)
        st["prune_s"] = st.get("prune_s", 0.0) + time.perf_counter() - t0
        for lvl in range(Lv):
            g = levels[lvl]
            rnode = node_of[lvl][batch]
            t0 = time.perf_counter()
            touched = _apply_kept(g, batch, kept_all[lvl], rnode, sort_rank)
            deg = g.cnt[touched]
            pending[lvl][touched[deg > g.m_max]] = True
            urgent = np.unique(touched[deg > hard_cap])
            if urgent.size:
                _reprune_vertices(g, urgent,
                                  int(sort_rank[int(batch[-1])]),
                                  sq_norm=Vn)
                pending[lvl][urgent] = False
            st["insert_s"] = st.get("insert_s", 0.0) \
                + time.perf_counter() - t0
        batch_no += 1
        if batch_no % REPRUNE_EVERY == 0 or end == n:
            t0 = time.perf_counter()
            close_ver = int(sort_rank[int(batch[-1])])
            for lvl in range(Lv):
                todo = np.nonzero(pending[lvl])[0]
                if todo.size:
                    _reprune_vertices(levels[lvl], todo, close_ver,
                                      sq_norm=Vn)
                    pending[lvl][todo] = False
            st["insert_s"] = st.get("insert_s", 0.0) \
                + time.perf_counter() - t0
        done = end
        if progress and (done // progress) > ((done - R) // progress):
            logger.progress("bulk_insert", variant=variant, done=done,
                            total=n, final=(done == n))
    return levels


def _coarse_level_candidates(g: _BulkLevel, V: np.ndarray,
                             Vn: np.ndarray, batch: np.ndarray,
                             rnode: np.ndarray, C: int,
                             pool_ids: np.ndarray, Dp: np.ndarray,
                             p_earlier: np.ndarray,
                             pool_node: np.ndarray):
    """One level's sorted candidate matrix from the coarse pool.

    Big-node rows take the top-``C`` same-node entries of the pool; rows
    whose entire earlier same-node population fits in ``C`` instead gather
    that population exactly (pool misses on a nearly-empty deep node would
    otherwise starve its adjacency), making small nodes stage-invariant.
    """
    R = batch.shape[0]
    cand_ids = np.full((R, C), -1, np.int64)
    cand_d = np.full((R, C), np.inf, np.float32)
    # earlier same-node population = pre-batch members + in-batch earlier
    pre = np.fromiter((len(g.node_members.get(int(nd), ()))
                       for nd in rnode), np.int64, count=R)
    tri = np.tril(rnode[:, None] == rnode[None, :], -1).sum(axis=1)
    small = (pre + tri) <= C
    bigi = np.nonzero(~small)[0]
    if bigi.size:
        Dm = np.where(p_earlier[bigi]
                      & (pool_node[bigi] == rnode[bigi, None]),
                      Dp[bigi], np.inf)
        cols, sd = _top_sorted(Dm, C)
        sid = pool_ids[bigi[:, None], cols]
        w = sd.shape[1]
        cand_d[bigi, :w] = sd
        cand_ids[bigi, :w] = np.where(np.isfinite(sd), sid, -1)
    smalli = np.nonzero(small)[0]
    if smalli.size:
        acc: Dict[int, List[int]] = {}
        lists: List[List[int]] = []
        for i in range(R):
            nd = int(rnode[i])
            if small[i]:
                lists.append(list(g.node_members.get(nd, ()))
                             + acc.get(nd, []))
            acc.setdefault(nd, []).append(int(batch[i]))
        Cs = max(1, max(len(l) for l in lists))
        ids_s = np.full((len(lists), Cs), -1, np.int64)
        for r, l in enumerate(lists):
            ids_s[r, :len(l)] = l
        ds = gathered_sq_ids(V, Vn, batch[smalli], ids_s)
        ds[ids_s < 0] = np.inf
        o = np.argsort(ds, axis=1, kind="stable")
        w = min(Cs, C)
        cand_d[smalli, :w] = np.take_along_axis(ds, o, axis=1)[:, :w]
        cand_ids[smalli, :w] = np.take_along_axis(ids_s, o, axis=1)[:, :w]
    return cand_ids, cand_d
