"""Bulk MSTG construction — batched Algorithms 1–3 (the default build path).

The incremental builder (:mod:`repro.core.hnsw`) inserts one object at a
time: every insertion runs a Python ``heapq`` beam search over the live
graph per touched tree node, which costs ~ms per object and makes
construction ~3 orders of magnitude slower than the query side. The bulk
builder exploits the one structural fact the incremental path ignores: the
graph is never *searched* during construction if candidates can be produced
another way. So it

1. processes objects in sorted (version) order in fixed-size batches,
2. generates candidates with ONE batched distance matmul per batch — each
   batch object's distances to every earlier-inserted object are computed
   once and *shared across all* ``Lv`` *levels* of its root→leaf tree path
   (per level, candidates are just the nearest earlier members of the same
   tree node: a boolean mask over the shared distance rows),
3. applies the RNG "select neighbors" rule to all (object, level) rows at
   once (:func:`rng_prune_batch` — m rounds of (R, C) vector ops instead of
   R sequential Python scans), and
4. defers reverse-edge re-pruning to the batch boundary, re-pruning every
   over-quota vertex of a level in one batched call.

Fidelity: candidate sets are *exact* nearest earlier same-node members
(the incremental beam search only approximates this), the pruning rule is
identical, and member / entry-point / version bookkeeping is bit-identical
to the incremental builder. Edge validity labels are a **superset** of the
incremental ones: an edge pruned at a batch boundary closes at the batch's
last version instead of the exact intra-batch insertion version, so every
query version sees at least the edges the incremental graph would expose
(never fewer — recall is preserved; Theorem D.1 *exactness* is what the
``builder="incremental"`` oracle is kept for). The frozen array schema is
unchanged: both builders fill the same :class:`LabeledLevelGraph` adjacency
structures and go through the same freeze.

On accelerator backends the batched distance matmuls map onto the
:mod:`repro.kernels.ops` pairwise kernels; on CPU (this container) NumPy's
BLAS matmul is the fast path, so that is what runs here.
"""
from __future__ import annotations

from typing import List, Optional, Set

import numpy as np

from repro.obs.log import get_logger

from .hnsw import LabeledLevelGraph

logger = get_logger(__name__)

# "scan" builds only the segment-tree member structure (flat/pruned routes,
# no graphs — see repro.core.mstg.build_scan_variant); the other two build
# the full labeled level graphs.
BUILDERS = ("bulk", "incremental", "scan")
DEFAULT_BATCH = 128


def pairwise_sq(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Squared L2 between row sets via one BLAS matmul, clamped at 0."""
    a = np.ascontiguousarray(a, np.float32)
    b = np.ascontiguousarray(b, np.float32)
    d = np.einsum("id,id->i", a, a)[:, None] \
        + np.einsum("jd,jd->j", b, b)[None, :] - 2.0 * (a @ b.T)
    return np.maximum(d, 0.0, out=d)


def gathered_sq(base: np.ndarray, gathered: np.ndarray) -> np.ndarray:
    """Squared L2 between ``base[r]`` and every gathered row
    ``gathered[r, c]`` — the per-row dot-identity counterpart of
    :func:`pairwise_sq`, clamped at 0."""
    d = np.einsum("rcd,rcd->rc", gathered, gathered) \
        + np.einsum("rd,rd->r", base, base)[:, None] \
        - 2.0 * np.einsum("rd,rcd->rc", base, gathered)
    return np.maximum(d, 0.0, out=d)


def rng_prune_batch(vectors: np.ndarray, cand_ids: np.ndarray,
                    cand_d: np.ndarray, m: int) -> np.ndarray:
    """Batched RNG rule ("select neighbors heuristic") over R rows at once.

    Per row, equivalent to :func:`repro.core.hnsw.rng_prune`: scanning
    candidates in ascending base distance, keep c iff no already-kept k has
    ``d(k, c) < d(base, c)``. Reformulated as suppression so it vectorizes:
    keeping a candidate suppresses every candidate j with
    ``d(kept, j) < d(base, j)``; the next kept is the first unsuppressed
    survivor. That is ``m`` rounds of (R, C) vector ops — the kept-vs-rest
    distances come from one batched matvec per round instead of per-row
    Python.

    cand_ids : (R, C) int, sorted ascending by ``cand_d``; ``-1`` = padding
    cand_d   : (R, C) float, base→candidate squared distance (inf padding)
    Returns (R, m) int64 kept ids, ``-1``-padded.
    """
    cand_ids = np.asarray(cand_ids)
    R, C = cand_ids.shape
    kept = np.full((R, m), -1, np.int64)
    if R == 0 or C == 0:
        return kept
    alive = cand_ids >= 0
    rows = np.arange(R)
    Vc = vectors[np.clip(cand_ids, 0, None)]            # (R, C, d)
    for t in range(m):
        first = np.argmax(alive, axis=1)                # first survivor
        act = alive[rows, first]                        # False when row done
        if not act.any():
            break
        kept[act, t] = cand_ids[act, first[act]]
        kv = np.take_along_axis(Vc, first[:, None, None], axis=1)[:, 0]
        dkj = gathered_sq(kv, Vc)       # d(kept, j) for every candidate j
        alive &= ~(act[:, None] & (dkj < cand_d))
        alive[rows, first] &= ~act
    return kept


def _reprune_vertices(g: LabeledLevelGraph, vertices: Set[int],
                      close_version: int) -> None:
    """Deferred, batched re-prune: RNG-prune every over-quota vertex of one
    level down to ``m_max`` in a single vectorized pass (the bulk analogue
    of ``LabeledLevelGraph._reprune``). Pruned edges close at
    ``close_version`` — the last version of the batch that caused the
    overflow — which keeps them valid for (at least) every version the
    incremental builder would have exposed them at."""
    todo = [u for u in vertices if len(g.open_adj.get(u, ())) > g.m_max]
    if not todo:
        return
    V = g.vectors
    deg = [len(g.open_adj[u]) for u in todo]
    Cmax = max(deg)
    tgt = np.full((len(todo), Cmax), -1, np.int64)
    for i, u in enumerate(todo):
        tgt[i, :deg[i]] = g.open_adj[u]
    base = V[np.asarray(todo, np.int64)]                # (R, d)
    Vt = V[np.clip(tgt, 0, None)]                       # (R, Cmax, d)
    d = gathered_sq(base, Vt)
    d[tgt < 0] = np.inf
    order = np.argsort(d, axis=1, kind="stable")
    kept = rng_prune_batch(V, np.take_along_axis(tgt, order, 1),
                           np.take_along_axis(d, order, 1), g.m_max)
    for i, u in enumerate(todo):
        keep = {int(c) for c in kept[i] if c >= 0}
        new_adj: List[int] = []
        new_born: List[int] = []
        log = None
        # keep surviving edges in original adjacency order (matches the
        # incremental builder's _reprune)
        for v, b in zip(g.open_adj[u], g.open_born[u]):
            if v in keep:
                new_adj.append(v)
                new_born.append(b)
            else:
                if log is None:
                    log = g.closed.setdefault(u, [])
                log.append((v, b, close_version))
        g.open_adj[u] = new_adj
        g.open_born[u] = new_born


def bulk_insert_levels(vectors: np.ndarray, order: np.ndarray,
                       sort_rank: np.ndarray, tkey: np.ndarray, Lv: int, *,
                       m: int, ef_con: int, m_max: Optional[int] = None,
                       n_entries: int = 4, batch_size: Optional[int] = None,
                       progress: Optional[int] = None,
                       variant: str = "?") -> List[LabeledLevelGraph]:
    """Build all ``Lv`` level graphs of one variant in sorted-order batches.

    Fills the exact same :class:`LabeledLevelGraph` structures the
    incremental path fills (so ``freeze`` / member / entry-point code is
    shared verbatim), but produces candidates from batched distance matmuls
    instead of per-object beam searches. Returns the populated level graphs.
    """
    n = int(order.shape[0])
    B = DEFAULT_BATCH if batch_size is None else int(batch_size)
    if B < 1:
        raise ValueError("batch_size must be >= 1")
    V = np.ascontiguousarray(vectors, np.float32)
    levels = [LabeledLevelGraph(V, m=m, ef_con=ef_con, m_max=m_max,
                                n_entries=n_entries) for _ in range(Lv)]
    if n == 0:
        return levels
    # tree node of every object at every level (Algorithm 1's root→leaf path)
    node_of = np.stack([np.asarray(tkey, np.int64) >> (Lv - 1 - lvl)
                        for lvl in range(Lv)])
    done = 0
    for start in range(0, n, B):
        batch = order[start:start + B]
        end = start + batch.shape[0]
        prev = order[:end]                    # insertion order, incl. batch
        # one matmul: batch rows vs every earlier-or-in-batch object; the
        # per-level candidate sets below are masks over these shared rows
        Db = pairwise_sq(V[batch], V[prev])
        earlier = np.arange(end)[None, :] \
            < (start + np.arange(batch.shape[0]))[:, None]
        C = min(ef_con, end)
        for lvl in range(Lv):
            g = levels[lvl]
            rnode = node_of[lvl][batch]
            Dm = np.where(earlier & (node_of[lvl][prev][None, :]
                                     == rnode[:, None]), Db, np.inf)
            # exact top-ef_con earlier same-node members per batch object
            # (the incremental beam search only approximates this set)
            part = np.argpartition(Dm, C - 1, axis=1)[:, :C]
            pd = np.take_along_axis(Dm, part, axis=1)
            o2 = np.argsort(pd, axis=1, kind="stable")
            cand_d = np.take_along_axis(pd, o2, axis=1)
            cand_ids = np.where(np.isfinite(cand_d),
                                prev[np.take_along_axis(part, o2, axis=1)], -1)
            kept = rng_prune_batch(V, cand_ids, cand_d, m)
            overfull: Set[int] = set()
            for i, u in enumerate(batch):
                u = int(u)
                ver = int(sort_rank[u])
                adj_u = g.open_adj.setdefault(u, [])
                born_u = g.open_born.setdefault(u, [])
                for c in kept[i]:
                    if c < 0:
                        break
                    c = int(c)
                    adj_u.append(c)
                    born_u.append(ver)
                    adj_c = g.open_adj[c]
                    adj_c.append(u)
                    g.open_born[c].append(ver)
                    if len(adj_c) > g.m_max:
                        overfull.add(c)
                if len(adj_u) > g.m_max:
                    overfull.add(u)
                node = int(rnode[i])
                g.node_members.setdefault(node, []).append(u)
                g.node_member_vers.setdefault(node, []).append(ver)
            _reprune_vertices(g, overfull, int(sort_rank[int(batch[-1])]))
        done = end
        if progress and (done // progress) > ((done - batch.shape[0]) // progress):
            logger.progress("bulk_insert", variant=variant, done=done,
                            total=n, final=(done == n))
    return levels
