"""Compressed-scan execution: approximate top-R over quantized codes, then
an exact float32 re-rank of those R candidates.

The flat route's cost at scale is streaming the corpus; scanning the
quantized codes instead cuts the streamed bytes 4x (int8) / 2x (float16).
The scan produces an over-fetched candidate list (``rerank_k >= k``) whose
distances are approximate — quantization error plus, on the Pallas int8
path, query-side rounding — and :func:`exact_rerank` recomputes the true
float32 distances for just those R rows before the final ``top_k(k)``, so
end recall matches the exact scan for any candidate set that contains the
true neighbors (the ``rerank_k`` knob trades that containment probability
against re-rank cost; the default ``max(4k, 32)`` recovers recall@10 to
within 0.01 on the bench grids).

Two scan implementations share the math
``dist = (||q||^2 - 2 q.offset) - 2 (q*scale).code + sq_norm``:

* :func:`compressed_flat_topr` — a ``lax.scan`` over corpus blocks that
  dequantizes each code block *in registers/cache* (never materializing a
  float32 copy of the corpus) and carries a running top-R. This is the
  CPU/XLA path and the shape the TPU kernel tiles follow.
* :func:`repro.kernels.pairwise_l2_int8` via ``use_kernel=True`` — the
  Pallas MXU path with integer dot products; the engine funnels its (Q, N)
  output through :func:`topr_from_dists`.

The float32 corpus used by the re-rank stays **host-side**: the engine
gathers the R candidate rows with NumPy and ships only the (Q, R, d) slice
to the device, so the quantized path never stages the full float32 corpus
in accelerator memory.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import intervals as iv

NO_EDGE = -1
DEFAULT_BLOCK = 4096


@functools.partial(jax.jit, static_argnames=("mask", "rerank", "block"))
def compressed_flat_topr(codes_t, scale, offset, sq_norm, lo, hi,
                         queries, ql, qh, *, mask: int, rerank: int,
                         block: int = DEFAULT_BLOCK):
    """Masked approximate top-``rerank`` over a **(d, n) transposed**
    quantized code table. Returns ((Q, R) int32 ids, (Q, R) approx dists),
    ascending, NO_EDGE/+inf padded where fewer than R rows qualify.

    The transposed layout is load-bearing, not cosmetic: each block slice
    is a contiguous (d, blk) panel, so the skinny (Q, d) x (d, blk) matmul
    consumes it directly — on XLA CPU that is ~4-5x faster than contracting
    against strided (blk, d) row-major slices, and it is what lets the
    1-byte stream actually beat the float32 fused scan end to end. The
    engine stages this view once per store (``QueryEngine.store_dev``); the
    canonical (n, d) ``QuantizedStore.codes`` stays row-major for the
    gather paths (pruned / graph) and persistence."""
    d, n = codes_t.shape
    Q = queries.shape[0]
    R = min(int(rerank), n)
    blk = min(block, n)
    nb = -(-n // blk)
    pad = nb * blk - n
    if pad:
        codes_t = jnp.pad(codes_t, ((0, 0), (0, pad)))
        sq_norm = jnp.pad(sq_norm, (0, pad))
        # NaN endpoints fail every RR comparison -> pad rows never qualify
        lo = jnp.pad(lo, (0, pad), constant_values=jnp.nan)
        hi = jnp.pad(hi, (0, pad), constant_values=jnp.nan)
    q = queries.astype(jnp.float32)
    w = q * scale[None, :]                                   # (Q, d)
    cq = jnp.sum(q * q, axis=1) - 2.0 * (q @ offset)         # (Q,)
    arange_b = jnp.arange(blk, dtype=jnp.int32)

    def body(carry, i):
        top_d, top_i = carry
        start = i * blk
        cb = jax.lax.dynamic_slice_in_dim(codes_t, start, blk, 1)
        sb = jax.lax.dynamic_slice_in_dim(sq_norm, start, blk, 0)
        lb = jax.lax.dynamic_slice_in_dim(lo, start, blk, 0)
        hb = jax.lax.dynamic_slice_in_dim(hi, start, blk, 0)
        # dequant-free distance: the scale is already folded into w and the
        # offset into cq/sq_norm, so the code block is consumed at its
        # stored width — one (Q, blk) matmul against the cast panel
        dist = (cq[:, None] - 2.0 * (w @ cb.astype(jnp.float32))
                + sb[None, :])
        sel = iv.eval_predicate(mask, lb[None, :], hb[None, :],
                                ql[:, None], qh[:, None])
        dist = jnp.where(sel, dist, jnp.inf)
        ids = (start + arange_b)[None, :]
        cat_d = jnp.concatenate([top_d, dist], axis=1)
        cat_i = jnp.concatenate(
            [top_i, jnp.broadcast_to(ids, (Q, blk)).astype(jnp.int32)], axis=1)
        neg, pos = jax.lax.top_k(-cat_d, R)
        return (-neg, jnp.take_along_axis(cat_i, pos, 1)), None

    top0 = (jnp.full((Q, R), jnp.inf, jnp.float32),
            jnp.full((Q, R), NO_EDGE, jnp.int32))
    (top_d, top_i), _ = jax.lax.scan(body, top0, jnp.arange(nb))
    top_i = jnp.where(jnp.isfinite(top_d), top_i, NO_EDGE)
    return top_i, top_d


@functools.partial(jax.jit, static_argnames=("rerank",))
def topr_from_dists(dists, *, rerank: int):
    """Reduce a full (Q, N) approximate distance matrix (e.g. the Pallas
    int8 kernel output) to the (ids, dists) top-R candidate form."""
    R = min(int(rerank), dists.shape[1])
    neg, idx = jax.lax.top_k(-dists, R)
    ids = jnp.where(jnp.isfinite(neg), idx, NO_EDGE).astype(jnp.int32)
    return ids, -neg


@functools.partial(jax.jit, static_argnames=("k",))
def exact_rerank(queries, cand_vecs, cand_ids, *, k: int):
    """Exact float32 squared L2 over the gathered (Q, R, d) candidate rows,
    then ``top_k(k)``. NO_EDGE candidates rank +inf; ids whose re-ranked
    distance is +inf come back as NO_EDGE (fewer than k qualifiers)."""
    q = queries.astype(jnp.float32)
    diff = cand_vecs.astype(jnp.float32) - q[:, None, :]
    dist = jnp.einsum("qrd,qrd->qr", diff, diff)
    dist = jnp.where(cand_ids >= 0, dist, jnp.inf)
    neg, pos = jax.lax.top_k(-dist, k)
    ids = jnp.where(jnp.isfinite(neg),
                    jnp.take_along_axis(cand_ids, pos, 1), NO_EDGE)
    return ids.astype(jnp.int32), -neg
