"""QueryEngine — the unified execution facade over a built MSTG index.

The canonical entry point is the declarative one::

    result = engine.search(SearchRequest(vectors, (qlo, qhi),
                                         Overlaps() | Before(), k=10))
    result.ids, result.dists, result.valid_mask, result.report

One object owns everything a request needs:

* **device staging** — graph arrays (:class:`repro.core.search.DeviceVariant`)
  and the pruned-scan member arrays are staged exactly once and shared by
  every path;
* **plan execution** — a batch is planned with the vectorized Theorem 4.1
  planner (:func:`repro.core.intervals.plan_batch_ranked`), every task slot is
  executed on its variant, and slot results are merged with
  :func:`repro.core.search.merge_topk`;
* **routing** — ``route="auto"`` estimates predicate selectivity *before any
  device work* from an O(1)-per-query exact rank-prefix table over a fixed
  corpus sample (:class:`repro.core.intervals.SelectivityIndex`; additionally
  memoized per ``(mask, rank-quantized query range)``) and sends
  low-selectivity batches to the exact pruned scan (work ∝ selectivity,
  recall 1.0) and everything else to the wavefront beam search — an
  auto-routed request executes the identical plan as pinning the route it
  selects;
* **wavefront execution** — the graph route resolves ``fanout`` (backend
  heuristic), skips plan slots whose tasks are all empty before dispatch,
  and chunks large batches through
  :func:`repro.core.search.mstg_graph_search_chunked` so converged queries
  are compacted out of the active batch between step slices;
* **jit-cache reuse** — query batches are padded up to power-of-two buckets so
  a serving process sees one trace per (mask, route, k, ef, bucket) instead of
  one per distinct batch size; padded queries carry empty tasks and cost no
  search steps.

Engine-lifetime tuning lives in one typed :class:`EngineConfig` dataclass
(``QueryEngine(index, config=EngineConfig(...))``); per-request knobs live on
the :class:`repro.core.api.SearchRequest`. When both speak to the same knob
the precedence is deterministic and uniform:

    **request wins over config wins over backend heuristic.**

Concretely: ``route`` resolves request → config; ``fanout`` and ``chunk``
resolve request → config → backend heuristic (TPU/CPU frontier width, batch
width chunking); ``ef``/``k``/``max_steps`` are request-only; ``use_kernel``/
``packed_visited``/routing-model constants are config-only.

Every execution returns a :class:`repro.core.api.SearchResult` whose
:class:`repro.core.api.RouteReport` records the chosen route, estimated
selectivity, plan slots, and selectivity-cache traffic. The tuple-era
positional call ``search(queries, qlo, qhi, mask)`` and the
``MSTGSearcher``/``FlatSearcher`` wrappers (deprecated since PR 2) were
removed in PR 6 — see the README migration guide. Bare constructor knobs
(``QueryEngine(index, use_kernel=True)``) still work but are deprecated
shims that warn once and fold into an :class:`EngineConfig`.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
import warnings
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

import jax.numpy as jnp

from repro import obs

from . import intervals as iv
from .api import RouteReport, SearchRequest, SearchResult
from .compressed import compressed_flat_topr, exact_rerank, topr_from_dists
from .flat import _pruned_search_variant, flat_search
from .hnsw import NO_EDGE
from .mstg import MSTGIndex
from .quant import QuantizedStore, check_storage_dtype, maybe_quantize
from .predicates import as_mask
from .search import (DeviceVariant, merge_topk, mstg_graph_search,
                     mstg_graph_search_chunked)

ROUTE_AUTO = "auto"
ROUTE_GRAPH = "graph"
ROUTE_PRUNED = "pruned"
ROUTE_FLAT = "flat"
_ROUTES = (ROUTE_AUTO, ROUTE_GRAPH, ROUTE_PRUNED, ROUTE_FLAT)


def _next_pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


# Deprecated shims (today: bare QueryEngine constructor knobs) warn exactly
# once per process per shim: serving loops that still cross a shim don't spam
# one warning per request, while the first crossing is always visible (and
# fails CI, which escalates DeprecationWarnings attributed to repro.* modules
# to errors).
_DEPRECATION_EMITTED: set = set()


def _warn_deprecated(key: str, message: str, *, stacklevel: int = 2) -> None:
    """Emit ``message`` as a DeprecationWarning once per process per ``key``,
    attributed to the shim's *caller* (``stacklevel`` counts from the shim
    function's own frame, exactly like a direct ``warnings.warn``)."""
    if key in _DEPRECATION_EMITTED:
        return
    _DEPRECATION_EMITTED.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=stacklevel + 1)


def reset_deprecation_warnings() -> None:
    """Forget which deprecation warnings already fired (test isolation)."""
    _DEPRECATION_EMITTED.clear()


def _empty_result(Q: int, k: int) -> Tuple[np.ndarray, np.ndarray]:
    return (np.full((Q, k), NO_EDGE, np.int32),
            np.full((Q, k), np.inf, np.float32))


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine-lifetime tuning for :class:`QueryEngine`, as one typed value.

    This replaces the constructor-knob sprawl (``use_kernel``, ``route``,
    ``graph_fanout``, ...) that accumulated across PRs 1-5: configs validate
    once, travel as a unit (serving fleets, per-shard engines of a
    :class:`repro.distributed.ShardedDeployment`), and derive variants with
    :meth:`replace`. A ``None`` on ``graph_fanout``/``graph_chunk`` means
    *the engine's backend heuristic decides*; a :class:`SearchRequest` field
    overrides both (request wins over config wins over backend heuristic).

    Parameters
    ----------
    use_kernel : bool
        Route distance evaluation through the Pallas kernels.
    route : str
        Default routing policy: ``auto`` | ``graph`` | ``pruned`` | ``flat``.
        A request's ``route`` overrides it per call.
    flat_threshold : float, optional
        ``None`` (default): ``auto`` routes by a work model — the exact
        pruned scan is chosen while its estimated per-query work
        (``mean_selectivity * n`` candidate distances) stays below
        ``route_work_ratio *`` the beam search's (``ef * S``). Pass a float
        for the legacy rule: pruned whenever mean estimated selectivity is
        at or below that fixed fraction of the corpus.
    route_work_ratio : float
        Work-model scan/beam crossover multiplier (only used when
        ``flat_threshold`` is None).
    selectivity_sample : int
        Corpus sample size for the selectivity estimator (whole corpus when
        smaller, making the estimate exact).
    pad_queries : bool
        Pad batches to power-of-two sizes so jit traces are reused across
        ragged serving batches.
    sel_cache_max : int
        Bound on the selectivity memo (FIFO eviction past it).
    graph_fanout : int, optional
        Frontier vertices the wavefront graph search expands per step when a
        request leaves ``fanout=None``. ``None`` (default) picks per
        backend: ``max(1, min(8, ef // 16))`` on TPU (wide steps amortize
        loop latency), 1 elsewhere (per-step op cost dominates).
    graph_chunk : int | "auto" | None
        Steps per compaction slice of the chunked graph driver; between
        slices converged query rows are repacked out of the active batch
        (power-of-two buckets). ``None`` disables chunking (single
        ``lax.while_loop`` to global convergence); ``"auto"`` (default)
        chunks at 16 steps once the padded batch reaches 64 queries — below
        that the per-slice dispatch overhead outweighs the compaction
        savings. Results are bit-identical in every mode. A request's
        ``chunk`` overrides it per call.
    packed_visited : bool
        Use the bit-packed ``(Q, ceil(n/32))`` uint32 visited bitmap (n/8
        bytes per query) instead of the dense ``(Q, n)`` bool reference
        array. Results are bit-identical; the dense path exists for property
        tests and as a fallback.
    trace_sample : float
        Fraction of requests to trace without the caller asking (0.0, the
        default, traces only ``SearchRequest(trace=True)``). Sampling is
        deterministic — every ``round(1/trace_sample)``-th request — so a
        serving process gets a steady trickle of traces on
        ``SearchResult.trace`` rather than a random burst.
    storage_dtype : str, optional
        Vector storage tier the engine *scans*: ``"float32"`` (exact, the
        default), ``"float16"``, or ``"int8"`` (per-dimension affine codes,
        4 bytes/dim -> 1). ``None`` inherits the index's own tier
        (``IndexSpec.storage_dtype``); an explicit value overrides it,
        re-quantizing on the fly when the index was built at a different
        tier. Compressed tiers scan approximate distances over the code
        table and then re-rank the top ``rerank_k`` candidates against the
        exact float32 rows, so end recall is preserved (see README "Vector
        compression"). With a compressed tier the float32 corpus is never
        staged on device — it stays host-side for the re-rank gather.
    rerank_k : int, optional
        How many approximate candidates per query survive to the exact
        float32 re-rank when the storage tier is compressed. ``None``
        (default) uses ``max(4 * k, 32)``; always clamped to
        ``[k, corpus size]`` (and to ``ef`` on the graph route, which can
        never rank more than its pool). Larger values close the recall gap
        at the cost of a wider re-rank gather.
    """

    use_kernel: bool = False
    route: str = ROUTE_AUTO
    flat_threshold: Optional[float] = None
    route_work_ratio: float = 1.0
    selectivity_sample: int = 2048
    pad_queries: bool = True
    sel_cache_max: int = 65536
    graph_fanout: Optional[int] = None
    graph_chunk: Union[int, str, None] = "auto"
    packed_visited: bool = True
    trace_sample: float = 0.0
    storage_dtype: Optional[str] = None
    rerank_k: Optional[int] = None

    def __post_init__(self):
        if self.route not in _ROUTES:
            raise ValueError(f"route must be one of {_ROUTES}, got "
                             f"{self.route!r}")
        if self.graph_fanout is not None and self.graph_fanout < 1:
            raise ValueError("graph_fanout must be >= 1 (or None: backend "
                             f"heuristic), got {self.graph_fanout!r}")
        if not (self.graph_chunk is None or self.graph_chunk == "auto"
                or (isinstance(self.graph_chunk, int)
                    and self.graph_chunk >= 0)):
            raise ValueError("graph_chunk must be an int >= 1, 0/None "
                             "(single-loop driver), or \"auto\", got "
                             f"{self.graph_chunk!r}")
        if self.selectivity_sample < 1:
            raise ValueError("selectivity_sample must be >= 1")
        if self.sel_cache_max < 1:
            raise ValueError("sel_cache_max must be >= 1")
        if not (0.0 <= self.trace_sample <= 1.0):
            raise ValueError("trace_sample must be in [0, 1], got "
                             f"{self.trace_sample!r}")
        if self.storage_dtype is not None:
            check_storage_dtype(self.storage_dtype)
        if self.rerank_k is not None and self.rerank_k < 1:
            raise ValueError("rerank_k must be >= 1 (or None: max(4k, 32)), "
                             f"got {self.rerank_k!r}")

    def replace(self, **overrides) -> "EngineConfig":
        """A copy with ``overrides`` applied (re-validated)."""
        return dataclasses.replace(self, **overrides)


_ENGINE_KNOBS = frozenset(f.name for f in dataclasses.fields(EngineConfig))


class QueryEngine:
    """Unified search facade: plan once, execute on the best engine.

    Parameters
    ----------
    index : MSTGIndex
        Built index; whichever variants it has bound the masks it can serve.
    config : EngineConfig, optional
        Engine-lifetime tuning (kernels, routing policy, wavefront knobs,
        padding, selectivity estimator) — see :class:`EngineConfig` for every
        field. Defaults to ``EngineConfig()``.
    **legacy_knobs
        The pre-config constructor surface (``QueryEngine(index,
        use_kernel=True, graph_chunk=16, ...)``). Deprecated: warns once per
        process and folds the knobs into ``config`` (knobs win over an
        explicitly passed config). New code should construct an
        :class:`EngineConfig`.
    """

    def __init__(self, index: MSTGIndex,
                 config: Optional[EngineConfig] = None, **legacy_knobs):
        if legacy_knobs:
            unknown = sorted(set(legacy_knobs) - _ENGINE_KNOBS)
            if unknown:
                raise TypeError(f"unknown QueryEngine knob(s) {unknown}; "
                                f"valid knobs: {sorted(_ENGINE_KNOBS)}")
            _warn_deprecated(
                "QueryEngine.knobs",
                "bare QueryEngine constructor knobs are deprecated; pass "
                "QueryEngine(index, config=EngineConfig(...))",
                stacklevel=2)
            config = (config or EngineConfig()).replace(**legacy_knobs)
        config = config if config is not None else EngineConfig()
        if not isinstance(config, EngineConfig):
            raise TypeError("config must be an EngineConfig, got "
                            f"{type(config).__name__}")
        self.config = config
        self.index = index
        self.use_kernel = config.use_kernel
        self.default_route = config.route
        self.flat_threshold = (None if config.flat_threshold is None
                               else float(config.flat_threshold))
        self.route_work_ratio = float(config.route_work_ratio)
        self._max_slots = max((fv.nbr.shape[2]
                               for fv in index.variants.values()), default=16)
        self.pad_queries = config.pad_queries
        self.graph_fanout = config.graph_fanout
        self.graph_chunk = config.graph_chunk
        self.packed_visited = bool(config.packed_visited)

        # storage tier: explicit config value wins over the index's own tier.
        # The float32 corpus device copy is lazy (``self.corpus`` property):
        # compressed configurations scan the code table and keep the exact
        # rows host-side for the re-rank gather, so they never stage it.
        sd = check_storage_dtype(config.storage_dtype
                                 or getattr(index.spec, "storage_dtype",
                                            "float32"))
        self.storage_dtype = sd
        store = getattr(index, "storage", None)
        if sd == "float32":
            store = None
        elif store is None or store.dtype != sd:
            store = QuantizedStore.from_vectors(index.vectors, sd)
        self._store: Optional[QuantizedStore] = store
        self._store_dev: Optional[dict] = None
        # router work model: scanning 1-byte codes streams 1/4 the bytes of
        # a float32 scan, so scan work is weighed by the tier's itemsize
        self._scan_cost_ratio = (store.itemsize / 4.0) if store else 1.0
        self._corpus_dev = None
        self.lo = jnp.asarray(index.lo, jnp.float32)
        self.hi = jnp.asarray(index.hi, jnp.float32)
        # per-route device staging is lazy (first use) so graph-only callers
        # never upload pruned member arrays and vice versa
        self._graph_dev: Dict[str, DeviceVariant] = {}
        self._pruned_dev: Dict[str, dict] = {}
        self._sorted_rank: Dict[str, np.ndarray] = {}

        n = index.vectors.shape[0]
        m = min(n, int(config.selectivity_sample))
        sel = (np.arange(n) if m == n
               else np.random.default_rng(0).choice(n, size=m, replace=False))
        self._sample_lo = np.asarray(index.lo)[sel]
        self._sample_hi = np.asarray(index.hi)[sel]
        # O(1)-per-query exact selectivity over the sample via a 2-D rank
        # prefix table — consulted before any device work, so the auto
        # router's cold path costs microseconds, not a sample scan. Falls
        # back to the eval_predicate scan for very large domains.
        dom = index.domain
        self._sel_index: Optional[iv.SelectivityIndex] = None
        if dom.K <= 2048:
            self._sel_index = iv.SelectivityIndex(
                dom.rank(self._sample_lo), dom.rank(self._sample_hi), dom.K)
        self.route_counts: Dict[str, int] = {ROUTE_GRAPH: 0, ROUTE_PRUNED: 0,
                                             ROUTE_FLAT: 0}
        # selectivity memo: (mask, fl, cl, fr, cr) -> sample fraction. The
        # rank signature determines the sample predicate exactly (sample
        # endpoints are domain values), so this is quantization, not change.
        # Bounded FIFO: overflow evicts the oldest entries (dict preserves
        # insertion order), never the whole memo.
        self._sel_cache: Dict[tuple, float] = {}
        self._sel_cache_max = int(config.sel_cache_max)
        self.sel_cache_hits = 0
        self.sel_cache_misses = 0
        self.sel_cache_evictions = 0

        # deterministic trace sampling: every round(1/trace_sample)-th request
        ts = float(config.trace_sample)
        self._trace_every = int(round(1.0 / ts)) if ts > 0 else 0
        self._trace_seq = 0
        # labeled metric children resolved once here so the per-request cost
        # is attribute updates, not name/label lookups
        reg = obs.get_registry()
        req_c = reg.counter("engine_requests_total",
                            "Batch requests executed, by resolved route",
                            labels=("route",))
        qry_c = reg.counter("engine_queries_total",
                            "Individual queries executed, by resolved route",
                            labels=("route",))
        lat_h = reg.histogram("engine_search_ms",
                              "QueryEngine.execute wall time (ms), by route",
                              labels=("route",))
        self._route_metrics = {
            r: (req_c.labels(route=r), qry_c.labels(route=r),
                lat_h.labels(route=r))
            for r in (ROUTE_GRAPH, ROUTE_PRUNED, ROUTE_FLAT)}
        sel_c = reg.counter("engine_sel_cache_total",
                            "Selectivity-memo lookups, by outcome",
                            labels=("outcome",))
        self._m_sel_hit = sel_c.labels(outcome="hit")
        self._m_sel_miss = sel_c.labels(outcome="miss")

    # ---- device staging (lazy, cached per variant) ----
    @property
    def corpus(self) -> jnp.ndarray:
        """Device-staged float32 corpus, uploaded on first use. Compressed
        storage tiers never touch it — the exact rows stay host-side and are
        gathered per-batch for the re-rank."""
        if self._corpus_dev is None:
            self._corpus_dev = jnp.asarray(self.index.vectors, jnp.float32)
        return self._corpus_dev

    def store_dev(self) -> dict:
        """Device-staged quantized store (codes + affine params), lazy.
        ``codes`` is the row-major (n, d) table the gather paths read;
        ``codes_t`` is the contiguous (d, n) panel layout the blocked
        compressed scan consumes (see :func:`compressed_flat_topr`)."""
        if self._store_dev is None:
            st = self._store
            self._store_dev = dict(
                codes=jnp.asarray(st.codes),
                codes_t=jnp.asarray(np.ascontiguousarray(st.codes.T)),
                scale=jnp.asarray(st.scale),
                offset=jnp.asarray(st.offset),
                sq_norm=jnp.asarray(st.sq_norm))
        return self._store_dev

    def graph_dev(self, variant: str) -> DeviceVariant:
        if variant not in self._graph_dev:
            fv = self.index.variants[variant]
            self._graph_dev[variant] = (
                DeviceVariant(fv, None, store=self._store)
                if self._store is not None else DeviceVariant(fv, self.corpus))
        return self._graph_dev[variant]

    def pruned_dev(self, variant: str) -> dict:
        if variant not in self._pruned_dev:
            fv = self.index.variants[variant]
            dev = dict(members=jnp.asarray(fv.members),
                       member_ver=jnp.asarray(fv.member_ver),
                       node_off=jnp.asarray(fv.node_off))
            if self._store is not None:
                sd = self.store_dev()
                dev.update(codes=sd["codes"], code_scale=sd["scale"],
                           code_offset=sd["offset"],
                           code_sq_norm=sd["sq_norm"])
            else:
                dev["vectors"] = self.corpus
            self._pruned_dev[variant] = dev
        return self._pruned_dev[variant]

    def _sorted_sort_rank(self, variant: str) -> np.ndarray:
        if variant not in self._sorted_rank:
            self._sorted_rank[variant] = np.sort(
                self.index.variants[variant].sort_rank)
        return self._sorted_rank[variant]

    # ---- planning / routing ----
    def plan(self, mask: int, qlo: np.ndarray, qhi: np.ndarray) -> List[iv.PlanSlot]:
        return self.index.plan_batch(as_mask(mask), qlo, qhi)

    def estimate_selectivity(self, mask, qlo, qhi) -> np.ndarray:
        """(Q,) estimated fraction of the corpus each query's predicate keeps
        (exact when the sample covers the corpus)."""
        return self._estimate_cached(as_mask(mask), qlo, qhi)[0]

    def _estimate_cached(self, mask: int, qlo, qhi) -> Tuple[np.ndarray, int, int]:
        """Memoized selectivity estimate -> (est (Q,), hits, misses).

        Queries are keyed by their exact rank signature (floor/ceil ranks of
        both endpoints): two float ranges with the same signature select the
        same sample objects, so repeated serving traffic is answered from the
        dict instead of re-evaluating the sample predicate."""
        ql = np.asarray(qlo, np.float64)
        qh = np.asarray(qhi, np.float64)
        dom = self.index.domain
        fl, cl = dom.floor_rank(ql), dom.ceil_rank(ql)
        fr, cr = dom.floor_rank(qh), dom.ceil_rank(qh)
        Q = ql.shape[0]
        out = np.empty(Q, np.float64)
        miss: List[int] = []
        hits = 0
        for i in range(Q):
            v = self._sel_cache.get((mask, fl[i], cl[i], fr[i], cr[i]))
            if v is None:
                miss.append(i)
            else:
                out[i] = v
                hits += 1
        if miss:
            mi = np.asarray(miss)
            if self._sel_index is not None:
                est = self._sel_index.fraction(mask, fl[mi], cl[mi],
                                               fr[mi], cr[mi])
            else:
                hit = iv.eval_predicate(mask, self._sample_lo[None, :],
                                        self._sample_hi[None, :],
                                        ql[mi][:, None], qh[mi][:, None])
                est = np.asarray(hit, np.float64).mean(axis=1)
            for j, i in enumerate(miss):
                v = float(est[j])
                self._sel_cache[(mask, fl[i], cl[i], fr[i], cr[i])] = v
                out[i] = v
            overflow = len(self._sel_cache) - self._sel_cache_max
            if overflow > 0:  # FIFO: drop the oldest entries only
                for key in list(itertools.islice(iter(self._sel_cache),
                                                 overflow)):
                    del self._sel_cache[key]
                self.sel_cache_evictions += overflow
        self.sel_cache_hits += hits
        self.sel_cache_misses += len(miss)
        if hits:
            self._m_sel_hit.inc(hits)
        if miss:
            self._m_sel_miss.inc(len(miss))
        return out, hits, len(miss)

    def _auto_route(self, est: np.ndarray, ef: int = 64) -> str:
        """The one auto-routing rule shared by route_for() and execute().

        With an explicit ``flat_threshold`` this is the legacy fixed-fraction
        rule. The default is a *work model*: the pruned scan evaluates
        ~``est * n`` candidate distances per query while the beam search
        evaluates ~``ef * S`` (S = adjacency slots), so route to the exact
        scan whenever its estimated work is below ``route_work_ratio`` times
        the beam's — at small corpora the scan wins far beyond any fixed 5%
        selectivity cutoff, and at millions of rows the crossover drops to
        fractions of a percent, exactly as it should. Scan work is weighed
        by the storage tier's bytes-per-component (int8 codes stream 1/4 the
        bytes of float32, so the bandwidth-bound scan stays competitive to
        4x the selectivity); the beam gathers the same tier either way."""
        if self.flat_threshold is not None:
            return (ROUTE_PRUNED if float(est.mean()) <= self.flat_threshold
                    else ROUTE_GRAPH)
        scan_work = (float(est.mean()) * self.index.vectors.shape[0]
                     * self._scan_cost_ratio)
        beam_work = float(ef) * self._max_slots
        return (ROUTE_PRUNED if scan_work <= self.route_work_ratio * beam_work
                else ROUTE_GRAPH)

    def route_for(self, mask, qlo, qhi, route: Optional[str] = None,
                  ef: int = 64) -> str:
        """Advisory routing answer. Pass the request's actual ``ef`` — the
        work model weighs beam work by it, so the default (64, matching
        ``SearchRequest``'s default) only mirrors ``execute()`` for requests
        that keep that default."""
        route = route or self.default_route
        if route != ROUTE_AUTO:
            return route
        return self._auto_route(self.estimate_selectivity(mask, qlo, qhi), ef)

    # ---- execution ----
    def search(self, request: SearchRequest, **opts) -> SearchResult:
        """Execute a :class:`repro.core.api.SearchRequest` ->
        :class:`repro.core.api.SearchResult`.

        The tuple-era positional form ``search(queries, qlo, qhi, mask, ...)``
        (deprecated since PR 2) was removed in PR 6 — build a
        ``SearchRequest`` instead (README has the migration table).
        """
        if not isinstance(request, SearchRequest):
            raise TypeError(
                "QueryEngine.search takes a repro.core.SearchRequest; the "
                "tuple-era positional form search(queries, qlo, qhi, mask) "
                "was removed — see the README migration guide")
        if opts:
            raise TypeError(
                f"unexpected search option(s) {sorted(opts)} — per-request "
                "knobs (k, ef, route, ...) go on the SearchRequest")
        return self.execute(request)

    def execute(self, request: SearchRequest) -> SearchResult:
        """Plan, route, and run one request; always returns a SearchResult.

        ``request.trace=True`` (or a hit of ``EngineConfig.trace_sample``)
        records the request's span tree — plan, route decision, per-slot
        execution — onto ``SearchResult.trace``. When this engine runs as a
        shard of a :class:`repro.distributed.ShardedDeployment`, its spans
        join the deployment's trace instead (inner layers never finish an
        outer trace)."""
        requested = request.route or self.default_route
        if requested not in _ROUTES:
            raise ValueError(f"route must be one of {_ROUTES}, got {requested!r}")
        wants_trace = request.trace
        if not wants_trace and self._trace_every:
            self._trace_seq += 1
            wants_trace = (self._trace_seq % self._trace_every) == 0
        tracer = obs.begin_request_trace() if wants_trace else None
        t_exec = time.perf_counter()
        try:
            with obs.span("search") as root:
                root.set("Q", len(request)).set("k", request.k)
                root.set("mask", request.mask).set("requested", requested)
                result = self._execute_routed(request, requested)
        finally:
            trace = obs.end_request_trace(tracer)
        route = result.report.route if result.report is not None else requested
        rm = self._route_metrics.get(route)
        if rm is not None:
            rm[0].inc()
            rm[1].inc(float(len(request)))
            rm[2].record((time.perf_counter() - t_exec) * 1e3)
        if trace is not None:
            result = dataclasses.replace(result, trace=trace)
        return result

    def _execute_routed(self, request: SearchRequest,
                        requested: str) -> SearchResult:
        queries, qlo, qhi = request.vectors, request.qlo, request.qhi
        mask, k = request.mask, request.k
        Q = len(request)
        est = None
        hits = misses = 0
        route = requested
        if requested == ROUTE_AUTO and Q:
            with obs.span("route") as rsp:
                est, hits, misses = self._estimate_cached(mask, qlo, qhi)
                route = self._auto_route(est, request.ef)
                if obs.tracing():
                    rsp.set("chosen", route)
                    rsp.set("est_mean", round(float(est.mean()), 6))
                    rsp.set("cache_hits", hits).set("cache_misses", misses)
        if Q == 0:
            ids, d = _empty_result(0, k)
            return SearchResult(ids, d, RouteReport(
                route=route, requested=requested, est_selectivity=est,
                slot_count=0, variants=()))
        self.route_counts[route] = self.route_counts.get(route, 0) + 1
        with obs.span("plan") as psp:
            slots = (self.plan(mask, qlo, qhi) if route in (ROUTE_GRAPH,
                                                            ROUTE_PRUNED)
                     else [])
            psp.set("slots", len(slots))
        with obs.span(route):
            if route == ROUTE_FLAT:
                ids, d = self._run_flat(queries, qlo, qhi, mask, k)
            elif route == ROUTE_PRUNED:
                ids, d = self._run_pruned(queries, qlo, qhi, mask, k,
                                          slots=slots)
            elif route == ROUTE_GRAPH:
                ids, d = self._run_graph(queries, qlo, qhi, mask, k,
                                         request.ef, request.max_steps,
                                         request.fanout, slots=slots,
                                         chunk=request.chunk)
            else:
                raise ValueError(f"unknown route {route!r}")
            ids, d = np.asarray(ids[:Q]), np.asarray(d[:Q])
        report = RouteReport(route=route, requested=requested,
                             est_selectivity=est, slot_count=len(slots),
                             variants=tuple(s.variant for s in slots),
                             cache_hits=hits, cache_misses=misses)
        return SearchResult(ids, d, report)

    # Convenience fixed-route entry points (legacy tuple returns).
    def search_graph(self, queries, qlo, qhi, mask, k=10, ef=64,
                     max_steps=None, fanout=1):
        req = SearchRequest(queries, (qlo, qhi), mask, k=k, ef=ef,
                            max_steps=max_steps, fanout=fanout,
                            route=ROUTE_GRAPH)
        return self.execute(req).astuple()

    def search_pruned(self, queries, qlo, qhi, mask, k=10, block: int = 256,
                      max_candidates: Optional[int] = None):
        queries = np.ascontiguousarray(queries, np.float32)
        qlo = np.asarray(qlo, np.float64)
        qhi = np.asarray(qhi, np.float64)
        mask = as_mask(mask)
        Q = queries.shape[0]
        if Q == 0:
            return _empty_result(0, k)
        self.route_counts[ROUTE_PRUNED] = self.route_counts.get(ROUTE_PRUNED, 0) + 1
        ids, d = self._run_pruned(queries, qlo, qhi, mask, k, block=block,
                                  max_candidates=max_candidates)
        return np.asarray(ids[:Q]), np.asarray(d[:Q])

    def search_flat(self, queries, qlo, qhi, mask, k=10):
        req = SearchRequest(queries, (qlo, qhi), mask, k=k, route=ROUTE_FLAT)
        return self.execute(req).astuple()

    # ---- internals ----
    def _padded(self, queries: np.ndarray, qlo: np.ndarray, qhi: np.ndarray):
        """Pad the batch to a power-of-two bucket; padded rows use the
        impossible query range [0, -1] so no predicate bit can select them."""
        Q = queries.shape[0]
        if not self.pad_queries:
            return queries, qlo, qhi
        Qp = max(_next_pow2(Q), 8)
        if Qp == Q:
            return queries, qlo, qhi
        pad = Qp - Q
        queries = np.concatenate(
            [queries, np.zeros((pad, queries.shape[1]), np.float32)])
        qlo = np.concatenate([qlo, np.zeros(pad)])
        qhi = np.concatenate([qhi, np.full(pad, -1.0)])
        return queries, qlo, qhi

    def _padded_slots(self, slots: List[iv.PlanSlot], Qp: int) -> List[iv.PlanSlot]:
        """Extend each slot's per-query arrays with empty tasks (version=-1,
        key_lo>key_hi): padded queries start with an empty pool and terminate
        on the first loop-condition check."""
        out = []
        for s in slots:
            pad = Qp - s.version.shape[0]
            if pad <= 0:
                out.append(s)
                continue
            out.append(iv.PlanSlot(
                s.variant,
                np.concatenate([s.version, np.full(pad, -1, np.int64)]),
                np.concatenate([s.key_lo, np.ones(pad, np.int64)]),
                np.concatenate([s.key_hi, np.zeros(pad, np.int64)])))
        return out

    def _resolve_fanout(self, ef: int, fanout: Optional[int]) -> int:
        """Wavefront width: an explicit request value wins, then the engine
        override, then a backend heuristic — on TPU wide steps amortize loop
        latency over fanout x S distance evals (total expansions stay ~ef
        either way); on CPU the per-step op cost grows with the width, so
        the narrow frontier is the fast one."""
        if fanout:
            return max(1, int(fanout))
        if self.graph_fanout:
            return max(1, int(self.graph_fanout))
        import jax
        if jax.default_backend() == "tpu":
            return max(1, min(8, ef // 16))
        return 1

    def _rerank_width(self, k: int, upper: Optional[int] = None) -> int:
        """Approximate candidates per query surviving to the exact re-rank:
        ``rerank_k`` (default ``max(4k, 32)``) clamped to [k, n] and to
        ``upper`` (the graph pool width ``ef``) when given."""
        n = self.index.vectors.shape[0]
        R = self.config.rerank_k or max(4 * k, 32)
        if upper is not None:
            R = min(R, upper)
        return max(k, min(R, n))

    def _rerank_exact(self, qdev, cand_ids, k: int):
        """Exact float32 re-rank of approximate top-R candidate ids: gather
        the exact rows host-side (the f32 corpus is never device-staged on a
        compressed tier) and re-rank on device."""
        cand = np.asarray(cand_ids)
        rows = self.index.vectors[np.clip(cand, 0, None)]
        with obs.span("rerank") as rsp:
            if obs.tracing():
                rsp.set("R", int(cand.shape[1]))
            return exact_rerank(qdev, jnp.asarray(rows), jnp.asarray(cand),
                                k=k)

    def _run_graph(self, queries, qlo, qhi, mask, k, ef, max_steps, fanout,
                   slots: Optional[List[iv.PlanSlot]] = None,
                   chunk: Optional[int] = None):
        if slots is None:
            slots = self.plan(mask, qlo, qhi)
        F = self._resolve_fanout(ef, fanout)
        chunk = chunk if chunk is not None else self.graph_chunk
        queries_p, _, _ = self._padded(queries, qlo, qhi)
        if chunk == "auto":  # compaction pays once the batch is wide enough
            chunk = 16 if queries_p.shape[0] >= 64 else None
        slots = self._padded_slots(slots, queries_p.shape[0])
        steps = max_steps or ((4 * ef + 64) // F + 8)
        qdev = jnp.asarray(queries_p)
        # compressed tier: the beam ranks approximate (dequantized-gather)
        # distances, so carry top-R of the pool through the merge and
        # re-rank exactly at the end. R can't exceed the pool width ef.
        kq = k if self._store is None else self._rerank_width(k, upper=ef)
        res = None
        for s in slots:
            # skip slots where every query's task is empty before any device
            # work (empty tasks produce all-NO_EDGE rows; merging them is a
            # no-op, so skipping is result-identical)
            if not np.any((s.version >= 0) & (s.key_lo <= s.key_hi)):
                continue
            dv = self.graph_dev(s.variant)
            common = dict(k=kq, ef=ef, max_steps=steps, Kpad=dv.meta.Kpad,
                          use_kernel=self.use_kernel, fanout=F,
                          packed=self.packed_visited)
            with obs.span("slot") as ssp:
                ssp.set("variant", s.variant).set("ef", ef).set("fanout", F)
                if chunk and chunk < steps:
                    ssp.set("chunk", int(chunk))
                    ids, d = mstg_graph_search_chunked(
                        dv.tree(), qdev, s.version, s.key_lo, s.key_hi,
                        chunk=int(chunk), **common)
                else:
                    ids, d = mstg_graph_search(
                        dv.tree(), qdev, jnp.asarray(s.version, jnp.int32),
                        jnp.asarray(s.key_lo, jnp.int32),
                        jnp.asarray(s.key_hi, jnp.int32), **common)
            res = (ids, d) if res is None else merge_topk(res[0], res[1], ids,
                                                          d, kq)
        if res is None:
            return _empty_result(queries_p.shape[0], k)
        if self._store is not None:
            return self._rerank_exact(qdev, res[0], k)
        return res

    def _run_pruned(self, queries, qlo, qhi, mask, k, block: int = 256,
                    max_candidates: Optional[int] = None,
                    slots: Optional[List[iv.PlanSlot]] = None):
        if slots is None:
            slots = self.plan(mask, qlo, qhi)
        n = self.index.vectors.shape[0]
        queries_p, qlo_p, qhi_p = self._padded(queries, qlo, qhi)
        slots = self._padded_slots(slots, queries_p.shape[0])
        qdev = jnp.asarray(queries_p)
        qlo_j = jnp.asarray(qlo_p, jnp.float32)
        qhi_j = jnp.asarray(qhi_p, jnp.float32)
        # compressed tier: scan distances are approximate, so keep top-R per
        # slot and through the merge, then re-rank exactly once at the end
        kq = k if self._store is None else self._rerank_width(k)
        res = None
        for s in slots:
            fv = self.index.variants[s.variant]
            # exact candidate upper bound for this slot: objects with
            # sort_rank <= max version (key-range pruning only shrinks it),
            # rounded to a power of two so max_blocks hits the jit cache —
            # never truncates, so the pruned route stays recall-1.0
            if max_candidates is not None:
                cap = min(n, int(max_candidates))
            else:
                hi_ver = int(s.version.max(initial=-1))
                cap = int(np.searchsorted(self._sorted_sort_rank(s.variant),
                                          hi_ver, side="right"))
                cap = min(n, _next_pow2(cap)) if cap else 0
            if cap == 0:
                continue  # every query's task in this slot is empty
            with obs.span("slot") as ssp:
                ssp.set("variant", s.variant).set("candidates", cap)
                ids, d = _pruned_search_variant(
                    self.pruned_dev(s.variant), self.lo, self.hi, qdev,
                    qlo_j, qhi_j, jnp.asarray(s.version, jnp.int32),
                    jnp.asarray(s.key_lo, jnp.int32), jnp.asarray(s.key_hi, jnp.int32),
                    pred_mask_bits=mask, k=kq, Kpad=fv.Kpad, block=block,
                    max_blocks=-(-cap // block))
            res = (ids, d) if res is None else merge_topk(res[0], res[1], ids,
                                                          d, kq)
        if res is None:
            return _empty_result(queries_p.shape[0], k)
        if self._store is not None:
            return self._rerank_exact(qdev, res[0], k)
        return res

    def _run_flat(self, queries, qlo, qhi, mask, k):
        queries_p, qlo_p, qhi_p = self._padded(queries, qlo, qhi)
        qdev = jnp.asarray(queries_p)
        qlo_j = jnp.asarray(qlo_p, jnp.float32)
        qhi_j = jnp.asarray(qhi_p, jnp.float32)
        if self._store is None:
            return flat_search(self.corpus, self.lo, self.hi, qdev,
                               qlo_j, qhi_j,
                               mask=mask, k=k, use_kernel=self.use_kernel)
        sd = self.store_dev()
        R = self._rerank_width(k)
        if self.use_kernel:
            from repro.kernels import ops as kops
            if self._store.dtype == "int8":
                approx = kops.pairwise_l2_int8(
                    qdev, sd["codes"], sd["scale"], sd["offset"],
                    sd["sq_norm"], self.lo, self.hi, qlo_j, qhi_j, mask)
            else:
                # float16 codes are affine-trivial (scale 1, offset 0): the
                # float32 kernel's in-VMEM upcast of the streamed tile is
                # exactly the dequantization
                approx = kops.pairwise_l2_masked(qdev, sd["codes"], self.lo,
                                                 self.hi, qlo_j, qhi_j, mask)
            cand_ids, _ = topr_from_dists(approx, rerank=R)
        else:
            cand_ids, _ = compressed_flat_topr(
                sd["codes_t"], sd["scale"], sd["offset"], sd["sq_norm"],
                self.lo, self.hi, qdev, qlo_j, qhi_j, mask=mask, rerank=R)
        return self._rerank_exact(qdev, cand_ids, k)
