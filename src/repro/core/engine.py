"""QueryEngine — the unified execution facade over a built MSTG index.

One object owns everything a query batch needs:

* **device staging** — graph arrays (:class:`repro.core.search.DeviceVariant`)
  and the pruned-scan member arrays are staged exactly once and shared by
  every path;
* **plan execution** — a batch is planned with the vectorized Theorem 4.1
  planner (:func:`repro.core.intervals.plan_batch_ranked`), every task slot is
  executed on its variant, and slot results are merged with
  :func:`repro.core.search.merge_topk`;
* **routing** — ``route="auto"`` estimates predicate selectivity from a fixed
  corpus sample and sends low-selectivity batches to the exact pruned scan
  (work ∝ selectivity, recall 1.0) and everything else to the TPU beam search;
* **jit-cache reuse** — query batches are padded up to power-of-two buckets so
  a serving process sees one trace per (mask, route, k, ef, bucket) instead of
  one per distinct batch size; padded queries carry empty tasks and cost no
  search steps.

``MSTGSearcher`` (the historical graph-path API) is a thin wrapper kept for
compatibility; new code should use :class:`QueryEngine` directly.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

import jax.numpy as jnp

from . import intervals as iv
from .flat import _pruned_search_variant, flat_search
from .hnsw import NO_EDGE
from .mstg import MSTGIndex
from .search import DeviceVariant, merge_topk, mstg_graph_search

ROUTE_AUTO = "auto"
ROUTE_GRAPH = "graph"
ROUTE_PRUNED = "pruned"
ROUTE_FLAT = "flat"
_ROUTES = (ROUTE_AUTO, ROUTE_GRAPH, ROUTE_PRUNED, ROUTE_FLAT)


def _next_pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


def _empty_result(Q: int, k: int) -> Tuple[np.ndarray, np.ndarray]:
    return (np.full((Q, k), NO_EDGE, np.int32),
            np.full((Q, k), np.inf, np.float32))


class QueryEngine:
    """Unified search facade: plan once, execute on the best engine.

    Parameters
    ----------
    index : MSTGIndex
        Built index; whichever variants it has bound the masks it can serve.
    use_kernel : bool
        Route distance evaluation through the Pallas kernels.
    route : str
        Default routing policy: ``auto`` | ``graph`` | ``pruned`` | ``flat``.
    flat_threshold : float
        ``auto`` sends a batch to the exact pruned scan when its mean
        estimated selectivity is at or below this fraction of the corpus.
    selectivity_sample : int
        Corpus sample size for the selectivity estimator (whole corpus when
        smaller, making the estimate exact).
    pad_queries : bool
        Pad batches to power-of-two sizes so jit traces are reused across
        ragged serving batches.
    """

    def __init__(self, index: MSTGIndex, use_kernel: bool = False,
                 route: str = ROUTE_AUTO, flat_threshold: float = 0.05,
                 selectivity_sample: int = 2048, pad_queries: bool = True):
        if route not in _ROUTES:
            raise ValueError(f"route must be one of {_ROUTES}, got {route!r}")
        self.index = index
        self.use_kernel = use_kernel
        self.default_route = route
        self.flat_threshold = float(flat_threshold)
        self.pad_queries = pad_queries

        self.corpus = jnp.asarray(index.vectors, jnp.float32)
        self.lo = jnp.asarray(index.lo, jnp.float32)
        self.hi = jnp.asarray(index.hi, jnp.float32)
        # per-route device staging is lazy (first use) so graph-only callers
        # never upload pruned member arrays and vice versa
        self._graph_dev: Dict[str, DeviceVariant] = {}
        self._pruned_dev: Dict[str, dict] = {}
        self._sorted_rank: Dict[str, np.ndarray] = {}

        n = index.vectors.shape[0]
        m = min(n, int(selectivity_sample))
        sel = (np.arange(n) if m == n
               else np.random.default_rng(0).choice(n, size=m, replace=False))
        self._sample_lo = np.asarray(index.lo)[sel]
        self._sample_hi = np.asarray(index.hi)[sel]
        self.route_counts: Dict[str, int] = {ROUTE_GRAPH: 0, ROUTE_PRUNED: 0,
                                             ROUTE_FLAT: 0}

    # ---- device staging (lazy, cached per variant) ----
    def graph_dev(self, variant: str) -> DeviceVariant:
        if variant not in self._graph_dev:
            self._graph_dev[variant] = DeviceVariant(
                self.index.variants[variant], self.corpus)
        return self._graph_dev[variant]

    def pruned_dev(self, variant: str) -> dict:
        if variant not in self._pruned_dev:
            fv = self.index.variants[variant]
            self._pruned_dev[variant] = dict(
                vectors=self.corpus,
                members=jnp.asarray(fv.members),
                member_ver=jnp.asarray(fv.member_ver),
                node_off=jnp.asarray(fv.node_off))
        return self._pruned_dev[variant]

    def _sorted_sort_rank(self, variant: str) -> np.ndarray:
        if variant not in self._sorted_rank:
            self._sorted_rank[variant] = np.sort(
                self.index.variants[variant].sort_rank)
        return self._sorted_rank[variant]

    # ---- planning / routing ----
    def plan(self, mask: int, qlo: np.ndarray, qhi: np.ndarray) -> List[iv.PlanSlot]:
        return self.index.plan_batch(mask, qlo, qhi)

    def estimate_selectivity(self, mask: int, qlo, qhi) -> np.ndarray:
        """(Q,) estimated fraction of the corpus each query's predicate keeps
        (exact when the sample covers the corpus)."""
        ql = np.asarray(qlo, np.float64)[:, None]
        qh = np.asarray(qhi, np.float64)[:, None]
        hit = iv.eval_predicate(mask, self._sample_lo[None, :],
                                self._sample_hi[None, :], ql, qh)
        return np.asarray(hit, np.float64).mean(axis=1)

    def route_for(self, mask: int, qlo, qhi, route: Optional[str] = None) -> str:
        route = route or self.default_route
        if route != ROUTE_AUTO:
            return route
        est = self.estimate_selectivity(mask, qlo, qhi)
        return ROUTE_PRUNED if float(est.mean()) <= self.flat_threshold else ROUTE_GRAPH

    # ---- execution ----
    def search(self, queries: np.ndarray, qlo: np.ndarray, qhi: np.ndarray,
               mask: int, k: int = 10, ef: int = 64,
               max_steps: Optional[int] = None, fanout: int = 1,
               route: Optional[str] = None) -> Tuple[np.ndarray, np.ndarray]:
        """Filtered top-k for a query batch: (Q, k) ids (NO_EDGE pad) and
        squared distances (+inf pad)."""
        queries = np.ascontiguousarray(queries, np.float32)
        qlo = np.asarray(qlo, np.float64)
        qhi = np.asarray(qhi, np.float64)
        Q = queries.shape[0]
        if Q == 0:
            return _empty_result(0, k)
        route = self.route_for(mask, qlo, qhi, route)
        self.route_counts[route] = self.route_counts.get(route, 0) + 1
        if route == ROUTE_FLAT:
            ids, d = self._run_flat(queries, qlo, qhi, mask, k)
        elif route == ROUTE_PRUNED:
            ids, d = self._run_pruned(queries, qlo, qhi, mask, k)
        elif route == ROUTE_GRAPH:
            ids, d = self._run_graph(queries, qlo, qhi, mask, k, ef,
                                     max_steps, fanout)
        else:
            raise ValueError(f"unknown route {route!r}")
        return np.asarray(ids[:Q]), np.asarray(d[:Q])

    # Convenience fixed-route entry points.
    def search_graph(self, queries, qlo, qhi, mask, k=10, ef=64,
                     max_steps=None, fanout=1):
        return self.search(queries, qlo, qhi, mask, k=k, ef=ef,
                           max_steps=max_steps, fanout=fanout,
                           route=ROUTE_GRAPH)

    def search_pruned(self, queries, qlo, qhi, mask, k=10, block: int = 256,
                      max_candidates: Optional[int] = None):
        queries = np.ascontiguousarray(queries, np.float32)
        qlo = np.asarray(qlo, np.float64)
        qhi = np.asarray(qhi, np.float64)
        Q = queries.shape[0]
        if Q == 0:
            return _empty_result(0, k)
        self.route_counts[ROUTE_PRUNED] = self.route_counts.get(ROUTE_PRUNED, 0) + 1
        ids, d = self._run_pruned(queries, qlo, qhi, mask, k, block=block,
                                  max_candidates=max_candidates)
        return np.asarray(ids[:Q]), np.asarray(d[:Q])

    def search_flat(self, queries, qlo, qhi, mask, k=10):
        return self.search(queries, qlo, qhi, mask, k=k, route=ROUTE_FLAT)

    # ---- internals ----
    def _padded(self, queries: np.ndarray, qlo: np.ndarray, qhi: np.ndarray):
        """Pad the batch to a power-of-two bucket; padded rows use the
        impossible query range [0, -1] so no predicate bit can select them."""
        Q = queries.shape[0]
        if not self.pad_queries:
            return queries, qlo, qhi
        Qp = max(_next_pow2(Q), 8)
        if Qp == Q:
            return queries, qlo, qhi
        pad = Qp - Q
        queries = np.concatenate(
            [queries, np.zeros((pad, queries.shape[1]), np.float32)])
        qlo = np.concatenate([qlo, np.zeros(pad)])
        qhi = np.concatenate([qhi, np.full(pad, -1.0)])
        return queries, qlo, qhi

    def _padded_slots(self, slots: List[iv.PlanSlot], Qp: int) -> List[iv.PlanSlot]:
        """Extend each slot's per-query arrays with empty tasks (version=-1,
        key_lo>key_hi): padded queries start with an empty pool and terminate
        on the first loop-condition check."""
        out = []
        for s in slots:
            pad = Qp - s.version.shape[0]
            if pad <= 0:
                out.append(s)
                continue
            out.append(iv.PlanSlot(
                s.variant,
                np.concatenate([s.version, np.full(pad, -1, np.int64)]),
                np.concatenate([s.key_lo, np.ones(pad, np.int64)]),
                np.concatenate([s.key_hi, np.zeros(pad, np.int64)])))
        return out

    def _run_graph(self, queries, qlo, qhi, mask, k, ef, max_steps, fanout):
        slots = self.plan(mask, qlo, qhi)
        queries_p, _, _ = self._padded(queries, qlo, qhi)
        slots = self._padded_slots(slots, queries_p.shape[0])
        steps = max_steps or ((4 * ef + 64) // max(fanout, 1) + 8)
        qdev = jnp.asarray(queries_p)
        res = None
        for s in slots:
            dv = self.graph_dev(s.variant)
            ids, d = mstg_graph_search(
                dv.tree(), qdev, jnp.asarray(s.version, jnp.int32),
                jnp.asarray(s.key_lo, jnp.int32),
                jnp.asarray(s.key_hi, jnp.int32),
                k=k, ef=ef, max_steps=steps, Kpad=dv.meta.Kpad,
                use_kernel=self.use_kernel, fanout=fanout)
            res = (ids, d) if res is None else merge_topk(res[0], res[1], ids, d, k)
        if res is None:
            return _empty_result(queries_p.shape[0], k)
        return res

    def _run_pruned(self, queries, qlo, qhi, mask, k, block: int = 256,
                    max_candidates: Optional[int] = None):
        slots = self.plan(mask, qlo, qhi)
        n = self.index.vectors.shape[0]
        queries_p, qlo_p, qhi_p = self._padded(queries, qlo, qhi)
        slots = self._padded_slots(slots, queries_p.shape[0])
        qdev = jnp.asarray(queries_p)
        qlo_j = jnp.asarray(qlo_p, jnp.float32)
        qhi_j = jnp.asarray(qhi_p, jnp.float32)
        res = None
        for s in slots:
            fv = self.index.variants[s.variant]
            # exact candidate upper bound for this slot: objects with
            # sort_rank <= max version (key-range pruning only shrinks it),
            # rounded to a power of two so max_blocks hits the jit cache —
            # never truncates, so the pruned route stays recall-1.0
            if max_candidates is not None:
                cap = min(n, int(max_candidates))
            else:
                hi_ver = int(s.version.max(initial=-1))
                cap = int(np.searchsorted(self._sorted_sort_rank(s.variant),
                                          hi_ver, side="right"))
                cap = min(n, _next_pow2(cap)) if cap else 0
            if cap == 0:
                continue  # every query's task in this slot is empty
            ids, d = _pruned_search_variant(
                self.pruned_dev(s.variant), self.lo, self.hi, qdev,
                qlo_j, qhi_j, jnp.asarray(s.version, jnp.int32),
                jnp.asarray(s.key_lo, jnp.int32), jnp.asarray(s.key_hi, jnp.int32),
                pred_mask_bits=mask, k=k, Kpad=fv.Kpad, block=block,
                max_blocks=-(-cap // block))
            res = (ids, d) if res is None else merge_topk(res[0], res[1], ids, d, k)
        if res is None:
            return _empty_result(queries_p.shape[0], k)
        return res

    def _run_flat(self, queries, qlo, qhi, mask, k):
        queries_p, qlo_p, qhi_p = self._padded(queries, qlo, qhi)
        return flat_search(self.corpus, self.lo, self.hi, jnp.asarray(queries_p),
                           jnp.asarray(qlo_p, jnp.float32),
                           jnp.asarray(qhi_p, jnp.float32),
                           mask=mask, k=k, use_kernel=self.use_kernel)


class MSTGSearcher:
    """Compatibility wrapper: the historical graph-path API, now a fixed-route
    view over :class:`QueryEngine`."""

    def __init__(self, index: MSTGIndex, use_kernel: bool = False,
                 engine: Optional[QueryEngine] = None):
        self.index = index
        self.use_kernel = use_kernel
        self.engine = engine or QueryEngine(index, use_kernel=use_kernel,
                                            route=ROUTE_GRAPH)

    def search(self, queries, qlo, qhi, mask, k: int = 10, ef: int = 64,
               max_steps: Optional[int] = None, fanout: int = 1
               ) -> Tuple[np.ndarray, np.ndarray]:
        return self.engine.search_graph(queries, qlo, qhi, mask, k=k, ef=ef,
                                        max_steps=max_steps, fanout=fanout)


class FlatSearcher:
    """Compatibility wrapper: the exact engines (full brute force + tree-pruned
    scan) as a fixed-route view over :class:`QueryEngine`."""

    def __init__(self, index: MSTGIndex, use_kernel: bool = False,
                 engine: Optional[QueryEngine] = None):
        self.index = index
        self.use_kernel = use_kernel
        self.engine = engine or QueryEngine(index, use_kernel=use_kernel,
                                            route=ROUTE_FLAT)

    def search(self, queries, qlo, qhi, mask: int, k: int = 10):
        """Full-corpus fused brute force (ground-truth grade)."""
        return self.engine.search_flat(queries, qlo, qhi, mask, k=k)

    def search_pruned(self, queries, qlo, qhi, mask: int, k: int = 10,
                      block: int = 256, max_candidates: Optional[int] = None):
        """Tree-pruned exact search: work ∝ selectivity."""
        return self.engine.search_pruned(queries, qlo, qhi, mask, k=k,
                                         block=block,
                                         max_candidates=max_candidates)
