"""QueryEngine — the unified execution facade over a built MSTG index.

The canonical entry point is the declarative one::

    result = engine.search(SearchRequest(vectors, (qlo, qhi),
                                         Overlaps() | Before(), k=10))
    result.ids, result.dists, result.valid_mask, result.report

One object owns everything a request needs:

* **device staging** — graph arrays (:class:`repro.core.search.DeviceVariant`)
  and the pruned-scan member arrays are staged exactly once and shared by
  every path;
* **plan execution** — a batch is planned with the vectorized Theorem 4.1
  planner (:func:`repro.core.intervals.plan_batch_ranked`), every task slot is
  executed on its variant, and slot results are merged with
  :func:`repro.core.search.merge_topk`;
* **routing** — ``route="auto"`` estimates predicate selectivity from a fixed
  corpus sample (memoized per ``(mask, rank-quantized query range)`` so
  repeated serving traffic never re-evaluates the sample predicate) and sends
  low-selectivity batches to the exact pruned scan (work ∝ selectivity,
  recall 1.0) and everything else to the TPU beam search;
* **jit-cache reuse** — query batches are padded up to power-of-two buckets so
  a serving process sees one trace per (mask, route, k, ef, bucket) instead of
  one per distinct batch size; padded queries carry empty tasks and cost no
  search steps.

Every execution returns a :class:`repro.core.api.SearchResult` whose
:class:`repro.core.api.RouteReport` records the chosen route, estimated
selectivity, plan slots, and selectivity-cache traffic. The tuple-era
positional call ``search(queries, qlo, qhi, mask)`` and the
``MSTGSearcher``/``FlatSearcher`` wrappers still work but are deprecated
shims over this surface.
"""
from __future__ import annotations

import itertools
import warnings
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

import jax.numpy as jnp

from . import intervals as iv
from .api import RouteReport, SearchRequest, SearchResult
from .flat import _pruned_search_variant, flat_search
from .hnsw import NO_EDGE
from .mstg import MSTGIndex
from .predicates import as_mask
from .search import DeviceVariant, merge_topk, mstg_graph_search

ROUTE_AUTO = "auto"
ROUTE_GRAPH = "graph"
ROUTE_PRUNED = "pruned"
ROUTE_FLAT = "flat"
_ROUTES = (ROUTE_AUTO, ROUTE_GRAPH, ROUTE_PRUNED, ROUTE_FLAT)


def _next_pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


# Deprecated tuple-API shims warn exactly once per process per shim: serving
# loops that still cross a shim don't spam one warning per request, while the
# first crossing is always visible (and fails CI, which escalates
# DeprecationWarnings attributed to repro.* modules to errors).
_DEPRECATION_EMITTED: set = set()


def _warn_deprecated(key: str, message: str, *, stacklevel: int = 2) -> None:
    """Emit ``message`` as a DeprecationWarning once per process per ``key``,
    attributed to the shim's *caller* (``stacklevel`` counts from the shim
    function's own frame, exactly like a direct ``warnings.warn``)."""
    if key in _DEPRECATION_EMITTED:
        return
    _DEPRECATION_EMITTED.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=stacklevel + 1)


def reset_deprecation_warnings() -> None:
    """Forget which deprecation warnings already fired (test isolation)."""
    _DEPRECATION_EMITTED.clear()


def _empty_result(Q: int, k: int) -> Tuple[np.ndarray, np.ndarray]:
    return (np.full((Q, k), NO_EDGE, np.int32),
            np.full((Q, k), np.inf, np.float32))


class QueryEngine:
    """Unified search facade: plan once, execute on the best engine.

    Parameters
    ----------
    index : MSTGIndex
        Built index; whichever variants it has bound the masks it can serve.
    use_kernel : bool
        Route distance evaluation through the Pallas kernels.
    route : str
        Default routing policy: ``auto`` | ``graph`` | ``pruned`` | ``flat``.
    flat_threshold : float
        ``auto`` sends a batch to the exact pruned scan when its mean
        estimated selectivity is at or below this fraction of the corpus.
    selectivity_sample : int
        Corpus sample size for the selectivity estimator (whole corpus when
        smaller, making the estimate exact).
    pad_queries : bool
        Pad batches to power-of-two sizes so jit traces are reused across
        ragged serving batches.
    """

    def __init__(self, index: MSTGIndex, use_kernel: bool = False,
                 route: str = ROUTE_AUTO, flat_threshold: float = 0.05,
                 selectivity_sample: int = 2048, pad_queries: bool = True,
                 sel_cache_max: int = 65536):
        if route not in _ROUTES:
            raise ValueError(f"route must be one of {_ROUTES}, got {route!r}")
        self.index = index
        self.use_kernel = use_kernel
        self.default_route = route
        self.flat_threshold = float(flat_threshold)
        self.pad_queries = pad_queries

        self.corpus = jnp.asarray(index.vectors, jnp.float32)
        self.lo = jnp.asarray(index.lo, jnp.float32)
        self.hi = jnp.asarray(index.hi, jnp.float32)
        # per-route device staging is lazy (first use) so graph-only callers
        # never upload pruned member arrays and vice versa
        self._graph_dev: Dict[str, DeviceVariant] = {}
        self._pruned_dev: Dict[str, dict] = {}
        self._sorted_rank: Dict[str, np.ndarray] = {}

        n = index.vectors.shape[0]
        m = min(n, int(selectivity_sample))
        sel = (np.arange(n) if m == n
               else np.random.default_rng(0).choice(n, size=m, replace=False))
        self._sample_lo = np.asarray(index.lo)[sel]
        self._sample_hi = np.asarray(index.hi)[sel]
        self.route_counts: Dict[str, int] = {ROUTE_GRAPH: 0, ROUTE_PRUNED: 0,
                                             ROUTE_FLAT: 0}
        # selectivity memo: (mask, fl, cl, fr, cr) -> sample fraction. The
        # rank signature determines the sample predicate exactly (sample
        # endpoints are domain values), so this is quantization, not change.
        # Bounded FIFO: overflow evicts the oldest entries (dict preserves
        # insertion order), never the whole memo.
        self._sel_cache: Dict[tuple, float] = {}
        self._sel_cache_max = int(sel_cache_max)
        self.sel_cache_hits = 0
        self.sel_cache_misses = 0
        self.sel_cache_evictions = 0

    # ---- device staging (lazy, cached per variant) ----
    def graph_dev(self, variant: str) -> DeviceVariant:
        if variant not in self._graph_dev:
            self._graph_dev[variant] = DeviceVariant(
                self.index.variants[variant], self.corpus)
        return self._graph_dev[variant]

    def pruned_dev(self, variant: str) -> dict:
        if variant not in self._pruned_dev:
            fv = self.index.variants[variant]
            self._pruned_dev[variant] = dict(
                vectors=self.corpus,
                members=jnp.asarray(fv.members),
                member_ver=jnp.asarray(fv.member_ver),
                node_off=jnp.asarray(fv.node_off))
        return self._pruned_dev[variant]

    def _sorted_sort_rank(self, variant: str) -> np.ndarray:
        if variant not in self._sorted_rank:
            self._sorted_rank[variant] = np.sort(
                self.index.variants[variant].sort_rank)
        return self._sorted_rank[variant]

    # ---- planning / routing ----
    def plan(self, mask: int, qlo: np.ndarray, qhi: np.ndarray) -> List[iv.PlanSlot]:
        return self.index.plan_batch(as_mask(mask), qlo, qhi)

    def estimate_selectivity(self, mask, qlo, qhi) -> np.ndarray:
        """(Q,) estimated fraction of the corpus each query's predicate keeps
        (exact when the sample covers the corpus)."""
        return self._estimate_cached(as_mask(mask), qlo, qhi)[0]

    def _estimate_cached(self, mask: int, qlo, qhi) -> Tuple[np.ndarray, int, int]:
        """Memoized selectivity estimate -> (est (Q,), hits, misses).

        Queries are keyed by their exact rank signature (floor/ceil ranks of
        both endpoints): two float ranges with the same signature select the
        same sample objects, so repeated serving traffic is answered from the
        dict instead of re-evaluating the sample predicate."""
        ql = np.asarray(qlo, np.float64)
        qh = np.asarray(qhi, np.float64)
        dom = self.index.domain
        fl, cl = dom.floor_rank(ql), dom.ceil_rank(ql)
        fr, cr = dom.floor_rank(qh), dom.ceil_rank(qh)
        Q = ql.shape[0]
        out = np.empty(Q, np.float64)
        miss: List[int] = []
        hits = 0
        for i in range(Q):
            v = self._sel_cache.get((mask, fl[i], cl[i], fr[i], cr[i]))
            if v is None:
                miss.append(i)
            else:
                out[i] = v
                hits += 1
        if miss:
            mi = np.asarray(miss)
            hit = iv.eval_predicate(mask, self._sample_lo[None, :],
                                    self._sample_hi[None, :],
                                    ql[mi][:, None], qh[mi][:, None])
            est = np.asarray(hit, np.float64).mean(axis=1)
            for j, i in enumerate(miss):
                v = float(est[j])
                self._sel_cache[(mask, fl[i], cl[i], fr[i], cr[i])] = v
                out[i] = v
            overflow = len(self._sel_cache) - self._sel_cache_max
            if overflow > 0:  # FIFO: drop the oldest entries only
                for key in list(itertools.islice(iter(self._sel_cache),
                                                 overflow)):
                    del self._sel_cache[key]
                self.sel_cache_evictions += overflow
        self.sel_cache_hits += hits
        self.sel_cache_misses += len(miss)
        return out, hits, len(miss)

    def _auto_route(self, est: np.ndarray) -> str:
        """The one auto-routing rule shared by route_for() and execute()."""
        return (ROUTE_PRUNED if float(est.mean()) <= self.flat_threshold
                else ROUTE_GRAPH)

    def route_for(self, mask, qlo, qhi, route: Optional[str] = None) -> str:
        route = route or self.default_route
        if route != ROUTE_AUTO:
            return route
        return self._auto_route(self.estimate_selectivity(mask, qlo, qhi))

    # ---- execution ----
    def search(self, request: Union[SearchRequest, np.ndarray],
               qlo: Optional[np.ndarray] = None,
               qhi: Optional[np.ndarray] = None, mask: Optional[int] = None,
               k: int = 10, ef: int = 64, max_steps: Optional[int] = None,
               fanout: int = 1, route: Optional[str] = None):
        """Execute a :class:`repro.core.api.SearchRequest` ->
        :class:`repro.core.api.SearchResult`.

        The tuple-era positional form ``search(queries, qlo, qhi, mask, ...)``
        still works — it returns the bare ``(ids, dists)`` pair — but is
        deprecated; build a ``SearchRequest`` instead.
        """
        if isinstance(request, SearchRequest):
            if (qlo is not None or qhi is not None or mask is not None
                    or k != 10 or ef != 64 or max_steps is not None
                    or fanout != 1 or route is not None):
                raise TypeError(
                    "options must be set on the SearchRequest itself; "
                    "extra search() arguments would be silently ignored")
            return self.execute(request)
        _warn_deprecated(
            "QueryEngine.search",
            "QueryEngine.search(queries, qlo, qhi, mask) is deprecated; pass "
            "a repro.core.SearchRequest (returns a SearchResult)",
            stacklevel=2)
        if qlo is None or qhi is None or mask is None:
            raise TypeError("legacy QueryEngine.search() requires queries, "
                            "qlo, qhi, and mask")
        req = SearchRequest(request, (qlo, qhi), mask, k=k, ef=ef, route=route,
                            max_steps=max_steps, fanout=fanout)
        return self.execute(req).astuple()

    def execute(self, request: SearchRequest) -> SearchResult:
        """Plan, route, and run one request; always returns a SearchResult."""
        queries, qlo, qhi = request.vectors, request.qlo, request.qhi
        mask, k = request.mask, request.k
        Q = len(request)
        requested = request.route or self.default_route
        if requested not in _ROUTES:
            raise ValueError(f"route must be one of {_ROUTES}, got {requested!r}")
        est = None
        hits = misses = 0
        route = requested
        if requested == ROUTE_AUTO and Q:
            est, hits, misses = self._estimate_cached(mask, qlo, qhi)
            route = self._auto_route(est)
        if Q == 0:
            ids, d = _empty_result(0, k)
            return SearchResult(ids, d, RouteReport(
                route=route, requested=requested, est_selectivity=est,
                slot_count=0, variants=()))
        self.route_counts[route] = self.route_counts.get(route, 0) + 1
        slots = (self.plan(mask, qlo, qhi) if route in (ROUTE_GRAPH,
                                                        ROUTE_PRUNED) else [])
        if route == ROUTE_FLAT:
            ids, d = self._run_flat(queries, qlo, qhi, mask, k)
        elif route == ROUTE_PRUNED:
            ids, d = self._run_pruned(queries, qlo, qhi, mask, k, slots=slots)
        elif route == ROUTE_GRAPH:
            ids, d = self._run_graph(queries, qlo, qhi, mask, k, request.ef,
                                     request.max_steps, request.fanout,
                                     slots=slots)
        else:
            raise ValueError(f"unknown route {route!r}")
        report = RouteReport(route=route, requested=requested,
                             est_selectivity=est, slot_count=len(slots),
                             variants=tuple(s.variant for s in slots),
                             cache_hits=hits, cache_misses=misses)
        return SearchResult(np.asarray(ids[:Q]), np.asarray(d[:Q]), report)

    # Convenience fixed-route entry points (legacy tuple returns).
    def search_graph(self, queries, qlo, qhi, mask, k=10, ef=64,
                     max_steps=None, fanout=1):
        req = SearchRequest(queries, (qlo, qhi), mask, k=k, ef=ef,
                            max_steps=max_steps, fanout=fanout,
                            route=ROUTE_GRAPH)
        return self.execute(req).astuple()

    def search_pruned(self, queries, qlo, qhi, mask, k=10, block: int = 256,
                      max_candidates: Optional[int] = None):
        queries = np.ascontiguousarray(queries, np.float32)
        qlo = np.asarray(qlo, np.float64)
        qhi = np.asarray(qhi, np.float64)
        mask = as_mask(mask)
        Q = queries.shape[0]
        if Q == 0:
            return _empty_result(0, k)
        self.route_counts[ROUTE_PRUNED] = self.route_counts.get(ROUTE_PRUNED, 0) + 1
        ids, d = self._run_pruned(queries, qlo, qhi, mask, k, block=block,
                                  max_candidates=max_candidates)
        return np.asarray(ids[:Q]), np.asarray(d[:Q])

    def search_flat(self, queries, qlo, qhi, mask, k=10):
        req = SearchRequest(queries, (qlo, qhi), mask, k=k, route=ROUTE_FLAT)
        return self.execute(req).astuple()

    # ---- internals ----
    def _padded(self, queries: np.ndarray, qlo: np.ndarray, qhi: np.ndarray):
        """Pad the batch to a power-of-two bucket; padded rows use the
        impossible query range [0, -1] so no predicate bit can select them."""
        Q = queries.shape[0]
        if not self.pad_queries:
            return queries, qlo, qhi
        Qp = max(_next_pow2(Q), 8)
        if Qp == Q:
            return queries, qlo, qhi
        pad = Qp - Q
        queries = np.concatenate(
            [queries, np.zeros((pad, queries.shape[1]), np.float32)])
        qlo = np.concatenate([qlo, np.zeros(pad)])
        qhi = np.concatenate([qhi, np.full(pad, -1.0)])
        return queries, qlo, qhi

    def _padded_slots(self, slots: List[iv.PlanSlot], Qp: int) -> List[iv.PlanSlot]:
        """Extend each slot's per-query arrays with empty tasks (version=-1,
        key_lo>key_hi): padded queries start with an empty pool and terminate
        on the first loop-condition check."""
        out = []
        for s in slots:
            pad = Qp - s.version.shape[0]
            if pad <= 0:
                out.append(s)
                continue
            out.append(iv.PlanSlot(
                s.variant,
                np.concatenate([s.version, np.full(pad, -1, np.int64)]),
                np.concatenate([s.key_lo, np.ones(pad, np.int64)]),
                np.concatenate([s.key_hi, np.zeros(pad, np.int64)])))
        return out

    def _run_graph(self, queries, qlo, qhi, mask, k, ef, max_steps, fanout,
                   slots: Optional[List[iv.PlanSlot]] = None):
        if slots is None:
            slots = self.plan(mask, qlo, qhi)
        queries_p, _, _ = self._padded(queries, qlo, qhi)
        slots = self._padded_slots(slots, queries_p.shape[0])
        steps = max_steps or ((4 * ef + 64) // max(fanout, 1) + 8)
        qdev = jnp.asarray(queries_p)
        res = None
        for s in slots:
            dv = self.graph_dev(s.variant)
            ids, d = mstg_graph_search(
                dv.tree(), qdev, jnp.asarray(s.version, jnp.int32),
                jnp.asarray(s.key_lo, jnp.int32),
                jnp.asarray(s.key_hi, jnp.int32),
                k=k, ef=ef, max_steps=steps, Kpad=dv.meta.Kpad,
                use_kernel=self.use_kernel, fanout=fanout)
            res = (ids, d) if res is None else merge_topk(res[0], res[1], ids, d, k)
        if res is None:
            return _empty_result(queries_p.shape[0], k)
        return res

    def _run_pruned(self, queries, qlo, qhi, mask, k, block: int = 256,
                    max_candidates: Optional[int] = None,
                    slots: Optional[List[iv.PlanSlot]] = None):
        if slots is None:
            slots = self.plan(mask, qlo, qhi)
        n = self.index.vectors.shape[0]
        queries_p, qlo_p, qhi_p = self._padded(queries, qlo, qhi)
        slots = self._padded_slots(slots, queries_p.shape[0])
        qdev = jnp.asarray(queries_p)
        qlo_j = jnp.asarray(qlo_p, jnp.float32)
        qhi_j = jnp.asarray(qhi_p, jnp.float32)
        res = None
        for s in slots:
            fv = self.index.variants[s.variant]
            # exact candidate upper bound for this slot: objects with
            # sort_rank <= max version (key-range pruning only shrinks it),
            # rounded to a power of two so max_blocks hits the jit cache —
            # never truncates, so the pruned route stays recall-1.0
            if max_candidates is not None:
                cap = min(n, int(max_candidates))
            else:
                hi_ver = int(s.version.max(initial=-1))
                cap = int(np.searchsorted(self._sorted_sort_rank(s.variant),
                                          hi_ver, side="right"))
                cap = min(n, _next_pow2(cap)) if cap else 0
            if cap == 0:
                continue  # every query's task in this slot is empty
            ids, d = _pruned_search_variant(
                self.pruned_dev(s.variant), self.lo, self.hi, qdev,
                qlo_j, qhi_j, jnp.asarray(s.version, jnp.int32),
                jnp.asarray(s.key_lo, jnp.int32), jnp.asarray(s.key_hi, jnp.int32),
                pred_mask_bits=mask, k=k, Kpad=fv.Kpad, block=block,
                max_blocks=-(-cap // block))
            res = (ids, d) if res is None else merge_topk(res[0], res[1], ids, d, k)
        if res is None:
            return _empty_result(queries_p.shape[0], k)
        return res

    def _run_flat(self, queries, qlo, qhi, mask, k):
        queries_p, qlo_p, qhi_p = self._padded(queries, qlo, qhi)
        return flat_search(self.corpus, self.lo, self.hi, jnp.asarray(queries_p),
                           jnp.asarray(qlo_p, jnp.float32),
                           jnp.asarray(qhi_p, jnp.float32),
                           mask=mask, k=k, use_kernel=self.use_kernel)


class MSTGSearcher:
    """Deprecated compatibility wrapper: the historical tuple-returning
    graph-path API, now a fixed-route view over :class:`QueryEngine`. New
    code should call ``QueryEngine.search(SearchRequest(...))``."""

    def __init__(self, index: MSTGIndex, use_kernel: bool = False,
                 engine: Optional[QueryEngine] = None):
        _warn_deprecated(
            "MSTGSearcher",
            "MSTGSearcher is deprecated; use QueryEngine with a "
            "SearchRequest(route='graph')", stacklevel=2)
        self.index = index
        self.use_kernel = use_kernel
        self.engine = engine or QueryEngine(index, use_kernel=use_kernel,
                                            route=ROUTE_GRAPH)

    def search(self, queries, qlo, qhi, mask, k: int = 10, ef: int = 64,
               max_steps: Optional[int] = None, fanout: int = 1
               ) -> Tuple[np.ndarray, np.ndarray]:
        return self.engine.search_graph(queries, qlo, qhi, mask, k=k, ef=ef,
                                        max_steps=max_steps, fanout=fanout)


class FlatSearcher:
    """Deprecated compatibility wrapper: the tuple-returning exact engines
    (full brute force + tree-pruned scan) as a fixed-route view over
    :class:`QueryEngine`. New code should call
    ``QueryEngine.search(SearchRequest(route='flat'|'pruned'))``."""

    def __init__(self, index: MSTGIndex, use_kernel: bool = False,
                 engine: Optional[QueryEngine] = None):
        _warn_deprecated(
            "FlatSearcher",
            "FlatSearcher is deprecated; use QueryEngine with a "
            "SearchRequest(route='flat') or route='pruned'", stacklevel=2)
        self.index = index
        self.use_kernel = use_kernel
        self.engine = engine or QueryEngine(index, use_kernel=use_kernel,
                                            route=ROUTE_FLAT)

    def search(self, queries, qlo, qhi, mask: int, k: int = 10):
        """Full-corpus fused brute force (ground-truth grade)."""
        return self.engine.search_flat(queries, qlo, qhi, mask, k=k)

    def search_pruned(self, queries, qlo, qhi, mask: int, k: int = 10,
                      block: int = 256, max_candidates: Optional[int] = None):
        """Tree-pruned exact search: work ∝ selectivity."""
        return self.engine.search_pruned(queries, qlo, qhi, mask, k=k,
                                         block=block,
                                         max_candidates=max_candidates)
