"""Exact predicate-filtered search engines (TPU-native MSTG execution).

Two engines (DESIGN.md §2 "flat path"):

* ``flat_search`` — fused predicate + brute-force distances over the whole
  corpus (the MXU-roofline path; also the test/benchmark ground truth).
* ``flat_search_pruned`` — uses the MSTG segment-tree decomposition to touch
  only qualifying *member slices*: every decomposition node stores its members
  grouped contiguously in insertion (=version) order, so the valid candidates
  of a node at version x are a PREFIX of its slice. Work scales with
  selectivity instead of n — the paper's pruning argument, executed as blocked
  gathers + matmuls instead of graph traversal. Exact (recall 1.0) by
  construction.

Both return squared-L2 top-k.
"""
from __future__ import annotations

import functools
from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp

from . import intervals as iv
from . import segment_tree as st
from .hnsw import NO_EDGE

INF = jnp.inf


def _pairwise_l2(queries: jnp.ndarray, corpus: jnp.ndarray) -> jnp.ndarray:
    """(Q, d) x (N, d) -> (Q, N) squared L2 via the MXU-friendly expansion."""
    qn = jnp.sum(queries * queries, axis=1, keepdims=True)
    cn = jnp.sum(corpus * corpus, axis=1)
    return qn - 2.0 * (queries @ corpus.T) + cn[None, :]


@functools.partial(jax.jit, static_argnames=("mask", "k", "use_kernel"))
def flat_search(corpus: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray,
                queries: jnp.ndarray, ql: jnp.ndarray, qh: jnp.ndarray,
                *, mask: int, k: int, use_kernel: bool = False
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact filtered k-NN: (Q, k) ids + squared distances (+inf / NO_EDGE pad
    when fewer than k objects qualify)."""
    if use_kernel:
        from repro.kernels import ops as kops
        d = kops.pairwise_l2_masked(queries, corpus, lo, hi, ql, qh, mask)
    else:
        sel = iv.eval_predicate(mask, lo[None, :], hi[None, :],
                                ql[:, None], qh[:, None])       # (Q, N)
        d = jnp.where(sel, _pairwise_l2(queries, corpus), INF)
    neg, idx = jax.lax.top_k(-d, k)
    ids = jnp.where(jnp.isfinite(neg), idx, NO_EDGE).astype(jnp.int32)
    return ids, -neg


@functools.partial(jax.jit, static_argnames=("mask", "k", "block"))
def flat_search_blocked(corpus, lo, hi, queries, ql, qh, *, mask: int, k: int,
                        block: int = 4096):
    """Exact filtered k-NN with a scanned running top-k: the (Q, N) distance
    matrix never materializes in HBM — per block it lives in VMEM and only the
    (Q, k) running winners persist. This is what makes the distributed serve
    step compute-bound (EXPERIMENTS.md §Perf iteration 6)."""
    N, d = corpus.shape
    Q = queries.shape[0]
    block = min(block, N)
    Np = -(-N // block) * block
    pad = Np - N
    if pad:
        corpus = jnp.pad(corpus, ((0, pad), (0, 0)))
        lo = jnp.pad(lo, (0, pad), constant_values=jnp.nan)  # NaN fails all
        hi = jnp.pad(hi, (0, pad), constant_values=jnp.nan)  # RR comparisons
    qn = jnp.sum(queries * queries, axis=1, keepdims=True)

    def body(carry, i):
        top_d, top_i = carry
        c = jax.lax.dynamic_slice_in_dim(corpus, i * block, block, 0)
        l = jax.lax.dynamic_slice_in_dim(lo, i * block, block, 0)
        h = jax.lax.dynamic_slice_in_dim(hi, i * block, block, 0)
        cn = jnp.sum(c * c, axis=1)
        dist = qn - 2.0 * (queries @ c.T) + cn[None, :]
        sel = iv.eval_predicate(mask, l[None, :], h[None, :],
                                ql[:, None], qh[:, None])
        dist = jnp.where(sel, dist, INF)
        ids = i * block + jnp.arange(block)
        cat_d = jnp.concatenate([top_d, dist], axis=1)
        cat_i = jnp.concatenate([top_i, jnp.broadcast_to(ids[None], (Q, block))
                                 .astype(jnp.int32)], axis=1)
        neg, pos = jax.lax.top_k(-cat_d, k)
        return (-neg, jnp.take_along_axis(cat_i, pos, 1)), None

    top0 = (jnp.full((Q, k), INF, jnp.float32),
            jnp.full((Q, k), NO_EDGE, jnp.int32))
    (top_d, top_i), _ = jax.lax.scan(body, top0, jnp.arange(Np // block))
    top_i = jnp.where(jnp.isfinite(top_d), top_i, NO_EDGE)
    return top_i, top_d


@functools.partial(jax.jit, static_argnames=("pred_mask_bits", "k", "Kpad",
                                              "block", "max_blocks"))
def _pruned_search_variant(arrays: dict, lo_attr, hi_attr, queries, ql, qh,
                           version, key_lo, key_hi, *, pred_mask_bits: int,
                           k: int, Kpad: int, block: int, max_blocks: int):
    """One variant's pruned scan: decomposition -> member prefixes -> blocked
    fused distance + running top-k. ``pred_mask_bits`` re-checks the exact
    predicate on gathered candidates (cheap; guards rank-boundary ties and
    lets one variant serve any sub-mask of its plan)."""
    # quantized layouts carry "codes" (+ affine params) instead of a float32
    # "vectors" table; dict keys are static under jit, so this picks the
    # gather source at trace time with no runtime branch
    quantized = "codes" in arrays
    vectors = None if quantized else arrays["vectors"]
    if quantized:
        # fold the affine dequant into the query side once (same identity as
        # the compressed flat scan): dist = cq - 2 (q*scale).code + sq_norm.
        # The gathered code tile is then consumed with a single cast +
        # contraction — no per-element scale/offset pass, no diff tensor.
        wq = queries * arrays["code_scale"][None, :]                  # (Q, d)
        cq = (jnp.sum(queries * queries, axis=1)
              - 2.0 * (queries @ arrays["code_offset"]))             # (Q,)
    members, member_ver = arrays["members"], arrays["member_ver"]
    node_off = arrays["node_off"]
    Q, d = queries.shape
    levels, idxs, valid = jax.vmap(lambda a, b: st.decompose_jax(a, b, Kpad))(key_lo, key_hi)
    P = levels.shape[1]

    off = node_off[levels, idxs]                                  # (Q, P) slice starts
    cnt = node_off[levels, idxs + 1] - off                        # (Q, P) member counts
    cnt = jnp.where(valid, cnt, 0)

    # valid prefix length per node at this version: member versions ascend
    # within a slice -> binary search, vectorized over (Q, P).
    def prefix_len(lvl, o, c, ver):
        def bs(state, _):
            lo_i, hi_i = state
            mid = (lo_i + hi_i) // 2
            v = member_ver[lvl, jnp.clip(o + mid, 0, members.shape[1] - 1)]
            go_right = (mid < c) & (v <= ver)
            return (jnp.where(go_right, mid + 1, lo_i),
                    jnp.where(go_right, hi_i, mid)), None
        iters = int(np.ceil(np.log2(max(int(members.shape[1]), 2)))) + 1
        (lo_i, _), _ = jax.lax.scan(bs, (jnp.zeros((), jnp.int32), c), None, length=iters)
        return lo_i

    plen = jax.vmap(jax.vmap(prefix_len))(
        levels, off, cnt.astype(jnp.int32),
        jnp.broadcast_to(version[:, None], (Q, P)).astype(jnp.int32))
    plen = jnp.where(valid, plen, 0)                              # (Q, P)

    # blocked scan over candidate prefixes
    cum = jnp.cumsum(plen, axis=1)
    starts = cum - plen                                           # (Q, P) in candidate space
    total = cum[:, -1]

    top_d = jnp.full((Q, k), INF, jnp.float32)
    top_i = jnp.full((Q, k), NO_EDGE, jnp.int32)

    def body(carry, blk):
        top_d, top_i = carry
        pos = blk * block + jnp.arange(block)                     # (B,) candidate positions
        # map candidate position -> (node slot, offset within prefix)
        slot = jnp.sum(pos[None, :, None] >= cum[:, None, :], axis=2)   # (Q, B)
        slot = jnp.clip(slot, 0, P - 1)
        inner = pos[None, :] - jnp.take_along_axis(starts, slot, 1)
        ok = pos[None, :] < total[:, None]
        lvl_b = jnp.take_along_axis(levels, slot, 1)
        off_b = jnp.take_along_axis(off, slot, 1)
        midx = jnp.clip(off_b + inner, 0, members.shape[1] - 1)
        cand = members[jnp.clip(lvl_b, 0, members.shape[0] - 1), midx]  # (Q, B)
        cand_safe = jnp.where(ok, cand, 0)
        # exact predicate re-check on raw endpoints
        sel = iv.eval_predicate(pred_mask_bits, lo_attr[cand_safe], hi_attr[cand_safe],
                                ql[:, None], qh[:, None]) & ok
        if quantized:
            # gather code rows (1-2 bytes/component); distances are
            # approximate and the engine re-ranks the merged top-R
            cb = arrays["codes"][cand_safe].astype(jnp.float32)
            dist = (cq[:, None]
                    - 2.0 * jnp.einsum("qd,qbd->qb", wq, cb)
                    + arrays["code_sq_norm"][cand_safe])
        else:
            diff = vectors[cand_safe] - queries[:, None, :]
            dist = jnp.einsum("qbd,qbd->qb", diff, diff)
        dist = jnp.where(sel, dist, INF)
        cat_d = jnp.concatenate([top_d, dist], axis=1)
        cat_i = jnp.concatenate([top_i, jnp.where(sel, cand, NO_EDGE)], axis=1)
        neg, pos_k = jax.lax.top_k(-cat_d, k)
        return (( -neg, jnp.take_along_axis(cat_i, pos_k, 1))), None

    (top_d, top_i), _ = jax.lax.scan(body, (top_d, top_i), jnp.arange(max_blocks))
    return top_i, top_d


# The host-facing exact-search API is QueryEngine (repro.core.engine) with
# route="flat"/"pruned"; this module keeps the jitted engines.
