"""Labeled navigable-graph construction (paper §4.3, Algorithm 3).

Host-side (numpy) incremental builder. One :class:`LabeledLevelGraph` holds all
tree-node graphs of ONE segment-tree level — node graphs at a level are disjoint
in key space, so a single per-vertex adjacency dict per level suffices, and it
freezes into a dense ``(n, slots)`` array for the TPU search path.

Faithfulness notes (see DESIGN.md §2):
* single-layer navigable graphs with per-node entry points (layer-0 of HNSW;
  iRangeGraph does the same) — insertion = ef-search + RNG pruning, exactly
  Algorithm 3's three steps;
* every edge carries a validity label ``(b, e)``: born at version ``b`` when its
  source/target was inserted, closed at ``e = x - 1`` when RNG pruning during the
  version-``x`` insertion removes it (Algorithm 3 lines 5, 10). ``e = OPEN``
  means "still live". Theorem D.1: the label-induced subgraph at version x equals
  the graph an unshared MSTG would have stored.
"""
from __future__ import annotations

import heapq
from itertools import chain
from typing import Dict, List, Optional, Tuple

import numpy as np

OPEN = np.iinfo(np.int32).max
NO_EDGE = -1


def l2sq(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    d = a - b
    return np.einsum("...d,...d->...", d, d)


def rng_prune(vectors: np.ndarray, base: int, cand_ids: np.ndarray,
              cand_dists: np.ndarray, m: int) -> List[int]:
    """HNSW 'select neighbors heuristic' (RNG rule): scan candidates in
    ascending distance; keep c iff no kept k has dist(c, k) < dist(base, c)."""
    order = np.argsort(cand_dists, kind="stable")
    kept: List[int] = []
    for j in order:
        c = int(cand_ids[j])
        if c == base:
            continue
        dc = float(cand_dists[j])
        if kept:
            dk = l2sq(vectors[kept], vectors[c])
            if np.any(dk < dc):
                continue
        kept.append(c)
        if len(kept) >= m:
            break
    return kept


class LabeledLevelGraph:
    """All labeled tree-node graphs of one segment-tree level."""

    def __init__(self, vectors: np.ndarray, m: int, ef_con: int,
                 m_max: Optional[int] = None, n_entries: int = 4):
        self.vectors = vectors
        self.m = int(m)
        self.m_max = int(m_max if m_max is not None else m)
        self.ef_con = int(ef_con)
        self.n_entries = int(n_entries)
        self.open_adj: Dict[int, List[int]] = {}
        self.open_born: Dict[int, List[int]] = {}
        self.closed: Dict[int, List[Tuple[int, int, int]]] = {}
        self.node_members: Dict[int, List[int]] = {}
        self.node_member_vers: Dict[int, List[int]] = {}

    # ---- live-graph beam search (build-time only) ----
    def _search_live(self, q: np.ndarray, entries: List[int], ef: int):
        V = self.vectors
        visited = set(entries)
        dists = l2sq(V[entries], q)
        cand = [(float(d), e) for d, e in zip(np.atleast_1d(dists), entries)]
        heapq.heapify(cand)
        result = [(-d, e) for d, e in cand]
        heapq.heapify(result)
        while len(result) > ef:
            heapq.heappop(result)
        while cand:
            d, u = heapq.heappop(cand)
            if len(result) >= ef and d > -result[0][0]:
                break
            nbrs = [v for v in self.open_adj.get(u, ()) if v not in visited]
            if not nbrs:
                continue
            visited.update(nbrs)
            nd = l2sq(V[nbrs], q)
            for dv, v in zip(np.atleast_1d(nd), nbrs):
                dv = float(dv)
                if len(result) < ef or dv < -result[0][0]:
                    heapq.heappush(cand, (dv, v))
                    heapq.heappush(result, (-dv, v))
                    if len(result) > ef:
                        heapq.heappop(result)
        out = sorted([(-d, u) for d, u in result])
        ids = np.array([u for _, u in out], dtype=np.int64)
        ds = np.array([d for d, _ in out], dtype=np.float64)
        return ids, ds

    def _add_edge(self, u: int, v: int, version: int) -> None:
        self.open_adj.setdefault(u, []).append(v)
        self.open_born.setdefault(u, []).append(version)

    def _reprune(self, u: int, version: int) -> None:
        """RNG-prune u's live out-edges down to m_max; close removed labels."""
        nbrs = self.open_adj[u]
        if len(nbrs) <= self.m_max:
            return
        ids = np.array(nbrs, dtype=np.int64)
        dists = l2sq(self.vectors[ids], self.vectors[u])
        kept = set(rng_prune(self.vectors, u, ids, dists, self.m_max))
        new_adj, new_born = [], []
        log = self.closed.setdefault(u, [])
        for v, b in zip(nbrs, self.open_born[u]):
            if v in kept:
                new_adj.append(v)
                new_born.append(b)
            else:
                e = version - 1
                if e >= b:  # an edge born and pruned at the same version never existed
                    log.append((v, b, e))
        self.open_adj[u] = new_adj
        self.open_born[u] = new_born

    def insert(self, u: int, node_idx: int, version: int) -> None:
        """Algorithm 3: insert object u into tree-node ``node_idx`` at ``version``."""
        members = self.node_members.setdefault(node_idx, [])
        vers = self.node_member_vers.setdefault(node_idx, [])
        self.open_adj.setdefault(u, [])
        self.open_born.setdefault(u, [])
        if members:
            entries = members[: self.n_entries]
            ids, dists = self._search_live(self.vectors[u], entries, self.ef_con)
            kept = rng_prune(self.vectors, u, ids, dists, self.m)
            for c in kept:
                self._add_edge(u, c, version)
                self._add_edge(c, u, version)
                self._reprune(c, version)
        members.append(u)
        vers.append(version)

    # ---- freeze to dense arrays ----
    def edge_log(self, u: int) -> List[Tuple[int, int, int]]:
        log = list(self.closed.get(u, ()))
        log.extend((v, b, OPEN) for v, b in
                   zip(self.open_adj.get(u, ()), self.open_born.get(u, ())))
        return log

    def max_slots(self, n: int) -> int:
        closed, open_adj = self.closed, self.open_adj
        s = 0
        for u in range(n):
            t = len(closed.get(u, ())) + len(open_adj.get(u, ()))
            if t > s:
                s = t
        return s

    def freeze(self, n: int, slots: Optional[int] = None, out=None):
        """Dense (n, S) arrays: targets / born / end labels. Vectorized
        scatter of the flat edge logs (closed triples first, then open
        edges — the :meth:`edge_log` order) instead of per-edge Python.
        ``out`` (a ``(tgt, lab_b, lab_e)`` triple of (n, S) int32 views)
        scatters in place instead of allocating."""
        closed, open_adj, open_born = self.closed, self.open_adj, self.open_born
        c_cnt = np.fromiter((len(closed.get(u, ())) for u in range(n)),
                            np.int64, count=n)
        o_cnt = np.fromiter((len(open_adj.get(u, ())) for u in range(n)),
                            np.int64, count=n)
        tot = c_cnt + o_cnt
        s_req = int(tot.max()) if n else 0
        S = int(slots if slots is not None else max(s_req, 1))
        if s_req > S:
            u = int(np.argmax(tot))
            raise ValueError(f"vertex {u} has {int(tot[u])} edges > {S} slots")
        if out is not None:
            tgt, lab_b, lab_e = out
            tgt[:] = NO_EDGE
            lab_b[:] = 0
            lab_e[:] = 0
        else:
            tgt = np.full((n, S), NO_EDGE, dtype=np.int32)
            lab_b = np.zeros((n, S), dtype=np.int32)
            lab_e = np.zeros((n, S), dtype=np.int32)
        ec = int(c_cnt.sum())
        if ec:
            rows = np.repeat(np.arange(n), c_cnt)
            within = np.arange(ec) - np.repeat(np.cumsum(c_cnt) - c_cnt, c_cnt)
            trip = np.fromiter(
                chain.from_iterable(chain.from_iterable(
                    closed.get(u, ()) for u in range(n))),
                np.int64, count=3 * ec).reshape(ec, 3)
            tgt[rows, within] = trip[:, 0]
            lab_b[rows, within] = trip[:, 1]
            lab_e[rows, within] = trip[:, 2]
        eo = int(o_cnt.sum())
        if eo:
            rows = np.repeat(np.arange(n), o_cnt)
            within = c_cnt[rows] + (np.arange(eo)
                                    - np.repeat(np.cumsum(o_cnt) - o_cnt,
                                                o_cnt))
            tgt[rows, within] = np.fromiter(
                chain.from_iterable(open_adj.get(u, ()) for u in range(n)),
                np.int64, count=eo)
            lab_b[rows, within] = np.fromiter(
                chain.from_iterable(open_born.get(u, ()) for u in range(n)),
                np.int64, count=eo)
            lab_e[rows, within] = OPEN
        return tgt, lab_b, lab_e

    def induced_adjacency(self, u: int, version: int) -> List[int]:
        """Neighbors of u valid at ``version`` (test oracle for Theorem D.1)."""
        return [v for (v, b, e) in self.edge_log(u) if b <= version <= e]


class PlainHNSW:
    """Unlabeled single-graph HNSW (layer-0) — substrate for the baselines
    (post-filtering, ACORN-style) and the oracle index."""

    def __init__(self, vectors: np.ndarray, m: int = 16, ef_con: int = 100,
                 m_max: Optional[int] = None, seed: int = 0):
        self.g = LabeledLevelGraph(vectors, m=m, ef_con=ef_con,
                                   m_max=m_max if m_max is not None else 2 * m)
        self.vectors = vectors
        self.ids: List[int] = []

    def add(self, u: int) -> None:
        self.g.insert(u, node_idx=0, version=0)
        self.ids.append(u)

    def build(self, ids) -> "PlainHNSW":
        for u in ids:
            self.add(int(u))
        return self

    @property
    def entry_points(self) -> List[int]:
        return self.g.node_members.get(0, [])[: self.g.n_entries]

    def adjacency(self, u: int) -> List[int]:
        return self.g.open_adj.get(u, [])

    def search(self, q: np.ndarray, k: int, ef: int,
               predicate=None, collect=None):
        """Greedy best-first search (paper Algorithm 4). ``predicate(id)->bool``
        makes this the ACORN-1/VBASE-style filtered traversal: all nodes
        navigate, only passing nodes enter the result. ``collect`` (optional
        list) records every distance evaluation for cost accounting."""
        entries = self.entry_points
        if not entries:
            return np.empty(0, np.int64), np.empty(0, np.float64)
        V = self.vectors
        visited = set(entries)
        d0 = np.atleast_1d(l2sq(V[entries], q))
        if collect is not None:
            collect.append(len(entries))
        cand = [(float(d), u) for d, u in zip(d0, entries)]
        heapq.heapify(cand)
        result = []  # max-heap of passing nodes
        nav = [(-float(d), u) for d, u in zip(d0, entries)]
        heapq.heapify(nav)
        while len(nav) > ef:
            heapq.heappop(nav)
        for d, u in cand:
            if predicate is None or predicate(u):
                heapq.heappush(result, (-d, u))
        while cand:
            d, u = heapq.heappop(cand)
            if len(nav) >= ef and d > -nav[0][0]:
                break
            nbrs = [v for v in self.adjacency(u) if v not in visited]
            if not nbrs:
                continue
            visited.update(nbrs)
            nd = np.atleast_1d(l2sq(V[nbrs], q))
            if collect is not None:
                collect.append(len(nbrs))
            for dv, v in zip(nd, nbrs):
                dv = float(dv)
                if len(nav) < ef or dv < -nav[0][0]:
                    heapq.heappush(cand, (dv, v))
                    heapq.heappush(nav, (-dv, v))
                    if len(nav) > ef:
                        heapq.heappop(nav)
                    if predicate is None or predicate(v):
                        heapq.heappush(result, (-dv, v))
                        while len(result) > max(ef, k):
                            heapq.heappop(result)
        out = sorted([(-d, u) for d, u in result])[:k]
        ids = np.array([u for _, u in out], dtype=np.int64)
        ds = np.array([d for d, _ in out], dtype=np.float64)
        return ids, ds
