"""Range-range (RR) predicates and the MSTG query planner (paper §2, §4.4, Thm 4.1).

Four atomic predicates between an object range ``[lo, hi]`` and a query range
``[ql, qh]`` (paper Fig. 1), encoded as a bitmask so arbitrary disjunctions are a
single int:

    ① LEFT_OVERLAP     lo <= ql <= hi <= qh          (query left-overlap)
    ② QUERY_CONTAINED  lo <= ql <= qh <= hi          (object covers query)
    ③ RIGHT_OVERLAP    ql <= lo <= qh <= hi          (query right-overlap)
    ④ QUERY_CONTAINING ql <= lo <= hi <= qh          (query covers object)

plus the two disjoint Allen relations (Appendix A), supported standalone:

    BEFORE  qh <  lo        AFTER  hi <  ql

Attribute values live in a finite ordered domain ``A`` (paper's a_1 < ... < a_|A|).
All index structures work on integer *ranks* into A; float query endpoints are
mapped with searchsorted so predicate evaluation is exact.
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Sequence

import numpy as np

LEFT_OVERLAP = 1        # case ①
QUERY_CONTAINED = 2     # case ②
RIGHT_OVERLAP = 4       # case ③
QUERY_CONTAINING = 8    # case ④
BEFORE = 16             # Allen <  : whole object strictly after query
AFTER = 32              # Allen >  : whole object strictly before query

ANY_OVERLAP = LEFT_OVERLAP | QUERY_CONTAINED | RIGHT_OVERLAP | QUERY_CONTAINING

_ATOMIC = (LEFT_OVERLAP, QUERY_CONTAINED, RIGHT_OVERLAP, QUERY_CONTAINING)

# Problem-variant shorthands (paper Table 1).
RFANN_MASK = QUERY_CONTAINING   # point object attr, a_i in [ql, qh]
IFANN_MASK = QUERY_CONTAINING   # [l_i, r_i] subset of [ql, qh]
TSANN_MASK = QUERY_CONTAINED    # ql = qh = t_q in [l_i, r_i]


def mask_name(mask: int) -> str:
    parts = []
    for bit, nm in ((1, "1"), (2, "2"), (4, "3"), (8, "4"), (16, "<"), (32, ">")):
        if mask & bit:
            parts.append(nm)
    return "|".join(parts) if parts else "none"


# Token vocabulary for :func:`parse_mask`. Single digits follow the paper's
# case numbering (so "4" is case ④ = QUERY_CONTAINING, not raw bit 4);
# multi-digit tokens are raw integer masks.
_MASK_TOKENS = {
    "1": LEFT_OVERLAP, "left_overlap": LEFT_OVERLAP,
    "2": QUERY_CONTAINED, "query_contained": QUERY_CONTAINED,
    "contains": QUERY_CONTAINED,
    "3": RIGHT_OVERLAP, "right_overlap": RIGHT_OVERLAP,
    "4": QUERY_CONTAINING, "query_containing": QUERY_CONTAINING,
    "contained_by": QUERY_CONTAINING, "containedby": QUERY_CONTAINING,
    "<": BEFORE, "before": BEFORE,
    ">": AFTER, "after": AFTER,
    "any_overlap": ANY_OVERLAP, "overlap": ANY_OVERLAP, "overlaps": ANY_OVERLAP,
    "rfann": RFANN_MASK, "ifann": IFANN_MASK, "tsann": TSANN_MASK,
    "none": 0,
}

FULL_MASK = ANY_OVERLAP | BEFORE | AFTER


def parse_mask(text) -> int:
    """Inverse of :func:`mask_name`: parse ``"1|2|<"``, ``"any_overlap"``,
    ``"before,after"``, a raw integer mask (``"15"`` or an int), or any
    ``|``/``,``/``+``/whitespace-separated mix of those tokens.

    Caution: in *strings*, the single digits ``"1"``–``"4"`` are the paper's
    case numbers (``"4"`` -> QUERY_CONTAINING, bit 8) so that ``mask_name``
    output round-trips; only multi-digit string tokens (``"15"``) and actual
    ints are raw bitmasks — ``parse_mask("3") != parse_mask(3)``."""
    if isinstance(text, (int, np.integer)):
        mask = int(text)
        if not 0 <= mask <= FULL_MASK:
            raise ValueError(f"mask {mask} outside [0, {FULL_MASK}]")
        return mask
    if not isinstance(text, str):
        raise TypeError(f"predicate mask must be an int or str, got "
                        f"{type(text).__name__}")
    s = text.strip().lower()
    if not s:
        raise ValueError("empty predicate mask string")
    mask = 0
    for tok in (t for t in _split_mask_tokens(s) if t):
        if tok in _MASK_TOKENS:
            mask |= _MASK_TOKENS[tok]
        elif tok.isdigit():
            val = int(tok)
            if not 0 <= val <= FULL_MASK:
                raise ValueError(f"mask {val} outside [0, {FULL_MASK}]")
            mask |= val
        else:
            raise ValueError(
                f"unknown predicate token {tok!r} "
                f"(known: {sorted(_MASK_TOKENS)} or an integer mask)")
    return mask


def _split_mask_tokens(s: str) -> List[str]:
    for sep in (",", "+", " ", "\t"):
        s = s.replace(sep, "|")
    return [t.strip() for t in s.split("|")]


def eval_predicate(mask, lo, hi, ql, qh):
    """Vectorized truth of the RR predicate. Works for numpy or jax arrays.

    ``lo/hi`` are object endpoints, ``ql/qh`` query endpoints; any mix of floats
    and integer ranks is fine as long as the two sides share one coordinate
    system.
    """
    out = (lo <= ql) & False  # typed all-false of broadcast shape (numpy or jax)
    if mask & LEFT_OVERLAP:
        out = out | ((lo <= ql) & (ql <= hi) & (hi <= qh))
    if mask & QUERY_CONTAINED:
        out = out | ((lo <= ql) & (qh <= hi))
    if mask & RIGHT_OVERLAP:
        out = out | ((ql <= lo) & (lo <= qh) & (qh <= hi))
    if mask & QUERY_CONTAINING:
        out = out | ((ql <= lo) & (hi <= qh))
    if mask & BEFORE:
        out = out | (qh < lo)
    if mask & AFTER:
        out = out | (hi < ql)
    return out


class AttributeDomain:
    """The finite ordered attribute domain A with exact float<->rank mapping."""

    def __init__(self, values: np.ndarray):
        vals = np.unique(np.asarray(values))
        if vals.size == 0:
            raise ValueError("empty attribute domain")
        self.values = vals.astype(np.float64)
        self.K = int(vals.size)

    @classmethod
    def from_ranges(cls, lo: np.ndarray, hi: np.ndarray) -> "AttributeDomain":
        return cls(np.concatenate([np.asarray(lo).ravel(), np.asarray(hi).ravel()]))

    def rank(self, x) -> np.ndarray:
        """Exact rank of values known to be in A."""
        r = np.searchsorted(self.values, x, side="left")
        return r.astype(np.int32)

    # Query endpoints may fall between domain values.
    def floor_rank(self, x) -> np.ndarray:
        """Largest rank i with A[i] <= x, or -1."""
        return (np.searchsorted(self.values, x, side="right") - 1).astype(np.int64)

    def ceil_rank(self, x) -> np.ndarray:
        """Smallest rank i with A[i] >= x, or K."""
        return np.searchsorted(self.values, x, side="left").astype(np.int64)


class SelectivityIndex:
    """Exact O(1)-per-query RR-predicate selectivity over a fixed object set.

    Every atomic predicate (and the Allen BEFORE/AFTER bits) is a conjunction
    of comparisons between the object's ``(lo_rank, hi_rank)`` and the
    query's floor/ceil ranks, so its truth region is an axis-aligned
    rectangle in rank space and a *mask* (any disjunction) is a union of such
    rectangles. This index answers "how many objects satisfy mask" with a
    handful of lookups into a 2-D prefix-sum table ``P[a, b] =
    #{lo_rank < a and hi_rank < b}``: the query's cut points split each rank
    axis into at most 4 intervals, the union is evaluated cell-by-cell on the
    resulting (disjoint) <= 4x4 grid, so overlapping predicate bits are never
    double-counted and the count is exact — no per-object work at query time.

    The table is ``(K+1)^2`` int32 (~16 MB at K=2048); callers should fall
    back to :func:`eval_predicate` scans for larger domains.
    """

    def __init__(self, lo_rank: np.ndarray, hi_rank: np.ndarray, K: int):
        lo_rank = np.asarray(lo_rank, np.int64).ravel()
        hi_rank = np.asarray(hi_rank, np.int64).ravel()
        if lo_rank.shape != hi_rank.shape:
            raise ValueError("lo_rank and hi_rank must align")
        if lo_rank.size and (min(lo_rank.min(), hi_rank.min()) < 0
                             or max(lo_rank.max(), hi_rank.max()) >= K):
            raise ValueError("ranks must lie in [0, K)")
        self.K = int(K)
        self.m = int(lo_rank.size)
        H = np.zeros((K + 1, K + 1), np.int32)
        np.add.at(H, (lo_rank + 1, hi_rank + 1), 1)
        self.P = H.cumsum(0).cumsum(1)

    def _rect(self, a0, a1, b0, b1) -> np.ndarray:
        """#objects with lo_rank in [a0, a1] and hi_rank in [b0, b1]
        (vectorized; inverted or out-of-range rectangles count 0)."""
        K, P = self.K, self.P
        a0c = np.clip(a0, 0, K)
        a1c = np.clip(a1 + 1, 0, K)
        b0c = np.clip(b0, 0, K)
        b1c = np.clip(b1 + 1, 0, K)
        cnt = (P[a1c, b1c] - P[a0c, b1c] - P[a1c, b0c] + P[a0c, b0c])
        return np.where((a1c > a0c) & (b1c > b0c), cnt, 0).astype(np.int64)

    @staticmethod
    def _segments(ends: np.ndarray, K: int):
        """Split [0, K-1] at per-query cut ``ends`` -> 4 inclusive
        (start, end) segment pairs (some may be empty)."""
        e = np.sort(np.concatenate(
            [ends, np.full((ends.shape[0], 1), K - 1)], axis=1), axis=1)
        s = np.concatenate(
            [np.zeros((e.shape[0], 1), np.int64), e[:, :-1] + 1], axis=1)
        return s, e

    def count(self, mask: int, fl, cl, fr, cr) -> np.ndarray:
        """(Q,) exact number of objects satisfying ``mask`` for queries given
        by their endpoint ranks (``fl/cl`` = floor/ceil rank of qlo, ``fr/cr``
        of qhi, as produced by :class:`AttributeDomain`). All <= 16 grid
        cells are evaluated in one broadcast pass."""
        fl = np.asarray(fl, np.int64)
        cl = np.asarray(cl, np.int64)
        fr = np.asarray(fr, np.int64)
        cr = np.asarray(cr, np.int64)
        K = self.K
        zero = np.zeros_like(fl)
        top = np.full_like(fl, K - 1)
        # single-rectangle masks skip the grid decomposition entirely
        if mask == ANY_OVERLAP:  # closed ranges overlap <=> lo<=qh & ql<=hi
            return self._rect(zero, fr, cl, top)
        if mask == LEFT_OVERLAP:
            return self._rect(zero, fl, cl, fr)
        if mask == QUERY_CONTAINED:
            return self._rect(zero, fl, cr, top)
        if mask == RIGHT_OVERLAP:
            return self._rect(cl, fr, cr, top)
        if mask == QUERY_CONTAINING:
            return self._rect(cl, top, zero, fr)
        if mask == BEFORE:
            return self._rect(fr + 1, top, zero, top)
        if mask == AFTER:
            return self._rect(zero, top, zero, cl - 1)
        lo_s, lo_e = self._segments(np.stack([fl, cl - 1, fr], 1), self.K)
        hi_s, hi_e = self._segments(np.stack([cl - 1, fr, cr - 1], 1), self.K)
        a0, a1 = lo_s[:, :, None], lo_e[:, :, None]        # (Q, 4, 1)
        b0, b1 = hi_s[:, None, :], hi_e[:, None, :]        # (Q, 1, 4)
        flq, clq = fl[:, None, None], cl[:, None, None]
        frq, crq = fr[:, None, None], cr[:, None, None]
        # atomic truth is constant inside a cell; test it at the lower corner
        hit = np.zeros((fl.shape[0], a0.shape[1], b0.shape[2]), bool)
        if mask & LEFT_OVERLAP:
            hit |= (a0 <= flq) & (b0 >= clq) & (b0 <= frq)
        if mask & QUERY_CONTAINED:
            hit |= (a0 <= flq) & (b0 >= crq)
        if mask & RIGHT_OVERLAP:
            hit |= (a0 >= clq) & (a0 <= frq) & (b0 >= crq)
        if mask & QUERY_CONTAINING:
            hit |= (a0 >= clq) & (b0 <= frq)
        if mask & BEFORE:
            hit |= np.broadcast_to(a0 >= frq + 1, hit.shape)
        if mask & AFTER:
            hit |= np.broadcast_to(b0 <= clq - 1, hit.shape)
        cells = np.where(hit, self._rect(a0, a1, b0, b1), 0)
        return cells.sum(axis=(1, 2))

    def fraction(self, mask: int, fl, cl, fr, cr) -> np.ndarray:
        """(Q,) fraction of the indexed objects satisfying ``mask``."""
        if self.m == 0:
            return np.zeros(np.asarray(fl).shape[0], np.float64)
        return self.count(mask, fl, cl, fr, cr) / float(self.m)


# MSTG index variants (paper §4.4).
VARIANT_T = "T"       # versions: ascending l   (objects with l_i <= a_x); tree key r_i
VARIANT_TP = "Tp"     # versions: descending r  (objects with r_i >= a_x); tree key l_i
VARIANT_TPP = "Tpp"   # versions: descending l  (objects with l_i >= a_x); tree key r_i


@dataclasses.dataclass(frozen=True)
class SearchTask:
    """One beam search on one MSTG variant.

    version   : max transformed sort-rank that is valid (objects with
                sort_rank <= version participate); version < 0 means empty.
    key_lo/hi : inclusive tree-key rank range (raw rank space, 0..K-1);
                key_lo > key_hi means empty.
    """

    variant: str
    version: int
    key_lo: int
    key_hi: int

    def is_empty(self, K: int) -> bool:
        return self.version < 0 or self.key_lo > self.key_hi or self.key_lo >= K


def variants_required(mask: int) -> List[str]:
    """Which MSTG variants a deployment must build to serve ``mask``."""
    return sorted({t.variant for t in plan_searches_ranked(mask, 0, 0, 1, 1, 4)},
                  reverse=True)


def plan_searches(domain: AttributeDomain, mask: int, ql: float, qh: float) -> List[SearchTask]:
    """Theorem 4.1 planner: any RR disjunction -> at most two SearchTasks.

    (The Allen BEFORE/AFTER bits each add one more task; they reduce to RFANN
    threshold filters, Appendix A.)
    """
    if ql > qh:
        raise ValueError("query range must have ql <= qh")
    fl = int(domain.floor_rank(ql))   # max rank with A[rank] <= ql  (or -1)
    cl = int(domain.ceil_rank(ql))    # min rank with A[rank] >= ql  (or K)
    fr = int(domain.floor_rank(qh))
    cr = int(domain.ceil_rank(qh))
    return [t for t in plan_searches_ranked(mask, fl, cl, fr, cr, domain.K)
            if not t.is_empty(domain.K)]


def plan_searches_ranked(mask: int, fl: int, cl: int, fr: int, cr: int, K: int) -> List[SearchTask]:
    """Planner on pre-computed rank bounds (see ``plan_searches``).

    Returns the UNFILTERED task list — the task sequence depends only on
    ``mask``, so batched planning can align per-query parameters slot by slot;
    per-query-empty tasks keep their slot (version < 0 or key_lo > key_hi)."""
    tasks: List[SearchTask] = []
    top = K - 1
    atomic = mask & ANY_OVERLAP

    def T(version, key_lo, key_hi):
        tasks.append(SearchTask(VARIANT_T, version, key_lo, key_hi))

    def Tp(version, key_lo, key_hi):
        tasks.append(SearchTask(VARIANT_TP, version, key_lo, key_hi))

    def Tpp(version, key_lo, key_hi):
        tasks.append(SearchTask(VARIANT_TPP, version, key_lo, key_hi))

    # -- the 15 non-empty atomic combinations, each <= 2 searches (Thm 4.1) --
    if atomic == QUERY_CONTAINED:                       # {2}: l<=ql, r>=qh
        T(fl, cr, top)
    elif atomic == LEFT_OVERLAP:                        # {1}: l<=ql, ql<=r<=qh
        T(fl, cl, fr)
    elif atomic == RIGHT_OVERLAP:                       # {3}: ql<=l<=qh, r>=qh
        Tp(top - cr, cl, fr)
    elif atomic == QUERY_CONTAINING:                    # {4}: l>=ql, r<=qh
        Tpp(top - cl, 0, fr)
    elif atomic == LEFT_OVERLAP | QUERY_CONTAINED:      # {1,2}: l<=ql, r>=ql
        T(fl, cl, top)
    elif atomic == QUERY_CONTAINED | RIGHT_OVERLAP:     # {2,3}: l<=qh, r>=qh
        T(fr, cr, top)
    elif atomic == RIGHT_OVERLAP | QUERY_CONTAINING:    # {3,4}: ql<=l<=qh (r>=l free'd to r>=ql)
        Tp(top - cl, cl, fr)
    elif atomic == LEFT_OVERLAP | RIGHT_OVERLAP:        # {1,3}
        T(fl, cl, fr)
        Tp(top - cr, cl, fr)
    elif atomic == LEFT_OVERLAP | QUERY_CONTAINING:     # {1,4}
        T(fl, cl, fr)
        Tpp(top - cl, 0, fr)
    elif atomic == QUERY_CONTAINED | QUERY_CONTAINING:  # {2,4}
        T(fl, cr, top)
        Tpp(top - cl, 0, fr)
    elif atomic == LEFT_OVERLAP | QUERY_CONTAINED | RIGHT_OVERLAP:      # {1,2,3}
        T(fl, cl, top)
        Tp(top - cr, cl, fr)
    elif atomic == LEFT_OVERLAP | QUERY_CONTAINED | QUERY_CONTAINING:   # {1,2,4}
        T(fl, cl, top)
        Tpp(top - cl, 0, fr)
    elif atomic == LEFT_OVERLAP | RIGHT_OVERLAP | QUERY_CONTAINING:     # {1,3,4}
        T(fl, cl, fr)
        Tp(top - cl, cl, fr)
    elif atomic == QUERY_CONTAINED | RIGHT_OVERLAP | QUERY_CONTAINING:  # {2,3,4}
        T(fr, cr, top)
        Tpp(top - cl, 0, fr)
    elif atomic == ANY_OVERLAP:                         # {1,2,3,4}: any intersection
        T(fl, cl, top)
        Tp(top - cl, cl, fr)
    elif atomic != 0:
        raise AssertionError(f"unhandled atomic mask {atomic}")

    # -- Allen disjoint relations (Appendix A): RFANN threshold filters --
    if mask & BEFORE:   # object strictly after query: l_i > qh
        # l_i >= A[rank] where rank = first rank with value > qh
        lo_rank = fr + 1 if cr == fr else cr  # first rank with A[rank] > qh
        Tpp(top - lo_rank, 0, top)
    if mask & AFTER:    # object strictly before query: r_i < ql
        hi_rank = cl - 1 if cl == fl else fl  # last rank with A[rank] < ql
        T(top, 0, hi_rank)

    return tasks


class PlanSlot(NamedTuple):
    """One task slot of a batched plan: ``version``/``key_lo``/``key_hi`` are
    (Q,) int64 arrays; a query's slot is empty when ``version < 0`` or
    ``key_lo > key_hi`` (same convention as :class:`SearchTask`)."""

    variant: str
    version: np.ndarray
    key_lo: np.ndarray
    key_hi: np.ndarray

    def empty_mask(self, K: int) -> np.ndarray:
        return (self.version < 0) | (self.key_lo > self.key_hi) | (self.key_lo >= K)


def plan_batch_ranked(mask: int, fl, cl, fr, cr, K: int) -> List[PlanSlot]:
    """Vectorized Theorem 4.1 planner over (Q,) rank-bound arrays.

    Array-native twin of :func:`plan_searches_ranked`: for a fixed ``mask`` the
    task sequence (variant per slot) is query-independent, so every slot's
    ``(version, key_lo, key_hi)`` is a pure arithmetic function of the per-query
    rank bounds ``fl``/``cl``/``fr``/``cr`` — no per-query Python. Slot order
    and per-slot values agree exactly with the scalar planner (property-tested
    in tests/test_engine.py); per-query-empty tasks keep their slot.
    """
    fl = np.asarray(fl, dtype=np.int64)
    cl = np.asarray(cl, dtype=np.int64)
    fr = np.asarray(fr, dtype=np.int64)
    cr = np.asarray(cr, dtype=np.int64)
    shape = np.broadcast_shapes(fl.shape, cl.shape, fr.shape, cr.shape)
    top = K - 1
    atomic = mask & ANY_OVERLAP
    slots: List[PlanSlot] = []

    def _b(x) -> np.ndarray:
        return np.broadcast_to(np.asarray(x, dtype=np.int64), shape).copy()

    def T(version, key_lo, key_hi):
        slots.append(PlanSlot(VARIANT_T, _b(version), _b(key_lo), _b(key_hi)))

    def Tp(version, key_lo, key_hi):
        slots.append(PlanSlot(VARIANT_TP, _b(version), _b(key_lo), _b(key_hi)))

    def Tpp(version, key_lo, key_hi):
        slots.append(PlanSlot(VARIANT_TPP, _b(version), _b(key_lo), _b(key_hi)))

    # -- the 15 non-empty atomic combinations (same dispatch as the scalar
    #    planner; expressions are element-wise so they broadcast over (Q,)) --
    if atomic == QUERY_CONTAINED:                       # {2}
        T(fl, cr, top)
    elif atomic == LEFT_OVERLAP:                        # {1}
        T(fl, cl, fr)
    elif atomic == RIGHT_OVERLAP:                       # {3}
        Tp(top - cr, cl, fr)
    elif atomic == QUERY_CONTAINING:                    # {4}
        Tpp(top - cl, 0, fr)
    elif atomic == LEFT_OVERLAP | QUERY_CONTAINED:      # {1,2}
        T(fl, cl, top)
    elif atomic == QUERY_CONTAINED | RIGHT_OVERLAP:     # {2,3}
        T(fr, cr, top)
    elif atomic == RIGHT_OVERLAP | QUERY_CONTAINING:    # {3,4}
        Tp(top - cl, cl, fr)
    elif atomic == LEFT_OVERLAP | RIGHT_OVERLAP:        # {1,3}
        T(fl, cl, fr)
        Tp(top - cr, cl, fr)
    elif atomic == LEFT_OVERLAP | QUERY_CONTAINING:     # {1,4}
        T(fl, cl, fr)
        Tpp(top - cl, 0, fr)
    elif atomic == QUERY_CONTAINED | QUERY_CONTAINING:  # {2,4}
        T(fl, cr, top)
        Tpp(top - cl, 0, fr)
    elif atomic == LEFT_OVERLAP | QUERY_CONTAINED | RIGHT_OVERLAP:      # {1,2,3}
        T(fl, cl, top)
        Tp(top - cr, cl, fr)
    elif atomic == LEFT_OVERLAP | QUERY_CONTAINED | QUERY_CONTAINING:   # {1,2,4}
        T(fl, cl, top)
        Tpp(top - cl, 0, fr)
    elif atomic == LEFT_OVERLAP | RIGHT_OVERLAP | QUERY_CONTAINING:     # {1,3,4}
        T(fl, cl, fr)
        Tp(top - cl, cl, fr)
    elif atomic == QUERY_CONTAINED | RIGHT_OVERLAP | QUERY_CONTAINING:  # {2,3,4}
        T(fr, cr, top)
        Tpp(top - cl, 0, fr)
    elif atomic == ANY_OVERLAP:                         # {1,2,3,4}
        T(fl, cl, top)
        Tp(top - cl, cl, fr)
    elif atomic != 0:
        raise AssertionError(f"unhandled atomic mask {atomic}")

    # -- Allen disjoint relations: the scalar planner's conditionals become
    #    np.where over the exact-endpoint predicate --
    if mask & BEFORE:   # l_i > qh
        lo_rank = np.where(cr == fr, fr + 1, cr)
        Tpp(top - lo_rank, 0, top)
    if mask & AFTER:    # r_i < ql
        hi_rank = np.where(cl == fl, cl - 1, fl)
        T(top, 0, hi_rank)

    return slots


def check_plan_cover(mask: int, tasks: Sequence[SearchTask], rl: np.ndarray,
                     rr: np.ndarray, fl: int, cl: int, fr: int, cr: int, K: int) -> bool:
    """Test helper: does the union of task-candidate sets equal the predicate set?

    ``rl``/``rr`` are the objects' endpoint ranks. Membership of a task is
    evaluated on the variant's (sort_rank, tree_key) encoding.
    """
    top = K - 1
    sel = np.zeros(rl.shape[0], dtype=bool)
    for t in tasks:
        if t.variant == VARIANT_T:
            s, k = rl, rr
        elif t.variant == VARIANT_TP:
            s, k = top - rr, rl
        else:
            s, k = top - rl, rr
        sel |= (s <= t.version) & (k >= t.key_lo) & (k <= t.key_hi)
    want = eval_predicate(mask, rl, rr,
                          np.float64(_rank_interp(fl, cl)), np.float64(_rank_interp(fr, cr)))
    return bool(np.array_equal(sel, want))


def _rank_interp(floor_r: int, ceil_r: int) -> float:
    """A synthetic query coordinate in rank space: exact rank if floor==ceil,
    else halfway between the two surrounding ranks."""
    if floor_r == ceil_r:
        return float(floor_r)
    return (floor_r + ceil_r) / 2.0
