"""MSTG — multi-segment tree graph index (paper §4, Algorithms 1–3).

Build is host-side, in ascending order of the variant's sort key; each object
touches the O(log|A|) segment-tree nodes on the root->leaf path of its tree
key (Algorithm 1), each touched node's labeled HNSW absorbs the vector
(Algorithm 3). Path-copying/persistence (§4.2) and label compression (§4.3)
collapse into the per-level labeled graphs of :mod:`repro.core.hnsw` — nothing
is ever duplicated, labels recover any version (Theorem D.1).

Two construction paths produce the same frozen schema (``builder`` knob):

* ``"bulk"`` (default) — :mod:`repro.core.build`: sorted-order batches,
  candidate generation via batched distance matmuls shared across the
  ``Lv`` levels of each object's tree path, batched RNG pruning, deferred
  per-batch re-pruning. ~an order of magnitude faster; edge labels are a
  superset of the incremental ones (recall preserved at every version).
* ``"incremental"`` — the paper-exact reference oracle: one beam-search
  insertion per (object, level), per-insertion re-pruning, exact Theorem
  D.1 labels. Kept selectable for equivalence tests and faithfulness runs.

The frozen index is a set of dense arrays per variant (DESIGN.md §2):

    nbr/lab_b/lab_e : (Lv, n, S)   per-level labeled adjacency
    sort_rank       : (n,)         version rank of each object (variant space)
    tkey            : (n,)         tree-key rank of each object
    entry_ids/ver   : (Lv, Kpad, E) per-(level,node) entry points
    members/mem_ver : (Lv, n)      per-level ids grouped by node, insertion order
    node_off        : (Lv, Kpad+1) member offsets per (level, node)

Three variants (§4.4): T (asc-l, tree on r), Tp (desc-r, tree on l),
Tpp (desc-l, tree on r). ``MSTGIndex`` builds the variants a predicate mask
needs and plans queries via Theorem 4.1.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.checkpoint import index_io

from . import intervals as iv
from . import segment_tree as st
from .api import IndexSpec
from .build import BUILDERS, bulk_insert_levels
from .parallel import pool_size, run_build_pool
from .hnsw import OPEN, NO_EDGE, LabeledLevelGraph
from .predicates import Predicate, as_mask
from .quant import QuantizedStore, check_storage_dtype, maybe_quantize

from repro.obs.log import get_logger

logger = get_logger(__name__)

# FrozenVariant array fields, in the order they are persisted.
_FV_ARRAYS = ("sort_rank", "tkey", "nbr", "lab_b", "lab_e",
              "entry_ids", "entry_ver", "members", "member_ver", "node_off")
_INDEX_FORMAT = "mstg-index"
_INDEX_FORMAT_VERSION = 1


@dataclasses.dataclass
class FrozenVariant:
    variant: str
    K: int
    Kpad: int
    Lv: int
    n: int
    sort_rank: np.ndarray
    tkey: np.ndarray
    nbr: np.ndarray
    lab_b: np.ndarray
    lab_e: np.ndarray
    entry_ids: np.ndarray
    entry_ver: np.ndarray
    members: np.ndarray
    member_ver: np.ndarray
    node_off: np.ndarray

    def nbytes(self) -> int:
        return sum(getattr(self, f).nbytes for f in
                   ("sort_rank", "tkey", "nbr", "lab_b", "lab_e",
                    "entry_ids", "entry_ver", "members", "member_ver", "node_off"))

    def live_edges(self) -> int:
        return int((self.nbr != NO_EDGE).sum())


def _variant_ranks(variant: str, rl: np.ndarray, rr: np.ndarray, K: int):
    top = K - 1
    if variant == iv.VARIANT_T:
        return rl.astype(np.int32), rr.astype(np.int32)
    if variant == iv.VARIANT_TP:
        return (top - rr).astype(np.int32), rl.astype(np.int32)
    if variant == iv.VARIANT_TPP:
        return (top - rl).astype(np.int32), rr.astype(np.int32)
    raise ValueError(f"unknown variant {variant}")


def _insert_incremental(vectors: np.ndarray, order: np.ndarray,
                        sort_rank: np.ndarray, tkey: np.ndarray, Lv: int, *,
                        m: int, ef_con: int, m_max: Optional[int],
                        n_entries: int, progress: Optional[int],
                        variant: str) -> List[LabeledLevelGraph]:
    """The paper-exact oracle: one beam-search insertion per (object, level)
    (Algorithm 3 verbatim), per-insertion RNG re-pruning, exact labels."""
    n = int(order.shape[0])
    levels = [LabeledLevelGraph(vectors, m=m, ef_con=ef_con, m_max=m_max,
                                n_entries=n_entries) for _ in range(Lv)]
    t0 = time.perf_counter()
    for i, u in enumerate(order):
        u = int(u)
        ver = int(sort_rank[u])
        key = int(tkey[u])
        for lvl in range(Lv):
            node = key >> (Lv - 1 - lvl)
            levels[lvl].insert(u, node, ver)
        if progress and (i + 1) % progress == 0:
            logger.progress("insert", variant=variant, done=i + 1, total=n,
                            elapsed_s=time.perf_counter() - t0,
                            final=(i + 1 == n))
    return levels


def build_scan_variant(rl: np.ndarray, rr: np.ndarray, K: int, variant: str,
                       n_entries: int = 4) -> FrozenVariant:
    """Scan-only MSTG construction (``builder="scan"``): the segment-tree
    member structure — members grouped per node in ascending version order,
    node offsets, entry seeds — without building any level graphs.

    The pruned route only touches ``members``/``member_ver``/``node_off``/
    ``sort_rank`` (plus the planner's domain), so this is everything it
    needs, built in O(Lv * n log n) numpy instead of the superlinear graph
    insertion pipeline — which makes pruned scans at n >= 100k feasible
    (the full build is ~108 s at n=20k). Adjacency freezes as a single
    all-``NO_EDGE`` slot: the *graph* route degrades to ranking the entry
    seeds and is not meaningfully served by a scan-built variant.
    """
    n = int(rl.shape[0])
    Kpad = st.padded_domain(K)
    Lv = st.num_levels(Kpad)
    E = n_entries
    sort_rank, tkey = _variant_ranks(variant, rl, rr, K)
    order = np.argsort(sort_rank, kind="stable")
    nbr = np.full((Lv, n, 1), NO_EDGE, np.int32)
    lab_b = np.zeros((Lv, n, 1), np.int32)
    lab_e = np.zeros((Lv, n, 1), np.int32)
    entry_ids = np.full((Lv, Kpad, E), NO_EDGE, np.int32)
    entry_ver = np.full((Lv, Kpad, E), OPEN, np.int32)
    members = np.zeros((Lv, n), np.int32)
    member_ver = np.full((Lv, n), OPEN, np.int32)
    node_off = np.zeros((Lv, Kpad + 1), np.int32)
    tk = tkey.astype(np.int64)
    for lvl in range(Lv):
        node = tk >> (Lv - 1 - lvl)
        # stable sort of the version-ordered rows by node keeps each node's
        # slice in ascending version order — the prefix invariant the
        # pruned scan's binary search relies on
        mem = order[np.argsort(node[order], kind="stable")]
        members[lvl] = mem
        member_ver[lvl] = sort_rank[mem]
        counts = np.bincount(node, minlength=Kpad)[:Kpad]
        node_off[lvl, 1:] = np.cumsum(counts).astype(np.int32)
        starts = node_off[lvl, :Kpad].astype(np.int64)
        for e_i in range(E):
            hasm = counts > e_i
            entry_ids[lvl, hasm, e_i] = members[lvl][starts[hasm] + e_i]
            entry_ver[lvl, hasm, e_i] = member_ver[lvl][starts[hasm] + e_i]
    return FrozenVariant(variant=variant, K=K, Kpad=Kpad, Lv=Lv, n=n,
                         sort_rank=sort_rank, tkey=tkey, nbr=nbr, lab_b=lab_b,
                         lab_e=lab_e, entry_ids=entry_ids, entry_ver=entry_ver,
                         members=members, member_ver=member_ver,
                         node_off=node_off)


def build_variant(vectors: np.ndarray, rl: np.ndarray, rr: np.ndarray, K: int,
                  variant: str, m: int = 16, ef_con: int = 100,
                  m_max: Optional[int] = None, n_entries: int = 4,
                  progress: Optional[int] = None, builder: str = "bulk",
                  batch_size: Optional[int] = None,
                  candidate_stage: str = "exact",
                  n_clusters: Optional[int] = None, n_probe: int = 8,
                  coarse_threshold: Optional[int] = None,
                  stats: Optional[dict] = None) -> FrozenVariant:
    """Algorithms 1+2: MSTG construction for one variant.

    ``builder="bulk"`` (default) batches candidate generation and pruning
    (:mod:`repro.core.build`); ``builder="incremental"`` is the paper-exact
    per-object reference path. Both freeze to the identical array schema.
    ``candidate_stage``/``n_clusters``/``n_probe``/``coarse_threshold``
    tune the bulk path's candidate generator (exact all-pairs vs coarse
    quantizer); ``stats`` (a dict) collects its wall-clock stage breakdown.
    """
    if builder == "scan":
        return build_scan_variant(rl, rr, K, variant, n_entries=n_entries)
    n = vectors.shape[0]
    Kpad = st.padded_domain(K)
    Lv = st.num_levels(Kpad)
    sort_rank, tkey = _variant_ranks(variant, rl, rr, K)
    order = np.argsort(sort_rank, kind="stable")

    if builder == "bulk":
        levels = bulk_insert_levels(vectors, order, sort_rank, tkey, Lv, m=m,
                                    ef_con=ef_con, m_max=m_max,
                                    n_entries=n_entries, batch_size=batch_size,
                                    progress=progress, variant=variant,
                                    candidate_stage=candidate_stage,
                                    n_clusters=n_clusters, n_probe=n_probe,
                                    coarse_threshold=coarse_threshold,
                                    stats=stats)
    elif builder == "incremental":
        levels = _insert_incremental(vectors, order, sort_rank, tkey, Lv, m=m,
                                     ef_con=ef_con, m_max=m_max,
                                     n_entries=n_entries, progress=progress,
                                     variant=variant)
    else:
        raise ValueError(f"unknown builder {builder!r}; expected one of "
                         f"{BUILDERS}")

    # freeze adjacency with a uniform slot count across levels
    t0 = time.perf_counter()
    S = max(max(g.max_slots(n) for g in levels), 1)
    nbr = np.empty((Lv, n, S), dtype=np.int32)
    lab_b = np.empty((Lv, n, S), dtype=np.int32)
    lab_e = np.empty((Lv, n, S), dtype=np.int32)
    for lvl, g in enumerate(levels):
        g.freeze(n, slots=S, out=(nbr[lvl], lab_b[lvl], lab_e[lvl]))
    if stats is not None:
        stats["freeze_s"] = (stats.get("freeze_s", 0.0)
                             + time.perf_counter() - t0)
        stats["slots"] = S

    t0 = time.perf_counter()
    E = n_entries
    entry_ids = np.full((Lv, Kpad, E), NO_EDGE, dtype=np.int32)
    entry_ver = np.full((Lv, Kpad, E), OPEN, dtype=np.int32)
    members = np.zeros((Lv, n), dtype=np.int32)
    member_ver = np.full((Lv, n), OPEN, dtype=np.int32)
    node_off = np.zeros((Lv, Kpad + 1), dtype=np.int32)
    for lvl, g in enumerate(levels):
        pos = 0
        counts = np.zeros(Kpad + 1, dtype=np.int64)
        for node in range(1 << lvl):
            mem = g.node_members.get(node, [])
            counts[node] = len(mem)
            if mem:
                vers = g.node_member_vers[node]
                members[lvl, pos:pos + len(mem)] = mem
                member_ver[lvl, pos:pos + len(mem)] = vers
                pos += len(mem)
                ent = mem[:E]
                entry_ids[lvl, node, :len(ent)] = ent
                entry_ver[lvl, node, :len(ent)] = vers[:len(ent)]
        node_off[lvl, 1:] = np.cumsum(counts[:-1])[:Kpad]
    if stats is not None:
        stats["pack_s"] = (stats.get("pack_s", 0.0)
                           + time.perf_counter() - t0)
    return FrozenVariant(variant=variant, K=K, Kpad=Kpad, Lv=Lv, n=n,
                         sort_rank=sort_rank, tkey=tkey, nbr=nbr, lab_b=lab_b,
                         lab_e=lab_e, entry_ids=entry_ids, entry_ver=entry_ver,
                         members=members, member_ver=member_ver, node_off=node_off)


def _variant_build_task(args):
    """Module-level worker body for parallel variant builds (spawn-context
    process pools need a picklable, importable callable)."""
    vectors, rl, rr, K, v, kwargs = args
    stats: dict = {}
    t0 = time.perf_counter()
    fv = build_variant(vectors, rl, rr, K, v, stats=stats, **kwargs)
    return v, fv, stats, time.perf_counter() - t0


class MSTGIndex:
    """The paper's index: builds the variants required by a predicate mask and
    plans queries per Theorem 4.1. Search execution lives in
    :mod:`repro.core.search` (graph engine) and :mod:`repro.core.flat` (exact
    block engine)."""

    def __init__(self, vectors: np.ndarray, lo: np.ndarray, hi: np.ndarray,
                 mask: int = iv.ANY_OVERLAP, variants: Optional[Sequence[str]] = None,
                 m: int = 16, ef_con: int = 100, m_max: Optional[int] = None,
                 n_entries: int = 4, domain: Optional[iv.AttributeDomain] = None,
                 progress: Optional[int] = None, builder: str = "bulk",
                 batch_size: Optional[int] = None,
                 storage_dtype: str = "float32",
                 candidate_stage: str = "exact",
                 n_clusters: Optional[int] = None, n_probe: int = 8,
                 coarse_threshold: Optional[int] = None, workers: int = 0):
        vectors = np.ascontiguousarray(vectors, dtype=np.float32)
        lo = np.asarray(lo, dtype=np.float64)
        hi = np.asarray(hi, dtype=np.float64)
        mask = as_mask(mask)  # Predicate | int | str, like every other entry
        if np.any(lo > hi):
            raise ValueError("object ranges must satisfy lo <= hi")
        self.vectors = vectors
        self.lo, self.hi = lo, hi
        self.domain = domain or iv.AttributeDomain.from_ranges(lo, hi)
        self.rl = self.domain.rank(lo)
        self.rr = self.domain.rank(hi)
        storage_dtype = check_storage_dtype(storage_dtype)
        self.params = dict(m=m, ef_con=ef_con, m_max=m_max, n_entries=n_entries,
                           builder=builder, batch_size=batch_size,
                           candidate_stage=candidate_stage,
                           n_clusters=n_clusters, n_probe=n_probe,
                           coarse_threshold=coarse_threshold)
        # quantize at build time (per index / per streaming segment — the
        # scales fit THIS corpus); None for float32
        self.storage = maybe_quantize(vectors, storage_dtype)
        if variants is None:
            variants = iv.variants_required(mask if mask else iv.ANY_OVERLAP)
        self.spec = IndexSpec(predicate=Predicate(mask), variants=tuple(variants),
                              m=m, ef_con=ef_con, m_max=m_max,
                              n_entries=n_entries, builder=builder,
                              batch_size=batch_size,
                              storage_dtype=storage_dtype,
                              candidate_stage=candidate_stage,
                              n_clusters=n_clusters, n_probe=n_probe,
                              coarse_threshold=coarse_threshold)
        self.build_seconds: Dict[str, float] = {}
        self.build_stats: Dict[str, dict] = {}
        self.build_workers = 0
        self.variants: Dict[str, FrozenVariant] = {}
        bv_kwargs = dict(m=m, ef_con=ef_con, m_max=m_max, n_entries=n_entries,
                         progress=progress, builder=builder,
                         batch_size=batch_size,
                         candidate_stage=candidate_stage,
                         n_clusters=n_clusters, n_probe=n_probe,
                         coarse_threshold=coarse_threshold)
        vlist = list(variants)
        results = run_build_pool(
            _variant_build_task,
            [(vectors, self.rl, self.rr, self.domain.K, v, bv_kwargs)
             for v in vlist],
            workers=int(workers or 0), label="variant")
        if results is not None:
            self.build_workers = pool_size(int(workers), len(vlist))
            for v, fv, stats, secs in results:
                self.variants[v] = fv
                self.build_stats[v] = stats
                self.build_seconds[v] = secs
        else:
            for v in vlist:
                stats: dict = {}
                t0 = time.perf_counter()
                self.variants[v] = build_variant(
                    vectors, self.rl, self.rr, self.domain.K, v, stats=stats,
                    **bv_kwargs)
                self.build_seconds[v] = time.perf_counter() - t0
                self.build_stats[v] = stats

    # ---- lifecycle ----
    @classmethod
    def build(cls, spec: IndexSpec, vectors: np.ndarray, lo: np.ndarray,
              hi: np.ndarray, domain: Optional[iv.AttributeDomain] = None,
              progress: Optional[int] = None, workers: int = 0) -> "MSTGIndex":
        """Declarative construction from an :class:`repro.core.api.IndexSpec`:
        the spec's predicate decides which variants are built (unless pinned),
        and the spec travels with the index through ``save()``/``load()``.
        ``workers > 1`` builds independent variants in a spawn process pool
        (an execution resource, so it is an argument here — not spec state)."""
        return cls(vectors, lo, hi, mask=spec.predicate.mask,
                   variants=spec.variants, m=spec.m, ef_con=spec.ef_con,
                   m_max=spec.m_max, n_entries=spec.n_entries,
                   domain=domain, progress=progress, builder=spec.builder,
                   batch_size=spec.batch_size,
                   storage_dtype=spec.storage_dtype,
                   candidate_stage=spec.candidate_stage,
                   n_clusters=spec.n_clusters, n_probe=spec.n_probe,
                   coarse_threshold=spec.coarse_threshold, workers=workers)

    def to_payload(self) -> Tuple[Dict[str, np.ndarray], dict]:
        """The persisted form: (arrays, meta). Embedders (e.g. the streaming
        segment format) may add their own arrays/meta keys on top before
        handing the payload to :mod:`repro.checkpoint.index_io`."""
        arrays = {"vectors": self.vectors,
                  "lo": self.lo, "hi": self.hi,
                  "domain_values": self.domain.values}
        if self.storage is not None:
            arrays.update(self.storage.to_arrays())
        meta = {"format": _INDEX_FORMAT, "format_version": _INDEX_FORMAT_VERSION,
                "storage_dtype": self.spec.storage_dtype,
                "spec": self.spec.to_dict(), "params": self.params,
                "build_seconds": {k: float(v) for k, v in
                                  self.build_seconds.items()},
                "build_stats": {k: {f: (float(x) if isinstance(x, float)
                                        else int(x))
                                    for f, x in v.items()}
                                for k, v in self.build_stats.items()},
                "variants": {}}
        for name, fv in self.variants.items():
            meta["variants"][name] = {"K": fv.K, "Kpad": fv.Kpad,
                                      "Lv": fv.Lv, "n": fv.n}
            for field in _FV_ARRAYS:
                arrays[f"{name}.{field}"] = getattr(fv, field)
        return arrays, meta

    @classmethod
    def from_payload(cls, arrays: Dict[str, np.ndarray], meta: dict,
                     path: str = "<payload>") -> "MSTGIndex":
        """Inverse of :meth:`to_payload`; missing arrays raise a clear
        :class:`repro.checkpoint.index_io.IndexIOError` naming the key."""
        if meta.get("format") != _INDEX_FORMAT:
            raise ValueError(f"{path}: not a {_INDEX_FORMAT} artifact")
        self = cls.__new__(cls)
        self.vectors = np.ascontiguousarray(
            index_io.take(arrays, "vectors", path), np.float32)
        self.lo = np.asarray(index_io.take(arrays, "lo", path), np.float64)
        self.hi = np.asarray(index_io.take(arrays, "hi", path), np.float64)
        self.domain = iv.AttributeDomain(
            index_io.take(arrays, "domain_values", path))
        self.rl = self.domain.rank(self.lo)
        self.rr = self.domain.rank(self.hi)
        self.params = dict(meta["params"])
        self.spec = IndexSpec.from_dict(meta["spec"])
        # pre-storage-tier artifacts have neither the spec field nor the code
        # arrays -> spec defaults to "float32" and storage stays None (old
        # files keep loading, served exactly). A quantized spec whose code
        # arrays are missing is re-quantized deterministically from the
        # float32 corpus (same min/max -> same codes).
        self.storage = None
        if self.spec.storage_dtype != "float32":
            self.storage = (QuantizedStore.from_arrays(self.spec.storage_dtype,
                                                       arrays)
                            or maybe_quantize(self.vectors,
                                              self.spec.storage_dtype))
        self.build_seconds = dict(meta.get("build_seconds", {}))
        self.build_stats = {k: dict(v) for k, v in
                            meta.get("build_stats", {}).items()}
        self.build_workers = 0
        self.variants = {}
        for name, scal in meta["variants"].items():
            self.variants[name] = FrozenVariant(
                variant=name, K=int(scal["K"]), Kpad=int(scal["Kpad"]),
                Lv=int(scal["Lv"]), n=int(scal["n"]),
                **{f: index_io.take(arrays, f"{name}.{f}", path)
                   for f in _FV_ARRAYS})
        return self

    def save(self, path: str) -> str:
        """Persist the whole serving artifact — corpus, ranges, attribute
        domain, every :class:`FrozenVariant` array, spec — to one atomic
        ``.npz`` (conventions of :mod:`repro.checkpoint.index_io`), so a
        serving process can :meth:`load` instead of rebuilding."""
        arrays, meta = self.to_payload()
        return index_io.save_npz_atomic(path, arrays, meta)

    @classmethod
    def load(cls, path: str) -> "MSTGIndex":
        """Reconstruct a saved index without rebuilding: search results are
        bit-identical to the freshly built index the file came from."""
        arrays, meta = index_io.load_npz(path)
        return cls.from_payload(arrays, meta, path=path)

    # ---- planning ----
    def plan(self, mask: int, ql: float, qh: float) -> List[iv.SearchTask]:
        tasks = iv.plan_searches(self.domain, mask, ql, qh)
        missing = {t.variant for t in tasks} - set(self.variants)
        if missing:
            raise ValueError(f"mask {iv.mask_name(mask)} needs variants {missing}; "
                             f"built: {sorted(self.variants)}")
        return tasks

    def plan_batch(self, mask: int, ql: np.ndarray, qh: np.ndarray) -> List[iv.PlanSlot]:
        """Vectorized planning: for a fixed mask the task *templates* (variant
        sequence) are query-independent; versions/key bounds vary per query.
        Returns a list of :class:`repro.core.intervals.PlanSlot` — tuples of
        (variant, version(Q,), key_lo(Q,), key_hi(Q,)) with no per-query
        Python (all searchsorted + arithmetic on (Q,) arrays)."""
        ql = np.asarray(ql, dtype=np.float64)
        qh = np.asarray(qh, dtype=np.float64)
        if np.any(ql > qh):
            raise ValueError("query ranges must satisfy ql <= qh")
        slots = iv.plan_batch_ranked(mask, self.domain.floor_rank(ql),
                                     self.domain.ceil_rank(ql),
                                     self.domain.floor_rank(qh),
                                     self.domain.ceil_rank(qh), self.domain.K)
        missing = {s.variant for s in slots} - set(self.variants)
        if missing:
            raise ValueError(f"mask {iv.mask_name(mask)} needs variants {missing}; "
                             f"built: {sorted(self.variants)}")
        return slots

    def index_bytes(self) -> int:
        return sum(v.nbytes() for v in self.variants.values())

    def storage_bytes(self) -> dict:
        """Per-tier byte accounting of the vector storage.

        ``codes``/``scales``/``sq_norm`` are what a compressed scan streams;
        ``float32_rerank`` is the exact corpus retained (host-side) for the
        re-rank step; ``graph`` is the variant structure
        (:meth:`index_bytes`). ``compression_ratio`` is the *scan-stream*
        ratio — float32 corpus bytes over the bytes the scan actually reads
        per pass — i.e. the bandwidth lever, not a total-RSS ratio.
        """
        full = int(self.vectors.nbytes)
        out = {"storage_dtype": self.spec.storage_dtype,
               "float32_rerank": full, "graph": self.index_bytes()}
        if self.storage is None:
            out.update(codes=0, scales=0, sq_norm=0,
                       scan_bytes=full, compression_ratio=1.0)
        else:
            bb = self.storage.bytes_breakdown()
            out.update(codes=bb["codes"], scales=bb["scales"],
                       sq_norm=bb["sq_norm"], scan_bytes=bb["total"],
                       compression_ratio=full / max(bb["total"], 1))
        return out

    def predicate_select(self, mask: int, ql: float, qh: float) -> np.ndarray:
        return np.asarray(iv.eval_predicate(mask, self.lo, self.hi,
                                            float(ql), float(qh)))
