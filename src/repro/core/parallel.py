"""Spawn-context process pools for CPU-bound index construction.

One helper shared by every parallel build site — variant-parallel
:class:`repro.core.mstg.MSTGIndex` builds, shard-parallel
:meth:`repro.distributed.ShardedDeployment.build`, and streaming segment
freezes. Uses the ``spawn`` start method only: the parent process usually
has JAX/XLA threads live by build time, and forking a threaded process is
deadlock-prone. Workers re-import the repro build modules (numpy-only on
the build path, so startup stays sub-second) and stream their own
rate-limited :mod:`repro.obs` progress lines to stderr; the parent
aggregates completion into one ``<label>_pool`` progress line per finished
task plus a per-task wall-clock report for bench attribution.

``run_build_pool`` degrades, never errors, on *pool* problems: if the
platform cannot spawn workers (sandboxes without process semaphores, broken
pools) it returns ``None`` and the caller runs its serial path. Exceptions
raised by the task function itself propagate unchanged.
"""
from __future__ import annotations

import multiprocessing
import time
from concurrent.futures import FIRST_COMPLETED, ProcessPoolExecutor, wait
from concurrent.futures.process import BrokenProcessPool
from typing import Any, Callable, List, Optional, Sequence

from repro.obs.log import get_logger

logger = get_logger(__name__)


def pool_size(workers: int, n_tasks: int) -> int:
    """Actual worker count a pool would use: capped by tasks, floor 0 when
    pooling is off (``workers <= 1`` means serial — one worker is never
    worth a process round-trip)."""
    return 0 if workers <= 1 or n_tasks <= 1 else min(int(workers), n_tasks)


def run_build_pool(fn: Callable[[Any], Any], tasks: Sequence[Any], *,
                   workers: int, label: str = "build",
                   timings: Optional[List[float]] = None
                   ) -> Optional[List[Any]]:
    """Run ``fn`` over ``tasks`` in a spawn process pool.

    Returns results in task order, or ``None`` when pooling is off/
    unavailable (the caller falls back to its serial loop). ``timings``,
    when given a list, receives each task's wall-clock seconds (task
    order) so callers can report per-worker build time.
    """
    n_pool = pool_size(workers, len(tasks))
    if n_pool == 0:
        return None
    try:
        ctx = multiprocessing.get_context("spawn")
        with ProcessPoolExecutor(max_workers=n_pool, mp_context=ctx) as ex:
            t_start = time.perf_counter()
            futs = {ex.submit(fn, t): i for i, t in enumerate(tasks)}
            out: List[Any] = [None] * len(tasks)
            secs: List[float] = [0.0] * len(tasks)
            pending = set(futs)
            done_n = 0
            while pending:
                done, pending = wait(pending, return_when=FIRST_COMPLETED)
                now = time.perf_counter() - t_start
                for f in done:
                    out[futs[f]] = f.result()
                    secs[futs[f]] = now  # queue wait + run, per completion
                    done_n += 1
                logger.progress(f"{label}_pool", done=done_n,
                                total=len(tasks), workers=n_pool,
                                elapsed_s=round(now, 3),
                                final=done_n == len(tasks))
    except (BrokenProcessPool, OSError, ImportError) as exc:
        # pool-level failure (no semaphores / spawn unavailable / worker
        # bootstrap died): degrade to the caller's serial path
        logger.warning(f"{label}_pool_unavailable", error=repr(exc),
                       workers=n_pool)
        return None
    if timings is not None:
        timings[:] = secs
    return out
