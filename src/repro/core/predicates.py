"""First-class RR predicate algebra — the declarative face of paper §2.

The paper's four atomic range-range relations (Fig. 1) plus the two Allen
disjoint relations (Appendix A) become small immutable objects that compose
with ``|`` into arbitrary disjunctions, replacing hand-assembled int bitmasks
at every public entry point:

    >>> pred = LeftOverlap() | QueryContained() | Before()
    >>> pred.mask
    19
    >>> pred.variants_required()
    ['Tpp', 'T']

Every :class:`Predicate` is a thin wrapper over the exact bitmask encoding of
:mod:`repro.core.intervals` — ``Predicate.from_mask(p.mask) == p`` and
``eval(repr(p))`` both round-trip, and ``Predicate.parse`` accepts everything
:func:`repro.core.intervals.parse_mask` does (``"1|2|<"``, ``"any_overlap"``,
raw integers). Engines only ever see ``.mask``, so the algebra adds zero
planning or execution cost.

Naming follows the object-vs-query reading used throughout the paper:
``QueryContained`` / ``Contains`` — the object range covers the query range
(case ②); ``ContainedBy`` / ``QueryContaining`` — the query range covers the
object range (case ④); ``Overlaps`` — any intersection (cases ①|②|③|④).
"""
from __future__ import annotations

from typing import List, Union

from . import intervals as iv

__all__ = [
    "Predicate", "LeftOverlap", "RightOverlap", "QueryContained",
    "QueryContaining", "Contains", "ContainedBy", "Overlaps", "Before",
    "After", "as_predicate", "as_mask",
]

PredicateLike = Union["Predicate", int, str]


class Predicate:
    """An immutable disjunction of atomic RR relations, backed by a bitmask.

    Compose with ``|`` (accepts other predicates, raw int masks, or parseable
    strings); compare with ``==``; feed anywhere the API expects a predicate.
    """

    __slots__ = ("_mask",)

    def __init__(self, mask: int = 0):
        mask = int(mask)
        if not 0 <= mask <= iv.FULL_MASK:
            raise ValueError(f"mask {mask} outside [0, {iv.FULL_MASK}]")
        object.__setattr__(self, "_mask", mask)

    # ---- identity ----
    @property
    def mask(self) -> int:
        """The exact :mod:`repro.core.intervals` bitmask this compiles to."""
        return self._mask

    @property
    def name(self) -> str:
        """Compact planner spelling, e.g. ``"1|2|<"`` (see ``mask_name``)."""
        return iv.mask_name(self._mask)

    def __eq__(self, other) -> bool:
        if isinstance(other, Predicate):
            return self._mask == other._mask
        if isinstance(other, int):
            return self._mask == other
        return NotImplemented

    def __hash__(self) -> int:
        # hash-consistent with the int equality above, so predicates and raw
        # masks interoperate as dict/set keys
        return hash(self._mask)

    def __bool__(self) -> bool:
        return self._mask != 0

    # ---- algebra ----
    def __or__(self, other: PredicateLike) -> "Predicate":
        return Predicate(self._mask | as_mask(other))

    __ror__ = __or__

    def __contains__(self, other: PredicateLike) -> bool:
        m = as_mask(other)
        return (self._mask & m) == m

    def atoms(self) -> List["Predicate"]:
        """The single-bit predicates whose disjunction equals ``self``."""
        return [Predicate(b) for b in _ATOM_ORDER if self._mask & b]

    # ---- round-trips ----
    @classmethod
    def from_mask(cls, mask: int) -> "Predicate":
        return cls(mask)

    @classmethod
    def parse(cls, text) -> "Predicate":
        """Parse any :func:`repro.core.intervals.parse_mask` spelling."""
        return cls(iv.parse_mask(text))

    def __repr__(self) -> str:
        if self._mask == 0:
            return "Predicate(0)"
        if self._mask & iv.ANY_OVERLAP == iv.ANY_OVERLAP:
            parts = ["Overlaps()"]
            rest = self._mask & ~iv.ANY_OVERLAP
        else:
            parts, rest = [], self._mask
        parts += [_ATOM_REPR[b] for b in _ATOM_ORDER if rest & b]
        return " | ".join(parts)

    # ---- planner hooks ----
    def variants_required(self) -> List[str]:
        """Which MSTG variants an index must build to serve this predicate."""
        return iv.variants_required(self._mask)

    def evaluate(self, lo, hi, ql, qh):
        """Vectorized truth against object ranges (numpy or jax arrays)."""
        return iv.eval_predicate(self._mask, lo, hi, ql, qh)


class _Atom(Predicate):
    """Fixed-mask predicate constructed with no arguments (``LeftOverlap()``)."""

    __slots__ = ()
    _MASK = 0

    def __init__(self):
        super().__init__(type(self)._MASK)


class LeftOverlap(_Atom):
    """Case ①: object starts before the query and ends inside it."""
    _MASK = iv.LEFT_OVERLAP


class QueryContained(_Atom):
    """Case ②: the object range covers the whole query range."""
    _MASK = iv.QUERY_CONTAINED


class RightOverlap(_Atom):
    """Case ③: object starts inside the query and ends after it."""
    _MASK = iv.RIGHT_OVERLAP


class QueryContaining(_Atom):
    """Case ④: the query range covers the whole object range."""
    _MASK = iv.QUERY_CONTAINING


class Before(_Atom):
    """Allen ``<``: the whole object lies strictly after the query."""
    _MASK = iv.BEFORE


class After(_Atom):
    """Allen ``>``: the whole object lies strictly before the query."""
    _MASK = iv.AFTER


class Overlaps(_Atom):
    """Any intersection between object and query range (①|②|③|④)."""
    _MASK = iv.ANY_OVERLAP


# Semantic aliases (object-centric reading).
Contains = QueryContained      # object ⊇ query
ContainedBy = QueryContaining  # object ⊆ query

_ATOM_ORDER = (iv.LEFT_OVERLAP, iv.QUERY_CONTAINED, iv.RIGHT_OVERLAP,
               iv.QUERY_CONTAINING, iv.BEFORE, iv.AFTER)
_ATOM_REPR = {
    iv.LEFT_OVERLAP: "LeftOverlap()",
    iv.QUERY_CONTAINED: "QueryContained()",
    iv.RIGHT_OVERLAP: "RightOverlap()",
    iv.QUERY_CONTAINING: "QueryContaining()",
    iv.BEFORE: "Before()",
    iv.AFTER: "After()",
}


def as_mask(pred: PredicateLike) -> int:
    """Normalize a Predicate | int | string to the engine bitmask."""
    if isinstance(pred, Predicate):
        return pred.mask
    return iv.parse_mask(pred)


def as_predicate(pred: PredicateLike) -> Predicate:
    """Normalize a Predicate | int | string to a :class:`Predicate`."""
    if isinstance(pred, Predicate):
        return pred
    return Predicate(iv.parse_mask(pred))
