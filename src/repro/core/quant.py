"""Scalar-quantized vector storage tier (int8 / float16) with exact re-rank.

At millions of vectors the float32 corpus dominates memory *and* bandwidth:
every route — flat scan, pruned scan, graph beam — is a streaming read of
vector rows, so shrinking the bytes per row is a direct speedup on any
bandwidth-bound backend. This module holds the storage side of that trade:

* ``int8`` — per-dimension min/max affine quantization. For dimension ``d``
  with corpus range ``[vmin_d, vmax_d]``::

      scale_d  = (vmax_d - vmin_d) / 254        (1.0 when the range is 0)
      code     = round((x - vmin_d) / scale_d) - 127     in [-127, 127]
      offset_d = vmin_d + 127 * scale_d
      x_hat    = offset_d + scale_d * code

  Codes are symmetric around 0 so integer dot products (the Pallas MXU
  path, ``preferred_element_type=int32``) need no zero-point correction,
  and constant dimensions reconstruct exactly. 4x smaller than float32.
* ``float16`` — plain downcast; ``scale``/``offset`` are identity
  (ones/zeros) so every downstream consumer handles both tiers uniformly.
  2x smaller, reconstruction error ~1e-3 relative.

Alongside the codes the store precomputes ``sq_norm[i] = ||x_hat_i||^2``
(float32), which turns the scan distance into

    ||q - x_hat||^2 = ||q||^2 - 2 q·x_hat + sq_norm
                    = (||q||^2 - 2 q·offset) - 2 (q*scale)·code + sq_norm

— one fused (Q, n) code matmul plus rank-1 corrections, with no dequantized
copy of the corpus ever materialized.

Quantization is *lossy on the scan, exact on the answer*: the engine scans
codes to a top-``rerank_k`` candidate list and re-ranks those rows against
the retained float32 corpus (:mod:`repro.core.compressed`), so end recall
is preserved. The float32 rows are kept host-side only — they never occupy
accelerator memory on the quantized path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

#: Accepted ``storage_dtype`` spellings, in decreasing precision order.
STORAGE_DTYPES = ("float32", "float16", "int8")

_ITEMSIZE = {"int8": 1, "float16": 2, "float32": 4}


def check_storage_dtype(dtype: Optional[str]) -> str:
    """Validate and normalize a ``storage_dtype`` knob (None -> float32)."""
    dtype = dtype or "float32"
    if dtype not in STORAGE_DTYPES:
        raise ValueError(f"storage_dtype must be one of {STORAGE_DTYPES}, "
                         f"got {dtype!r}")
    return dtype


@dataclasses.dataclass
class QuantizedStore:
    """Compressed codes + affine dequantization parameters for one corpus
    (or one streaming segment — each segment quantizes against its own
    min/max, so flush/compact re-fit the scales to the surviving rows)."""

    dtype: str                # "int8" | "float16"
    codes: np.ndarray         # (n, d) int8 or float16
    scale: np.ndarray         # (d,) float32 (ones for float16)
    offset: np.ndarray        # (d,) float32 (zeros for float16)
    sq_norm: np.ndarray       # (n,) float32: ||dequantize(codes)||^2

    @classmethod
    def from_vectors(cls, vectors: np.ndarray, dtype: str) -> "QuantizedStore":
        vectors = np.ascontiguousarray(vectors, np.float32)
        n, d = vectors.shape
        if dtype == "float16":
            codes = vectors.astype(np.float16)
            scale = np.ones(d, np.float32)
            offset = np.zeros(d, np.float32)
            deq = codes.astype(np.float32)
        elif dtype == "int8":
            if n == 0:
                vmin = np.zeros(d, np.float32)
                span = np.zeros(d, np.float32)
            else:
                vmin = vectors.min(axis=0)
                span = vectors.max(axis=0) - vmin
            scale = np.where(span > 0, span / 254.0, 1.0).astype(np.float32)
            codes = (np.rint((vectors - vmin) / scale) - 127.0)
            codes = np.clip(codes, -127, 127).astype(np.int8)
            offset = (vmin + 127.0 * scale).astype(np.float32)
            deq = offset + scale * codes.astype(np.float32)
        else:
            raise ValueError(f"no quantized tier for dtype {dtype!r} "
                             f"(float32 means: no QuantizedStore)")
        sq_norm = np.einsum("nd,nd->n", deq, deq).astype(np.float32)
        return cls(dtype=dtype, codes=codes, scale=scale, offset=offset,
                   sq_norm=sq_norm)

    # ---- reconstruction ----
    def dequantize(self, rows: Optional[np.ndarray] = None) -> np.ndarray:
        """Reconstructed float32 vectors (``x_hat``); optionally a row
        subset. This is what every scan distance is computed against."""
        codes = self.codes if rows is None else self.codes[rows]
        return self.offset + self.scale * codes.astype(np.float32)

    @property
    def itemsize(self) -> int:
        """Bytes per stored component — the router's scan-cost ratio vs
        float32 is ``itemsize / 4``."""
        return _ITEMSIZE[self.dtype]

    # ---- accounting ----
    @property
    def nbytes(self) -> int:
        return int(self.codes.nbytes + self.scale.nbytes
                   + self.offset.nbytes + self.sq_norm.nbytes)

    def bytes_breakdown(self) -> Dict[str, int]:
        """Per-tier byte split of what the compressed scan actually streams:
        ``codes`` (the (n, d) code matrix), ``scales`` (per-dim scale +
        offset), ``sq_norm`` (per-row norms)."""
        return {"codes": int(self.codes.nbytes),
                "scales": int(self.scale.nbytes + self.offset.nbytes),
                "sq_norm": int(self.sq_norm.nbytes),
                "total": self.nbytes}

    # ---- persistence (embedded into the index .npz payload) ----
    def to_arrays(self) -> Dict[str, np.ndarray]:
        return {"codes": self.codes, "code_scale": self.scale,
                "code_offset": self.offset, "code_sq_norm": self.sq_norm}

    @classmethod
    def from_arrays(cls, dtype: str,
                    arrays: Dict[str, np.ndarray]) -> Optional["QuantizedStore"]:
        """Rehydrate from payload arrays; returns None when the artifact
        predates the storage tier (no ``codes`` key) — callers fall back to
        float32 (old artifacts keep loading)."""
        if "codes" not in arrays:
            return None
        return cls(dtype=dtype,
                   codes=np.asarray(arrays["codes"]),
                   scale=np.asarray(arrays["code_scale"], np.float32),
                   offset=np.asarray(arrays["code_offset"], np.float32),
                   sq_norm=np.asarray(arrays["code_sq_norm"], np.float32))


def maybe_quantize(vectors: np.ndarray,
                   dtype: Optional[str]) -> Optional[QuantizedStore]:
    """``None`` for float32 (no compression), a :class:`QuantizedStore`
    otherwise. The single entry point used by build/flush/compact and by
    the engine's on-the-fly override path."""
    dtype = check_storage_dtype(dtype)
    if dtype == "float32":
        return None
    return QuantizedStore.from_vectors(vectors, dtype)
