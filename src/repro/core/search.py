"""Batched MSTG graph search in JAX (paper Algorithm 4, generalized §4.1/§4.4).

TPU-native execution of the paper's search: one ``lax.while_loop`` advances a
whole query batch; each step expands the closest unexpanded pool vertex per
query with

    1. one gather from the per-level labeled adjacency (the decomposition nodes
       are disjoint, so a vertex's neighbors live at exactly one level),
    2. label masking  b <= version <= e  (this IS the paper's "never traverse a
       non-qualifying vertex" guarantee — edges only connect qualifying members),
    3. a batched distance evaluation (Pallas kernel on TPU, jnp fallback), and
    4. a sorted pool merge (keep the L best).

Termination matches Algorithm 4: a query is done when its L best are all
expanded. Results for two-task plans (Theorem 4.1) are merged with id-dedupe.
"""
from __future__ import annotations

import functools
from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp

from . import segment_tree as st
from .hnsw import NO_EDGE
from .mstg import FrozenVariant

INF = jnp.inf


class DeviceVariant:
    """FrozenVariant arrays staged on device."""

    def __init__(self, fv: FrozenVariant, vectors: np.ndarray):
        self.meta = fv
        self.vectors = jnp.asarray(vectors, jnp.float32)
        self.sort_rank = jnp.asarray(fv.sort_rank)
        self.tkey = jnp.asarray(fv.tkey)
        self.nbr = jnp.asarray(fv.nbr)
        self.lab_b = jnp.asarray(fv.lab_b)
        self.lab_e = jnp.asarray(fv.lab_e)
        self.entry_ids = jnp.asarray(fv.entry_ids)
        self.entry_ver = jnp.asarray(fv.entry_ver)
        self.members = jnp.asarray(fv.members)
        self.member_ver = jnp.asarray(fv.member_ver)
        self.node_off = jnp.asarray(fv.node_off)

    def tree(self):
        return dict(vectors=self.vectors, sort_rank=self.sort_rank,
                    tkey=self.tkey, nbr=self.nbr, lab_b=self.lab_b,
                    lab_e=self.lab_e, entry_ids=self.entry_ids,
                    entry_ver=self.entry_ver, members=self.members,
                    member_ver=self.member_ver, node_off=self.node_off)


def _batched_l2(queries: jnp.ndarray, cand_vecs: jnp.ndarray) -> jnp.ndarray:
    """(Q, d) x (Q, S, d) -> (Q, S) squared L2. jnp fallback; the Pallas path
    is selected in repro.kernels.ops."""
    diff = cand_vecs - queries[:, None, :]
    return jnp.einsum("qsd,qsd->qs", diff, diff)


@functools.partial(jax.jit, static_argnames=("k", "ef", "max_steps", "Kpad",
                                              "use_kernel", "fanout",
                                              "with_steps"))
def mstg_graph_search(arrays: dict, queries: jnp.ndarray, version: jnp.ndarray,
                      key_lo: jnp.ndarray, key_hi: jnp.ndarray, *, k: int,
                      ef: int, max_steps: int, Kpad: int,
                      use_kernel: bool = False, fanout: int = 1,
                      with_steps: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Batched beam search on one MSTG variant.

    arrays   : DeviceVariant.tree()
    queries  : (Q, d) float32
    version  : (Q,) int32 — max valid sort rank (< 0 => empty task)
    key_lo/hi: (Q,) int32 — inclusive tree-key range (lo > hi => empty)
    fanout   : frontier vertices expanded per loop step (beyond-paper: TPU
               amortizes loop latency over fanout x S distance evals; see
               EXPERIMENTS.md §Perf)
    returns  : ids (Q, k) int32 (NO_EDGE pad), dists (Q, k) float32 (+inf pad)
    """
    vectors = arrays["vectors"]
    tkey = arrays["tkey"]
    nbr, lab_b, lab_e = arrays["nbr"], arrays["lab_b"], arrays["lab_e"]
    entry_ids, entry_ver = arrays["entry_ids"], arrays["entry_ver"]
    n = vectors.shape[0]
    Q = queries.shape[0]
    S = nbr.shape[2]
    L = ef
    version = version.astype(jnp.int32)

    if use_kernel:
        from repro.kernels import ops as kops
        dist_fn = lambda q, c: kops.gathered_l2(q, c)
    else:
        dist_fn = _batched_l2

    # --- decomposition nodes per query ---
    levels, idxs, valid = jax.vmap(lambda a, b: st.decompose_jax(a, b, Kpad))(key_lo, key_hi)
    P = levels.shape[1]

    # --- initial pool from per-node entry points ---
    ent = entry_ids[levels, idxs]            # (Q, P, E)
    ever = entry_ver[levels, idxs]           # (Q, P, E)
    ent_ok = valid[:, :, None] & (ent != NO_EDGE) & (ever <= version[:, None, None])
    ent = jnp.where(ent_ok, ent, 0).reshape(Q, -1)
    ent_ok = ent_ok.reshape(Q, -1)
    ed = dist_fn(queries, vectors[ent])
    ed = jnp.where(ent_ok, ed, INF)
    ent = jnp.where(ent_ok, ent, NO_EDGE)

    order = jnp.argsort(ed, axis=1)
    take = min(L, ent.shape[1])
    pool_ids = jnp.full((Q, L), NO_EDGE, jnp.int32)
    pool_d = jnp.full((Q, L), INF, jnp.float32)
    pool_ids = pool_ids.at[:, :take].set(
        jnp.take_along_axis(ent, order, 1)[:, :take].astype(jnp.int32))
    pool_d = pool_d.at[:, :take].set(jnp.take_along_axis(ed, order, 1)[:, :take])
    expanded = jnp.zeros((Q, L), bool)

    visited = jnp.zeros((Q, n), bool)
    qix = jnp.arange(Q)
    ent_safe = jnp.where(ent == NO_EDGE, 0, ent)
    visited = visited.at[qix[:, None], ent_safe].max(ent != NO_EDGE)

    def active_fn(pool_d, expanded):
        return jnp.any(~expanded & jnp.isfinite(pool_d), axis=1)

    def cond(state):
        pool_ids, pool_d, expanded, visited, step = state
        return (step < max_steps) & jnp.any(active_fn(pool_d, expanded))

    F = fanout

    def body(state):
        pool_ids, pool_d, expanded, visited, step = state
        frontier_d = jnp.where(expanded, INF, pool_d)
        # expand the F closest unexpanded pool vertices at once
        neg_fd, slot = jax.lax.top_k(-frontier_d, F)               # (Q, F)
        act = jnp.isfinite(-neg_fd)
        u = jnp.take_along_axis(pool_ids, slot, 1)                 # (Q, F)
        u_safe = jnp.where(act, u, 0)
        expanded = expanded.at[qix[:, None], slot].max(act)

        # which decomposition node covers u -> its level   (Q, F)
        start, end = st.node_ranges_jax(levels, idxs, Kpad)        # (Q, P)
        t = tkey[u_safe][..., None]                                # (Q, F, 1)
        inside = (valid[:, None, :] & (t >= start[:, None, :]) &
                  (t <= end[:, None, :]))                          # (Q, F, P)
        lvl = jnp.max(jnp.where(inside, levels[:, None, :], -1), axis=-1)
        lvl_safe = jnp.clip(lvl, 0, nbr.shape[0] - 1)
        tg = nbr[lvl_safe, u_safe].reshape(Q, F * S)               # (Q, F*S)
        b = lab_b[lvl_safe, u_safe].reshape(Q, F * S)
        e = lab_e[lvl_safe, u_safe].reshape(Q, F * S)
        ok = jnp.repeat(act & (lvl >= 0), S, axis=1) & (tg != NO_EDGE)
        ok &= (b <= version[:, None]) & (version[:, None] <= e)
        tg_safe = jnp.where(ok, tg, 0)
        # dedupe within the step: keep only the first occurrence of each id
        seen = visited[qix[:, None], tg_safe]
        if F > 1:
            first = jnp.ones_like(ok)
            srt = jnp.argsort(tg_safe, axis=1)
            tg_sorted = jnp.take_along_axis(tg_safe, srt, 1)
            dup_sorted = jnp.concatenate(
                [jnp.zeros((Q, 1), bool),
                 tg_sorted[:, 1:] == tg_sorted[:, :-1]], axis=1)
            inv = jnp.argsort(srt, axis=1)
            first = ~jnp.take_along_axis(dup_sorted, inv, 1)
            ok &= first
        new = ok & ~seen
        visited = visited.at[qix[:, None], tg_safe].max(new)

        nd = dist_fn(queries, vectors[tg_safe])
        nd = jnp.where(new, nd, INF)

        cat_ids = jnp.concatenate([pool_ids, jnp.where(new, tg, NO_EDGE)], axis=1)
        cat_d = jnp.concatenate([pool_d, nd], axis=1)
        cat_exp = jnp.concatenate([expanded, jnp.zeros((Q, F * S), bool)], axis=1)
        neg, order = jax.lax.top_k(-cat_d, L)
        pool_ids = jnp.take_along_axis(cat_ids, order, 1)
        pool_d = -neg
        expanded = jnp.take_along_axis(cat_exp, order, 1)
        return pool_ids, pool_d, expanded, visited, step + 1

    state = (pool_ids, pool_d, expanded, visited, jnp.array(0, jnp.int32))
    pool_ids, pool_d, expanded, visited, n_steps = jax.lax.while_loop(
        cond, body, state)
    if with_steps:
        return pool_ids[:, :k], pool_d[:, :k], n_steps
    return pool_ids[:, :k], pool_d[:, :k]


def merge_topk(ids_a, d_a, ids_b, d_b, k: int):
    """Merge two (Q, k) result sets, dropping duplicate ids (Theorem 4.1 plans
    may overlap at predicate boundaries)."""
    ids = jnp.concatenate([ids_a, ids_b], axis=1)
    d = jnp.concatenate([d_a, d_b], axis=1)
    order = jnp.argsort(d, axis=1)
    ids = jnp.take_along_axis(ids, order, 1)
    d = jnp.take_along_axis(d, order, 1)
    # mark duplicates of any earlier (closer) id
    dup = (ids[:, :, None] == ids[:, None, :])
    earlier = jnp.tril(jnp.ones((ids.shape[1], ids.shape[1]), bool), k=-1)
    is_dup = jnp.any(dup & earlier[None] & (ids[:, None, :] != NO_EDGE), axis=2)
    d = jnp.where(is_dup, INF, d)
    ids = jnp.where(is_dup, NO_EDGE, ids)
    order = jnp.argsort(d, axis=1)[:, :k]
    return jnp.take_along_axis(ids, order, 1), jnp.take_along_axis(d, order, 1)


# MSTGSearcher (the host-facing graph-path API) lives in repro.core.engine,
# built on the QueryEngine facade; this module keeps the device-level pieces.
