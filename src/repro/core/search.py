"""Wavefront MSTG graph search in JAX (paper Algorithm 4, generalized §4.1/§4.4).

TPU-native execution of the paper's search: a ``lax.while_loop`` advances a
whole query batch; each step expands the ``fanout`` closest unexpanded pool
vertices per query with

    1. one gather from the per-level labeled adjacency (the decomposition nodes
       are disjoint, so a vertex's neighbors live at exactly one level),
    2. label masking  b <= version <= e  (this IS the paper's "never traverse a
       non-qualifying vertex" guarantee — edges only connect qualifying members),
    3. a batched distance evaluation (Pallas kernel on TPU, jnp fallback), and
    4. a sorted pool merge (keep the L best).

Termination matches Algorithm 4: a query is done when its L best are all
expanded. Results for two-task plans (Theorem 4.1) are merged with id-dedupe.

Beyond the seed implementation, this module is a *wavefront engine*:

* **bit-packed visited sets** — the per-query visited structure is a
  ``(Q, ceil(n/32))`` uint32 bitmap instead of a dense ``(Q, n)`` bool array
  (8x smaller state, cheaper while-loop carries; ``packed=False`` keeps the
  dense reference path, property-tested bit-identical).
* **chunked execution + active-batch compaction** —
  :func:`mstg_graph_search_chunked` runs the loop in fixed-size step chunks
  and, between chunks, repacks the still-active query rows into a smaller
  power-of-two bucket, so converged queries stop paying gather + distance
  cost while the slowest queries finish. Per-row trajectories are
  independent, so chunked results are bit-identical to the single-loop ones.
* **fused merge kernel** — with ``use_kernel=True`` the per-step gather →
  distance → label-mask → pool-merge chain runs as one Pallas kernel
  (:mod:`repro.kernels.gathered_topk`) instead of a gather + einsum +
  concat + ``top_k(L + F*S)`` op chain.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs

from . import segment_tree as st
from .hnsw import NO_EDGE
from .mstg import FrozenVariant

INF = jnp.inf


class DeviceVariant:
    """FrozenVariant arrays staged on device.

    With ``store`` (a :class:`repro.core.quant.QuantizedStore`) the staged
    vector table is the int8/float16 *code* matrix plus its (d,) affine
    dequant params — the float32 corpus never reaches the device; the
    wavefront dequantizes gathered candidate rows on the fly and the engine
    re-ranks the final beam against the host-side float32 rows."""

    def __init__(self, fv: FrozenVariant, vectors: np.ndarray, store=None):
        self.meta = fv
        if store is not None:
            self.vectors = jnp.asarray(store.codes)
            self.vec_scale = jnp.asarray(store.scale, jnp.float32)
            self.vec_offset = jnp.asarray(store.offset, jnp.float32)
        else:
            self.vectors = jnp.asarray(vectors, jnp.float32)
            self.vec_scale = None
            self.vec_offset = None
        self.sort_rank = jnp.asarray(fv.sort_rank)
        self.tkey = jnp.asarray(fv.tkey)
        self.nbr = jnp.asarray(fv.nbr)
        self.lab_b = jnp.asarray(fv.lab_b)
        self.lab_e = jnp.asarray(fv.lab_e)
        self.entry_ids = jnp.asarray(fv.entry_ids)
        self.entry_ver = jnp.asarray(fv.entry_ver)
        self.members = jnp.asarray(fv.members)
        self.member_ver = jnp.asarray(fv.member_ver)
        self.node_off = jnp.asarray(fv.node_off)

    def tree(self):
        t = dict(vectors=self.vectors, sort_rank=self.sort_rank,
                 tkey=self.tkey, nbr=self.nbr, lab_b=self.lab_b,
                 lab_e=self.lab_e, entry_ids=self.entry_ids,
                 entry_ver=self.entry_ver, members=self.members,
                 member_ver=self.member_ver, node_off=self.node_off)
        # quant keys only exist on quantized layouts: their presence is
        # static per jit trace, so float32 programs are unchanged
        if self.vec_scale is not None:
            t["vec_scale"] = self.vec_scale
            t["vec_offset"] = self.vec_offset
        return t


def _tree_quant(arrays: dict):
    """(scale, offset) when ``arrays`` is a quantized layout, else None.
    Dict-key presence is resolved at trace time."""
    if "vec_scale" in arrays:
        return arrays["vec_scale"], arrays["vec_offset"]
    return None


def _gather_dequant(vectors, idx, quant):
    """Gather rows by index and, on quantized tables, apply the affine
    dequant to the gathered tile only (the full table stays compressed)."""
    cand = vectors[idx]
    if quant is None:
        return cand
    scale, offset = quant
    shape = (1,) * (cand.ndim - 1) + (-1,)
    return (cand.astype(jnp.float32) * scale.reshape(shape)
            + offset.reshape(shape))


def _batched_l2(queries: jnp.ndarray, cand_vecs: jnp.ndarray) -> jnp.ndarray:
    """(Q, d) x (Q, S, d) -> (Q, S) squared L2. jnp fallback; the Pallas path
    is selected in repro.kernels.ops."""
    diff = cand_vecs - queries[:, None, :]
    return jnp.einsum("qsd,qsd->qs", diff, diff)


def _dist_fn(use_kernel: bool):
    """The one candidate-distance dispatch shared by every driver (the
    single-shot search, the chunked init, and the chunk runner must stay on
    the same path for their bit-identity contract)."""
    if use_kernel:
        from repro.kernels import ops as kops
        return lambda q, c: kops.gathered_l2(q, c)
    return _batched_l2


# ---- bit-packed visited sets ------------------------------------------------

def packed_words(n: int) -> int:
    """uint32 words per query row of a packed visited bitmap (n/8 bytes)."""
    return (int(n) + 31) // 32


def _visited_init(Q: int, n: int, packed: bool):
    if packed:
        return jnp.zeros((Q, packed_words(n)), jnp.uint32)
    return jnp.zeros((Q, n), bool)


def _visited_get(visited, qix, ids, packed: bool):
    """(Q, M) bool: is each (clamped, >=0) id already visited in its row."""
    if packed:
        w = visited[qix[:, None], ids >> 5]
        return ((w >> (ids & 31).astype(jnp.uint32)) & jnp.uint32(1)) != 0
    return visited[qix[:, None], ids]


def _visited_set(visited, qix, ids, mark, packed: bool):
    """Set the bits for ``ids`` where ``mark``. Marked ids must be unique per
    row and not yet visited (the callers guarantee both), so the packed
    scatter-add touches each bit at most once and equals a scatter-OR."""
    if packed:
        bit = jnp.uint32(1) << (ids & 31).astype(jnp.uint32)
        upd = jnp.where(mark, bit, jnp.uint32(0))
        return visited.at[qix[:, None], ids >> 5].add(upd)
    return visited.at[qix[:, None], ids].max(mark)


def _first_occurrence(ids):
    """(Q, M) bool: True at the first occurrence of each value per row.
    O(M^2) pairwise compare — far cheaper than the sort/inverse-sort
    formulation for the small M = fanout * slots widths of the step loop."""
    eq = ids[:, :, None] == ids[:, None, :]
    earlier = jnp.tril(jnp.ones((ids.shape[1], ids.shape[1]), bool), k=-1)
    return ~jnp.any(eq & earlier[None], axis=2)


# ---- search state construction ----------------------------------------------

def _active_rows(pool_d, expanded):
    """A query is live while any finite pool entry is unexpanded."""
    return jnp.any(~expanded & jnp.isfinite(pool_d), axis=1)


def _plan_nodes(key_lo, key_hi, Kpad: int):
    """Per-query canonical decomposition + covered key ranges (loop-invariant,
    computed once and carried beside the mutable state)."""
    levels, idxs, valid = jax.vmap(
        lambda a, b: st.decompose_jax(a, b, Kpad))(key_lo, key_hi)
    start, end = st.node_ranges_jax(levels, idxs, Kpad)
    return levels, idxs, valid, start, end


def _init_state(vectors, entry_ids, entry_ver, queries, version,
                levels, idxs, valid, *, L: int, dist_fn, packed: bool,
                quant=None):
    """Initial pool from per-node entry points + visited marking."""
    Q = queries.shape[0]
    n = vectors.shape[0]
    ent = entry_ids[levels, idxs]            # (Q, P, E)
    ever = entry_ver[levels, idxs]           # (Q, P, E)
    ent_ok = valid[:, :, None] & (ent != NO_EDGE) & (ever <= version[:, None, None])
    ent = jnp.where(ent_ok, ent, 0).reshape(Q, -1)
    ent_ok = ent_ok.reshape(Q, -1)
    ed = dist_fn(queries, _gather_dequant(vectors, ent, quant))
    ed = jnp.where(ent_ok, ed, INF)
    ent = jnp.where(ent_ok, ent, NO_EDGE)

    order = jnp.argsort(ed, axis=1)
    take = min(L, ent.shape[1])
    pool_ids = jnp.full((Q, L), NO_EDGE, jnp.int32)
    pool_d = jnp.full((Q, L), INF, jnp.float32)
    pool_ids = pool_ids.at[:, :take].set(
        jnp.take_along_axis(ent, order, 1)[:, :take].astype(jnp.int32))
    pool_d = pool_d.at[:, :take].set(jnp.take_along_axis(ed, order, 1)[:, :take])
    expanded = jnp.zeros((Q, L), bool)

    qix = jnp.arange(Q)
    mark = ent != NO_EDGE
    ent_safe = jnp.where(mark, ent, 0)
    if packed:
        # entries across disjoint decomposition nodes are distinct vertices;
        # the dedupe is defensive (a duplicate would double-add its bit)
        sentinel = jnp.where(mark, ent, n + jnp.arange(ent.shape[1])[None, :])
        mark = mark & _first_occurrence(sentinel)
    visited = _visited_init(Q, n, packed)
    visited = _visited_set(visited, qix, ent_safe, mark, packed)
    alive_steps = jnp.zeros((Q,), jnp.int32)
    return pool_ids, pool_d, expanded, visited, alive_steps


def _make_body(vectors, tkey, nbr, lab_b, lab_e, queries, version,
               levels, idxs, valid, start, end, *, L: int, F: int,
               dist_fn, packed: bool, use_kernel: bool, quant=None):
    """The per-step wavefront body, shared by the single-shot and chunked
    drivers. State: (pool_ids, pool_d, expanded, visited, alive_steps, step)."""
    Q = queries.shape[0]
    S = nbr.shape[2]
    n = vectors.shape[0]
    qix = jnp.arange(Q)

    def body(state):
        pool_ids, pool_d, expanded, visited, alive_steps, step = state
        alive_steps = alive_steps + _active_rows(pool_d, expanded).astype(jnp.int32)
        frontier_d = jnp.where(expanded, INF, pool_d)
        # expand the F closest unexpanded pool vertices at once
        neg_fd, slot = jax.lax.top_k(-frontier_d, F)               # (Q, F)
        act = jnp.isfinite(-neg_fd)
        u = jnp.take_along_axis(pool_ids, slot, 1)                 # (Q, F)
        u_safe = jnp.where(act, u, 0)
        expanded = expanded.at[qix[:, None], slot].max(act)

        # which decomposition node covers u -> its level   (Q, F)
        t = tkey[u_safe][..., None]                                # (Q, F, 1)
        inside = (valid[:, None, :] & (t >= start[:, None, :]) &
                  (t <= end[:, None, :]))                          # (Q, F, P)
        lvl = jnp.max(jnp.where(inside, levels[:, None, :], -1), axis=-1)
        lvl_safe = jnp.clip(lvl, 0, nbr.shape[0] - 1)
        tg = nbr[lvl_safe, u_safe].reshape(Q, F * S)               # (Q, F*S)
        b = lab_b[lvl_safe, u_safe].reshape(Q, F * S)
        e = lab_e[lvl_safe, u_safe].reshape(Q, F * S)
        ok = jnp.repeat(act & (lvl >= 0), S, axis=1) & (tg != NO_EDGE)
        ok &= (b <= version[:, None]) & (version[:, None] <= e)
        tg_safe = jnp.where(ok, tg, 0)
        # dedupe within the step: keep only the first occurrence of each id
        # (one vertex's slot list never repeats a live target, so F == 1
        # needs no dedupe; across fanout rows targets can collide). Invalid
        # slots get out-of-range sentinels so they can never shadow the real
        # corpus vertex 0 (the 0-fill of tg_safe would).
        seen = _visited_get(visited, qix, tg_safe, packed)
        if F > 1:
            sentinel = jnp.where(
                ok, tg, n + jnp.arange(F * S, dtype=jnp.int32)[None, :])
            ok &= _first_occurrence(sentinel)
        new = ok & ~seen
        visited = _visited_set(visited, qix, tg_safe, new, packed)

        if use_kernel:
            from repro.kernels import ops as kops
            if quant is not None:
                pool_ids, pool_d, expanded = kops.gathered_topk_quant(
                    queries, vectors, quant[0], quant[1], tg, new, b, e,
                    version, pool_ids, pool_d, expanded)
            else:
                pool_ids, pool_d, expanded = kops.gathered_topk(
                    queries, vectors, tg, new, b, e, version,
                    pool_ids, pool_d, expanded)
        else:
            nd = dist_fn(queries, _gather_dequant(vectors, tg_safe, quant))
            nd = jnp.where(new, nd, INF)
            cat_ids = jnp.concatenate(
                [pool_ids, jnp.where(new, tg, NO_EDGE)], axis=1)
            cat_d = jnp.concatenate([pool_d, nd], axis=1)
            cat_exp = jnp.concatenate(
                [expanded, jnp.zeros((Q, F * S), bool)], axis=1)
            neg, order = jax.lax.top_k(-cat_d, L)
            pool_ids = jnp.take_along_axis(cat_ids, order, 1)
            pool_d = -neg
            expanded = jnp.take_along_axis(cat_exp, order, 1)
        return pool_ids, pool_d, expanded, visited, alive_steps, step + 1

    return body


# ---- single-shot driver (one jitted call, runs to global convergence) -------

@functools.partial(jax.jit, static_argnames=("k", "ef", "max_steps", "Kpad",
                                              "use_kernel", "fanout",
                                              "with_steps", "packed"))
def mstg_graph_search(arrays: dict, queries: jnp.ndarray, version: jnp.ndarray,
                      key_lo: jnp.ndarray, key_hi: jnp.ndarray, *, k: int,
                      ef: int, max_steps: int, Kpad: int,
                      use_kernel: bool = False, fanout: int = 1,
                      with_steps: bool = False,
                      packed: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Batched beam search on one MSTG variant.

    arrays   : DeviceVariant.tree()
    queries  : (Q, d) float32
    version  : (Q,) int32 — max valid sort rank (< 0 => empty task)
    key_lo/hi: (Q,) int32 — inclusive tree-key range (lo > hi => empty)
    fanout   : frontier vertices expanded per loop step (beyond-paper: TPU
               amortizes loop latency over fanout x S distance evals; see
               EXPERIMENTS.md §Perf)
    packed   : bit-packed (Q, ceil(n/32)) uint32 visited bitmap (default) vs
               the dense (Q, n) bool reference — bit-identical results
    returns  : ids (Q, k) int32 (NO_EDGE pad), dists (Q, k) float32 (+inf pad)
    """
    vectors = arrays["vectors"]
    quant = _tree_quant(arrays)
    version = version.astype(jnp.int32)
    L = ef
    dist_fn = _dist_fn(use_kernel)
    levels, idxs, valid, start, end = _plan_nodes(key_lo, key_hi, Kpad)
    pool_ids, pool_d, expanded, visited, alive_steps = _init_state(
        vectors, arrays["entry_ids"], arrays["entry_ver"], queries, version,
        levels, idxs, valid, L=L, dist_fn=dist_fn, packed=packed, quant=quant)

    body = _make_body(vectors, arrays["tkey"], arrays["nbr"], arrays["lab_b"],
                      arrays["lab_e"], queries, version, levels, idxs, valid,
                      start, end, L=L, F=fanout, dist_fn=dist_fn,
                      packed=packed, use_kernel=use_kernel, quant=quant)

    def cond(state):
        pool_ids, pool_d, expanded, visited, alive_steps, step = state
        return (step < max_steps) & jnp.any(_active_rows(pool_d, expanded))

    state = (pool_ids, pool_d, expanded, visited, alive_steps,
             jnp.array(0, jnp.int32))
    pool_ids, pool_d, expanded, visited, alive_steps, n_steps = \
        jax.lax.while_loop(cond, body, state)
    if with_steps:
        return pool_ids[:, :k], pool_d[:, :k], n_steps
    return pool_ids[:, :k], pool_d[:, :k]


# ---- chunked driver (wavefront compaction between chunks) -------------------

@functools.partial(jax.jit, static_argnames=("ef", "Kpad", "use_kernel",
                                              "packed"))
def _graph_init(arrays, queries, version, key_lo, key_hi, *, ef, Kpad,
                use_kernel, packed):
    version = version.astype(jnp.int32)
    dist_fn = _dist_fn(use_kernel)
    levels, idxs, valid, start, end = _plan_nodes(key_lo, key_hi, Kpad)
    pool_ids, pool_d, expanded, visited, alive_steps = _init_state(
        arrays["vectors"], arrays["entry_ids"], arrays["entry_ver"], queries,
        version, levels, idxs, valid, L=ef, dist_fn=dist_fn, packed=packed,
        quant=_tree_quant(arrays))
    nodes = (levels, idxs, valid, start, end)
    state = (pool_ids, pool_d, expanded, visited, alive_steps,
             jnp.array(0, jnp.int32))
    return nodes, state, _active_rows(pool_d, expanded)


@functools.partial(jax.jit, static_argnames=("ef", "Kpad", "use_kernel",
                                              "fanout", "packed"))
def _graph_chunk(arrays, queries, version, nodes, state, limit, *, ef, Kpad,
                 use_kernel, fanout, packed):
    """Advance ``state`` by up to ``limit`` (dynamic) steps, returning the new
    state, per-row active flags, and the number of steps actually run."""
    version = version.astype(jnp.int32)
    dist_fn = _dist_fn(use_kernel)
    levels, idxs, valid, start, end = nodes
    body = _make_body(arrays["vectors"], arrays["tkey"], arrays["nbr"],
                      arrays["lab_b"], arrays["lab_e"], queries, version,
                      levels, idxs, valid, start, end, L=ef, F=fanout,
                      dist_fn=dist_fn, packed=packed, use_kernel=use_kernel,
                      quant=_tree_quant(arrays))
    step0 = state[-1]
    bound = step0 + limit.astype(jnp.int32)

    def cond(state):
        pool_ids, pool_d, expanded, visited, alive_steps, step = state
        return (step < bound) & jnp.any(_active_rows(pool_d, expanded))

    state = jax.lax.while_loop(cond, body, state)
    return state, _active_rows(state[1], state[2]), state[-1] - step0


@jax.jit
def _gather_rows(tree, idx):
    """Row-compact a state pytree (retraces per (shape-in, bucket) pair; both
    are power-of-two bounded by the engine's padding policy)."""
    return jax.tree_util.tree_map(lambda a: a if a.ndim == 0 else a[idx], tree)


def _harvest(state, idx: np.ndarray, k: int):
    """Pull converged rows to host. Plain numpy slicing — harvest sets have
    arbitrary sizes, so a jitted version would retrace per size and grow the
    jit cache without bound on a serving path."""
    pool_ids, pool_d, expanded, visited, alive_steps, step = state
    return (np.asarray(pool_ids)[idx, :k], np.asarray(pool_d)[idx, :k],
            np.asarray(alive_steps)[idx])


def _next_pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


def mstg_graph_search_chunked(arrays: dict, queries, version, key_lo, key_hi,
                              *, k: int, ef: int, max_steps: int, Kpad: int,
                              use_kernel: bool = False, fanout: int = 1,
                              chunk: int = 16, min_bucket: int = 8,
                              packed: bool = True, with_stats: bool = False):
    """Wavefront driver: run the beam search in ``chunk``-step slices and
    compact the still-active rows to a power-of-two bucket between slices.

    Per-row trajectories are independent (a converged row's step is the
    identity), so results are bit-identical to :func:`mstg_graph_search` with
    the same parameters — compaction only stops converged queries from paying
    gather + distance cost while stragglers finish.

    Returns ``(ids, dists)`` as numpy arrays, plus a stats dict when
    ``with_stats`` (total steps, per-query convergence steps, executed vs
    useful candidate-evaluation counts).
    """
    queries = jnp.asarray(queries, jnp.float32)
    version = jnp.asarray(version, jnp.int32)
    key_lo = jnp.asarray(key_lo, jnp.int32)
    key_hi = jnp.asarray(key_hi, jnp.int32)
    k = min(k, ef)     # the beam holds ef entries (single-shot slices likewise)
    chunk = max(int(chunk), 1)   # chunk=0 ("single-loop") belongs to the
    Q = queries.shape[0]         # engine; here it would make zero progress
    S = arrays["nbr"].shape[2]
    kw = dict(ef=ef, Kpad=Kpad, use_kernel=use_kernel, packed=packed)

    out_ids = np.full((Q, k), NO_EDGE, np.int32)
    out_d = np.full((Q, k), np.inf, np.float32)
    conv_steps = np.zeros(Q, np.int64)

    nodes, state, active = _graph_init(arrays, queries, version, key_lo,
                                       key_hi, **kw)
    qs, ver = queries, version
    perm = np.arange(Q)                      # current row -> original query
    active_h = np.asarray(active)
    total = 0
    executed_row_steps = 0
    harvested = np.zeros(Q, bool)

    def harvest(rows: np.ndarray) -> None:
        if rows.size == 0:
            return
        ids_h, d_h, steps_h = _harvest(state, rows, k)
        orig = perm[rows]
        out_ids[orig] = ids_h
        out_d[orig] = d_h
        conv_steps[orig] = steps_h
        harvested[orig] = True

    while True:
        live = np.flatnonzero(active_h)
        done = np.flatnonzero(~active_h)
        # harvest rows not yet written (duplicated pad rows rewrite the same
        # values — their trajectories are copies of a live row's)
        harvest(done[~harvested[perm[done]]])
        if live.size == 0 or total >= max_steps:
            if live.size:
                harvest(live)                # truncated at the step budget
            break
        cur_Q = int(qs.shape[0])
        bucket = min(max(min_bucket, _next_pow2(live.size)), cur_Q)
        if bucket < cur_Q:
            pad = bucket - live.size
            idx = np.concatenate([live, live[:1].repeat(pad)]) if pad \
                else live
            idx_dev = jnp.asarray(idx)
            qs, ver, nodes, state = _gather_rows((qs, ver, nodes, state),
                                                 idx_dev)
            perm = perm[idx]
        limit = jnp.asarray(min(chunk, max_steps - total), jnp.int32)
        with obs.span("chunk") as csp:
            state, active, ran = _graph_chunk(arrays, qs, ver, nodes, state,
                                              limit, fanout=fanout, **kw)
            ran = int(ran)
            active_h = np.asarray(active)
            if obs.tracing():
                csp.set("rows", int(qs.shape[0])).set("live", int(live.size))
                csp.set("steps", ran)
                csp.set("evals_executed", int(qs.shape[0]) * ran * fanout * S)
        total += ran
        executed_row_steps += int(qs.shape[0]) * ran

    if obs.tracing():
        u = int(conv_steps.sum())
        obs.span("wavefront_totals").set("steps", total) \
            .set("evals_executed", executed_row_steps * fanout * S) \
            .set("evals_useful", u * fanout * S).stop()
    if not with_stats:
        return out_ids, out_d
    useful = int(conv_steps.sum())
    stats = {
        "steps": total,
        "conv_steps": conv_steps,
        "evals_executed": executed_row_steps * fanout * S,
        "evals_useful": useful * fanout * S,
        "wasted_eval_frac": (1.0 - useful / executed_row_steps
                             if executed_row_steps else 0.0),
    }
    return out_ids, out_d, stats


# ---- continuous-batching stream (slot refill between chunks) ---------------

def _tree_concat_rows(a, b):
    """Concatenate two state pytrees along the row axis; scalar leaves (the
    step counter) keep ``a``'s value — the counter only bounds chunk length,
    never a row's trajectory."""
    return jax.tree_util.tree_map(
        lambda x, y: x if x.ndim == 0 else jnp.concatenate([x, y], axis=0),
        a, b)


@jax.jit
def _refill_rows(old, new, idx):
    """Admit a newcomer block into a live batch: concat along rows, then
    gather ``idx`` — fused in ONE compiled computation. Eager per-leaf
    concatenates would each compile per (live, newcomer) shape pair, and
    those pairs depend on arrival timing, so a serving process would keep
    hitting fresh compiles mid-flight; fused, the retrace space is the
    power-of-two (old bucket, new block, out bucket) triples."""
    cat = _tree_concat_rows(old, new)
    return jax.tree_util.tree_map(
        lambda a: a if a.ndim == 0 else a[idx], cat)


class WavefrontStream:
    """Continuous-batching wavefront driver over one MSTG variant.

    The chunked driver (:func:`mstg_graph_search_chunked`) compacts converged
    rows *out* of the active batch; this driver additionally admits **newly
    arrived** queries *into* the freed slots between chunks — true continuous
    batching: the device batch stays near-full while individual queries enter
    and leave mid-flight.

    Correctness contract: per-row trajectories are independent (the step body
    is the identity for converged rows, and init/distance/merge are all
    row-local), so every query's ``(ids, dists)`` is **bit-identical** to
    running it alone through :func:`mstg_graph_search` /
    :func:`mstg_graph_search_chunked` with the same ``ef`` / ``fanout`` /
    ``packed`` / ``use_kernel`` / ``max_steps`` — regardless of which other
    queries shared its batch or when it was admitted (property-tested in
    ``tests/test_serving_async.py``).

    Usage::

        stream = WavefrontStream(dv.tree(), ef=64, Kpad=dv.meta.Kpad)
        stream.admit(tags, queries, version, key_lo, key_hi, max_steps=320)
        while not stream.idle:
            for tag, ids, dists, steps in stream.step():
                ...   # one converged (or budget-truncated) query

    ``tags`` are opaque non-negative ints the caller uses to route results;
    harvested rows return the full ``ef``-wide beam (slice ``[:k]`` for a
    request's k — a prefix slice, so per-request k costs nothing).

    Batch mechanics: rows live in power-of-two buckets (jit-cache reuse,
    same policy as the engine); ``max_bucket`` caps rows in flight and must
    be a power of two. Padding rows are empty-task or duplicated rows with
    ``tag -1`` — never harvested. The per-chunk step budget is
    ``min(chunk, min remaining budget over live rows)`` so a truncated query
    stops at *exactly* its ``max_steps``, matching solo execution bit for
    bit.

    Occupancy / refill accounting for the serving metrics layer:
    ``executed_row_steps`` (slots x steps paid), ``useful_row_steps``
    (per-row convergence steps actually needed), ``refills`` /
    ``refilled_rows`` (admissions into an already-running batch),
    ``occupancy_rows`` / ``occupancy_capacity`` (live rows vs bucket width
    summed per chunk).
    """

    def __init__(self, arrays: dict, *, ef: int, Kpad: int,
                 use_kernel: bool = False, fanout: int = 1, chunk: int = 16,
                 min_bucket: int = 8, max_bucket: int = 256,
                 packed: bool = True):
        if max_bucket < 1 or (max_bucket & (max_bucket - 1)):
            raise ValueError(f"max_bucket must be a power of two, got "
                             f"{max_bucket}")
        self.arrays = arrays
        self.ef = int(ef)
        self.fanout = max(1, int(fanout))
        self.chunk = max(1, int(chunk))
        self.min_bucket = min(int(min_bucket), max_bucket)
        self.max_bucket = int(max_bucket)
        self._kw = dict(ef=self.ef, Kpad=int(Kpad),
                        use_kernel=bool(use_kernel), packed=bool(packed))
        # pending admissions (host-side, FIFO)
        self._pending: list = []
        # in-flight device state; perm -1 marks pad/dead rows
        self._qs = self._ver = self._nodes = self._state = None
        self._perm = np.zeros(0, np.int64)
        self._steps_run = np.zeros(0, np.int64)
        self._budget = np.zeros(0, np.int64)
        self._active = np.zeros(0, bool)
        # cumulative counters (serving metrics)
        self.admitted = 0
        self.completed = 0
        self.refills = 0
        self.refilled_rows = 0
        self.chunks = 0
        self.executed_row_steps = 0
        self.useful_row_steps = 0
        self.occupancy_rows = 0
        self.occupancy_capacity = 0

    # ---- introspection ----
    @property
    def inflight(self) -> int:
        """Real (tagged) rows currently in the device batch."""
        return int((self._perm >= 0).sum())

    @property
    def n_pending(self) -> int:
        return len(self._pending)

    @property
    def idle(self) -> bool:
        return not self._pending and self.inflight == 0

    @property
    def refill_efficiency(self) -> float:
        """useful / executed row-steps (1.0 = every paid slot-step advanced
        an unconverged query)."""
        if not self.executed_row_steps:
            return 1.0
        return self.useful_row_steps / self.executed_row_steps

    # ---- admission ----
    def admit(self, tags, queries, version, key_lo, key_hi,
              max_steps) -> None:
        """Queue rows for admission at the next :meth:`step`. One entry per
        row; ``max_steps`` is scalar or per-row."""
        queries = np.ascontiguousarray(queries, np.float32)
        tags = np.asarray(tags, np.int64).ravel()
        version = np.asarray(version, np.int64).ravel()
        key_lo = np.asarray(key_lo, np.int64).ravel()
        key_hi = np.asarray(key_hi, np.int64).ravel()
        budget = np.broadcast_to(np.asarray(max_steps, np.int64),
                                 tags.shape).copy()
        if np.any(tags < 0):
            raise ValueError("tags must be >= 0 (-1 is the pad sentinel)")
        if np.any(budget < 1):
            raise ValueError("max_steps must be >= 1")
        for i in range(tags.shape[0]):
            self._pending.append((int(tags[i]), queries[i], int(version[i]),
                                  int(key_lo[i]), int(key_hi[i]),
                                  int(budget[i])))
        self.admitted += int(tags.shape[0])

    # ---- internals ----
    def _init_new(self, count: int):
        """Pop ``count`` pending rows, init their state padded to a
        power-of-two block (pad rows carry empty tasks: version -1,
        key_lo > key_hi — converged before their first step)."""
        rows = self._pending[:count]
        del self._pending[:count]
        Nb = max(self.min_bucket, _next_pow2(count))
        pad = Nb - count
        d = rows[0][1].shape[0]
        q = np.zeros((Nb, d), np.float32)
        ver = np.full(Nb, -1, np.int64)
        klo = np.ones(Nb, np.int64)
        khi = np.zeros(Nb, np.int64)
        perm = np.full(Nb, -1, np.int64)
        budget = np.zeros(Nb, np.int64)
        for i, (tag, qv, v, lo, hi, b) in enumerate(rows):
            q[i], ver[i], klo[i], khi[i] = qv, v, lo, hi
            perm[i], budget[i] = tag, b
        qs = jnp.asarray(q)
        vj = jnp.asarray(ver, jnp.int32)
        nodes, state, active = _graph_init(
            self.arrays, qs, vj, jnp.asarray(klo, jnp.int32),
            jnp.asarray(khi, jnp.int32), **self._kw)
        return (qs, vj, nodes, state, np.asarray(active), perm, budget,
                np.zeros(Nb, np.int64), pad)

    def _compose(self) -> bool:
        """Drop dead rows, admit pending ones into the freed slots, and
        repack to a power-of-two bucket. Returns True when a runnable batch
        exists."""
        keep_mask = ((self._perm >= 0) & self._active
                     & (self._steps_run < self._budget))
        keep = np.flatnonzero(keep_mask)
        n_live = keep.size
        n_new = min(len(self._pending), max(0, self.max_bucket - n_live))
        if n_live == 0 and n_new == 0:
            self._qs = self._ver = self._nodes = self._state = None
            self._perm = np.zeros(0, np.int64)
            self._active = np.zeros(0, bool)
            return False
        if n_new == 0:
            # no admissions: rebucket only when shrinking pays or a live-but-
            # finished (budget-exhausted) row must be evicted; dead inactive
            # rows ride along as identity steps, exactly like the chunked
            # driver's compaction policy
            cur = self._perm.shape[0]
            bucket = min(max(self.min_bucket, _next_pow2(n_live)), cur)
            zombies = bool(np.any(self._active & ~keep_mask))
            if bucket == cur and not zombies:
                return True
            idx, n_pad = self._pad_idx(keep, bucket,
                                       np.flatnonzero(~self._active))
            self._gather(idx, n_pad)
            return True
        if n_live:
            self.refills += 1
            self.refilled_rows += n_new
        (nqs, nver, nnodes, nstate, nactive, nperm, nbudget, nsteps,
         n_pad) = self._init_new(n_new)
        if n_live == 0:
            # nothing in flight survives: adopt the newcomer block as-is
            self._qs, self._ver = nqs, nver
            self._nodes, self._state = nnodes, nstate
            self._active, self._perm = nactive, nperm
            self._budget, self._steps_run = nbudget, nsteps
            return True
        # gather (kept live rows | newcomer rows | pads) from the virtual
        # concat [old; newcomer block] in one fused device call
        old_rows = self._perm.shape[0]
        active = np.concatenate([self._active, nactive])
        perm = np.concatenate([self._perm, nperm])
        budget = np.concatenate([self._budget, nbudget])
        steps = np.concatenate([self._steps_run, nsteps])
        bucket = max(self.min_bucket, _next_pow2(n_live + n_new))
        take = np.concatenate([keep, old_rows + np.arange(n_new)])
        idx, n_pad = self._pad_idx(take, bucket, np.flatnonzero(~active))
        self._qs, self._ver, self._nodes, self._state = _refill_rows(
            (self._qs, self._ver, self._nodes, self._state),
            (nqs, nver, nnodes, nstate), jnp.asarray(idx))
        self._active = active[idx]
        perm = perm[idx]
        if n_pad:
            perm[idx.size - n_pad:] = -1
        self._perm = perm
        self._budget = budget[idx]
        self._steps_run = steps[idx]
        return True

    @staticmethod
    def _pad_idx(take: np.ndarray, bucket: int, inactive: np.ndarray):
        """Row-index vector of length ``bucket``: the kept rows plus pad
        slots. Pads point at an inactive source row when one exists (zero
        marginal work: converged rows run the identity), else duplicate the
        first kept row. Returns ``(idx, n_pad)``."""
        pad = bucket - take.size
        if pad <= 0:
            return take, 0
        src = inactive[0] if inactive.size else take[0]
        return np.concatenate([take, np.full(pad, src, np.int64)]), pad

    def _gather(self, idx: np.ndarray, n_pad: int) -> None:
        idx_dev = jnp.asarray(idx)
        self._qs, self._ver, self._nodes, self._state = _gather_rows(
            (self._qs, self._ver, self._nodes, self._state), idx_dev)
        self._active = self._active[idx]
        perm = self._perm[idx]
        if n_pad:
            perm[idx.size - n_pad:] = -1
        self._perm = perm
        self._budget = self._budget[idx]
        self._steps_run = self._steps_run[idx]

    # ---- the serving loop entry point ----
    def step(self):
        """Compose (drop converged + refill from pending), run one chunk,
        and harvest rows that converged or exhausted their budget.

        Returns a list of ``(tag, ids, dists, steps)`` — ids/dists are the
        full ``ef``-wide beam (NO_EDGE / +inf padded), steps the row's
        convergence (or truncation) step count.
        """
        with obs.span("chunk") as csp:
            if not self._compose():
                return []
            real = self._perm >= 0
            live = real & self._active & (self._steps_run < self._budget)
            remaining = self._budget[live] - self._steps_run[live]
            limit = min(self.chunk, int(remaining.min())) if remaining.size \
                else self.chunk
            bucket = self._perm.shape[0]
            self.occupancy_rows += int(live.sum())
            self.occupancy_capacity += bucket
            self._state, active, ran = _graph_chunk(
                self.arrays, self._qs, self._ver, self._nodes, self._state,
                jnp.asarray(limit, jnp.int32), fanout=self.fanout, **self._kw)
            ran = int(ran)
            self._active = np.asarray(active)
            self._steps_run = self._steps_run + ran
            self.chunks += 1
            self.executed_row_steps += bucket * ran
            # harvest: converged, or truncated at exactly their step budget
            done = np.flatnonzero(real & (~self._active
                                          | (self._steps_run >= self._budget)))
            if obs.tracing():
                csp.set("live", int(live.sum())).set("bucket", bucket)
                csp.set("steps", ran).set("harvested", int(done.size))
                csp.set("occupancy", round(int(live.sum()) / bucket, 4))
            if done.size == 0:
                return []
            ids_h, d_h, steps_h = _harvest(self._state, done, self.ef)
            out = [(int(self._perm[r]), ids_h[j], d_h[j], int(steps_h[j]))
                   for j, r in enumerate(done)]
            self._perm[done] = -1
            self.completed += done.size
            self.useful_row_steps += int(steps_h.sum())
            return out

    def drain(self):
        """Run :meth:`step` until idle; returns every harvested row."""
        out = []
        while not self.idle:
            out.extend(self.step())
        return out


@functools.partial(jax.jit, static_argnames=("k",))
def merge_topk(ids_a, d_a, ids_b, d_b, k: int):
    """Merge two (Q, k) result sets, dropping duplicate ids (Theorem 4.1 plans
    may overlap at predicate boundaries). Jitted: the engine calls it on
    device arrays between plan slots."""
    ids = jnp.concatenate([ids_a, ids_b], axis=1)
    d = jnp.concatenate([d_a, d_b], axis=1)
    order = jnp.argsort(d, axis=1)
    ids = jnp.take_along_axis(ids, order, 1)
    d = jnp.take_along_axis(d, order, 1)
    # mark duplicates of any earlier (closer) id
    dup = (ids[:, :, None] == ids[:, None, :])
    earlier = jnp.tril(jnp.ones((ids.shape[1], ids.shape[1]), bool), k=-1)
    is_dup = jnp.any(dup & earlier[None] & (ids[:, None, :] != NO_EDGE), axis=2)
    d = jnp.where(is_dup, INF, d)
    ids = jnp.where(is_dup, NO_EDGE, ids)
    order = jnp.argsort(d, axis=1)[:, :k]
    return jnp.take_along_axis(ids, order, 1), jnp.take_along_axis(d, order, 1)


# The host-facing graph-path API is QueryEngine (repro.core.engine) with
# route="graph"; this module keeps the device-level pieces.
