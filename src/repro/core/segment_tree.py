"""Perfect-binary segment tree over the rank domain (paper §4.1–4.2).

The tree is *structural only* (paper: "a segment tree T^0 based on A without
objects"): node (level, idx) at level ``lvl`` (root = level 0) covers ranks
``[idx * W, (idx+1) * W - 1]`` with ``W = Kpad >> lvl`` and ``Kpad`` the padded
power-of-two domain size. Object membership lives in the per-level adjacency
arrays built by :mod:`repro.core.mstg`.

Key property used throughout the system: the canonical decomposition of any rank
range returns nodes that are pairwise disjoint in key space and number at most 2
per level — so every qualifying vertex belongs to exactly ONE decomposition node,
and per-LEVEL dense adjacency arrays give one-gather neighbor lookups on TPU.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

import jax
import jax.numpy as jnp


def padded_domain(K: int) -> int:
    """Smallest power of two >= K."""
    p = 1
    while p < K:
        p <<= 1
    return p


def num_levels(Kpad: int) -> int:
    return int(Kpad).bit_length()  # log2(Kpad) + 1 for powers of two


def node_range(level: int, idx: int, Kpad: int) -> Tuple[int, int]:
    w = Kpad >> level
    return idx * w, (idx + 1) * w - 1


def decompose(lo: int, hi: int, Kpad: int) -> List[Tuple[int, int]]:
    """Canonical cover of rank range [lo, hi] (inclusive) as (level, idx) nodes."""
    if lo > hi:
        return []
    lo = max(0, int(lo))
    hi = min(Kpad - 1, int(hi))
    if lo > hi:
        return []
    out = []
    a, b = lo + Kpad, hi + Kpad + 1  # half-open in heap coordinates
    while a < b:
        if a & 1:
            out.append(a)
            a += 1
        if b & 1:
            b -= 1
            out.append(b)
        a >>= 1
        b >>= 1
    nodes = []
    for h in out:
        level = h.bit_length() - 1
        nodes.append((level, h - (1 << level)))
    nodes.sort()
    return nodes


def max_cover_nodes(Kpad: int) -> int:
    """Static bound on decomposition size (2 emission slots per level)."""
    return 2 * num_levels(Kpad)


def decompose_jax(lo, hi, Kpad: int):
    """JIT-able canonical decomposition.

    Returns (levels, idxs, valid) int32 arrays of static length
    ``max_cover_nodes(Kpad)``. ``lo > hi`` yields an all-invalid result. Inputs
    may be traced scalars; they are clipped to [0, Kpad-1].
    """
    P = max_cover_nodes(Kpad)
    Lv = num_levels(Kpad)
    lo_raw, hi_raw = jnp.asarray(lo), jnp.asarray(hi)
    empty = (lo_raw > hi_raw) | (hi_raw < 0) | (lo_raw > Kpad - 1)
    lo = jnp.clip(lo, 0, Kpad - 1).astype(jnp.int32)
    hi = jnp.clip(hi, 0, Kpad - 1).astype(jnp.int32)
    a0 = jnp.where(empty, 2 * Kpad, lo + Kpad).astype(jnp.int32)
    b0 = jnp.where(empty, 2 * Kpad, hi + Kpad + 1).astype(jnp.int32)

    def body(i, carry):
        a, b, heaps = carry
        emit_a = (a < b) & ((a & 1) == 1)
        heaps = heaps.at[2 * i].set(jnp.where(emit_a, a, 0))
        a = a + emit_a.astype(jnp.int32)
        emit_b = (a < b) & ((b & 1) == 1)
        b = b - emit_b.astype(jnp.int32)
        heaps = heaps.at[2 * i + 1].set(jnp.where(emit_b, b, 0))
        return a >> 1, b >> 1, heaps

    heaps0 = jnp.zeros((P,), jnp.int32)
    _, _, heaps = jax.lax.fori_loop(0, Lv, body, (a0, b0, heaps0))
    valid = heaps > 0
    safe = jnp.maximum(heaps, 1)
    levels = (jnp.log2(safe.astype(jnp.float32)) + 1e-4).astype(jnp.int32)
    idxs = safe - (1 << levels).astype(jnp.int32)
    return (jnp.where(valid, levels, 0).astype(jnp.int32),
            jnp.where(valid, idxs, 0).astype(jnp.int32),
            valid)


def node_ranges_jax(levels, idxs, Kpad: int):
    """Inclusive key ranges covered by (levels, idxs) nodes."""
    w = (Kpad >> levels).astype(jnp.int32)
    start = idxs * w
    return start, start + w - 1


def leaf_path_nodes(key_rank: int, Kpad: int) -> List[Tuple[int, int]]:
    """All (level, idx) ancestors of the leaf for ``key_rank`` — the O(log|A|)
    nodes an insertion touches (paper Algorithm 1)."""
    Lv = num_levels(Kpad)
    return [(lvl, int(key_rank) >> (Lv - 1 - lvl)) for lvl in range(Lv)]


def level_shift(level: int, Kpad: int) -> int:
    return num_levels(Kpad) - 1 - level


def vertex_levels_for_cover(tkeys, levels, idxs, valid, Kpad: int):
    """For each vertex key in ``tkeys``, the level of the (unique) covering
    decomposition node, or -1 if uncovered. Vectorized: (..., P) comparison."""
    start, end = node_ranges_jax(levels, idxs, Kpad)         # (P,)
    t = tkeys[..., None]
    inside = valid & (t >= start) & (t <= end)               # (..., P)
    lvl = jnp.max(jnp.where(inside, levels, -1), axis=-1)
    return lvl.astype(jnp.int32)
