from .loader import TokenLoader
from .datasets import (RangeDataset, make_range_dataset, make_queries, relative_distance_error,
                       brute_force_topk, recall_at_k)
