"""Synthetic RRANN datasets (paper §5 protocol at laptop scale).

Vectors: mixture-of-Gaussians embeddings (clustered like real image/text
embeddings). Ranges: endpoints drawn over [0, span) from the paper's attribute
distributions (uniform / normal / poisson / longtail / zipf), Exp. 8. Queries:
vectors from held-out cluster samples; query ranges calibrated by bisection to
hit a target selectivity for a given RR mask (paper: "query ranges are randomly
determined according to the specified selectivity").
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core import intervals as iv


@dataclasses.dataclass
class RangeDataset:
    vectors: np.ndarray   # (n, d) float32
    lo: np.ndarray        # (n,)
    hi: np.ndarray        # (n,)
    queries: np.ndarray   # (Q, d) float32
    span: float

    @property
    def n(self) -> int:
        return self.vectors.shape[0]

    @property
    def d(self) -> int:
        return self.vectors.shape[1]


def _attr_values(n: int, dist: str, span: float, rng: np.random.Generator) -> np.ndarray:
    if dist == "uniform":
        v = rng.uniform(0, span, n)
    elif dist == "normal":
        v = np.clip(rng.normal(span / 2, span / 6, n), 0, span)
    elif dist == "poisson":
        v = np.minimum(rng.poisson(span / 3, n).astype(np.float64), span)
    elif dist == "longtail":
        v = np.minimum(rng.exponential(span / 5, n), span)
    elif dist == "zipf":
        z = rng.zipf(1.7, n).astype(np.float64)
        v = span * np.minimum(z, 1000.0) / 1000.0
    else:
        raise ValueError(f"unknown attribute distribution {dist}")
    return v


def make_range_dataset(n: int = 2000, d: int = 32, n_queries: int = 32,
                       clusters: int = 16, dist: str = "uniform",
                       span: float = 1000.0, max_width_frac: float = 0.25,
                       quantize: Optional[int] = None,
                       seed: int = 0) -> RangeDataset:
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 1, (clusters, d))
    assign = rng.integers(0, clusters, n)
    vectors = (centers[assign] + 0.35 * rng.normal(0, 1, (n, d))).astype(np.float32)
    qassign = rng.integers(0, clusters, n_queries)
    queries = (centers[qassign] + 0.35 * rng.normal(0, 1, (n_queries, d))).astype(np.float32)

    a = _attr_values(n, dist, span, rng)
    w = rng.uniform(0, span * max_width_frac, n)
    lo = np.minimum(a, np.clip(a + w * rng.choice([-1, 1], n), 0, span))
    hi = np.maximum(a, np.clip(a + w * rng.choice([-1, 1], n), 0, span))
    lo, hi = np.minimum(lo, hi), np.maximum(lo, hi)
    if quantize:
        # finite attribute domain |A| = quantize (paper Exp. 10 varies |A|)
        grid = np.linspace(0, span, quantize)
        lo = grid[np.clip(np.searchsorted(grid, lo), 0, quantize - 1)]
        hi = grid[np.clip(np.searchsorted(grid, hi), 0, quantize - 1)]
        lo, hi = np.minimum(lo, hi), np.maximum(lo, hi)
    return RangeDataset(vectors=vectors, lo=lo, hi=hi, queries=queries, span=span)


def make_queries(ds: RangeDataset, mask: int, selectivity: float,
                 n_queries: Optional[int] = None, tol: float = 0.35,
                 seed: int = 1) -> Tuple[np.ndarray, np.ndarray]:
    """Per-query (qlo, qhi) calibrated so that ~selectivity of objects satisfy
    ``mask``; bisection on the query width around a random center."""
    rng = np.random.default_rng(seed)
    Q = n_queries or ds.queries.shape[0]
    qlo = np.empty(Q)
    qhi = np.empty(Q)
    target = selectivity * ds.n
    # count(width) is not monotone for general masks (e.g. QUERY_CONTAINED
    # shrinks with width) -> probe a geometric width grid and keep the best.
    widths = np.concatenate([[0.0], np.geomspace(ds.span * 1e-4, ds.span, 28)])
    for qi in range(Q):
        best, best_err = (0.0, 0.0), np.inf
        for _ in range(8):  # retry centers until within tolerance
            c = rng.uniform(0, ds.span)
            for w in widths:
                a, b = max(0.0, c - w / 2), min(ds.span, c + w / 2)
                cnt = int(np.count_nonzero(iv.eval_predicate(mask, ds.lo, ds.hi, a, b)))
                err = abs(cnt - target)
                if err < best_err:
                    best, best_err = (a, b), err
            if best_err <= tol * target:
                break
        qlo[qi], qhi[qi] = best
    return qlo, qhi


def brute_force_topk(vectors: np.ndarray, lo: np.ndarray, hi: np.ndarray,
                     queries: np.ndarray, qlo: np.ndarray, qhi: np.ndarray,
                     mask: int, k: int) -> Tuple[np.ndarray, np.ndarray]:
    """Exact numpy ground truth (independent of the JAX flat engine)."""
    Q = queries.shape[0]
    ids = np.full((Q, k), -1, np.int64)
    ds = np.full((Q, k), np.inf)
    for qi in range(Q):
        sel = np.asarray(iv.eval_predicate(mask, lo, hi, qlo[qi], qhi[qi]))
        idx = np.nonzero(sel)[0]
        if idx.size == 0:
            continue
        diff = vectors[idx] - queries[qi]
        dist = np.einsum("nd,nd->n", diff, diff)
        order = np.argsort(dist, kind="stable")[:k]
        ids[qi, :order.size] = idx[order]
        ds[qi, :order.size] = dist[order]
    return ids, ds


def recall_at_k(found_ids: np.ndarray, true_ids: np.ndarray) -> float:
    """Plain Recall@k: |found ∩ true| / |true| averaged over queries with
    non-empty ground truth."""
    hit = 0
    total = 0
    for qi in range(true_ids.shape[0]):
        t = set(int(x) for x in true_ids[qi] if x >= 0)
        if not t:
            continue
        total += len(t)
        f = set(int(x) for x in found_ids[qi] if x >= 0)
        hit += len(t & f)
    return hit / max(total, 1)


def relative_distance_error(found_dists: np.ndarray, true_dists: np.ndarray
                            ) -> float:
    """RDE (paper Exp. 1 / Fig. 11): mean over queries of
    (1/k) * sum_i (d(q, p_i)/d(q, p_i*) - 1), on squared-L2-consistent
    distances (monotone-equivalent ranking; we report sqrt for L2)."""
    out = []
    for qi in range(true_dists.shape[0]):
        t = np.sqrt(np.maximum(true_dists[qi][np.isfinite(true_dists[qi])], 0))
        f = np.sqrt(np.maximum(found_dists[qi][:len(t)], 0))
        if t.size == 0:
            continue
        f = np.where(np.isfinite(f), f, np.nanmax(t) * 4 + 1e-9)
        out.append(np.mean(f / np.maximum(t, 1e-12) - 1.0))
    return float(np.mean(out)) if out else 0.0
