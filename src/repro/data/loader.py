"""Deterministic LM token pipeline: synthetic corpus, sharded batching with a
pure step->batch cursor (preemption-safe: resuming at step s replays batch s)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

import jax.numpy as jnp


@dataclasses.dataclass
class TokenLoader:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    n_docs: int = 512
    frontend: Optional[str] = None      # vision_stub | audio_stub
    n_frontend_tokens: int = 0
    frontend_dim: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # zipf-ish synthetic documents with local structure (bigram chains)
        self.trans = rng.integers(0, self.vocab, size=(self.vocab, 4))
        self.doc_starts = rng.integers(0, self.vocab, self.n_docs)

    def _doc_tokens(self, doc_id: int, length: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 1_000_003 + doc_id)
        out = np.empty(length, np.int32)
        t = self.doc_starts[doc_id % self.n_docs]
        for i in range(length):
            out[i] = t
            t = self.trans[t, rng.integers(0, 4)]
        return out

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        toks = np.stack([
            self._doc_tokens((step * self.batch + b) % self.n_docs,
                             self.seq_len + 1)
            for b in range(self.batch)])
        batch = {"tokens": jnp.asarray(toks[:, :-1]),
                 "labels": jnp.asarray(toks[:, 1:])}
        if self.frontend == "vision_stub":
            rng = np.random.default_rng(self.seed + 7 + step)
            batch["patches"] = jnp.asarray(rng.normal(
                0, 1, (self.batch, self.n_frontend_tokens, self.frontend_dim)
            ).astype(np.float32))
        elif self.frontend == "audio_stub":
            rng = np.random.default_rng(self.seed + 11 + step)
            batch["frames"] = jnp.asarray(rng.normal(
                0, 1, (self.batch, self.seq_len, self.frontend_dim)
            ).astype(np.float32))
        return batch
