from .topk import sharded_flat_topk, tournament_topk_merge, global_topk_merge
from .sharding import batch_spec, replicated, shard_or_replicate
