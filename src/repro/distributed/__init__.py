from .topk import (sharded_flat_topk, sharded_topk_merge,
                   tournament_topk_merge, global_topk_merge,
                   MERGE_SCHEDULES, resolve_merge)
from .sharding import batch_spec, replicated, shard_or_replicate
from .fault import HeartbeatRegistry
from .deployment import DeploymentSpec, ShardedDeployment
