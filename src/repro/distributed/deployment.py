"""ShardedDeployment — multi-device serving of RRANN search.

The corpus partitions across the shards of a device mesh
(:func:`repro.launch.mesh.make_mesh`); each :class:`repro.core.SearchRequest`
fans out to every shard, runs the *existing* per-shard routes locally (the
exact pruned scan, the wavefront graph search, or a whole streaming
:class:`repro.streaming.SegmentedIndex` per shard), and the per-shard top-k
lists are combined through the :mod:`repro.distributed.topk` merge schedules
— ``all_gather`` for small meshes, ``tournament`` ppermute for pod-scale
ones, or a host merge when no mesh is attached.

Three shard layouts:

* :meth:`ShardedDeployment.build` — contiguous corpus slices, one
  :class:`repro.core.MSTGIndex` + :class:`repro.core.QueryEngine` per shard
  (every engine route available per shard; local ids are rebased to global
  row ids).
* :meth:`ShardedDeployment.from_segmented` — an existing
  :class:`repro.streaming.SegmentedIndex`'s frozen segments dealt round-robin
  onto shards (the delta buffer rides on shard 0). A snapshot view: segments
  are shared, not copied, so mutate the source index and re-derive.
* :meth:`ShardedDeployment.flat` — raw corpus slices served by the exact
  flat scan. The only layout with a fully *fused* device path: one
  ``shard_map`` call (:func:`repro.distributed.topk.sharded_flat_topk`)
  computes local scans and the merge without ever materializing per-shard
  results on host — this is what the ``--scale`` bench lane measures.

Fan-in width: ``DeploymentSpec.per_shard_k`` caps how many candidates each
shard contributes to the merge. ``k' == k`` reproduces the single-device
answer exactly (every global top-k member lives in some shard's local
top-k); ``k' < k`` trades recall for merge traffic (bytes ∝ D·Q·k') — the
recall-QPS pareto knob the scale bench sweeps.

Fault handling (:mod:`repro.distributed.fault`): shards ping a
:class:`HeartbeatRegistry` on every answer; a shard marked failed
(:meth:`fail`), timed out past ``shard_timeout_s``, or raising mid-search
contributes only sentinel rows. The request still answers — a
degraded-recall :class:`repro.core.SearchResult` with the lost shards in
``report.missing_shards`` and ``result.degraded == True`` — never an error.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.core.api import (IndexSpec, RouteReport, SearchRequest,
                            SearchResult, ShardReport)
from repro.core.engine import EngineConfig, QueryEngine
from repro.core.flat import flat_search
from repro.core.hnsw import NO_EDGE
from repro.core.mstg import MSTGIndex
from repro.core.parallel import pool_size, run_build_pool

from .fault import HeartbeatRegistry
from .topk import resolve_merge, sharded_flat_topk, sharded_topk_merge

_MERGES = ("auto", "all_gather", "tournament", "host")


@dataclasses.dataclass(frozen=True)
class DeploymentSpec:
    """How a corpus deploys across shards — the distributed counterpart of
    :class:`repro.core.EngineConfig` (which it carries, one per-shard copy).

    Parameters
    ----------
    n_shards : int
        Shard count. Device merge schedules additionally need a mesh whose
        ``corpus_axis`` has exactly this size.
    corpus_axis : str
        Mesh axis the corpus partitions over.
    merge : str
        ``all_gather`` | ``tournament`` | ``host`` | ``auto``. ``auto``
        resolves to ``host`` without a mesh, ``all_gather`` for D <= 8, and
        ``tournament`` for power-of-two D > 8.
    per_shard_k : int
        Per-shard fan-in width k' (0 = the request's full k). ``k' == k`` is
        exact relative to single-device; smaller trades recall for merge
        bytes.
    engine : EngineConfig
        Config for every per-shard :class:`repro.core.QueryEngine`. This
        includes the quantized storage tier: ``EngineConfig(
        storage_dtype="int8", ...)`` gives every shard its own compressed
        code layout (each shard quantizes its corpus slice with its own
        per-dimension scales) plus the exact per-shard re-rank; the fused
        :meth:`ShardedDeployment.flat` layout is separate and always
        float32.
    index : IndexSpec, optional
        Build spec for :meth:`ShardedDeployment.build` shards (default
        ``IndexSpec()``).
    build_workers : int
        Process-pool width for :meth:`ShardedDeployment.build` — shard
        builds are independent, so ``build_workers > 1`` constructs them
        concurrently in spawn workers (each streams its own rate-limited
        build progress; the parent aggregates one pool line per finished
        shard). ``0``/``1`` = serial. An execution resource, not index
        state: it never changes the built shards, only the wall clock, and
        the pool degrades to the serial loop on platforms without process
        support.
    shard_timeout_s : float
        Heartbeat staleness beyond which a shard counts as lost.
    """

    n_shards: int = 1
    corpus_axis: str = "data"
    merge: str = "auto"
    per_shard_k: int = 0
    engine: EngineConfig = dataclasses.field(default_factory=EngineConfig)
    index: Optional[IndexSpec] = None
    build_workers: int = 0
    shard_timeout_s: float = 30.0

    def __post_init__(self):
        if self.n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if self.build_workers < 0:
            raise ValueError("build_workers must be >= 0 (0 = serial)")
        if self.merge not in _MERGES:
            raise ValueError(f"merge must be one of {_MERGES}, got "
                             f"{self.merge!r}")
        if self.per_shard_k < 0:
            raise ValueError("per_shard_k must be >= 0 (0 = full k)")
        if not isinstance(self.engine, EngineConfig):
            raise TypeError("engine must be an EngineConfig")

    def replace(self, **overrides) -> "DeploymentSpec":
        return dataclasses.replace(self, **overrides)


@dataclasses.dataclass
class _Shard:
    """One shard's serving state: a local engine plus the id rebase."""

    name: str
    engine: object                 # QueryEngine | SegmentedIndex | None(flat)
    n: int
    id_offset: Optional[int]       # local row -> global id shift; None = the
    #                                engine already returns external ids


def _shard_build_task(args):
    """Module-level worker body for parallel shard builds (spawn-context
    pools need a picklable top-level callable). Ships the finished index
    back as its save payload — plain numpy arrays + a meta dict — rather
    than the live object, and reports the in-worker build seconds so the
    parent can attribute wall clock per shard."""
    i, ispec, vectors, lo, hi = args
    t0 = time.perf_counter()
    idx = MSTGIndex.build(ispec, vectors, lo, hi)
    arrays, meta = idx.to_payload()
    return i, arrays, meta, time.perf_counter() - t0


def _host_merge(ids: np.ndarray, dists: np.ndarray, k: int
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Merge stacked (D, Q, k') lists on host, shard-major like all_gather."""
    D, Q, w = ids.shape
    flat_i = np.moveaxis(ids, 0, 1).reshape(Q, D * w)
    flat_d = np.moveaxis(dists, 0, 1).reshape(Q, D * w)
    order = np.argsort(flat_d, axis=1, kind="stable")[:, :k]
    gi = np.take_along_axis(flat_i, order, 1)
    gd = np.take_along_axis(flat_d, order, 1)
    if gi.shape[1] < k:
        pad = [(0, 0), (0, k - gi.shape[1])]
        gi = np.pad(gi, pad, constant_values=NO_EDGE)
        gd = np.pad(gd, pad, constant_values=np.inf)
    return gi.astype(np.int64), gd.astype(np.float32)


class ShardedDeployment:
    """Serve one logical corpus from many shards (see module docstring).

    The declarative surface matches :class:`repro.core.QueryEngine`:
    ``execute(SearchRequest) -> SearchResult`` (and ``search`` as an alias),
    so a deployment drops into :class:`repro.serving.RetrievalServer`
    unchanged. ``result.report.route == "sharded"`` with one
    :class:`repro.core.ShardReport` per shard.
    """

    def __init__(self, shards: Sequence[_Shard], spec: DeploymentSpec,
                 mesh=None, *, _flat_arrays=None):
        if len(shards) != spec.n_shards:
            raise ValueError(f"{len(shards)} shards built but spec.n_shards "
                             f"= {spec.n_shards}")
        if mesh is not None and mesh.shape[spec.corpus_axis] != spec.n_shards:
            raise ValueError(
                f"mesh axis {spec.corpus_axis!r} has size "
                f"{mesh.shape[spec.corpus_axis]} but the deployment has "
                f"{spec.n_shards} shards")
        self.shards = list(shards)
        self.spec = spec
        self.mesh = mesh
        self._flat = _flat_arrays      # (corpus, lo, hi) for the fused path
        self._failed: set = set()
        self.build_report: Optional[dict] = None
        self.heartbeats = HeartbeatRegistry(timeout_s=spec.shard_timeout_s)
        now = time.time()
        for s in self.shards:
            self.heartbeats.ping(s.name, 0, now=now)
        self._step = 0

    # ---- constructors ----
    @classmethod
    def build(cls, vectors, lo, hi, *, spec: Optional[DeploymentSpec] = None,
              mesh=None) -> "ShardedDeployment":
        """Partition rows into ``n_shards`` contiguous slices and build one
        MSTG index + engine per slice. Result ids are global row indices.

        ``spec.build_workers > 1`` builds the shards in a spawn process
        pool (shard builds share nothing); the pool degrades to the serial
        loop when process pools are unavailable. Either way the deployment
        carries a ``build_report`` dict — pool size, wall seconds, per-shard
        build seconds, rows/sec — for bench attribution."""
        spec = spec or DeploymentSpec()
        vectors = np.ascontiguousarray(vectors, np.float32)
        lo = np.asarray(lo, np.float64)
        hi = np.asarray(hi, np.float64)
        ispec = spec.index or IndexSpec()
        n = vectors.shape[0]
        bounds = np.linspace(0, n, spec.n_shards + 1, dtype=np.int64)
        slices = [(int(bounds[i]), int(bounds[i + 1]))
                  for i in range(spec.n_shards)]
        t_wall = time.perf_counter()
        shard_secs: List[float] = []
        indexes: List[MSTGIndex] = []
        results = run_build_pool(
            _shard_build_task,
            [(i, ispec, vectors[a:b], lo[a:b], hi[a:b])
             for i, (a, b) in enumerate(slices)],
            workers=spec.build_workers, label="shard")
        if results is not None:
            for _i, arrays, meta, secs in results:
                indexes.append(MSTGIndex.from_payload(arrays, meta))
                shard_secs.append(float(secs))
        else:
            for a, b in slices:
                t0 = time.perf_counter()
                indexes.append(
                    MSTGIndex.build(ispec, vectors[a:b], lo[a:b], hi[a:b]))
                shard_secs.append(time.perf_counter() - t0)
        shards = [_Shard(f"shard-{i}",
                         QueryEngine(idx, config=spec.engine), b - a, a)
                  for i, (idx, (a, b)) in enumerate(zip(indexes, slices))]
        wall = time.perf_counter() - t_wall
        self = cls(shards, spec, mesh)
        self.build_report = {
            "pool_size": pool_size(spec.build_workers, spec.n_shards),
            "wall_s": wall,
            "shard_seconds": shard_secs,
            "rows_per_sec": n / wall if wall > 0 else 0.0,
        }
        return self

    @classmethod
    def from_segmented(cls, segmented, *,
                       spec: Optional[DeploymentSpec] = None,
                       mesh=None) -> "ShardedDeployment":
        """Deal an existing SegmentedIndex's frozen segments round-robin onto
        shards (delta buffer on shard 0). Segments are shared with the
        source, not copied — a snapshot view; re-derive after mutations."""
        from repro.streaming.segmented import SegmentedIndex
        spec = spec or DeploymentSpec()
        shards = []
        for i in range(spec.n_shards):
            view = SegmentedIndex(segmented.spec, policy=segmented.policy,
                                  engine_config=spec.engine)
            shards.append(_Shard(f"shard-{i}", view, 0, None))
        for j, seg in enumerate(segmented.segments):
            shards[j % spec.n_shards].engine.segments.append(seg)
        shards[0].engine.delta = segmented.delta
        for s in shards:
            s.n = len(s.engine)        # live rows: tombstones excluded
        return cls(shards, spec, mesh)

    @classmethod
    def flat(cls, vectors, lo, hi, *, spec: Optional[DeploymentSpec] = None,
             mesh=None) -> "ShardedDeployment":
        """Exact-scan shards over raw corpus slices. With a mesh and a device
        merge schedule the whole fan-out runs as ONE fused shard_map call
        (local scan + collective merge, nothing per-shard on host)."""
        spec = spec or DeploymentSpec()
        vectors = np.ascontiguousarray(vectors, np.float32)
        lo = np.asarray(lo, np.float64)
        hi = np.asarray(hi, np.float64)
        n = vectors.shape[0]
        if n % spec.n_shards:
            raise ValueError(f"flat deployment needs corpus size ({n}) "
                             f"divisible by n_shards ({spec.n_shards})")
        nloc = n // spec.n_shards
        shards = [_Shard(f"shard-{i}", None, nloc, i * nloc)
                  for i in range(spec.n_shards)]
        return cls(shards, spec, mesh, _flat_arrays=(vectors, lo, hi))

    # ---- fault injection / liveness ----
    def fail(self, shard: int) -> None:
        """Mark a shard down (fleet-controller stand-in). Requests keep
        answering, degraded."""
        self._failed.add(int(shard))

    def restore(self, shard: int) -> None:
        self._failed.discard(int(shard))
        self.heartbeats.ping(self.shards[shard].name, self._step)

    def _alive(self) -> np.ndarray:
        """(D,) bool — failed or heartbeat-timed-out shards are down."""
        dead = set(self.heartbeats.dead_workers())
        return np.array([(i not in self._failed
                          and s.name not in dead)
                         for i, s in enumerate(self.shards)], bool)

    # ---- execution ----
    def execute(self, request: SearchRequest) -> SearchResult:
        """Fan one request out over the shards and merge. With
        ``request.trace=True`` the deployment owns the root trace — per-shard
        engine spans nest under ``shard-i`` — and the finished
        :class:`repro.obs.Trace` rides back on ``SearchResult.trace``."""
        if not isinstance(request, SearchRequest):
            raise TypeError("ShardedDeployment serves the declarative API "
                            "only; pass a repro.core.SearchRequest")
        tracer = obs.begin_request_trace() if request.trace else None
        try:
            with obs.span("sharded_search") as root:
                root.set("Q", len(request)).set("k", request.k)
                root.set("shards", self.spec.n_shards)
                result = self._execute_sharded(request)
        finally:
            trace = obs.end_request_trace(tracer)
        if trace is not None:
            result = dataclasses.replace(result, trace=trace)
        return result

    def _execute_sharded(self, request: SearchRequest) -> SearchResult:
        D, Q, k = self.spec.n_shards, len(request), request.k
        with obs.span("plan") as psp:
            k_loc = min(self.spec.per_shard_k, k) if self.spec.per_shard_k \
                else k
            merge = resolve_merge(self.spec.merge, D) \
                if (self.mesh is not None and self.spec.merge != "host") \
                else "host"
            alive = self._alive()
            psp.set("merge", merge).set("k_loc", k_loc)
            psp.set("alive", int(alive.sum()))
        self._step += 1
        if self._flat is not None and merge != "host":
            return self._execute_flat_fused(request, k_loc, merge, alive)

        ids = np.full((D, Q, k_loc), NO_EDGE, np.int64)
        dists = np.full((D, Q, k_loc), np.inf, np.float32)
        reports: List[ShardReport] = []
        missing: List[int] = []
        slot_total = 0
        variants: List[str] = []
        for i, shard in enumerate(self.shards):
            if not alive[i]:
                reports.append(ShardReport(shard=i, n=shard.n, route="lost",
                                           alive=False, k_fetched=0))
                missing.append(i)
                continue
            t0 = time.perf_counter()
            ssp = obs.span(f"shard-{i}")
            try:
                li, ld, rep = self._run_shard(shard, request, k_loc)
            except Exception:
                # a shard raising mid-search is a lost shard, not a lost
                # request: sentinel rows, flagged, never re-raised
                ssp.set("alive", False).stop()
                reports.append(ShardReport(shard=i, n=shard.n, route="error",
                                           alive=False, k_fetched=0))
                missing.append(i)
                continue
            ssp.set("n", shard.n).set("route", rep.route if rep else "flat")
            ssp.stop()
            ids[i], dists[i] = li, ld
            self.heartbeats.ping(shard.name, self._step)
            lat = time.perf_counter() - t0
            slot_total += rep.slot_count if rep else 0
            if rep:
                variants.extend(rep.variants)
            reports.append(ShardReport(
                shard=i, n=shard.n,
                route=rep.route if rep else "flat", k_fetched=k_loc,
                latency_s=lat, slot_count=rep.slot_count if rep else 0))
        with obs.span("merge") as msp:
            msp.set("schedule", merge)
            if merge == "host":
                gi, gd = _host_merge(ids, dists, k)
            else:
                gi, gd = sharded_topk_merge(self.mesh, ids, dists, k,
                                            axis=self.spec.corpus_axis,
                                            merge=merge, alive=alive)
            gi, gd = np.asarray(gi), np.asarray(gd)
        report = RouteReport(
            route="sharded", requested=request.route or "auto",
            est_selectivity=None, slot_count=slot_total,
            variants=tuple(variants), shards=tuple(reports),
            missing_shards=tuple(missing), merge=merge)
        return SearchResult(gi, gd, report)

    # QueryEngine-compatible alias (RetrievalServer & co).
    def search(self, request: SearchRequest) -> SearchResult:
        return self.execute(request)

    def _run_shard(self, shard: _Shard, request: SearchRequest, k_loc: int):
        """One shard's local answer as (Q, k_loc) global-id arrays."""
        if shard.engine is None:      # flat layout, host path
            corpus, lo, hi = self._flat
            a = shard.id_offset
            b = a + shard.n
            li, ld = flat_search(
                corpus[a:b], lo[a:b], hi[a:b], request.vectors,
                request.qlo.astype(np.float32), request.qhi.astype(np.float32),
                mask=request.mask, k=min(k_loc, shard.n),
                use_kernel=self.spec.engine.use_kernel)
            li, ld, rep = np.asarray(li, np.int64), np.asarray(ld), None
        else:
            # the graph route's beam pool is ef wide; keep ef >= k' so the
            # narrowed fan-in never truncates below the requested width
            res = shard.engine.execute(dataclasses.replace(
                request, k=min(k_loc, max(shard.n, 1)),
                ef=max(request.ef, k_loc)))
            li, ld, rep = (np.asarray(res.ids, np.int64),
                           np.asarray(res.dists), res.report)
        if li.shape[1] < k_loc:      # tiny shard: pad to the uniform width
            pad = [(0, 0), (0, k_loc - li.shape[1])]
            li = np.pad(li, pad, constant_values=NO_EDGE)
            ld = np.pad(ld, pad, constant_values=np.inf)
        if shard.id_offset is not None:
            li = np.where(li >= 0, li + shard.id_offset, np.int64(NO_EDGE))
        return li, ld.astype(np.float32), rep

    def _execute_flat_fused(self, request: SearchRequest, k_loc: int,
                            merge: str, alive: np.ndarray) -> SearchResult:
        """The flat layout's one-call device path: shard-local exact scans
        and the collective merge fused into a single shard_map program."""
        corpus, lo, hi = self._flat
        t0 = time.perf_counter()
        with obs.span("fused_scan") as fsp:
            fsp.set("merge", merge).set("shards", len(self.shards))
            gi, gd = sharded_flat_topk(
                self.mesh, corpus, lo, hi, request.vectors,
                request.qlo.astype(np.float32), request.qhi.astype(np.float32),
                mask=request.mask, k=request.k,
                corpus_axis=self.spec.corpus_axis, merge=merge,
                per_shard_k=k_loc if k_loc < request.k else 0, alive=alive,
                use_kernel=self.spec.engine.use_kernel)
            gi = np.asarray(gi, np.int64)
            gd = np.asarray(gd, np.float32)
        lat = time.perf_counter() - t0
        now = time.time()
        for i, s in enumerate(self.shards):
            if alive[i]:
                self.heartbeats.ping(s.name, self._step, now=now)
        reports = tuple(
            ShardReport(shard=i, n=s.n,
                        route="flat" if alive[i] else "lost",
                        alive=bool(alive[i]),
                        k_fetched=k_loc if alive[i] else 0,
                        latency_s=lat / len(self.shards))
            for i, s in enumerate(self.shards))
        missing = tuple(int(i) for i in np.flatnonzero(~alive))
        report = RouteReport(
            route="sharded", requested=request.route or "auto",
            est_selectivity=None, slot_count=0, variants=(),
            shards=reports, missing_shards=missing, merge=merge)
        return SearchResult(gi, gd, report)
