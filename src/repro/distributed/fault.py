"""Fault-tolerance scaffolding: heartbeat registry + failure/straggler
simulation hooks (single-process stand-ins for the fleet controller)."""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional


@dataclasses.dataclass
class Heartbeat:
    worker: str
    last_seen: float
    step: int


class HeartbeatRegistry:
    """Controller-side view of worker liveness. At fleet scale each host pings
    its heartbeat; a missed deadline triggers elastic restart from the latest
    checkpoint on the surviving topology (tests simulate this end to end)."""

    def __init__(self, timeout_s: float = 30.0):
        self.timeout = timeout_s
        self.beats: Dict[str, Heartbeat] = {}

    def ping(self, worker: str, step: int, now: Optional[float] = None):
        self.beats[worker] = Heartbeat(worker, now or time.time(), step)

    def dead_workers(self, now: Optional[float] = None) -> List[str]:
        now = now or time.time()
        return [w for w, hb in self.beats.items()
                if now - hb.last_seen > self.timeout]

    def should_restart(self, now: Optional[float] = None) -> bool:
        return len(self.dead_workers(now)) > 0
