"""Small sharding helpers shared by launch/serving/training."""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def mesh_axis_size(mesh: Mesh, axes: Union[str, Sequence[str], None]) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s


def shard_or_replicate(mesh: Mesh, dim_size: int,
                       axes: Union[str, Sequence[str], None]):
    """Use ``axes`` for this dim only if it divides evenly, else replicate.

    Small models (gemma3-1b has 4 heads) or tiny batches (long_500k has B=1)
    cannot shard every logical axis on a 16-wide mesh — replication is the
    correct degradation and is recorded by the dry-run memory analysis."""
    if axes is None:
        return None
    size = mesh_axis_size(mesh, axes)
    if size <= 1 or dim_size % size != 0:
        return None
    return axes if isinstance(axes, str) else tuple(axes)


def batch_spec(mesh: Mesh, batch: int, axes=("pod", "data")) -> P:
    """Batch dim over (pod, data) when divisible; degrade gracefully."""
    present = tuple(a for a in axes if a in mesh.shape)
    while present and (mesh_axis_size(mesh, present) == 0 or
                       batch % mesh_axis_size(mesh, present) != 0):
        present = present[1:]
    return P(present if present else None)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def named(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))
