"""Distributed filtered top-k over a corpus-sharded MSTG deployment.

Architecture (DESIGN.md §5): the corpus (vectors + ranges [+ per-shard MSTG
arrays]) is sharded along ``corpus_axis``; each device computes a local
filtered top-k, then shards exchange results. Two merge schedules:

* ``all_gather`` — every shard gathers all (Q, k) lists, one collective,
  bytes/device ∝ D·Q·k. Simple, latency-optimal for small D.
* ``tournament`` — log2(D) ``ppermute`` rounds, each merging two k-lists;
  bytes/device ∝ log2(D)·Q·k. The beyond-paper schedule for pod-scale D
  (D=512: 9 rounds vs 512x gather) — see EXPERIMENTS.md §Perf.

Both schedules accept local lists narrower than the global ``k`` (the
deployment's ``per_shard_k`` fan-in knob): every intermediate merge retains
``min(k, candidates so far)`` entries, so no candidate that can reach the
global top-k is ever dropped and the two schedules stay bit-identical for
distinct distances. When ``D * k' < k`` the result is padded with
``NO_EDGE``/``inf`` columns. Dead shards (``alive`` mask) contribute only
sentinel rows — a lost device degrades recall, never correctness of the
merge itself.
"""
from __future__ import annotations

import functools
from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.flat import flat_search
from repro.core.hnsw import NO_EDGE


def _axis_size(axis: str) -> int:
    """Static size of a named mesh axis inside shard_map.

    jax.lax.axis_size only exists on newer JAX; on 0.4.x the axis env exposes
    the (already static) size via jax.core.axis_frame."""
    if hasattr(jax.lax, "axis_size"):
        return int(jax.lax.axis_size(axis))
    return int(jax.core.axis_frame(axis))


def _pad_to_k(ids, dists, k: int):
    """Right-pad (Q, w) lists to (Q, k) with NO_EDGE/inf sentinel columns."""
    w = ids.shape[1]
    if w >= k:
        return ids, dists
    pad = [(0, 0), (0, k - w)]
    return (jnp.pad(ids, pad, constant_values=NO_EDGE),
            jnp.pad(dists, pad, constant_values=jnp.inf))


def global_topk_merge(ids, dists, k: int, axis: str):
    """all_gather merge inside shard_map: (Q, k') local -> (Q, k) global.

    Accepts local width k' != k (the ``per_shard_k`` fan-in knob); pads with
    sentinels when the union D*k' holds fewer than k candidates."""
    all_ids = jax.lax.all_gather(ids, axis)     # (D, Q, k')
    all_d = jax.lax.all_gather(dists, axis)
    D = all_ids.shape[0]
    Q = all_ids.shape[1]
    w = all_ids.shape[2]
    flat_ids = jnp.moveaxis(all_ids, 0, 1).reshape(Q, D * w)
    flat_d = jnp.moveaxis(all_d, 0, 1).reshape(Q, D * w)
    kk = min(k, D * w)
    neg, pos = jax.lax.top_k(-flat_d, kk)
    return _pad_to_k(jnp.take_along_axis(flat_ids, pos, 1), -neg, k)


def tournament_topk_merge(ids, dists, k: int, axis: str):
    """Recursive-halving merge: log2(D) ppermute rounds of k-list merges.

    After round r, device i holds the merged top-k of its 2^(r+1)-device
    group; all devices finish with the global top-k (butterfly exchange).
    Each round keeps ``min(k, 2w)`` of the 2w concatenated candidates, so a
    narrow local width k' < k widens toward k instead of truncating — the
    final list is bit-identical to :func:`global_topk_merge` whenever
    distances are distinct."""
    D = _axis_size(axis)
    rounds = int(np.log2(D))
    assert (1 << rounds) == D, "tournament merge needs power-of-two shards"
    for r in range(rounds):
        stride = 1 << r
        perm = [(int(i), int((i + stride) if (i // stride) % 2 == 0 else (i - stride)))
                for i in range(D)]
        other_ids = jax.lax.ppermute(ids, axis, perm)
        other_d = jax.lax.ppermute(dists, axis, perm)
        cat_ids = jnp.concatenate([ids, other_ids], axis=1)
        cat_d = jnp.concatenate([dists, other_d], axis=1)
        neg, pos = jax.lax.top_k(-cat_d, min(k, cat_d.shape[1]))
        ids = jnp.take_along_axis(cat_ids, pos, 1)
        dists = -neg
    return _pad_to_k(ids, dists, k)


MERGE_SCHEDULES = {"all_gather": global_topk_merge,
                   "tournament": tournament_topk_merge}


def resolve_merge(merge: str, n_shards: int) -> str:
    """``auto`` -> all_gather for small meshes, tournament for pow2 D > 8."""
    if merge == "auto":
        if n_shards > 8 and (n_shards & (n_shards - 1)) == 0:
            return "tournament"
        return "all_gather"
    if merge not in MERGE_SCHEDULES:
        raise ValueError(f"unknown merge schedule {merge!r}; "
                         f"expected one of {sorted(MERGE_SCHEDULES)} or 'auto'")
    return merge


def sharded_topk_merge(mesh: Mesh, ids, dists, k: int, *,
                       axis: str = "data", merge: str = "all_gather",
                       alive=None) -> Tuple[np.ndarray, np.ndarray]:
    """Merge host-stacked per-shard results through the device collectives.

    ``ids``/``dists`` are (D, Q, k') arrays — one top-k' list per shard, as
    produced by heterogeneous per-shard engines (graph / pruned / flat) whose
    local searches ran on host. Each device receives its own shard's slice,
    the chosen schedule (all_gather / tournament) merges across the mesh
    axis, and the replicated (Q, k) global list is returned. ``alive`` is an
    optional (D,) bool mask: a dead shard's list is replaced by sentinels
    *on device*, modeling a shard that never answered."""
    D = int(ids.shape[0])
    if mesh.shape[axis] != D:
        raise ValueError(f"stacked results have {D} shards but mesh axis "
                         f"{axis!r} has size {mesh.shape[axis]}")
    merge_fn = MERGE_SCHEDULES[resolve_merge(merge, D)]
    ids = jnp.asarray(ids, jnp.int64 if jax.config.jax_enable_x64
                      else jnp.int32)
    dists = jnp.asarray(dists, jnp.float32)
    alive_arr = (jnp.ones((D,), bool) if alive is None
                 else jnp.asarray(alive, bool))

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(axis, None, None), P(axis, None, None), P(None)),
        out_specs=(P(None, None), P(None, None)),
        check_rep=False)
    def run(i, d, a):
        i, d = i[0], d[0]                       # (Q, k') local slice
        ok = a[jax.lax.axis_index(axis)]
        i = jnp.where(ok, i, NO_EDGE)
        d = jnp.where(ok, d, jnp.inf)
        return merge_fn(i, d, k, axis)

    gi, gd = run(ids, dists, alive_arr)
    return np.asarray(gi, np.int64), np.asarray(gd, np.float32)


def sharded_flat_topk(mesh: Mesh, corpus, lo, hi, queries, ql, qh, *, mask: int,
                      k: int, corpus_axis: str = "data",
                      merge: str = "all_gather", per_shard_k: int = 0,
                      alive=None,
                      use_kernel: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact distributed RRANN: corpus sharded on ``corpus_axis``, queries
    replicated, result replicated. Local ids are rebased to global ids.

    ``per_shard_k`` < k narrows the per-shard fan-in (less merge traffic,
    possibly lower recall); 0 means fetch the full k per shard. ``alive`` is
    an optional (D,) bool mask — a False shard contributes only sentinels,
    yielding the degraded-recall answer a lost device would."""
    D = mesh.shape[corpus_axis]
    n = corpus.shape[0]
    assert n % D == 0, f"corpus size {n} not divisible by {D} shards"
    nloc = n // D
    k_loc = min(per_shard_k, k) if per_shard_k else k
    k_loc = min(k_loc, nloc)
    merge_fn = MERGE_SCHEDULES[resolve_merge(merge, D)]
    alive_arr = (jnp.ones((D,), bool) if alive is None
                 else jnp.asarray(alive, bool))

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(corpus_axis, None), P(corpus_axis), P(corpus_axis),
                  P(None, None), P(None), P(None), P(None)),
        out_specs=(P(None, None), P(None, None)),
        check_rep=False)
    def run(c, l, h, q, a, b, ok):
        ids, d = flat_search(c, l, h, q, a, b, mask=mask, k=k_loc,
                             use_kernel=use_kernel)
        shard = jax.lax.axis_index(corpus_axis)
        gids = jnp.where(ids != NO_EDGE, ids + shard * nloc, NO_EDGE)
        up = ok[shard]
        gids = jnp.where(up, gids, NO_EDGE)
        d = jnp.where(up, d, jnp.inf)
        return merge_fn(gids, d, k, corpus_axis)

    return run(corpus, lo, hi, queries, ql, qh, alive_arr)
