"""Distributed filtered top-k over a corpus-sharded MSTG deployment.

Architecture (DESIGN.md §5): the corpus (vectors + ranges [+ per-shard MSTG
arrays]) is sharded along ``corpus_axis``; each device computes a local
filtered top-k, then shards exchange results. Two merge schedules:

* ``all_gather`` — every shard gathers all (Q, k) lists, one collective,
  bytes/device ∝ D·Q·k. Simple, latency-optimal for small D.
* ``tournament`` — log2(D) ``ppermute`` rounds, each merging two k-lists;
  bytes/device ∝ log2(D)·Q·k. The beyond-paper schedule for pod-scale D
  (D=512: 9 rounds vs 512x gather) — see EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools
from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.flat import flat_search
from repro.core.hnsw import NO_EDGE


def _axis_size(axis: str) -> int:
    """Static size of a named mesh axis inside shard_map.

    jax.lax.axis_size only exists on newer JAX; on 0.4.x the axis env exposes
    the (already static) size via jax.core.axis_frame."""
    if hasattr(jax.lax, "axis_size"):
        return int(jax.lax.axis_size(axis))
    return int(jax.core.axis_frame(axis))


def global_topk_merge(ids, dists, k: int, axis: str):
    """all_gather merge inside shard_map: (Q, k) local -> (Q, k) global."""
    all_ids = jax.lax.all_gather(ids, axis)     # (D, Q, k)
    all_d = jax.lax.all_gather(dists, axis)
    D = all_ids.shape[0]
    Q = all_ids.shape[1]
    flat_ids = jnp.moveaxis(all_ids, 0, 1).reshape(Q, D * k)
    flat_d = jnp.moveaxis(all_d, 0, 1).reshape(Q, D * k)
    neg, pos = jax.lax.top_k(-flat_d, k)
    return jnp.take_along_axis(flat_ids, pos, 1), -neg


def tournament_topk_merge(ids, dists, k: int, axis: str):
    """Recursive-halving merge: log2(D) ppermute rounds of k-list merges.

    After round r, device i holds the merged top-k of its 2^(r+1)-device
    group; all devices finish with the global top-k (butterfly exchange)."""
    D = _axis_size(axis)
    rounds = int(np.log2(D))
    assert (1 << rounds) == D, "tournament merge needs power-of-two shards"
    for r in range(rounds):
        stride = 1 << r
        idx = jax.lax.axis_index(axis)
        partner = jnp.where((idx // stride) % 2 == 0, idx + stride, idx - stride)
        perm = [(int(i), int((i + stride) if (i // stride) % 2 == 0 else (i - stride)))
                for i in range(D)]
        other_ids = jax.lax.ppermute(ids, axis, perm)
        other_d = jax.lax.ppermute(dists, axis, perm)
        cat_ids = jnp.concatenate([ids, other_ids], axis=1)
        cat_d = jnp.concatenate([dists, other_d], axis=1)
        neg, pos = jax.lax.top_k(-cat_d, k)
        ids = jnp.take_along_axis(cat_ids, pos, 1)
        dists = -neg
    return ids, dists


def sharded_flat_topk(mesh: Mesh, corpus, lo, hi, queries, ql, qh, *, mask: int,
                      k: int, corpus_axis: str = "data",
                      merge: str = "all_gather",
                      use_kernel: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact distributed RRANN: corpus sharded on ``corpus_axis``, queries
    replicated, result replicated. Local ids are rebased to global ids."""
    D = mesh.shape[corpus_axis]
    n = corpus.shape[0]
    assert n % D == 0, f"corpus size {n} not divisible by {D} shards"
    nloc = n // D
    merge_fn = {"all_gather": global_topk_merge,
                "tournament": tournament_topk_merge}[merge]

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(corpus_axis, None), P(corpus_axis), P(corpus_axis),
                  P(None, None), P(None), P(None)),
        out_specs=(P(None, None), P(None, None)),
        check_rep=False)
    def run(c, l, h, q, a, b):
        ids, d = flat_search(c, l, h, q, a, b, mask=mask, k=k,
                             use_kernel=use_kernel)
        shard = jax.lax.axis_index(corpus_axis)
        gids = jnp.where(ids != NO_EDGE, ids + shard * nloc, NO_EDGE)
        return merge_fn(gids, d, k, corpus_axis)

    return run(corpus, lo, hi, queries, ql, qh)
