"""Pallas TPU kernels for the paper's distance-verification hot spot.

- pairwise_l2.py   : fused RR-predicate + pairwise squared-L2 (MXU tiles)
- gathered_l2.py   : beam-candidate distances (VPU + MXU formulations)
- fused_topk.py    : predicate + distance + running top-k in ONE kernel
                     (grid-persistent accumulator; no (Q, N) matrix ever)
- gathered_topk.py : the wavefront beam step — gather-by-id + L2 + label
                     mask + sorted-pool merge in ONE kernel
- ref.py          : pure-jnp oracles (the allclose ground truth)
- ops.py          : jit entry points; interpret=True off-TPU

Tests sweep shapes/dtypes via hypothesis in interpret mode
(tests/test_kernels.py).
"""
from . import ops
