"""Fused predicate + L2 + running top-k Pallas kernel.

One kernel call answers an exact filtered k-NN query batch: the TPU grid walks
corpus blocks sequentially (TPU grids execute in order), each step computes the
masked distance tile in VMEM and folds it into a persistent (Q, k) accumulator
that every grid step aliases (out block index 0) — the (Q, N) distance matrix
never exists, in VMEM or HBM. This is the §Perf-iteration-6 engine as a single
kernel: HBM traffic = corpus + queries + (Q, 2k) outputs.

Top-k inside the kernel uses k rounds of (min, argmin, mask) — k is small
(<=32) and the VPU eats the (Q, BN) compares; no sort network needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import intervals as iv

NO_EDGE = -1
DEFAULT_BN = 1024


def _extract_topk(dist, ids, k: int):
    """k rounds of min-extraction. dist: (Q, M) fp32; ids: (Q, M) int32."""
    Q = dist.shape[0]
    out_d = []
    out_i = []
    for _ in range(k):
        m = jnp.min(dist, axis=1)                      # (Q,)
        am = jnp.argmin(dist, axis=1)                  # (Q,)
        out_d.append(m)
        out_i.append(jnp.take_along_axis(ids, am[:, None], 1)[:, 0])
        dist = jnp.where(jnp.arange(dist.shape[1])[None, :] == am[:, None],
                         jnp.inf, dist)
    return jnp.stack(out_d, 1), jnp.stack(out_i, 1)    # (Q, k)


def _kernel(q_ref, c_ref, lo_ref, hi_ref, ql_ref, qh_ref,
            outd_ref, outi_ref, *, mask: int, k: int, bn: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        outd_ref[...] = jnp.full(outd_ref.shape, jnp.inf, jnp.float32)
        outi_ref[...] = jnp.full(outi_ref.shape, NO_EDGE, jnp.int32)

    q = q_ref[...].astype(jnp.float32)                 # (Q, d)
    c = c_ref[...].astype(jnp.float32)                 # (BN, d)
    qn = jnp.sum(q * q, axis=1, keepdims=True)
    cn = jnp.sum(c * c, axis=1)
    dist = qn - 2.0 * jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) + cn[None, :]
    sel = iv.eval_predicate(mask, lo_ref[...][None, :], hi_ref[...][None, :],
                            ql_ref[...][:, None], qh_ref[...][:, None])
    dist = jnp.where(sel, dist, jnp.inf)
    gids = (step * bn + jnp.arange(bn, dtype=jnp.int32))[None, :]
    gids = jnp.broadcast_to(gids, dist.shape)

    new_d, new_i = _extract_topk(dist, gids, k)        # (Q, k)
    cat_d = jnp.concatenate([outd_ref[...], new_d], axis=1)
    cat_i = jnp.concatenate([outi_ref[...], new_i], axis=1)
    merged_d, merged_i = _extract_topk(cat_d, cat_i, k)
    outd_ref[...] = merged_d
    outi_ref[...] = jnp.where(jnp.isfinite(merged_d), merged_i, NO_EDGE)


@functools.partial(jax.jit, static_argnames=("mask", "k", "bn", "interpret"))
def fused_topk_l2(queries, corpus, lo, hi, ql, qh, mask: int, k: int = 10,
                  bn: int = DEFAULT_BN, interpret: bool = False):
    """(Q, d) x (N, d) -> exact filtered ((Q, k) ids, (Q, k) sq-distances)."""
    Q, d = queries.shape
    N = corpus.shape[0]
    bn = min(bn, max(128, N))
    Np = -(-N // bn) * bn
    cpad = jnp.pad(corpus, ((0, Np - N), (0, 0)))
    # NaN endpoints fail every RR comparison -> padded rows never qualify
    lop = jnp.pad(lo.astype(jnp.float32), (0, Np - N), constant_values=jnp.nan)
    hip = jnp.pad(hi.astype(jnp.float32), (0, Np - N), constant_values=jnp.nan)

    outd, outi = pl.pallas_call(
        functools.partial(_kernel, mask=mask, k=k, bn=bn),
        grid=(Np // bn,),
        in_specs=[
            pl.BlockSpec((Q, d), lambda i: (0, 0)),
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((Q,), lambda i: (0,)),
            pl.BlockSpec((Q,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((Q, k), lambda i: (0, 0)),   # all steps alias block 0
            pl.BlockSpec((Q, k), lambda i: (0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((Q, k), jnp.float32),
                   jax.ShapeDtypeStruct((Q, k), jnp.int32)],
        interpret=interpret,
    )(queries, cpad, lop, hip, ql.astype(jnp.float32), qh.astype(jnp.float32))
    return outi, outd
