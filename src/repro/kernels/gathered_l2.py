"""Beam-candidate distance Pallas kernel (graph-search inner step).

Computes squared L2 between each query and its S gathered candidate vectors:
``(Q, d) x (Q, S, d) -> (Q, S)``. This is the per-expansion hot loop of
Algorithm 4: S is the (label-masked) neighbor slot count. The gather itself
(HBM row fetch by neighbor id) is left to XLA's native dynamic-gather DMA —
the kernel owns the arithmetic: one VMEM-resident (BQ, S, d) tile reduced on
the VPU with fp32 accumulation.

A second entry point ``gathered_l2_dot`` reformulates the reduction as an MXU
contraction (useful when S*d is large and d is lane-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 8


def _kernel_vpu(q_ref, c_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)      # (BQ, d)
    c = c_ref[...].astype(jnp.float32)      # (BQ, S, d)
    diff = c - q[:, None, :]
    out_ref[...] = jnp.sum(diff * diff, axis=-1)


def _kernel_mxu(q_ref, c_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)      # (BQ, d)
    c = c_ref[...].astype(jnp.float32)      # (BQ, S, d)
    qn = jnp.sum(q * q, axis=-1)            # (BQ,)
    cn = jnp.sum(c * c, axis=-1)            # (BQ, S)
    # batched (S, d) @ (d,) per query on the MXU
    cross = jax.lax.dot_general(c, q, (((2,), (1,)), ((0,), (0,))),
                                preferred_element_type=jnp.float32)  # (BQ, S)
    out_ref[...] = qn[:, None] - 2.0 * cross + cn


def _call(kernel, queries, cand_vecs, bq: int, interpret: bool):
    Q, d = queries.shape
    S = cand_vecs.shape[1]
    bq = min(bq, Q) if Q else 1
    Qp = -(-Q // bq) * bq
    qpad = jnp.pad(queries, ((0, Qp - Q), (0, 0)))
    cpad = jnp.pad(cand_vecs, ((0, Qp - Q), (0, 0), (0, 0)))
    out = pl.pallas_call(
        kernel,
        grid=(Qp // bq,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((bq, S, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, S), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Qp, S), jnp.float32),
        interpret=interpret,
    )(qpad, cpad)
    return out[:Q]


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def gathered_l2(queries, cand_vecs, bq: int = DEFAULT_BQ, interpret: bool = False):
    return _call(_kernel_vpu, queries, cand_vecs, bq, interpret)


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def gathered_l2_dot(queries, cand_vecs, bq: int = DEFAULT_BQ, interpret: bool = False):
    return _call(_kernel_mxu, queries, cand_vecs, bq, interpret)
