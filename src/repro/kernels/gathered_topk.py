"""Fused gather + distance + label-mask + beam-merge Pallas kernel.

One kernel call executes the whole wavefront step of Algorithm 4's beam
search: for each query it gathers the candidate vectors by id from the corpus
table, computes squared L2, applies the label mask ``b <= version <= e``, and
folds the masked candidates into the sorted (pool_ids, pool_d, expanded) beam
— replacing the unfused gather → einsum → concat → ``top_k(L + F*S)`` chain
with a single call. Modeled on :mod:`repro.kernels.fused_topk`'s
running-accumulator design: the merge is L rounds of (min, argmin, mask) on
the VPU, which matches ``jax.lax.top_k``'s first-index tie-breaking exactly.

The corpus table is presented to every grid step whole (the gather indices
are per-query dynamic), so the TPU path assumes the table fits VMEM; the
CPU/test path runs in interpret mode where the gather is a plain jnp take.
Inputs follow the search loop's conventions: ``avail`` marks candidates that
are structurally valid, unvisited, and first-occurrence (the loop computes
this against its packed visited bitmap); ids may be ``NO_EDGE`` where not
available.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NO_EDGE = -1
DEFAULT_BQ = 8


def _extract_pool(dist, ids, exp, L: int):
    """L rounds of min-extraction carrying (id, expanded) along; ties break on
    the first index, matching ``top_k(-dist)``. +inf slots yield
    (NO_EDGE, +inf, False) — the beam's empty-slot invariant."""
    out_d, out_i, out_e = [], [], []
    pos = jnp.arange(dist.shape[1])[None, :]
    for _ in range(L):
        m = jnp.min(dist, axis=1)                       # (BQ,)
        am = jnp.argmin(dist, axis=1)                   # (BQ,)
        out_d.append(m)
        out_i.append(jnp.take_along_axis(ids, am[:, None], 1)[:, 0])
        out_e.append(jnp.take_along_axis(exp, am[:, None], 1)[:, 0])
        dist = jnp.where(pos == am[:, None], jnp.inf, dist)
    d = jnp.stack(out_d, 1)                             # (BQ, L)
    i = jnp.stack(out_i, 1)
    e = jnp.stack(out_e, 1)
    fin = jnp.isfinite(d)
    return jnp.where(fin, i, NO_EDGE), d, jnp.where(fin, e, 0)


def _merge_step(q, cand, ids, ok, pid_ref, pd_ref, pexp_ref,
                oid_ref, od_ref, oexp_ref, L: int):
    """Shared epilogue of both table layouts: squared L2 of the gathered
    candidates, label mask, beam merge, write-back."""
    diff = cand - q[:, None, :]
    nd = jnp.sum(diff * diff, axis=-1)
    nd = jnp.where(ok, nd, jnp.inf)
    nid = jnp.where(ok, ids, NO_EDGE)

    cat_d = jnp.concatenate([pd_ref[...], nd], axis=1)
    cat_i = jnp.concatenate([pid_ref[...], nid], axis=1)
    cat_e = jnp.concatenate(
        [pexp_ref[...], jnp.zeros(nd.shape, pexp_ref.dtype)], axis=1)
    mi, md, me = _extract_pool(cat_d, cat_i, cat_e, L)
    oid_ref[...] = mi
    od_ref[...] = md
    oexp_ref[...] = me


def _kernel(q_ref, v_ref, ids_ref, avail_ref, b_ref, e_ref, ver_ref,
            pid_ref, pd_ref, pexp_ref, oid_ref, od_ref, oexp_ref, *, L: int):
    q = q_ref[...].astype(jnp.float32)                  # (BQ, d)
    table = v_ref[...].astype(jnp.float32)              # (n, d)
    ids = ids_ref[...]                                  # (BQ, M)
    ver = ver_ref[...]                                  # (BQ,)
    ok = ((avail_ref[...] != 0) & (b_ref[...] <= ver[:, None]) &
          (ver[:, None] <= e_ref[...]))
    idx = jnp.where(ids < 0, 0, ids)
    cand = table[idx]                                   # (BQ, M, d) gather
    _merge_step(q, cand, ids, ok, pid_ref, pd_ref, pexp_ref,
                oid_ref, od_ref, oexp_ref, L)


def _kernel_quant(q_ref, v_ref, sc_ref, of_ref, ids_ref, avail_ref, b_ref,
                  e_ref, ver_ref, pid_ref, pd_ref, pexp_ref,
                  oid_ref, od_ref, oexp_ref, *, L: int):
    """Quantized-table wavefront step: the gather pulls int8/float16 code
    rows (the bandwidth win — 4x/2x fewer bytes per candidate) and the
    affine dequantization ``code * scale + offset`` happens on the gathered
    (BQ, M, d) tile in VMEM, never on the full table."""
    q = q_ref[...].astype(jnp.float32)                  # (BQ, d)
    table = v_ref[...]                                  # (n, d) codes
    ids = ids_ref[...]                                  # (BQ, M)
    ver = ver_ref[...]                                  # (BQ,)
    ok = ((avail_ref[...] != 0) & (b_ref[...] <= ver[:, None]) &
          (ver[:, None] <= e_ref[...]))
    idx = jnp.where(ids < 0, 0, ids)
    cand = (table[idx].astype(jnp.float32) * sc_ref[...][None, None, :]
            + of_ref[...][None, None, :])               # (BQ, M, d)
    _merge_step(q, cand, ids, ok, pid_ref, pd_ref, pexp_ref,
                oid_ref, od_ref, oexp_ref, L)


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def gathered_topk(queries, vectors, ids, avail, b, e, version,
                  pool_ids, pool_d, pool_exp, bq: int = DEFAULT_BQ,
                  interpret: bool = False):
    """(Q, d) queries x (n, d) table x (Q, M) candidates x (Q, L) beam ->
    merged ((Q, L) ids, (Q, L) sq-dists, (Q, L) expanded-flags)."""
    Q, d = queries.shape
    M = ids.shape[1]
    L = pool_d.shape[1]
    bq = min(bq, Q) if Q else 1
    Qp = -(-Q // bq) * bq
    pad = Qp - Q

    def padq(a, fill=0):
        return jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1),
                       constant_values=fill)

    exp_in = pool_exp.astype(jnp.int32)
    args = (padq(queries), jnp.asarray(vectors, jnp.float32),
            padq(ids.astype(jnp.int32), NO_EDGE),
            padq(avail.astype(jnp.int32)), padq(b.astype(jnp.int32)),
            padq(e.astype(jnp.int32)), padq(version.astype(jnp.int32)),
            padq(pool_ids.astype(jnp.int32), NO_EDGE),
            padq(pool_d.astype(jnp.float32), jnp.inf), padq(exp_in))
    n = vectors.shape[0]
    oid, od, oexp = pl.pallas_call(
        functools.partial(_kernel, L=L),
        grid=(Qp // bq,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((bq, M), lambda i: (i, 0)),
            pl.BlockSpec((bq, M), lambda i: (i, 0)),
            pl.BlockSpec((bq, M), lambda i: (i, 0)),
            pl.BlockSpec((bq, M), lambda i: (i, 0)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq, L), lambda i: (i, 0)),
            pl.BlockSpec((bq, L), lambda i: (i, 0)),
            pl.BlockSpec((bq, L), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, L), lambda i: (i, 0)),
            pl.BlockSpec((bq, L), lambda i: (i, 0)),
            pl.BlockSpec((bq, L), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((Qp, L), jnp.int32),
                   jax.ShapeDtypeStruct((Qp, L), jnp.float32),
                   jax.ShapeDtypeStruct((Qp, L), jnp.int32)],
        interpret=interpret,
    )(*args)
    return oid[:Q], od[:Q], oexp[:Q].astype(bool)


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def gathered_topk_quant(queries, codes, scale, offset, ids, avail, b, e,
                        version, pool_ids, pool_d, pool_exp,
                        bq: int = DEFAULT_BQ, interpret: bool = False):
    """:func:`gathered_topk` over a quantized (n, d) code table (int8 or
    float16) with per-dimension affine dequant params ``scale``/``offset``
    (each (d,) float32). Distances are squared L2 against the dequantized
    rows ``code * scale + offset``."""
    Q, d = queries.shape
    M = ids.shape[1]
    L = pool_d.shape[1]
    bq = min(bq, Q) if Q else 1
    Qp = -(-Q // bq) * bq
    pad = Qp - Q

    def padq(a, fill=0):
        return jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1),
                       constant_values=fill)

    exp_in = pool_exp.astype(jnp.int32)
    args = (padq(queries), jnp.asarray(codes),
            jnp.asarray(scale, jnp.float32), jnp.asarray(offset, jnp.float32),
            padq(ids.astype(jnp.int32), NO_EDGE),
            padq(avail.astype(jnp.int32)), padq(b.astype(jnp.int32)),
            padq(e.astype(jnp.int32)), padq(version.astype(jnp.int32)),
            padq(pool_ids.astype(jnp.int32), NO_EDGE),
            padq(pool_d.astype(jnp.float32), jnp.inf), padq(exp_in))
    n = codes.shape[0]
    oid, od, oexp = pl.pallas_call(
        functools.partial(_kernel_quant, L=L),
        grid=(Qp // bq,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((bq, M), lambda i: (i, 0)),
            pl.BlockSpec((bq, M), lambda i: (i, 0)),
            pl.BlockSpec((bq, M), lambda i: (i, 0)),
            pl.BlockSpec((bq, M), lambda i: (i, 0)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq, L), lambda i: (i, 0)),
            pl.BlockSpec((bq, L), lambda i: (i, 0)),
            pl.BlockSpec((bq, L), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, L), lambda i: (i, 0)),
            pl.BlockSpec((bq, L), lambda i: (i, 0)),
            pl.BlockSpec((bq, L), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((Qp, L), jnp.int32),
                   jax.ShapeDtypeStruct((Qp, L), jnp.float32),
                   jax.ShapeDtypeStruct((Qp, L), jnp.int32)],
        interpret=interpret,
    )(*args)
    return oid[:Q], od[:Q], oexp[:Q].astype(bool)
