"""Public jit'd kernel entry points with automatic backend dispatch.

On TPU the Pallas kernels run compiled; everywhere else (this CPU container)
they run in ``interpret=True`` mode, which executes the kernel body in Python
on CPU — bitwise the same program structure, used by tests/benchmarks to
validate against the :mod:`repro.kernels.ref` oracles.

When a request trace is active (``repro.obs``), each entry point records a
``kernel:<name>`` span annotated with achieved memory bandwidth vs the TPU
v5e HBM peak (:func:`repro.obs.profile.bandwidth_annotation`). The traced
path blocks on the result so the span measures the kernel, not the dispatch;
with tracing off the wrappers stay fully async and add no work.
"""
from __future__ import annotations

import functools
import time

import jax

from repro import obs
from repro.obs.profile import bandwidth_annotation

from . import pairwise_l2 as _pw
from . import gathered_l2 as _gl
from . import ref


@functools.cache
def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _nbytes(*arrays) -> int:
    """Total bytes the kernel must at least stream from memory (inputs)."""
    total = 0
    for a in arrays:
        nb = getattr(a, "nbytes", None)
        if nb is not None:
            total += int(nb)
    return total


def _run_traced(name: str, inputs, thunk):
    """Run ``thunk`` inside a ``kernel:<name>`` span with an achieved-vs-peak
    bandwidth annotation. Only entered when a tracer is active — the traced
    path blocks on the result so the measured wall time bounds the kernel."""
    with obs.span(f"kernel:{name}") as sp:
        t0 = time.perf_counter()
        out = jax.block_until_ready(thunk())
        ann = bandwidth_annotation(_nbytes(*inputs), time.perf_counter() - t0)
        for key, v in ann.items():
            sp.set(key, v)
    return out


def pairwise_l2_masked(queries, corpus, lo, hi, ql, qh, mask: int,
                       bq: int = _pw.DEFAULT_BQ, bn: int = _pw.DEFAULT_BN):
    thunk = lambda: _pw.pairwise_l2_masked(  # noqa: E731
        queries, corpus, lo, hi, ql, qh, mask, bq=bq, bn=bn,
        interpret=_interpret())
    if not obs.tracing():
        return thunk()
    return _run_traced("pairwise_l2_masked", (queries, corpus, lo, hi),
                       thunk)


def gathered_l2(queries, cand_vecs, bq: int = _gl.DEFAULT_BQ):
    thunk = lambda: _gl.gathered_l2(  # noqa: E731
        queries, cand_vecs, bq=bq, interpret=_interpret())
    if not obs.tracing():
        return thunk()
    return _run_traced("gathered_l2", (queries, cand_vecs), thunk)


def gathered_l2_dot(queries, cand_vecs, bq: int = _gl.DEFAULT_BQ):
    thunk = lambda: _gl.gathered_l2_dot(  # noqa: E731
        queries, cand_vecs, bq=bq, interpret=_interpret())
    if not obs.tracing():
        return thunk()
    return _run_traced("gathered_l2_dot", (queries, cand_vecs), thunk)


def gathered_topk(queries, vectors, ids, avail, b, e, version,
                  pool_ids, pool_d, pool_exp, bq: int = None):
    """Fused wavefront step: gather-by-id + L2 + label mask + beam merge
    (:mod:`repro.kernels.gathered_topk`) in one kernel call."""
    from . import gathered_topk as _gt
    thunk = lambda: _gt.gathered_topk(  # noqa: E731
        queries, vectors, ids, avail, b, e, version, pool_ids, pool_d,
        pool_exp, bq=bq or _gt.DEFAULT_BQ, interpret=_interpret())
    if not obs.tracing():
        return thunk()
    return _run_traced("gathered_topk",
                       (queries, ids, pool_ids, pool_d), thunk)


# re-export oracles for convenience
pairwise_l2_masked_ref = ref.pairwise_l2_masked_ref
gathered_l2_ref = ref.gathered_l2_ref
gathered_topk_ref = ref.gathered_topk_ref


def fused_topk_l2(queries, corpus, lo, hi, ql, qh, mask: int, k: int = 10,
                  bn: int = 1024):
    from . import fused_topk as _ft
    thunk = lambda: _ft.fused_topk_l2(  # noqa: E731
        queries, corpus, lo, hi, ql, qh, mask, k=k, bn=bn,
        interpret=_interpret())
    if not obs.tracing():
        return thunk()
    return _run_traced("fused_topk_l2", (queries, corpus, lo, hi), thunk)
