"""Public jit'd kernel entry points with automatic backend dispatch.

On TPU the Pallas kernels run compiled; everywhere else (this CPU container)
they run in ``interpret=True`` mode, which executes the kernel body in Python
on CPU — bitwise the same program structure, used by tests/benchmarks to
validate against the :mod:`repro.kernels.ref` oracles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import pairwise_l2 as _pw
from . import gathered_l2 as _gl
from . import ref


@functools.cache
def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def pairwise_l2_masked(queries, corpus, lo, hi, ql, qh, mask: int,
                       bq: int = _pw.DEFAULT_BQ, bn: int = _pw.DEFAULT_BN):
    return _pw.pairwise_l2_masked(queries, corpus, lo, hi, ql, qh, mask,
                                  bq=bq, bn=bn, interpret=_interpret())


def gathered_l2(queries, cand_vecs, bq: int = _gl.DEFAULT_BQ):
    return _gl.gathered_l2(queries, cand_vecs, bq=bq, interpret=_interpret())


def gathered_l2_dot(queries, cand_vecs, bq: int = _gl.DEFAULT_BQ):
    return _gl.gathered_l2_dot(queries, cand_vecs, bq=bq, interpret=_interpret())


def gathered_topk(queries, vectors, ids, avail, b, e, version,
                  pool_ids, pool_d, pool_exp, bq: int = None):
    """Fused wavefront step: gather-by-id + L2 + label mask + beam merge
    (:mod:`repro.kernels.gathered_topk`) in one kernel call."""
    from . import gathered_topk as _gt
    return _gt.gathered_topk(queries, vectors, ids, avail, b, e, version,
                             pool_ids, pool_d, pool_exp,
                             bq=bq or _gt.DEFAULT_BQ, interpret=_interpret())


# re-export oracles for convenience
pairwise_l2_masked_ref = ref.pairwise_l2_masked_ref
gathered_l2_ref = ref.gathered_l2_ref
gathered_topk_ref = ref.gathered_topk_ref


def fused_topk_l2(queries, corpus, lo, hi, ql, qh, mask: int, k: int = 10,
                  bn: int = 1024):
    from . import fused_topk as _ft
    return _ft.fused_topk_l2(queries, corpus, lo, hi, ql, qh, mask, k=k,
                             bn=bn, interpret=_interpret())
