"""Public jit'd kernel entry points with automatic backend dispatch.

On TPU the Pallas kernels run compiled; everywhere else (this CPU container)
they run in ``interpret=True`` mode, which executes the kernel body in Python
on CPU — bitwise the same program structure, used by tests/benchmarks to
validate against the :mod:`repro.kernels.ref` oracles.

When a request trace is active (``repro.obs``), each entry point records a
``kernel:<name>`` span annotated with achieved memory bandwidth vs the TPU
v5e HBM peak (:func:`repro.obs.profile.bandwidth_annotation`). The traced
path blocks on the result so the span measures the kernel, not the dispatch;
with tracing off the wrappers stay fully async and add no work.

Bandwidth is annotated against *per-kernel byte models*, not a naive sum of
input array sizes: the gathered kernels read ``Q*M`` candidate rows out of
the table (not the whole table), and the compressed-scan kernels stream the
int8/float16 code bytes (not a float32-equivalent) — so the achieved-GB/s
roofline numbers stay honest across storage tiers. The models are exported
(:func:`pairwise_stream_bytes`, :func:`gathered_stream_bytes`) for
benchmarks that report side-by-side float32/int8 bandwidth.
"""
from __future__ import annotations

import functools
import time

import jax

from repro import obs
from repro.obs.profile import bandwidth_annotation

from . import pairwise_l2 as _pw
from . import pairwise_l2_int8 as _pw8
from . import gathered_l2 as _gl
from . import ref


@functools.cache
def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _nbytes(*arrays) -> int:
    """Sum of input array bytes — the byte model for kernels that stream
    every input exactly once (the pairwise family)."""
    total = 0
    for a in arrays:
        nb = getattr(a, "nbytes", None)
        if nb is not None:
            total += int(nb)
    return total


def pairwise_stream_bytes(Q: int, N: int, d: int, itemsize: int) -> int:
    """Byte model of a full-table masked scan: the (N, d) table at its
    storage itemsize, float32 queries, per-row endpoints, per-query bounds.
    ``itemsize`` is the table's bytes per component (4 float32, 2 float16,
    1 int8) — the lever the compressed tier pulls."""
    return N * d * itemsize + Q * d * 4 + 2 * N * 4 + 2 * Q * 4


def gathered_stream_bytes(Q: int, M: int, L: int, d: int,
                          itemsize: int) -> int:
    """Byte model of one wavefront step: the gather touches ``Q*M``
    candidate rows of ``d*itemsize`` bytes each — NOT the whole (n, d)
    table — plus the per-candidate id/avail/label arrays and the (Q, L)
    beam state in and out."""
    return (Q * d * 4                   # queries
            + Q * M * d * itemsize      # gathered candidate rows
            + Q * M * (4 * 4)           # ids, avail, lab_b, lab_e (int32)
            + Q * 4                     # versions
            + 2 * Q * L * (4 + 4 + 4))  # beam pool in + out (ids, d, exp)


def _run_traced(name: str, nbytes: int, thunk):
    """Run ``thunk`` inside a ``kernel:<name>`` span with an achieved-vs-peak
    bandwidth annotation. Only entered when a tracer is active — the traced
    path blocks on the result so the measured wall time bounds the kernel."""
    with obs.span(f"kernel:{name}") as sp:
        t0 = time.perf_counter()
        out = jax.block_until_ready(thunk())
        ann = bandwidth_annotation(nbytes, time.perf_counter() - t0)
        for key, v in ann.items():
            sp.set(key, v)
    return out


def pairwise_l2_masked(queries, corpus, lo, hi, ql, qh, mask: int,
                       bq: int = _pw.DEFAULT_BQ, bn: int = _pw.DEFAULT_BN):
    thunk = lambda: _pw.pairwise_l2_masked(  # noqa: E731
        queries, corpus, lo, hi, ql, qh, mask, bq=bq, bn=bn,
        interpret=_interpret())
    if not obs.tracing():
        return thunk()
    Q, d = queries.shape
    N = corpus.shape[0]
    return _run_traced(
        "pairwise_l2_masked",
        pairwise_stream_bytes(Q, N, d, corpus.dtype.itemsize), thunk)


def pairwise_l2_int8(queries, codes, scale, offset, sq_norm, lo, hi, ql, qh,
                     mask: int, bq: int = _pw8.DEFAULT_BQ,
                     bn: int = _pw8.DEFAULT_BN):
    """Compressed masked scan over int8 codes (integer MXU dot products +
    dequantized correction; :mod:`repro.kernels.pairwise_l2_int8`). The
    bandwidth annotation counts the *compressed* byte stream."""
    thunk = lambda: _pw8.pairwise_l2_int8(  # noqa: E731
        queries, codes, scale, offset, sq_norm, lo, hi, ql, qh, mask,
        bq=bq, bn=bn, interpret=_interpret())
    if not obs.tracing():
        return thunk()
    Q, d = queries.shape
    N = codes.shape[0]
    nbytes = (pairwise_stream_bytes(Q, N, d, 1)
              + N * 4                   # sq_norm
              + 2 * d * 4)              # scale + offset
    return _run_traced("pairwise_l2_int8", nbytes, thunk)


def gathered_l2(queries, cand_vecs, bq: int = _gl.DEFAULT_BQ):
    thunk = lambda: _gl.gathered_l2(  # noqa: E731
        queries, cand_vecs, bq=bq, interpret=_interpret())
    if not obs.tracing():
        return thunk()
    return _run_traced("gathered_l2", _nbytes(queries, cand_vecs), thunk)


def gathered_l2_dot(queries, cand_vecs, bq: int = _gl.DEFAULT_BQ):
    thunk = lambda: _gl.gathered_l2_dot(  # noqa: E731
        queries, cand_vecs, bq=bq, interpret=_interpret())
    if not obs.tracing():
        return thunk()
    return _run_traced("gathered_l2_dot", _nbytes(queries, cand_vecs), thunk)


def gathered_topk(queries, vectors, ids, avail, b, e, version,
                  pool_ids, pool_d, pool_exp, bq: int = None):
    """Fused wavefront step: gather-by-id + L2 + label mask + beam merge
    (:mod:`repro.kernels.gathered_topk`) in one kernel call."""
    from . import gathered_topk as _gt
    thunk = lambda: _gt.gathered_topk(  # noqa: E731
        queries, vectors, ids, avail, b, e, version, pool_ids, pool_d,
        pool_exp, bq=bq or _gt.DEFAULT_BQ, interpret=_interpret())
    if not obs.tracing():
        return thunk()
    Q, d = queries.shape
    nbytes = gathered_stream_bytes(Q, ids.shape[1], pool_d.shape[1], d,
                                   vectors.dtype.itemsize)
    return _run_traced("gathered_topk", nbytes, thunk)


def gathered_topk_quant(queries, codes, scale, offset, ids, avail, b, e,
                        version, pool_ids, pool_d, pool_exp, bq: int = None):
    """Wavefront step over a quantized code table: the gather streams
    int8/float16 rows and dequantizes in VMEM
    (:func:`repro.kernels.gathered_topk.gathered_topk_quant`)."""
    from . import gathered_topk as _gt
    thunk = lambda: _gt.gathered_topk_quant(  # noqa: E731
        queries, codes, scale, offset, ids, avail, b, e, version, pool_ids,
        pool_d, pool_exp, bq=bq or _gt.DEFAULT_BQ, interpret=_interpret())
    if not obs.tracing():
        return thunk()
    Q, d = queries.shape
    nbytes = (gathered_stream_bytes(Q, ids.shape[1], pool_d.shape[1], d,
                                    codes.dtype.itemsize)
              + 2 * d * 4)              # scale + offset
    return _run_traced("gathered_topk_quant", nbytes, thunk)


# re-export oracles for convenience
pairwise_l2_masked_ref = ref.pairwise_l2_masked_ref
pairwise_l2_int8_ref = ref.pairwise_l2_int8_ref
gathered_l2_ref = ref.gathered_l2_ref
gathered_topk_ref = ref.gathered_topk_ref
gathered_topk_quant_ref = ref.gathered_topk_quant_ref


def fused_topk_l2(queries, corpus, lo, hi, ql, qh, mask: int, k: int = 10,
                  bn: int = 1024):
    from . import fused_topk as _ft
    thunk = lambda: _ft.fused_topk_l2(  # noqa: E731
        queries, corpus, lo, hi, ql, qh, mask, k=k, bn=bn,
        interpret=_interpret())
    if not obs.tracing():
        return thunk()
    Q, d = queries.shape
    N = corpus.shape[0]
    return _run_traced(
        "fused_topk_l2",
        pairwise_stream_bytes(Q, N, d, corpus.dtype.itemsize), thunk)
