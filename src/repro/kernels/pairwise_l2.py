"""Fused RR-predicate + pairwise-L2 Pallas TPU kernel (DESIGN.md §2).

The paper's search cost is dominated by distance verification of candidates
that may or may not satisfy the filter. On TPU we fuse the two: each grid cell
loads a (BQ, d) query tile and a (BN, d) corpus tile into VMEM, forms
``|q|^2 - 2 q·cᵀ + |c|^2`` on the MXU with fp32 accumulation, evaluates the RR
predicate on the (BN,) endpoint tiles in VREGs and writes ``+inf`` for failing
candidates — non-qualifying vectors never leave the chip, the TPU analogue of
"avoid verifying vectors that do not satisfy the query predicate".

Block sizes are MXU-aligned (multiples of 128 on the N axis, 8+ on Q); the
full feature depth d rides along the minor dimension (d <= ~4k keeps the
working set ~4 MB < VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import intervals as iv

DEFAULT_BQ = 128
DEFAULT_BN = 256


def _kernel(q_ref, c_ref, lo_ref, hi_ref, ql_ref, qh_ref, out_ref, *, mask: int):
    q = q_ref[...].astype(jnp.float32)          # (BQ, d)
    c = c_ref[...].astype(jnp.float32)          # (BN, d)
    qn = jnp.sum(q * q, axis=1, keepdims=True)  # (BQ, 1)
    cn = jnp.sum(c * c, axis=1)                 # (BN,)
    # MXU: (BQ, d) x (d, BN)
    cross = jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    dist = qn - 2.0 * cross + cn[None, :]
    sel = iv.eval_predicate(mask, lo_ref[...][None, :], hi_ref[...][None, :],
                            ql_ref[...][:, None], qh_ref[...][:, None])
    out_ref[...] = jnp.where(sel, dist, jnp.inf)


@functools.partial(jax.jit, static_argnames=("mask", "bq", "bn", "interpret"))
def pairwise_l2_masked(queries, corpus, lo, hi, ql, qh, mask: int,
                       bq: int = DEFAULT_BQ, bn: int = DEFAULT_BN,
                       interpret: bool = False):
    """(Q, d) x (N, d) -> (Q, N) fused masked squared-L2. Q and N need not be
    block-aligned; inputs are padded and the pad region is predicate-masked."""
    Q, d = queries.shape
    N = corpus.shape[0]
    bq = min(bq, max(8, Q))
    bn = min(bn, max(128, N))
    Qp = -(-Q // bq) * bq
    Np = -(-N // bn) * bn
    qpad = jnp.pad(queries, ((0, Qp - Q), (0, 0)))
    cpad = jnp.pad(corpus, ((0, Np - N), (0, 0)))
    # NaN endpoints fail every RR comparison -> padded rows never qualify
    lop = jnp.pad(lo.astype(jnp.float32), (0, Np - N), constant_values=jnp.nan)
    hip = jnp.pad(hi.astype(jnp.float32), (0, Np - N), constant_values=jnp.nan)
    qlp = jnp.pad(ql.astype(jnp.float32), (0, Qp - Q), constant_values=jnp.nan)
    qhp = jnp.pad(qh.astype(jnp.float32), (0, Qp - Q), constant_values=jnp.nan)

    grid = (Qp // bq, Np // bn)
    out = pl.pallas_call(
        functools.partial(_kernel, mask=mask),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Qp, Np), jnp.float32),
        interpret=interpret,
    )(qpad, cpad, lop, hip, qlp, qhp)
    return out[:Q, :N]
