"""Fused RR-predicate + int8 compressed-scan Pallas TPU kernel.

The float32 variant (:mod:`repro.kernels.pairwise_l2`) is bandwidth-bound:
each grid cell streams a (BN, d) float32 corpus tile from HBM. This variant
streams the *codes* instead — 4x fewer bytes per tile — and keeps the MXU on
the int8 path: the per-query weights ``w = q * scale`` are symmetric-
quantized to int8 on the host side of the call (``alpha`` per query), the
tile product is an int8 x int8 -> int32 ``dot_general``
(``preferred_element_type=jnp.int32``), and the dequantized correction

    dist ~= (||q||^2 - 2 q.offset) - 2 * alpha * (wq . code) + sq_norm

is applied in VREGs before the RR predicate writes ``+inf`` for failing
candidates. The only approximation beyond storage quantization is the
query-side rounding of ``w / alpha``; both are absorbed by the engine's
exact float32 re-rank of the top ``rerank_k`` candidates.

Block shapes follow the float32 kernel (the repo's kernels are exercised in
interpret mode on this container); on a real TPU the int8 operands want the
(32, 128) minimum tile, which the default (128, 256) blocks satisfy on the
N axis whenever ``d`` is a lane multiple.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import intervals as iv

from .ref import quantize_query_weights_ref

DEFAULT_BQ = 128
DEFAULT_BN = 256


def _kernel(wq_ref, c_ref, alpha_ref, cq_ref, sqn_ref, lo_ref, hi_ref,
            ql_ref, qh_ref, out_ref, *, mask: int):
    wq = wq_ref[...]                            # (BQ, d) int8
    c = c_ref[...]                              # (BN, d) int8
    # MXU int8 path: (BQ, d) x (d, BN) with int32 accumulation
    acc = jax.lax.dot_general(wq, c, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.int32)
    dist = (cq_ref[...][:, None]
            - 2.0 * alpha_ref[...][:, None] * acc.astype(jnp.float32)
            + sqn_ref[...][None, :])
    sel = iv.eval_predicate(mask, lo_ref[...][None, :], hi_ref[...][None, :],
                            ql_ref[...][:, None], qh_ref[...][:, None])
    out_ref[...] = jnp.where(sel, dist, jnp.inf)


@functools.partial(jax.jit, static_argnames=("mask", "bq", "bn", "interpret"))
def pairwise_l2_int8(queries, codes, scale, offset, sq_norm, lo, hi, ql, qh,
                     mask: int, bq: int = DEFAULT_BQ, bn: int = DEFAULT_BN,
                     interpret: bool = False):
    """(Q, d) float32 queries x (N, d) int8 codes -> (Q, N) approximate
    masked squared-L2 against the dequantized corpus. Q and N need not be
    block-aligned; pad rows are zero codes masked by NaN endpoints."""
    Q, d = queries.shape
    N = codes.shape[0]
    wq, alpha, cq = quantize_query_weights_ref(queries, scale, offset)
    bq = min(bq, max(8, Q))
    bn = min(bn, max(128, N))
    Qp = -(-Q // bq) * bq
    Np = -(-N // bn) * bn
    wqp = jnp.pad(wq, ((0, Qp - Q), (0, 0)))
    cpad = jnp.pad(codes, ((0, Np - N), (0, 0)))
    # alpha pads to 1 (a 0 divisor never happens; value is irrelevant —
    # padded rows/cols are predicate-masked via NaN endpoints below)
    alphap = jnp.pad(alpha, (0, Qp - Q), constant_values=1.0)
    cqp = jnp.pad(cq, (0, Qp - Q))
    sqnp = jnp.pad(sq_norm.astype(jnp.float32), (0, Np - N))
    lop = jnp.pad(lo.astype(jnp.float32), (0, Np - N), constant_values=jnp.nan)
    hip = jnp.pad(hi.astype(jnp.float32), (0, Np - N), constant_values=jnp.nan)
    qlp = jnp.pad(ql.astype(jnp.float32), (0, Qp - Q), constant_values=jnp.nan)
    qhp = jnp.pad(qh.astype(jnp.float32), (0, Qp - Q), constant_values=jnp.nan)

    grid = (Qp // bq, Np // bn)
    out = pl.pallas_call(
        functools.partial(_kernel, mask=mask),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Qp, Np), jnp.float32),
        interpret=interpret,
    )(wqp, cpad, alphap, cqp, sqnp, lop, hip, qlp, qhp)
    return out[:Q, :N]
