"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import intervals as iv

NO_EDGE = -1


def pairwise_l2_masked_ref(queries, corpus, lo, hi, ql, qh, mask: int):
    """(Q, d) x (N, d) -> (Q, N) squared L2; +inf where the RR predicate fails.

    fp32 accumulation regardless of input dtype (matches kernel contract).
    """
    q = queries.astype(jnp.float32)
    c = corpus.astype(jnp.float32)
    qn = jnp.sum(q * q, axis=1, keepdims=True)
    cn = jnp.sum(c * c, axis=1)
    d = qn - 2.0 * (q @ c.T) + cn[None, :]
    sel = iv.eval_predicate(mask, lo[None, :], hi[None, :], ql[:, None], qh[:, None])
    return jnp.where(sel, d, jnp.inf)


def gathered_l2_ref(queries, cand_vecs):
    """(Q, d) x (Q, S, d) -> (Q, S) squared L2, fp32 accumulation."""
    q = queries.astype(jnp.float32)
    c = cand_vecs.astype(jnp.float32)
    diff = c - q[:, None, :]
    return jnp.sum(diff * diff, axis=-1)


def gathered_topk_ref(queries, vectors, ids, avail, b, e, version,
                      pool_ids, pool_d, pool_exp):
    """Oracle for the fused wavefront-step kernel: gather candidate vectors by
    id, squared L2, label mask ``b <= version <= e``, and a ``top_k`` merge
    into the sorted beam. Returns (ids, dists, expanded) of the pool width."""
    import jax

    q = queries.astype(jnp.float32)
    L = pool_d.shape[1]
    ok = ((avail != 0) & (b <= version[:, None]) & (version[:, None] <= e))
    idx = jnp.where(ids < 0, 0, ids)
    cand = vectors.astype(jnp.float32)[idx]
    diff = cand - q[:, None, :]
    nd = jnp.sum(diff * diff, axis=-1)
    nd = jnp.where(ok, nd, jnp.inf)
    nid = jnp.where(ok, ids, NO_EDGE)
    cat_d = jnp.concatenate([pool_d.astype(jnp.float32), nd], axis=1)
    cat_i = jnp.concatenate([pool_ids, nid], axis=1)
    cat_e = jnp.concatenate([pool_exp.astype(bool),
                             jnp.zeros(nd.shape, bool)], axis=1)
    neg, order = jax.lax.top_k(-cat_d, L)
    return (jnp.take_along_axis(cat_i, order, 1), -neg,
            jnp.take_along_axis(cat_e, order, 1))


def quantize_query_weights_ref(queries, scale, offset):
    """Shared prologue of the int8 scan: fold the per-dimension dequant
    scale into the query (``w = q * scale``), symmetric-quantize ``w`` to
    int8 with a per-query step ``alpha``, and precompute the query-side
    constant ``cq = ||q||^2 - 2 q.offset``. Returns (wq int8, alpha, cq)."""
    q = queries.astype(jnp.float32)
    w = q * scale[None, :]
    amax = jnp.max(jnp.abs(w), axis=1)
    alpha = jnp.where(amax > 0, amax / 127.0, 1.0)
    wq = jnp.clip(jnp.round(w / alpha[:, None]), -127, 127).astype(jnp.int8)
    cq = jnp.sum(q * q, axis=1) - 2.0 * (q @ offset)
    return wq, alpha, cq


def pairwise_l2_int8_ref(queries, codes, scale, offset, sq_norm,
                         lo, hi, ql, qh, mask: int):
    """Oracle for the int8 compressed scan: integer dot products between
    the symmetric-quantized query weights and the stored codes, followed by
    the dequantized correction

        dist ~= (||q||^2 - 2 q.offset) - 2 alpha * (wq . code) + sq_norm

    which is ``||q - x_hat||^2`` up to the query-side rounding of ``w/alpha``
    (absorbed by the exact float32 re-rank). +inf where the predicate fails.
    """
    wq, alpha, cq = quantize_query_weights_ref(queries, scale, offset)
    acc = (wq.astype(jnp.int32) @ codes.astype(jnp.int32).T)
    d = (cq[:, None] - 2.0 * alpha[:, None] * acc.astype(jnp.float32)
         + sq_norm.astype(jnp.float32)[None, :])
    sel = iv.eval_predicate(mask, lo[None, :], hi[None, :],
                            ql[:, None], qh[:, None])
    return jnp.where(sel, d, jnp.inf)


def gathered_topk_quant_ref(queries, codes, scale, offset, ids, avail, b, e,
                            version, pool_ids, pool_d, pool_exp):
    """Oracle for the quantized-table wavefront step: identical to
    :func:`gathered_topk_ref` against the affinely dequantized table
    ``codes * scale + offset`` (int8 or float16 codes)."""
    deq = (codes.astype(jnp.float32) * scale[None, :] + offset[None, :])
    return gathered_topk_ref(queries, deq, ids, avail, b, e, version,
                             pool_ids, pool_d, pool_exp)


def topk_mask_ref(dists, k: int):
    """(Q, N) -> bool mask of the k smallest per row (ties broken by index)."""
    idx = jnp.argsort(dists, axis=1)[:, :k]
    out = jnp.zeros_like(dists, dtype=bool)
    return out.at[jnp.arange(dists.shape[0])[:, None], idx].set(True)
