"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import intervals as iv

NO_EDGE = -1


def pairwise_l2_masked_ref(queries, corpus, lo, hi, ql, qh, mask: int):
    """(Q, d) x (N, d) -> (Q, N) squared L2; +inf where the RR predicate fails.

    fp32 accumulation regardless of input dtype (matches kernel contract).
    """
    q = queries.astype(jnp.float32)
    c = corpus.astype(jnp.float32)
    qn = jnp.sum(q * q, axis=1, keepdims=True)
    cn = jnp.sum(c * c, axis=1)
    d = qn - 2.0 * (q @ c.T) + cn[None, :]
    sel = iv.eval_predicate(mask, lo[None, :], hi[None, :], ql[:, None], qh[:, None])
    return jnp.where(sel, d, jnp.inf)


def gathered_l2_ref(queries, cand_vecs):
    """(Q, d) x (Q, S, d) -> (Q, S) squared L2, fp32 accumulation."""
    q = queries.astype(jnp.float32)
    c = cand_vecs.astype(jnp.float32)
    diff = c - q[:, None, :]
    return jnp.sum(diff * diff, axis=-1)


def gathered_topk_ref(queries, vectors, ids, avail, b, e, version,
                      pool_ids, pool_d, pool_exp):
    """Oracle for the fused wavefront-step kernel: gather candidate vectors by
    id, squared L2, label mask ``b <= version <= e``, and a ``top_k`` merge
    into the sorted beam. Returns (ids, dists, expanded) of the pool width."""
    import jax

    q = queries.astype(jnp.float32)
    L = pool_d.shape[1]
    ok = ((avail != 0) & (b <= version[:, None]) & (version[:, None] <= e))
    idx = jnp.where(ids < 0, 0, ids)
    cand = vectors.astype(jnp.float32)[idx]
    diff = cand - q[:, None, :]
    nd = jnp.sum(diff * diff, axis=-1)
    nd = jnp.where(ok, nd, jnp.inf)
    nid = jnp.where(ok, ids, NO_EDGE)
    cat_d = jnp.concatenate([pool_d.astype(jnp.float32), nd], axis=1)
    cat_i = jnp.concatenate([pool_ids, nid], axis=1)
    cat_e = jnp.concatenate([pool_exp.astype(bool),
                             jnp.zeros(nd.shape, bool)], axis=1)
    neg, order = jax.lax.top_k(-cat_d, L)
    return (jnp.take_along_axis(cat_i, order, 1), -neg,
            jnp.take_along_axis(cat_e, order, 1))


def topk_mask_ref(dists, k: int):
    """(Q, N) -> bool mask of the k smallest per row (ties broken by index)."""
    idx = jnp.argsort(dists, axis=1)[:, :k]
    out = jnp.zeros_like(dists, dtype=bool)
    return out.at[jnp.arange(dists.shape[0])[:, None], idx].set(True)
