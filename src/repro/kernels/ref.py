"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import intervals as iv


def pairwise_l2_masked_ref(queries, corpus, lo, hi, ql, qh, mask: int):
    """(Q, d) x (N, d) -> (Q, N) squared L2; +inf where the RR predicate fails.

    fp32 accumulation regardless of input dtype (matches kernel contract).
    """
    q = queries.astype(jnp.float32)
    c = corpus.astype(jnp.float32)
    qn = jnp.sum(q * q, axis=1, keepdims=True)
    cn = jnp.sum(c * c, axis=1)
    d = qn - 2.0 * (q @ c.T) + cn[None, :]
    sel = iv.eval_predicate(mask, lo[None, :], hi[None, :], ql[:, None], qh[:, None])
    return jnp.where(sel, d, jnp.inf)


def gathered_l2_ref(queries, cand_vecs):
    """(Q, d) x (Q, S, d) -> (Q, S) squared L2, fp32 accumulation."""
    q = queries.astype(jnp.float32)
    c = cand_vecs.astype(jnp.float32)
    diff = c - q[:, None, :]
    return jnp.sum(diff * diff, axis=-1)


def topk_mask_ref(dists, k: int):
    """(Q, N) -> bool mask of the k smallest per row (ties broken by index)."""
    idx = jnp.argsort(dists, axis=1)[:, :k]
    out = jnp.zeros_like(dists, dtype=bool)
    return out.at[jnp.arange(dists.shape[0])[:, None], idx].set(True)
