# NOTE: do not import dryrun here — it sets XLA_FLAGS at import time and must
# only be imported as the program entry point.
from .mesh import make_production_mesh, make_host_mesh
