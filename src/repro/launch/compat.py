"""Small shims over XLA/JAX API drift so the launch tooling runs on both the
pinned 0.4.x environment and current JAX."""
from __future__ import annotations


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` returns a one-element list of dicts on
    jax 0.4.x and a plain dict on newer versions; normalize to a dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}
