import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input shape x mesh) cell on 512 placeholder devices, print
memory_analysis()/cost_analysis(), and persist per-cell JSON artifacts
(memory, flops, bytes, per-collective byte totals) for §Roofline.

The XLA_FLAGS line above MUST precede every other import — jax locks the
device count on first init.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi_pod
  PYTHONPATH=src python -m repro.launch.dryrun --list
"""
import argparse
import json
import re
import sys
import time
import traceback

import numpy as np

import jax

from repro.configs import (ALL_SHAPES, ARCH_NAMES, SHAPES_BY_NAME, get_config,
                           supports_shape)
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import ArchRunner

ARTIFACT_DIR = os.environ.get("DRYRUN_ARTIFACTS",
                              os.path.join(os.path.dirname(__file__),
                                           "..", "..", "..", "artifacts", "dryrun"))

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast")

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)"
                       r"\[([0-9,]*)\]")


def _type_bytes(m) -> int:
    dt, dims = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def collective_bytes(hlo_text: str, n_devices: int = 1):
    """Per-device collective accounting from post-SPMD HLO.

    HLO prints only the RESULT type at the call site, so operand bytes are
    derived: all-gather operand = result/P, reduce-scatter operand = result*P,
    everything else operand = result (P = replica group size). ``wire`` is the
    estimated bytes a device moves on the ICI for the op (ring schedules)."""
    totals = {c: 0 for c in _COLLECTIVES}
    wire = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    opname_re = re.compile(
        r"=\s+(.*?)\s+(" + "|".join(_COLLECTIVES) + r")(-start)?\(")
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s.startswith("%") and not s.startswith("ROOT"):
            continue
        m = opname_re.search(s)
        if not m:
            continue
        result_sec, op, is_start = m.group(1), m.group(2), m.group(3)
        shapes = [_type_bytes(t) for t in _SHAPE_RE.finditer(result_sec)]
        if not shapes:
            continue
        # async -start ops carry (operand, result, ...) tuples: the gathered
        # result is the largest element
        rbytes = max(shapes) if is_start else sum(shapes)
        P = _group_size(s, n_devices)
        if op == "all-gather":
            operand = rbytes // max(P, 1)
            w = rbytes * (P - 1) // max(P, 1)
        elif op == "reduce-scatter":
            operand = rbytes * P
            w = rbytes * (P - 1)
        elif op == "all-reduce":
            operand = rbytes
            w = 2 * rbytes * (P - 1) // max(P, 1)
        elif op == "all-to-all":
            operand = rbytes
            w = rbytes * (P - 1) // max(P, 1)
        else:  # collective-permute / broadcast
            operand = rbytes
            w = rbytes
        totals[op] += operand
        wire[op] += w
        counts[op] += 1
    return totals, wire, counts


def run_cell(arch: str, shape_name: str, mesh_kind: str, artifact_dir: str,
             force: bool = False):
    cell_id = f"{arch}__{shape_name}__{mesh_kind}"
    out_path = os.path.join(artifact_dir, cell_id + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            prev = json.load(f)
        if prev.get("status") in ("ok", "skipped"):
            print(f"[cached ] {cell_id}: {prev['status']}")
            return prev
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = supports_shape(cfg, shape)
    rec = {"cell": cell_id, "arch": arch, "shape": shape_name,
           "mesh": mesh_kind, "kind": shape.kind}
    if not ok:
        rec.update(status="skipped", reason=why)
        _write(out_path, rec)
        print(f"[skipped] {cell_id}: {why}")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi_pod"))
    t0 = time.time()
    try:
        runner = ArchRunner(cfg, mesh)
        bundle = runner.bundle_for(shape)
        with mesh:
            jf = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                         out_shardings=bundle.out_shardings,
                         donate_argnums=bundle.donate)
            lowered = jf.lower(*bundle.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        print(compiled.memory_analysis())
        from .compat import cost_analysis_dict
        ca = cost_analysis_dict(compiled)
        print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})
        hlo = compiled.as_text()
        colls, cwire, ccounts = collective_bytes(
            hlo, int(np.prod(list(mesh.shape.values()))))
        rec.update(
            status="ok",
            step=bundle.name,
            devices=int(np.prod(list(mesh.shape.values()))),
            mesh_shape={k: int(v) for k, v in mesh.shape.items()},
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            },
            flops_per_device=ca.get("flops") if isinstance(ca, dict) else None,
            bytes_per_device=ca.get("bytes accessed") if isinstance(ca, dict) else None,
            collective_bytes=colls,
            collective_wire_bytes=cwire,
            collective_counts=ccounts,
        )
        print(f"[ok     ] {cell_id}: lower {t_lower:.1f}s compile "
              f"{t_compile:.1f}s flops/dev {rec['flops_per_device']:.3e}")
    except Exception as e:  # noqa: BLE001 — record failures as artifacts
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[ERROR  ] {cell_id}: {type(e).__name__}: {e}")
    _write(out_path, rec)
    return rec


def _write(path, rec):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_NAMES) + [None])
    ap.add_argument("--shape", default=None,
                    choices=[s.name for s in ALL_SHAPES] + [None])
    ap.add_argument("--mesh", default=None, choices=["single_pod", "multi_pod", None])
    ap.add_argument("--artifacts", default=ARTIFACT_DIR)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_NAMES)
    shapes = [args.shape] if args.shape else [s.name for s in ALL_SHAPES]
    meshes = [args.mesh] if args.mesh else ["single_pod", "multi_pod"]

    if args.list:
        for a in archs:
            for s in shapes:
                ok, why = supports_shape(get_config(a), SHAPES_BY_NAME[s])
                print(f"{a:24s} {s:12s} {'RUN' if ok else 'SKIP: ' + why}")
        return

    results = []
    for a in archs:
        for s in shapes:
            for m in meshes:
                results.append(run_cell(a, s, m, args.artifacts,
                                        force=args.force))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"of {len(results)} cells")
    if n_err:
        sys.exit(1)


if __name__ == "__main__":
    main()
