import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Dry-run + roofline for the PAPER's serving step itself: distributed
RR-filtered top-k (MSTG flat engine) over a pod-scale corpus.

Corpus sharded over 'data' (and 'pod'), queries replicated, per-shard fused
predicate+distance + top-k, tournament/all-gather merge. Lowered with
ShapeDtypeStructs only; costs are exact (no scan bodies).

  PYTHONPATH=src python -m repro.launch.dryrun_mstg
"""
import argparse
import functools
import json
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import ANY_OVERLAP
from repro.core.flat import flat_search
from repro.core.hnsw import NO_EDGE
from repro.distributed.topk import global_topk_merge, tournament_topk_merge
from repro.launch.dryrun import ARTIFACT_DIR, collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS

# production serving shape: 1M corpus x 1024-query batch, d=128 (SIFT-like)
N_CORPUS = 1 << 20
N_QUERIES = 1024
DIM = 128
K = 10


def build_step(mesh, merge: str, mask: int = ANY_OVERLAP, k: int = K):
    from jax.experimental.shard_map import shard_map
    corpus_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    D = int(np.prod([mesh.shape[a] for a in corpus_axes]))
    nloc = N_CORPUS // D
    merge_fn = {"all_gather": global_topk_merge,
                "tournament": tournament_topk_merge}[merge]
    # flatten (pod, data) into one logical shard axis via nested merges
    ax = corpus_axes[-1]

    # corpus over (pod, data); queries over 'model' — every device does
    # (Q/model) x (N/(pod*data)) distance work, the full-mesh decomposition
    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(corpus_axes, None), P(corpus_axes), P(corpus_axes),
                  P("model", None), P("model"), P("model")),
        out_specs=(P("model", None), P("model", None)),
        check_rep=False)
    def run(c, l, h, q, a, b):
        ids, d = flat_search(c, l, h, q, a, b, mask=mask, k=k)
        idx = jax.lax.axis_index(corpus_axes[0])
        if len(corpus_axes) > 1:
            idx = idx * mesh.shape[corpus_axes[1]] + jax.lax.axis_index(
                corpus_axes[1])
        gids = jnp.where(ids != NO_EDGE, ids + idx * nloc, NO_EDGE)
        gids, d = merge_fn(gids, d, k, ax)
        if len(corpus_axes) > 1:
            gids_all = jax.lax.all_gather(gids, corpus_axes[0])
            d_all = jax.lax.all_gather(d, corpus_axes[0])
            Dp = gids_all.shape[0]
            gids = jnp.moveaxis(gids_all, 0, 1).reshape(gids.shape[0], Dp * k)
            d2 = jnp.moveaxis(d_all, 0, 1).reshape(d.shape[0], Dp * k)
            neg, pos = jax.lax.top_k(-d2, k)
            gids = jnp.take_along_axis(gids, pos, 1)
            d = -neg
        return gids, d

    args = (jax.ShapeDtypeStruct((N_CORPUS, DIM), jnp.float32),
            jax.ShapeDtypeStruct((N_CORPUS,), jnp.float32),
            jax.ShapeDtypeStruct((N_CORPUS,), jnp.float32),
            jax.ShapeDtypeStruct((N_QUERIES, DIM), jnp.float32),
            jax.ShapeDtypeStruct((N_QUERIES,), jnp.float32),
            jax.ShapeDtypeStruct((N_QUERIES,), jnp.float32))
    return run, args


def build_step_v2(mesh, mask: int = ANY_OVERLAP, k: int = K):
    """§Perf iteration 6 layout: corpus over the FULL mesh, queries
    replicated, blocked fused top-k (no HBM distance matrix), hierarchical
    tournament merge. Arithmetic intensity per corpus byte rises from
    2·(Q/model) to 2·Q — past the v5e knee."""
    from jax.experimental.shard_map import shard_map
    from repro.core.flat import flat_search_blocked
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    Dall = int(np.prod([mesh.shape[a] for a in axes]))
    nloc = N_CORPUS // Dall

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(axes, None), P(axes), P(axes),
                  P(None, None), P(None), P(None)),
        out_specs=(P(None, None), P(None, None)),
        check_rep=False)
    def run(c, l, h, q, a, b):
        ids, d = flat_search_blocked(c, l, h, q, a, b, mask=mask, k=k)
        idx = jnp.zeros((), jnp.int32)
        for ax in axes:
            idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
        gids = jnp.where(ids != NO_EDGE, ids + idx * nloc, NO_EDGE)
        d_out, i_out = d, gids
        for ax in reversed(axes):  # butterfly per axis, innermost first
            i_out, d_out = tournament_topk_merge(i_out, d_out, k, ax)
        return i_out, d_out

    args = (jax.ShapeDtypeStruct((N_CORPUS, DIM), jnp.float32),
            jax.ShapeDtypeStruct((N_CORPUS,), jnp.float32),
            jax.ShapeDtypeStruct((N_CORPUS,), jnp.float32),
            jax.ShapeDtypeStruct((N_QUERIES, DIM), jnp.float32),
            jax.ShapeDtypeStruct((N_QUERIES,), jnp.float32),
            jax.ShapeDtypeStruct((N_QUERIES,), jnp.float32))
    return run, args


def run_cell(mesh_kind: str, merge: str, artifact_dir: str, force=False):
    cell = f"mstg-flat-serve__{merge}__{mesh_kind}"
    path = os.path.join(artifact_dir, cell + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi_pod"))
    ndev = int(np.prod(list(mesh.shape.values())))
    if merge == "fullmesh_v2":
        fn, args = build_step_v2(mesh)
    else:
        fn, args = build_step(mesh, merge)
    t0 = time.time()
    with mesh:
        compiled = jax.jit(fn).lower(*args).compile()
    from .compat import cost_analysis_dict
    ca = cost_analysis_dict(compiled)
    mem = compiled.memory_analysis()
    colls, wire, counts = collective_bytes(compiled.as_text(), ndev)
    flops = float(ca.get("flops", 0))
    nbytes = float(ca.get("bytes accessed", 0))
    rec = {
        "cell": cell, "status": "ok", "devices": ndev, "merge": merge,
        "corpus": N_CORPUS, "queries": N_QUERIES, "dim": DIM, "k": K,
        "compile_s": round(time.time() - t0, 1),
        "flops_per_device": flops, "bytes_per_device": nbytes,
        "memory": {"temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                   "argument_bytes": getattr(mem, "argument_size_in_bytes", None)},
        "collective_bytes": colls, "collective_wire_bytes": wire,
        "collective_counts": counts,
        "terms": {"compute_s": flops / PEAK_FLOPS,
                  "memory_hlo_s": nbytes / HBM_BW,
                  "collective_s": sum(colls.values()) / LINK_BW},
        # model flops per device: Q_loc x N_loc masked distances
        "model_flops_per_device": (
            N_QUERIES * (N_CORPUS / ndev) * 2 * DIM if merge == "fullmesh_v2"
            else (N_QUERIES / mesh.shape["model"]) *
                 (N_CORPUS * mesh.shape["model"] / ndev) * 2 * DIM),
    }
    os.makedirs(artifact_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    t = rec["terms"]
    print(f"[ok] {cell}: flops/dev {flops:.3e} compute {t['compute_s']*1e3:.3f}ms "
          f"mem-ub {t['memory_hlo_s']*1e3:.3f}ms coll {t['collective_s']*1e3:.4f}ms "
          f"counts={ {k: v for k, v in counts.items() if v} }")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default=ARTIFACT_DIR)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    for mesh_kind in ("single_pod", "multi_pod"):
        for merge in ("all_gather", "tournament", "fullmesh_v2"):
            run_cell(mesh_kind, merge, args.artifacts, force=args.force)


if __name__ == "__main__":
    main()
