"""Production mesh construction (multi-pod dry-run contract).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import)."""
from __future__ import annotations

import jax

try:  # AxisType / make_mesh(axis_types=...) appeared after jax 0.4.x
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def make_mesh(shape, axes):
    """jax.make_mesh with explicit Auto axis types where supported."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(data, 1)))
    return make_mesh((data, model), ("data", "model"))
