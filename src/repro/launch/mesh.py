"""Production mesh construction (multi-pod dry-run contract).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import)."""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(data, 1)))
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
