import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Roofline analysis (deliverable g) from dry-run artifacts.

Terms per (arch x shape), single-pod mesh, TPU v5e constants:

    compute    = HLO_FLOPs_per_device / 197e12
    memory     = HLO_bytes_per_device / 819e9
    collective = collective_operand_bytes_per_device / 50e9

Scan-count correction: XLA's cost_analysis counts a ``lax.scan`` body ONCE
regardless of trip count. We therefore re-lower each cell twice per segment
with `scan_layers=False` (unrolled) tiny-depth variants — base (all segments
repeat=1) and per-segment bump (repeat=2) — whose difference is the exact
per-layer cost; corrected totals add (repeats-1) x unit to the full compile's
numbers. MODEL_FLOPS uses 6·N·D (train) / 2·N_active·tokens (serve).

Usage: PYTHONPATH=src python -m repro.launch.roofline [--arch A] [--shape S]
"""
import argparse
import dataclasses
import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, ALL_SHAPES, SHAPES_BY_NAME, get_config
from repro.launch.dryrun import ARTIFACT_DIR, collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import ArchRunner
from repro.models.transformer import LM

# chip constants live in repro.obs.profile so kernel trace spans and this
# analytic model agree on the same peaks; re-exported here for callers.
from repro.obs.profile import HBM_BW, LINK_BW, PEAK_FLOPS

ROOF_DIR = os.environ.get("ROOFLINE_ARTIFACTS",
                          os.path.join(os.path.dirname(ARTIFACT_DIR), "roofline"))


def _measure(cfg, shape_name, mesh, repeats):
    # unrolled layers AND unrolled flash blocks (big chunks keep the HLO
    # small) so cost_analysis sees every scanned body — incl. the true
    # S^2 attention work with causal/window block-skipping (§Perf iter. 7)
    seq = SHAPES_BY_NAME[shape_name].seq_len
    chunk = max(min(seq // 4, 8192), 128)
    runner = ArchRunner(dataclasses.replace(cfg, scan_layers=False,
                                            flash_unroll=True,
                                            q_chunk=chunk, kv_chunk=chunk),
                        mesh, segment_repeats=tuple(repeats))
    bundle = runner.bundle_for(SHAPES_BY_NAME[shape_name])
    with mesh:
        compiled = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                           out_shardings=bundle.out_shardings,
                           donate_argnums=bundle.donate
                           ).lower(*bundle.args).compile()
    from .compat import cost_analysis_dict
    ca = cost_analysis_dict(compiled)
    ndev = int(np.prod(list(mesh.shape.values())))
    colls, wire, _ = collective_bytes(compiled.as_text(), ndev)
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": float(sum(colls.values())),
            "wire": float(sum(wire.values()))}


def _sub(a, b):
    return {k: max(a[k] - b[k], 0.0) for k in a}


def analytic_memory_bytes(cfg, lm: LM, shape, mesh_shape) -> float:
    """First-principles per-device HBM traffic estimate (documented ±2x).

    XLA-CPU's ``bytes accessed`` counts every unfused operand — a large upper
    bound relative to a TPU compile. This model instead counts what a fused
    TPU program must move: weight reads (post-FSDP-gather, so TP-sharded
    only; x3 for fwd/bwd/remat in training), optimizer/gradient traffic on
    the fully-sharded copies, a per-layer activation constant, logits chunks,
    and KV-cache traffic for serving."""
    dp = int(np.prod([v for k, v in mesh_shape.items() if k != "model"]))
    mp = int(mesh_shape.get("model", 1))
    devices = dp * mp
    pb = jnp.dtype(cfg.param_dtype).itemsize
    ab = jnp.dtype(cfg.activ_dtype).itemsize
    n_params = lm.param_count()
    n_active = lm.active_param_count()
    P_tp = n_params * pb / mp          # per-device weight bytes after gather
    P_dev = n_params * pb / devices    # fully-sharded (FSDP) weight bytes
    B_loc = max(shape.global_batch // dp, 1)
    L = cfg.n_layers + cfg.n_enc_layers
    D = cfg.d_model
    F = (cfg.top_k * cfg.moe_d_ff + cfg.n_shared_experts * cfg.moe_d_ff
         if cfg.n_experts else cfg.d_ff)

    if shape.kind == "train":
        T = B_loc * shape.seq_len
        w = 3 * P_tp + (1 + 4 * 4 / pb) * P_dev * 2
        acts = L * T * ab * (10 * D + 6 * F / max(mp, 1))
        logits = 4 * T * (cfg.vocab / mp) * 4
        return w + acts + logits
    if shape.kind == "prefill":
        T = B_loc * shape.seq_len
        w = P_tp
        acts = L * T * ab * (6 * D + 3 * F / max(mp, 1))
        cache = _cache_bytes(lm, shape, devices)
        return w + acts + cache
    # decode: weights read once per step (batch>1 touches ~all experts) +
    # the whole resident cache. Experts shard over the full mesh at serve
    # time when divisible (SERVE_RULES; §Perf iteration 2).
    del n_active
    if cfg.n_experts:
        moe_layers = sum(1 for d in lm.descs if d.mlp == "moe")
        expert_params = moe_layers * cfg.n_experts * 3 * cfg.d_model * cfg.moe_d_ff
        ep = devices if cfg.n_experts % devices == 0 else mp
        w = (n_params - expert_params) * pb / mp + expert_params * pb / ep
    else:
        w = P_tp
    return w + _cache_bytes(lm, shape, devices)


def _cache_bytes(lm: LM, shape, devices: int) -> float:
    n_front = (lm.cfg.n_frontend_tokens
               if lm.cfg.frontend == "vision_stub" else 0)
    enc_len = shape.seq_len if lm.cfg.n_enc_layers else 0
    metas = lm.decode_cache_meta(shape.global_batch, shape.seq_len + n_front,
                                 enc_len)
    total = 0
    for seg in metas:
        for s in jax.tree.leaves(seg):
            total += int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
    return total / devices


def model_flops(cfg, lm: LM, shape, devices: int) -> float:
    """Per-device MODEL_FLOPS: 6·N·D for training, 2·N_active·D for serving."""
    n_active = lm.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / devices
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / devices
    tokens = shape.global_batch  # one token per sequence per step
    return 2.0 * n_active * tokens / devices


def analyze_cell(arch: str, shape_name: str, artifact_dir: str,
                 out_dir: str, force: bool = False):
    out_path = os.path.join(out_dir, f"{arch}__{shape_name}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)
    cell_path = os.path.join(artifact_dir, f"{arch}__{shape_name}__single_pod.json")
    if not os.path.exists(cell_path):
        return None
    with open(cell_path) as f:
        cell = json.load(f)
    if cell["status"] != "ok":
        rec = {"arch": arch, "shape": shape_name, "status": cell["status"],
               "reason": cell.get("reason", cell.get("error", ""))}
        _write(out_path, rec)
        return rec

    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=False)
    devices = int(np.prod(list(mesh.shape.values())))
    lm = LM(cfg)
    R = [s.repeats for s in lm.segments]

    t0 = time.time()
    base = _measure(cfg, shape_name, mesh, [1] * len(R))
    units = []
    for k in range(len(R)):
        if R[k] == 1:
            units.append({k2: 0.0 for k2 in base})
            continue
        reps = [1] * len(R)
        reps[k] = 2
        units.append(_sub(_measure(cfg, shape_name, mesh, reps), base))

    full = {"flops": cell["flops_per_device"],
            "bytes": cell["bytes_per_device"],
            "coll": float(sum(cell["collective_bytes"].values())),
            "wire": float(sum(cell["collective_wire_bytes"].values()))}
    corr = dict(full)
    for k, u in enumerate(units):
        for key in corr:
            corr[key] += (R[k] - 1) * u[key]

    mf = model_flops(cfg, lm, shape, devices)
    terms = {
        "compute_s": corr["flops"] / PEAK_FLOPS,
        "memory_hlo_s": corr["bytes"] / HBM_BW,      # unfused upper bound
        "memory_s": analytic_memory_bytes(cfg, lm, shape,
                                          dict(mesh.shape)) / HBM_BW,
        "collective_s": corr["coll"] / LINK_BW,
        "collective_wire_s": corr["wire"] / LINK_BW,
    }
    core = {k: terms[k] for k in ("compute_s", "memory_s", "collective_s")}
    dominant = max(core, key=core.get)
    bound = max(core.values())
    rec = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "kind": cell["kind"], "devices": devices,
        "hlo": full, "corrected": corr, "segment_repeats": R,
        "model_flops_per_device": mf,
        "useful_ratio": mf / corr["flops"] if corr["flops"] else None,
        "terms": terms,
        "dominant": dominant,
        "roofline_fraction": (terms["compute_s"] / bound) if bound else None,
        "analysis_s": round(time.time() - t0, 1),
    }
    _write(out_path, rec)
    print(f"[roofline] {arch:24s} {shape_name:12s} dominant={dominant:12s} "
          f"compute={terms['compute_s']*1e3:9.2f}ms memory={terms['memory_s']*1e3:9.2f}ms "
          f"coll={terms['collective_s']*1e3:9.2f}ms useful={rec['useful_ratio']:.3f}")
    return rec


def _write(path, rec):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def emit_markdown(out_dir: str) -> str:
    rows = []
    for a in ARCH_NAMES:
        for s in ALL_SHAPES:
            p = os.path.join(out_dir, f"{a}__{s.name}.json")
            if os.path.exists(p):
                with open(p) as f:
                    rows.append(json.load(f))
    lines = ["| arch | shape | dominant | compute (ms) | memory (ms) | "
             "mem-HLO-ub (ms) | collective (ms) | MODEL/HLO flops | "
             "roofline frac |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — skipped: "
                         f"{r.get('reason','')[:60]} | | | | | | |")
            continue
        t = r["terms"]
        mh = t.get("memory_hlo_s", t["memory_s"])
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['dominant'].replace('_s','')} "
            f"| {t['compute_s']*1e3:.2f} | {t['memory_s']*1e3:.2f} "
            f"| {mh*1e3:.2f} "
            f"| {t['collective_s']*1e3:.2f} | {r['useful_ratio']:.3f} "
            f"| {r['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--artifacts", default=ARTIFACT_DIR)
    ap.add_argument("--out", default=ROOF_DIR)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    if args.markdown:
        print(emit_markdown(args.out))
        return
    archs = [args.arch] if args.arch else list(ARCH_NAMES)
    shapes = [args.shape] if args.shape else [s.name for s in ALL_SHAPES]
    for a in archs:
        for s in shapes:
            try:
                analyze_cell(a, s, args.artifacts, args.out, force=args.force)
            except Exception as e:  # noqa: BLE001
                print(f"[roofline-ERROR] {a} {s}: {type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
