"""End-to-end serving driver — the paper's deployment scenario.

  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --requests 24
  PYTHONPATH=src python -m repro.launch.serve --streaming   # live corpus
  PYTHONPATH=src python -m repro.launch.serve --async       # SLO front end
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --shards 8  # sharded corpus

Builds an MSTG index over a synthetic corpus, stands up the batched
RetrievalServer with an LM-embedding front (smoke-scale model), and serves
RR-filtered ANN requests end to end (generate + retrieve). ``--streaming``
backs the server with a :class:`repro.streaming.SegmentedIndex` instead and
interleaves upserts/deletes with the query traffic. ``--shards N`` serves
from a :class:`repro.distributed.ShardedDeployment` — per-shard MSTG
engines merged through the device collectives when a mesh covers N, else
the host merge. ``--async`` routes the same traffic through the
continuous-batching :class:`repro.serving.AsyncRetrievalServer` (bounded
admission, EDF deadlines, typed shedding) and prints its metrics
snapshot."""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import (IndexSpec, MSTGIndex, Overlaps, QueryContained,
                        QueryEngine)
from repro.data import make_range_dataset, make_queries
from repro.models.transformer import LM
from repro.serving import RetrievalServer, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--n", type=int, default=1500)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--streaming", action="store_true",
                    help="serve from a mutable SegmentedIndex and interleave "
                         "upserts/deletes with query traffic")
    ap.add_argument("--shards", type=int, default=0, metavar="N",
                    help="serve from an N-shard ShardedDeployment (device "
                         "merge when the mesh covers N, else host merge)")
    ap.add_argument("--async", dest="use_async", action="store_true",
                    help="serve through the continuous-batching async front "
                         "end (SLO admission + wavefront slot refill) and "
                         "print its metrics snapshot")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline for --async traffic (late "
                         "queued requests are shed as Rejected)")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve the process metrics registry over HTTP: "
                         "Prometheus text at /metrics, the typed JSON "
                         "snapshot at /metrics.json (0 = ephemeral port)")
    args = ap.parse_args()
    if args.shards and args.streaming:
        ap.error("--shards and --streaming are mutually exclusive (shard a "
                 "SegmentedIndex via ShardedDeployment.from_segmented)")

    if args.metrics_port is not None:
        from repro import obs
        http = obs.start_metrics_server(args.metrics_port)
        print(f"metrics: http://{http.server_address[0]}:"
              f"{http.server_address[1]}/metrics (+ /metrics.json)")

    # 1) corpus + index (the paper's contribution)
    ds = make_range_dataset(n=args.n, d=args.dim, n_queries=args.requests,
                            quantize=128, seed=0)
    spec = IndexSpec(variants=("T", "Tp"), m=12, ef_con=64)
    t0 = time.time()
    if args.shards:
        from repro.distributed import DeploymentSpec, ShardedDeployment
        from repro.launch.mesh import make_mesh
        mesh = (make_mesh((args.shards,), ("data",))
                if args.shards <= len(jax.devices()) else None)
        qengine = ShardedDeployment.build(
            ds.vectors, ds.lo, ds.hi, mesh=mesh,
            spec=DeploymentSpec(n_shards=args.shards, index=spec))
        print(f"sharded MSTG built: n={args.n} shards={args.shards} "
              f"mesh={'yes' if mesh is not None else 'no (host merge)'} "
              f"in {time.time()-t0:.1f}s")
    elif args.streaming:
        from repro.streaming import SegmentedIndex
        qengine = SegmentedIndex(spec, flush_threshold=args.n)
        qengine.add(np.arange(args.n), ds.vectors, ds.lo, ds.hi)
        qengine.flush()
        print(f"segmented MSTG built: n={args.n} "
              f"segments={len(qengine.segments)} in {time.time()-t0:.1f}s")
    else:
        idx = MSTGIndex.build(spec, ds.vectors, ds.lo, ds.hi)
        qengine = QueryEngine(idx)
        print(f"MSTG built: n={args.n} K={idx.domain.K} "
              f"bytes={idx.index_bytes()/1e6:.1f}MB in {time.time()-t0:.1f}s")

    # 2) LM endpoint (smoke-scale) — generates and embeds requests
    cfg = get_smoke_config(args.arch)
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    engine = ServeEngine(lm, params)
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)
    batch = {"tokens": tokens}
    if cfg.frontend == "vision_stub":
        batch["patches"] = jnp.asarray(rng.normal(
            0, 1, (4, cfg.n_frontend_tokens, cfg.frontend_dim)).astype(np.float32))
    if cfg.frontend == "audio_stub":
        batch["frames"] = jnp.asarray(rng.normal(
            0, 1, (4, 16, cfg.frontend_dim)).astype(np.float32))
    gen = engine.generate(batch, n_new=8, max_len=64)
    print(f"LM generate ok: {gen.tokens.shape} tokens")

    # 3) batched retrieval serving: Predicate submits, one embed call per tick
    embed_fn = lambda items: ds.queries[np.asarray(items)]  # stub embedding
    qlo, qhi = make_queries(ds, Overlaps().mask, 0.15, seed=2)
    rng = np.random.default_rng(7)

    if args.use_async:
        from repro.serving import AsyncRetrievalServer, SLOPolicy
        server = AsyncRetrievalServer(
            qengine, embed_fn, k=args.k, ef=64,
            policy=SLOPolicy(max_wait_ms=1.0, max_batch=32))
        n_mut = 0
        t0 = time.time()
        for i in range(args.requests):
            if args.streaming and i % 4 == 1:
                j = i % args.n
                server.submit_upsert(args.n + i, i, ds.lo[j], ds.hi[j])
                server.submit_delete(int(rng.integers(0, args.n)))
                n_mut += 2
            pred = Overlaps() if i % 2 == 0 else QueryContained()
            server.submit(i, qlo[i], qhi[i], pred,
                          deadline_ms=args.deadline_ms)
        results = server.run_until_idle()
        dt = time.time() - t0
        served = {t: r for t, r in results.items() if r and r.hit is not None}
        ok = sum(1 for r in served.values() if r.hit.valid.any())
        print(f"async served {len(served)} requests (+{n_mut} mutations) in "
              f"{dt*1e3:.1f} ms ({len(served)/dt:.1f} qps); {ok} non-empty")
        snap = server.snapshot()
        print(f"  metrics: served={snap['served']} shed={snap['shed']} "
              f"deadline_missed={snap['deadline_missed']} "
              f"degraded={snap['degraded']}")
        print(f"  queue-wait ms p50/p95/p99: "
              f"{snap['queue_wait_ms']['p50']:.2f}/"
              f"{snap['queue_wait_ms']['p95']:.2f}/"
              f"{snap['queue_wait_ms']['p99']:.2f}")
        print(f"  e2e ms p50/p95/p99: {snap['e2e_ms']['p50']:.2f}/"
              f"{snap['e2e_ms']['p95']:.2f}/{snap['e2e_ms']['p99']:.2f}")
        if "batch_occupancy" in snap:
            print(f"  occupancy={snap['batch_occupancy']:.2f} "
                  f"refill_eff={snap['refill_efficiency']:.2f} "
                  f"refills={snap['refills']}")
        for t in list(served)[:3]:
            print(f"  ticket {t}: top ids "
                  f"{served[t].hit.ids[:5].tolist()}")
        return

    server = RetrievalServer(qengine, embed_fn, k=args.k, ef=64)
    n_mut = 0
    for i in range(args.requests):
        if args.streaming and i % 4 == 1:  # live traffic: mutate mid-stream
            j = i % args.n
            server.submit_upsert(args.n + i, i, ds.lo[j], ds.hi[j])
            server.submit_delete(int(rng.integers(0, args.n)))
            n_mut += 2
        pred = Overlaps() if i % 2 == 0 else QueryContained()
        server.submit(i, qlo[i], qhi[i], pred)
    t0 = time.time()
    results = server.tick()
    dt = time.time() - t0
    ok = sum(1 for hit in results.values() if hit.valid.any())
    print(f"served {len(results)} requests (+{n_mut} mutations) in "
          f"{dt*1e3:.1f} ms ({len(results)/dt:.1f} qps); "
          f"embed/mutate/search s="
          f"{server.tick_stats['embed_s']:.3f}/"
          f"{server.tick_stats['mutate_s']:.3f}/"
          f"{server.tick_stats['search_s']:.3f}; {ok} non-empty")
    if args.streaming:
        print(f"  streaming stats: {qengine.stats()}")
        rep = qengine.compact(full=True)
        print(f"  compacted: merged={rep['merged']} -> {rep['new_segment']} "
              f"(dropped {rep['dropped']} tombstoned rows)")
    elif args.shards:
        print(f"  shards={args.shards} "
              f"degraded_queries={server.tick_stats['degraded_queries']}")
    else:
        print(f"  routes={qengine.route_counts}; "
              f"sel_cache={qengine.sel_cache_hits}h/{qengine.sel_cache_misses}m")
    for i in list(results)[:3]:
        print(f"  req {i}: top ids {results[i].ids[:5].tolist()}")


if __name__ == "__main__":
    main()
