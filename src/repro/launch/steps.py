"""Per-(arch x shape) step builders: callable + ShapeDtypeStruct inputs +
NamedShardings for jit lowering. This is the single source of truth used by
the dry-run, the roofline, and the real train/serve entry points.

input_specs() follows the shannon/kernels pattern: weak-type-correct,
shardable ShapeDtypeStructs, zero device allocation. ``[audio]``/``[vlm]``
frontends are stubs — specs carry precomputed frame/patch embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig, ShapeConfig, get_config, supports_shape
from repro.models import params as pr
from repro.models.transformer import LM, cache_meta
from repro.training import AdamWConfig
from repro.training.train_loop import make_train_step


def _divides(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def batch_axes_for(mesh: Mesh, batch: int) -> Tuple[str, ...]:
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    while axes:
        size = int(np.prod([mesh.shape[a] for a in axes]))
        if _divides(batch, size):
            return axes
        axes = axes[1:]
    return ()


def _seq_axis(mesh: Mesh, M: int) -> Optional[str]:
    return "model" if ("model" in mesh.shape and _divides(M, mesh.shape["model"])) \
        else None


def cache_specs(lm: LM, mesh: Mesh, batch_axes, batch: int, max_len: int,
                enc_len: int = 0) -> Any:
    """PartitionSpec tree structurally matching ``lm.decode_cache_meta``:
    batch over (pod, data), cache sequence axis over 'model' (distributed-LSE
    decode), recurrent state heads/channels over 'model'; stacked segments get
    a leading None for the scan dim."""
    from repro.models.transformer import cache_meta_for_desc
    ba = tuple(batch_axes)
    B_axes = ba if ba else None

    def leaf_spec(sds):
        shp = sds.shape
        if len(shp) == 4:       # (B, M, Hkv, Dh) kv / (B, H, Dk, Dv) rwkv state
            return P(B_axes, _seq_axis(mesh, shp[1]), None, None)
        if len(shp) == 3:       # (B, M, r) latent / (B, ck-1, W) conv
            ax = _seq_axis(mesh, shp[1])
            if ax:
                return P(B_axes, ax, None)
            return P(B_axes, None, _seq_axis(mesh, shp[2]))
        if len(shp) == 2:       # (B, W) state / (B, D) shift
            return P(B_axes, _seq_axis(mesh, shp[1]))
        return P(*([None] * len(shp)))

    out = []
    for seg in lm.segments:
        unit_sds = {f"L{j}": cache_meta_for_desc(lm.cfg, d, batch, max_len,
                                                 enc_len)
                    for j, d in enumerate(seg.pattern)}
        unit_spec = jax.tree.map(leaf_spec, unit_sds)
        if seg.repeats > 1:
            unit_spec = jax.tree.map(lambda p: P(None, *p), unit_spec)
        out.append(unit_spec)
    return out


@dataclasses.dataclass
class StepBundle:
    """Everything needed to ``jax.jit(fn, in_shardings=...).lower(*args)``."""
    name: str
    fn: Any
    args: Tuple
    in_shardings: Tuple
    out_shardings: Any = None
    donate: Tuple[int, ...] = ()


class ArchRunner:
    """Builds train/prefill/decode step bundles for one architecture.

    ``segment_repeats`` overrides each segment's scan repeat count — used by
    the roofline's scan-cost correction (XLA costs a scan body once)."""

    def __init__(self, cfg: ModelConfig, mesh: Mesh,
                 segment_repeats: Optional[Tuple[int, ...]] = None):
        self.cfg = cfg
        self.mesh = mesh
        self.lm = LM(cfg)
        if segment_repeats is not None:
            from repro.models.transformer import Segment
            assert len(segment_repeats) == len(self.lm.segments)
            self.lm.segments = [Segment(s.pattern, r) for s, r in
                                zip(self.lm.segments, segment_repeats)]
        self.metas = self.lm.abstract_params()

    def _psharding(self, rules=None):
        return pr.map_tree(
            lambda m: NamedSharding(self.mesh, pr.spec_for(m, self.mesh,
                                                           rules or pr.DEFAULT_RULES)),
            self.metas)

    def _batch_sds(self, shape: ShapeConfig, seq: Optional[int] = None,
                   with_labels: bool = True) -> Dict[str, jax.ShapeDtypeStruct]:
        cfg = self.cfg
        B = shape.global_batch
        S = seq if seq is not None else shape.seq_len
        n_front = cfg.n_frontend_tokens if cfg.frontend == "vision_stub" else 0
        sds = {"tokens": jax.ShapeDtypeStruct((B, S - n_front), jnp.int32)}
        if with_labels:
            sds["labels"] = jax.ShapeDtypeStruct((B, S - n_front), jnp.int32)
        if cfg.frontend == "vision_stub":
            sds["patches"] = jax.ShapeDtypeStruct(
                (B, n_front, cfg.frontend_dim), jnp.bfloat16
                if cfg.activ_dtype == "bfloat16" else jnp.float32)
        if cfg.frontend == "audio_stub":
            sds["frames"] = jax.ShapeDtypeStruct(
                (B, S, cfg.frontend_dim), jnp.bfloat16
                if cfg.activ_dtype == "bfloat16" else jnp.float32)
        return sds

    def _batch_shardings(self, batch_sds, batch_axes):
        ba = tuple(batch_axes) or None
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh,
                                    P(*((ba,) + (None,) * (len(s.shape) - 1)))),
            batch_sds)

    # ---- bundles ----
    def train_bundle(self, shape: ShapeConfig) -> StepBundle:
        mesh = self.mesh
        ba = batch_axes_for(mesh, shape.global_batch)
        psh = self._psharding(pr.DEFAULT_RULES)
        osh = {"m": psh, "v": psh,
               "step": NamedSharding(mesh, P())}
        batch_sds = self._batch_sds(shape)
        bsh = self._batch_shardings(batch_sds, ba)
        params_sds = pr.shape_dtype_tree(self.metas)
        opt_sds = {"m": jax.tree.map(
                       lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                       params_sds),
                   "v": jax.tree.map(
                       lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                       params_sds),
                   "step": jax.ShapeDtypeStruct((), jnp.int32)}
        lm = self.lm

        def loss_fn(params, batch):
            return lm.train_loss(params, batch, mesh=mesh, batch_axes=ba)

        from repro.training.optimizer import adamw_update, clip_by_global_norm
        ocfg = AdamWConfig()

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            grads, gnorm = clip_by_global_norm(grads, ocfg.grad_clip)
            params, opt_state = adamw_update(ocfg, params, grads, opt_state)
            return params, opt_state, {"loss": loss, "grad_norm": gnorm}

        return StepBundle(
            name="train_step", fn=train_step,
            args=(params_sds, opt_sds, batch_sds),
            in_shardings=(psh, osh, bsh),
            out_shardings=(psh, osh, None),
            donate=(0, 1))

    def prefill_bundle(self, shape: ShapeConfig) -> StepBundle:
        mesh = self.mesh
        ba = batch_axes_for(mesh, shape.global_batch)
        psh = self._psharding(pr.SERVE_RULES)
        batch_sds = self._batch_sds(shape, with_labels=False)
        bsh = self._batch_shardings(batch_sds, ba)
        params_sds = pr.shape_dtype_tree(self.metas)
        lm = self.lm

        def prefill(params, batch):
            return lm.prefill(params, batch, mesh=mesh, batch_axes=ba)

        return StepBundle(name="prefill", fn=prefill,
                          args=(params_sds, batch_sds),
                          in_shardings=(psh, bsh))

    def decode_bundle(self, shape: ShapeConfig) -> StepBundle:
        mesh = self.mesh
        cfg = self.cfg
        B = shape.global_batch
        ba = batch_axes_for(mesh, B)
        psh = self._psharding(pr.SERVE_RULES)
        enc_len = shape.seq_len if cfg.n_enc_layers else 0
        n_front = cfg.n_frontend_tokens if cfg.frontend == "vision_stub" else 0
        cache_sds = self.lm.decode_cache_meta(B, shape.seq_len + n_front,
                                              enc_len)
        csp = cache_specs(self.lm, mesh, ba, B, shape.seq_len + n_front, enc_len)
        csh = jax.tree.map(lambda s: NamedSharding(mesh, s), csp)
        tok_sds = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
        tok_sh = NamedSharding(mesh, P(tuple(ba) or None, None))
        pos_sh = NamedSharding(mesh, P())
        lm = self.lm

        def decode(params, caches, tokens, pos):
            return lm.decode_step(params, caches, tokens, pos, mesh=mesh,
                                  batch_axes=ba)

        return StepBundle(name="serve_step", fn=decode,
                          args=(params_sds_serve(self.metas), cache_sds,
                                tok_sds, pos_sds),
                          in_shardings=(psh, csh, tok_sh, pos_sh),
                          donate=(1,))

    def bundle_for(self, shape: ShapeConfig) -> StepBundle:
        return {"train": self.train_bundle, "prefill": self.prefill_bundle,
                "decode": self.decode_bundle}[shape.kind](shape)


def params_sds_serve(metas):
    return pr.shape_dtype_tree(metas)
