"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 200 \
      --preset 100m --batch 8 --seq 512

``--preset 100m`` rescales the arch to ~100M params (the runnable-example
contract); ``--preset smoke`` uses the per-arch smoke config. Runs on
whatever devices exist (CPU here), with the same code path that the dry-run
lowers for the production mesh: FSDP/TP shardings when the mesh has those
axes, checkpoint/resume, straggler watchdog.
"""
from __future__ import annotations

import argparse
import dataclasses
import os

import numpy as np

import jax

from repro.configs import get_config, get_smoke_config
from repro.checkpoint import Checkpointer
from repro.data import TokenLoader
from repro.models.transformer import LM
from repro.training import AdamWConfig, adamw_init, make_train_step
from repro.training.train_loop import TrainLoop, StragglerWatchdog


def preset_100m(cfg):
    """~100M-param variant of the same family."""
    return cfg.scaled(
        n_layers=max(4, min(cfg.n_layers, 8)),
        d_model=512, n_heads=8,
        n_kv_heads=min(8, max(1, cfg.n_kv_heads)),
        head_dim=64, d_ff=2048,
        vocab=min(cfg.vocab, 32768),
        n_experts=min(cfg.n_experts, 16) if cfg.n_experts else 0,
        moe_d_ff=512 if cfg.n_experts else 0,
        lru_width=512 if cfg.lru_width else 0,
        q_lora_rank=128 if cfg.q_lora_rank else 0,
        kv_lora_rank=64 if cfg.kv_lora_rank else 0,
        qk_nope_dim=32 if cfg.qk_nope_dim else 0,
        qk_rope_dim=16 if cfg.qk_rope_dim else 0,
        v_head_dim=32 if cfg.v_head_dim else 0,
        n_enc_layers=min(cfg.n_enc_layers, 4),
        frontend_dim=min(cfg.frontend_dim, 256) if cfg.frontend_dim else 0,
        n_frontend_tokens=min(cfg.n_frontend_tokens, 16),
        q_chunk=128, kv_chunk=128,
        param_dtype="float32", activ_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "100m", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = {"smoke": lambda: get_smoke_config(args.arch),
           "100m": lambda: preset_100m(get_config(args.arch)),
           "full": lambda: get_config(args.arch)}[args.preset]()
    lm = LM(cfg)
    print(f"arch={cfg.name} preset={args.preset} params={lm.param_count()/1e6:.1f}M "
          f"devices={len(jax.devices())}")

    loader = TokenLoader(vocab=cfg.vocab, batch=args.batch, seq_len=args.seq,
                         frontend=cfg.frontend,
                         n_frontend_tokens=cfg.n_frontend_tokens,
                         frontend_dim=cfg.frontend_dim)
    step = make_train_step(lm, opt_cfg=AdamWConfig(lr=args.lr, warmup_steps=20))
    ckpt = Checkpointer(os.path.join(args.ckpt_dir, cfg.name))
    params = lm.init(jax.random.key(0))
    opt = adamw_init(params)
    start = 0
    if args.resume and ckpt.latest_step() is not None:
        state, start, _ = ckpt.restore({"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        print(f"resumed from step {start}")
    loop = TrainLoop(lm, loader, step, checkpointer=ckpt,
                     ckpt_every=args.ckpt_every,
                     watchdog=StragglerWatchdog())
    params, opt, hist = loop.run(params, opt, start, args.steps)
    ckpt.save(start + args.steps, {"params": params, "opt": opt})
    ckpt.wait()
    print(f"final loss {hist[-1]:.4f} (start {hist[0]:.4f}); "
          f"straggler events: {len(loop.watchdog.events)}")


if __name__ == "__main__":
    main()
