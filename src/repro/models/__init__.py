from .transformer import LM, make_segments, layer_descs
from . import params
