"""Attention: chunked (flash-style) training/prefill path, cache decode path,
GQA with qk-norm/bias/sliding-window, and MLA (DeepSeek latent attention).

Memory discipline: the (Sq, Skv) score matrix is never materialized — a double
``lax.scan`` over (q chunks) x (kv chunks) carries online-softmax statistics
(m, l, acc) exactly like FlashAttention; fp32 statistics, bf16-safe inputs.
Decode (Sq == 1) attends over a KV cache whose sequence axis may be sharded
('model'); XLA turns the masked softmax reductions into local reduce +
all-reduce (distributed LSE combine).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .common import apply_rope, rmsnorm
from .params import meta

NEG_INF = -1e30


def _block_mask(q_pos, k_pos, causal: bool, window: Optional[int]):
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None and window > 0:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    q_offset=0, softcap: Optional[float] = None,
                    q_chunk: int = 512, kv_chunk: int = 512,
                    kv_len=None, unroll: bool = False,
                    block_skip: bool = False) -> jnp.ndarray:
    """q: (B, Sq, H, Dk); k: (B, Skv, Hkv, Dk); v: (B, Skv, Hkv, Dv).
    GQA via head grouping (H % Hkv == 0). Returns (B, Sq, H, Dv).

    ``block_skip=True`` (forward-only paths: prefill/serve) runs the inner
    loop over the dynamic block range a causal/windowed q chunk can see —
    a ~2x flop cut for causal, ~S/window for sliding windows (§Perf
    iteration 7). Training keeps the full-range ``lax.scan`` (dynamic-bound
    fori_loop is not reverse-differentiable). ``unroll=True`` replaces the
    loops with Python loops over the same block set so cost_analysis counts
    every block (roofline measurement)."""
    B, Sq, H, Dk = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    Dv = v.shape[-1]
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    # pad both sequence axes to chunk multiples; padded kv is masked via
    # kv_len, padded q rows are sliced off at the end
    Sq_p = -(-Sq // q_chunk) * q_chunk
    Skv_p = -(-Skv // kv_chunk) * kv_chunk
    if Skv_p != Skv:
        kv_len = jnp.minimum(kv_len, Skv) if kv_len is not None else Skv
        k = jnp.pad(k, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    Sq_full, Sq, Skv = Sq, Sq_p, Skv_p
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    scale = 1.0 / jnp.sqrt(Dk).astype(jnp.float32)

    qg = q.reshape(B, Sq, Hkv, G, Dk)

    def kv_bounds(qi):
        """Dynamic kv-block range visible to q chunk ``qi`` (block skipping)."""
        q_lo = q_offset + qi * q_chunk
        q_hi = q_lo + q_chunk - 1
        hi = nk if not causal else jnp.minimum(nk, q_hi // kv_chunk + 1)
        lo = 0
        if window is not None and window > 0:
            lo = jnp.maximum(0, (q_lo - window + 1) // kv_chunk)
        return lo, hi

    def q_block(qi, q_blk):
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        @functools.partial(jax.checkpoint, prevent_cse=False)
        def kv_block(ki, carry):
            m_i, l_i, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk, 1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk, 1)
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            if softcap:
                s = softcap * jnp.tanh(s / softcap)
            mask = _block_mask(q_pos, k_pos, causal, window)
            if kv_len is not None:
                mask = mask & (k_pos[None, :] < kv_len)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_i - m_new)
            l_new = l_i * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return m_new, l_new, acc

        m0 = jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_chunk, Dv), jnp.float32)
        if unroll:  # static bounds, every visible block in the HLO
            q_lo = int(q_offset) + int(qi) * q_chunk
            if block_skip:
                hi_s = (nk if not causal
                        else min(nk, (q_lo + q_chunk - 1) // kv_chunk + 1))
                lo_s = (max(0, (q_lo - window + 1) // kv_chunk)
                        if (window and window > 0) else 0)
            else:
                lo_s, hi_s = 0, nk
            carry = (m0, l0, a0)
            for ki in range(lo_s, hi_s):
                carry = kv_block(jnp.asarray(ki), carry)
            m_f, l_f, acc = carry
        elif block_skip:  # forward-only: dynamic-bound loop skips masked blocks
            lo, hi = kv_bounds(qi)
            m_f, l_f, acc = jax.lax.fori_loop(lo, hi, kv_block, (m0, l0, a0))
        else:  # differentiable full-range scan (training)
            def scan_body(carry, ki):
                return kv_block(ki, carry), None

            (m_f, l_f, acc), _ = jax.lax.scan(scan_body, (m0, l0, a0),
                                              jnp.arange(nk))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return out  # (B, Hkv, G, q_chunk, Dv)

    if unroll:
        blocks = jnp.stack([q_block(qi, qg[:, qi * q_chunk:(qi + 1) * q_chunk])
                            for qi in range(nq)], 0)
    else:
        def outer(_, qi):
            q_blk = jax.lax.dynamic_slice_in_dim(qg, qi * q_chunk, q_chunk, 1)
            return None, q_block(qi, q_blk)

        _, blocks = jax.lax.scan(outer, None, jnp.arange(nq))
    out = jnp.moveaxis(blocks, 0, 3).reshape(B, Hkv, G, Sq, Dv)  # (nq,B,Hkv,G,qc,Dv)
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(B, Sq, H, Dv)
    return out[:, :Sq_full].astype(v.dtype)


def decode_attention(q, k_cache, v_cache, key_valid, *,
                     softcap: Optional[float] = None) -> jnp.ndarray:
    """Single-token attention over a cache. q: (B, 1, H, Dk);
    caches: (B, M, Hkv, D*); ``key_valid``: (M,) bool mask of live entries
    (handles both linear and ring caches). Sequence axis of the cache may be
    sharded; the reductions below become local+all-reduce under SPMD."""
    B, _, H, Dk = q.shape
    M, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    scale = 1.0 / jnp.sqrt(Dk).astype(jnp.float32)
    qg = q.reshape(B, Hkv, G, Dk)
    s = jnp.einsum("bhgd,bmhd->bhgm", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(key_valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhgm,bmhd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, -1).astype(v_cache.dtype)


def cache_slot_and_mask(cur_pos, M: int, window: Optional[int]):
    """Write slot + validity mask for a decode cache of capacity M.

    Linear cache (M >= sequence): slot = cur_pos, valid = pos <= cur_pos
    (+ window). Ring cache (local attention, M == window): slot = cur_pos % M,
    valid = entries whose absolute position is within the window."""
    pos = jnp.arange(M)
    ring = window is not None and window > 0 and M <= window
    if ring:
        slot = cur_pos % M
        abs_pos = cur_pos - ((cur_pos - pos) % M)
        valid = abs_pos >= 0
    else:
        slot = cur_pos
        valid = pos <= cur_pos
        if window is not None and window > 0:
            valid &= pos > cur_pos - window
    return slot, valid


# ---------------- GQA attention block ----------------
def attn_meta(cfg, dtype):
    D, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": meta((D, H, Dh), ("embed", "heads", "head_dim"), dtype),
        "wk": meta((D, Hkv, Dh), ("embed", "kv_heads", "head_dim"), dtype),
        "wv": meta((D, Hkv, Dh), ("embed", "kv_heads", "head_dim"), dtype),
        "wo": meta((H, Dh, D), ("heads", "head_dim", "embed"), dtype),
    }
    if cfg.attn_bias:
        p["bq"] = meta((H, Dh), ("heads", "head_dim"), dtype, init="zeros")
        p["bk"] = meta((Hkv, Dh), ("kv_heads", "head_dim"), dtype, init="zeros")
        p["bv"] = meta((Hkv, Dh), ("kv_heads", "head_dim"), dtype, init="zeros")
    if cfg.qk_norm:
        p["q_norm"] = meta((Dh,), ("head_dim",), dtype, init="ones")
        p["k_norm"] = meta((Dh,), ("head_dim",), dtype, init="ones")
    return p


def _qk_normalize(p, q, k):
    if "q_norm" in p:
        q = rmsnorm({"scale": p["q_norm"]}, q)
        k = rmsnorm({"scale": p["k_norm"]}, k)
    return q, k


def attn_apply(p, x, *, cfg, rope_theta: float, window: Optional[int],
               positions, mode: str, cache=None, cur_pos=None,
               kv_len=None, cross_memory=None, causal: bool = True,
               is_cross: bool = False):
    """mode: 'train' | 'prefill' | 'decode'. Returns (out, new_cache).

    ``is_cross``: cross-attention block — keys/values come from
    ``cross_memory`` (encoder states, train/prefill) or from the cached
    projections (decode); no rope, no causal mask."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]

    if is_cross or cross_memory is not None:
        if mode == "decode":
            k, v = cache  # projected at prefill
            new_cache = cache
        else:
            k = jnp.einsum("bsd,dhk->bshk", cross_memory, p["wk"])
            v = jnp.einsum("bsd,dhk->bshk", cross_memory, p["wv"])
            if "bk" in p:
                k, v = k + p["bk"], v + p["bv"]
            new_cache = (k, v) if mode == "prefill" else None
        if "q_norm" in p:
            q = rmsnorm({"scale": p["q_norm"]}, q)
        if mode == "decode":
            out = decode_attention(q, k, v, jnp.ones((k.shape[1],), bool),
                                   softcap=cfg.attn_logit_softcap)
        else:
            out = flash_attention(q, k, v, causal=False, window=None,
                                  softcap=cfg.attn_logit_softcap,
                                  q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                  kv_len=kv_len, unroll=cfg.flash_unroll,
                                  block_skip=(mode != "train"))
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache

    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    q, k = _qk_normalize(p, q, k)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)

    new_cache = cache
    if mode == "decode":
        k_cache, v_cache = cache
        slot, valid = cache_slot_and_mask(cur_pos, k_cache.shape[1], window)
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), slot, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), slot, 1)
        out = decode_attention(q, k_cache, v_cache, valid,
                               softcap=cfg.attn_logit_softcap)
        new_cache = (k_cache, v_cache)
    else:
        out = flash_attention(q, k, v, causal=causal, window=window,
                              softcap=cfg.attn_logit_softcap,
                              q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                              kv_len=kv_len, unroll=cfg.flash_unroll,
                              block_skip=(mode != "train"))
        if mode == "prefill":
            new_cache = (k, v)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


# ---------------- MLA (DeepSeek-V3) ----------------
def mla_meta(cfg, dtype):
    D, H = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "w_dq": meta((D, qr), ("embed", "q_lora"), dtype),
        "q_norm": meta((qr,), ("q_lora",), dtype, init="ones"),
        "w_uq": meta((qr, H, dn + dr), ("q_lora", "heads", "head_dim"), dtype),
        "w_dkv": meta((D, kvr + dr), ("embed", None), dtype),
        "kv_norm": meta((kvr,), (None,), dtype, init="ones"),
        "w_uk": meta((kvr, H, dn), (None, "heads", "head_dim"), dtype),
        "w_uv": meta((kvr, H, dv), (None, "heads", "head_dim"), dtype),
        "wo": meta((H, dv, D), ("heads", "head_dim", "embed"), dtype),
    }


def mla_apply(p, x, *, cfg, positions, mode: str, cache=None, cur_pos=None):
    B, S, D = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    # queries
    ql = rmsnorm({"scale": p["q_norm"]}, x @ p["w_dq"])
    q = jnp.einsum("bsr,rhk->bshk", ql, p["w_uq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    # latent kv
    dkv = x @ p["w_dkv"]
    latent, k_rope = dkv[..., :kvr], dkv[..., kvr:]
    latent = rmsnorm({"scale": p["kv_norm"]}, latent)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # 1 shared head

    if mode == "decode":
        lat_cache, rope_cache = cache
        lat_cache = jax.lax.dynamic_update_slice_in_dim(
            lat_cache, latent.astype(lat_cache.dtype), cur_pos, 1)
        rope_cache = jax.lax.dynamic_update_slice_in_dim(
            rope_cache, k_rope[:, :, 0, :].astype(rope_cache.dtype), cur_pos, 1)
        # absorbed attention in latent space
        q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])   # (B,1,H,kvr)
        s = (jnp.einsum("bshr,bmr->bhsm", q_abs, lat_cache,
                        preferred_element_type=jnp.float32) +
             jnp.einsum("bshk,bmk->bhsm", q_rope, rope_cache,
                        preferred_element_type=jnp.float32))
        s = s / jnp.sqrt(dn + dr)
        ok = jnp.arange(lat_cache.shape[1])[None, :] <= cur_pos
        s = jnp.where(ok[:, None, None], s, NEG_INF)
        att = jax.nn.softmax(s.astype(jnp.float32), -1)
        ctx = jnp.einsum("bhsm,bmr->bshr", att.astype(lat_cache.dtype), lat_cache,
                         preferred_element_type=jnp.float32)
        out = jnp.einsum("bshr,rhv->bshv", ctx.astype(x.dtype), p["w_uv"])
        new_cache = (lat_cache, rope_cache)
    else:
        k_nope = jnp.einsum("bsr,rhk->bshk", latent, p["w_uk"])
        v = jnp.einsum("bsr,rhv->bshv", latent, p["w_uv"])
        k = jnp.concatenate([k_nope, jnp.broadcast_to(
            k_rope, (B, S, H, dr)).astype(k_nope.dtype)], axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = flash_attention(qq, k, v, causal=True,
                              q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                              unroll=cfg.flash_unroll,
                              block_skip=(mode != "train"))
        new_cache = ((latent, k_rope[:, :, 0, :]) if mode == "prefill" else None)
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    return y, new_cache
