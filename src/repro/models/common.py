"""Shared neural building blocks (raw JAX, no flax)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .params import meta


# ---------------- norms ----------------
def rmsnorm_meta(d, dtype):
    return {"scale": meta((d,), ("embed",), dtype, init="ones")}


def rmsnorm(params, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_np(x, eps: float = 1e-5):
    """OLMo's non-parametric LayerNorm (no scale/bias)."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def make_norm(cfg):
    if cfg.norm_type == "layernorm_np":
        return (lambda d, dt: {}), (lambda p, x: layernorm_np(x))
    return rmsnorm_meta, rmsnorm


# ---------------- rope ----------------
def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta):
    """x: (..., S, H, Dh) with rotary over Dh; positions: (..., S) or (S,)."""
    Dh = x.shape[-1]
    inv = rope_freqs(Dh, theta)                        # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * inv   # (..., S, Dh/2)
    sin = jnp.sin(ang)[..., None, :]
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------- MLP ----------------
def mlp_meta(d_model, d_ff, dtype, bias=False):
    p = {"w_gate": meta((d_model, d_ff), ("embed", "mlp"), dtype),
         "w_up": meta((d_model, d_ff), ("embed", "mlp"), dtype),
         "w_down": meta((d_ff, d_model), ("mlp", "embed"), dtype)}
    if bias:
        p["b_gate"] = meta((d_ff,), ("mlp",), dtype, init="zeros")
        p["b_up"] = meta((d_ff,), ("mlp",), dtype, init="zeros")
        p["b_down"] = meta((d_model,), ("embed",), dtype, init="zeros")
    return p


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": functools.partial(jax.nn.gelu, approximate=True),
            "relu": jax.nn.relu}[name]


def mlp(params, x, act: str = "silu"):
    g = x @ params["w_gate"]
    u = x @ params["w_up"]
    if "b_gate" in params:
        g = g + params["b_gate"]
        u = u + params["b_up"]
    h = act_fn(act)(g) * u
    y = h @ params["w_down"]
    if "b_down" in params:
        y = y + params["b_down"]
    return y


# ---------------- embedding / unembedding ----------------
def embed_meta(vocab, d_model, dtype):
    # N(0, 1/sqrt(d)): O(1) logits under tied unembedding; models with
    # embed_scale (gemma) restore O(1) activations via the sqrt(d) multiplier
    return {"table": meta((vocab, d_model), ("vocab", "embed"), dtype,
                          init="embed", scale=d_model ** -0.5)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed_meta(vocab, d_model, dtype, tied: bool):
    if tied:
        return {}
    return {"w_out": meta((d_model, vocab), ("embed", "vocab"), dtype)}


def logits_fn(head_params, embed_params, x, tied: bool):
    if tied:
        return x @ embed_params["table"].T
    return x @ head_params["w_out"]


def chunked_softmax_xent(logits_fn_, x, labels, mask, chunk: int = 512):
    """Cross entropy over the sequence in chunks to bound the fp32 (B, C, V)
    intermediate on huge vocabularies. ``logits_fn_``: (B, C, D) -> (B, C, V).

    Returns (mean_loss, total_weight)."""
    B, S, D = x.shape
    chunk = min(chunk, S)
    n_chunks = S // chunk
    rem = S - n_chunks * chunk

    def one(xc, yc, mc):
        lg = logits_fn_(xc).astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, yc[..., None].astype(jnp.int32), axis=-1)[..., 0]
        return jnp.sum((lse - gold) * mc), jnp.sum(mc)

    def body(carry, idx):
        tot, cnt = carry
        xc = jax.lax.dynamic_slice_in_dim(x, idx * chunk, chunk, axis=1)
        yc = jax.lax.dynamic_slice_in_dim(labels, idx * chunk, chunk, axis=1)
        mc = jax.lax.dynamic_slice_in_dim(mask, idx * chunk, chunk, axis=1)
        l, c = one(xc, yc, mc)
        return (tot + l, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)),
                                 jnp.arange(n_chunks))
    if rem:
        l, c = one(x[:, -rem:], labels[:, -rem:], mask[:, -rem:])
        tot, cnt = tot + l, cnt + c
    return tot / jnp.maximum(cnt, 1.0), cnt
