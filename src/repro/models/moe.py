"""Mixture-of-Experts block (Qwen3-MoE, DeepSeek-V3 style).

Execution (DESIGN.md §5): expert parallelism over the ``model`` axis with
activations replicated across it — each model shard owns E/|model| experts,
scatters its *local* tokens into an (E_loc, C, D) capacity buffer, runs the
expert MLPs as dense einsums, gathers back, and a single psum over ``model``
combines. Expert weights are additionally FSDP-sharded over ``data`` and
all-gathered per layer inside the shard_map body (the canonical FSDP unshard,
visible to the roofline as all-gather bytes).

Router: softmax (or sigmoid for DeepSeek-style) top-k with optional
normalization and a static aux-free bias (DeepSeek-V3's balancing bias is a
buffer, not updated here), plus an optional load-balance aux loss.

``mesh=None`` (or an absent axis) degrades to single-shard execution with the
same math — used by CPU smoke tests.
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .common import act_fn
from .params import meta


def moe_meta(cfg, dtype):
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    p = {
        "router": meta((D, E), ("embed", None), dtype, scale=0.02),
        "bias": meta((E,), (None,), jnp.float32, init="zeros"),
        "w_gate": meta((E, D, F), ("expert", "embed", "expert_mlp"), dtype),
        "w_up": meta((E, D, F), ("expert", "embed", "expert_mlp"), dtype),
        "w_down": meta((E, F, D), ("expert", "expert_mlp", "embed"), dtype),
    }
    if cfg.n_shared_experts:
        Fs = cfg.moe_d_ff * cfg.n_shared_experts
        p["shared"] = {
            "w_gate": meta((D, Fs), ("embed", "mlp"), dtype),
            "w_up": meta((D, Fs), ("embed", "mlp"), dtype),
            "w_down": meta((Fs, D), ("mlp", "embed"), dtype),
        }
    return p


def _expert_ffn(x, wg, wu, wd, act):
    h = act_fn(act)(jnp.einsum("ecd,edf->ecf", x, wg)) * jnp.einsum(
        "ecd,edf->ecf", x, wu)
    return jnp.einsum("ecf,efd->ecd", h, wd)


def _local_moe(x_loc, router_w, bias, wg, wu, wd, *, cfg, e_lo: int,
               capacity: int, act: str, fsdp_axis: Optional[str],
               model_axis: Optional[str]):
    """Body shared by the shard_map and single-device paths.
    x_loc: (T_loc, D); wg/wu/wd: this model-shard's experts, possibly
    FSDP-sharded on dim 1/2 (all-gathered here)."""
    if fsdp_axis is not None:
        wg = jax.lax.all_gather(wg, fsdp_axis, axis=1, tiled=True)
        wu = jax.lax.all_gather(wu, fsdp_axis, axis=1, tiled=True)
        wd = jax.lax.all_gather(wd, fsdp_axis, axis=2, tiled=True)
    E_loc = wg.shape[0]
    T, D = x_loc.shape
    k = cfg.top_k

    logits = (x_loc @ router_w).astype(jnp.float32)            # (T, E)
    if cfg.router_fn == "sigmoid":
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(scores + bias[None, :], k)     # (T, k)
    gates = jnp.take_along_axis(scores, eidx, axis=1)          # bias only routes
    if cfg.router_norm_topk:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    flat_e = eidx.reshape(-1)                                   # (T*k,)
    loc_e = flat_e - e_lo
    mine = (loc_e >= 0) & (loc_e < E_loc)
    loc_e_safe = jnp.where(mine, loc_e, 0)
    onehot = (jax.nn.one_hot(loc_e_safe, E_loc, dtype=jnp.int32) *
              mine[:, None].astype(jnp.int32))                  # (T*k, E_loc)
    pos = jnp.cumsum(onehot, axis=0) - onehot                   # exclusive
    pos_e = jnp.sum(pos * onehot, axis=1)                       # (T*k,)
    keep = mine & (pos_e < capacity)
    tok = jnp.repeat(jnp.arange(T), k)

    buf = jnp.zeros((E_loc, capacity, D), x_loc.dtype)
    buf = buf.at[jnp.where(keep, loc_e_safe, 0),
                 jnp.where(keep, pos_e, 0)].add(
        jnp.where(keep[:, None], x_loc[tok], 0))
    out_buf = _expert_ffn(buf, wg, wu, wd, act)                 # (E_loc, C, D)
    vals = out_buf[loc_e_safe, jnp.where(keep, pos_e, 0)]       # (T*k, D)
    vals = jnp.where(keep[:, None], vals, 0) * gates.reshape(-1)[:, None]
    out = jnp.zeros_like(x_loc).at[tok].add(vals)
    if model_axis is not None:
        out = jax.lax.psum(out, model_axis)

    # load-balance aux (switch-style), computed on the replicated router state
    me = jnp.mean(jax.nn.softmax(logits, -1), axis=0)
    ce = jnp.mean(jax.nn.one_hot(eidx[:, 0], cfg.n_experts, dtype=jnp.float32), axis=0)
    aux = cfg.n_experts * jnp.sum(me * ce)
    return out, aux


def moe_apply(p, x, *, cfg, mesh: Optional[Mesh], batch_axes,
              capacity_factor: float = 1.25, mode: str = "train"):
    """x: (B, S, D) -> (B, S, D). Chooses sharded or local execution.

    Serving (mode != 'train', few tokens): experts shard over the FULL mesh
    when divisible — tokens are tiny at decode, expert weights dominate HBM,
    so maximal EP is the right trade (EXPERIMENTS.md §Perf iteration 2)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    act = cfg.act

    if mesh is not None and mode != "train" and B * S <= 16384:
        ep_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
        while ep_axes and E % int(np.prod([mesh.shape[a] for a in ep_axes])) != 0:
            ep_axes = ep_axes[1:]
        if len(ep_axes) > 1:
            return _moe_full_ep(p, x, cfg=cfg, mesh=mesh, ep_axes=ep_axes,
                                capacity_factor=capacity_factor)

    model_ok = mesh is not None and "model" in mesh.shape and \
        mesh.shape["model"] > 1 and E % mesh.shape["model"] == 0
    data_axes = tuple(a for a in (batch_axes or ()) if mesh is not None
                      and a in mesh.shape)
    dp = int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes else 1
    T_loc = (B // dp) * S
    capacity = int(np.ceil(T_loc * k / E * capacity_factor))
    capacity = max(capacity, 4)

    if not model_ok:
        def run_local(xf):
            return _local_moe(xf, p["router"], p["bias"], p["w_gate"],
                              p["w_up"], p["w_down"], cfg=cfg, e_lo=0,
                              capacity=capacity, act=act, fsdp_axis=None,
                              model_axis=None)
        out, aux = run_local(x.reshape(B * S, D))
        y = out.reshape(B, S, D)
    else:
        mp = mesh.shape["model"]
        E_loc = E // mp
        # expert weights are FSDP-sharded over 'data' on their D dim when the
        # param specs could shard them (divisibility); gathered per layer.
        fsdp_axis = ("data" if ("data" in mesh.shape and mesh.shape["data"] > 1
                                and D % mesh.shape["data"] == 0) else None)

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(batch_axes, None, None), P(None, None), P(None),
                      P("model", "data" if fsdp_axis else None, None),
                      P("model", "data" if fsdp_axis else None, None),
                      P("model", None, "data" if fsdp_axis else None)),
            out_specs=(P(batch_axes, None, None), P()),
            check_rep=False)
        def run(x_blk, router_w, bias, wg, wu, wd):
            Bl, Sl, Dl = x_blk.shape
            e_lo = jax.lax.axis_index("model") * E_loc
            out, aux = _local_moe(x_blk.reshape(Bl * Sl, Dl), router_w, bias,
                                  wg, wu, wd, cfg=cfg, e_lo=e_lo,
                                  capacity=capacity, act=act,
                                  fsdp_axis=fsdp_axis, model_axis="model")
            axes = data_axes + ("model",)
            return out.reshape(Bl, Sl, Dl), jax.lax.pmean(aux, axes)

        y, aux = run(x, p["router"], p["bias"], p["w_gate"], p["w_up"],
                     p["w_down"])

    if cfg.n_shared_experts:
        from .common import mlp
        y = y + mlp(p["shared"], x, act)
    return y, aux


def _moe_full_ep(p, x, *, cfg, mesh, ep_axes, capacity_factor):
    """Serving-time full-mesh expert parallelism: tokens replicated (tiny),
    each device runs its E/devices experts, one psum over all EP axes."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    ep = int(np.prod([mesh.shape[a] for a in ep_axes]))
    E_loc = E // ep
    T = B * S
    capacity = max(int(np.ceil(T * k / E * capacity_factor)), 4)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(None, None, None), P(None, None), P(None),
                  P(ep_axes, None, None), P(ep_axes, None, None),
                  P(ep_axes, None, None)),
        out_specs=(P(None, None, None), P()),
        check_rep=False)
    def run(x_rep, router_w, bias, wg, wu, wd):
        e_lo = jnp.zeros((), jnp.int32)
        stride = E_loc
        for a in reversed(ep_axes):
            e_lo = e_lo + jax.lax.axis_index(a) * stride
            stride = stride * mesh.shape[a]
        out, aux = _local_moe(x_rep.reshape(T, D), router_w, bias, wg, wu, wd,
                              cfg=cfg, e_lo=e_lo, capacity=capacity,
                              act=cfg.act, fsdp_axis=None, model_axis=ep_axes)
        return out.reshape(B, S, D), jax.lax.pmean(aux, ep_axes)

    y, aux = run(x, p["router"], p["bias"], p["w_gate"], p["w_up"], p["w_down"])
    if cfg.n_shared_experts:
        from .common import mlp
        y = y + mlp(p["shared"], x, cfg.act)
    return y, aux
