"""Parameter metadata trees: shapes + logical axes, materialized lazily.

Models declare ``ParamMeta`` trees (shape, dtype, logical axis names). From a
meta tree we derive, without ever allocating:

* ``shape_dtype_tree``  — ShapeDtypeStructs for dry-run lowering,
* ``spec_tree``         — PartitionSpecs via the logical->mesh rules (with
                          divisibility fallback to replication),
* ``init_tree``         — real arrays (smoke tests / examples / training).

Logical axes: embed, vocab, heads, kv_heads, head_dim, mlp, expert, layers,
q_lora, kv_lora, conv, stack (scan units). The default rule set implements
FSDP ("embed" over data) x TP ("vocab"/"heads"/"mlp"/"expert" over model).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Tree = Any


@dataclasses.dataclass(frozen=True)
class ParamMeta:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.float32
    init: str = "normal"   # normal | zeros | ones | embed
    scale: Optional[float] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def meta(shape, axes, dtype=jnp.float32, init="normal", scale=None) -> ParamMeta:
    return ParamMeta(tuple(int(s) for s in shape), tuple(axes), dtype, init, scale)


def is_meta(x) -> bool:
    return isinstance(x, ParamMeta)


def map_tree(fn: Callable[[ParamMeta], Any], metas: Tree) -> Tree:
    return jax.tree.map(fn, metas, is_leaf=is_meta)


def shape_dtype_tree(metas: Tree) -> Tree:
    return map_tree(lambda m: jax.ShapeDtypeStruct(m.shape, m.dtype), metas)


# Default logical-axis -> mesh-axis rules (training posture: FSDP x TP).
DEFAULT_RULES: Dict[str, Sequence[str]] = {
    "embed": ("data",),          # FSDP shard over the data axis
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "expert": ("model",),
    "expert_mlp": None,
    "head_dim": None,
    "q_lora": None,
    "kv_lora": ("model",),
    "layers": None,
    "stack": None,
    "conv": None,
}

# Inference posture: no FSDP (weights stationary), TP only — except experts,
# which shard over the FULL mesh (pod x data x model): a 671B MoE cannot fit
# 16-way (85 GB/device); 512-way EP brings it to ~2.7 GB/device. See
# EXPERIMENTS.md §Perf iteration 2.
SERVE_RULES = dict(DEFAULT_RULES, embed=None,
                   expert=("pod", "data", "model"))


def spec_for(m: ParamMeta, mesh: Mesh, rules: Dict[str, Sequence[str]]) -> P:
    parts = []
    used = set()
    for dim, ax in zip(m.shape, m.axes):
        r = rules.get(ax) if ax else None
        if r is None:
            parts.append(None)
            continue
        r = (r,) if isinstance(r, str) else tuple(r)
        r = tuple(a for a in r if a in mesh.shape and a not in used)
        # drop leading axes until the product divides the dim (e.g. experts
        # over ('data','model') degrade to ('model',) when E < devices)
        while r and (dim % int(np.prod([mesh.shape[a] for a in r])) != 0
                     or int(np.prod([mesh.shape[a] for a in r])) <= 1):
            r = r[1:]
        if not r:
            parts.append(None)
            continue
        used.update(r)
        parts.append(r[0] if len(r) == 1 else r)
    return P(*parts)


def spec_tree(metas: Tree, mesh: Mesh, rules: Optional[Dict] = None) -> Tree:
    rules = rules or DEFAULT_RULES
    return map_tree(lambda m: spec_for(m, mesh, rules), metas)


def sharding_tree(metas: Tree, mesh: Mesh, rules: Optional[Dict] = None) -> Tree:
    rules = rules or DEFAULT_RULES
    return map_tree(lambda m: NamedSharding(mesh, spec_for(m, mesh, rules)), metas)


def init_tree(metas: Tree, key: jax.Array) -> Tree:
    """Materialize parameters. Deterministic per-leaf keys via path folding."""
    leaves, treedef = jax.tree.flatten(metas, is_leaf=is_meta)
    out = []
    for i, m in enumerate(leaves):
        k = jax.random.fold_in(key, i)
        if m.init == "zeros":
            arr = jnp.zeros(m.shape, m.dtype)
        elif m.init == "ones":
            arr = jnp.ones(m.shape, m.dtype)
        else:
            fan_in = m.shape[-2] if len(m.shape) >= 2 else m.shape[-1]
            scale = m.scale if m.scale is not None else (1.0 / np.sqrt(fan_in))
            if m.init == "embed":
                scale = m.scale if m.scale is not None else 1.0
            arr = (scale * jax.random.normal(k, m.shape, jnp.float32)).astype(m.dtype)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def count_params(metas: Tree) -> int:
    leaves = jax.tree.leaves(metas, is_leaf=is_meta)
    return int(sum(np.prod(m.shape) for m in leaves))


def tree_bytes(metas: Tree) -> int:
    leaves = jax.tree.leaves(metas, is_leaf=is_meta)
    return int(sum(np.prod(m.shape) * jnp.dtype(m.dtype).itemsize for m in leaves))
