"""Recurrent sequence mixers: RG-LRU (Griffin/RecurrentGemma) and RWKV-6.

Both are written in *chunk/scan-parallel* forms so training lowers to matmuls
and associative scans (MXU/VPU friendly), while decode is an O(1) state
update — this is what makes the ``long_500k`` cells runnable for these
families (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .common import act_fn, rmsnorm
from .params import meta

# ---------------- RG-LRU recurrent block (Griffin) ----------------
_LRU_C = 8.0


def rglru_meta(cfg, dtype):
    D, W = cfg.d_model, cfg.lru_width
    ck = cfg.conv_width
    return {
        "w_x": meta((D, W), ("embed", "mlp"), dtype),
        "w_gate_branch": meta((D, W), ("embed", "mlp"), dtype),
        "conv": meta((ck, W), ("conv", "mlp"), dtype, scale=0.1),
        "conv_b": meta((W,), ("mlp",), dtype, init="zeros"),
        "lru_in_gate": meta((W,), ("mlp",), dtype, init="ones"),
        "lru_in_gate_b": meta((W,), ("mlp",), dtype, init="zeros"),
        "lru_rec_gate": meta((W,), ("mlp",), dtype, init="ones"),
        "lru_rec_gate_b": meta((W,), ("mlp",), dtype, init="zeros"),
        "lru_a": meta((W,), ("mlp",), jnp.float32, init="ones", scale=1.0),
        "w_out": meta((W, D), ("mlp", "embed"), dtype),
    }


def _causal_conv(x, w, b, state):
    """Depthwise causal conv. x: (B, S, W); w: (ck, W); state: (B, ck-1, W)."""
    ck = w.shape[0]
    xx = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xx[:, i:i + x.shape[1]] * w[i] for i in range(ck)) + b
    new_state = xx[:, -(ck - 1):] if ck > 1 else state
    return out, new_state


def _rglru_scan(x, r_gate, i_gate, a_param, h0):
    """h_t = a_t * h_{t-1} + sqrt(1-a_t^2) * (i_t * x_t), parallel scan.
    x/r_gate/i_gate: (B, S, W); h0: (B, W)."""
    log_a = -_LRU_C * jax.nn.softplus(a_param) * r_gate.astype(jnp.float32)
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * (i_gate * x).astype(jnp.float32)
    # prepend carry as a pseudo-step: h0 enters with a=1
    a_all = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
    b_all = jnp.concatenate([h0[:, None].astype(jnp.float32), b], axis=1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    aa, hh = jax.lax.associative_scan(combine, (a_all, b_all), axis=1)
    return hh[:, 1:].astype(x.dtype), hh[:, -1]


def rglru_apply(p, x, *, cfg, mode: str, cache=None):
    """Griffin recurrent block. cache: (conv_state (B, ck-1, W), h (B, W))."""
    B, S, D = x.shape
    W = cfg.lru_width
    ck = cfg.conv_width
    if cache is None:
        cache = (jnp.zeros((B, ck - 1, W), x.dtype),
                 jnp.zeros((B, W), jnp.float32))
    conv_state, h0 = cache
    gate = act_fn("gelu")(x @ p["w_gate_branch"])
    u = x @ p["w_x"]
    u, conv_state = _causal_conv(u, p["conv"], p["conv_b"], conv_state)
    r = jax.nn.sigmoid(u * p["lru_rec_gate"] + p["lru_rec_gate_b"]).astype(jnp.float32)
    i = jax.nn.sigmoid(u * p["lru_in_gate"] + p["lru_in_gate_b"]).astype(jnp.float32)
    if mode == "decode":
        log_a = -_LRU_C * jax.nn.softplus(p["lru_a"]) * r[:, 0]
        a = jnp.exp(log_a)
        mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
        h = a * h0 + mult * (i[:, 0] * u[:, 0].astype(jnp.float32))
        y = h[:, None].astype(x.dtype)
        new_cache = (conv_state, h)
    else:
        y, h = _rglru_scan(u, r, i, p["lru_a"], h0)
        new_cache = (conv_state, h)
    out = (y * gate) @ p["w_out"]
    return out, new_cache


# ---------------- RWKV-6 (Finch) ----------------
def rwkv6_meta(cfg, dtype):
    D = cfg.d_model
    H = cfg.n_heads
    Dh = D // H
    lora = cfg.rwkv_lora
    return {
        "mu": meta((5, D), (None, "embed"), dtype, scale=0.5),       # w,k,v,r,g
        "mu_x": meta((D,), ("embed",), dtype, scale=0.5),
        "ddl_a": meta((D, 5 * lora), ("embed", None), dtype, scale=0.02),
        "ddl_b": meta((5, lora, D), (None, None, "embed"), dtype, scale=0.02),
        "w0": meta((D,), ("embed",), jnp.float32, init="zeros"),
        "w_lora_a": meta((D, lora), ("embed", None), dtype, scale=0.02),
        "w_lora_b": meta((lora, D), (None, "embed"), dtype, scale=0.02),
        "bonus": meta((H, Dh), ("heads", "head_dim"), jnp.float32, init="zeros"),
        "w_r": meta((D, D), ("embed", "mlp"), dtype),
        "w_k": meta((D, D), ("embed", "mlp"), dtype),
        "w_v": meta((D, D), ("embed", "mlp"), dtype),
        "w_g": meta((D, D), ("embed", "mlp"), dtype),
        "ln_scale": meta((H, Dh), ("heads", "head_dim"), dtype, init="ones"),
        "w_o": meta((D, D), ("mlp", "embed"), dtype),
    }


def _rwkv_mix(p, x, shifted):
    """RWKV-6 data-dependent token-shift (ddlerp) producing the five mixed
    streams (w, k, v, r, g). x/shifted: (B, S, D)."""
    dx = shifted - x
    base = x + dx * p["mu_x"]
    low = jnp.tanh(base @ p["ddl_a"])                      # (B, S, 5*lora)
    low = low.reshape(*low.shape[:-1], 5, -1)              # (B, S, 5, lora)
    mix = p["mu"] + jnp.einsum("bsfl,fld->bsfd", low, p["ddl_b"])
    return x[..., None, :] + dx[..., None, :] * mix        # (B, S, 5, D)


def _rwkv_chunk_scan(r, k, v, lw, u, S0, chunk: int):
    """Chunkwise-parallel WKV6. r/k/v: (B, H, S, Dh); lw: log-decay (B, H, S,
    Dh) (<=0); u: (H, Dh) bonus; S0: (B, H, Dh, Dh) initial state.
    Returns out (B, H, S, Dh), S_final."""
    B, H, S, Dh = r.shape
    C = min(chunk, S)
    assert S % C == 0
    n = S // C
    rc = r.reshape(B, H, n, C, Dh)
    kc = k.reshape(B, H, n, C, Dh)
    vc = v.reshape(B, H, n, C, Dh)
    lwc = lw.reshape(B, H, n, C, Dh)

    tri = jnp.tril(jnp.ones((C, C), bool), k=-1)           # strict lower

    def body(S_prev, inp):
        rb, kb, vb, lwb = inp                               # (B, H, C, Dh)
        c_incl = jnp.cumsum(lwb, axis=2)                    # inclusive
        c_prev = c_incl - lwb                               # exclusive
        r_tld = (rb * jnp.exp(c_prev)).astype(jnp.float32)
        k_tld = (kb * jnp.exp(-c_incl)).astype(jnp.float32)
        # intra-chunk: A[t,j] = sum_d r~[t,d] k~[j,d]  (j < t)
        A = jnp.einsum("bhtd,bhjd->bhtj", r_tld, k_tld)
        A = jnp.where(tri[None, None], A, 0.0)
        intra = jnp.einsum("bhtj,bhjd->bhtd", A, vb.astype(jnp.float32))
        # diagonal bonus term
        diag = jnp.einsum("bhtd,bhtd->bht", rb.astype(jnp.float32),
                          u[None, :, None, :] * kb.astype(jnp.float32))
        intra = intra + diag[..., None] * vb.astype(jnp.float32)
        # inter-chunk from carried state
        inter = jnp.einsum("bhtd,bhdv->bhtv", r_tld, S_prev)
        # state update
        tot = c_incl[:, :, -1:, :]                          # (B, H, 1, Dh)
        k_dec = (kb * jnp.exp(tot - c_incl)).astype(jnp.float32)
        S_new = S_prev * jnp.exp(tot[:, :, 0, :])[..., None] + jnp.einsum(
            "bhtd,bhtv->bhdv", k_dec, vb.astype(jnp.float32))
        return S_new, intra + inter

    inp = (jnp.moveaxis(rc, 2, 0), jnp.moveaxis(kc, 2, 0),
           jnp.moveaxis(vc, 2, 0), jnp.moveaxis(lwc, 2, 0))
    S_f, outs = jax.lax.scan(body, S0.astype(jnp.float32), inp)
    out = jnp.moveaxis(outs, 0, 2).reshape(B, H, S, Dh)
    return out, S_f


def rwkv6_apply(p, x, *, cfg, mode: str, cache=None, chunk: int = 64):
    """RWKV-6 time-mix block. cache: (shift (B, D), state (B, H, Dh, Dh))."""
    B, S, D = x.shape
    H = cfg.n_heads
    Dh = D // H
    if cache is None:
        cache = (jnp.zeros((B, D), x.dtype),
                 jnp.zeros((B, H, Dh, Dh), jnp.float32))
    shift_in, S0 = cache
    shifted = jnp.concatenate([shift_in[:, None], x[:, :-1]], axis=1)
    mixed = _rwkv_mix(p, x, shifted)                        # (B, S, 5, D)
    xw, xk, xv, xr, xg = [mixed[:, :, i] for i in range(5)]
    lw = p["w0"] + jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
    lw = -jnp.exp(jnp.clip(lw.astype(jnp.float32), -8.0, 4.0))  # log-decay <= 0
    lw = jnp.clip(lw, -8.0, -1e-4)

    def heads(t):
        return t.reshape(B, S, H, Dh).transpose(0, 2, 1, 3)

    r = heads(xr @ p["w_r"])
    k = heads(xk @ p["w_k"])
    v = heads(xv @ p["w_v"])
    g = jax.nn.silu(xg @ p["w_g"])
    lwh = heads(lw)

    if mode == "decode":
        # single-step recurrence
        rb, kb, vb = r[:, :, 0], k[:, :, 0], v[:, :, 0]     # (B, H, Dh)
        u = p["bonus"]
        wkv = S0 + u[None, :, :, None] * jnp.einsum("bhd,bhv->bhdv",
                                                    kb.astype(jnp.float32),
                                                    vb.astype(jnp.float32))
        out = jnp.einsum("bhd,bhdv->bhv", rb.astype(jnp.float32), wkv)
        S_new = S0 * jnp.exp(lwh[:, :, 0])[..., None] + jnp.einsum(
            "bhd,bhv->bhdv", kb.astype(jnp.float32), vb.astype(jnp.float32))
        out = out[:, :, None]                               # (B, H, 1, Dh)
    else:
        out, S_new = _rwkv_chunk_scan(r, k, v, lwh, p["bonus"], S0, chunk)

    out = rmsnorm({"scale": p["ln_scale"]},
                  out.transpose(0, 2, 1, 3)).reshape(B, S, D)
    y = ((out.astype(x.dtype) * g) @ p["w_o"]).astype(x.dtype)
    new_cache = (x[:, -1], S_new)
    return y, new_cache
