"""Model assembly: decoder LMs, enc-dec, MoE/MLA/recurrent mixers, frontends.

Layers are grouped into *segments* — (pattern, repeats) pairs where a pattern
is a short tuple of layer descriptors and the segment lowers to one
``lax.scan`` over the stacked pattern parameters (HLO size is O(|pattern|),
not O(n_layers); deepseek-v3's 61 layers compile as 2 scanned bodies). Every
mode (train / prefill / decode) walks the same segment structure; caches are
pytrees stacked along the scan dimension.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import attention as attn
from . import moe as moe_mod
from . import recurrent as rec
from .common import (chunked_softmax_xent, embed, embed_meta, logits_fn, make_norm,
                     mlp, mlp_meta, unembed_meta)
from .params import ParamMeta, init_tree, is_meta, meta, shape_dtype_tree


# ---------------- layer descriptors & segments ----------------
@dataclasses.dataclass(frozen=True)
class LayerDesc:
    mixer: str     # attn | attn_local | rg | rwkv | mla
    mlp: str       # dense | moe
    cross: bool = False


@dataclasses.dataclass(frozen=True)
class Segment:
    pattern: Tuple[LayerDesc, ...]
    repeats: int


def layer_descs(cfg: ModelConfig, cross: bool = False) -> List[LayerDesc]:
    kinds = cfg.layer_kinds()
    descs = []
    for i, k in enumerate(kinds):
        if cfg.use_mla and k == "attn":
            k = "mla"
        mlp_kind = "moe" if (cfg.n_experts and i >= cfg.first_dense_layers) else "dense"
        descs.append(LayerDesc(k, mlp_kind, cross))
    return descs


def make_segments(descs: Sequence[LayerDesc]) -> List[Segment]:
    """Greedy periodic segmentation: find the shortest repeating unit of the
    remaining prefix, take as many whole repeats as possible."""
    segs: List[Segment] = []
    i = 0
    n = len(descs)
    while i < n:
        best = (1, 1)  # fall back to a single unrolled layer
        for plen in range(1, min(8, (n - i) // 2) + 1):
            pat = descs[i:i + plen]
            reps = 1
            while descs[i + reps * plen: i + (reps + 1) * plen] == pat:
                reps += 1
            # only repeating units are worth a scan; unrolled singletons
            # otherwise (keeps heterogeneous prefixes like deepseek's 3 dense
            # layers out of wide unrolled patterns)
            if reps >= 2 and reps * plen > best[0] * best[1]:
                best = (plen, reps)
        plen, reps = best
        segs.append(Segment(tuple(descs[i:i + plen]), reps))
        i += plen * reps
    return segs


# ---------------- per-layer params ----------------
def _mixer_meta(cfg: ModelConfig, kind: str, dtype):
    if kind in ("attn", "attn_local"):
        return attn.attn_meta(cfg, dtype)
    if kind == "mla":
        return attn.mla_meta(cfg, dtype)
    if kind == "rg":
        return rec.rglru_meta(cfg, dtype)
    if kind == "rwkv":
        return rec.rwkv6_meta(cfg, dtype)
    raise ValueError(kind)


def layer_meta(cfg: ModelConfig, desc: LayerDesc):
    norm_meta_fn, _ = make_norm(cfg)
    dtype = cfg.pdtype
    p = {
        "norm1": norm_meta_fn(cfg.d_model, dtype),
        "mixer": _mixer_meta(cfg, desc.mixer, dtype),
        "norm2": norm_meta_fn(cfg.d_model, dtype),
        "mlp": (moe_mod.moe_meta(cfg, dtype) if desc.mlp == "moe"
                else mlp_meta(cfg.d_model, cfg.d_ff, dtype, bias=False)),
    }
    if desc.cross:
        p["norm_cross"] = norm_meta_fn(cfg.d_model, dtype)
        p["cross"] = attn.attn_meta(cfg, dtype)
    return p


def _stack_meta(tree, n: int):
    return jax.tree.map(
        lambda m: ParamMeta((n,) + m.shape, ("stack",) + m.axes, m.dtype,
                            m.init, m.scale),
        tree, is_leaf=is_meta)


def segment_meta(cfg: ModelConfig, seg: Segment):
    pat = {f"L{j}": layer_meta(cfg, d) for j, d in enumerate(seg.pattern)}
    return _stack_meta(pat, seg.repeats) if seg.repeats > 1 else pat


# ---------------- layer forward ----------------
def _theta_window(cfg: ModelConfig, desc: LayerDesc):
    if desc.mixer == "attn_local":
        return cfg.rope_theta, cfg.window
    theta = cfg.rope_theta_global or cfg.rope_theta
    return theta, None


def layer_apply(lp, x, desc: LayerDesc, *, cfg: ModelConfig, mode: str,
                cache, positions, cur_pos, mesh, batch_axes,
                cross_memory=None, kv_len=None):
    _, norm = make_norm(cfg)
    aux = jnp.zeros((), jnp.float32)
    if desc.cross and isinstance(cache, dict):
        mixer_cache, cross_cache = cache["self"], cache["cross"]
    else:
        mixer_cache, cross_cache = cache, None
    h = norm(lp["norm1"], x)
    if desc.mixer in ("attn", "attn_local"):
        theta, window = _theta_window(cfg, desc)
        h, new_cache = attn.attn_apply(
            lp["mixer"], h, cfg=cfg, rope_theta=theta, window=window,
            positions=positions, mode=mode, cache=mixer_cache, cur_pos=cur_pos,
            kv_len=kv_len, causal=cfg.causal)
    elif desc.mixer == "mla":
        h, new_cache = attn.mla_apply(lp["mixer"], h, cfg=cfg,
                                      positions=positions, mode=mode,
                                      cache=mixer_cache, cur_pos=cur_pos)
    elif desc.mixer == "rg":
        h, new_cache = rec.rglru_apply(lp["mixer"], h, cfg=cfg, mode=mode,
                                       cache=mixer_cache)
    elif desc.mixer == "rwkv":
        h, new_cache = rec.rwkv6_apply(lp["mixer"], h, cfg=cfg, mode=mode,
                                       cache=mixer_cache, chunk=cfg.rwkv_chunk)
    else:
        raise ValueError(desc.mixer)
    x = x + h

    if desc.cross:
        h = norm(lp["norm_cross"], x)
        h, new_cross = attn.attn_apply(
            lp["cross"], h, cfg=cfg, rope_theta=cfg.rope_theta, window=None,
            positions=positions, mode=mode, cache=cross_cache,
            cur_pos=cur_pos, cross_memory=cross_memory, is_cross=True)
        x = x + h
        new_cache = {"self": new_cache, "cross": new_cross}

    h = norm(lp["norm2"], x)
    if desc.mlp == "moe":
        h, aux = moe_mod.moe_apply(lp["mlp"], h, cfg=cfg, mesh=mesh,
                                   batch_axes=batch_axes,
                                   capacity_factor=cfg.capacity_factor,
                                   mode=mode)
    else:
        h = mlp(lp["mlp"], h, cfg.act)
    x = x + h
    return x, new_cache, aux


# ---------------- cache construction ----------------
def cache_meta_for_desc(cfg: ModelConfig, desc: LayerDesc, batch: int,
                        max_len: int, enc_len: int = 0):
    """ShapeDtypeStruct tree for one layer's decode cache."""
    ad = cfg.adtype
    D = cfg.d_model

    def sds(shape, dtype):
        return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)

    if desc.mixer in ("attn", "attn_local"):
        _, window = _theta_window(cfg, desc)
        M = min(max_len, window) if window else max_len
        kv = sds((batch, M, cfg.n_kv_heads, cfg.head_dim), ad)
        base = (kv, kv)
    elif desc.mixer == "mla":
        base = (sds((batch, max_len, cfg.kv_lora_rank), ad),
                sds((batch, max_len, cfg.qk_rope_dim), ad))
    elif desc.mixer == "rg":
        base = (sds((batch, cfg.conv_width - 1, cfg.lru_width), ad),
                sds((batch, cfg.lru_width), jnp.float32))
    elif desc.mixer == "rwkv":
        Dh = D // cfg.n_heads
        base = (sds((batch, D), ad),
                sds((batch, cfg.n_heads, Dh, Dh), jnp.float32))
    else:
        raise ValueError(desc.mixer)
    if desc.cross:
        ckv = sds((batch, enc_len, cfg.n_kv_heads, cfg.head_dim), ad)
        return {"self": base, "cross": (ckv, ckv)}
    return base


def cache_meta(cfg: ModelConfig, segments: Sequence[Segment], batch: int,
               max_len: int, enc_len: int = 0):
    out = []
    for seg in segments:
        unit = {f"L{j}": cache_meta_for_desc(cfg, d, batch, max_len, enc_len)
                for j, d in enumerate(seg.pattern)}
        if seg.repeats > 1:
            unit = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((seg.repeats,) + s.shape, s.dtype),
                unit)
        out.append(unit)
    return out


def zeros_like_meta(tree):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), tree)


# ---------------- segment walk ----------------
def segment_apply(seg_p, x, seg: Segment, *, cfg: ModelConfig, mode: str,
                  caches, positions, cur_pos, mesh, batch_axes,
                  cross_memory=None, kv_len=None, unshard=None):
    """Run one segment. caches: stacked cache pytree or None (train).

    ``unshard``: optional NamedSharding tree (one unit, unstacked) applied to
    the layer's parameters before use — the explicit FSDP unshard. Without it
    XLA may resolve the weight-over-data x batch-over-data conflict by
    all-reducing activations (orders of magnitude more collective bytes, see
    EXPERIMENTS.md §Perf iteration 1); constraining the per-layer weight slice
    to its data-replicated spec forces the per-layer weight all-gather
    (forward) / gradient reduce-scatter (backward) instead. MoE expert weights
    keep their FSDP spec — the MoE block gathers them itself."""

    def unit(lp, xx, cache_unit):
        if unshard is not None:
            lp = jax.tree.map(jax.lax.with_sharding_constraint, lp, unshard)
        aux = jnp.zeros((), jnp.float32)
        new_c = {}
        for j, d in enumerate(seg.pattern):
            c = cache_unit[f"L{j}"] if cache_unit is not None else None
            xx, nc, a = layer_apply(lp[f"L{j}"], xx, d, cfg=cfg, mode=mode,
                                    cache=c, positions=positions,
                                    cur_pos=cur_pos, mesh=mesh,
                                    batch_axes=batch_axes,
                                    cross_memory=cross_memory, kv_len=kv_len)
            new_c[f"L{j}"] = nc
            aux = aux + a
        return xx, new_c, aux

    if seg.repeats == 1:
        return unit(seg_p, x, caches)

    if not cfg.scan_layers:
        # unrolled walk over the stacked params (used by the roofline's
        # scan-count correction; lax.scan bodies are costed once by XLA)
        aux = jnp.zeros((), jnp.float32)
        ncs = []
        for r in range(seg.repeats):
            lp = jax.tree.map(lambda t: t[r], seg_p)
            cu = (jax.tree.map(lambda t: t[r], caches)
                  if caches is not None else None)
            x, nc, a = unit(lp, x, cu)
            ncs.append(nc)
            aux = aux + a
        new_caches = (jax.tree.map(lambda *ts: jnp.stack(ts), *ncs)
                      if caches is not None else None)
        return x, new_caches, aux

    if mode == "train" and cfg.remat:
        unit_fn = jax.checkpoint(lambda lp, xx: unit(lp, xx, None)[::2],
                                 prevent_cse=False)

        def body(carry, lp):
            xx, aux = carry
            xx, a = unit_fn(lp, xx)
            return (xx, aux + a), None

        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), seg_p)
        return x, None, aux

    def body(carry, inp):
        xx, aux = carry
        lp, cu = inp
        xx, nc, a = unit(lp, xx, cu)
        return (xx, aux + a), nc

    xs = (seg_p, caches) if caches is not None else (seg_p, None)
    if caches is None:
        def body_nc(carry, lp):
            xx, aux = carry
            xx, nc, a = unit(lp, xx, None)
            return (xx, aux + a), nc
        (x, aux), new_caches = jax.lax.scan(
            body_nc, (x, jnp.zeros((), jnp.float32)), seg_p)
    else:
        (x, aux), new_caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, new_caches, aux


# ---------------- the model ----------------
class LM:
    """Decoder-only / enc-dec language model with pluggable mixers."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.descs = layer_descs(cfg, cross=cfg.n_enc_layers > 0)
        self.segments = make_segments(self.descs)
        self.enc_cfg = None
        self.enc_segments = None
        if cfg.n_enc_layers:
            self.enc_cfg = dataclasses.replace(cfg, causal=False,
                                               n_layers=cfg.n_enc_layers,
                                               n_experts=0, use_mla=False,
                                               block_pattern=(),
                                               local_per_global=0)
            self.enc_segments = make_segments(layer_descs(self.enc_cfg))

    # ----- params -----
    def abstract_params(self):
        cfg = self.cfg
        norm_meta_fn, _ = make_norm(cfg)
        p: Dict[str, Any] = {
            "embed": embed_meta(cfg.vocab, cfg.d_model, cfg.pdtype),
            "final_norm": norm_meta_fn(cfg.d_model, cfg.pdtype),
            "head": unembed_meta(cfg.vocab, cfg.d_model, cfg.pdtype,
                                 cfg.tie_embeddings),
            "segments": [segment_meta(cfg, s) for s in self.segments],
        }
        if self.enc_cfg is not None:
            p["encoder"] = {
                "segments": [segment_meta(self.enc_cfg, s)
                             for s in self.enc_segments],
                "final_norm": norm_meta_fn(cfg.d_model, cfg.pdtype),
            }
        if cfg.frontend in ("vision_stub", "audio_stub") and cfg.frontend_dim:
            p["frontend_proj"] = {
                "w": meta((cfg.frontend_dim, cfg.d_model), (None, "embed"),
                          cfg.pdtype)}
        if cfg.mtp:
            p["mtp"] = {
                "proj": meta((2 * cfg.d_model, cfg.d_model), (None, "embed"),
                             cfg.pdtype),
                "norm_h": norm_meta_fn(cfg.d_model, cfg.pdtype),
                "norm_e": norm_meta_fn(cfg.d_model, cfg.pdtype),
                "layer": layer_meta(cfg, LayerDesc(
                    "mla" if cfg.use_mla else "attn",
                    "moe" if cfg.n_experts else "dense")),
            }
        return p

    def init(self, key):
        return init_tree(self.abstract_params(), key)

    # ----- explicit FSDP unshard specs (see segment_apply docstring) -----
    def _unit_unshard(self, seg: Segment, mesh, cfg):
        if mesh is None:
            return None
        from jax.sharding import NamedSharding
        from . import params as pr
        pat = {f"L{j}": layer_meta(cfg, d) for j, d in enumerate(seg.pattern)}

        def f(m):
            keep_fsdp = any(a in ("expert", "expert_mlp") for a in m.axes)
            rules = pr.DEFAULT_RULES if keep_fsdp else pr.SERVE_RULES
            return NamedSharding(mesh, pr.spec_for(m, mesh, rules))

        return pr.map_tree(f, pat)

    def _gather_embed(self, params, mesh):
        """Strip the FSDP ('data') axis from the embedding/head weights once
        per step (they are reused by every loss chunk)."""
        if mesh is None:
            return params
        from jax.sharding import NamedSharding
        from . import params as pr
        out = dict(params)
        emb_meta = embed_meta(self.cfg.vocab, self.cfg.d_model, self.cfg.pdtype)
        out["embed"] = {"table": jax.lax.with_sharding_constraint(
            params["embed"]["table"],
            NamedSharding(mesh, pr.spec_for(emb_meta["table"], mesh,
                                            pr.SERVE_RULES)))}
        if not self.cfg.tie_embeddings and params.get("head"):
            hm = unembed_meta(self.cfg.vocab, self.cfg.d_model,
                              self.cfg.pdtype, False)
            out["head"] = {"w_out": jax.lax.with_sharding_constraint(
                params["head"]["w_out"],
                NamedSharding(mesh, pr.spec_for(hm["w_out"], mesh,
                                                pr.SERVE_RULES)))}
        return out

    def param_count(self) -> int:
        from .params import count_params
        return count_params(self.abstract_params())

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k of routed experts)."""
        cfg = self.cfg
        total = self.param_count()
        if not cfg.n_experts:
            return total
        from .params import count_params
        moe_layers = sum(1 for d in self.descs if d.mlp == "moe")
        per_expert = 3 * cfg.d_model * cfg.moe_d_ff
        total -= moe_layers * (cfg.n_experts - cfg.top_k) * per_expert
        return total

    # ----- embedding -----
    def _embed_tokens(self, params, tokens):
        x = embed(params["embed"], tokens).astype(self.cfg.adtype)
        if self.cfg.embed_scale:
            x = x * jnp.sqrt(jnp.asarray(self.cfg.d_model, jnp.float32)
                             ).astype(x.dtype)
        return x

    def _frontend(self, params, batch, tokens_x):
        """Prepend projected patch/frame embeddings (vlm stub)."""
        emb = batch["patches"].astype(self.cfg.adtype)
        if "frontend_proj" in params:
            emb = emb @ params["frontend_proj"]["w"].astype(emb.dtype)
        return jnp.concatenate([emb, tokens_x], axis=1)

    def _encode(self, params, frames, mesh, batch_axes):
        cfg = self.enc_cfg
        x = frames.astype(cfg.adtype)
        if "frontend_proj" in params:
            x = x @ params["frontend_proj"]["w"].astype(x.dtype)
        positions = jnp.arange(x.shape[1])
        aux = jnp.zeros((), jnp.float32)
        for sp, seg in zip(params["encoder"]["segments"], self.enc_segments):
            x, _, a = segment_apply(sp, x, seg, cfg=cfg, mode="train",
                                    caches=None, positions=positions,
                                    cur_pos=None, mesh=mesh,
                                    batch_axes=batch_axes,
                                    unshard=self._unit_unshard(seg, mesh, cfg))
            aux = aux + a
        _, norm = make_norm(cfg)
        return norm(params["encoder"]["final_norm"], x), aux

    # ----- train -----
    def train_loss(self, params, batch, *, mesh=None, batch_axes=("data",)):
        cfg = self.cfg
        params = self._gather_embed(params, mesh)
        tokens = batch["tokens"]
        labels = batch["labels"]
        x = self._embed_tokens(params, tokens)
        cross_memory = None
        aux_total = jnp.zeros((), jnp.float32)
        if self.enc_cfg is not None:
            cross_memory, a = self._encode(params, batch["frames"], mesh,
                                           batch_axes)
            aux_total += a
        if cfg.frontend == "vision_stub":
            x = self._frontend(params, batch, x)
            pad = jnp.full((labels.shape[0], batch["patches"].shape[1]), -1,
                           labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        positions = jnp.arange(x.shape[1])
        for sp, seg in zip(params["segments"], self.segments):
            x, _, a = segment_apply(sp, x, seg, cfg=cfg, mode="train",
                                    caches=None, positions=positions,
                                    cur_pos=None, mesh=mesh,
                                    batch_axes=batch_axes,
                                    cross_memory=cross_memory,
                                    unshard=self._unit_unshard(seg, mesh, cfg))
            aux_total += a
        _, norm = make_norm(cfg)
        x = norm(params["final_norm"], x)
        mask = (labels >= 0).astype(jnp.float32)
        lab = jnp.maximum(labels, 0)
        lf = lambda xc: logits_fn(params.get("head", {}), params["embed"], xc,
                                  cfg.tie_embeddings)
        loss, denom = chunked_softmax_xent(lf, x, lab, mask)
        metrics = {"xent": loss, "aux": aux_total, "tokens": denom}
        if cfg.mtp:
            mtp_loss = self._mtp_loss(params, x, tokens, labels, mesh,
                                      batch_axes, positions)
            metrics["mtp"] = mtp_loss
            loss = loss + 0.3 * mtp_loss
        if cfg.n_experts:
            loss = loss + 0.01 * aux_total
        return loss, metrics

    def _mtp_loss(self, params, h, tokens, labels, mesh, batch_axes, positions):
        """DeepSeek-V3 multi-token prediction: one extra block predicts t+2
        from [h_t ; emb(token_{t+1})]."""
        cfg = self.cfg
        _, norm = make_norm(cfg)
        h_in = norm(params["mtp"]["norm_h"], h[:, :-1])
        e_in = norm(params["mtp"]["norm_e"],
                    self._embed_tokens(params, tokens[:, 1:]))
        x = jnp.concatenate([h_in, e_in], axis=-1) @ params["mtp"]["proj"].astype(h.dtype)
        desc = LayerDesc("mla" if cfg.use_mla else "attn",
                         "moe" if cfg.n_experts else "dense")
        lp = params["mtp"]["layer"]
        if mesh is not None:
            us = self._unit_unshard(Segment((desc,), 1), mesh, cfg)["L0"]
            lp = jax.tree.map(jax.lax.with_sharding_constraint, lp, us)
        x, _, _ = layer_apply(lp, x, desc, cfg=cfg, mode="train", cache=None,
                              positions=positions[:-1], cur_pos=None,
                              mesh=mesh, batch_axes=batch_axes)
        x = norm(params["final_norm"], x)
        lab = labels[:, 1:]
        mask = (lab >= 0).astype(jnp.float32)
        lf = lambda xc: logits_fn(params.get("head", {}), params["embed"], xc,
                                  cfg.tie_embeddings)
        loss, _ = chunked_softmax_xent(lf, x, jnp.maximum(lab, 0), mask)
        return loss

    # ----- prefill -----
    def prefill(self, params, batch, *, mesh=None, batch_axes=("data",),
                max_len: Optional[int] = None):
        """Full-prompt forward; returns (last_logits, caches).

        Prefill caches are emitted at prompt length; the decode cache layout
        (``cache_meta``) is seeded from them by the serving engine."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed_tokens(params, tokens)
        cross_memory = None
        if self.enc_cfg is not None:
            cross_memory, _ = self._encode(params, batch["frames"], mesh,
                                           batch_axes)
        if cfg.frontend == "vision_stub":
            x = self._frontend(params, batch, x)
        positions = jnp.arange(x.shape[1])
        caches = []
        for sp, seg in zip(params["segments"], self.segments):
            x, nc, _ = segment_apply(sp, x, seg, cfg=cfg, mode="prefill",
                                     caches=None, positions=positions,
                                     cur_pos=None, mesh=mesh,
                                     batch_axes=batch_axes,
                                     cross_memory=cross_memory)
            caches.append(nc)
        _, norm = make_norm(cfg)
        x = norm(params["final_norm"], x)
        last = x[:, -1:]
        logits = logits_fn(params.get("head", {}), params["embed"], last,
                           cfg.tie_embeddings)
        return logits, caches

    # ----- decode -----
    def decode_step(self, params, caches, tokens, cur_pos, *, mesh=None,
                    batch_axes=("data",), cross_memory=None):
        """One token for every sequence. tokens: (B, 1); cur_pos: scalar."""
        cfg = self.cfg
        x = self._embed_tokens(params, tokens)
        positions = jnp.asarray(cur_pos)[None]
        new_caches = []
        for sp, seg, cu in zip(params["segments"], self.segments, caches):
            x, nc, _ = segment_apply(sp, x, seg, cfg=cfg, mode="decode",
                                     caches=cu, positions=positions,
                                     cur_pos=cur_pos, mesh=mesh,
                                     batch_axes=batch_axes,
                                     cross_memory=cross_memory)
            new_caches.append(nc)
        _, norm = make_norm(cfg)
        x = norm(params["final_norm"], x)
        logits = logits_fn(params.get("head", {}), params["embed"], x,
                           cfg.tie_embeddings)
        return logits, new_caches

    # ----- shapes -----
    def decode_cache_meta(self, batch: int, max_len: int, enc_len: int = 0):
        return cache_meta(self.cfg, self.segments, batch, max_len, enc_len)
