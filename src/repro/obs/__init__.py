"""Unified observability: metrics registry, trace spans, logs, profiling.

One subsystem, three pillars, shared by core / serving / streaming /
distributed (and the benchmark drivers):

* **metrics** (:mod:`repro.obs.metrics`) — process-local
  :class:`MetricsRegistry` of labeled :class:`Counter` / :class:`Gauge` /
  :class:`Histogram` families with a typed, round-trippable ``snapshot()``
  schema and Prometheus text exposition
  (:func:`start_metrics_server`, ``repro.launch.serve --metrics-port``).
  :class:`StreamingHistogram` (formerly ``repro.serving.scheduler``) is the
  shared percentile structure.
* **traces** (:mod:`repro.obs.trace`) — per-request span trees. Library
  code calls :func:`span` unconditionally; with no tracer installed it
  returns a no-op singleton (one thread-local read, zero allocation), so
  instrumentation-off is the fast path. ``SearchRequest(trace=True)``
  (or ``EngineConfig(trace_sample=...)``) rides a finished :class:`Trace`
  back on ``SearchResult.trace`` — export Chrome-trace JSON with
  ``.save()`` or print ``result.explain()``; ``with obs.capture() as tr:``
  scopes a trace around arbitrary code (serving steps, flush/compact).
* **logs + profiling** (:mod:`repro.obs.log`, :mod:`repro.obs.profile`) —
  rate-limited structured progress logging (:func:`get_logger`), an opt-in
  ``jax.profiler`` capture wrapper (:func:`profiler_capture`), and the
  roofline peak constants + :func:`bandwidth_annotation` used to annotate
  kernel spans with achieved-vs-peak bandwidth.
"""
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry, REGISTRY,
                      StreamingHistogram, get_registry, start_metrics_server)
from .trace import (NULL_SPAN, Span, Trace, Tracer, active_tracer,
                    begin_request_trace, capture, end_request_trace, span,
                    tracing)
from .log import StructuredLogger, get_logger
from .profile import (HBM_BW, LINK_BW, PEAK_FLOPS, bandwidth_annotation,
                      profiler_capture)

__all__ = [
    # metrics
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    "StreamingHistogram", "get_registry", "start_metrics_server",
    # traces
    "NULL_SPAN", "Span", "Trace", "Tracer", "active_tracer",
    "begin_request_trace", "capture", "end_request_trace", "span", "tracing",
    # logs
    "StructuredLogger", "get_logger",
    # profiling
    "HBM_BW", "LINK_BW", "PEAK_FLOPS", "bandwidth_annotation",
    "profiler_capture",
]
