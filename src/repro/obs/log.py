"""Rate-limited structured logging for build/search progress.

Stdlib ``logging`` underneath (handlers, levels, and capture keep working),
but events are structured — an event name plus ``key=value`` fields — so
progress lines stay greppable and machine-parseable instead of ad-hoc
``print`` f-strings:

    log = obs.get_logger(__name__)
    log.info("bulk_insert", variant="T", done=4096, total=20000)
    # repro.core.build: bulk_insert variant=T done=4096 total=20000

``progress()`` is the rate-limited variant for per-batch/per-item loops: at
most one emission per ``every_s`` seconds per event name (the final call can
force-flush with ``final=True`` so the 100% line always lands). Rate state
is per-logger, so two builders logging the same event don't suppress each
other.
"""
from __future__ import annotations

import logging
import time
from typing import Any, Dict

__all__ = ["StructuredLogger", "get_logger"]


def _fmt(event: str, fields: Dict[str, Any]) -> str:
    if not fields:
        return event
    body = " ".join(f"{k}={_fmt_val(v)}" for k, v in fields.items())
    return f"{event} {body}"


def _fmt_val(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    s = str(v)
    return s if " " not in s else repr(s)


class StructuredLogger:
    """Thin structured facade over one stdlib logger."""

    def __init__(self, name: str):
        self._log = logging.getLogger(name)
        self._last_emit: Dict[str, float] = {}

    def debug(self, event: str, **fields) -> None:
        self._log.debug("%s", _fmt(event, fields))

    def info(self, event: str, **fields) -> None:
        self._log.info("%s", _fmt(event, fields))

    def warning(self, event: str, **fields) -> None:
        self._log.warning("%s", _fmt(event, fields))

    def error(self, event: str, **fields) -> None:
        self._log.error("%s", _fmt(event, fields))

    def progress(self, event: str, every_s: float = 1.0, final: bool = False,
                 **fields) -> bool:
        """Rate-limited info: emits at most once per ``every_s`` per
        ``event`` (``final=True`` bypasses the limit and resets it, so a
        loop's closing 100% line is never swallowed). Returns whether the
        line was emitted. Field formatting is skipped on suppressed calls —
        a suppressed progress call costs one clock read and a dict get."""
        now = time.perf_counter()
        last = self._last_emit.get(event)
        if not final and last is not None and (now - last) < every_s:
            return False
        if final:
            self._last_emit.pop(event, None)
        else:
            self._last_emit[event] = now
        self._log.info("%s", _fmt(event, fields))
        return True


_LOGGERS: Dict[str, StructuredLogger] = {}


def get_logger(name: str) -> StructuredLogger:
    """Process-cached structured logger (mirrors ``logging.getLogger``)."""
    log = _LOGGERS.get(name)
    if log is None:
        log = _LOGGERS.setdefault(name, StructuredLogger(name))
    return log
