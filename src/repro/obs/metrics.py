"""Process-local metrics registry: Counter / Gauge / Histogram families.

One :class:`MetricsRegistry` per process (:data:`REGISTRY`) holds named
metric *families*; a family fans out into labeled *series* (``requests_total
{route="graph"}``). The registry renders three ways:

* :meth:`MetricsRegistry.snapshot` — a typed, JSON-stable schema (versioned
  ``schema`` field) that round-trips through
  :meth:`MetricsRegistry.from_snapshot` bit-for-bit, so operators can diff,
  persist, or ship snapshots;
* :meth:`MetricsRegistry.render_prometheus` — Prometheus text exposition
  (``# TYPE``/``# HELP`` + series lines, cumulative ``_bucket`` rows for
  histograms), served by :func:`start_metrics_server` /
  ``repro.launch.serve --metrics-port``;
* plain attribute reads (``counter.value()``) for tests and in-process
  consumers.

:class:`StreamingHistogram` moved here from ``repro.serving.scheduler`` (PR
7) and is re-exported there for compat: log-spaced bins give p50/p95/p99 in
O(bins) memory with no samples stored — the same structure now backs every
labeled :class:`Histogram` series.

Recording is designed for hot paths: a labeled child is resolved once
(``c = counter.labels(route="graph")``) and cached by the caller; ``inc`` /
``observe`` on a child is then one attribute update. Unlabeled families skip
the child layer entirely.
"""
from __future__ import annotations

import json
import threading
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "StreamingHistogram",
           "MetricsRegistry", "REGISTRY", "get_registry",
           "start_metrics_server"]

SNAPSHOT_SCHEMA = 1


class StreamingHistogram:
    """Log-spaced latency histogram: percentile estimates in O(bins) memory,
    no samples stored. Values are milliseconds; out-of-range values clamp to
    the edge bins. ``percentile`` returns the upper edge of the bin holding
    the target rank (conservative: never under-reports a latency SLO)."""

    def __init__(self, lo_ms: float = 1e-3, hi_ms: float = 6e4,
                 bins: int = 128):
        self.lo_ms = float(lo_ms)
        self.hi_ms = float(hi_ms)
        self.bins = int(bins)
        self._edges = np.geomspace(lo_ms, hi_ms, bins - 1)
        self._counts = np.zeros(bins, np.int64)
        self.count = 0
        self.total_ms = 0.0
        self.max_ms = 0.0

    def record(self, ms: float) -> None:
        self._counts[int(np.searchsorted(self._edges, ms))] += 1
        self.count += 1
        self.total_ms += ms
        self.max_ms = max(self.max_ms, ms)

    def percentile(self, p: float) -> float:
        """p in [0, 100]; 0.0 when empty."""
        if not self.count:
            return 0.0
        target = max(1, int(np.ceil(p / 100.0 * self.count)))
        idx = int(np.searchsorted(np.cumsum(self._counts), target))
        if idx >= self._edges.size:
            return self.max_ms
        return float(min(self._edges[idx], self.max_ms))

    @property
    def mean(self) -> float:
        return self.total_ms / self.count if self.count else 0.0

    # ---- snapshot round-trip ----
    def to_dict(self) -> dict:
        return {"lo_ms": self.lo_ms, "hi_ms": self.hi_ms, "bins": self.bins,
                "count": self.count, "sum_ms": self.total_ms,
                "max_ms": self.max_ms,
                "counts": self._counts.tolist(),
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99), "mean": self.mean}

    @classmethod
    def from_dict(cls, d: dict) -> "StreamingHistogram":
        h = cls(d["lo_ms"], d["hi_ms"], d["bins"])
        h._counts = np.asarray(d["counts"], np.int64)
        h.count = int(d["count"])
        h.total_ms = float(d["sum_ms"])
        h.max_ms = float(d["max_ms"])
        return h


def _label_key(label_names: Tuple[str, ...], labels: dict) -> tuple:
    if set(labels) != set(label_names):
        raise ValueError(f"expected labels {label_names}, got "
                         f"{tuple(sorted(labels))}")
    return tuple(str(labels[n]) for n in label_names)


class _Family:
    """Shared family mechanics: name, help text, labeled series dict."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labels: Tuple[str, ...] = ()):
        self.name = name
        self.help = help
        self.label_names = tuple(labels)
        self._series: Dict[tuple, object] = {}
        self._lock = threading.Lock()

    def _child(self, labels: dict):
        key = _label_key(self.label_names, labels)
        child = self._series.get(key)
        if child is None:
            with self._lock:
                child = self._series.setdefault(key, self._new_child())
        return child

    def labels(self, **labels):
        """Resolve (and cache) one labeled series — hot paths hold on to the
        returned child instead of re-resolving per event."""
        return self._child(labels)

    def series(self) -> List[Tuple[dict, object]]:
        return [(dict(zip(self.label_names, key)), child)
                for key, child in sorted(self._series.items())]


class _CounterChild:
    __slots__ = ("v",)

    def __init__(self):
        self.v = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.v += amount


class Counter(_Family):
    """Monotone counter family. ``inc(n, **labels)``, or cache a
    ``labels()`` child and ``child.inc(n)`` on the hot path."""

    kind = "counter"

    def _new_child(self):
        return _CounterChild()

    def inc(self, amount: float = 1.0, **labels) -> None:
        self._child(labels).inc(amount)

    def value(self, **labels) -> float:
        return self._child(labels).v


class _GaugeChild:
    __slots__ = ("v",)

    def __init__(self):
        self.v = 0.0

    def set(self, value: float) -> None:
        self.v = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.v += amount


class Gauge(_Family):
    """Point-in-time value family (queue depth, inflight rows, ...)."""

    kind = "gauge"

    def _new_child(self):
        return _GaugeChild()

    def set(self, value: float, **labels) -> None:
        self._child(labels).set(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        self._child(labels).inc(amount)

    def value(self, **labels) -> float:
        return self._child(labels).v


class Histogram(_Family):
    """Labeled family of :class:`StreamingHistogram` series. Values are
    milliseconds by convention (matches the serving layer)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labels: Tuple[str, ...] = (), lo_ms: float = 1e-3,
                 hi_ms: float = 6e4, bins: int = 128):
        super().__init__(name, help, labels)
        self._hist_args = (lo_ms, hi_ms, bins)

    def _new_child(self):
        return StreamingHistogram(*self._hist_args)

    def observe(self, ms: float, **labels) -> None:
        self._child(labels).record(ms)

    def percentile(self, p: float, **labels) -> float:
        return self._child(labels).percentile(p)


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Named metric families with get-or-create semantics: asking twice for
    the same (name, kind) returns the same family; a kind or label-set
    mismatch raises (metric names are a schema, not a suggestion)."""

    def __init__(self):
        self._families: Dict[str, _Family] = {}
        self._lock = threading.Lock()

    # ---- family constructors ----
    def _get_or_create(self, cls, name: str, help: str, labels, **kw):
        fam = self._families.get(name)
        if fam is not None:
            if not isinstance(fam, cls):
                raise ValueError(f"metric {name!r} already registered as "
                                 f"{fam.kind}, not {cls.kind}")
            if labels and tuple(labels) != fam.label_names:
                raise ValueError(f"metric {name!r} registered with labels "
                                 f"{fam.label_names}, not {tuple(labels)}")
            return fam
        with self._lock:
            return self._families.setdefault(
                name, cls(name, help, tuple(labels), **kw))

    def counter(self, name: str, help: str = "", labels=()) -> Counter:
        return self._get_or_create(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", labels=()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "", labels=(), **kw
                  ) -> Histogram:
        return self._get_or_create(Histogram, name, help, labels, **kw)

    def get(self, name: str) -> Optional[_Family]:
        return self._families.get(name)

    def clear(self) -> None:
        """Drop every family (test isolation)."""
        self._families.clear()

    # ---- typed snapshot schema (round-trips via from_snapshot) ----
    def snapshot(self) -> dict:
        out = {"schema": SNAPSHOT_SCHEMA, "metrics": {}}
        for name, fam in sorted(self._families.items()):
            series = []
            for labels, child in fam.series():
                if fam.kind == "histogram":
                    series.append({"labels": labels, **child.to_dict()})
                else:
                    series.append({"labels": labels, "value": child.v})
            entry = {"type": fam.kind, "help": fam.help,
                     "label_names": list(fam.label_names), "series": series}
            if fam.kind == "histogram":
                entry["hist_args"] = list(fam._hist_args)
            out["metrics"][name] = entry
        return out

    @classmethod
    def from_snapshot(cls, snap: dict) -> "MetricsRegistry":
        if snap.get("schema") != SNAPSHOT_SCHEMA:
            raise ValueError(f"unknown metrics snapshot schema "
                             f"{snap.get('schema')!r} (expected "
                             f"{SNAPSHOT_SCHEMA})")
        reg = cls()
        for name, entry in snap["metrics"].items():
            kind = entry["type"]
            if kind not in _KINDS:
                raise ValueError(f"unknown metric type {kind!r} for {name!r}")
            labels = tuple(entry["label_names"])
            if kind == "histogram":
                lo, hi, bins = entry.get("hist_args", (1e-3, 6e4, 128))
                fam = reg.histogram(name, entry["help"], labels, lo_ms=lo,
                                    hi_ms=hi, bins=bins)
                for s in entry["series"]:
                    fam._series[_label_key(labels, s["labels"])] = \
                        StreamingHistogram.from_dict(s)
            else:
                fam = (reg.counter if kind == "counter" else reg.gauge)(
                    name, entry["help"], labels)
                for s in entry["series"]:
                    fam._child(s["labels"]).v = float(s["value"])
        return reg

    # ---- Prometheus text exposition ----
    def render_prometheus(self) -> str:
        lines: List[str] = []
        for name, fam in sorted(self._families.items()):
            if fam.help:
                lines.append(f"# HELP {name} {fam.help}")
            lines.append(f"# TYPE {name} {fam.kind}")
            for labels, child in fam.series():
                if fam.kind == "histogram":
                    lines.extend(_prom_histogram(name, labels, child))
                else:
                    lines.append(f"{name}{_prom_labels(labels)} "
                                 f"{_prom_num(child.v)}")
        return "\n".join(lines) + "\n"


def _prom_labels(labels: dict, extra: Optional[dict] = None) -> str:
    items = dict(labels)
    if extra:
        items.update(extra)
    if not items:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in items.items())
    return "{" + body + "}"


def _prom_num(v: float) -> str:
    return repr(int(v)) if float(v).is_integer() else repr(float(v))


def _prom_histogram(name: str, labels: dict, h: StreamingHistogram
                    ) -> List[str]:
    lines = []
    cum = np.cumsum(h._counts)
    for edge, c in zip(h._edges, cum[:-1]):
        lines.append(f"{name}_bucket"
                     f"{_prom_labels(labels, {'le': f'{edge:.6g}'})} "
                     f"{int(c)}")
    lines.append(f"{name}_bucket{_prom_labels(labels, {'le': '+Inf'})} "
                 f"{h.count}")
    lines.append(f"{name}_sum{_prom_labels(labels)} {_prom_num(h.total_ms)}")
    lines.append(f"{name}_count{_prom_labels(labels)} {h.count}")
    return lines


REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-default registry every subsystem records into."""
    return REGISTRY


def start_metrics_server(port: int, registry: Optional[MetricsRegistry] = None,
                         host: str = "127.0.0.1"):
    """Serve ``/metrics`` (Prometheus text) and ``/metrics.json`` (typed
    snapshot) on a daemon thread. ``port=0`` binds an ephemeral port; read
    ``server.server_address[1]``. Returns the ``ThreadingHTTPServer`` —
    call ``.shutdown()`` to stop."""
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    reg = registry if registry is not None else REGISTRY

    class _Handler(BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802 (http.server API)
            if self.path.startswith("/metrics.json"):
                body = json.dumps(reg.snapshot()).encode()
                ctype = "application/json"
            elif self.path.startswith("/metrics"):
                body = reg.render_prometheus().encode()
                ctype = "text/plain; version=0.0.4"
            else:
                self.send_error(404)
                return
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):  # metrics scrapes don't spam stderr
            pass

    server = ThreadingHTTPServer((host, port), _Handler)
    thread = threading.Thread(target=server.serve_forever,
                              name="obs-metrics-http", daemon=True)
    thread.start()
    server._obs_thread = thread
    return server
