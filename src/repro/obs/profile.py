"""Profiling hooks: opt-in ``jax.profiler`` capture + roofline annotation.

Two pieces:

* :func:`profiler_capture` — a context manager around
  ``jax.profiler.trace``: dumps a TensorBoard/XProf profile directory for
  the enclosed block. Opt-in and failure-tolerant: if the installed jax
  build lacks profiler support (or the capture races another one), the
  block still runs and the context records ``.error`` instead of raising —
  profiling must never take down a serving process.

* roofline constants + :func:`bandwidth_annotation` — the hardware peaks
  that ``repro.launch.roofline`` prices HLO costs against (TPU v5e: bf16
  FLOPs, HBM and ICI link bandwidth) now live here so kernel-level spans
  and the roofline driver agree on one set of numbers.
  ``bandwidth_annotation(nbytes, seconds)`` turns a measured kernel span
  into achieved GB/s and the fraction of peak — attached to kernel spans by
  ``repro.kernels.ops`` when tracing is on, and usable standalone from
  benchmark drivers.
"""
from __future__ import annotations

from typing import Dict, Optional

__all__ = ["PEAK_FLOPS", "HBM_BW", "LINK_BW", "bandwidth_annotation",
           "profiler_capture"]

# TPU v5e single-chip peaks (the roofline reference point; CPU interpret-mode
# numbers annotated against these document *distance from target hardware*,
# not CPU efficiency).
PEAK_FLOPS = 197e12     # bf16 FLOP/s per chip
HBM_BW = 819e9          # HBM bytes/s per chip
LINK_BW = 50e9          # bytes/s per ICI link


def bandwidth_annotation(nbytes: float, seconds: float,
                         peak_bw: float = HBM_BW) -> Dict[str, float]:
    """Achieved memory bandwidth of a measured region vs a peak.

    Returns ``{"bytes", "gb_per_s", "frac_of_peak"}`` — the dict a kernel
    span attaches via ``sp.set``. ``seconds <= 0`` reports 0 bandwidth
    rather than dividing by zero (a clock can quantize to 0 on tiny
    kernels)."""
    gbs = (nbytes / seconds / 1e9) if seconds > 0 else 0.0
    return {"bytes": float(nbytes), "gb_per_s": round(gbs, 3),
            "frac_of_peak": round(gbs * 1e9 / peak_bw, 6)}


class profiler_capture:
    """``with obs.profiler_capture("/tmp/prof") as cap:`` — capture a
    ``jax.profiler`` trace of the block into ``log_dir`` (view with
    TensorBoard/XProf). ``cap.ok`` says whether the capture actually ran;
    ``cap.error`` holds the reason when it did not."""

    def __init__(self, log_dir: str, create_perfetto_link: bool = False):
        self.log_dir = log_dir
        self._perfetto = create_perfetto_link
        self._active = False
        self.ok = False
        self.error: Optional[str] = None

    def __enter__(self) -> "profiler_capture":
        try:
            import jax
            jax.profiler.start_trace(
                self.log_dir, create_perfetto_link=self._perfetto)
            self._active = True
        except Exception as e:  # noqa: BLE001 — profiling is best-effort
            self.error = f"{type(e).__name__}: {e}"
        return self

    def __exit__(self, *exc) -> bool:
        if self._active:
            try:
                import jax
                jax.profiler.stop_trace()
                self.ok = True
            except Exception as e:  # noqa: BLE001
                self.error = f"{type(e).__name__}: {e}"
        return False
