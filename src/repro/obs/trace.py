"""Structured trace spans — where one request spent its time.

A :class:`Tracer` records a tree of timed :class:`Span` records; finished
tracers freeze into a :class:`Trace` that exports Chrome-trace/Perfetto JSON
(``chrome://tracing`` / https://ui.perfetto.dev) or renders as a text tree
(:meth:`Trace.render`, which backs ``SearchResult.explain()``).

The instrumentation contract is a **no-op fast path**: library code calls the
module-level :func:`span` unconditionally; when no tracer is installed it
returns the singleton :data:`NULL_SPAN` — one thread-local attribute read,
no allocation, no dict churn — so always-on instrumentation costs nothing on
untraced requests. Annotations attach via ``sp.set("key", value)``
(positional, so the disabled path never builds a kwargs dict) and should sit
behind ``if obs.tracing():`` when computing the value itself is not free.

Two activation styles:

* **per request** — ``SearchRequest(trace=True)``; the outermost engine
  (:class:`repro.core.QueryEngine`, :class:`repro.distributed.\
ShardedDeployment`, :class:`repro.streaming.SegmentedIndex`) installs a
  tracer via :func:`begin_request_trace`, inner layers add spans into it, and
  the finished :class:`Trace` rides back on ``SearchResult.trace``;
* **scoped** — ``with obs.capture() as tr: ...`` around any code (serving
  steps, flush/compact, benchmarks); ``tr.trace()`` afterwards.

Spans support both ``with`` blocks and explicit start/stop (``sp =
obs.span("jit_region"); ...; sp.stop()``) for regions whose boundaries do
not nest lexically (dispatch vs device completion of a jit call).
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, Optional

__all__ = ["Span", "Tracer", "Trace", "NULL_SPAN", "span", "tracing",
           "active_tracer", "capture", "begin_request_trace",
           "end_request_trace"]

_STATE = threading.local()


def active_tracer() -> Optional["Tracer"]:
    """The tracer currently installed on this thread, or None."""
    return getattr(_STATE, "tracer", None)


def tracing() -> bool:
    """True when a tracer is installed — guard for non-free annotations."""
    return getattr(_STATE, "tracer", None) is not None


class _NullSpan:
    """The disabled-instrumentation singleton: every operation is a no-op
    returning self, so hot paths never branch on 'is tracing on'."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, key: str, value: Any) -> "_NullSpan":
        return self

    def stop(self) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class Span:
    """One timed region. Started at construction; closed by ``stop()`` or
    leaving its ``with`` block. ``set(key, value)`` attaches an annotation
    (rendered in Chrome-trace ``args`` and ``explain()``)."""

    __slots__ = ("name", "t_start", "t_stop", "args", "children", "_tracer")

    def __init__(self, tracer: "Tracer", name: str):
        self._tracer = tracer
        self.name = name
        self.t_start = tracer.clock()
        self.t_stop: Optional[float] = None
        self.args: Dict[str, Any] = {}
        self.children: List["Span"] = []

    def set(self, key: str, value: Any) -> "Span":
        self.args[key] = value
        return self

    def stop(self) -> "Span":
        if self.t_stop is None:
            self.t_stop = self._tracer.clock()
            self._tracer._close(self)
        return self

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False

    @property
    def duration_ms(self) -> float:
        end = self.t_stop if self.t_stop is not None else self._tracer.clock()
        return (end - self.t_start) * 1e3


class Tracer:
    """Collects a span tree for one capture. Not thread-safe (one tracer per
    thread by construction — :func:`capture` installs thread-locally)."""

    def __init__(self, clock=time.perf_counter):
        self.clock = clock
        self.t0 = clock()
        self.roots: List[Span] = []
        self._stack: List[Span] = []

    def span(self, name: str) -> Span:
        sp = Span(self, name)
        if self._stack:
            self._stack[-1].children.append(sp)
        else:
            self.roots.append(sp)
        self._stack.append(sp)
        return sp

    def _close(self, sp: Span) -> None:
        # tolerate out-of-lexical-order stops (explicit start/stop regions):
        # unwind to the stopped span, force-closing anything it encloses
        if sp in self._stack:
            while self._stack:
                top = self._stack.pop()
                if top is sp:
                    break
                if top.t_stop is None:
                    top.t_stop = top._tracer.clock()

    def trace(self) -> "Trace":
        """Freeze into a Trace (open spans are closed at the current time)."""
        for sp in list(self._stack):
            if sp.t_stop is None:
                sp.t_stop = self.clock()
        self._stack.clear()
        return Trace(self.roots, self.t0)


class Trace:
    """A finished span tree: export as Chrome-trace JSON or a text tree."""

    def __init__(self, roots: List[Span], t0: float):
        self.roots = list(roots)
        self.t0 = t0

    def __len__(self) -> int:
        return sum(1 for _ in self.walk())

    def walk(self):
        """Yield ``(span, depth)`` depth-first in start order."""
        stack = [(sp, 0) for sp in reversed(self.roots)]
        while stack:
            sp, d = stack.pop()
            yield sp, d
            for ch in reversed(sp.children):
                stack.append((ch, d + 1))

    def span_names(self) -> List[str]:
        return [sp.name for sp, _ in self.walk()]

    def to_chrome(self) -> dict:
        """Chrome-trace/Perfetto JSON object (``traceEvents`` of complete
        'X' events; timestamps/durations in microseconds per the format)."""
        events = []
        for sp, _ in self.walk():
            end = sp.t_stop if sp.t_stop is not None else sp.t_start
            events.append({
                "name": sp.name, "cat": "repro", "ph": "X",
                "ts": round((sp.t_start - self.t0) * 1e6, 3),
                "dur": round((end - sp.t_start) * 1e6, 3),
                "pid": 0, "tid": 0,
                "args": {k: _jsonable(v) for k, v in sp.args.items()},
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def to_json(self) -> str:
        return json.dumps(self.to_chrome())

    def save(self, path: str) -> str:
        """Write Chrome-trace JSON; load in chrome://tracing or Perfetto."""
        with open(path, "w") as f:
            f.write(self.to_json())
        return path

    def render(self, width: int = 72) -> str:
        """Text tree — one line per span with duration and annotations."""
        lines = []
        for sp, depth in self.walk():
            pad = "  " * depth
            args = " ".join(f"{k}={_compact(v)}" for k, v in sp.args.items())
            head = f"{pad}{sp.name}"
            lines.append(f"{head:<{width}s} {sp.duration_ms:9.3f} ms"
                         + (f"  {args}" if args else ""))
        return "\n".join(lines)


def _jsonable(v):
    try:
        json.dumps(v)
        return v
    except (TypeError, ValueError):
        return repr(v)


def _compact(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    s = str(v)
    return s if len(s) <= 48 else s[:45] + "..."


# ---- module-level instrumentation surface ----------------------------------

def span(name: str) -> Any:
    """Open a span on the active tracer; :data:`NULL_SPAN` when tracing is
    off (the no-op fast path: one thread-local read, zero allocation)."""
    t = getattr(_STATE, "tracer", None)
    if t is None:
        return NULL_SPAN
    return t.span(name)


class capture:
    """``with obs.capture() as tr:`` — install a fresh tracer for the block
    (no-op passthrough if one is already active: nested captures join the
    outer trace). ``tr.trace()`` afterwards returns the finished Trace."""

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self._installed = False
        self.tracer: Optional[Tracer] = None

    def __enter__(self) -> Tracer:
        cur = getattr(_STATE, "tracer", None)
        if cur is not None:
            self.tracer = cur
            return cur
        self.tracer = Tracer(clock=self._clock)
        _STATE.tracer = self.tracer
        self._installed = True
        return self.tracer

    def __exit__(self, *exc) -> bool:
        if self._installed:
            _STATE.tracer = None
        return False


def begin_request_trace() -> Optional[Tracer]:
    """Install a fresh tracer for one traced request IF none is active;
    returns it (caller must pass it to :func:`end_request_trace`). Returns
    None when a tracer is already installed — the caller is an inner layer
    of an ongoing trace and must not finish it."""
    if getattr(_STATE, "tracer", None) is not None:
        return None
    t = Tracer()
    _STATE.tracer = t
    return t


def end_request_trace(tracer: Optional[Tracer]) -> Optional[Trace]:
    """Uninstall ``tracer`` (from :func:`begin_request_trace`) and return its
    finished Trace; None passthrough for inner layers."""
    if tracer is None:
        return None
    if getattr(_STATE, "tracer", None) is tracer:
        _STATE.tracer = None
    return tracer.trace()
