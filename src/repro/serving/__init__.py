from .engine import ServeEngine, RetrievalServer, seed_caches
from .ops import QueryOp, UpsertOp, DeleteOp
from .scheduler import SLOPolicy, Scheduler, ServerMetrics, StreamingHistogram
from .async_engine import AsyncRetrievalServer

__all__ = [
    "ServeEngine", "RetrievalServer", "seed_caches",
    "QueryOp", "UpsertOp", "DeleteOp",
    "SLOPolicy", "Scheduler", "ServerMetrics", "StreamingHistogram",
    "AsyncRetrievalServer",
]
