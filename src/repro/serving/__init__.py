from .engine import ServeEngine, RetrievalServer, seed_caches
