"""Async continuous-batching retrieval server.

The sync :class:`~repro.serving.engine.RetrievalServer` runs its whole queue
to completion every ``tick()`` — deterministic and great for debugging, but a
straggler query holds the batch and arriving queries wait a full tick. This
module serves the same ops through a :class:`~repro.serving.scheduler.Scheduler`
(bounded admission, EDF, typed shedding) and — on a
:class:`repro.core.QueryEngine` backend — executes graph-routed queries on
:class:`repro.core.WavefrontStream`: converged rows are harvested and their
device slots refilled with newly admitted queries **mid-flight**, so the
wavefront batch stays occupied instead of draining to a straggler.

Correctness: every served hit is bit-identical to running that query alone
through ``engine.execute`` with the same (k, ef, route, fanout, max_steps) —
the stream preserves per-row trajectories (see
:class:`repro.core.WavefrontStream`), per-row plan slots are admitted
independently, and slot results merge in plan order with the same
``merge_topk``. Property-tested over the mask x route grid in
``tests/test_serving_async.py``.

Backends other than ``QueryEngine`` (:class:`repro.streaming.SegmentedIndex`,
:class:`repro.distributed.ShardedDeployment`) execute each round as a
micro-batch through their ``execute()`` — they still get admission control,
deadlines, shedding, and metrics; a sharded backend that loses a shard
mid-stream degrades per-response (``Served.degraded``) without stalling the
scheduler.

Mutation semantics match the sync server: a round applies its mutations in
submit order *before* its queries, and the scheduler never reorders a query
across a mutation barrier — a query sees exactly the mutations submitted
before it. Queries already in flight on a stream keep their admission-time
snapshot.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.core import QueryEngine, QueryHit, Rejected, SearchRequest, Served
from repro.core import as_mask
from repro.core.engine import _empty_result
from repro.core.search import WavefrontStream, merge_topk

from .ops import DeleteOp, QueryOp, UpsertOp
from .scheduler import Round, Scheduler, ServerMetrics, SLOPolicy

__all__ = ["AsyncRetrievalServer"]


class _Embedder:
    """The sync server's batched-vs-per-item embed probe, factored for reuse:
    one batched call per round; a first-call signature error demotes to the
    per-item loop for the server's lifetime."""

    def __init__(self, embed_fn):
        self.embed_fn = embed_fn
        self._batched: Optional[bool] = None

    def __call__(self, items: List[Any]) -> np.ndarray:
        if self._batched:
            return np.ascontiguousarray(np.asarray(self.embed_fn(items)),
                                        np.float32)
        if self._batched is None:
            try:
                vecs = np.asarray(self.embed_fn(items))
                if vecs.ndim == 2 and vecs.shape[0] == len(items):
                    self._batched = True
                    return np.ascontiguousarray(vecs, np.float32)
            except (TypeError, ValueError, IndexError, KeyError,
                    AttributeError):
                pass
            self._batched = False
        return np.stack([np.asarray(self.embed_fn(it), np.float32)
                         for it in items])


class _Pending:
    """One in-flight query on the continuous path: its outstanding stream
    rows and the per-slot results harvested so far."""
    __slots__ = ("entry", "remaining", "parts", "degraded", "queue_ms")

    def __init__(self, entry, remaining: int, queue_ms: float):
        self.entry = entry
        self.remaining = remaining
        self.parts: List[Tuple[int, np.ndarray, np.ndarray]] = []
        self.degraded = False
        self.queue_ms = queue_ms


class AsyncRetrievalServer:
    """Continuous-batching front end over any ``execute()`` backend.

    ``submit*`` returns a ticket (int) or a typed
    :class:`repro.core.Rejected` — overload and shutdown shed, they never
    raise. :meth:`step` advances the server by one scheduling round + one
    wavefront chunk and returns ``{ticket: Served | Rejected}`` for every op
    that resolved during the step. :meth:`run_until_idle` drains everything.

    SLO knobs live on :class:`repro.serving.scheduler.SLOPolicy`;
    observability on :attr:`metrics` (cumulative) and :attr:`step_stats`
    (last step, the async analog of the sync server's ``tick_stats``).

    ``max_inflight`` caps rows across the wavefront streams (admission
    backpressure on the continuous path); ``chunk`` is the stream's
    steps-per-slice between refill points.

    ``bucket`` caps every wavefront stream at that many row slots (rounded
    up to a power of two) instead of the default adaptive cap derived from
    ``max_inflight``. A small cap bounds the jit retrace space to a handful
    of pow2 shapes — all touched during warmup — which is what a
    latency-SLO deployment wants: with a large cap the adaptive buckets
    retrace per (live, newcomer, repacked) pow2 shape combination, and
    which combinations occur depends on arrival timing, so fresh
    multi-hundred-ms compiles keep landing in the serving path long after
    warmup. Sparse streams (a variant that only sees occasional fan-out
    extras) still shrink below the cap rather than padding every chunk to
    full width.
    """

    def __init__(self, engine, embed_fn, k: int = 10, ef: int = 64,
                 policy: Optional[SLOPolicy] = None, route: Optional[str] = None,
                 max_steps: Optional[int] = None, auto_compact: bool = True,
                 max_inflight: int = 256, chunk: int = 16,
                 bucket: Optional[int] = None,
                 clock: Callable[[], float] = time.perf_counter):
        self.engine = engine
        self.k = int(k)
        self.ef = int(ef)
        self.route = route
        self.max_steps = max_steps
        self.auto_compact = auto_compact
        self.max_inflight = int(max_inflight)
        self.chunk = int(chunk)
        self.bucket = None if bucket is None else _pow2_at_least(int(bucket))
        self.clock = clock
        self.scheduler = Scheduler(policy, clock=clock)
        self.metrics = ServerMetrics()
        self.step_stats: Dict[str, Any] = {}
        self._embed = _Embedder(embed_fn)
        self._continuous = isinstance(engine, QueryEngine)
        self._streams: Dict[str, WavefrontStream] = {}
        self._pending: Dict[int, _Pending] = {}   # ticket -> in-flight query
        self._tags: Dict[int, Tuple[int, int]] = {}  # row tag -> (ticket, slot)
        self._next_tag = 0
        self._outcomes: Dict[int, Any] = {}       # resolved, not yet collected

    @classmethod
    def from_index(cls, index, embed_fn, k: int = 10, ef: int = 64,
                   config=None, **kw):
        from repro.core import EngineConfig
        return cls(QueryEngine(index, config=config or EngineConfig()),
                   embed_fn, k=k, ef=ef, **kw)

    # ---- submission ----
    @property
    def mutable(self) -> bool:
        return hasattr(self.engine, "add") and hasattr(self.engine, "delete")

    def submit(self, item, qlo: float, qhi: float, predicate,
               deadline_ms: Optional[float] = None, priority: int = 0):
        """Queue one query; returns a ticket or ``Rejected("queue_full")``."""
        op = QueryOp(item, float(qlo), float(qhi), as_mask(predicate),
                     deadline_ms=deadline_ms, priority=priority)
        return self._offer(op)

    def submit_upsert(self, ext_id: int, item, lo: float, hi: float,
                      deadline_ms: Optional[float] = None, priority: int = 0):
        if not self.mutable:
            r = Rejected("not_mutable", op="upsert",
                         queue_depth=self.scheduler.depth)
            self.metrics.record_shed(r.reason)
            return r
        return self._offer(UpsertOp(int(ext_id), item, float(lo), float(hi),
                                    deadline_ms=deadline_ms,
                                    priority=priority))

    def submit_delete(self, ext_id: int, deadline_ms: Optional[float] = None,
                      priority: int = 0):
        if not self.mutable:
            r = Rejected("not_mutable", op="delete",
                         queue_depth=self.scheduler.depth)
            self.metrics.record_shed(r.reason)
            return r
        return self._offer(DeleteOp(int(ext_id), deadline_ms=deadline_ms,
                                    priority=priority))

    def _offer(self, op):
        out = self.scheduler.offer(op)
        if isinstance(out, Rejected):
            self.metrics.record_shed(out.reason)
        else:
            self.metrics.record_admitted()
        return out

    # ---- serving loop ----
    @property
    def inflight(self) -> int:
        return len(self._pending)

    @property
    def idle(self) -> bool:
        return (self.scheduler.depth == 0 and not self._pending
                and all(s.idle for s in self._streams.values()))

    def step(self) -> Dict[str, Any]:
        """One scheduling round + one wavefront chunk. Returns every outcome
        that resolved during this step, keyed by ticket."""
        t0 = self.clock()
        stats = {"dispatched": 0, "mutations": 0, "served": 0, "shed": 0,
                 "admitted_rows": 0, "harvested_rows": 0}
        resolved: Dict[int, Any] = {}
        with obs.span("round") as rsp:
            rows_inflight = sum(s.inflight + s.n_pending
                                for s in self._streams.values())
            want_dispatch = self.scheduler.due() or (
                self.scheduler.depth > 0 and rows_inflight == 0)
            if want_dispatch:
                capacity = (self.max_inflight - rows_inflight
                            if self._continuous else None)
                with obs.span("admission") as asp:
                    rnd = self.scheduler.next_round(capacity=capacity)
                    self._run_round(rnd, resolved, stats)
                    asp.set("dispatched", stats["dispatched"])
                    asp.set("mutations", stats["mutations"])
                    asp.set("shed", stats["shed"])
            # advance every stream one chunk; harvest completions (each
            # stream.step() records its own "chunk" span: occupancy, refill,
            # harvested rows)
            for variant, stream in self._streams.items():
                if stream.idle:
                    continue
                for tag, ids, dists, steps in stream.step():
                    stats["harvested_rows"] += 1
                    self._absorb_row(tag, ids, dists, resolved, stats)
            if obs.tracing():
                rsp.set("served", stats["served"])
                rsp.set("harvested_rows", stats["harvested_rows"])
        self.metrics.steps += 1
        stats["queue_depth"] = self.scheduler.depth
        stats["inflight"] = self.inflight
        stats["step_s"] = self.clock() - t0
        self.step_stats = stats
        self._outcomes.update(resolved)
        return resolved

    def run_until_idle(self, max_steps: int = 100000) -> Dict[int, Any]:
        """Drain queue + streams; returns all outcomes resolved since the
        last collection (including ones from earlier ``step()`` calls)."""
        for _ in range(max_steps):
            if self.idle:
                break
            self.step()
        else:
            raise RuntimeError("run_until_idle: no convergence "
                               f"(queue={self.scheduler.depth}, "
                               f"inflight={self.inflight})")
        out = self._outcomes
        self._outcomes = {}
        return out

    def collect(self) -> Dict[int, Any]:
        """Pop every outcome resolved so far (non-blocking)."""
        out = self._outcomes
        self._outcomes = {}
        return out

    def close(self) -> Dict[int, Any]:
        """Stop admissions; shed the queue as ``Rejected("shutdown")``.
        In-flight work is NOT cancelled — keep stepping to drain it."""
        resolved = {}
        for e, rej in self.scheduler.close():
            self.metrics.record_shed(rej.reason)
            resolved[e.ticket] = rej
        self._outcomes.update(resolved)
        return resolved

    def snapshot(self) -> Dict[str, Any]:
        """Cumulative metrics view (includes stream occupancy/refill)."""
        return self.metrics.snapshot(list(self._streams.values()))

    # ---- round execution ----
    def _run_round(self, rnd: Round, resolved: Dict[int, Any],
                   stats: Dict[str, Any]) -> None:
        now = self.clock()
        for e, rej in rnd.shed:
            self.metrics.record_shed(rej.reason)
            resolved[e.ticket] = rej
            stats["shed"] += 1
        if not (rnd.mutations or rnd.queries):
            return
        # one batched embed for the round: queries + upsert items
        need = [e for e in rnd.mutations if isinstance(e.op, UpsertOp)] + \
               list(rnd.queries)
        vec_of: Dict[int, np.ndarray] = {}
        if need:
            vecs = self._embed([e.op.item for e in need])
            vec_of = {e.ticket: vecs[i] for i, e in enumerate(need)}
        # mutations first, strictly in submit order (the scheduler already
        # guarantees no query in this round was submitted after them)
        mutated = 0
        for e in rnd.mutations:
            op = e.op
            if isinstance(op, UpsertOp):
                self.engine.add(np.array([op.ext_id], np.int64),
                                vec_of[e.ticket][None, :],
                                np.array([op.lo]), np.array([op.hi]))
            else:
                self.engine.delete(np.array([op.ext_id], np.int64),
                                   strict=False)
            mutated += 1
            done = self.clock()
            self.metrics.record_served((now - e.t_submit) * 1e3,
                                       (done - e.t_submit) * 1e3,
                                       deadline_missed=_missed(e, done),
                                       mutation=True)
            resolved[e.ticket] = Served(
                hit=None, queue_ms=(now - e.t_submit) * 1e3,
                e2e_ms=(done - e.t_submit) * 1e3,
                deadline_missed=_missed(e, done))
        if (self.auto_compact and mutated
                and hasattr(self.engine, "compact")):
            self.engine.compact()
        stats["mutations"] += mutated
        if not rnd.queries:
            return
        stats["dispatched"] += len(rnd.queries)
        # group queries by (mask, resolved route)
        groups: Dict[Tuple[int, str], List[Any]] = {}
        for e in rnd.queries:
            if self._continuous:
                route = self.engine.route_for(
                    e.op.mask, np.array([e.op.qlo]), np.array([e.op.qhi]),
                    route=self.route, ef=self.ef)
            else:
                route = "backend"
            groups.setdefault((e.op.mask, route), []).append(e)
        for (mask, route), entries in groups.items():
            if self._continuous and route == "graph":
                self._admit_graph(mask, entries, vec_of, now, resolved, stats)
            else:
                self._run_microbatch(mask, route, entries, vec_of, now,
                                     resolved, stats)

    def _admit_graph(self, mask: int, entries, vec_of, now: float,
                     resolved: Dict[int, Any], stats: Dict[str, Any]) -> None:
        """Continuous path: per-row plan slots become wavefront stream rows;
        freed slots refill from later rounds mid-flight."""
        eng = self.engine
        qlo = np.array([e.op.qlo for e in entries])
        qhi = np.array([e.op.qhi for e in entries])
        qvecs = np.stack([vec_of[e.ticket] for e in entries])
        slots = eng.plan(mask, qlo, qhi)
        F = eng._resolve_fanout(self.ef, None)
        steps = self.max_steps or ((4 * self.ef + 64) // F + 8)
        live_slots = 0
        counts = np.zeros(len(entries), np.int64)
        admit: Dict[str, List[Tuple[int, int, int]]] = {}  # variant -> rows
        for si, s in enumerate(slots):
            nonempty = (np.asarray(s.version) >= 0) & \
                       (np.asarray(s.key_lo) <= np.asarray(s.key_hi))
            for qi in np.flatnonzero(nonempty):
                admit.setdefault(s.variant, []).append((int(qi), si, 0))
                counts[qi] += 1
        for qi, e in enumerate(entries):
            wait_ms = (now - e.t_submit) * 1e3
            self._pending[e.ticket] = _Pending(e, int(counts[qi]), wait_ms)
            self.metrics.queue_wait.record(wait_ms)
        for variant, rows in admit.items():
            stream = self._stream(variant, F)
            s_by_idx = {si: slots[si] for si in {r[1] for r in rows}}
            tags, qv, ver, klo, khi = [], [], [], [], []
            for qi, si, _ in rows:
                tag = self._next_tag
                self._next_tag += 1
                self._tags[tag] = (entries[qi].ticket, si)
                s = s_by_idx[si]
                tags.append(tag)
                qv.append(vec_of[entries[qi].ticket])
                ver.append(int(np.asarray(s.version)[qi]))
                klo.append(int(np.asarray(s.key_lo)[qi]))
                khi.append(int(np.asarray(s.key_hi)[qi]))
            stream.admit(np.array(tags), np.stack(qv), np.array(ver),
                         np.array(klo), np.array(khi), steps)
            live_slots += len(rows)
        stats["admitted_rows"] += live_slots
        # queries whose whole plan is empty complete immediately (solo
        # execute returns the all-NO_EDGE empty result for them)
        for qi, e in enumerate(entries):
            if counts[qi] == 0:
                resolved[e.ticket] = self._finish_query(e.ticket, stats)

    def _run_microbatch(self, mask: int, route: str, entries, vec_of,
                        now: float, resolved: Dict[int, Any],
                        stats: Dict[str, Any]) -> None:
        """Fallback path: one engine.execute per (mask, route) group. Used
        for pruned/flat routes and for non-QueryEngine backends (segmented /
        sharded); still scheduled, shed, and measured."""
        qlo = np.array([e.op.qlo for e in entries])
        qhi = np.array([e.op.qhi for e in entries])
        qvecs = np.stack([vec_of[e.ticket] for e in entries])
        req = SearchRequest(qvecs, (qlo, qhi), mask, k=self.k, ef=self.ef,
                            route=None if route == "backend" else route,
                            max_steps=self.max_steps)
        res = self.engine.execute(req)
        degraded = bool(getattr(res, "degraded", False))
        done = self.clock()
        for j, e in enumerate(entries):
            self.metrics.record_served(
                (now - e.t_submit) * 1e3, (done - e.t_submit) * 1e3,
                degraded=degraded, deadline_missed=_missed(e, done))
            resolved[e.ticket] = Served(
                hit=QueryHit(res.ids[j], res.dists[j]),
                queue_ms=(now - e.t_submit) * 1e3,
                e2e_ms=(done - e.t_submit) * 1e3,
                degraded=degraded, deadline_missed=_missed(e, done))
            stats["served"] += 1

    # ---- continuous-path plumbing ----
    def _stream(self, variant: str, fanout: int) -> WavefrontStream:
        """NOTE (quantized engines): the continuous path harvests beam rows
        straight from the wavefront and merges them in ``_finish_query``
        without the engine's exact float32 re-rank, so with
        ``storage_dtype`` of "int8"/"float16" both the streamed per-step
        distances AND the served top-k distances are the approximate
        quantized ones (ordering is re-rank-free). The sync
        :class:`repro.core.QueryEngine` path re-ranks; route quantized
        traffic there when exact distances matter."""
        if variant not in self._streams:
            eng = self.engine
            dv = eng.graph_dev(variant)
            min_b, max_b = ((min(8, self.bucket), self.bucket) if self.bucket
                            else (8, _pow2_at_least(self.max_inflight)))
            self._streams[variant] = WavefrontStream(
                dv.tree(), ef=self.ef, Kpad=dv.meta.Kpad,
                use_kernel=eng.use_kernel, fanout=fanout, chunk=self.chunk,
                min_bucket=min_b, max_bucket=max_b,
                packed=eng.packed_visited)
        return self._streams[variant]

    def _absorb_row(self, tag: int, ids: np.ndarray, dists: np.ndarray,
                    resolved: Dict[int, Any], stats: Dict[str, Any]) -> None:
        ticket, slot_idx = self._tags.pop(tag)
        pend = self._pending[ticket]
        k = min(self.k, self.ef)
        pend.parts.append((slot_idx, ids[:k], dists[:k]))
        pend.remaining -= 1
        if pend.remaining == 0:
            out = self._finish_query(ticket, stats)
            resolved[ticket] = out

    def _finish_query(self, ticket: int, stats: Dict[str, Any]):
        """Merge a completed query's slot results in plan order (identical
        merge chain to solo execute) and emit its Served outcome."""
        pend = self._pending.pop(ticket)
        k = min(self.k, self.ef)
        if pend.parts:
            parts = sorted(pend.parts, key=lambda p: p[0])
            ids, d = parts[0][1][None, :], parts[0][2][None, :]
            for _, pi, pd in parts[1:]:
                ids, d = merge_topk(ids, d, pi[None, :], pd[None, :], k)
            ids = np.asarray(ids[0])
            d = np.asarray(d[0])
        else:
            e_ids, e_d = _empty_result(1, k)
            ids, d = e_ids[0], e_d[0]
        e = pend.entry
        done = self.clock()
        # queue wait was recorded into the histogram at dispatch time
        out = Served(hit=QueryHit(ids, d), queue_ms=pend.queue_ms,
                     e2e_ms=(done - e.t_submit) * 1e3,
                     degraded=pend.degraded,
                     deadline_missed=_missed(e, done))
        self.metrics.e2e.record(out.e2e_ms)
        self.metrics.served += 1
        self.metrics.degraded += bool(out.degraded)
        self.metrics.deadline_missed += bool(out.deadline_missed)
        stats["served"] += 1
        self._outcomes[ticket] = out
        return out


def _missed(entry, now: float) -> bool:
    return entry.deadline_abs is not None and now > entry.deadline_abs


def _pow2_at_least(x: int) -> int:
    p = 1
    while p < x:
        p <<= 1
    return p
