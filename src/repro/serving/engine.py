"""Serving: cache seeding (prefill -> decode layout), greedy generation, and a
batched request engine that pairs LM embedding with MSTG retrieval (the
paper's deployment: RR-filtered vector search behind a model endpoint)."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs
from repro.models.transformer import LM, Segment
from repro.serving.ops import DeleteOp, QueryOp, UpsertOp
from repro.serving.scheduler import ServerMetrics


def _seed_leaf(prefill_leaf, target_sds, prompt_len: int):
    """Place a prefill cache leaf into its decode-capacity layout."""
    z = jnp.zeros(target_sds.shape, target_sds.dtype)
    if prefill_leaf is None:
        return z
    x = prefill_leaf.astype(target_sds.dtype)
    if x.shape == tuple(target_sds.shape):
        return x
    # sequence-extendable leaves: (B, P, ...) -> (B, M, ...)
    M = target_sds.shape[1]
    P = x.shape[1]
    if P <= M:
        return jax.lax.dynamic_update_slice_in_dim(z, x, 0, 1)
    # ring cache smaller than the prompt: keep the last M entries at their
    # ring slots (slot = pos % M)
    tail = x[:, P - M:]
    pos = np.arange(P - M, P)
    slots = pos % M
    return z.at[:, slots].set(tail)


def seed_caches(lm: LM, prefill_caches, batch: int, max_len: int,
                prompt_len: int, enc_len: int = 0):
    """Convert prefill caches (prompt-length kv / recurrent states) into the
    decode cache layout from ``lm.decode_cache_meta``."""
    metas = lm.decode_cache_meta(batch, max_len, enc_len)
    out = []
    for seg_meta, seg_cache in zip(metas, prefill_caches):
        out.append(jax.tree.map(
            lambda sds, leaf: _seed_leaf(leaf, sds, prompt_len),
            seg_meta, seg_cache))
    return out


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray        # (B, n_new)
    logits_last: np.ndarray


class ServeEngine:
    """Batched greedy decoding over the LM API (single host; the distributed
    decode path is exercised by launch/dryrun.py shardings)."""

    def __init__(self, lm: LM, params, mesh=None, batch_axes=("data",)):
        self.lm = lm
        self.params = params
        self.mesh = mesh
        self.batch_axes = batch_axes
        self._decode = jax.jit(
            lambda p, c, t, pos: lm.decode_step(p, c, t, pos, mesh=mesh,
                                                batch_axes=batch_axes))

    def generate(self, batch: Dict[str, Any], n_new: int, max_len: int
                 ) -> GenerationResult:
        lm = self.lm
        tokens = batch["tokens"]
        B, P = tokens.shape
        logits, prefill_caches = lm.prefill(self.params, batch, mesh=self.mesh,
                                            batch_axes=self.batch_axes)
        enc_len = batch["frames"].shape[1] if "frames" in batch else 0
        prompt_len = P + (batch["patches"].shape[1] if "patches" in batch else 0)
        caches = seed_caches(lm, prefill_caches, B, max_len, prompt_len, enc_len)
        out = []
        cur = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        for i in range(n_new):
            out.append(np.asarray(cur))
            logits, caches = self._decode(self.params, caches, cur,
                                          jnp.asarray(prompt_len + i, jnp.int32))
            cur = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        return GenerationResult(tokens=np.concatenate(out, 1),
                                logits_last=np.asarray(logits))


class RetrievalServer:
    """The paper's serving scenario: requests carry (text -> query vector via
    the LM's embedding table pooling) + an RR :class:`repro.core.Predicate`;
    answers come from the :class:`repro.core.QueryEngine`. Batched: requests
    are queued, the whole tick's queue is embedded in **one** ``embed_fn``
    call, then executed grouped by predicate mask so each group hits one
    vectorized plan and one jit-cached trace (the engine pads ragged groups
    to bucket sizes). Each answer is a :class:`repro.core.QueryHit`.

    Live corpora: when ``engine`` is a mutable index (anything with
    ``add``/``delete`` — i.e. :class:`repro.streaming.SegmentedIndex`),
    :meth:`submit_upsert` / :meth:`submit_delete` queue corpus mutations.
    A tick applies every queued mutation in submit order *before* running the
    tick's queries, so a query always sees the mutations submitted ahead of
    it; upserted items share the tick's single batched ``embed_fn`` call.

    ``embed_fn`` should be batched — called with the list of queued items,
    returning a ``(B, d)`` array. Legacy per-item embedders (one item -> one
    ``(d,)`` vector) are auto-detected and looped over as a fallback.

    Background compaction: when the engine is mutable and compactable (a
    :class:`repro.streaming.SegmentedIndex`), every tick that applied at
    least one mutation ends by offering the engine's
    :class:`repro.streaming.CompactionPolicy` a ``compact()`` — the policy
    decides whether any segment tier is worth merging, so idle ticks and
    well-compacted indexes cost nothing. ``auto_compact=False`` restores
    the manual-only behavior. Per-tick counters land in ``tick_stats``
    (including ``compactions``) and accumulate in ``stats``.
    """

    def __init__(self, engine, embed_fn, k: int = 10, ef: int = 64,
                 auto_compact: bool = True):
        # ``engine`` is anything with the declarative .execute(SearchRequest)
        # entry point: QueryEngine, SegmentedIndex, or a
        # repro.distributed.ShardedDeployment.
        self.engine = engine
        self.embed_fn = embed_fn
        self.k = k
        self.ef = ef
        self.auto_compact = auto_compact
        # typed op queue (repro.serving.ops) in submit order
        self.queue: List[Any] = []
        self._t_submit: List[float] = []  # perf_counter at submit, per op
        self._embed_batched: Optional[bool] = None  # decided on first tick
        self.tick_stats: Dict[str, Any] = self._zero_stats()  # last tick
        self.stats: Dict[str, Any] = self._zero_stats()       # cumulative
        # the same cumulative metrics structure the async server records, so
        # one snapshot() schema covers both front ends (queue-wait here is
        # submit -> tick dispatch; e2e is submit -> answer materialized)
        self.metrics = ServerMetrics()

    @staticmethod
    def _zero_stats() -> Dict[str, Any]:
        # counts are ints; *_s entries are wall-clock seconds for the tick's
        # phases (embed / mutations+compaction / search / whole tick), so the
        # sync server reports numbers comparable to the async ServerMetrics
        return {"ticks": 0, "queries": 0, "upserts": 0, "deletes": 0,
                "compactions": 0, "compacted_rows": 0, "degraded_queries": 0,
                "embed_s": 0.0, "mutate_s": 0.0, "search_s": 0.0,
                "tick_s": 0.0}

    @classmethod
    def from_index(cls, index, embed_fn, k: int = 10, ef: int = 64,
                   config=None):
        from repro.core import EngineConfig, QueryEngine
        return cls(QueryEngine(index, config=config or EngineConfig()),
                   embed_fn, k=k, ef=ef)

    @property
    def mutable(self) -> bool:
        """Whether the backing engine accepts upserts/deletes."""
        return hasattr(self.engine, "add") and hasattr(self.engine, "delete")

    def submit(self, item, qlo: float, qhi: float, predicate):
        """Queue one request; ``predicate`` is a repro.core Predicate, a raw
        int mask, or a parseable string like ``"any_overlap"``."""
        from repro.core import as_mask
        self.queue.append(QueryOp(item, float(qlo), float(qhi),
                                  as_mask(predicate)))
        self._t_submit.append(time.perf_counter())
        self.metrics.record_admitted()

    def submit_upsert(self, ext_id: int, item, lo: float, hi: float):
        """Queue a corpus upsert: ``item`` is embedded on the next tick (in
        the tick's one batched call) and inserted under stable ``ext_id``
        with object range ``[lo, hi]``."""
        if not self.mutable:
            raise TypeError("engine is a frozen index; upserts need a "
                            "repro.streaming.SegmentedIndex")
        self.queue.append(UpsertOp(int(ext_id), item, float(lo), float(hi)))
        self._t_submit.append(time.perf_counter())
        self.metrics.record_admitted()

    def submit_delete(self, ext_id: int):
        """Queue a corpus delete (tombstone) of ``ext_id``."""
        if not self.mutable:
            raise TypeError("engine is a frozen index; deletes need a "
                            "repro.streaming.SegmentedIndex")
        self.queue.append(DeleteOp(int(ext_id)))
        self._t_submit.append(time.perf_counter())
        self.metrics.record_admitted()

    def _embed(self, items: List[Any]) -> np.ndarray:
        """One stacked embedding call for the whole tick (per-item fallback).

        The batched-vs-per-item probe runs exactly once, on the first tick:
        a signature/shape error there demotes to the per-item path for the
        server's lifetime (a batched-only embedder must not raise on its
        first batch). After an embedder has proven batched, every exception
        propagates — a transient failure never latches the fallback."""
        if self._embed_batched:
            return np.ascontiguousarray(np.asarray(self.embed_fn(items)),
                                        np.float32)
        if self._embed_batched is None:
            try:
                vecs = np.asarray(self.embed_fn(items))
                if vecs.ndim == 2 and vecs.shape[0] == len(items):
                    self._embed_batched = True
                    return np.ascontiguousarray(vecs, np.float32)
            except (TypeError, ValueError, IndexError, KeyError,
                    AttributeError):
                pass  # per-item embedder given a list — fall back below
            self._embed_batched = False
        return np.stack([np.asarray(self.embed_fn(it), np.float32)
                         for it in items])

    def tick(self):
        """Apply queued mutations (submit order), auto-compact if any were
        applied (policy-gated), then execute all queued requests ->
        {submit order index: QueryHit}. Mutation entries occupy submit-order
        slots but produce no result entry; ``tick_stats`` describes what the
        tick did (queries/upserts/deletes/compactions)."""
        from repro.core import QueryHit, SearchRequest
        if not self.queue:
            # an idle tick did nothing: tick_stats must say so, not replay
            # the previous tick's counters into a caller's metrics loop
            self.tick_stats = self._zero_stats()
            return {}
        tick_stats = self._zero_stats()
        tick_stats["ticks"] = 1
        t_tick = time.perf_counter()
        t_dispatch = {i: t_tick - t for i, t in enumerate(self._t_submit)}
        degraded_idx: set = set()
        with obs.span("tick") as tsp:
            tsp.set("ops", len(self.queue))
            # one batched embed call for the whole tick: queries AND upserts
            embed_slots = [i for i, op in enumerate(self.queue)
                           if isinstance(op, (QueryOp, UpsertOp))]
            items = [self.queue[i].item for i in embed_slots]
            vec_of = {}
            if items:
                t0 = time.perf_counter()
                with obs.span("embed") as esp:
                    esp.set("items", len(items))
                    vecs = self._embed(items)
                tick_stats["embed_s"] = time.perf_counter() - t0
                vec_of = {i: vecs[j] for j, i in enumerate(embed_slots)}
            # 1) mutations, strictly in submit order
            t0 = time.perf_counter()
            with obs.span("mutate") as msp:
                for i, op in enumerate(self.queue):
                    if isinstance(op, UpsertOp):
                        self.engine.add(np.array([op.ext_id], np.int64),
                                        vec_of[i][None, :], np.array([op.lo]),
                                        np.array([op.hi]))
                        tick_stats["upserts"] += 1
                    elif isinstance(op, DeleteOp):
                        self.engine.delete(np.array([op.ext_id], np.int64),
                                           strict=False)
                        tick_stats["deletes"] += 1
                # 1b) background compaction: after a mutating tick, let the
                # engine's CompactionPolicy decide whether a segment tier is
                # worth merging (compact() no-ops when it picks no victims)
                if (self.auto_compact
                        and tick_stats["upserts"] + tick_stats["deletes"] > 0
                        and hasattr(self.engine, "compact")):
                    rep = self.engine.compact()
                    if rep.get("merged"):
                        tick_stats["compactions"] += 1
                        tick_stats["compacted_rows"] += rep.get("rows", 0)
                msp.set("upserts", tick_stats["upserts"])
                msp.set("deletes", tick_stats["deletes"])
            tick_stats["mutate_s"] = time.perf_counter() - t0
            # 2) queries, grouped by predicate mask
            t0 = time.perf_counter()
            results = {}
            by_mask: Dict[int, List[int]] = {}
            for i, op in enumerate(self.queue):
                if isinstance(op, QueryOp):
                    by_mask.setdefault(op.mask, []).append(i)
            with obs.span("search") as ssp:
                ssp.set("groups", len(by_mask))
                for mask, idxs in by_mask.items():
                    qlo = np.array([self.queue[i].qlo for i in idxs])
                    qhi = np.array([self.queue[i].qhi for i in idxs])
                    qvecs = np.stack([vec_of[i] for i in idxs])
                    res = self.engine.execute(SearchRequest(
                        qvecs, (qlo, qhi), mask, k=self.k, ef=self.ef))
                    ids, d = res.ids, res.dists
                    if getattr(res, "degraded", False):
                        # sharded backend answered with shards missing — the
                        # answers are still served, but the operator should
                        # see the count
                        tick_stats["degraded_queries"] += len(idxs)
                        degraded_idx.update(idxs)
                    for j, i in enumerate(idxs):
                        results[i] = QueryHit(ids[j], d[j])
            tick_stats["search_s"] = time.perf_counter() - t0
        tick_stats["queries"] = len(results)
        tick_stats["tick_s"] = time.perf_counter() - t_tick
        self.tick_stats = tick_stats
        for k_, v in tick_stats.items():
            self.stats[k_] += v
        # unified ServerMetrics accounting: one record per op, same meaning
        # as the async server's (queue = submit -> dispatch, e2e = submit ->
        # answer ready)
        t_end = time.perf_counter()
        for i, op in enumerate(self.queue):
            wait_s = t_dispatch.get(i, 0.0)
            e2e_s = wait_s + (t_end - t_tick)
            self.metrics.record_served(wait_s * 1e3, e2e_s * 1e3,
                                       degraded=i in degraded_idx,
                                       mutation=not isinstance(op, QueryOp))
        self.metrics.steps += 1
        self.queue.clear()
        self._t_submit.clear()
        return results

    def snapshot(self) -> Dict[str, Any]:
        """Operator metrics in the SAME schema as
        :meth:`repro.serving.AsyncRetrievalServer.snapshot` (the sync server
        has no WavefrontStreams, so the occupancy/refill keys are absent —
        exactly as an idle async server's snapshot would render them)."""
        return self.metrics.snapshot()
