"""Typed serving operations, shared by the sync :class:`RetrievalServer`
queue and the async :class:`~repro.serving.scheduler.Scheduler`.

These replace the op-tagged tuples (``("query", item, qlo, qhi, mask)`` /
``("upsert", ext_id, item, lo, hi)`` / ``("delete", ext_id)``) that the sync
server used to index positionally in ``tick()``. One dataclass per op kind;
both servers dispatch on type, never on tuple position.

``deadline_ms`` / ``priority`` are SLO metadata consumed only by the async
scheduler (earliest-deadline-first ordering, deadline shedding); the sync
server ignores them — its ``tick()`` is the deterministic run-everything
mode.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

__all__ = ["QueryOp", "UpsertOp", "DeleteOp", "embeddable_item"]


@dataclasses.dataclass(frozen=True)
class QueryOp:
    """One retrieval request: ``item`` is embedded by the server's
    ``embed_fn``; ``mask`` is the resolved predicate bitmask (call
    :func:`repro.core.as_mask` before constructing)."""
    item: Any
    qlo: float
    qhi: float
    mask: int
    deadline_ms: Optional[float] = None
    priority: int = 0

    def __post_init__(self):
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise ValueError("deadline_ms must be > 0 (or None: no deadline)")


@dataclasses.dataclass(frozen=True)
class UpsertOp:
    """Corpus upsert: ``item`` is embedded in the tick's batched call and
    inserted under stable ``ext_id`` with object range ``[lo, hi]``."""
    ext_id: int
    item: Any
    lo: float
    hi: float
    deadline_ms: Optional[float] = None
    priority: int = 0


@dataclasses.dataclass(frozen=True)
class DeleteOp:
    """Corpus delete (tombstone) of ``ext_id``."""
    ext_id: int
    deadline_ms: Optional[float] = None
    priority: int = 0


def embeddable_item(op) -> Optional[Any]:
    """The payload an embedder must vectorize for this op, or None (deletes
    carry no item)."""
    if isinstance(op, QueryOp):
        return op.item
    if isinstance(op, UpsertOp):
        return op.item
    return None
