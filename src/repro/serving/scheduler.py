"""SLO-aware admission control for the async serving front end.

Three pieces, all host-side and device-free:

* :class:`SLOPolicy` — the operator's knobs: bounded queue depth, micro-batch
  dispatch triggers (max-wait / max-batch), earliest-deadline-first ordering,
  and shed-on-overload behavior. Overload NEVER raises: a request that cannot
  be admitted or served in time comes back as a typed
  :class:`repro.core.Rejected` outcome.
* :class:`Scheduler` — a bounded FIFO admission queue over the typed ops of
  :mod:`repro.serving.ops`. Mutations are **barriers**: queries may be
  EDF-reordered among themselves but never across a mutation, which preserves
  the sync server's submit-order semantics ("a query sees exactly the
  mutations submitted before it") while still letting the wavefront refill
  slots mid-flight.
* :class:`ServerMetrics` / :class:`StreamingHistogram` — latency
  observability without storing samples: log-spaced histograms give
  p50/p95/p99 queue-wait and end-to-end latency; counters track
  admitted/shed/deadline-missed and batch occupancy / slot-refill efficiency
  (fed by :class:`repro.core.WavefrontStream` counters).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.api import Rejected
from repro.obs.metrics import StreamingHistogram  # moved to repro.obs (PR 7);
                                                  # re-exported for compat

from .ops import DeleteOp, QueryOp, UpsertOp

__all__ = ["SLOPolicy", "Scheduler", "ServerMetrics", "StreamingHistogram",
           "Round"]


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """Operator knobs for the admission queue and micro-batch former.

    * ``max_queue`` — bounded admission queue depth; an ``offer()`` beyond it
      returns ``Rejected("queue_full")`` (explicit shed, no exception).
    * ``max_wait_ms`` — dispatch trigger: a round is due once the oldest
      queued op has waited this long (latency bound under light load).
    * ``max_batch`` — cap on queries dispatched per round (bounds tail
      latency added by giant batches under burst).
    * ``edf`` — order the round's queries earliest-deadline-first (ties:
      higher ``priority`` first, then FIFO). Off = pure FIFO.
    * ``shed_expired`` — drop queued ops whose deadline has already passed at
      dispatch time as ``Rejected("deadline_expired")`` instead of running
      work the client has given up on. A request that *finishes* late is
      still served, flagged ``deadline_missed=True``.
    """
    max_queue: int = 1024
    max_wait_ms: float = 2.0
    max_batch: int = 64
    edf: bool = True
    shed_expired: bool = True

    def __post_init__(self):
        if self.max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.max_wait_ms < 0:
            raise ValueError("max_wait_ms must be >= 0")


_SHED_REASONS = ("queue_full", "deadline_expired", "shutdown", "not_mutable")


class ServerMetrics:
    """Cumulative serving observability. The async server records into this
    as outcomes resolve; :meth:`snapshot` renders the operator view
    (percentiles, counters, occupancy). Per-step deltas live in the server's
    ``step_stats`` (the async analog of the sync server's ``tick_stats``)."""

    def __init__(self):
        self.queue_wait = StreamingHistogram()
        self.e2e = StreamingHistogram()
        self.submitted = 0
        self.admitted = 0
        self.served = 0
        self.mutations = 0
        self.deadline_missed = 0
        self.degraded = 0
        self.shed: Dict[str, int] = {r: 0 for r in _SHED_REASONS}
        self.steps = 0

    def record_admitted(self) -> None:
        self.submitted += 1
        self.admitted += 1

    def record_shed(self, reason: str) -> None:
        if reason not in self.shed:
            self.shed[reason] = 0
        # queue_full sheds happen at offer() (already counted submitted);
        # later sheds (deadline/shutdown) were admitted earlier
        if reason == "queue_full":
            self.submitted += 1
        self.shed[reason] += 1

    def record_served(self, queue_ms: float, e2e_ms: float,
                      degraded: bool = False,
                      deadline_missed: bool = False,
                      mutation: bool = False) -> None:
        self.queue_wait.record(queue_ms)
        self.e2e.record(e2e_ms)
        if mutation:
            self.mutations += 1
        else:
            self.served += 1
        self.degraded += bool(degraded)
        self.deadline_missed += bool(deadline_missed)

    def snapshot(self, streams: Optional[List[Any]] = None) -> Dict[str, Any]:
        """Operator view; pass the server's live WavefrontStreams to include
        batch-occupancy and slot-refill efficiency."""
        out = {
            "submitted": self.submitted,
            "admitted": self.admitted,
            "served": self.served,
            "mutations": self.mutations,
            "shed": dict(self.shed),
            "shed_total": sum(self.shed.values()),
            "deadline_missed": self.deadline_missed,
            "degraded": self.degraded,
            "steps": self.steps,
            "queue_wait_ms": {
                "p50": self.queue_wait.percentile(50),
                "p95": self.queue_wait.percentile(95),
                "p99": self.queue_wait.percentile(99),
                "mean": self.queue_wait.mean,
                "max": self.queue_wait.max_ms,
            },
            "e2e_ms": {
                "p50": self.e2e.percentile(50),
                "p95": self.e2e.percentile(95),
                "p99": self.e2e.percentile(99),
                "mean": self.e2e.mean,
                "max": self.e2e.max_ms,
            },
        }
        if streams:
            occ_rows = sum(s.occupancy_rows for s in streams)
            occ_cap = sum(s.occupancy_capacity for s in streams)
            exe = sum(s.executed_row_steps for s in streams)
            use = sum(s.useful_row_steps for s in streams)
            out["batch_occupancy"] = occ_rows / occ_cap if occ_cap else 1.0
            out["refill_efficiency"] = use / exe if exe else 1.0
            out["refills"] = sum(s.refills for s in streams)
            out["refilled_rows"] = sum(s.refilled_rows for s in streams)
            out["chunks"] = sum(s.chunks for s in streams)
        return out


@dataclasses.dataclass
class _Entry:
    ticket: int
    op: Any
    t_submit: float            # clock() at offer
    deadline_abs: Optional[float]  # clock()-based absolute deadline, or None


@dataclasses.dataclass
class Round:
    """One scheduling round: mutations strictly in submit order, then the
    queries queued before the next mutation barrier (EDF-ordered when the
    policy says so), plus entries shed at dispatch."""
    mutations: List[_Entry]
    queries: List[_Entry]
    shed: List[Tuple[_Entry, Rejected]]

    def __bool__(self) -> bool:
        return bool(self.mutations or self.queries or self.shed)


class Scheduler:
    """Bounded admission queue + micro-batch former. Host-only: it never
    touches the engine; the async server drives it and executes rounds."""

    def __init__(self, policy: Optional[SLOPolicy] = None,
                 clock: Callable[[], float] = time.perf_counter):
        self.policy = policy or SLOPolicy()
        self.clock = clock
        self._queue: List[_Entry] = []
        self._next_ticket = 0
        self.closed = False

    # ---- admission ----
    def offer(self, op, now: Optional[float] = None):
        """Admit an op. Returns a ticket (int) or ``Rejected`` (queue full /
        scheduler closed). Never raises on overload."""
        now = self.clock() if now is None else now
        if self.closed:
            return Rejected("shutdown", op=_kind(op), queue_depth=self.depth)
        if len(self._queue) >= self.policy.max_queue:
            return Rejected("queue_full", op=_kind(op),
                            queue_depth=self.depth)
        deadline = None
        if op.deadline_ms is not None:
            deadline = now + op.deadline_ms / 1e3
        t = self._next_ticket
        self._next_ticket += 1
        self._queue.append(_Entry(t, op, now, deadline))
        return t

    @property
    def depth(self) -> int:
        return len(self._queue)

    def oldest_wait_ms(self, now: Optional[float] = None) -> float:
        if not self._queue:
            return 0.0
        now = self.clock() if now is None else now
        return (now - self._queue[0].t_submit) * 1e3

    def due(self, now: Optional[float] = None) -> bool:
        """Is a round worth dispatching? True when the oldest op has waited
        ``max_wait_ms``, the queue can fill a ``max_batch``, or a mutation is
        queued (mutations never wait on batch formation)."""
        if not self._queue:
            return False
        if len(self._queue) >= self.policy.max_batch:
            return True
        if any(not isinstance(e.op, QueryOp) for e in self._queue):
            return True
        return self.oldest_wait_ms(now) >= self.policy.max_wait_ms

    # ---- dispatch ----
    def next_round(self, now: Optional[float] = None,
                   capacity: Optional[int] = None) -> Round:
        """Pop one round: leading mutations (submit order), then up to
        ``min(max_batch, capacity)`` queries queued before the next mutation
        barrier. Expired entries shed here (policy.shed_expired)."""
        now = self.clock() if now is None else now
        pol = self.policy
        shed: List[Tuple[_Entry, Rejected]] = []
        if pol.shed_expired:
            live: List[_Entry] = []
            for e in self._queue:
                if e.deadline_abs is not None and now > e.deadline_abs:
                    shed.append((e, Rejected("deadline_expired",
                                             op=_kind(e.op),
                                             queue_depth=len(self._queue))))
                else:
                    live.append(e)
            self._queue = live
        mutations: List[_Entry] = []
        while self._queue and not isinstance(self._queue[0].op, QueryOp):
            mutations.append(self._queue.pop(0))
        n = 0
        while n < len(self._queue) and isinstance(self._queue[n].op, QueryOp):
            n += 1
        budget = pol.max_batch if capacity is None \
            else min(pol.max_batch, max(0, capacity))
        take = self._queue[:n]
        if pol.edf:
            take = sorted(take, key=_edf_key)
        take = take[:budget]
        taken = {e.ticket for e in take}
        self._queue = [e for e in self._queue if e.ticket not in taken]
        return Round(mutations, take, shed)

    def close(self) -> List[Tuple[_Entry, Rejected]]:
        """Stop admitting; shed everything still queued as
        ``Rejected("shutdown")``."""
        self.closed = True
        shed = [(e, Rejected("shutdown", op=_kind(e.op),
                             queue_depth=len(self._queue)))
                for e in self._queue]
        self._queue = []
        return shed


def _kind(op) -> str:
    if isinstance(op, QueryOp):
        return "query"
    if isinstance(op, UpsertOp):
        return "upsert"
    if isinstance(op, DeleteOp):
        return "delete"
    return type(op).__name__


def _edf_key(e: _Entry):
    d = e.deadline_abs if e.deadline_abs is not None else float("inf")
    return (d, -e.op.priority, e.ticket)
