"""Streaming MSTG — LSM-style segmented index with upserts, deletes, flush,
and compaction over the frozen per-segment graphs of :mod:`repro.core`.

    from repro.streaming import SegmentedIndex

    sidx = SegmentedIndex(IndexSpec(predicate=Overlaps()))
    sidx.add(ids, vectors, lo, hi)      # upsert into the mutable delta
    sidx.delete(ids[:5])                # tombstone / in-delta kill
    sidx.flush()                        # freeze delta -> immutable segment
    sidx.compact()                      # size-tiered merge, drops tombstones
    result = sidx.search(SearchRequest(...))   # fan-out + host top-k merge
    sidx.save("idx_dir/"); SegmentedIndex.load("idx_dir/")
"""
from .compaction import CompactionPolicy
from .delta import DeltaBuffer
from .segmented import Segment, SegmentedIndex

__all__ = ["CompactionPolicy", "DeltaBuffer", "Segment", "SegmentedIndex"]
