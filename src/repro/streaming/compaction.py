"""Size-tiered compaction policy for the segmented MSTG.

LSM-style: flushing the delta produces many small immutable segments; every
extra segment adds one more fan-out search per query, so the policy merges
segments of similar (small) size into one rebuilt segment, dropping
tombstoned rows. Victim selection is pure and separately testable —
:class:`repro.streaming.SegmentedIndex` owns the actual rebuild.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence


@dataclasses.dataclass(frozen=True)
class CompactionPolicy:
    """Pick which segments a ``compact()`` call should merge.

    tier_ratio : segments whose live size is strictly under ``tier_ratio`` x
                 the smallest segment's live size form the smallest tier
    min_merge  : don't bother merging fewer than this many segments —
                 *unless* one of them is fully tombstoned (dead weight is
                 always worth dropping)
    max_merge  : cap on victims per compaction (bounds rebuild cost)
    """

    tier_ratio: float = 4.0
    min_merge: int = 2
    max_merge: int = 8

    def __post_init__(self):
        if self.tier_ratio < 1.0:
            raise ValueError("tier_ratio must be >= 1")
        if self.min_merge < 2:
            raise ValueError("min_merge must be >= 2")

    def pick(self, live_sizes: Sequence[int]) -> List[int]:
        """Indices of segments to merge, smallest live size first.

        ``live_sizes[i]`` is segment i's row count minus its tombstones.
        Empty (fully tombstoned) segments are always victims; otherwise the
        smallest tier is merged when it has >= ``min_merge`` members."""
        order = sorted(range(len(live_sizes)), key=lambda i: live_sizes[i])
        dead = [i for i in order if live_sizes[i] == 0]
        tier = []
        alive = [i for i in order if live_sizes[i] > 0]
        if alive:
            smallest = live_sizes[alive[0]]
            tier = [i for i in alive
                    if live_sizes[i] < smallest * self.tier_ratio]
        if len(tier) >= self.min_merge:
            return (dead + tier)[:self.max_merge]
        return dead  # dropping fully-dead segments costs no rebuild
