"""Mutable in-memory delta buffer — the L0 of the streaming MSTG.

Freshly upserted objects land here and are served by an exact predicate-masked
brute-force scan (:func:`repro.core.flat.flat_search`, the same fused kernel
path as the static flat route) until ``SegmentedIndex.flush()`` freezes them
into an immutable MSTG segment.

Storage is a capacity-doubling arena: rows are appended in arrival order and
never moved, deletes mark the row dead by setting its range endpoints to NaN
(NaN fails every RR comparison, so a dead row can never be selected — the
same trick the blocked flat engine uses for padding). Capacities are powers
of two so the jitted scan sees O(log n) distinct shapes, not one per insert.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

import jax.numpy as jnp

from repro.core.flat import flat_search
from repro.core.hnsw import NO_EDGE

_MIN_CAPACITY = 64


class DeltaBuffer:
    """Append-only (vector, [lo, hi], external id) arena with dead-row marks.

    ``ext_of_row`` / ``row_of_ext`` bookkeeping guarantees at most one *live*
    row per external id; re-adding an id kills the old row first (upsert).
    """

    def __init__(self, d: Optional[int] = None):
        self.d = d
        self._cap = 0
        self._size = 0          # rows appended (live + dead)
        self.n_dead = 0
        self._vecs: Optional[np.ndarray] = None
        self._lo = np.zeros(0)
        self._hi = np.zeros(0)
        self._ext = np.zeros(0, np.int64)
        self._row_of_ext: Dict[int, int] = {}

    # ---- sizes ----
    def __len__(self) -> int:
        """Live rows."""
        return self._size - self.n_dead

    @property
    def nbytes(self) -> int:
        if self._vecs is None:
            return 0
        return (self._vecs.nbytes + self._lo.nbytes + self._hi.nbytes
                + self._ext.nbytes)

    def bytes_breakdown(self) -> dict:
        """Per-tier byte accounting (MSTGIndex.storage_bytes schema subset).
        The delta buffer is always exact float32 — quantization happens at
        segment freeze — so codes/scales are structurally zero."""
        full = 0 if self._vecs is None else int(self._vecs.nbytes)
        return {"storage_dtype": "float32", "float32_rerank": full,
                "codes": 0, "scales": 0, "sq_norm": 0, "scan_bytes": full,
                "compression_ratio": 1.0}

    def __contains__(self, ext_id: int) -> bool:
        return int(ext_id) in self._row_of_ext

    def _grow(self, need: int, d: int) -> None:
        if self._vecs is None:
            self.d = d
        elif d != self.d:
            raise ValueError(f"vector dim {d} != buffer dim {self.d}")
        cap = max(self._cap, _MIN_CAPACITY)
        while cap < need:
            cap *= 2
        if cap == self._cap:
            return
        vecs = np.zeros((cap, self.d), np.float32)
        lo = np.full(cap, np.nan)
        hi = np.full(cap, np.nan)
        ext = np.full(cap, NO_EDGE, np.int64)
        if self._vecs is not None:
            vecs[:self._size] = self._vecs[:self._size]
            lo[:self._size] = self._lo[:self._size]
            hi[:self._size] = self._hi[:self._size]
            ext[:self._size] = self._ext[:self._size]
        self._vecs, self._lo, self._hi, self._ext = vecs, lo, hi, ext
        self._cap = cap

    # ---- mutation ----
    @staticmethod
    def validate(ext_ids, vectors, lo, hi, d: Optional[int] = None):
        """Normalize + validate one upsert batch WITHOUT mutating anything
        -> (ext_ids, vectors, lo, hi). Callers that must apply side effects
        before appending (e.g. SegmentedIndex discarding old copies) call
        this first so a rejected batch never leaves partial state."""
        vectors = np.ascontiguousarray(vectors, np.float32)
        ext_ids = np.asarray(ext_ids, np.int64).ravel()
        lo = np.asarray(lo, np.float64).ravel()
        hi = np.asarray(hi, np.float64).ravel()
        if vectors.ndim != 2 or not (len(ext_ids) == vectors.shape[0]
                                     == len(lo) == len(hi)):
            raise ValueError("ext_ids, vectors, lo, hi must agree on rows")
        if d is not None and vectors.shape[1] != d:
            raise ValueError(f"vector dim {vectors.shape[1]} != buffer dim {d}")
        if np.any(lo > hi) or np.any(~np.isfinite(lo)) or np.any(~np.isfinite(hi)):
            raise ValueError("object ranges must be finite with lo <= hi")
        if len(np.unique(ext_ids)) != len(ext_ids):
            raise ValueError("duplicate external ids in one add() batch")
        return ext_ids, vectors, lo, hi

    def add(self, ext_ids: np.ndarray, vectors: np.ndarray,
            lo: np.ndarray, hi: np.ndarray) -> None:
        """Append rows (upsert: an id already live in the buffer is killed
        first). Callers own cross-structure upsert semantics; within the
        buffer ids stay unique."""
        self._append(*self.validate(ext_ids, vectors, lo, hi, d=self.d))

    def _append(self, ext_ids: np.ndarray, vectors: np.ndarray,
                lo: np.ndarray, hi: np.ndarray) -> None:
        """Append a batch that already went through :meth:`validate`."""
        self._grow(self._size + len(ext_ids), vectors.shape[1])
        for e in ext_ids:
            self.kill(int(e))  # in-buffer upsert
        s = self._size
        b = len(ext_ids)
        self._vecs[s:s + b] = vectors
        self._lo[s:s + b] = lo
        self._hi[s:s + b] = hi
        self._ext[s:s + b] = ext_ids
        for j, e in enumerate(ext_ids):
            self._row_of_ext[int(e)] = s + j
        self._size += b

    def kill(self, ext_id: int) -> bool:
        """Mark the live row of ``ext_id`` dead; False if not in the buffer."""
        row = self._row_of_ext.pop(int(ext_id), None)
        if row is None:
            return False
        self._lo[row] = np.nan
        self._hi[row] = np.nan
        self._ext[row] = NO_EDGE
        self.n_dead += 1
        return True

    def clear(self) -> None:
        self.__init__(self.d)

    # ---- read views (live rows, arrival order) ----
    def live(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """(ext_ids, vectors, lo, hi) of live rows in arrival order."""
        alive = np.isfinite(self._lo[:self._size])
        return (self._ext[:self._size][alive].copy(),
                self._vecs[:self._size][alive].copy(),
                self._lo[:self._size][alive].copy(),
                self._hi[:self._size][alive].copy())

    # ---- search ----
    def search(self, queries: np.ndarray, qlo: np.ndarray, qhi: np.ndarray,
               mask: int, k: int, use_kernel: bool = False
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Exact predicate-masked brute force over live rows ->
        ``(Q, k')`` external ids (NO_EDGE pad) + squared distances, with
        ``k' = min(k, capacity)``. Dead/unused rows carry NaN ranges and are
        unselectable."""
        Q = queries.shape[0]
        if len(self) == 0 or Q == 0:
            return (np.full((Q, 0), NO_EDGE, np.int64),
                    np.full((Q, 0), np.inf, np.float32))
        k_eff = min(int(k), self._cap)
        ids, d = flat_search(
            jnp.asarray(self._vecs), jnp.asarray(self._lo, jnp.float32),
            jnp.asarray(self._hi, jnp.float32),
            jnp.asarray(np.ascontiguousarray(queries, np.float32)),
            jnp.asarray(qlo, jnp.float32), jnp.asarray(qhi, jnp.float32),
            mask=int(mask), k=k_eff, use_kernel=use_kernel)
        ids = np.asarray(ids)
        d = np.asarray(d)
        ext = np.where(ids >= 0, self._ext[np.clip(ids, 0, None)],
                       np.int64(NO_EDGE))
        return ext, d
