"""SegmentedIndex — LSM-style streaming MSTG with upserts, deletes, flush,
and background-style compaction.

Layout (classic log-structured merge, specialized to the paper's index):

* **delta** (L0) — a mutable :class:`repro.streaming.delta.DeltaBuffer`;
  upserts land here and are served by an exact predicate-masked brute scan.
* **segments** — immutable :class:`repro.core.MSTGIndex` instances, each with
  a sorted ``ext_ids`` array mapping its internal rows to stable external
  ids, plus a per-segment *tombstone set* of external ids deleted after the
  segment froze. Frozen segments are bit-identical to a static build over
  the same rows — streaming never perturbs a frozen graph.
* ``flush()`` freezes the delta's live rows (canonically sorted by external
  id) into a new segment; ``compact()`` merges the smallest size tier
  (:class:`repro.streaming.compaction.CompactionPolicy`), dropping tombstoned
  rows, into one rebuilt segment. After ``compact(full=True)`` with an empty
  delta, the single surviving segment **equals** ``MSTGIndex.build`` over the
  live corpus sorted by external id — bit-identical results on all routes.
  Segment construction honors the spec's ``builder`` knob: ``flush``/
  ``compact`` rebuilds run the bulk path by default (an order of magnitude
  cheaper, so compaction stalls shrink accordingly); pin
  ``IndexSpec(builder="incremental")`` to freeze with the paper-exact
  reference builder instead.

Search fans out: every live segment executes the request on its own cached
:class:`repro.core.QueryEngine` (graph / pruned / flat / auto per segment),
over-fetching ``k + |segment tombstones|`` so tombstone filtering can never
evict a true neighbor, the delta is scanned exactly, and per-source top-k
lists are merged on host. Per-segment engines inherit the wavefront graph
loop — bit-packed visited bitmaps, chunked active-batch compaction, fanout
heuristics — and one :class:`repro.core.EngineConfig` tunes it fleet-wide
(e.g. ``EngineConfig(graph_chunk=16, packed_visited=True)``); a request's pinned
``fanout``/``chunk`` travel through the fan-out untouched. The returned :class:`repro.core.SearchResult`
carries external ids and a :class:`repro.core.RouteReport` with one
:class:`repro.core.SegmentReport` per source.

Persistence is a manifest directory (``manifest.json`` + immutable
per-segment ``.npz`` + ``delta.npz``): the manifest rename is the commit
point, so a crash mid-save never corrupts the previous artifact, and a
save/load round-trip (segments, tombstones, *and* the unflushed delta) is
bit-identical under search.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro import obs
from repro.checkpoint import index_io
from repro.core.api import (IndexSpec, RouteReport, SearchRequest,
                            SearchResult, SegmentReport)
from repro.core.engine import EngineConfig, QueryEngine
from repro.core.hnsw import NO_EDGE
from repro.core.mstg import MSTGIndex

from .compaction import CompactionPolicy
from .delta import DeltaBuffer

_MANIFEST_FORMAT = "mstg-segmented"
_MANIFEST_VERSION = 1
_SEGMENT_FORMAT = "mstg-segment"
DELTA = "delta"  # the _locate sentinel for "lives in the delta buffer"


@dataclasses.dataclass
class Segment:
    """One immutable MSTG segment plus its row->external-id map and the set
    of external ids tombstoned since it froze."""

    seg_id: str
    index: MSTGIndex
    ext_ids: np.ndarray            # (n,) int64, ascending
    tombs: set = dataclasses.field(default_factory=set)
    fingerprint: str = ""          # content digest, computed once on 1st save
    _tomb_arr: Optional[np.ndarray] = dataclasses.field(default=None,
                                                        repr=False)

    @property
    def n(self) -> int:
        return int(self.ext_ids.shape[0])

    @property
    def n_live(self) -> int:
        return self.n - len(self.tombs)

    def tomb_array(self) -> np.ndarray:
        """The tombstone set as an int64 array, cached between searches
        (tombs only ever grows, so a stale cache is detectable by length)."""
        if self._tomb_arr is None or self._tomb_arr.shape[0] != len(self.tombs):
            self._tomb_arr = np.fromiter(self.tombs, np.int64, len(self.tombs))
        return self._tomb_arr

    def live_rows(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """(ext_ids, vectors, lo, hi) of non-tombstoned rows."""
        if self.tombs:
            alive = ~np.isin(self.ext_ids, self.tomb_array())
        else:
            alive = np.ones(self.n, bool)
        return (self.ext_ids[alive], self.index.vectors[alive],
                self.index.lo[alive], self.index.hi[alive])


def _fingerprint(index: MSTGIndex, ext_ids: np.ndarray) -> str:
    """Content digest of a segment (rows + ranges + ids + build spec). Part
    of the persisted filename, so two *different* segments that happen to
    share a counter-derived id (e.g. two SegmentedIndex instances saving
    into the same directory) can never silently reuse each other's file."""
    h = hashlib.sha1()
    h.update(np.ascontiguousarray(ext_ids).tobytes())
    h.update(np.ascontiguousarray(index.vectors).tobytes())
    h.update(np.ascontiguousarray(index.lo).tobytes())
    h.update(np.ascontiguousarray(index.hi).tobytes())
    h.update(repr(sorted(index.spec.to_dict().items())).encode())
    return h.hexdigest()[:12]


def _merge_topk_host(ids_list: List[np.ndarray], d_list: List[np.ndarray],
                     Q: int, k: int) -> Tuple[np.ndarray, np.ndarray]:
    """Merge per-source ``(Q, k_i)`` top-k lists into ``(Q, k)``; stable in
    source order, so a single clean source passes through bit-identically."""
    widths = [i.shape[1] for i in ids_list]
    if not ids_list or sum(widths) == 0:
        return (np.full((Q, k), NO_EDGE, np.int64),
                np.full((Q, k), np.inf, np.float32))
    ids = np.concatenate([np.asarray(i, np.int64) for i in ids_list], axis=1)
    d = np.concatenate([np.asarray(x, np.float32) for x in d_list], axis=1)
    if ids.shape[1] < k:
        pad = k - ids.shape[1]
        ids = np.pad(ids, ((0, 0), (0, pad)), constant_values=NO_EDGE)
        d = np.pad(d, ((0, 0), (0, pad)), constant_values=np.inf)
    order = np.argsort(d, axis=1, kind="stable")[:, :k]
    return np.take_along_axis(ids, order, 1), np.take_along_axis(d, order, 1)


class SegmentedIndex:
    """Streaming MSTG: delta buffer + immutable segments + tombstones.

    Parameters
    ----------
    spec : IndexSpec
        Build configuration shared by every frozen segment (variants, m,
        ef_con, ...). Defaults to ``IndexSpec()`` (any-overlap variants).
    policy : CompactionPolicy
        Victim selection for :meth:`compact`.
    flush_threshold : int, optional
        Auto-flush the delta into a segment once its live size reaches this
        (None = flush only on explicit :meth:`flush` / :meth:`save`).
    engine_config : EngineConfig, optional
        Shared config for every per-segment :class:`QueryEngine` (route,
        use_kernel, flat_threshold, ...). Defaults to ``EngineConfig()``.
    engine_kwargs : dict, optional
        Legacy spelling of ``engine_config`` — converted through
        ``EngineConfig(**engine_kwargs)`` (and applied on top of
        ``engine_config`` when both are given).
    build_workers : int
        Process-pool width for segment freezes (:meth:`flush` /
        :meth:`compact` — a freeze builds the spec's variants, which are
        independent). ``0``/``1`` = serial. An execution resource, not
        index state: it never changes the frozen segment.
    """

    def __init__(self, spec: Optional[IndexSpec] = None, *,
                 policy: Optional[CompactionPolicy] = None,
                 flush_threshold: Optional[int] = None,
                 engine_config: Optional[EngineConfig] = None,
                 engine_kwargs: Optional[dict] = None,
                 build_workers: int = 0):
        self.spec = spec if spec is not None else IndexSpec()
        self.policy = policy or CompactionPolicy()
        self.flush_threshold = flush_threshold
        self.build_workers = int(build_workers)
        cfg = engine_config if engine_config is not None else EngineConfig()
        if engine_kwargs:
            cfg = cfg.replace(**engine_kwargs)
        self.engine_config = cfg
        self.delta = DeltaBuffer()
        self.segments: List[Segment] = []
        self.ops = {"adds": 0, "deletes": 0, "flushes": 0, "compactions": 0}
        self._seg_counter = 0
        self._locate: Dict[int, str] = {}      # live ext id -> seg_id | DELTA
        self._engines: Dict[str, QueryEngine] = {}

    # ---- sizes / lookup ----
    def __len__(self) -> int:
        """Live objects across segments + delta."""
        return sum(s.n_live for s in self.segments) + len(self.delta)

    def __contains__(self, ext_id: int) -> bool:
        return int(ext_id) in self._locate

    def _segment(self, seg_id: str) -> Segment:
        for s in self.segments:
            if s.seg_id == seg_id:
                return s
        raise KeyError(seg_id)

    def stats(self) -> dict:
        # per-tier bytes: frozen segments quantize at freeze time (they
        # inherit spec.storage_dtype); the delta buffer stays exact float32
        # until its rows reach a segment, so it reports codes=0
        seg_sb = [s.index.storage_bytes() for s in self.segments]
        delta_sb = self.delta.bytes_breakdown()
        scan = delta_sb["scan_bytes"] + sum(b["scan_bytes"] for b in seg_sb)
        full = delta_sb["float32_rerank"] + sum(b["float32_rerank"]
                                                for b in seg_sb)
        return {
            "n_live": len(self),
            "delta": len(self.delta),
            "delta_dead": self.delta.n_dead,
            "tombstones": sum(len(s.tombs) for s in self.segments),
            "segments": [{"id": s.seg_id, "n": s.n, "live": s.n_live,
                          "tombstones": len(s.tombs),
                          "storage_bytes": sb}
                         for s, sb in zip(self.segments, seg_sb)],
            "ops": dict(self.ops),
            "storage_dtype": self.spec.storage_dtype,
            "storage_bytes": {
                "codes": sum(b["codes"] for b in seg_sb),
                "scales": sum(b["scales"] for b in seg_sb),
                "sq_norm": sum(b["sq_norm"] for b in seg_sb),
                "float32_rerank": full,
                "scan_bytes": scan,
                "compression_ratio": full / max(scan, 1),
            },
        }

    # ---- mutation ----
    def _discard(self, ext_id: int) -> bool:
        """Drop the live copy of ``ext_id`` wherever it is; False if absent."""
        loc = self._locate.pop(ext_id, None)
        if loc is None:
            return False
        if loc == DELTA:
            self.delta.kill(ext_id)
        else:
            self._segment(loc).tombs.add(ext_id)
        return True

    def add(self, ext_ids, vectors, lo, hi) -> None:
        """Upsert a batch: ``(B,)`` stable external ids, ``(B, d)`` vectors,
        ``(B,)`` range endpoints. An id that is already live anywhere (delta
        or a frozen segment) is atomically replaced."""
        # validate BEFORE discarding old copies: a rejected batch must not
        # tombstone/kill the rows it failed to replace
        ext_ids, vectors, lo, hi = DeltaBuffer.validate(
            ext_ids, vectors, lo, hi, d=self.delta.d)
        for e in ext_ids:
            self._discard(int(e))
        self.delta._append(ext_ids, vectors, lo, hi)
        for e in ext_ids:
            self._locate[int(e)] = DELTA
        self.ops["adds"] += len(ext_ids)
        if (self.flush_threshold is not None
                and len(self.delta) >= self.flush_threshold):
            self.flush()

    upsert = add

    def delete(self, ext_ids, strict: bool = True) -> int:
        """Delete by external id (tombstone for frozen rows, in-place kill for
        delta rows). Unknown ids raise ``KeyError`` unless ``strict=False``.
        Returns the number of objects actually deleted."""
        ext_ids = np.atleast_1d(np.asarray(ext_ids, np.int64)).ravel()
        done = 0
        for e in ext_ids:
            if self._discard(int(e)):
                done += 1
            elif strict:
                raise KeyError(f"external id {int(e)} is not live in the index")
        self.ops["deletes"] += done
        return done

    # ---- lifecycle ----
    def _next_seg_id(self) -> str:
        self._seg_counter += 1
        return f"seg-{self._seg_counter:06d}"

    def _freeze(self, ext: np.ndarray, vecs: np.ndarray, lo: np.ndarray,
                hi: np.ndarray) -> Segment:
        """Build one immutable segment over rows *sorted by external id* (the
        canonical order, so a fully compacted index is bit-identical to a
        static ``MSTGIndex.build`` over the same corpus)."""
        order = np.argsort(ext, kind="stable")
        seg = Segment(self._next_seg_id(),
                      MSTGIndex.build(self.spec, vecs[order], lo[order],
                                      hi[order], workers=self.build_workers),
                      np.ascontiguousarray(ext[order], np.int64))
        self.segments.append(seg)
        for e in seg.ext_ids:
            self._locate[int(e)] = seg.seg_id
        return seg

    def flush(self) -> Optional[str]:
        """Freeze the delta's live rows into a new immutable segment.
        No-op (returns None) on an empty delta."""
        if len(self.delta) == 0:
            return None
        with obs.span("flush") as fsp:
            ext, vecs, lo, hi = self.delta.live()
            fsp.set("rows", int(ext.shape[0]))
            seg = self._freeze(ext, vecs, lo, hi)
            fsp.set("segment", seg.seg_id)
        self.delta.clear()
        self.ops["flushes"] += 1
        return seg.seg_id

    def compact(self, full: bool = False) -> dict:
        """Merge segments (dropping tombstoned rows) into one rebuilt segment.

        ``full=False`` asks the :class:`CompactionPolicy` for the smallest
        size tier; ``full=True`` merges everything. Idempotent: a single
        tombstone-free victim is left alone."""
        if full:
            victims = list(self.segments)
        else:
            victims = [self.segments[i]
                       for i in self.policy.pick([s.n_live
                                                  for s in self.segments])]
        if not victims or (len(victims) == 1 and not victims[0].tombs):
            return {"merged": [], "new_segment": None, "rows": 0, "dropped": 0}
        csp = obs.span("compact")
        csp.set("victims", len(victims))
        parts = [s.live_rows() for s in victims]
        ext = np.concatenate([p[0] for p in parts])
        dropped = sum(len(s.tombs) for s in victims)
        victim_ids = [s.seg_id for s in victims]
        pos = self.segments.index(victims[0])
        for s in victims:
            self.segments.remove(s)
            self._engines.pop(s.seg_id, None)
        new_id = None
        if ext.size:
            vecs = np.concatenate([p[1] for p in parts])
            lo = np.concatenate([p[2] for p in parts])
            hi = np.concatenate([p[3] for p in parts])
            seg = self._freeze(ext, vecs, lo, hi)
            # keep the merged segment at the first victim's position so
            # source order (merge tie-breaks) stays deterministic
            self.segments.remove(seg)
            self.segments.insert(pos, seg)
            new_id = seg.seg_id
        self.ops["compactions"] += 1
        csp.set("rows", int(ext.size)).set("dropped", dropped).stop()
        return {"merged": victim_ids, "new_segment": new_id,
                "rows": int(ext.size), "dropped": dropped}

    # ---- search ----
    def _engine(self, seg: Segment) -> QueryEngine:
        if seg.seg_id not in self._engines:
            self._engines[seg.seg_id] = QueryEngine(seg.index,
                                                    config=self.engine_config)
        return self._engines[seg.seg_id]

    def execute(self, request: SearchRequest) -> SearchResult:
        """Fan the request out across live segments + delta, filter
        tombstones, merge per-source top-k. Result ids are EXTERNAL ids."""
        if not isinstance(request, SearchRequest):
            raise TypeError("SegmentedIndex serves the declarative API only; "
                            "pass a repro.core.SearchRequest")
        tracer = obs.begin_request_trace() if request.trace else None
        try:
            with obs.span("segmented_search") as root:
                root.set("Q", len(request)).set("k", request.k)
                root.set("segments", len(self.segments))
                result = self._execute_fanout(request)
        finally:
            trace = obs.end_request_trace(tracer)
        if trace is not None:
            result = dataclasses.replace(result, trace=trace)
        return result

    def _execute_fanout(self, request: SearchRequest) -> SearchResult:
        Q, k = len(request), request.k
        ids_list: List[np.ndarray] = []
        d_list: List[np.ndarray] = []
        seg_reports: List[SegmentReport] = []
        slot_count = hits = misses = 0
        variants: List[str] = []
        for seg in self.segments:
            k_eff = min(k + len(seg.tombs), seg.n)
            # the graph route's beam pool is ef wide — raise ef with k_eff or
            # the over-fetch would silently truncate to ef columns and
            # tombstone filtering could evict true neighbors after all
            with obs.span(f"segment-{seg.seg_id}") as ssp:
                res = self._engine(seg).execute(dataclasses.replace(
                    request, k=k_eff, ef=max(request.ef, k_eff)))
                if obs.tracing():
                    ssp.set("n", seg.n).set("route", res.report.route)
                    ssp.set("tombstones", len(seg.tombs))
                ext = np.where(res.ids >= 0,
                               seg.ext_ids[np.clip(res.ids, 0, None)],
                               np.int64(NO_EDGE))
                dists = np.asarray(res.dists, np.float32)
                if seg.tombs:
                    dead = np.isin(ext, seg.tomb_array())
                    ext = np.where(dead, np.int64(NO_EDGE), ext)
                    dists = np.where(dead, np.float32(np.inf), dists)
            ids_list.append(ext)
            d_list.append(dists)
            rep = res.report
            slot_count += rep.slot_count
            hits += rep.cache_hits
            misses += rep.cache_misses
            variants.extend(rep.variants)
            seg_reports.append(SegmentReport(
                segment=seg.seg_id, n=seg.n, route=rep.route, k_fetched=k_eff,
                tombstones=len(seg.tombs), slot_count=rep.slot_count))
        if len(self.delta):
            with obs.span("delta") as dsp:
                dsp.set("n", len(self.delta))
                ext, dists = self.delta.search(
                    request.vectors, request.qlo, request.qhi, request.mask,
                    k, use_kernel=self.engine_config.use_kernel)
            ids_list.append(ext)
            d_list.append(dists)
            seg_reports.append(SegmentReport(
                segment=DELTA, n=len(self.delta), route=DELTA,
                k_fetched=ext.shape[1]))
        with obs.span("merge"):
            ids, dists = _merge_topk_host(ids_list, d_list, Q, k)
        report = RouteReport(
            route="segmented", requested=request.route or "auto",
            est_selectivity=None, slot_count=slot_count,
            variants=tuple(variants), cache_hits=hits, cache_misses=misses,
            segments=tuple(seg_reports))
        return SearchResult(ids, dists, report)

    # QueryEngine-compatible declarative entry point (RetrievalServer & co).
    def search(self, request: SearchRequest) -> SearchResult:
        return self.execute(request)

    # ---- persistence (manifest directory) ----
    def save(self, root: str) -> str:
        """Persist segments + tombstones + the *unflushed* delta to a manifest
        directory. Per-segment files are immutable and written before the
        atomic ``manifest.json`` rename (the commit point); unreferenced
        files are garbage-collected afterwards. Returns the manifest path."""
        root = os.fspath(root)
        seg_dir = os.path.join(root, "segments")
        os.makedirs(seg_dir, exist_ok=True)
        seg_entries = []
        referenced = set()
        for seg in self.segments:
            if not seg.fingerprint:  # immutable content: hash at most once
                seg.fingerprint = _fingerprint(seg.index, seg.ext_ids)
            fname = f"{seg.seg_id}-{seg.fingerprint}.npz"
            fpath = os.path.join(seg_dir, fname)
            # content-named + immutable: an existing file with this exact
            # name is guaranteed to hold this segment's data, so repeated
            # saves skip the write; a same-id-different-content collision
            # (another index saving into this directory) gets its own file
            if not os.path.exists(fpath):
                arrays, meta = seg.index.to_payload()
                arrays["ext_ids"] = seg.ext_ids
                meta["segment"] = {"format": _SEGMENT_FORMAT, "id": seg.seg_id}
                index_io.save_npz_atomic(fpath, arrays, meta)
            referenced.add(fname)
            seg_entries.append({"id": seg.seg_id,
                                "file": f"segments/{fname}", "n": seg.n,
                                "tombstones": sorted(int(e)
                                                     for e in seg.tombs)})
        delta_entry = None
        if len(self.delta):
            ext, vecs, lo, hi = self.delta.live()
            h = hashlib.sha1()
            for a in (ext, vecs, lo, hi):
                h.update(np.ascontiguousarray(a).tobytes())
            # content-named like segment files: never overwrite a file the
            # previous manifest still references (crash between delta write
            # and manifest rename must leave the old artifact loadable)
            dname = f"delta-{h.hexdigest()[:12]}.npz"
            dpath = os.path.join(root, dname)
            if not os.path.exists(dpath):
                index_io.save_npz_atomic(
                    dpath, {"ext_ids": ext, "vectors": vecs,
                            "lo": lo, "hi": hi},
                    {"format": "mstg-delta", "n": int(len(ext))})
            delta_entry = {"file": dname, "n": int(len(ext))}
        manifest = {"format": _MANIFEST_FORMAT,
                    "format_version": _MANIFEST_VERSION,
                    "spec": self.spec.to_dict(),
                    "seg_counter": self._seg_counter,
                    "segments": seg_entries, "delta": delta_entry,
                    "ops": dict(self.ops)}
        path = index_io.save_manifest_atomic(root, manifest)
        index_io.gc_unreferenced(root, referenced)
        keep = delta_entry["file"] if delta_entry else None
        for name in os.listdir(root):  # stale delta files from prior saves
            if (name.startswith("delta") and name.endswith(".npz")
                    and name != keep):
                os.unlink(os.path.join(root, name))
        return path

    @classmethod
    def load(cls, root: str, *, policy: Optional[CompactionPolicy] = None,
             flush_threshold: Optional[int] = None,
             engine_config: Optional[EngineConfig] = None,
             engine_kwargs: Optional[dict] = None) -> "SegmentedIndex":
        """Restore a :meth:`save` directory — segments, tombstones, and the
        unflushed delta — with bit-identical search results."""
        root = os.fspath(root)
        manifest = index_io.load_manifest(root)
        if manifest.get("format") != _MANIFEST_FORMAT:
            raise index_io.IndexIOError(
                f"{root}: not a {_MANIFEST_FORMAT} manifest")
        self = cls(IndexSpec.from_dict(manifest["spec"]), policy=policy,
                   flush_threshold=flush_threshold,
                   engine_config=engine_config, engine_kwargs=engine_kwargs)
        self._seg_counter = int(manifest.get("seg_counter", 0))
        self.ops.update(manifest.get("ops", {}))
        for entry in manifest["segments"]:
            fpath = os.path.join(root, entry["file"])
            arrays, meta = index_io.load_npz(fpath)
            index = MSTGIndex.from_payload(arrays, meta, path=fpath)
            ext_ids = np.asarray(index_io.take(arrays, "ext_ids", fpath),
                                 np.int64)
            if ext_ids.shape[0] != index.vectors.shape[0]:
                raise index_io.IndexIOError(
                    f"{fpath}: ext_ids rows != index rows")
            seg = Segment(entry["id"], index, ext_ids,
                          set(int(e) for e in entry.get("tombstones", ())))
            self.segments.append(seg)
            for e in seg.ext_ids:
                if int(e) not in seg.tombs:
                    self._locate[int(e)] = seg.seg_id
        if manifest.get("delta"):
            fpath = os.path.join(root, manifest["delta"]["file"])
            arrays, meta = index_io.load_npz(fpath)
            if meta.get("format") != "mstg-delta":
                raise index_io.IndexIOError(f"{fpath}: not a delta artifact")
            ext = np.asarray(index_io.take(arrays, "ext_ids", fpath), np.int64)
            self.delta.add(ext, index_io.take(arrays, "vectors", fpath),
                           index_io.take(arrays, "lo", fpath),
                           index_io.take(arrays, "hi", fpath))
            for e in ext:
                self._locate[int(e)] = DELTA
        return self
