from .optimizer import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm
from .train_loop import make_train_step, TrainLoop, StragglerWatchdog
from .grad_compression import (compressed_grad_sync, compressed_mean,
                               init_residuals, quantize_int8, dequantize_int8)
