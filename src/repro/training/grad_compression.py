"""Gradient compression for the data-parallel all-reduce.

``int8 quantize -> all-reduce -> dequantize`` with *error feedback*: the
quantization residual is carried to the next step so compression bias does not
accumulate (Seide et al. / EF-SGD). Used inside a shard_map'd DP gradient sync
— the collective itself moves int8, a 4x traffic cut on the gradient
all-reduce (see EXPERIMENTS.md §Perf, collective term).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_mean(x: jnp.ndarray, axis: str, residual: jnp.ndarray):
    """Error-feedback int8 all-reduce-mean over a mesh axis (inside shard_map).
    Returns (mean, new_residual)."""
    x32 = x.astype(jnp.float32) + residual
    q, scale = quantize_int8(x32)
    # int8 payload summed in int32 to avoid overflow across shards
    summed = jax.lax.psum(q.astype(jnp.int32), axis)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis).astype(jnp.float32)
    # scales differ per shard -> reduce them too (mean of scales is a standard
    # approximation; exactness is restored over steps by error feedback)
    scale_mean = jax.lax.pmean(scale, axis)
    # residual accounting: what this shard actually contributed to the global
    # mean is q * scale_mean (receivers dequantize with the reduced scale),
    # so that — not the locally-scaled dequant — is what error feedback must
    # subtract; otherwise the scale mismatch accumulates as bias.
    new_residual = x32 - dequantize_int8(q, scale_mean)
    return summed.astype(jnp.float32) * scale_mean / n, new_residual


def compressed_grad_sync(grads, axis: str, residuals):
    """Apply compressed_mean leaf-wise. grads/residuals: matching pytrees."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out_g, out_r = [], []
    for g, r in zip(flat_g, flat_r):
        m, nr = compressed_mean(g, axis, r)
        out_g.append(m.astype(g.dtype))
        out_r.append(nr)
    return jax.tree.unflatten(treedef, out_g), jax.tree.unflatten(treedef, out_r)


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
