"""AdamW in raw JAX with fp32 moments (ZeRO-sharded: moment trees inherit the
FSDP param specs, so optimizer state is fully sharded over 'data')."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def adamw_init(params):
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(f32, params),
            "v": jax.tree.map(f32, params),
            "step": jnp.zeros((), jnp.int32)}


def lr_schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        tree), gn


def adamw_update(cfg: AdamWConfig, params, grads, state):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        mh = m / b1c
        vh = v / b2c
        step_ = mh / (jnp.sqrt(vh) + cfg.eps)
        p32 = p.astype(jnp.float32)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        p32 = p32 - lr * (step_ + wd * p32)
        return p32.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    return (jax.tree.unflatten(treedef, new_p),
            {"m": jax.tree.unflatten(treedef, new_m),
             "v": jax.tree.unflatten(treedef, new_v),
             "step": step})
