"""Training step factory + fault-tolerant loop (DESIGN.md §5).

``make_train_step`` builds a jit'd (params, opt, batch) -> (params, opt,
metrics) step with FSDP/TP shardings, optional gradient accumulation
(microbatch scan) and global-norm clipping. ``TrainLoop`` adds checkpointing,
deterministic data cursor, preemption-safe resume and a straggler watchdog.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import batch_spec
from repro.models import params as pr
from .optimizer import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm


def make_train_step(lm, mesh: Optional[Mesh] = None, batch_axes=("data",),
                    opt_cfg: Optional[AdamWConfig] = None,
                    microbatches: int = 1):
    opt_cfg = opt_cfg or AdamWConfig()

    def loss_fn(params, batch):
        loss, metrics = lm.train_loss(params, batch, mesh=mesh,
                                      batch_axes=batch_axes)
        return loss, metrics

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            B = jax.tree.leaves(batch)[0].shape[0]
            mb = B // microbatches

            def micro(carry, i):
                gsum, msum = carry
                sl = jax.tree.map(
                    lambda t: jax.lax.dynamic_slice_in_dim(t, i * mb, mb, 0),
                    batch)
                (l, met), g = jax.value_and_grad(loss_fn, has_aux=True)(params, sl)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, msum + l), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                micro, (zero, jnp.zeros((), jnp.float32)),
                jnp.arange(microbatches))
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = {"xent": loss}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        params, opt_state = adamw_update(opt_cfg, params, grads, opt_state)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return params, opt_state, metrics

    if mesh is None:
        return jax.jit(train_step)

    metas = lm.abstract_params()
    pspec = pr.spec_tree(metas, mesh)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec)
    osh = {"m": psh, "v": psh, "step": NamedSharding(mesh, P())}
    bspec = batch_spec(mesh, 1 << 30, axes=batch_axes)  # shard batch dim

    def batch_shardings(batch):
        return jax.tree.map(
            lambda t: NamedSharding(mesh, P(*(bspec + (None,) * (t.ndim - 1)))),
            batch)

    def jitted(batch_example):
        return jax.jit(train_step,
                       in_shardings=(psh, osh, batch_shardings(batch_example)),
                       out_shardings=(psh, osh, None),
                       donate_argnums=(0, 1))

    jitted.step_fn = train_step
    jitted.param_shardings = psh
    jitted.opt_shardings = osh
    return jitted


@dataclasses.dataclass
class StragglerWatchdog:
    """Flags steps slower than ``factor`` x running median — at fleet scale the
    remediation is re-sharding around the slow host; here we surface the event
    so the loop can checkpoint early (simulated mitigation, see tests)."""
    factor: float = 3.0
    history: list = dataclasses.field(default_factory=list)
    events: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, seconds: float) -> bool:
        self.history.append(seconds)
        if len(self.history) < 5:
            return False
        med = float(np.median(self.history[-50:]))
        if seconds > self.factor * med:
            self.events.append((step, seconds, med))
            return True
        return False


class TrainLoop:
    """Deterministic, preemption-safe loop: state = (params, opt, data cursor).
    Resuming from a checkpoint replays the exact batch sequence."""

    def __init__(self, lm, loader, step_fn, checkpointer=None,
                 ckpt_every: int = 50, watchdog: Optional[StragglerWatchdog] = None):
        self.lm = lm
        self.loader = loader
        self.step_fn = step_fn
        self.ckpt = checkpointer
        self.ckpt_every = ckpt_every
        self.watchdog = watchdog or StragglerWatchdog()

    def run(self, params, opt_state, start_step: int, n_steps: int,
            log_every: int = 10):
        history = []
        for step in range(start_step, start_step + n_steps):
            batch = self.loader.batch_at(step)
            t0 = time.time()
            params, opt_state, metrics = self.step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            straggle = self.watchdog.observe(step, dt)
            history.append(loss)
            if self.ckpt and ((step + 1) % self.ckpt_every == 0 or straggle):
                self.ckpt.save(step + 1, {"params": params, "opt": opt_state})
            if log_every and step % log_every == 0:
                print(f"step {step:5d} loss {loss:.4f} ({dt*1e3:.0f} ms)")
        return params, opt_state, history
