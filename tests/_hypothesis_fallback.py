"""Minimal stand-in for ``hypothesis`` when the real package is unavailable.

Installed into ``sys.modules`` by ``conftest.py`` only when ``import
hypothesis`` fails (e.g. offline containers without pip access); CI installs
the real package from requirements.txt and never sees this module.

Scope: exactly the API surface this repo's property tests use — ``given``,
``settings(max_examples=..., deadline=...)`` and the ``integers`` /
``booleans`` / ``sampled_from`` / ``data`` strategies. Examples are plain
deterministic random sampling seeded per test (no shrinking, no example
database, no directed edge-case generation) with the interval endpoints forced
into the stream so boundary behavior is always exercised.
"""
from __future__ import annotations

import random
import zlib


class _Strategy:
    def __init__(self, draw_fn, endpoints=()):
        self._draw_fn = draw_fn
        self.endpoints = tuple(endpoints)  # always-tried boundary examples

    def example_from(self, rng: random.Random):
        return self._draw_fn(rng)


class DataObject:
    """Stand-in for the object produced by ``st.data()``."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: _Strategy, label=None):
        return strategy.example_from(self._rng)


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        if min_value > max_value:
            raise ValueError("integers(): min_value > max_value")
        return _Strategy(lambda rng: rng.randint(min_value, max_value),
                         endpoints=(min_value, max_value))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.getrandbits(1)),
                         endpoints=(False, True))

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        elements = list(elements)
        if not elements:
            raise ValueError("sampled_from(): empty collection")
        return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

    @staticmethod
    def data() -> _Strategy:
        return _Strategy(lambda rng: DataObject(rng))


strategies = _Strategies()


def settings(max_examples: int = 100, deadline=None, **_ignored):
    def decorate(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return decorate


def given(*arg_strategies, **kw_strategies):
    def decorate(fn):
        def wrapper():
            n = getattr(wrapper, "_fallback_max_examples",
                        getattr(fn, "_fallback_max_examples", 50))
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                if i == 0 and all(s.endpoints for s in arg_strategies):
                    args = [s.endpoints[0] for s in arg_strategies]
                elif i == 1 and all(s.endpoints for s in arg_strategies):
                    args = [s.endpoints[-1] for s in arg_strategies]
                else:
                    args = [s.example_from(rng) for s in arg_strategies]
                kwargs = {k: s.example_from(rng)
                          for k, s in kw_strategies.items()}
                fn(*args, **kwargs)

        # keep pytest's signature inspection from treating the original
        # parameters as fixtures: expose a zero-arg callable, copy identity
        # attributes by hand, and do NOT set __wrapped__
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._hypothesis_fallback = True
        return wrapper
    return decorate
