import importlib.util
import os
import sys

# NOTE: do NOT set XLA_FLAGS / device-count overrides here — smoke tests and
# benches must see the real single CPU device; only launch/dryrun.py forces 512.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property tests use hypothesis; offline containers can't pip install it, so
# fall back to the minimal random-sampling shim (tests/_hypothesis_fallback.py)
# when the real package is absent. CI installs real hypothesis and skips this.
try:
    import hypothesis  # noqa: F401
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        os.path.join(os.path.dirname(__file__), "_hypothesis_fallback.py"))
    _mod = importlib.util.module_from_spec(_spec)
    sys.modules["hypothesis"] = _mod
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis.strategies"] = _mod.strategies

import numpy as np
import pytest

from repro.data import make_range_dataset


@pytest.fixture(scope="session")
def small_ds():
    return make_range_dataset(n=600, d=16, n_queries=12, quantize=32, seed=0)


@pytest.fixture(scope="session")
def built_index(small_ds):
    from repro.core import MSTGIndex
    ds = small_ds
    return MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp", "Tpp"),
                     m=8, ef_con=40)
