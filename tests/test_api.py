"""Declarative API: predicate algebra <-> mask round-trips (all 63 masks),
SearchRequest/SearchResult invariants, RouteReport + selectivity cache,
IndexSpec build, and the save()/load() -> bit-identical-results e2e."""
import os

import numpy as np
import pytest

from repro.core import (After, Before, ContainedBy, Contains, IndexSpec,
                        LeftOverlap, MSTGIndex, Overlaps, Predicate,
                        QueryContained, QueryContaining, QueryEngine,
                        QueryHit, RightOverlap, SearchRequest, SearchResult,
                        as_mask, as_predicate, intervals as iv, parse_mask)
from repro.core import predicates as preds
from repro.data import make_queries


# ---- predicate algebra <-> mask round-trips ----

def test_predicate_mask_roundtrip_all_63_masks():
    ns = {k: getattr(preds, k) for k in preds.__all__}
    for m in range(64):
        p = Predicate.from_mask(m)
        assert p.mask == m
        # name round-trip through the planner spelling
        assert parse_mask(iv.mask_name(m)) == m
        assert Predicate.parse(iv.mask_name(m)) == p
        # repr round-trip through the algebra
        assert eval(repr(p), dict(ns)) == p
        # planner agreement: algebra-level variants == mask-level variants
        assert p.variants_required() == iv.variants_required(m)


def test_predicate_composition_and_aliases():
    assert (LeftOverlap() | QueryContained()).mask == 3
    assert (LeftOverlap() | RightOverlap() | QueryContained()
            | QueryContaining()) == Overlaps()
    assert Overlaps().mask == iv.ANY_OVERLAP
    assert Contains() == QueryContained()
    assert ContainedBy() == QueryContaining()
    assert (Before() | After()).mask == iv.BEFORE | iv.AFTER
    # composition with raw masks and strings
    assert (LeftOverlap() | iv.QUERY_CONTAINED).mask == 3
    assert (LeftOverlap() | "2").mask == 3
    assert iv.BEFORE | After() == Predicate(48)  # __ror__
    # membership + atoms
    p = Overlaps() | Before()
    assert QueryContained() in p and After() not in p
    assert [a.mask for a in p.atoms()] == [1, 2, 4, 8, 16]


def test_predicate_validation_and_helpers():
    with pytest.raises(ValueError):
        Predicate(64)
    with pytest.raises(ValueError):
        Predicate(-1)
    assert not Predicate(0) and Overlaps()
    assert as_mask(Overlaps()) == 15 == as_mask(15) == as_mask("any_overlap")
    assert as_predicate("1|3") == LeftOverlap() | RightOverlap()
    lo = np.array([0.0, 5.0])
    hi = np.array([1.0, 6.0])
    want = iv.eval_predicate(15, lo, hi, 0.5, 5.5)
    np.testing.assert_array_equal(Overlaps().evaluate(lo, hi, 0.5, 5.5), want)


def test_parse_mask_spellings():
    assert parse_mask("1|2|<") == 19
    assert parse_mask("before,after") == 48
    assert parse_mask("2 + 4") == iv.QUERY_CONTAINED | iv.QUERY_CONTAINING
    assert parse_mask("contains|contained_by") == 10
    assert parse_mask(63) == 63
    assert parse_mask("63") == 63  # multi-digit token = raw mask
    assert parse_mask("none") == 0
    assert parse_mask("before after") == 48  # whitespace-separated
    for bad in ("", "bogus", 64, -1, "99"):
        with pytest.raises(ValueError):
            parse_mask(bad)
    with pytest.raises(TypeError):
        parse_mask(None)  # must not silently become mask 0


# ---- SearchRequest normalization ----

def test_search_request_normalization(small_ds):
    ds = small_ds
    qlo = np.zeros(4)
    qhi = np.ones(4)
    r1 = SearchRequest(ds.queries[:4], (qlo, qhi), "any_overlap", k=5)
    r2 = SearchRequest(np.asarray(ds.queries[:4], np.float64),
                       np.stack([qlo, qhi], axis=1), Overlaps(), k=5)
    assert r1.vectors.dtype == np.float32 and r1.ranges.shape == (4, 2)
    np.testing.assert_array_equal(r1.ranges, r2.ranges)
    assert r1.mask == r2.mask == 15 and len(r1) == 4
    np.testing.assert_array_equal(r1.qlo, qlo)
    np.testing.assert_array_equal(r1.qhi, qhi)
    with pytest.raises(ValueError):
        SearchRequest(ds.queries[:4], (qlo[:3], qhi[:3]), Overlaps())
    with pytest.raises(ValueError):
        SearchRequest(ds.queries[:4], (qhi, qlo), Overlaps())  # inverted
    with pytest.raises(ValueError):
        SearchRequest(ds.queries[0], (qlo[:1], qhi[:1]), Overlaps())  # 1-D
    with pytest.raises(ValueError):
        SearchRequest(ds.queries[:4], (qlo, qhi), Overlaps(), k=0)
    # a nested list of [qlo, qhi] ROWS is row-oriented even at Q=2 (only a
    # 2-tuple is read as the (qlo, qhi) pair form)
    rows = SearchRequest(ds.queries[:2], [[0.0, 1.0], [2.0, 3.0]], Overlaps())
    np.testing.assert_array_equal(rows.qlo, [0.0, 2.0])
    np.testing.assert_array_equal(rows.qhi, [1.0, 3.0])


# ---- SearchResult invariants + RouteReport ----

def test_search_result_invariants(small_ds, built_index):
    ds = small_ds
    eng = QueryEngine(built_index)
    qlo, qhi = make_queries(ds, 15, 0.15, seed=3)
    res = eng.search(SearchRequest(ds.queries, (qlo, qhi), Overlaps(), k=7))
    assert isinstance(res, SearchResult)
    assert len(res) == ds.queries.shape[0] and res.k == 7
    assert res.ids.shape == res.dists.shape == (len(res), 7)
    np.testing.assert_array_equal(res.valid_mask, res.ids >= 0)
    # invalid slots carry +inf distances, valid ones finite
    assert np.isinf(res.dists[~res.valid_mask]).all()
    assert np.isfinite(res.dists[res.valid_mask]).all()
    # per-query iteration yields QueryHit records, aligned with __getitem__
    hits = list(res)
    assert len(hits) == len(res)
    assert isinstance(hits[0], QueryHit)
    np.testing.assert_array_equal(hits[2].ids, res[2].ids)
    assert res[0].n_valid == int(res.valid_mask[0].sum())
    assert len(res[0]) == 2  # NamedTuple semantics: (ids, dists)
    # tuple interop + recall helpers
    ids, dists = res.astuple()
    assert ids is res.ids and dists is res.dists
    assert res.recall_vs(res) == 1.0
    assert res.recall_vs(res.ids) == 1.0
    # route/plan diagnostics
    rep = res.report
    assert rep.route in ("graph", "pruned") and rep.requested == "auto"
    assert rep.slot_count == len(rep.variants) >= 1
    assert rep.est_selectivity.shape == (len(res),)
    assert 0.0 <= rep.mean_selectivity <= 1.0
    assert rep.cache_hits + rep.cache_misses == len(res)


def test_search_result_shape_validation():
    with pytest.raises(ValueError):
        SearchResult(np.zeros((2, 3), np.int32), np.zeros((2, 4), np.float32))
    r = SearchResult(np.full((2, 3), -1, np.int32),
                     np.full((2, 3), np.inf, np.float32))
    assert not r.valid_mask.any() and r.recall_vs(r) == 0.0


def test_selectivity_cache_hits(small_ds, built_index):
    ds = small_ds
    eng = QueryEngine(built_index)
    qlo, qhi = make_queries(ds, 15, 0.2, seed=5)
    est1, h1, m1 = eng._estimate_cached(15, qlo, qhi)
    assert h1 == 0 and m1 == len(qlo)
    est2, h2, m2 = eng._estimate_cached(15, qlo, qhi)
    assert h2 == len(qlo) and m2 == 0
    np.testing.assert_array_equal(est1, est2)
    # distinct mask -> distinct cache entries
    _, h3, m3 = eng._estimate_cached(2, qlo, qhi)
    assert m3 == len(qlo)
    assert eng.sel_cache_hits == h2 and eng.sel_cache_misses == m1 + m3
    # flows into the report on auto-routed repeats
    req = SearchRequest(ds.queries, (qlo, qhi), Overlaps(), k=5)
    rep = eng.search(req).report
    assert rep.cache_hits == len(qlo) and rep.cache_misses == 0


# ---- IndexSpec lifecycle ----

def test_index_spec_build(small_ds):
    ds = small_ds
    spec = IndexSpec(predicate=QueryContaining(), m=8, ef_con=40)
    idx = MSTGIndex.build(spec, ds.vectors, ds.lo, ds.hi)
    assert set(idx.variants) == set(QueryContaining().variants_required())
    assert idx.spec.predicate == QueryContaining()
    assert idx.spec.m == 8 and idx.spec.ef_con == 40
    # round-trip through the persisted dict form
    assert IndexSpec.from_dict(idx.spec.to_dict()) == idx.spec


def test_index_save_load_bit_identical(tmp_path, small_ds, built_index):
    ds = small_ds
    path = built_index.save(os.path.join(tmp_path, "idx"))
    assert path.endswith(".npz") and os.path.exists(path)
    loaded = MSTGIndex.load(path)
    assert sorted(loaded.variants) == sorted(built_index.variants)
    assert loaded.spec == built_index.spec
    assert loaded.domain.K == built_index.domain.K
    np.testing.assert_array_equal(loaded.rl, built_index.rl)
    eng_a = QueryEngine(built_index)
    eng_b = QueryEngine(loaded)
    qlo, qhi = make_queries(ds, 15, 0.12, seed=9)
    for route in ("graph", "pruned", "flat"):
        req = SearchRequest(ds.queries, (qlo, qhi), Overlaps(), k=10, ef=64,
                            route=route)
        a = eng_a.search(req)
        b = eng_b.search(req)
        np.testing.assert_array_equal(a.ids, b.ids, err_msg=route)
        np.testing.assert_array_equal(a.dists, b.dists, err_msg=route)
        assert b.report.route == route


def test_index_load_rejects_non_index(tmp_path):
    from repro.checkpoint import index_io
    p = index_io.save_npz_atomic(os.path.join(tmp_path, "other"),
                                 {"x": np.arange(3)}, {"format": "other"})
    with pytest.raises(ValueError, match="not a mstg-index"):
        MSTGIndex.load(p)


# ---- tuple API removal ----

def test_tuple_search_api_is_removed(small_ds, built_index):
    """The tuple-era surface is gone: positional search args raise with a
    pointer to the migration guide, and the Searcher shims no longer exist."""
    import repro.core
    ds = small_ds
    eng = QueryEngine(built_index)
    qlo, qhi = make_queries(ds, 15, 0.15, seed=7)
    with pytest.raises(TypeError, match="SearchRequest"):
        eng.search(np.asarray(ds.queries))           # queries array, no request
    with pytest.raises(TypeError):
        eng.search(ds.queries, qlo, qhi, 15)         # old positional arity
    with pytest.raises(TypeError, match="on the SearchRequest"):
        # options alongside a request would be silently ignored — rejected
        eng.search(SearchRequest(ds.queries, (qlo, qhi), 15), k=100)
    assert not hasattr(repro.core, "MSTGSearcher")
    assert not hasattr(repro.core, "FlatSearcher")
    with pytest.raises(ImportError):
        from repro.core import MSTGSearcher  # noqa: F401
