"""Fast API-surface smoke check (not-slow CI lane): the declared public
surface of repro.core imports, __all__ is complete and resolvable, and the
core request/predicate types construct without touching an index."""
import numpy as np


def test_core_all_resolves():
    import repro.core as core
    assert core.__all__, "repro.core must declare __all__"
    missing = [name for name in core.__all__ if not hasattr(core, name)]
    assert not missing, f"__all__ names missing from repro.core: {missing}"
    # star-import view == __all__ (no stale or shadowed exports)
    ns = {}
    exec("from repro.core import *", ns)
    exported = {k for k in ns if not k.startswith("__")}
    assert exported == set(core.__all__)


def test_key_surface_types_construct():
    from repro.core import (Overlaps, Predicate, IndexSpec, QueryHit,
                            SearchRequest, SearchResult, parse_mask)
    req = SearchRequest(np.zeros((2, 4), np.float32),
                        (np.zeros(2), np.ones(2)), Overlaps(), k=3)
    assert len(req) == 2 and req.mask == 15
    res = SearchResult(np.full((2, 3), -1, np.int32),
                       np.full((2, 3), np.inf, np.float32))
    assert len(res) == 2 and not res.valid_mask.any()
    assert isinstance(res[0], QueryHit)
    assert parse_mask("any_overlap") == Predicate.parse("1|2|3|4").mask
    assert IndexSpec().predicate == Overlaps()


def test_engine_config_and_shard_report_construct():
    from repro.core import EngineConfig, ShardReport
    cfg = EngineConfig(route="pruned", sel_cache_max=16)
    assert cfg.route == "pruned"
    assert cfg.replace(route="auto").route == "auto"
    rep = ShardReport(shard=3, n=100, route="lost", alive=False)
    assert rep.shard == 3 and not rep.alive


def test_distributed_surface_imports():
    from repro.distributed import (DeploymentSpec, HeartbeatRegistry,
                                   MERGE_SCHEDULES, ShardedDeployment,
                                   resolve_merge, sharded_flat_topk,
                                   sharded_topk_merge)  # noqa: F401
    assert set(MERGE_SCHEDULES) == {"all_gather", "tournament"}
    assert resolve_merge("auto", 4) == "all_gather"
    assert resolve_merge("auto", 16) == "tournament"
    spec = DeploymentSpec(n_shards=4, per_shard_k=5)
    assert spec.replace(merge="tournament").merge == "tournament"


def test_serving_and_checkpoint_surface_imports():
    from repro.serving import RetrievalServer, ServeEngine  # noqa: F401
    from repro.checkpoint import IndexIOError, index_io
    assert callable(index_io.save_npz_atomic) and callable(index_io.load_npz)
    assert issubclass(IndexIOError, ValueError)


def test_streaming_surface_imports():
    import repro.streaming as streaming
    missing = [n for n in streaming.__all__ if not hasattr(streaming, n)]
    assert not missing
    from repro.core import SegmentReport
    from repro.streaming import CompactionPolicy, SegmentedIndex
    assert CompactionPolicy().pick([]) == []
    s = SegmentedIndex()
    assert len(s) == 0 and 0 not in s and s.stats()["segments"] == []
    assert SegmentReport("delta", 0, "delta", 0).tombstones == 0
