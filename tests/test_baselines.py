"""Baselines return valid, predicate-satisfying results with sane recall."""
import numpy as np
import pytest

from repro.core import ANY_OVERLAP, intervals as iv
from repro.core.baselines import (Prefiltering, Postfiltering, AcornLike,
                                  IRangeGraphLike, TSGraphLike, HiPNGLike)
from repro.data import make_range_dataset, make_queries, brute_force_topk, recall_at_k


@pytest.fixture(scope="module")
def ds():
    return make_range_dataset(n=500, d=16, n_queries=10, quantize=32, seed=9)


def test_prefiltering_exact(ds):
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.2, seed=1)
    tids, tds = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                 qlo, qhi, ANY_OVERLAP, 10)
    b = Prefiltering(ds.vectors, ds.lo, ds.hi)
    ids, d = b.search(ds.queries, qlo, qhi, ANY_OVERLAP, k=10)
    assert recall_at_k(ids, tids) == 1.0
    assert b.last_dist_evals > 0


@pytest.mark.parametrize("cls,kw", [(Postfiltering, {}), (AcornLike, {})])
def test_graph_baselines_recall_and_validity(ds, cls, kw):
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.3, seed=2)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                               qlo, qhi, ANY_OVERLAP, 10)
    b = cls(ds.vectors, ds.lo, ds.hi, m=8, ef_con=40, **kw)
    ids, d = b.search(ds.queries, qlo, qhi, ANY_OVERLAP, k=10, ef=80)
    assert recall_at_k(ids, tids) >= 0.55  # baselines are *worse*, not broken
    for qi in range(ids.shape[0]):
        got = ids[qi][ids[qi] >= 0]
        sel = np.asarray(iv.eval_predicate(ANY_OVERLAP, ds.lo[got], ds.hi[got],
                                           qlo[qi], qhi[qi]))
        assert sel.all()
    assert b.index_bytes() > 0


def test_irangegraph_rfann(ds):
    attr = (ds.lo + ds.hi) / 2
    b = IRangeGraphLike(ds.vectors, attr, m=8, ef_con=40)
    qlo = np.quantile(attr, 0.2) * np.ones(10)
    qhi = np.quantile(attr, 0.6) * np.ones(10)
    tids, _ = brute_force_topk(ds.vectors, attr, attr, ds.queries,
                               qlo, qhi, iv.RFANN_MASK, 10)
    ids, d = b.search(ds.queries, qlo, qhi, k=10, ef=64)
    assert recall_at_k(ids, tids) >= 0.85
    for qi in range(ids.shape[0]):
        got = ids[qi][ids[qi] >= 0]
        assert ((attr[got] >= qlo[qi]) & (attr[got] <= qhi[qi])).all()


def test_tsgraph_tsann(ds):
    b = TSGraphLike(ds.vectors, ds.lo, ds.hi, n_buckets=8, m=8, ef_con=40)
    t = float(np.median((ds.lo + ds.hi) / 2))
    qlo = np.full(10, t)
    qhi = np.full(10, t)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                               qlo, qhi, iv.TSANN_MASK, 10)
    ids, _ = b.search(ds.queries, qlo, qhi, k=10, ef=64)
    assert recall_at_k(ids, tids) >= 0.6
    for qi in range(ids.shape[0]):
        got = ids[qi][ids[qi] >= 0]
        assert ((ds.lo[got] <= t) & (ds.hi[got] >= t)).all()


def test_hipng_ifann(ds):
    b = HiPNGLike(ds.vectors, ds.lo, ds.hi, leaf_size=48, m=8, ef_con=40)
    qlo, qhi = make_queries(ds, iv.IFANN_MASK, 0.25, seed=3)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                               qlo, qhi, iv.IFANN_MASK, 10)
    ids, _ = b.search(ds.queries, qlo, qhi, k=10, ef=80)
    assert recall_at_k(ids, tids) >= 0.6
    for qi in range(ids.shape[0]):
        got = ids[qi][ids[qi] >= 0]
        assert ((ds.lo[got] >= qlo[qi]) & (ds.hi[got] <= qhi[qi])).all()
