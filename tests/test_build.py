"""Builder equivalence: the bulk construction path vs the incremental
oracle — frozen schema parity, bit-identical save/load round trips, recall
parity across the 8-mask x 3-route engine grid, and the batched RNG-prune
primitive against the sequential reference."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (ANY_OVERLAP, IndexSpec, MSTGIndex, QueryEngine,
                        SearchRequest, intervals as iv)
from repro.core.build import pairwise_sq, rng_prune_batch
from repro.core.hnsw import rng_prune
from repro.data import make_range_dataset, make_queries, brute_force_topk

MASKS = [
    iv.ANY_OVERLAP,
    iv.QUERY_CONTAINED,
    iv.QUERY_CONTAINING,
    iv.LEFT_OVERLAP,
    iv.RIGHT_OVERLAP,
    iv.LEFT_OVERLAP | iv.RIGHT_OVERLAP,
    iv.QUERY_CONTAINED | iv.QUERY_CONTAINING,
    iv.LEFT_OVERLAP | iv.QUERY_CONTAINED | iv.RIGHT_OVERLAP,
]
ROUTES = ("graph", "pruned", "flat")

# the adjacency fields' slot axis (S) is builder-dependent (deferred bulk
# re-pruning logs a superset of the incremental labels); everything else
# must be bit-identical between builders
_ADJ_FIELDS = ("nbr", "lab_b", "lab_e")
_EXACT_FIELDS = ("sort_rank", "tkey", "entry_ids", "entry_ver", "members",
                 "member_ver", "node_off")


@pytest.fixture(scope="module")
def ds():
    return make_range_dataset(n=400, d=16, n_queries=10, quantize=32, seed=3)


@pytest.fixture(scope="module")
def pair(ds):
    kw = dict(variants=("T", "Tp", "Tpp"), m=8, ef_con=48)
    return (MSTGIndex(ds.vectors, ds.lo, ds.hi, builder="bulk", **kw),
            MSTGIndex(ds.vectors, ds.lo, ds.hi, builder="incremental", **kw))


def test_builder_knob_round_trips(pair):
    bulk, inc = pair
    assert bulk.spec.builder == "bulk" and inc.spec.builder == "incremental"
    assert IndexSpec.from_dict(bulk.spec.to_dict()) == bulk.spec
    # specs persisted before the builder field existed load as bulk
    legacy = {k: v for k, v in inc.spec.to_dict().items()
              if k not in ("builder", "batch_size")}
    assert IndexSpec.from_dict(legacy).builder == "bulk"
    with pytest.raises(ValueError):
        IndexSpec(builder="nope")
    with pytest.raises(ValueError):
        IndexSpec(batch_size=0)


def test_frozen_schema_parity(pair):
    """Same fields, dtypes, and shapes (the slot axis S may differ); the
    version/membership bookkeeping must be bit-identical."""
    bulk, inc = pair
    for name in bulk.variants:
        fb, fi = bulk.variants[name], inc.variants[name]
        assert (fb.K, fb.Kpad, fb.Lv, fb.n) == (fi.K, fi.Kpad, fi.Lv, fi.n)
        for field in _EXACT_FIELDS:
            a, b = getattr(fb, field), getattr(fi, field)
            assert a.dtype == b.dtype, field
            np.testing.assert_array_equal(a, b, err_msg=f"{name}.{field}")
        for field in _ADJ_FIELDS:
            a, b = getattr(fb, field), getattr(fi, field)
            assert a.dtype == b.dtype, field
            assert a.shape[:2] == b.shape[:2] == (fb.Lv, fb.n), field
        assert fb.live_edges() > 0


def test_save_load_bit_identical_both_builders(pair, tmp_path):
    for idx in pair:
        path = str(tmp_path / f"{idx.spec.builder}.npz")
        idx.save(path)
        loaded = MSTGIndex.load(path)
        assert loaded.spec == idx.spec
        for name, fv in idx.variants.items():
            lv = loaded.variants[name]
            for field in _EXACT_FIELDS + _ADJ_FIELDS:
                np.testing.assert_array_equal(getattr(fv, field),
                                              getattr(lv, field))


@pytest.mark.parametrize("mask", MASKS, ids=iv.mask_name)
def test_recall_parity_all_masks_all_routes(ds, pair, mask):
    """recall@10 parity (+-0) on the 8-mask x 3-route grid: both builders
    hit full recall at this scale, and the exact routes are identical."""
    bulk, inc = pair
    qlo, qhi = make_queries(ds, mask, 0.15, seed=5)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                               qlo, qhi, mask, 10)
    eb, ei = QueryEngine(bulk), QueryEngine(inc)
    for route in ROUTES:
        req = SearchRequest(ds.queries, (qlo, qhi), mask, k=10, ef=96,
                            route=route)
        rb, ri = eb.search(req), ei.search(req)
        assert rb.recall_vs(tids) == ri.recall_vs(tids) == 1.0, \
            (iv.mask_name(mask), route)


def test_graph_route_never_returns_nonqualifying(ds, pair):
    """The paper's core guarantee holds for the bulk-built graph too."""
    bulk, _ = pair
    eng = QueryEngine(bulk)
    for mask in MASKS:
        qlo, qhi = make_queries(ds, mask, 0.1, seed=13)
        res = eng.search(SearchRequest(ds.queries, (qlo, qhi), mask, k=10,
                                       ef=32, route="graph"))
        for qi, hit in enumerate(res):
            got = hit.ids[hit.valid]
            sel = np.asarray(iv.eval_predicate(mask, ds.lo[got], ds.hi[got],
                                               qlo[qi], qhi[qi]))
            assert sel.all(), iv.mask_name(mask)


def test_bulk_build_is_deterministic(ds):
    kw = dict(variants=("T",), m=8, ef_con=40)
    a = MSTGIndex(ds.vectors, ds.lo, ds.hi, **kw)
    b = MSTGIndex(ds.vectors, ds.lo, ds.hi, **kw)
    fa, fb = a.variants["T"], b.variants["T"]
    for field in _EXACT_FIELDS + _ADJ_FIELDS:
        np.testing.assert_array_equal(getattr(fa, field), getattr(fb, field))


def test_batch_size_only_perturbs_adjacency(ds):
    """The batch knob changes re-pruning boundaries, never the schema or
    version/membership arrays — and any batch size keeps full recall."""
    kw = dict(variants=("T", "Tp"), m=8, ef_con=40)
    big = MSTGIndex(ds.vectors, ds.lo, ds.hi, batch_size=1024, **kw)
    small = MSTGIndex(ds.vectors, ds.lo, ds.hi, batch_size=16, **kw)
    for field in _EXACT_FIELDS:
        np.testing.assert_array_equal(getattr(big.variants["T"], field),
                                      getattr(small.variants["T"], field))
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.15, seed=5)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                               qlo, qhi, ANY_OVERLAP, 10)
    for idx in (big, small):
        res = QueryEngine(idx).search(SearchRequest(
            ds.queries, (qlo, qhi), ANY_OVERLAP, k=10, ef=96, route="graph"))
        assert res.recall_vs(tids) == 1.0


# ---- coarse candidate stage (sub-quadratic builds) ----

COARSE_KW = dict(variants=("T", "Tp", "Tpp"), m=8, ef_con=48,
                 candidate_stage="coarse", coarse_threshold=100)


@pytest.fixture(scope="module")
def coarse_idx(ds):
    """Coarse-stage build with the threshold lowered so the quantizer
    actually engages at test scale (default threshold > n here)."""
    return MSTGIndex(ds.vectors, ds.lo, ds.hi, **COARSE_KW)


@pytest.mark.parametrize("mask", MASKS, ids=iv.mask_name)
def test_coarse_recall_parity_all_masks_all_routes(ds, coarse_idx, mask):
    """The coarse candidate stage keeps full recall on the same 8-mask x
    3-route grid the exact stage is held to."""
    qlo, qhi = make_queries(ds, mask, 0.15, seed=5)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                               qlo, qhi, mask, 10)
    eng = QueryEngine(coarse_idx)
    for route in ROUTES:
        res = eng.search(SearchRequest(ds.queries, (qlo, qhi), mask, k=10,
                                       ef=96, route=route))
        assert res.recall_vs(tids) == 1.0, (iv.mask_name(mask), route)


def test_coarse_build_is_deterministic(ds):
    a = MSTGIndex(ds.vectors, ds.lo, ds.hi, **COARSE_KW)
    b = MSTGIndex(ds.vectors, ds.lo, ds.hi, **COARSE_KW)
    for name in a.variants:
        fa, fb = a.variants[name], b.variants[name]
        for field in _EXACT_FIELDS + _ADJ_FIELDS:
            np.testing.assert_array_equal(getattr(fa, field),
                                          getattr(fb, field),
                                          err_msg=f"{name}.{field}")


def test_coarse_threshold_fallback_bit_identical(ds):
    """Batches below ``coarse_threshold`` run the literal exact code path,
    so a threshold at or above n makes candidate_stage="coarse" produce a
    bit-identical index to the exact stage."""
    kw = dict(variants=("T",), m=8, ef_con=40)
    exact = MSTGIndex(ds.vectors, ds.lo, ds.hi, candidate_stage="exact",
                      **kw)
    gated = MSTGIndex(ds.vectors, ds.lo, ds.hi, candidate_stage="coarse",
                      coarse_threshold=ds.vectors.shape[0], **kw)
    for field in _EXACT_FIELDS + _ADJ_FIELDS:
        np.testing.assert_array_equal(getattr(exact.variants["T"], field),
                                      getattr(gated.variants["T"], field),
                                      err_msg=field)


def test_candidate_stage_spec_round_trip(ds, coarse_idx, tmp_path):
    """The candidate-stage knobs ride IndexSpec through to_dict/from_dict
    and save/load; artifacts from before the knobs existed load as the
    exact stage."""
    spec = coarse_idx.spec
    assert spec.candidate_stage == "coarse"
    assert spec.coarse_threshold == 100
    assert IndexSpec.from_dict(spec.to_dict()) == spec
    legacy = {k: v for k, v in spec.to_dict().items()
              if k not in ("candidate_stage", "n_clusters", "n_probe",
                           "coarse_threshold")}
    pre = IndexSpec.from_dict(legacy)
    assert pre.candidate_stage == "exact" and pre.n_clusters is None
    path = str(tmp_path / "coarse.npz")
    coarse_idx.save(path)
    loaded = MSTGIndex.load(path)
    assert loaded.spec == spec
    for name, fv in coarse_idx.variants.items():
        for field in _EXACT_FIELDS + _ADJ_FIELDS:
            np.testing.assert_array_equal(getattr(fv, field),
                                          getattr(loaded.variants[name],
                                                  field))
    with pytest.raises(ValueError):
        IndexSpec(candidate_stage="nope")
    with pytest.raises(ValueError):
        IndexSpec(n_clusters=0)
    with pytest.raises(ValueError):
        IndexSpec(n_probe=0)
    with pytest.raises(ValueError):
        IndexSpec(coarse_threshold=0)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 32 - 1), st.integers(2, 40), st.integers(1, 12))
def test_rng_prune_batch_matches_sequential(seed, n_cand, m):
    """Property: the batched suppression formulation == the incremental
    builder's sequential scan, row for row."""
    rng = np.random.default_rng(seed)
    # integer-valued vectors: both distance formulations (direct difference
    # vs dot identity) are exact in float32, so strict-< tie behavior is
    # identical and the property is deterministic
    vectors = rng.integers(-8, 9, (64, 8)).astype(np.float32)
    base = int(rng.integers(0, 64))
    cand = rng.choice([i for i in range(64) if i != base], size=n_cand,
                      replace=False).astype(np.int64)
    d = pairwise_sq(vectors[base][None], vectors[cand])[0]
    order = np.argsort(d, kind="stable")
    cand, d = cand[order], d[order]
    want = rng_prune(vectors, base, cand, d, m)
    got = rng_prune_batch(vectors, cand[None], d[None], m)[0]
    assert [int(c) for c in got if c >= 0] == want
