"""Checkpointing: atomicity, async, restore equality, elastic resharding,
and the kill/resume fault-tolerance contract (bitwise resume)."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint import Checkpointer
from repro.data import TokenLoader
from repro.models.transformer import LM
from repro.training import AdamWConfig, adamw_init, make_train_step


def _state(seed=0):
    k = jax.random.key(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "nested": {"b": jnp.arange(5.0)}},
            "step": jnp.asarray(7, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), async_write=False)
    st = _state()
    ck.save(3, st, extra={"cursor": 42})
    got, step, extra = ck.restore(st)
    assert step == 3 and extra["cursor"] == 42
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_write_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_write=True)
    st = _state()
    for s in (1, 2, 3, 4):
        ck.save(s, st)
    ck.wait()
    assert ck.list_steps() == [3, 4]


def test_atomicity_no_tmp_left(tmp_path):
    ck = Checkpointer(str(tmp_path), async_write=False)
    ck.save(1, _state())
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_elastic_restore_to_sharding(tmp_path):
    """Restore onto a (1-device) mesh sharding — the elastic path."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    ck = Checkpointer(str(tmp_path), async_write=False)
    st = _state()
    ck.save(1, st)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    sh = NamedSharding(mesh, P())
    got, _, _ = ck.restore(st, shardings=sh)
    assert got["params"]["w"].sharding == sh


def test_kill_and_resume_bitwise(tmp_path):
    """Train 8 steps straight vs train 4 + 'crash' + restore + 4: identical."""
    cfg = configs.get_smoke_config("olmo-1b").scaled(n_layers=2, vocab=64)
    lm = LM(cfg)
    loader = TokenLoader(vocab=cfg.vocab, batch=4, seq_len=32, seed=3)
    step = make_train_step(lm, opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=4))

    p = lm.init(jax.random.key(1))
    o = adamw_init(p)
    for i in range(8):
        p, o, _ = step(p, o, loader.batch_at(i))
    ref = p

    ck = Checkpointer(str(tmp_path), async_write=False)
    p = lm.init(jax.random.key(1))
    o = adamw_init(p)
    for i in range(4):
        p, o, _ = step(p, o, loader.batch_at(i))
    ck.save(4, {"params": p, "opt": o})
    del p, o  # the crash

    st, start, _ = ck.restore({"params": lm.init(jax.random.key(1)),
                               "opt": adamw_init(lm.init(jax.random.key(1)))})
    p, o = st["params"], st["opt"]
    for i in range(start, 8):
        p, o, _ = step(p, o, loader.batch_at(i))
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_index_io_corrupt_and_truncated_raise_index_io_error(tmp_path):
    """Truncated/corrupt .npz artifacts surface as IndexIOError (a ValueError
    subclass), never a bare zipfile/KeyError."""
    from repro.checkpoint import IndexIOError, index_io
    p = index_io.save_npz_atomic(str(tmp_path / "good"),
                                 {"x": np.arange(64)}, {"format": "t"})
    arrays, meta = index_io.load_npz(p)
    np.testing.assert_array_equal(arrays["x"], np.arange(64))
    # truncation
    blob = open(p, "rb").read()
    trunc = str(tmp_path / "trunc.npz")
    with open(trunc, "wb") as f:
        f.write(blob[:len(blob) // 2])
    with pytest.raises(IndexIOError, match="corrupt or truncated"):
        index_io.load_npz(trunc)
    # garbage bytes
    garb = str(tmp_path / "garbage.npz")
    with open(garb, "wb") as f:
        f.write(b"these are not the arrays you are looking for")
    with pytest.raises(IndexIOError):
        index_io.load_npz(garb)
    # missing file
    with pytest.raises(IndexIOError, match="no such index artifact"):
        index_io.load_npz(str(tmp_path / "never_saved"))
    # missing required key -> IndexIOError naming the key, not KeyError
    with pytest.raises(IndexIOError, match="missing required array 'y'"):
        index_io.take(arrays, "y", p)
    assert isinstance(IndexIOError("x"), ValueError)


def test_index_io_missing_key_via_mstg_load(tmp_path):
    """An index artifact with a missing array names the key in the error."""
    from repro.checkpoint import IndexIOError, index_io
    from repro.core import MSTGIndex
    p = index_io.save_npz_atomic(
        str(tmp_path / "hollow"), {"lo": np.zeros(3)},
        {"format": "mstg-index", "variants": {}})
    with pytest.raises(IndexIOError, match="vectors"):
        MSTGIndex.load(p)


def test_index_io_partial_write_never_clobbers(tmp_path, monkeypatch):
    """A failing save leaves the previous good artifact byte-identical and
    no .tmp litter behind."""
    from repro.checkpoint import index_io
    p = index_io.save_npz_atomic(str(tmp_path / "idx"),
                                 {"x": np.arange(10)}, {"v": 1})
    good = open(p, "rb").read()

    def boom(*a, **kw):
        raise OSError("disk full")

    monkeypatch.setattr(np, "savez", boom)
    with pytest.raises(OSError):
        index_io.save_npz_atomic(p, {"x": np.arange(99)}, {"v": 2})
    monkeypatch.undo()
    assert open(p, "rb").read() == good
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
    arrays, meta = index_io.load_npz(p)
    assert meta == {"v": 1}


def test_index_io_manifest_failure_paths(tmp_path):
    from repro.checkpoint import IndexIOError, index_io
    with pytest.raises(IndexIOError, match="no such manifest"):
        index_io.load_manifest(str(tmp_path))
    index_io.save_manifest_atomic(str(tmp_path), {"format": "t", "n": 1})
    assert index_io.load_manifest(str(tmp_path)) == {"format": "t", "n": 1}
    with open(tmp_path / "manifest.json", "w") as f:
        f.write("{not json")
    with pytest.raises(IndexIOError, match="corrupt manifest"):
        index_io.load_manifest(str(tmp_path))


def test_heartbeat_registry():
    from repro.distributed.fault import HeartbeatRegistry
    hb = HeartbeatRegistry(timeout_s=10)
    hb.ping("w0", 5, now=100.0)
    hb.ping("w1", 5, now=100.0)
    assert hb.dead_workers(now=105.0) == []
    hb.ping("w0", 6, now=112.0)
    assert hb.dead_workers(now=115.0) == ["w1"]
    assert hb.should_restart(now=115.0)


def test_straggler_watchdog():
    from repro.training import StragglerWatchdog
    wd = StragglerWatchdog(factor=3.0)
    flagged = [wd.observe(i, 0.1) for i in range(10)]
    assert not any(flagged)
    assert wd.observe(10, 1.0)
    assert wd.events and wd.events[0][0] == 10
