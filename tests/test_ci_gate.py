"""The scheduled-lane perf gate: skip rules and the regression verdict."""
import json
import subprocess
import sys


def _run_gate(tmp_path, records, tolerance=0.2, field="graph_qps",
              direction=None):
    hist = tmp_path / "hist.jsonl"
    hist.write_text("".join(json.dumps(r) + "\n" for r in records))
    cmd = [sys.executable, "-m", "benchmarks.ci_gate", "--history", str(hist),
           "--field", field, "--tolerance", str(tolerance)]
    if direction:
        cmd += ["--direction", direction]
    return subprocess.run(cmd, capture_output=True, text=True)


def test_gate_skips_empty_and_prefield_history(tmp_path):
    assert _run_gate(tmp_path, []).returncode == 0
    assert _run_gate(tmp_path, [{"commit": "a"}]).returncode == 0
    # only one record carries the field -> skip
    r = _run_gate(tmp_path, [{"commit": "a"},
                             {"commit": "b", "graph_qps": 500,
                              "platform": "p1"}])
    assert r.returncode == 0 and "skipping" in r.stdout


def test_gate_skips_cross_platform_comparisons(tmp_path):
    """QPS is not comparable across machines: a cache-miss run whose only
    prior record came from a different box must skip, not fail."""
    r = _run_gate(tmp_path, [
        {"commit": "a", "graph_qps": 1000, "platform": "laptop"},
        {"commit": "b", "graph_qps": 300, "platform": "ci-runner"}])
    assert r.returncode == 0 and "platform" in r.stdout


def test_gate_passes_within_tolerance_and_fails_beyond(tmp_path):
    ok = _run_gate(tmp_path, [
        {"commit": "a", "graph_qps": 1000, "platform": "p"},
        {"commit": "b", "graph_qps": 850, "platform": "p"}])
    assert ok.returncode == 0 and "OK" in ok.stdout
    bad = _run_gate(tmp_path, [
        {"commit": "a", "graph_qps": 1000, "platform": "p"},
        {"commit": "b", "graph_qps": 700, "platform": "p"}])
    assert bad.returncode == 1 and "REGRESSION" in bad.stdout
    # comparison skips interleaved records from other machines
    mixed = _run_gate(tmp_path, [
        {"commit": "a", "graph_qps": 1000, "platform": "p"},
        {"commit": "x", "graph_qps": 10, "platform": "other"},
        {"commit": "b", "graph_qps": 900, "platform": "p"}])
    assert mixed.returncode == 0


def test_gate_direction_min_lower_is_better(tmp_path):
    """build_seconds-style metrics: baseline is the window *minimum* and the
    gate fails when the new value rises beyond tolerance."""
    def rec(commit, secs, platform="p"):
        return {"commit": commit, "build_seconds": secs, "platform": platform}

    ok = _run_gate(tmp_path, [rec("a", 10.0), rec("b", 11.0)],
                   field="build_seconds", direction="min")
    assert ok.returncode == 0 and "OK" in ok.stdout
    bad = _run_gate(tmp_path, [rec("a", 10.0), rec("b", 13.0)],
                    field="build_seconds", direction="min")
    assert bad.returncode == 1 and "REGRESSION" in bad.stdout
    # a faster-than-ever run obviously passes
    fast = _run_gate(tmp_path, [rec("a", 10.0), rec("b", 4.0)],
                     field="build_seconds", direction="min")
    assert fast.returncode == 0
    # same-platform-only and skip rules apply unchanged
    cross = _run_gate(tmp_path, [rec("a", 10.0, "laptop"), rec("b", 99.0)],
                      field="build_seconds", direction="min")
    assert cross.returncode == 0 and "platform" in cross.stdout


def test_gate_direction_min_anchors_on_window_best(tmp_path):
    """The min-direction baseline is the *fastest* of the window, so slow
    creep trips once cumulative slowdown crosses the tolerance."""
    slide = [{"commit": f"c{i}", "build_seconds": 10.0 * (1.15 ** i),
              "platform": "p"} for i in range(4)]  # 10, 11.5, 13.2, 15.2
    r = _run_gate(tmp_path, slide, field="build_seconds", direction="min")
    assert r.returncode == 1 and "REGRESSION" in r.stdout


def test_gate_baseline_cannot_ratchet_down(tmp_path):
    """Sub-tolerance regressions must not compound: the gate anchors on the
    best of the window, so a 15%-per-run slide trips once cumulative drop
    crosses the tolerance."""
    slide = [{"commit": f"c{i}", "graph_qps": 1000 * (0.85 ** i),
              "platform": "p"} for i in range(4)]  # 1000, 850, 722.5, 614.1
    r = _run_gate(tmp_path, slide)
    assert r.returncode == 1 and "REGRESSION" in r.stdout
