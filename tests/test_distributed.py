"""Distributed serving: ShardedDeployment fan-out/merge/fault semantics on
the host path inline; the in-process device-merge tests (bit-parity between
schedules, sharded-vs-single parity grid) skip below 8 devices and run in
CI's ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` lane; an 8-device
subprocess covers the fused kernel when the parent owns only one device."""
import os
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import (ANY_OVERLAP, EngineConfig, IndexSpec, QueryEngine,
                        SearchRequest)
from repro.core.hnsw import NO_EDGE
from repro.distributed import (DeploymentSpec, ShardedDeployment,
                               sharded_flat_topk)
from repro.data import make_range_dataset, make_queries, brute_force_topk, recall_at_k

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _mesh8():
    from repro.launch.mesh import make_mesh
    return make_mesh((8,), ("data",))


def test_sharded_flat_single_device(small_ds):
    ds = small_ds
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.2, seed=5)
    # corpus size must divide the shard count (1) — always true
    ids, d = sharded_flat_topk(mesh, jnp.asarray(ds.vectors),
                               jnp.asarray(ds.lo, jnp.float32),
                               jnp.asarray(ds.hi, jnp.float32),
                               jnp.asarray(ds.queries),
                               jnp.asarray(qlo, jnp.float32),
                               jnp.asarray(qhi, jnp.float32),
                               mask=ANY_OVERLAP, k=10)
    tids, tds = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                 qlo, qhi, ANY_OVERLAP, 10)
    np.testing.assert_allclose(np.sort(np.asarray(d), 1), np.sort(tds, 1),
                               rtol=1e-4, atol=1e-4)


# ---- host path: fan-out/merge/fault semantics, no mesh required ----

def test_deployment_host_merge_matches_single_engine(small_ds, built_index):
    """4 exact shards merged on host == the single-device exact answer."""
    ds = small_ds
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.2, seed=5)
    req = SearchRequest(ds.queries, (qlo, qhi), ANY_OVERLAP, k=10)
    single = QueryEngine(built_index).search(
        SearchRequest(ds.queries, (qlo, qhi), ANY_OVERLAP, k=10, route="flat"))
    dep = ShardedDeployment.flat(ds.vectors, ds.lo, ds.hi,
                                 spec=DeploymentSpec(n_shards=4))
    res = dep.execute(req)
    assert res.report.route == "sharded" and res.report.merge == "host"
    assert len(res.report.shards) == 4 and not res.degraded
    np.testing.assert_allclose(np.sort(res.dists, 1), np.sort(single.dists, 1),
                               rtol=1e-4, atol=1e-4)
    assert res.recall_vs(single) == 1.0


def test_shard_loss_degrades_never_raises(small_ds):
    """A failed shard yields a flagged degraded answer with sentinel rows
    from its range — and restore() heals it."""
    ds = small_ds
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.3, seed=6)
    req = SearchRequest(ds.queries, (qlo, qhi), ANY_OVERLAP, k=10)
    dep = ShardedDeployment.flat(ds.vectors, ds.lo, ds.hi,
                                 spec=DeploymentSpec(n_shards=4))
    nloc = ds.vectors.shape[0] // 4
    full = dep.execute(req)
    dep.fail(2)
    res = dep.execute(req)
    assert res.degraded and res.report.missing_shards == (2,)
    rep = res.report.shards[2]
    assert rep.shard == 2 and not rep.alive and rep.route == "lost"
    assert rep.k_fetched == 0
    assert all(r.alive for i, r in enumerate(res.report.shards) if i != 2)
    # nothing from the lost shard's row range leaks into the answer
    got = res.ids[res.ids >= 0]
    assert not ((got >= 2 * nloc) & (got < 3 * nloc)).any()
    dep.restore(2)
    healed = dep.execute(req)
    assert not healed.degraded
    np.testing.assert_array_equal(healed.ids, full.ids)


def test_shard_exception_and_heartbeat_timeout_flagged(small_ds):
    ds = small_ds
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.3, seed=7)
    req = SearchRequest(ds.queries, (qlo, qhi), ANY_OVERLAP, k=5)
    # a shard raising mid-search is reported as route="error", not re-raised
    dep = ShardedDeployment.flat(ds.vectors, ds.lo, ds.hi,
                                 spec=DeploymentSpec(n_shards=3))
    dep.shards[1].engine = object()          # .execute() -> AttributeError
    res = dep.execute(req)
    assert res.degraded and res.report.missing_shards == (1,)
    assert res.report.shards[1].route == "error"
    assert not res.report.shards[1].alive
    # heartbeat staleness past shard_timeout_s counts every shard as lost
    dep2 = ShardedDeployment.flat(
        ds.vectors, ds.lo, ds.hi,
        spec=DeploymentSpec(n_shards=2, shard_timeout_s=0.005))
    time.sleep(0.02)
    stale = dep2.execute(req)
    assert stale.degraded and stale.report.missing_shards == (0, 1)
    assert not stale.valid_mask.any()
    for i in range(2):
        dep2.restore(i)                      # restore pings the heartbeat
    assert not dep2.execute(req).degraded


def test_per_shard_k_narrowing_and_padding(small_ds):
    """D*k' < k pads the merged answer with sentinel columns instead of
    inventing candidates; k' == k stays exact."""
    ds = small_ds
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.3, seed=8)
    req = SearchRequest(ds.queries, (qlo, qhi), ANY_OVERLAP, k=10)
    dep = ShardedDeployment.flat(ds.vectors, ds.lo, ds.hi,
                                 spec=DeploymentSpec(n_shards=4,
                                                     per_shard_k=1))
    res = dep.execute(req)                   # union of 4 candidates, k=10
    assert (res.ids[:, 4:] == NO_EDGE).all()
    assert np.isinf(res.dists[:, 4:]).all()
    assert all(r.k_fetched == 1 for r in res.report.shards)
    assert (res.valid_mask.sum(1) <= 4).all()
    # the merged prefix is sorted and the global best survives narrowing:
    # every shard forwards its local minimum, so the true rank-1 id is there
    exact = ShardedDeployment.flat(ds.vectors, ds.lo, ds.hi,
                                   spec=DeploymentSpec(n_shards=4))
    eres = exact.execute(req)
    np.testing.assert_array_equal(res.ids[:, 0], eres.ids[:, 0])
    assert (np.diff(res.dists[:, :4], axis=1) >= 0).all()


def test_from_segmented_matches_direct_search(small_ds):
    """Sharding a SegmentedIndex round-robin must not change exact-route
    answers (segments are shared, ids are external either way)."""
    from repro.streaming import SegmentedIndex
    ds = small_ds
    n = 400
    spec = IndexSpec(variants=("T", "Tp"), m=8, ef_con=40)
    seg = SegmentedIndex(spec)
    ids = np.arange(n)
    seg.add(ids[:200], ds.vectors[:200], ds.lo[:200], ds.hi[:200])
    seg.flush()
    seg.add(ids[200:], ds.vectors[200:n], ds.lo[200:n], ds.hi[200:n])
    seg.flush()
    seg.delete(np.arange(20, 40))
    dep = ShardedDeployment.from_segmented(
        seg, spec=DeploymentSpec(n_shards=2))
    assert sum(s.n for s in dep.shards) == len(seg)
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.25, seed=9)
    req = SearchRequest(ds.queries, (qlo, qhi), ANY_OVERLAP, k=8,
                        route="pruned")
    a = seg.search(req)
    b = dep.execute(req)
    np.testing.assert_allclose(np.sort(a.dists, 1), np.sort(b.dists, 1),
                               rtol=1e-4, atol=1e-4)
    assert b.recall_vs(a) == 1.0


def test_parallel_build_matches_serial(small_ds):
    """build_workers is an execution resource: pooled and serial builds
    produce deployments that answer identically, and both carry a
    build_report (pool size, wall seconds, per-shard seconds, rows/sec).
    On platforms where the spawn pool is unavailable the pooled spec
    degrades to the serial path — the assertions hold either way."""
    ds = small_ds
    ispec = IndexSpec(variants=("T",), m=8, ef_con=32)
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.2, seed=7)
    req = SearchRequest(ds.queries, (qlo, qhi), ANY_OVERLAP, k=10)
    deps = {}
    for w in (0, 2):
        spec = DeploymentSpec(n_shards=4, index=ispec, build_workers=w)
        deps[w] = ShardedDeployment.build(ds.vectors, ds.lo, ds.hi,
                                          spec=spec)
        br = deps[w].build_report
        assert set(br) == {"pool_size", "wall_s", "shard_seconds",
                           "rows_per_sec"}
        assert len(br["shard_seconds"]) == 4
        assert br["rows_per_sec"] > 0
    assert deps[0].build_report["pool_size"] == 0
    a, b = deps[0].execute(req), deps[2].execute(req)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))


def test_deployment_spec_validation(small_ds):
    ds = small_ds
    with pytest.raises(ValueError):
        DeploymentSpec(n_shards=0)
    with pytest.raises(ValueError):
        DeploymentSpec(build_workers=-1)
    with pytest.raises(ValueError):
        DeploymentSpec(merge="bogus")
    with pytest.raises(ValueError):
        DeploymentSpec(per_shard_k=-1)
    with pytest.raises(TypeError):
        DeploymentSpec(engine={"route": "flat"})
    with pytest.raises(ValueError, match="divisible"):
        ShardedDeployment.flat(ds.vectors, ds.lo, ds.hi,
                               spec=DeploymentSpec(n_shards=7))  # 600 % 7
    dep = ShardedDeployment.flat(ds.vectors, ds.lo, ds.hi,
                                 spec=DeploymentSpec(n_shards=2))
    with pytest.raises(TypeError, match="SearchRequest"):
        dep.execute(ds.queries)


# ---- device merges: run under the 8-virtual-device CPU lane ----

@needs8
def test_merge_schedules_bit_parity_8dev(small_ds):
    """all_gather and tournament return bit-identical ids AND distances on
    the same 8-shard corpus (distinct distances)."""
    ds = small_ds
    mesh = _mesh8()
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.2, seed=5)
    req = SearchRequest(ds.queries, (qlo, qhi), ANY_OVERLAP, k=10)
    out = {}
    for merge in ("all_gather", "tournament"):
        dep = ShardedDeployment.flat(
            ds.vectors, ds.lo, ds.hi, mesh=mesh,
            spec=DeploymentSpec(n_shards=8, merge=merge))
        res = dep.execute(req)
        assert res.report.merge == merge
        out[merge] = res
    np.testing.assert_array_equal(out["all_gather"].ids,
                                  out["tournament"].ids)
    np.testing.assert_array_equal(out["all_gather"].dists,
                                  out["tournament"].dists)
    # and both equal the host merge (same candidates, same order)
    host = ShardedDeployment.flat(ds.vectors, ds.lo, ds.hi,
                                  spec=DeploymentSpec(n_shards=8,
                                                      merge="host"))
    np.testing.assert_array_equal(out["all_gather"].ids,
                                  host.execute(req).ids)


@needs8
@pytest.mark.parametrize("mask", [1, 2, 3, 4, 8, 15, 48, 63])
def test_sharded_vs_single_parity_grid_8dev(small_ds, built_index, mask):
    """The smoke grid: every route on every predicate family answers from 8
    shards what one device answers — exactly for the exact routes, at
    matched recall for the graph route (per-shard graphs differ from the
    single graph, so parity there is recall, not bits)."""
    ds = small_ds
    mesh = _mesh8()
    dep = ShardedDeployment.build(
        ds.vectors, ds.lo, ds.hi, mesh=mesh,
        spec=DeploymentSpec(
            n_shards=8,
            index=IndexSpec(variants=("T", "Tp", "Tpp"), m=8, ef_con=40)))
    single = QueryEngine(built_index)
    qlo, qhi = make_queries(ds, mask, 0.25, seed=10 + mask)
    exact = single.search(SearchRequest(ds.queries, (qlo, qhi), mask, k=10,
                                        route="flat"))
    for route in ("flat", "pruned", "graph"):
        res = dep.execute(SearchRequest(ds.queries, (qlo, qhi), mask, k=10,
                                        ef=64, route=route))
        assert res.report.merge == "all_gather" and not res.degraded
        if route == "graph":
            assert res.recall_vs(exact) >= 0.9, (mask, route)
        else:
            np.testing.assert_allclose(np.sort(res.dists, 1),
                                       np.sort(exact.dists, 1),
                                       rtol=1e-4, atol=1e-4,
                                       err_msg=f"{mask}/{route}")
            assert res.recall_vs(exact) == 1.0, (mask, route)


@needs8
def test_fused_flat_device_path_matches_host_8dev(small_ds):
    """The fused shard_map path (per_shard_k narrowing included) returns
    what the host-orchestrated merge returns, and a dead shard is masked
    identically on device."""
    ds = small_ds
    mesh = _mesh8()
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.25, seed=12)
    req = SearchRequest(ds.queries, (qlo, qhi), ANY_OVERLAP, k=10)
    for fk in (0, 4):
        dev = ShardedDeployment.flat(
            ds.vectors, ds.lo, ds.hi, mesh=mesh,
            spec=DeploymentSpec(n_shards=8, per_shard_k=fk))
        host = ShardedDeployment.flat(
            ds.vectors, ds.lo, ds.hi,
            spec=DeploymentSpec(n_shards=8, per_shard_k=fk, merge="host"))
        dev.fail(5)
        host.fail(5)
        a = dev.execute(req)
        b = host.execute(req)
        assert a.degraded and a.report.missing_shards == (5,)
        assert a.report.shards[5].route == "lost"
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_allclose(a.dists, b.dists, rtol=1e-5, atol=1e-6)


_SUBPROCESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core import ANY_OVERLAP, QUERY_CONTAINED
    from repro.distributed import sharded_flat_topk
    from repro.data import make_range_dataset, make_queries, brute_force_topk

    ds = make_range_dataset(n=512, d=16, n_queries=8, quantize=32, seed=1)
    for mask in (ANY_OVERLAP, QUERY_CONTAINED):
        qlo, qhi = make_queries(ds, mask, 0.25, seed=2)
        tids, tds = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                     qlo, qhi, mask, 10)
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        for merge in ("all_gather", "tournament"):
            ids, d = sharded_flat_topk(
                mesh, jnp.asarray(ds.vectors), jnp.asarray(ds.lo, jnp.float32),
                jnp.asarray(ds.hi, jnp.float32), jnp.asarray(ds.queries),
                jnp.asarray(qlo, jnp.float32), jnp.asarray(qhi, jnp.float32),
                mask=mask, k=10, merge=merge)
            np.testing.assert_allclose(np.sort(np.asarray(d), 1), np.sort(tds, 1),
                                       rtol=1e-4, atol=1e-4)
            # ids must be correctly rebased to global
            got = set(int(x) for x in np.asarray(ids)[0] if x >= 0)
            want = set(int(x) for x in tids[0] if x >= 0)
            dmat = np.sort(np.asarray(d)[0])
            tmat = np.sort(tds[0])
            ok = np.allclose(dmat, tmat, rtol=1e-4, atol=1e-4)
            assert ok, (merge, mask)
    print("OK-8DEV")
""")


@pytest.mark.slow
def test_sharded_flat_8dev_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROCESS_PROG],
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.join(os.path.dirname(__file__), ".."), env=env)
    assert "OK-8DEV" in r.stdout, r.stdout + r.stderr
