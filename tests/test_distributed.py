"""Distributed top-k: single-device meshes inline; an 8-device fake mesh runs
in a subprocess (XLA device count must be fixed before jax init)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import ANY_OVERLAP
from repro.distributed import sharded_flat_topk
from repro.data import make_range_dataset, make_queries, brute_force_topk, recall_at_k


def test_sharded_flat_single_device(small_ds):
    ds = small_ds
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.2, seed=5)
    # corpus size must divide the shard count (1) — always true
    ids, d = sharded_flat_topk(mesh, jnp.asarray(ds.vectors),
                               jnp.asarray(ds.lo, jnp.float32),
                               jnp.asarray(ds.hi, jnp.float32),
                               jnp.asarray(ds.queries),
                               jnp.asarray(qlo, jnp.float32),
                               jnp.asarray(qhi, jnp.float32),
                               mask=ANY_OVERLAP, k=10)
    tids, tds = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                 qlo, qhi, ANY_OVERLAP, 10)
    np.testing.assert_allclose(np.sort(np.asarray(d), 1), np.sort(tds, 1),
                               rtol=1e-4, atol=1e-4)


_SUBPROCESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core import ANY_OVERLAP, QUERY_CONTAINED
    from repro.distributed import sharded_flat_topk
    from repro.data import make_range_dataset, make_queries, brute_force_topk

    ds = make_range_dataset(n=512, d=16, n_queries=8, quantize=32, seed=1)
    for mask in (ANY_OVERLAP, QUERY_CONTAINED):
        qlo, qhi = make_queries(ds, mask, 0.25, seed=2)
        tids, tds = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                     qlo, qhi, mask, 10)
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        for merge in ("all_gather", "tournament"):
            ids, d = sharded_flat_topk(
                mesh, jnp.asarray(ds.vectors), jnp.asarray(ds.lo, jnp.float32),
                jnp.asarray(ds.hi, jnp.float32), jnp.asarray(ds.queries),
                jnp.asarray(qlo, jnp.float32), jnp.asarray(qhi, jnp.float32),
                mask=mask, k=10, merge=merge)
            np.testing.assert_allclose(np.sort(np.asarray(d), 1), np.sort(tds, 1),
                                       rtol=1e-4, atol=1e-4)
            # ids must be correctly rebased to global
            got = set(int(x) for x in np.asarray(ids)[0] if x >= 0)
            want = set(int(x) for x in tids[0] if x >= 0)
            dmat = np.sort(np.asarray(d)[0])
            tmat = np.sort(tds[0])
            ok = np.allclose(dmat, tmat, rtol=1e-4, atol=1e-4)
            assert ok, (merge, mask)
    print("OK-8DEV")
""")


@pytest.mark.slow
def test_sharded_flat_8dev_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROCESS_PROG],
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.join(os.path.dirname(__file__), ".."), env=env)
    assert "OK-8DEV" in r.stdout, r.stdout + r.stderr
