"""Vectorized planner (property-based vs the scalar Theorem 4.1 reference)
and the QueryEngine facade (routing, padding, end-to-end recall)."""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as hst

from repro.core import (ANY_OVERLAP, QUERY_CONTAINED, QUERY_CONTAINING,
                        EngineConfig, MSTGIndex, Overlaps, QueryEngine,
                        SearchRequest, intervals as iv)
from repro.core.engine import ROUTE_GRAPH, ROUTE_PRUNED, _next_pow2
from repro.data import make_queries, brute_force_topk


def _req(queries, qlo, qhi, mask, route=None, **kw):
    return SearchRequest(queries, (qlo, qhi), mask, route=route, **kw)


# ---- plan_batch_ranked vs scalar plan_searches_ranked ----

@settings(max_examples=150, deadline=None)
@given(hst.integers(1, 63), hst.integers(2, 40), hst.data())
def test_plan_batch_ranked_matches_scalar(mask, K, data):
    """Slot-for-slot agreement on random rank bounds, including the Allen
    BEFORE/AFTER bits and exact-vs-between endpoint encodings."""
    rng = np.random.default_rng(data.draw(hst.integers(0, 2**31)))
    Q = 32
    fl = rng.integers(-1, K, Q)
    exact_l = rng.integers(0, 2, Q).astype(bool) & (fl >= 0)
    cl = np.where(exact_l, fl, fl + 1)
    fr = np.maximum(fl, rng.integers(-1, K, Q))
    exact_r = rng.integers(0, 2, Q).astype(bool) & (fr >= cl)
    cr = np.where(exact_r, fr, fr + 1)

    slots = iv.plan_batch_ranked(mask, fl, cl, fr, cr, K)
    for qi in range(Q):
        ref = iv.plan_searches_ranked(mask, int(fl[qi]), int(cl[qi]),
                                      int(fr[qi]), int(cr[qi]), K)
        assert len(slots) == len(ref)
        for s, t in zip(slots, ref):
            assert s.variant == t.variant
            got = (int(s.version[qi]), int(s.key_lo[qi]), int(s.key_hi[qi]))
            assert got == (t.version, t.key_lo, t.key_hi), (
                iv.mask_name(mask), qi, got, t)


def test_plan_batch_ranked_empty_mask_and_shapes():
    slots = iv.plan_batch_ranked(0, np.zeros(4, np.int64), np.zeros(4, np.int64),
                                 np.ones(4, np.int64), np.ones(4, np.int64), 8)
    assert slots == []
    slots = iv.plan_batch_ranked(ANY_OVERLAP, np.zeros(5, np.int64),
                                 np.zeros(5, np.int64), np.full(5, 3),
                                 np.full(5, 3), 8)
    assert [s.variant for s in slots] == [iv.VARIANT_T, iv.VARIANT_TP]
    for s in slots:
        assert s.version.shape == s.key_lo.shape == s.key_hi.shape == (5,)


def test_plan_batch_rejects_inverted_ranges(built_index):
    with pytest.raises(ValueError):
        built_index.plan_batch(ANY_OVERLAP, np.array([5.0]), np.array([1.0]))


def test_plan_batch_rejects_missing_variant(small_ds):
    ds = small_ds
    idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T",), m=8, ef_con=40)
    with pytest.raises(ValueError, match="needs variants"):
        idx.plan_batch(QUERY_CONTAINING, np.array([1.0]), np.array([2.0]))


# ---- QueryEngine ----

def test_engine_graph_matches_flat_ground_truth(small_ds, built_index):
    """End-to-end: graph path vs flat route ground truth at high recall."""
    ds = small_ds
    eng = QueryEngine(built_index)
    for mask in (ANY_OVERLAP, QUERY_CONTAINED, QUERY_CONTAINING):
        qlo, qhi = make_queries(ds, mask, 0.15, seed=31)
        truth = eng.search(_req(ds.queries, qlo, qhi, mask, route="flat"))
        graph = eng.search(_req(ds.queries, qlo, qhi, mask, route="graph",
                                ef=96))
        assert graph.recall_vs(truth) >= 0.9, iv.mask_name(mask)


def test_engine_routes_agree_and_pruned_is_exact(small_ds, built_index):
    ds = small_ds
    eng = QueryEngine(built_index)
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.1, seed=37)
    tids, tds = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                 qlo, qhi, ANY_OVERLAP, 10)
    pruned = eng.search(_req(ds.queries, qlo, qhi, Overlaps(), route="pruned"))
    np.testing.assert_allclose(np.sort(pruned.dists, 1), np.sort(tds, 1),
                               rtol=1e-4, atol=1e-4)
    flat = eng.search(_req(ds.queries, qlo, qhi, Overlaps(), route="flat"))
    np.testing.assert_allclose(np.sort(flat.dists, 1), np.sort(tds, 1),
                               rtol=1e-4, atol=1e-4)
    assert pruned.report.route == "pruned" and flat.report.route == "flat"


def test_engine_auto_routing_by_selectivity(small_ds, built_index):
    ds = small_ds
    eng = QueryEngine(built_index, config=EngineConfig(flat_threshold=0.15))
    # narrow query -> low selectivity -> pruned; wide -> graph
    qlo_n, qhi_n = make_queries(ds, ANY_OVERLAP, 0.02, seed=41)
    qlo_w, qhi_w = make_queries(ds, ANY_OVERLAP, 0.6, seed=41)
    est_n = eng.estimate_selectivity(ANY_OVERLAP, qlo_n, qhi_n)
    est_w = eng.estimate_selectivity(ANY_OVERLAP, qlo_w, qhi_w)
    assert est_n.mean() < est_w.mean()
    assert eng.route_for(ANY_OVERLAP, qlo_n, qhi_n) == ROUTE_PRUNED
    assert eng.route_for(ANY_OVERLAP, qlo_w, qhi_w) == ROUTE_GRAPH
    # selectivity estimate is exact here (sample == corpus)
    want = np.stack([np.asarray(iv.eval_predicate(
        ANY_OVERLAP, ds.lo, ds.hi, qlo_n[i], qhi_n[i])).mean()
        for i in range(len(qlo_n))])
    np.testing.assert_allclose(est_n, want, atol=1e-12)


def test_engine_padding_is_invisible(small_ds, built_index):
    """Bucketed (padded) batches return exactly what unpadded batches do."""
    ds = small_ds
    eng_pad = QueryEngine(built_index, config=EngineConfig(pad_queries=True))
    eng_raw = QueryEngine(built_index, config=EngineConfig(pad_queries=False))
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.15, seed=43)
    for Q in (1, 3, 7):  # all pad up to buckets
        req = _req(ds.queries[:Q], qlo[:Q], qhi[:Q], Overlaps(),
                   route=ROUTE_GRAPH)
        a = eng_pad.search(req)
        b = eng_raw.search(req)
        assert a.ids.shape == (Q, 10)
        np.testing.assert_allclose(np.sort(a.dists, 1), np.sort(b.dists, 1),
                                   rtol=1e-4, atol=1e-4)


def test_engine_pruned_exact_despite_bad_estimator(small_ds, built_index):
    """The pruned candidate cap comes from the plan (exact bound), not the
    sampled selectivity estimate — a pathological estimator must not cause
    truncation (regression: cap used to be 2x the sampled selectivity)."""
    ds = small_ds
    eng = QueryEngine(built_index, config=EngineConfig(selectivity_sample=4))
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.05, seed=47)
    tids, tds = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                 qlo, qhi, ANY_OVERLAP, 10)
    pids, pds = eng.search_pruned(ds.queries, qlo, qhi, ANY_OVERLAP, k=10)
    np.testing.assert_allclose(np.sort(pds, 1), np.sort(tds, 1),
                               rtol=1e-4, atol=1e-4)


def test_engine_empty_batch_and_empty_predicate(built_index, small_ds):
    eng = QueryEngine(built_index)
    res = eng.search(_req(np.zeros((0, small_ds.d), np.float32),
                          np.zeros(0), np.zeros(0), ANY_OVERLAP, k=5))
    assert res.ids.shape == (0, 5) and res.dists.shape == (0, 5)
    assert len(res) == 0 and list(res) == []
    qlo = np.full(3, -50.0)
    qhi = np.full(3, -40.0)
    res = eng.search(_req(small_ds.queries[:3], qlo, qhi, QUERY_CONTAINED,
                          k=5))
    assert (res.ids < 0).all() and np.isinf(res.dists).all()
    assert not res.valid_mask.any()


def test_next_pow2():
    assert [_next_pow2(x) for x in (1, 2, 3, 7, 8, 9)] == [1, 2, 4, 8, 8, 16]


def test_selectivity_cache_bounded_fifo_eviction(small_ds, built_index):
    """Overflow evicts the oldest entries only (FIFO), never the whole memo,
    and the hit/miss/eviction counters stay consistent throughout."""
    ds = small_ds
    eng = QueryEngine(built_index, config=EngineConfig(sel_cache_max=8))
    vals = built_index.domain.values
    qlo = vals[:12].copy()                    # 12 distinct rank signatures
    qhi = qlo + (vals[-1] - vals[0])
    _, h1, m1 = eng._estimate_cached(15, qlo, qhi)
    assert (h1, m1) == (0, 12)
    assert len(eng._sel_cache) == 8           # bounded, not cleared
    assert eng.sel_cache_evictions == 4       # the 4 oldest fell out
    # newest 8 still hit; oldest 4 miss again and evict the next-oldest 4
    _, h2, m2 = eng._estimate_cached(15, qlo[4:], qhi[4:])
    assert (h2, m2) == (8, 0)
    _, h3, m3 = eng._estimate_cached(15, qlo[:4], qhi[:4])
    assert (h3, m3) == (0, 4)
    assert len(eng._sel_cache) == 8
    assert eng.sel_cache_evictions == 8
    assert eng.sel_cache_hits == h1 + h2 + h3
    assert eng.sel_cache_misses == m1 + m2 + m3
    # estimates themselves are unaffected by eviction
    est, _, _ = eng._estimate_cached(15, qlo, qhi)
    want = eng.estimate_selectivity(15, qlo, qhi)
    np.testing.assert_array_equal(est, want)


def test_auto_route_parity_with_pinned_route(small_ds, built_index):
    """The auto-route regression fix: an auto-routed request must execute the
    *same* plan as pinning the route it selects — identical ids, distances,
    slot count, and variants — with selectivity answered from the O(1) rank
    table before any device work (no sample scan on the request path)."""
    ds = small_ds
    eng = QueryEngine(built_index, config=EngineConfig(flat_threshold=0.15))
    for sel, want_route in ((0.02, ROUTE_PRUNED), (0.6, ROUTE_GRAPH)):
        qlo, qhi = make_queries(ds, ANY_OVERLAP, sel, seed=53)
        auto = eng.search(_req(ds.queries, qlo, qhi, ANY_OVERLAP))
        assert auto.report.route == want_route
        assert auto.report.requested == "auto"
        pinned = eng.search(_req(ds.queries, qlo, qhi, ANY_OVERLAP,
                                 route=want_route))
        np.testing.assert_array_equal(auto.ids, pinned.ids)
        np.testing.assert_array_equal(auto.dists, pinned.dists)
        assert auto.report.slot_count == pinned.report.slot_count
        assert auto.report.variants == pinned.report.variants
        # route_for agrees with what execute() actually did
        assert eng.route_for(ANY_OVERLAP, qlo, qhi) == want_route


def test_auto_route_work_model_default(small_ds, built_index):
    """Default routing is the work model: at this corpus size the exact
    pruned scan's estimated work (sel * n) stays under the beam's (ef * S)
    for any selectivity, and route_for/execute agree."""
    ds = small_ds
    eng = QueryEngine(built_index)          # flat_threshold=None -> work model
    n = built_index.vectors.shape[0]
    for sel in (0.05, 0.6):
        qlo, qhi = make_queries(ds, ANY_OVERLAP, sel, seed=61)
        est = eng.estimate_selectivity(ANY_OVERLAP, qlo, qhi)
        scan_work = est.mean() * n
        beam_work = 64 * eng._max_slots
        want = ROUTE_PRUNED if scan_work <= beam_work else ROUTE_GRAPH
        assert eng.route_for(ANY_OVERLAP, qlo, qhi, ef=64) == want
        res = eng.search(_req(ds.queries, qlo, qhi, ANY_OVERLAP))
        assert res.report.route == want
        pinned = eng.search(_req(ds.queries, qlo, qhi, ANY_OVERLAP,
                                 route=want))
        np.testing.assert_array_equal(res.ids, pinned.ids)
        np.testing.assert_array_equal(res.dists, pinned.dists)


def test_selectivity_table_built_and_bounded(small_ds, built_index):
    """Small domains get the O(1) table; its estimates equal the sample scan
    (here sample == corpus, so both are exact)."""
    eng = QueryEngine(built_index)
    assert eng._sel_index is not None
    assert eng._sel_index.K == built_index.domain.K
    assert eng._sel_index.m == built_index.vectors.shape[0]
    ds = small_ds
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.3, seed=59)
    est = eng.estimate_selectivity(ANY_OVERLAP, qlo, qhi)
    want = np.stack([np.asarray(iv.eval_predicate(
        ANY_OVERLAP, ds.lo, ds.hi, qlo[i], qhi[i])).mean()
        for i in range(len(qlo))])
    np.testing.assert_allclose(est, want, atol=1e-12)


def test_legacy_constructor_knobs_warn_once_and_fold(built_index):
    """Bare constructor knobs still work but warn exactly once per process
    (attributed to the caller) and fold into the typed EngineConfig; unknown
    knobs and non-EngineConfig configs are rejected outright."""
    import warnings as w
    from repro.core.engine import reset_deprecation_warnings
    reset_deprecation_warnings()
    with w.catch_warnings(record=True) as rec:
        w.simplefilter("always")
        eng1 = QueryEngine(built_index, pad_queries=False, sel_cache_max=7)
        eng2 = QueryEngine(built_index, selectivity_sample=3)
    deps = [r for r in rec if issubclass(r.category, DeprecationWarning)]
    assert len(deps) == 1                     # once per process, not per call
    assert deps[0].filename == __file__       # stacklevel points at the caller
    assert eng1.config.pad_queries is False and eng1.config.sel_cache_max == 7
    assert eng2.config.selectivity_sample == 3
    # knobs layered on an explicit config win over that config
    base = EngineConfig(sel_cache_max=5, pad_queries=False)
    with w.catch_warnings():
        w.simplefilter("ignore", DeprecationWarning)
        eng3 = QueryEngine(built_index, config=base, sel_cache_max=9)
    assert eng3.config.sel_cache_max == 9 and eng3.config.pad_queries is False
    with pytest.raises(TypeError, match="unknown QueryEngine knob"):
        QueryEngine(built_index, beam_width=32)
    with pytest.raises(TypeError, match="EngineConfig"):
        QueryEngine(built_index, config={"route": "flat"})
    reset_deprecation_warnings()


def test_engine_config_validates_and_replaces():
    cfg = EngineConfig()
    assert cfg.route == "auto" and cfg.flat_threshold is None
    assert cfg.replace(route="pruned").route == "pruned"
    assert cfg.route == "auto"                # replace() copies, frozen intact
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.route = "flat"
    for bad in (dict(route="beam"), dict(graph_fanout=0),
                dict(graph_chunk=-1), dict(graph_chunk="wide"),
                dict(selectivity_sample=0), dict(sel_cache_max=0)):
        with pytest.raises(ValueError):
            EngineConfig(**bad)
        with pytest.raises(ValueError):
            cfg.replace(**bad)                # replace() re-validates


def test_request_wins_over_config_wins_over_heuristic(small_ds, built_index):
    """The documented precedence: a SearchRequest field beats the
    EngineConfig value, which beats the backend heuristic."""
    ds = small_ds
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.2, seed=67)
    eng = QueryEngine(built_index, config=EngineConfig(route="pruned"))
    res = eng.search(_req(ds.queries, qlo, qhi, ANY_OVERLAP))
    assert res.report.route == "pruned"       # config overrides auto-routing
    res = eng.search(_req(ds.queries, qlo, qhi, ANY_OVERLAP, route="flat"))
    assert res.report.route == "flat"         # request overrides config
    # fanout: request > config > backend heuristic (CPU heuristic is 1)
    eng2 = QueryEngine(built_index, config=EngineConfig(graph_fanout=2))
    assert eng2._resolve_fanout(64, None) == 2
    assert eng2._resolve_fanout(64, 5) == 5
    assert QueryEngine(built_index)._resolve_fanout(64, None) == 1
