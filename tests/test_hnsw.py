"""Labeled HNSW build invariants incl. Theorem D.1 (label losslessness)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as hst

from repro.core.hnsw import LabeledLevelGraph, PlainHNSW, rng_prune, l2sq, OPEN


def _rand_vectors(n, d, seed):
    rng = np.random.default_rng(seed)
    return rng.normal(0, 1, (n, d)).astype(np.float32)


def test_rng_prune_keeps_closest_and_caps():
    V = _rand_vectors(64, 8, 0)
    base = 0
    cand = np.arange(1, 64)
    dists = l2sq(V[cand], V[base])
    kept = rng_prune(V, base, cand, dists, m=6)
    assert len(kept) <= 6
    assert kept[0] == cand[np.argmin(dists)]  # the closest always survives


@settings(max_examples=10, deadline=None)
@given(hst.integers(0, 10_000))
def test_theorem_d1_label_losslessness(seed):
    """Induced subgraph at version x == live graph snapshot after inserting
    the version-x prefix (the paper's Theorem D.1)."""
    V = _rand_vectors(80, 8, seed)
    g = LabeledLevelGraph(V, m=4, ef_con=16)
    rng = np.random.default_rng(seed)
    versions = np.sort(rng.integers(0, 10, 80))
    snapshot_at = int(versions[40])
    snap = None
    for u in range(80):
        if snap is None and versions[u] > snapshot_at:
            snap = {w: list(g.open_adj.get(w, ())) for w in range(u)}
        g.insert(u, node_idx=0, version=int(versions[u]))
    if snap is None:
        snap = {w: list(g.open_adj.get(w, ())) for w in range(80)}
    for u, live in snap.items():
        induced = g.induced_adjacency(u, snapshot_at)
        assert sorted(induced) == sorted(live), f"vertex {u} @ v{snapshot_at}"


def test_freeze_roundtrip():
    V = _rand_vectors(50, 8, 1)
    g = LabeledLevelGraph(V, m=4, ef_con=16)
    for u in range(50):
        g.insert(u, node_idx=0, version=u)
    tgt, b, e = g.freeze(50)
    for u in range(50):
        frozen = [(int(t), int(bb), int(ee)) for t, bb, ee in zip(tgt[u], b[u], e[u])
                  if t >= 0]
        assert sorted(frozen) == sorted(g.edge_log(u))


def test_plain_hnsw_recall():
    V = _rand_vectors(400, 16, 2)
    h = PlainHNSW(V, m=8, ef_con=48).build(range(400))
    rng = np.random.default_rng(3)
    ok = 0
    for _ in range(20):
        q = V[rng.integers(0, 400)] + 0.01 * rng.normal(0, 1, 16).astype(np.float32)
        ids, _ = h.search(q, k=10, ef=48)
        true = np.argsort(l2sq(V, q))[:10]
        ok += len(set(ids.tolist()) & set(true.tolist()))
    assert ok / 200 >= 0.9


def test_filtered_traversal_only_returns_matching():
    V = _rand_vectors(300, 8, 4)
    h = PlainHNSW(V, m=8, ef_con=32).build(range(300))
    allowed = set(range(0, 300, 3))
    ids, _ = h.search(V[7], k=10, ef=64, predicate=lambda u: u in allowed)
    assert all(int(u) in allowed for u in ids)


def test_same_version_prune_edge_never_existed():
    """An edge born and pruned within one version must not appear at any
    version (the paper's intra-version consistency)."""
    V = _rand_vectors(60, 4, 5)
    g = LabeledLevelGraph(V, m=3, ef_con=8)
    for u in range(60):
        g.insert(u, node_idx=0, version=0)  # everything at version 0
    for u in range(60):
        for (v, b, e) in g.edge_log(u):
            assert e >= b
