"""Predicate semantics + Theorem 4.1 planner correctness (property-based)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as hst

from repro.core import intervals as iv


ATOMIC_MASKS = list(range(1, 16))


def test_atomic_truth_table():
    # object [2, 5]
    lo, hi = 2.0, 5.0
    cases = [
        (iv.LEFT_OVERLAP, 3.0, 8.0, True),     # lo<=3<=5<=8
        (iv.LEFT_OVERLAP, 0.0, 8.0, False),    # ql < lo
        (iv.QUERY_CONTAINED, 3.0, 4.0, True),
        (iv.QUERY_CONTAINED, 1.0, 4.0, False),
        (iv.RIGHT_OVERLAP, 1.0, 3.0, True),    # 1<=2<=3<=5
        (iv.RIGHT_OVERLAP, 3.0, 4.0, False),
        (iv.QUERY_CONTAINING, 1.0, 6.0, True),
        (iv.QUERY_CONTAINING, 3.0, 6.0, False),
        (iv.BEFORE, 0.0, 1.0, True),
        (iv.BEFORE, 0.0, 2.0, False),
        (iv.AFTER, 6.0, 7.0, True),
        (iv.AFTER, 5.0, 7.0, False),
    ]
    for mask, ql, qh, want in cases:
        got = bool(iv.eval_predicate(mask, np.array([lo]), np.array([hi]), ql, qh)[0])
        assert got == want, (iv.mask_name(mask), ql, qh)


def test_any_overlap_equals_intersection():
    rng = np.random.default_rng(0)
    lo = rng.uniform(0, 10, 500)
    hi = lo + rng.uniform(0, 5, 500)
    ql, qh = 3.0, 6.0
    got = iv.eval_predicate(iv.ANY_OVERLAP, lo, hi, ql, qh)
    want = (lo <= qh) & (hi >= ql)
    assert np.array_equal(got, want)


@settings(max_examples=120, deadline=None)
@given(hst.integers(1, 15), hst.integers(2, 40), hst.data())
def test_planner_cover_exact(mask, K, data):
    """Union of planned task candidate sets == predicate-satisfying set."""
    rng = np.random.default_rng(data.draw(hst.integers(0, 2**31)))
    n = 200
    rl = rng.integers(0, K, n)
    rr = rl + rng.integers(0, K, n)
    rr = np.minimum(rr, K - 1)
    fl = data.draw(hst.integers(-1, K - 1))
    # derive consistent (fl, cl) pair: either exact rank or between ranks
    exact_l = data.draw(hst.booleans())
    cl = fl if (exact_l and fl >= 0) else fl + 1
    fr = data.draw(hst.integers(max(fl, 0) if cl > fl else fl, K - 1))
    exact_r = data.draw(hst.booleans())
    cr = fr if (exact_r and fr >= cl) else fr + 1
    # ensure query lo <= hi in interpolated coordinates
    if iv._rank_interp(fl, cl) > iv._rank_interp(fr, cr):
        return
    tasks = [t for t in iv.plan_searches_ranked(mask, fl, cl, fr, cr, K)
             if not t.is_empty(K)]
    assert len(tasks) <= 2
    assert iv.check_plan_cover(mask, tasks, rl, rr, fl, cl, fr, cr, K)


def test_plan_searches_float_domain():
    dom = iv.AttributeDomain(np.array([1.0, 2.0, 5.0, 9.0]))
    # query [1.5, 6.0]: contained objects need lo<=1.5 (rank<=0), hi>=6 (rank>=3)
    tasks = iv.plan_searches(dom, iv.QUERY_CONTAINED, 1.5, 6.0)
    assert len(tasks) == 1
    t = tasks[0]
    assert t.variant == iv.VARIANT_T and t.version == 0 and t.key_lo == 3


def test_variants_required():
    assert iv.variants_required(iv.QUERY_CONTAINED) == ["T"]
    assert set(iv.variants_required(iv.ANY_OVERLAP)) == {"T", "Tp"}
    assert set(iv.variants_required(iv.QUERY_CONTAINING)) == {"Tpp"}


def test_planner_max_two_tasks_all_masks():
    dom = iv.AttributeDomain(np.arange(16.0))
    for mask in ATOMIC_MASKS:
        tasks = iv.plan_searches(dom, mask, 3.0, 11.0)
        assert len(tasks) <= 2, iv.mask_name(mask)


def test_allen_disjoint_filters():
    dom = iv.AttributeDomain(np.arange(10.0))
    rl = np.arange(10, dtype=np.int64)
    rr = np.minimum(rl + 2, 9)
    for mask in (iv.BEFORE, iv.AFTER):
        tasks = iv.plan_searches(dom, mask, 3.0, 5.0)
        assert len(tasks) == 1
        got = iv.check_plan_cover(mask, tasks, rl, rr, 3, 3, 5, 5, 10)
        assert got
