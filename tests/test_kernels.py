"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as hst

import jax.numpy as jnp

from repro.core import intervals as iv
from repro.kernels import ops
from repro.kernels.pairwise_l2 import pairwise_l2_masked
from repro.kernels.gathered_l2 import gathered_l2, gathered_l2_dot
from repro.kernels.ref import pairwise_l2_masked_ref, gathered_l2_ref

MASKS = [iv.ANY_OVERLAP, iv.QUERY_CONTAINED, iv.QUERY_CONTAINING,
         iv.LEFT_OVERLAP | iv.RIGHT_OVERLAP]


def _mk(Q, N, d, dtype, seed=0):
    rng = np.random.default_rng(seed)
    q = rng.normal(0, 1, (Q, d)).astype(dtype)
    c = rng.normal(0, 1, (N, d)).astype(dtype)
    lo = rng.uniform(0, 100, N).astype(np.float32)
    hi = lo + rng.uniform(0, 30, N).astype(np.float32)
    ql = rng.uniform(0, 100, Q).astype(np.float32)
    qh = ql + rng.uniform(0, 30, Q).astype(np.float32)
    return q, c, lo, hi, ql, qh


@pytest.mark.parametrize("mask", MASKS, ids=iv.mask_name)
@pytest.mark.parametrize("shape", [(3, 5, 8), (16, 130, 32), (9, 257, 17)])
def test_pairwise_l2_masked_matches_ref(mask, shape):
    Q, N, d = shape
    q, c, lo, hi, ql, qh = _mk(Q, N, d, np.float32)
    got = pairwise_l2_masked(q, c, lo, hi, ql, qh, mask, bq=8, bn=128,
                             interpret=True)
    want = pairwise_l2_masked_ref(jnp.asarray(q), jnp.asarray(c),
                                  jnp.asarray(lo), jnp.asarray(hi),
                                  jnp.asarray(ql), jnp.asarray(qh), mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(hst.integers(1, 12), hst.integers(1, 200), hst.integers(1, 48),
       hst.sampled_from([np.float32, np.float16]),
       hst.sampled_from(MASKS), hst.integers(0, 2**30))
def test_pairwise_l2_masked_hypothesis(Q, N, d, dtype, mask, seed):
    q, c, lo, hi, ql, qh = _mk(Q, N, d, dtype, seed)
    got = pairwise_l2_masked(q, c, lo, hi, ql, qh, mask, bq=8, bn=128,
                             interpret=True)
    want = pairwise_l2_masked_ref(jnp.asarray(q), jnp.asarray(c),
                                  jnp.asarray(lo), jnp.asarray(hi),
                                  jnp.asarray(ql), jnp.asarray(qh), mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-3, atol=5e-3)


@settings(max_examples=25, deadline=None)
@given(hst.integers(1, 12), hst.integers(1, 40), hst.integers(1, 64),
       hst.sampled_from([np.float32, np.float16]), hst.integers(0, 2**30))
def test_gathered_l2_hypothesis(Q, S, d, dtype, seed):
    rng = np.random.default_rng(seed)
    q = rng.normal(0, 1, (Q, d)).astype(dtype)
    cv = rng.normal(0, 1, (Q, S, d)).astype(dtype)
    want = gathered_l2_ref(jnp.asarray(q), jnp.asarray(cv))
    for fn in (gathered_l2, gathered_l2_dot):
        got = fn(q, cv, bq=4, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=5e-3, atol=5e-3)


def test_bf16_accumulation_is_fp32():
    """bf16 inputs must not lose the fp32 accumulation contract."""
    rng = np.random.default_rng(0)
    q = rng.normal(0, 1, (4, 256)).astype(np.float32)
    c = rng.normal(0, 1, (8, 256)).astype(np.float32)
    qb = jnp.asarray(q, jnp.bfloat16)
    cb = jnp.asarray(c, jnp.bfloat16)
    lo = np.zeros(8, np.float32); hi = np.ones(8, np.float32)
    ql = np.zeros(4, np.float32); qh = np.ones(4, np.float32)
    got = pairwise_l2_masked(qb, cb, lo, hi, ql, qh, iv.ANY_OVERLAP,
                             bq=8, bn=128, interpret=True)
    want = pairwise_l2_masked_ref(qb, cb, jnp.asarray(lo), jnp.asarray(hi),
                                  jnp.asarray(ql), jnp.asarray(qh), iv.ANY_OVERLAP)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-2)


def test_ops_dispatch_interpret_on_cpu():
    q, c, lo, hi, ql, qh = _mk(4, 40, 16, np.float32)
    got = ops.pairwise_l2_masked(q, c, lo, hi, ql, qh, iv.ANY_OVERLAP)
    want = pairwise_l2_masked_ref(jnp.asarray(q), jnp.asarray(c),
                                  jnp.asarray(lo), jnp.asarray(hi),
                                  jnp.asarray(ql), jnp.asarray(qh), iv.ANY_OVERLAP)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_flat_engine_with_kernel_path(small_ds):
    """flat_search(use_kernel=True) must equal the jnp path end to end."""
    from repro.core.flat import flat_search
    ds = small_ds
    ql = np.quantile(ds.lo, 0.3) * np.ones(6, np.float32)
    qh = np.quantile(ds.hi, 0.7) * np.ones(6, np.float32)
    a = flat_search(jnp.asarray(ds.vectors), jnp.asarray(ds.lo, jnp.float32),
                    jnp.asarray(ds.hi, jnp.float32), jnp.asarray(ds.queries[:6]),
                    jnp.asarray(ql), jnp.asarray(qh), mask=iv.ANY_OVERLAP, k=10,
                    use_kernel=True)
    b = flat_search(jnp.asarray(ds.vectors), jnp.asarray(ds.lo, jnp.float32),
                    jnp.asarray(ds.hi, jnp.float32), jnp.asarray(ds.queries[:6]),
                    jnp.asarray(ql), jnp.asarray(qh), mask=iv.ANY_OVERLAP, k=10,
                    use_kernel=False)
    np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mask", MASKS[:2], ids=iv.mask_name)
@pytest.mark.parametrize("shape", [(4, 300, 16), (8, 1030, 32)])
def test_fused_topk_matches_bruteforce(mask, shape):
    """The single-kernel filtered k-NN (grid-accumulated running top-k)."""
    from repro.kernels.fused_topk import fused_topk_l2
    from repro.kernels.ref import pairwise_l2_masked_ref
    Q, N, d = shape
    q, c, lo, hi, ql, qh = _mk(Q, N, d, np.float32, seed=7)
    ids, dd = fused_topk_l2(jnp.asarray(q), jnp.asarray(c), jnp.asarray(lo),
                            jnp.asarray(hi), jnp.asarray(ql), jnp.asarray(qh),
                            mask, k=5, bn=256, interpret=True)
    ref = pairwise_l2_masked_ref(jnp.asarray(q), jnp.asarray(c),
                                 jnp.asarray(lo), jnp.asarray(hi),
                                 jnp.asarray(ql), jnp.asarray(qh), mask)
    want = np.sort(np.asarray(ref), axis=1)[:, :5]
    np.testing.assert_allclose(np.sort(np.asarray(dd), 1), want,
                               rtol=1e-4, atol=1e-4)


def _mk_wavefront_step(Q, n, d, M, L, seed=0):
    """Random inputs shaped like one wavefront beam step."""
    rng = np.random.default_rng(seed)
    q = rng.normal(0, 1, (Q, d)).astype(np.float32)
    table = rng.normal(0, 1, (n, d)).astype(np.float32)
    ids = rng.integers(-1, n, (Q, M)).astype(np.int32)      # NO_EDGE mixed in
    avail = (rng.random((Q, M)) < 0.7) & (ids >= 0)
    b = rng.integers(0, 40, (Q, M)).astype(np.int32)
    e = b + rng.integers(0, 40, (Q, M)).astype(np.int32)
    ver = rng.integers(0, 70, Q).astype(np.int32)
    # a plausible beam: sorted finite prefix, NO_EDGE/+inf tail
    pool_d = np.sort(rng.random((Q, L)).astype(np.float32), axis=1)
    pool_ids = rng.integers(0, n, (Q, L)).astype(np.int32)
    tail = rng.integers(0, L + 1, Q)
    for qi in range(Q):
        if tail[qi] < L:
            pool_d[qi, tail[qi]:] = np.inf
            pool_ids[qi, tail[qi]:] = -1
    pool_exp = (rng.random((Q, L)) < 0.5) & np.isfinite(pool_d)
    return q, table, ids, avail, b, e, ver, pool_ids, pool_d, pool_exp


@pytest.mark.parametrize("shape", [(3, 50, 8, 12, 6), (9, 200, 16, 40, 16)])
def test_gathered_topk_matches_ref(shape):
    """The fused wavefront-step kernel == its jnp oracle: ids bit-equal,
    distances allclose, expanded flags bit-equal."""
    from repro.kernels.gathered_topk import gathered_topk
    from repro.kernels.ref import gathered_topk_ref
    Q, n, d, M, L = shape
    args = _mk_wavefront_step(Q, n, d, M, L, seed=3)
    ki, kd, ke = gathered_topk(*map(jnp.asarray, args), bq=4, interpret=True)
    ri, rd, re = gathered_topk_ref(*map(jnp.asarray, args))
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(kd), np.asarray(rd),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(ke), np.asarray(re))


@settings(max_examples=10, deadline=None)
@given(hst.integers(1, 6), hst.integers(2, 80), hst.integers(1, 16),
       hst.integers(1, 24), hst.integers(1, 12), hst.integers(0, 2**30))
def test_gathered_topk_hypothesis(Q, n, d, M, L, seed):
    from repro.kernels.gathered_topk import gathered_topk
    from repro.kernels.ref import gathered_topk_ref
    args = _mk_wavefront_step(Q, n, d, M, L, seed)
    ki, kd, ke = gathered_topk(*map(jnp.asarray, args), bq=4, interpret=True)
    ri, rd, re = gathered_topk_ref(*map(jnp.asarray, args))
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(kd), np.asarray(rd),
                               rtol=1e-5, atol=1e-5)


def test_graph_search_fused_kernel_path(small_ds, built_index):
    """End to end: mstg_graph_search(use_kernel=True) routes the whole step
    merge through the fused kernel and matches the jnp path."""
    import jax.numpy as jnp2
    from repro.core import QueryEngine, ANY_OVERLAP as AO
    from repro.core.search import mstg_graph_search
    from repro.data import make_queries
    ds = small_ds
    eng = QueryEngine(built_index)
    qlo, qhi = make_queries(ds, AO, 0.15, seed=41)
    s = eng.plan(AO, qlo, qhi)[0]
    dv = eng.graph_dev(s.variant)
    args = (dv.tree(), jnp2.asarray(ds.queries[:6]),
            jnp2.asarray(s.version[:6], jnp2.int32),
            jnp2.asarray(s.key_lo[:6], jnp2.int32),
            jnp2.asarray(s.key_hi[:6], jnp2.int32))
    kw = dict(k=5, ef=12, max_steps=60, Kpad=dv.meta.Kpad, fanout=2)
    ji, jd = mstg_graph_search(*args, **kw, use_kernel=False)
    ki, kd = mstg_graph_search(*args, **kw, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(ji), np.asarray(ki))
    np.testing.assert_allclose(np.asarray(jd), np.asarray(kd),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(hst.integers(1, 6), hst.integers(1, 400), hst.integers(1, 24),
       hst.integers(1, 8), hst.integers(0, 2**30))
def test_fused_topk_hypothesis(Q, N, d, k, seed):
    from repro.kernels.fused_topk import fused_topk_l2
    from repro.kernels.ref import pairwise_l2_masked_ref
    q, c, lo, hi, ql, qh = _mk(Q, N, d, np.float32, seed)
    ids, dd = fused_topk_l2(jnp.asarray(q), jnp.asarray(c), jnp.asarray(lo),
                            jnp.asarray(hi), jnp.asarray(ql), jnp.asarray(qh),
                            iv.ANY_OVERLAP, k=k, bn=128, interpret=True)
    ref = np.asarray(pairwise_l2_masked_ref(
        jnp.asarray(q), jnp.asarray(c), jnp.asarray(lo), jnp.asarray(hi),
        jnp.asarray(ql), jnp.asarray(qh), iv.ANY_OVERLAP))
    want = np.sort(ref, axis=1)[:, :k]
    if want.shape[1] < k:  # k > N: pad ground truth with +inf
        want = np.pad(want, ((0, 0), (0, k - want.shape[1])),
                      constant_values=np.inf)
    np.testing.assert_allclose(np.sort(np.asarray(dd), 1), want,
                               rtol=1e-4, atol=1e-4)
