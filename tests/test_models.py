"""Per-arch smoke tests (reduced configs): init + one forward/train step on
CPU, shape and finiteness asserts; decode-vs-forward consistency for each
cache family; param-count sanity vs the published sizes."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.models.transformer import LM
from repro.data import TokenLoader
from repro.serving import seed_caches

ARCHS = list(configs.ARCH_NAMES)


def make_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    if cfg.frontend == "vision_stub":
        batch["patches"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.n_frontend_tokens, cfg.frontend_dim))
            .astype(np.float32))
    if cfg.frontend == "audio_stub":
        batch["frames"] = jnp.asarray(
            rng.normal(0, 1, (B, S, cfg.frontend_dim)).astype(np.float32))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = configs.get_smoke_config(arch)
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    batch = make_batch(cfg)
    loss, metrics = jax.jit(lambda p, b: lm.train_loss(p, b))(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    # one grad step moves the loss
    g = jax.grad(lambda p: lm.train_loss(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_shapes(arch):
    cfg = configs.get_smoke_config(arch)
    lm = LM(cfg)
    params = lm.init(jax.random.key(1))
    batch = make_batch(cfg)
    logits, caches = jax.jit(lambda p, b: lm.prefill(p, b))(params, batch)
    assert logits.shape[0] == 2 and logits.shape[1] == 1
    assert logits.shape[2] == cfg.vocab
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    assert len(caches) == len(lm.segments)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """Teacher-forcing consistency: decoding token-by-token after a prefill
    must reproduce the full-forward logits (validates every cache family:
    linear KV, ring/local KV, MLA latent, RG-LRU state, RWKV state, cross)."""
    cfg = configs.get_smoke_config(arch)
    lm = LM(cfg)
    params = lm.init(jax.random.key(2))
    B, S, P = 2, 32, 16
    batch = make_batch(cfg, B=B, S=S, seed=3)

    # reference: prefill over the full sequence gives last-position logits
    full_logits, _ = jax.jit(lambda p, b: lm.prefill(p, b))(params, batch)

    # prefill the first P tokens, then decode the rest
    pb = {k: (v[:, :P] if k in ("tokens", "labels") else v)
          for k, v in batch.items()}
    if "frames" in pb:
        pb["frames"] = batch["frames"]  # encoder memory stays full
    lg, pc = jax.jit(lambda p, b: lm.prefill(p, b))(params, pb)
    n_front = batch["patches"].shape[1] if "patches" in batch else 0
    enc_len = batch["frames"].shape[1] if "frames" in batch else 0
    caches = seed_caches(lm, pc, B, S + n_front, P + n_front, enc_len)

    decode = jax.jit(lambda p, c, t, pos: lm.decode_step(p, c, t, pos))
    logits = lg
    for i in range(P, S):
        tok = batch["tokens"][:, i:i + 1]
        logits, caches = decode(params, caches, tok,
                                jnp.asarray(n_front + i, jnp.int32))
    got = np.asarray(logits[:, 0], np.float32)
    want = np.asarray(full_logits[:, 0], np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_param_counts_match_published():
    expect = {
        "recurrentgemma-2b": 2.7e9, "qwen3-moe-30b-a3b": 30.5e9,
        "deepseek-v3-671b": 671e9, "seamless-m4t-large-v2": 2.3e9,
        "llava-next-mistral-7b": 7.2e9, "gemma3-1b": 1.0e9,
        "qwen3-32b": 32.8e9, "qwen1.5-110b": 111e9, "olmo-1b": 1.2e9,
        "rwkv6-7b": 7.6e9,
    }
    for arch, want in expect.items():
        lm = LM(configs.get_config(arch))
        got = lm.param_count()
        assert 0.8 * want <= got <= 1.25 * want, (arch, got, want)


def test_moe_routes_tokens_differently():
    cfg = configs.get_smoke_config("qwen3-moe-30b-a3b")
    lm = LM(cfg)
    params = lm.init(jax.random.key(4))
    b1 = make_batch(cfg, seed=5)
    b2 = make_batch(cfg, seed=6)
    l1 = float(lm.train_loss(params, b1)[0])
    l2 = float(lm.train_loss(params, b2)[0])
    assert l1 != l2


def test_training_reduces_loss_tiny_lm():
    """~50 steps on a tiny olmo must reduce loss (end-to-end substrate test)."""
    from repro.training import AdamWConfig, adamw_init, make_train_step
    cfg = configs.get_smoke_config("olmo-1b").scaled(n_layers=2, vocab=64)
    lm = LM(cfg)
    params = lm.init(jax.random.key(7))
    loader = TokenLoader(vocab=cfg.vocab, batch=4, seq_len=32, seed=1)
    step = make_train_step(lm, opt_cfg=AdamWConfig(lr=3e-3, warmup_steps=10))
    opt = adamw_init(params)
    losses = []
    for i in range(40):
        params, opt, m = step(params, opt, loader.batch_at(i % 4))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < 0.7 * np.mean(losses[:5]), losses[:3] + losses[-3:]
