"""MSTG end-to-end: exactness of flat/pruned engines, recall of the graph
engine, index accounting, and plan/batch machinery (paper §4, §5)."""
import numpy as np
import pytest

from repro.core import (ANY_OVERLAP, QUERY_CONTAINED, QUERY_CONTAINING,
                        LEFT_OVERLAP, RIGHT_OVERLAP, MSTGIndex, MSTGSearcher,
                        FlatSearcher, intervals as iv)
from repro.data import make_range_dataset, make_queries, brute_force_topk, recall_at_k

MASKS = [
    ANY_OVERLAP,
    QUERY_CONTAINED,
    QUERY_CONTAINING,
    LEFT_OVERLAP,
    RIGHT_OVERLAP,
    LEFT_OVERLAP | RIGHT_OVERLAP,
    QUERY_CONTAINED | QUERY_CONTAINING,
    LEFT_OVERLAP | QUERY_CONTAINED | RIGHT_OVERLAP,
]


@pytest.fixture(scope="module")
def setup(small_ds, built_index):
    return small_ds, built_index


@pytest.mark.parametrize("mask", MASKS, ids=iv.mask_name)
def test_flat_engines_exact(setup, mask):
    ds, idx = setup
    qlo, qhi = make_queries(ds, mask, 0.15, seed=7)
    tids, tds = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries, qlo, qhi, mask, 10)
    fs = FlatSearcher(idx)
    fids, fds = fs.search(ds.queries, qlo, qhi, mask, k=10)
    np.testing.assert_allclose(np.sort(fds, axis=1), np.sort(tds, axis=1),
                               rtol=1e-4, atol=1e-4)
    pids, pds = fs.search_pruned(ds.queries, qlo, qhi, mask, k=10)
    np.testing.assert_allclose(np.sort(pds, axis=1), np.sort(tds, axis=1),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mask", MASKS, ids=iv.mask_name)
def test_graph_engine_recall(setup, mask):
    ds, idx = setup
    qlo, qhi = make_queries(ds, mask, 0.15, seed=11)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries, qlo, qhi, mask, 10)
    ss = MSTGSearcher(idx)
    gids, _ = ss.search(ds.queries, qlo, qhi, mask, k=10, ef=48)
    assert recall_at_k(gids, tids) >= 0.85, iv.mask_name(mask)


def test_graph_engine_never_returns_nonqualifying(setup):
    """The paper's core guarantee: search traverses only qualifying objects."""
    ds, idx = setup
    for mask in MASKS:
        qlo, qhi = make_queries(ds, mask, 0.1, seed=13)
        ss = MSTGSearcher(idx)
        ids, d = ss.search(ds.queries, qlo, qhi, mask, k=10, ef=32)
        for qi in range(ids.shape[0]):
            got = ids[qi][ids[qi] >= 0]
            sel = np.asarray(iv.eval_predicate(mask, ds.lo[got], ds.hi[got],
                                               qlo[qi], qhi[qi]))
            assert sel.all(), iv.mask_name(mask)


def test_recall_improves_with_ef(setup):
    ds, idx = setup
    mask = ANY_OVERLAP
    qlo, qhi = make_queries(ds, mask, 0.2, seed=17)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries, qlo, qhi, mask, 10)
    ss = MSTGSearcher(idx)
    recalls = []
    for ef in (12, 32, 96):
        gids, _ = ss.search(ds.queries, qlo, qhi, mask, k=10, ef=ef)
        recalls.append(recall_at_k(gids, tids))
    assert recalls[-1] >= recalls[0]
    assert recalls[-1] >= 0.95


def test_empty_predicate_returns_empty(setup):
    ds, idx = setup
    # query range outside any object: QUERY_CONTAINED impossible
    qlo = np.full(4, -50.0)
    qhi = np.full(4, -40.0)
    ss = MSTGSearcher(idx)
    ids, d = ss.search(ds.queries[:4], qlo, qhi, QUERY_CONTAINED, k=5, ef=16)
    assert (ids < 0).all() and np.isinf(d).all()


def test_point_specializations(setup):
    """RFANN/TSANN/IFANN are special cases (paper Table 1)."""
    ds, idx = setup
    # TSANN: point query t inside object range
    t = float(np.median(ds.lo))
    qlo = np.full(8, t)
    qhi = np.full(8, t)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries[:8],
                               qlo, qhi, iv.TSANN_MASK, 10)
    ss = MSTGSearcher(idx)
    gids, _ = ss.search(ds.queries[:8], qlo, qhi, iv.TSANN_MASK, k=10, ef=48)
    assert recall_at_k(gids, tids) >= 0.85


def test_index_accounting(built_index):
    idx = built_index
    assert set(idx.variants) == {"T", "Tp", "Tpp"}
    for fv in idx.variants.values():
        assert fv.nbr.shape == fv.lab_b.shape == fv.lab_e.shape
        assert fv.live_edges() > 0
    assert idx.index_bytes() > 0
    assert all(t > 0 for t in idx.build_seconds.values())


def test_plan_batch_alignment(built_index):
    idx = built_index
    qlo = np.array([10.0, 500.0, 900.0])
    qhi = np.array([20.0, 700.0, 990.0])
    plans = idx.plan_batch(ANY_OVERLAP, qlo, qhi)
    assert [p[0] for p in plans] == ["T", "Tp"]
    for _, ver, klo, khi in plans:
        assert ver.shape == (3,)


def test_blocked_flat_matches_full(setup):
    """§Perf iteration 6 engine: scanned running top-k == full brute force."""
    import jax.numpy as jnp
    from repro.core.flat import flat_search, flat_search_blocked
    ds, idx = setup
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.2, seed=23)
    args = (jnp.asarray(ds.vectors), jnp.asarray(ds.lo, jnp.float32),
            jnp.asarray(ds.hi, jnp.float32), jnp.asarray(ds.queries),
            jnp.asarray(qlo, jnp.float32), jnp.asarray(qhi, jnp.float32))
    a = flat_search(*args, mask=ANY_OVERLAP, k=10)
    b = flat_search_blocked(*args, mask=ANY_OVERLAP, k=10, block=128)
    np.testing.assert_allclose(np.sort(np.asarray(a[1]), 1),
                               np.sort(np.asarray(b[1]), 1),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("fanout", [2, 4])
def test_graph_engine_fanout_recall(setup, fanout):
    """§Perf iteration 3: multi-expansion keeps (or improves) recall."""
    ds, idx = setup
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.15, seed=29)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                               qlo, qhi, ANY_OVERLAP, 10)
    ss = MSTGSearcher(idx)
    base, _ = ss.search(ds.queries, qlo, qhi, ANY_OVERLAP, k=10, ef=48)
    fast, _ = ss.search(ds.queries, qlo, qhi, ANY_OVERLAP, k=10, ef=48,
                        fanout=fanout)
    assert recall_at_k(fast, tids) >= recall_at_k(base, tids) - 0.05
    # fanout results still satisfy the predicate
    for qi in range(fast.shape[0]):
        got = fast[qi][fast[qi] >= 0]
        sel = np.asarray(iv.eval_predicate(ANY_OVERLAP, ds.lo[got], ds.hi[got],
                                           qlo[qi], qhi[qi]))
        assert sel.all()
