"""MSTG end-to-end: exactness of flat/pruned engines, recall of the graph
engine, index accounting, and plan/batch machinery (paper §4, §5), all on the
declarative SearchRequest surface."""
import numpy as np
import pytest

from repro.core import (ANY_OVERLAP, QUERY_CONTAINED, QUERY_CONTAINING,
                        LEFT_OVERLAP, RIGHT_OVERLAP, QueryEngine,
                        SearchRequest, intervals as iv)
from repro.data import make_queries, brute_force_topk

MASKS = [
    ANY_OVERLAP,
    QUERY_CONTAINED,
    QUERY_CONTAINING,
    LEFT_OVERLAP,
    RIGHT_OVERLAP,
    LEFT_OVERLAP | RIGHT_OVERLAP,
    QUERY_CONTAINED | QUERY_CONTAINING,
    LEFT_OVERLAP | QUERY_CONTAINED | RIGHT_OVERLAP,
]


@pytest.fixture(scope="module")
def setup(small_ds, built_index):
    return small_ds, built_index


@pytest.fixture(scope="module")
def engine(built_index):
    return QueryEngine(built_index)


def _search(eng, queries, qlo, qhi, mask, route, k=10, ef=64, fanout=1):
    return eng.search(SearchRequest(queries, (qlo, qhi), mask, k=k, ef=ef,
                                    fanout=fanout, route=route))


@pytest.mark.parametrize("mask", MASKS, ids=iv.mask_name)
def test_flat_engines_exact(setup, engine, mask):
    ds, idx = setup
    qlo, qhi = make_queries(ds, mask, 0.15, seed=7)
    tids, tds = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries, qlo, qhi, mask, 10)
    flat = _search(engine, ds.queries, qlo, qhi, mask, "flat")
    np.testing.assert_allclose(np.sort(flat.dists, axis=1),
                               np.sort(tds, axis=1), rtol=1e-4, atol=1e-4)
    pruned = _search(engine, ds.queries, qlo, qhi, mask, "pruned")
    np.testing.assert_allclose(np.sort(pruned.dists, axis=1),
                               np.sort(tds, axis=1), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mask", MASKS, ids=iv.mask_name)
def test_graph_engine_recall(setup, engine, mask):
    ds, idx = setup
    qlo, qhi = make_queries(ds, mask, 0.15, seed=11)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries, qlo, qhi, mask, 10)
    res = _search(engine, ds.queries, qlo, qhi, mask, "graph", ef=48)
    assert res.recall_vs(tids) >= 0.85, iv.mask_name(mask)


def test_graph_engine_never_returns_nonqualifying(setup, engine):
    """The paper's core guarantee: search traverses only qualifying objects."""
    ds, idx = setup
    for mask in MASKS:
        qlo, qhi = make_queries(ds, mask, 0.1, seed=13)
        res = _search(engine, ds.queries, qlo, qhi, mask, "graph", ef=32)
        for qi, hit in enumerate(res):
            got = hit.ids[hit.valid]
            sel = np.asarray(iv.eval_predicate(mask, ds.lo[got], ds.hi[got],
                                               qlo[qi], qhi[qi]))
            assert sel.all(), iv.mask_name(mask)


def test_recall_improves_with_ef(setup, engine):
    ds, idx = setup
    mask = ANY_OVERLAP
    qlo, qhi = make_queries(ds, mask, 0.2, seed=17)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries, qlo, qhi, mask, 10)
    recalls = []
    for ef in (12, 32, 96):
        res = _search(engine, ds.queries, qlo, qhi, mask, "graph", ef=ef)
        recalls.append(res.recall_vs(tids))
    assert recalls[-1] >= recalls[0]
    assert recalls[-1] >= 0.95


def test_empty_predicate_returns_empty(setup, engine):
    ds, idx = setup
    # query range outside any object: QUERY_CONTAINED impossible
    qlo = np.full(4, -50.0)
    qhi = np.full(4, -40.0)
    res = _search(engine, ds.queries[:4], qlo, qhi, QUERY_CONTAINED, "graph",
                  k=5, ef=16)
    assert (res.ids < 0).all() and np.isinf(res.dists).all()
    assert not res.valid_mask.any()


def test_point_specializations(setup, engine):
    """RFANN/TSANN/IFANN are special cases (paper Table 1)."""
    ds, idx = setup
    # TSANN: point query t inside object range
    t = float(np.median(ds.lo))
    qlo = np.full(8, t)
    qhi = np.full(8, t)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries[:8],
                               qlo, qhi, iv.TSANN_MASK, 10)
    res = _search(engine, ds.queries[:8], qlo, qhi, iv.TSANN_MASK, "graph",
                  ef=48)
    assert res.recall_vs(tids) >= 0.85


def test_index_accounting(built_index):
    idx = built_index
    assert set(idx.variants) == {"T", "Tp", "Tpp"}
    for fv in idx.variants.values():
        assert fv.nbr.shape == fv.lab_b.shape == fv.lab_e.shape
        assert fv.live_edges() > 0
    assert idx.index_bytes() > 0
    assert all(t > 0 for t in idx.build_seconds.values())


def test_plan_batch_alignment(built_index):
    idx = built_index
    qlo = np.array([10.0, 500.0, 900.0])
    qhi = np.array([20.0, 700.0, 990.0])
    plans = idx.plan_batch(ANY_OVERLAP, qlo, qhi)
    assert [p[0] for p in plans] == ["T", "Tp"]
    for _, ver, klo, khi in plans:
        assert ver.shape == (3,)


def test_blocked_flat_matches_full(setup):
    """§Perf iteration 6 engine: scanned running top-k == full brute force."""
    import jax.numpy as jnp
    from repro.core.flat import flat_search, flat_search_blocked
    ds, idx = setup
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.2, seed=23)
    args = (jnp.asarray(ds.vectors), jnp.asarray(ds.lo, jnp.float32),
            jnp.asarray(ds.hi, jnp.float32), jnp.asarray(ds.queries),
            jnp.asarray(qlo, jnp.float32), jnp.asarray(qhi, jnp.float32))
    a = flat_search(*args, mask=ANY_OVERLAP, k=10)
    b = flat_search_blocked(*args, mask=ANY_OVERLAP, k=10, block=128)
    np.testing.assert_allclose(np.sort(np.asarray(a[1]), 1),
                               np.sort(np.asarray(b[1]), 1),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("fanout", [2, 4])
def test_graph_engine_fanout_recall(setup, engine, fanout):
    """§Perf iteration 3: multi-expansion keeps (or improves) recall."""
    ds, idx = setup
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.15, seed=29)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                               qlo, qhi, ANY_OVERLAP, 10)
    base = _search(engine, ds.queries, qlo, qhi, ANY_OVERLAP, "graph", ef=48)
    fast = _search(engine, ds.queries, qlo, qhi, ANY_OVERLAP, "graph", ef=48,
                   fanout=fanout)
    assert fast.recall_vs(tids) >= base.recall_vs(tids) - 0.05
    # fanout results still satisfy the predicate
    for qi, hit in enumerate(fast):
        got = hit.ids[hit.valid]
        sel = np.asarray(iv.eval_predicate(ANY_OVERLAP, ds.lo[got], ds.hi[got],
                                           qlo[qi], qhi[qi]))
        assert sel.all()
